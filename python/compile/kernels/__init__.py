# build-time compile package
