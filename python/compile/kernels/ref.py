"""Pure-jnp oracles for the Pallas kernels (correctness ground truth).

Every Pallas kernel in this package has an exact reference here; pytest
(including the hypothesis shape/dtype sweeps in ``tests/test_kernel.py``)
asserts allclose between kernel and oracle.
"""

import jax.numpy as jnp


def rbf_gram_ref(x, y, ell, sf2):
    """Dense RBF gram matrix: sf2 * exp(-||x - y||^2 / (2 ell^2))."""
    xx = jnp.sum(x * x, axis=1)[:, None]
    yy = jnp.sum(y * y, axis=1)[None, :]
    d2 = jnp.maximum(xx + yy - 2.0 * (x @ y.T), 0.0)
    return sf2 * jnp.exp(-d2 / (2.0 * ell * ell))


def ata_ref(a):
    """G = A^T A."""
    return a.T @ a


def chol_solve_ref(k, y, sigma2):
    """(K + sigma2 I)^{-1} y via Cholesky."""
    kp = k + sigma2 * jnp.eye(k.shape[0], dtype=k.dtype)
    c = jnp.linalg.cholesky(kp)
    z = jnp.linalg.solve(c, y)
    return jnp.linalg.solve(c.T, z)
