"""Layer-1 Pallas kernel: tiled RBF (Gaussian) gram-matrix tile.

Computes out[i, j] = sf2 * exp(-||x_i - y_j||^2 / (2 * ell^2)) for a tile of
points, as a single fused kernel:

  * the pairwise-distance contraction ``x @ y.T`` targets the MXU (it is a
    (T, D) x (D, T) matmul — bf16-friendly on real hardware, f32/f64 here);
  * the squared-norm broadcast and the ``exp`` run on the VPU in the same
    kernel invocation, so each tile makes exactly one HBM->VMEM round trip.

The BlockSpec schedule tiles the full gram matrix over an (n/T, m/T) grid;
both point blocks are staged into VMEM. With T = 128 and D <= 64 in f32,
the working set per grid step is 2*T*D + T*T floats ~ 128 KiB, far inside
the ~16 MiB VMEM budget — chosen so that on a real TPU the kernel is
MXU-bound, not HBM-bound (see DESIGN.md "Hardware-Adaptation").

NOTE: ``interpret=True`` is mandatory here — on CPU the Mosaic lowering
is unavailable; interpret mode lowers to plain HLO so the AOT artifact can
be executed by the rust PJRT CPU client.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile edge (points per block) and the feature-dim padding target.
TILE = 128
MAX_DIM = 32


def _gram_tile_kernel(x_ref, y_ref, ell_ref, sf2_ref, o_ref):
    """One (TILE x TILE) tile: distances via MXU matmul, exp on the VPU."""
    x = x_ref[...]  # (T, D)
    y = y_ref[...]  # (T, D)
    # ||x||^2 + ||y||^2 - 2 x.y — the MXU does the cross term.
    xx = jnp.sum(x * x, axis=1, keepdims=True)         # (T, 1)
    yy = jnp.sum(y * y, axis=1, keepdims=True).T       # (1, T)
    xy = jnp.dot(x, y.T, preferred_element_type=x.dtype)  # (T, T) on MXU
    d2 = jnp.maximum(xx + yy - 2.0 * xy, 0.0)
    inv = 1.0 / (2.0 * ell_ref[0] * ell_ref[0])
    o_ref[...] = sf2_ref[0] * jnp.exp(-d2 * inv)


@functools.partial(jax.jit, static_argnames=())
def gram_tile(x, y, ell, sf2):
    """RBF gram tile for fixed-shape blocks (TILE, MAX_DIM).

    ``ell``/``sf2`` are shape-(1,) arrays so the lowered HLO takes them as
    runtime parameters (no recompilation per length scale).
    """
    assert x.shape == (TILE, MAX_DIM) and y.shape == (TILE, MAX_DIM)
    return pl.pallas_call(
        _gram_tile_kernel,
        out_shape=jax.ShapeDtypeStruct((TILE, TILE), x.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, y, ell, sf2)


def gram_blocked(x, y, ell, sf2, tile=TILE):
    """Full gram matrix via the Pallas tile over a python grid.

    Build-time helper (tests, reference lowering of bigger shapes); the
    rust runtime drives tiling itself and calls the single-tile artifact.
    """
    n, d = x.shape
    m, _ = y.shape
    pad_n = (-n) % tile
    pad_m = (-m) % tile
    pad_d = MAX_DIM - d
    assert pad_d >= 0, f"feature dim {d} exceeds MAX_DIM={MAX_DIM}"
    xp = jnp.pad(x, ((0, pad_n), (0, pad_d)))
    yp = jnp.pad(y, ((0, pad_m), (0, pad_d)))
    rows = []
    for i in range(0, n + pad_n, tile):
        row = []
        for j in range(0, m + pad_m, tile):
            row.append(gram_tile(xp[i:i + tile], yp[j:j + tile], ell, sf2))
        rows.append(jnp.concatenate(row, axis=1))
    return jnp.concatenate(rows, axis=0)[:n, :m]
