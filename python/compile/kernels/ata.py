"""Layer-1 Pallas kernel: blocked symmetric Gram product G = A^T A.

This is the leading cost term of the greedy-Jacobi MMF compression
(paper Prop. 4: "the leading term in the cost is the m^3 cost of computing
A^T A, but this is a BLAS operation"). The kernel accumulates K-blocks of
rows into the (M, M) output:

    G = sum_k A[k*B:(k+1)*B, :]^T @ A[k*B:(k+1)*B, :]

Each grid step stages one (B, M) row-panel into VMEM and performs an
(M, B) x (B, M) MXU contraction — the classic SYRK panel schedule mapped
onto BlockSpec instead of threadblocks (DESIGN.md "Hardware-Adaptation").

``interpret=True`` for CPU-PJRT executability, as everywhere.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block-size of the compressed matrix (MKA cluster blocks are <= 256).
ATA_M = 256
# Row-panel height per grid step.
ATA_B = 64


def _ata_kernel(a_ref, o_ref):
    """Grid over row panels; accumulate panel^T @ panel into the output."""
    k = pl.program_id(0)
    panel = a_ref[...]  # (B, M) — BlockSpec delivers the k-th row panel

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(panel.T, panel, preferred_element_type=panel.dtype)


@functools.partial(jax.jit, static_argnames=())
def ata(a):
    """G = A^T A for a fixed-shape (ATA_M, ATA_M) block."""
    assert a.shape == (ATA_M, ATA_M)
    grid = (ATA_M // ATA_B,)
    return pl.pallas_call(
        _ata_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((ATA_B, ATA_M), lambda k: (k, 0))],
        out_specs=pl.BlockSpec((ATA_M, ATA_M), lambda k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((ATA_M, ATA_M), a.dtype),
        interpret=True,
    )(a)
