"""AOT lowering: JAX functions -> HLO *text* artifacts + manifest.json.

HLO text (NOT ``lowered.compiler_ir(...).serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
the image's xla_extension 0.5.1 (behind the rust ``xla`` crate) rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids and round-trips
cleanly — see /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO module -> XlaComputation -> HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    examples = model.example_args()
    manifest = {
        "dtype": "f64",
        "artifacts": {},
        "shapes": {
            "gram_tile": {"tile": model.GRAM_TILE, "dim": model.GRAM_DIM},
            "ata": {"m": model.ATA_M},
            "chol_solve": {"n": model.CHOL_N},
            "chol_solve_mat": {"n": model.CHOL_N, "b": model.CHOL_B},
        },
    }
    for name, fn in model.EXPORTS.items():
        lowered = jax.jit(fn).lower(*examples[name])
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": fname,
            "n_params": len(examples[name]),
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
            "bytes": len(text),
        }
        print(f"lowered {name}: {len(text)} chars -> {path}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    manifest = lower_all(args.out_dir)
    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
