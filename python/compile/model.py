"""Layer-2 JAX compute graphs — the dense hot spots of MKA-GP, built on the
Layer-1 Pallas kernels and AOT-lowered by ``aot.py``.

Three exported functions (fixed shapes; the rust runtime pads/tiles):

* ``gram_tile_fn``   — one RBF gram tile (Pallas kernel ``kernels.gram``);
* ``ata_fn``         — blocked A^T A for MMF compression (``kernels.ata``);
* ``chol_solve_fn``  — (K + sigma^2 I)^{-1} y at a fixed n, the Full-GP
                       baseline's solve, exercising XLA's fused
                       decomposition path end to end.

Everything is float64 (jax_enable_x64): the rust side works in f64 and the
factorization math is precision sensitive.
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from .kernels import ata as ata_kernel
from .kernels import gram as gram_kernel

# Fixed AOT shapes (mirrored in artifacts/manifest.json).
GRAM_TILE = gram_kernel.TILE
GRAM_DIM = gram_kernel.MAX_DIM
ATA_M = ata_kernel.ATA_M
CHOL_N = 512
# RHS-block width of the multi-RHS solve artifact (rust pads ragged
# column chunks with zero columns).
CHOL_B = 32

DTYPE = jnp.float64


def gram_tile_fn(x, y, ell, sf2):
    """One (TILE, TILE) RBF gram tile; returns a 1-tuple for PJRT."""
    return (gram_kernel.gram_tile(x, y, ell, sf2),)


def ata_fn(a):
    """G = A^T A on a fixed (ATA_M, ATA_M) block."""
    return (ata_kernel.ata(a),)


def chol_solve_fn(k, y, sigma2):
    """alpha = (K + sigma2*I)^{-1} y, fixed shape (CHOL_N, CHOL_N).

    Implemented with Jacobi-preconditioned conjugate gradients rather than
    LAPACK Cholesky: ``cho_factor`` lowers to a typed-FFI custom call that
    the image's xla_extension 0.5.1 (behind the rust ``xla`` crate) cannot
    compile, while CG lowers to a pure-HLO while loop. CG is exact in at
    most n steps for an SPD system; with the σ²-regularized kernel it
    converges to ~1e-12 relative residual long before the iteration cap.

    The rust caller pads K with an identity block (and y with zeros) when
    n < CHOL_N, which leaves the leading alpha entries exact.
    """
    kp = k + sigma2[0] * jnp.eye(CHOL_N, dtype=k.dtype)
    diag_inv = 1.0 / jnp.diagonal(kp)
    alpha, _info = jax.scipy.sparse.linalg.cg(
        lambda v: kp @ v,
        y,
        M=lambda v: diag_inv * v,
        tol=1e-14,
        maxiter=CHOL_N,
    )
    return (alpha,)


def chol_solve_mat_fn(k, ys, sigma2):
    """ALPHA = (K + sigma2*I)^{-1} YS for a (CHOL_N, CHOL_B) RHS block.

    One regularization + one Jacobi preconditioner shared by all columns;
    the CG solve is vmapped over columns, so K is factored/streamed once
    per artifact execution instead of once per right-hand side — this is
    the batched counterpart the rust engine's ``chol_solve_mat`` request
    executes. Zero-padded columns converge instantly (alpha = 0), so the
    rust side's ragged-chunk padding is exact.
    """
    kp = k + sigma2[0] * jnp.eye(CHOL_N, dtype=k.dtype)
    diag_inv = 1.0 / jnp.diagonal(kp)

    def solve_one(y):
        alpha, _info = jax.scipy.sparse.linalg.cg(
            lambda v: kp @ v,
            y,
            M=lambda v: diag_inv * v,
            tol=1e-14,
            maxiter=CHOL_N,
        )
        return alpha

    return (jax.vmap(solve_one, in_axes=1, out_axes=1)(ys),)


def example_args():
    """Concrete example arguments for each exported function."""
    f64 = lambda shape: jnp.zeros(shape, DTYPE)
    return {
        "gram_tile": (
            f64((GRAM_TILE, GRAM_DIM)),
            f64((GRAM_TILE, GRAM_DIM)),
            jnp.ones((1,), DTYPE),
            jnp.ones((1,), DTYPE),
        ),
        "ata": (f64((ATA_M, ATA_M)),),
        "chol_solve": (
            f64((CHOL_N, CHOL_N)),
            f64((CHOL_N,)),
            jnp.ones((1,), DTYPE),
        ),
        "chol_solve_mat": (
            f64((CHOL_N, CHOL_N)),
            f64((CHOL_N, CHOL_B)),
            jnp.ones((1,), DTYPE),
        ),
    }


EXPORTS = {
    "gram_tile": gram_tile_fn,
    "ata": ata_fn,
    "chol_solve": chol_solve_fn,
    "chol_solve_mat": chol_solve_mat_fn,
}
