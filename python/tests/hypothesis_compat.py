"""`hypothesis` front-end with an offline fallback.

The container image does not ship `hypothesis`. Property tests still run:
when the real package is available we re-export it untouched; otherwise a
minimal deterministic substitute sweeps each test over seeded
pseudo-random draws from the declared strategies (plus the strategy
endpoints), which preserves the value-sweep coverage if not the shrinking.
"""

try:  # pragma: no cover - exercised only where hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import random

    HAVE_HYPOTHESIS = False

    _DEFAULT_EXAMPLES = 10

    class _Strategy:
        """A value source: endpoint examples first, then seeded draws."""

        def __init__(self, lo, hi, draw):
            self._lo = lo
            self._hi = hi
            self._draw = draw

        def endpoints(self):
            return [self._lo, self._hi]

        def draw(self, rng):
            return self._draw(rng)

    class _StrategiesModule:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                min_value, max_value, lambda rng: rng.randint(min_value, max_value)
            )

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                min_value, max_value, lambda rng: rng.uniform(min_value, max_value)
            )

    st = _StrategiesModule()

    def settings(max_examples=_DEFAULT_EXAMPLES, **_ignored):
        """Record the example budget on the wrapped test (deadline etc. are
        accepted and ignored)."""

        def wrap(fn):
            fn._compat_max_examples = max_examples
            return fn

        return wrap

    def given(**strategies):
        names = sorted(strategies)

        def wrap(fn):
            def runner(*args, **kwargs):
                budget = getattr(runner, "_compat_max_examples", _DEFAULT_EXAMPLES)
                # Deterministic per-test stream so failures reproduce.
                rng = random.Random(f"hypothesis-compat:{fn.__name__}")
                cases = []
                # Endpoint case: every strategy at its minimum, then maximum.
                cases.append({n: strategies[n].endpoints()[0] for n in names})
                cases.append({n: strategies[n].endpoints()[1] for n in names})
                while len(cases) < max(budget, 2):
                    cases.append({n: strategies[n].draw(rng) for n in names})
                for case in cases[: max(budget, 2)]:
                    try:
                        fn(*args, **kwargs, **case)
                    except Exception:
                        print(f"falsifying example ({fn.__name__}): {case}")
                        raise

            # NOT functools.wraps: copying __wrapped__ would expose the
            # strategy parameters to pytest's fixture resolution.
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            return runner

        return wrap
