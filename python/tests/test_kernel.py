"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/values; fixed-shape tests cover the exact AOT
configurations the rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

jax.config.update("jax_enable_x64", True)

from compile.kernels import ata as ata_kernel
from compile.kernels import gram as gram_kernel
from compile.kernels import ref


def _rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape) * scale, dtype=jnp.float64)


# ---------------------------------------------------------------------------
# gram tile
# ---------------------------------------------------------------------------


class TestGramTile:
    def test_matches_ref_at_aot_shape(self):
        t, d = gram_kernel.TILE, gram_kernel.MAX_DIM
        x = _rand((t, d), 0)
        y = _rand((t, d), 1)
        out = gram_kernel.gram_tile(x, y, jnp.array([0.8]), jnp.array([1.5]))
        expected = ref.rbf_gram_ref(x, y, 0.8, 1.5)
        np.testing.assert_allclose(out, expected, rtol=1e-12, atol=1e-12)

    def test_symmetric_when_x_equals_y(self):
        t, d = gram_kernel.TILE, gram_kernel.MAX_DIM
        x = _rand((t, d), 2)
        out = np.asarray(
            gram_kernel.gram_tile(x, x, jnp.array([1.0]), jnp.array([1.0]))
        )
        np.testing.assert_allclose(out, out.T, rtol=1e-12)
        np.testing.assert_allclose(np.diag(out), 1.0, rtol=1e-12)

    def test_zero_padding_rows_are_harmless(self):
        # rust pads short blocks with zero rows; the valid region must be
        # unaffected.
        t, d = gram_kernel.TILE, gram_kernel.MAX_DIM
        x = _rand((t, d), 3)
        xz = x.at[t // 2 :, :].set(0.0)
        out = gram_kernel.gram_tile(xz, xz, jnp.array([1.0]), jnp.array([1.0]))
        expected = ref.rbf_gram_ref(xz[: t // 2], xz[: t // 2], 1.0, 1.0)
        np.testing.assert_allclose(out[: t // 2, : t // 2], expected, rtol=1e-12)

    def test_lengthscale_is_runtime_parameter(self):
        t, d = gram_kernel.TILE, gram_kernel.MAX_DIM
        x = _rand((t, d), 4)
        y = _rand((t, d), 5)
        for ell in (0.25, 1.0, 4.0):
            out = gram_kernel.gram_tile(x, y, jnp.array([ell]), jnp.array([1.0]))
            expected = ref.rbf_gram_ref(x, y, ell, 1.0)
            np.testing.assert_allclose(out, expected, rtol=1e-12, atol=1e-12)

    @settings(max_examples=20, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        ell=st.floats(0.05, 10.0),
        sf2=st.floats(0.1, 5.0),
        scale=st.floats(0.1, 3.0),
    )
    def test_hypothesis_values(self, seed, ell, sf2, scale):
        t, d = gram_kernel.TILE, gram_kernel.MAX_DIM
        x = _rand((t, d), seed, scale)
        y = _rand((t, d), seed + 1, scale)
        out = gram_kernel.gram_tile(x, y, jnp.array([ell]), jnp.array([sf2]))
        expected = ref.rbf_gram_ref(x, y, ell, sf2)
        np.testing.assert_allclose(out, expected, rtol=1e-10, atol=1e-12)

    @settings(max_examples=10, deadline=None)
    @given(
        n=st.integers(1, 200),
        m=st.integers(1, 200),
        d=st.integers(1, gram_kernel.MAX_DIM),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_blocked_shapes(self, n, m, d, seed):
        # the tiled driver must agree with the oracle for ragged shapes
        x = _rand((n, d), seed)
        y = _rand((m, d), seed + 7)
        out = gram_kernel.gram_blocked(x, y, jnp.array([1.3]), jnp.array([1.0]), tile=gram_kernel.TILE)
        expected = ref.rbf_gram_ref(x, y, 1.3, 1.0)
        np.testing.assert_allclose(out, expected, rtol=1e-10, atol=1e-12)


# ---------------------------------------------------------------------------
# A^T A
# ---------------------------------------------------------------------------


class TestAta:
    def test_matches_ref_at_aot_shape(self):
        a = _rand((ata_kernel.ATA_M, ata_kernel.ATA_M), 10)
        out = ata_kernel.ata(a)
        np.testing.assert_allclose(out, ref.ata_ref(a), rtol=1e-11, atol=1e-11)

    def test_output_symmetric_psd_diag(self):
        a = _rand((ata_kernel.ATA_M, ata_kernel.ATA_M), 11)
        out = np.asarray(ata_kernel.ata(a))
        np.testing.assert_allclose(out, out.T, rtol=1e-11)
        assert (np.diag(out) >= 0).all()

    def test_zero_padding_is_exact(self):
        # rust pads smaller blocks with zeros: G of the padded matrix must
        # embed G of the original.
        m = ata_kernel.ATA_M
        a_small = _rand((m // 2, m // 2), 12)
        a = jnp.zeros((m, m), jnp.float64).at[: m // 2, : m // 2].set(a_small)
        out = ata_kernel.ata(a)
        np.testing.assert_allclose(
            out[: m // 2, : m // 2], ref.ata_ref(a_small), rtol=1e-11, atol=1e-11
        )
        np.testing.assert_allclose(out[m // 2 :, :], 0.0, atol=1e-14)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), scale=st.floats(0.01, 10.0))
    def test_hypothesis_values(self, seed, scale):
        a = _rand((ata_kernel.ATA_M, ata_kernel.ATA_M), seed, scale)
        out = ata_kernel.ata(a)
        np.testing.assert_allclose(out, ref.ata_ref(a), rtol=1e-9, atol=1e-9)
