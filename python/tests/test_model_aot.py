"""Layer-2 + AOT path: exported functions, lowering, manifest integrity."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from compile import aot, model
from compile.kernels import ref


class TestModelFunctions:
    def test_chol_solve_matches_ref(self):
        rng = np.random.default_rng(0)
        n = model.CHOL_N
        b = jnp.asarray(rng.normal(size=(n, n + 5)))
        k = (b @ b.T) / n
        y = jnp.asarray(rng.normal(size=(n,)))
        (out,) = model.chol_solve_fn(k, y, jnp.array([0.1]))
        expected = ref.chol_solve_ref(k, y, 0.1)
        np.testing.assert_allclose(out, expected, rtol=1e-8, atol=1e-8)

    def test_chol_solve_identity_padding_contract(self):
        # The rust runtime pads K with an identity block; leading entries of
        # alpha must equal the unpadded solve.
        rng = np.random.default_rng(1)
        n_small = 100
        n = model.CHOL_N
        b = jnp.asarray(rng.normal(size=(n_small, n_small + 5)))
        k_small = (b @ b.T) / n_small
        y_small = jnp.asarray(rng.normal(size=(n_small,)))
        k = jnp.eye(n, dtype=jnp.float64).at[:n_small, :n_small].set(k_small)
        y = jnp.zeros((n,), jnp.float64).at[:n_small].set(y_small)
        (out,) = model.chol_solve_fn(k, y, jnp.array([0.05]))
        expected = ref.chol_solve_ref(k_small, y_small, 0.05)
        np.testing.assert_allclose(out[:n_small], expected, rtol=1e-8, atol=1e-8)
        np.testing.assert_allclose(out[n_small:], 0.0, atol=1e-12)

    def test_chol_solve_mat_matches_per_column(self):
        rng = np.random.default_rng(2)
        n, b = model.CHOL_N, model.CHOL_B
        a = jnp.asarray(rng.normal(size=(n, n + 5)))
        k = (a @ a.T) / n
        ys = jnp.asarray(rng.normal(size=(n, b)))
        (out,) = model.chol_solve_mat_fn(k, ys, jnp.array([0.1]))
        assert out.shape == (n, b)
        for j in range(0, b, 7):
            expected = ref.chol_solve_ref(k, ys[:, j], 0.1)
            np.testing.assert_allclose(out[:, j], expected, rtol=1e-8, atol=1e-8)

    def test_chol_solve_mat_zero_columns_stay_zero(self):
        # rust pads ragged chunks with zero columns; they must come back 0.
        rng = np.random.default_rng(3)
        n, b = model.CHOL_N, model.CHOL_B
        a = jnp.asarray(rng.normal(size=(n, n + 5)))
        k = (a @ a.T) / n
        ys = jnp.zeros((n, b), jnp.float64).at[:, 0].set(
            jnp.asarray(rng.normal(size=(n,)))
        )
        (out,) = model.chol_solve_mat_fn(k, ys, jnp.array([0.1]))
        np.testing.assert_allclose(out[:, 1:], 0.0, atol=1e-12)
        np.testing.assert_allclose(
            out[:, 0], ref.chol_solve_ref(k, ys[:, 0], 0.1), rtol=1e-8, atol=1e-8
        )

    def test_exports_run_on_examples(self):
        examples = model.example_args()
        for name, fn in model.EXPORTS.items():
            out = fn(*examples[name])
            assert isinstance(out, tuple) and len(out) == 1, name
            assert jnp.all(jnp.isfinite(out[0])), name


class TestAotLowering:
    def test_hlo_text_wellformed(self):
        examples = model.example_args()
        lowered = jax.jit(model.EXPORTS["gram_tile"]).lower(*examples["gram_tile"])
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule")
        assert "ENTRY" in text
        # f64 end to end
        assert "f64" in text

    def test_lower_all_writes_manifest(self, tmp_path):
        manifest = aot.lower_all(str(tmp_path))
        assert set(manifest["artifacts"]) == {
            "gram_tile",
            "ata",
            "chol_solve",
            "chol_solve_mat",
        }
        for name, meta in manifest["artifacts"].items():
            p = tmp_path / meta["file"]
            assert p.exists(), name
            text = p.read_text()
            assert text.startswith("HloModule"), name
            assert meta["bytes"] == len(text)
        # manifest dumps as valid json
        s = json.dumps(manifest)
        assert "gram_tile" in s

    def test_lowering_deterministic(self, tmp_path):
        m1 = aot.lower_all(str(tmp_path / "a"))
        m2 = aot.lower_all(str(tmp_path / "b"))
        for name in m1["artifacts"]:
            assert m1["artifacts"][name]["sha256"] == m2["artifacts"][name]["sha256"]
