"""Test bootstrap: make `compile.*` and sibling test helpers importable
regardless of the pytest invocation directory (repo root, python/, or
python/tests)."""

import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
_PY_ROOT = os.path.dirname(_HERE)  # python/

for p in (_PY_ROOT, _HERE):
    if p not in sys.path:
        sys.path.insert(0, p)
