//! Bench: §4's sparse-kernel claim — diffusion kernels exp(−βL) from a
//! sparse graph Laplacian. MKA factorizes L once, then exp/logdet are
//! O(n + d³) (Prop. 7); the dense oracle needs an O(n³) EVD.
//!
//!     cargo bench --bench graph_diffusion [-- --sizes 256,512,1024,2048]

use mka_gp::bench::{bench_budget, fmt_secs, Table};
use mka_gp::data::synth::clustered_features;
use mka_gp::kernels::graph::{diffusion_dense, knn_graph};
use mka_gp::la::gemv;
use mka_gp::mka::{factorize, MkaConfig};
use mka_gp::util::{Args, Rng, Timer};

fn main() {
    let args = Args::from_env(false);
    let sizes = args.get_usize_list("sizes", &[256, 512, 1024, 2048]);
    let beta = args.get_f64("beta", 0.5);

    println!("=== §4: diffusion kernel exp(−βL) — MKA direct vs dense EVD ===\n");
    let mut table =
        Table::new(&["n", "nnz(L)", "factorize", "exp-apply", "dense-EVD", "rel-err", "logdet"]);
    let mut rng = Rng::new(9);
    for &n in &sizes {
        // structured kNN graph over clustered points — the regime where the
        // "distant clusters interact in a low-rank way" assumption holds
        // (a uniformly random expander has no multiscale structure and is
        // MKA's worst case; see the ablation notes in EXPERIMENTS.md)
        let x = clustered_features(n, 2, 12, &mut rng);
        let g = knn_graph(&x, 4, 1.0);
        let lap = g.laplacian();
        let ld = lap.to_dense();
        let cfg = MkaConfig { d_core: args.get_usize("d-core", 128), block_size: 64, gamma: 0.6, ..MkaConfig::default() };
        let t = Timer::start();
        let f = factorize(&ld, None, &cfg).expect("factorize");
        let fact_s = t.elapsed_secs();

        // smooth probe vector (diffusion of a smooth field)
        let v: Vec<f64> = (0..n).map(|i| ((i as f64) * 0.01).sin()).collect();
        let ap = bench_budget("exp", 0.3, 50, || {
            std::hint::black_box(f.exp_apply(-beta, &v));
        });

        // dense oracle (skip at large n; extrapolate cubically)
        let (dense_s, rel) = if n <= 1024 {
            let t = Timer::start();
            let exact = diffusion_dense(&g, beta);
            let dense_s = t.elapsed_secs();
            let ev = gemv(&exact, &v);
            let av = f.exp_apply(-beta, &v);
            let num: f64 = av.iter().zip(&ev).map(|(a, b)| (a - b) * (a - b)).sum();
            let den: f64 = ev.iter().map(|x| x * x).sum();
            (fmt_secs(dense_s), format!("{:.2e}", (num / den.max(1e-300)).sqrt()))
        } else {
            ("-".into(), "-".into())
        };

        let mut lreg = ld.clone();
        lreg.add_diag(0.1);
        let freg = factorize(&lreg, None, &cfg).unwrap();
        let t = Timer::start();
        let logdet = freg.logdet().unwrap();
        let ld_s = t.elapsed_secs();

        table.row(&[
            n.to_string(),
            lap.nnz().to_string(),
            fmt_secs(fact_s),
            fmt_secs(ap.mean_s),
            dense_s,
            rel,
            format!("{logdet:.0} ({})", fmt_secs(ld_s)),
        ]);
    }
    table.print();
    println!("\nexpected shape: factorize + exp-apply stay near-linear in n while the");
    println!("dense EVD oracle grows cubically — §4's claim is about *time* (\"can be");
    println!("approximated in about O(n log n) time\"), which this reproduces.");
    println!("accuracy note: rel-err is reported for transparency — diffusion weights");
    println!("the *bottom* of the Laplacian spectrum, whose smooth eigenvectors spread");
    println!("across blocks; core-diagonal truncation (any compressor) cannot represent");
    println!("them as independent wavelet diagonals, so pointwise accuracy is limited.");
    println!("(GP kernels are the opposite regime: the σ² floor protects the inverse.)");
}
