//! Bench: coordinator overhead and batching behaviour (E8) — router
//! dispatch latency, TCP round-trip latency, and the effect of the dynamic
//! prediction batcher under concurrent clients.
//!
//!     cargo bench --bench coordinator_perf [-- --clients 8]
//!
//! `--json` mode benches the sharded serving plane instead: fit, predict
//! and retune wall time vs shard count, asserting sharded predictions are
//! bit-identical at every thread count, plus the streaming plane's
//! observe-vs-refit wall-time gap and the predict-path cache's
//! repeat-test-set burst (cold vs hot p50/p99, hit rate, factorization
//! delta, tiled-assembly savings), written to `BENCH_shard.json`:
//!
//!     cargo bench --bench coordinator_perf -- --json \
//!         [--n 960] [--shards 1,2,4] [--threads 1,2,4] [--k 24] \
//!         [--out ../BENCH_shard.json]

use std::sync::Arc;

use mka_gp::bench::{bench, fmt_secs, Table};
use mka_gp::coordinator::{Client, Router, Server, ServiceConfig};
use mka_gp::data::synth::{gp_dataset, SynthSpec};
use mka_gp::experiments::methods::mka_config_for;
use mka_gp::gp::sharded::ShardedGp;
use mka_gp::gp::{ObservePath, ObservePolicy};
use mka_gp::prelude::*;
use mka_gp::util::Timer;

fn main() {
    let args = Args::from_env(false);
    if args.has_flag("json") {
        run_shard_json_bench(&args);
        return;
    }
    let n_clients = args.get_usize("clients", 8);

    // Service with a published MKA model.
    let cfg = ServiceConfig { port: 0, n_workers: 2, batch_window_ms: 3, ..Default::default() };
    let router = Arc::new(Router::new(cfg));
    let data = gp_dataset(&SynthSpec::named("perf", 600, 4), 3);
    let (tr, te) = data.split(0.9, 1);
    let kern = RbfKernel::new(0.8);
    let model =
        MkaGp::fit(&tr, &kern, 0.1, &MkaConfig { d_core: 32, block_size: 128, ..Default::default() })
            .unwrap();
    router.registry.publish("m", Arc::new(model));
    let server = Server::start(Arc::clone(&router), "127.0.0.1", 0).unwrap();
    let addr = format!("{}", server.addr());

    println!("=== Coordinator performance ===\n");
    let mut table = Table::new(&["op", "p50", "p95", "mean"]);

    // 1. Router dispatch (in-process, no TCP).
    let ping = Json::parse(r#"{"op":"ping"}"#).unwrap();
    let st = bench("router-ping", 50, 2000, || {
        std::hint::black_box(router.handle(&ping));
    });
    table.row(&["router ping".into(), fmt_secs(st.p50_s), fmt_secs(st.p95_s), fmt_secs(st.mean_s)]);

    // 2. TCP round trip.
    let mut client = Client::connect(&addr).unwrap();
    let st = bench("tcp-ping", 20, 500, || {
        std::hint::black_box(client.call(&ping).unwrap());
    });
    table.row(&["tcp ping".into(), fmt_secs(st.p50_s), fmt_secs(st.p95_s), fmt_secs(st.mean_s)]);

    // 3. Single predict (1 point) over TCP.
    let one = Json::obj()
        .with("op", Json::Str("predict".into()))
        .with("model", Json::Str("m".into()))
        .with("x", Json::Arr(vec![Json::from_f64_slice(te.x.row(0))]));
    let st = bench("tcp-predict-1", 3, 20, || {
        std::hint::black_box(client.call(&one).unwrap());
    });
    table.row(&["predict x1".into(), fmt_secs(st.p50_s), fmt_secs(st.p95_s), fmt_secs(st.mean_s)]);

    // 4. Batched predict (32 points) over TCP.
    let x32: Vec<Json> = (0..32.min(te.n())).map(|i| Json::from_f64_slice(te.x.row(i))).collect();
    let batch = Json::obj()
        .with("op", Json::Str("predict".into()))
        .with("model", Json::Str("m".into()))
        .with("x", Json::Arr(x32));
    let st = bench("tcp-predict-32", 3, 15, || {
        std::hint::black_box(client.call(&batch).unwrap());
    });
    table.row(&["predict x32".into(), fmt_secs(st.p50_s), fmt_secs(st.p95_s), fmt_secs(st.mean_s)]);
    table.print();

    // 5. Concurrent clients: batching amortizes the factorization.
    println!("\nconcurrent predict ({n_clients} clients × 1 point each):");
    let t = Timer::start();
    let handles: Vec<_> = (0..n_clients)
        .map(|i| {
            let addr = addr.clone();
            let row = te.x.row(i % te.n()).to_vec();
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                let req = Json::obj()
                    .with("op", Json::Str("predict".into()))
                    .with("model", Json::Str("m".into()))
                    .with("x", Json::Arr(vec![Json::from_f64_slice(&row)]));
                c.call(&req).unwrap()
            })
        })
        .collect();
    for h in handles {
        let resp = h.join().unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
    }
    let wall = t.elapsed_secs();
    let snap = router.metrics.snapshot();
    let batches = snap.get("counters").and_then(|c| c.num_field("batches")).unwrap_or(0.0);
    let preds = snap.get("counters").and_then(|c| c.num_field("predictions")).unwrap_or(0.0);
    println!(
        "  wall {:.2}s | {} predictions served in {} model calls (batching gain {:.1}x)",
        wall,
        preds,
        batches,
        preds / batches.max(1.0)
    );

    // 6. Blocked multi-RHS execution: one b-point predict (one joint
    // factorization + ONE blocked cascade for all b+1 right-hand sides)
    // vs b independent per-vector predicts. This is the acceptance
    // comparison for the blocked path: the batched predict must beat b
    // independent predicts at b >= 32.
    let b = args.get_usize("batch", 32).min(te.n());
    let model = router.registry.get("m").expect("model published");
    println!("\nblocked multi-RHS predict (b = {b}):");
    let xb = te.x.block(0, b, 0, te.x.cols);
    let c0 = mka_gp::mka::cascade_count();
    let t = Timer::start();
    let batched = model.predict(&xb);
    let batched_s = t.elapsed_secs();
    let batched_cascades = mka_gp::mka::cascade_count() - c0;
    let c0 = mka_gp::mka::cascade_count();
    let t = Timer::start();
    let mut singles = Vec::with_capacity(b);
    for i in 0..b {
        let xi = te.x.block(i, i + 1, 0, te.x.cols);
        singles.push(model.predict(&xi).mean[0]);
    }
    let serial_s = t.elapsed_secs();
    let serial_cascades = mka_gp::mka::cascade_count() - c0;
    assert_eq!(batched.mean.len(), b);
    println!(
        "  batched x{b}: {} ({batched_cascades} cascades) | {b} × x1: {} ({serial_cascades} cascades) | speedup {:.1}x",
        fmt_secs(batched_s),
        fmt_secs(serial_s),
        serial_s / batched_s.max(1e-12)
    );
    if batched_s < serial_s {
        println!("  OK: batched predict beats {b} independent per-vector predicts");
    } else {
        println!("  WARN: batched predict did NOT beat independent predicts");
    }
}

/// `--json` mode: the sharded serving plane's scaling trajectory — fit,
/// predict and retune wall time vs shard count, with bit-determinism
/// asserts across thread counts — plus the streaming plane's
/// observe-vs-refit wall-time comparison, written to `BENCH_shard.json`.
fn run_shard_json_bench(args: &Args) {
    let n = args.get_usize("n", 960);
    let shard_counts = args.get_usize_list("shards", &[1, 2, 4]);
    let threads_list = args.get_usize_list("threads", &[1, 2, 4]);
    let k = args.get_usize("k", 24);
    let out_path = args.get_or("out", "../BENCH_shard.json").to_string();

    let data = gp_dataset(&SynthSpec::named("shardperf", n, 4), 3);
    let (tr, te) = data.split(0.9, 1);
    let kern = RbfKernel::new(1.0);
    let cfg = mka_config_for(k, tr.n(), 7);

    let mut results: Vec<Json> = Vec::new();
    // fit wall at the highest thread count, per shard count — the
    // fit-scaling acceptance series (shards=1 entry is the baseline).
    let mut fit_walls: Vec<(usize, f64)> = Vec::new();
    for &s in &shard_counts {
        let mut ref_bits: Option<Vec<u64>> = None;
        let mut last_fit_s: Option<f64> = None;
        for &t in &threads_list {
            mka_gp::par::set_threads(t);
            let t_fit = Timer::start();
            let fleet = ShardedGp::fit(&tr, &kern, 0.1, &cfg, s, ClusterMethod::KMeans)
                .expect("sharded fit");
            let fit_s = t_fit.elapsed_secs();
            // Predict latency as a distribution over repeated warm runs
            // (first call warms the arenas), matching BENCH_perf.json:
            // min + p50/p95/p99.
            let pred = fleet.predict(&te.x);
            let mut lat = Vec::with_capacity(7);
            for _ in 0..7 {
                let t_pred = Timer::start();
                let again = fleet.predict(&te.x);
                lat.push(t_pred.elapsed_secs());
                assert_eq!(again.mean.len(), pred.mean.len());
            }
            lat.sort_by(|a, b| a.total_cmp(b));
            let predict_s = lat[0];
            let predict_p50 = mka_gp::la::stats::quantile_sorted(&lat, 0.5);
            let predict_p95 = mka_gp::la::stats::quantile_sorted(&lat, 0.95);
            let predict_p99 = mka_gp::la::stats::quantile_sorted(&lat, 0.99);
            // Serving-plane retune: O(shards) spectrum shifts, never a
            // refit — must stay orders of magnitude under fit_s.
            let t_ret = Timer::start();
            let retuned = fleet.retuned(0.25).expect("retune");
            let retune_s = t_ret.elapsed_secs();
            assert_eq!(retuned.sigma2(), 0.25);

            // PR-2 determinism contract through the fleet: the same shard
            // count must produce bit-identical posteriors at any thread
            // count.
            let bits: Vec<u64> =
                pred.mean.iter().chain(pred.var.iter()).map(|v| v.to_bits()).collect();
            match &ref_bits {
                None => ref_bits = Some(bits),
                Some(r) => assert_eq!(
                    r, &bits,
                    "sharded predict at {t} threads must be bit-identical (shards={s})"
                ),
            }

            let e = smse(&te.y, &pred.mean);
            println!(
                "shards={s} ({} effective) t={t}: fit {} predict {} retune {} ({:.0}x) smse {:.3}",
                fleet.n_shards(),
                fmt_secs(fit_s),
                fmt_secs(predict_s),
                fmt_secs(retune_s),
                fit_s / retune_s.max(1e-12),
                e
            );
            results.push(
                Json::obj()
                    .with("shards", Json::Num(s as f64))
                    .with("effective_shards", Json::Num(fleet.n_shards() as f64))
                    .with("threads", Json::Num(t as f64))
                    .with("n", Json::Num(tr.n() as f64))
                    .with("fit_s", Json::Num(fit_s))
                    .with("predict_s", Json::Num(predict_s))
                    .with("predict_p50_s", Json::Num(predict_p50))
                    .with("predict_p95_s", Json::Num(predict_p95))
                    .with("predict_p99_s", Json::Num(predict_p99))
                    .with("retune_s", Json::Num(retune_s))
                    .with("retune_speedup", Json::Num(fit_s / retune_s.max(1e-12)))
                    .with("smse", Json::Num(e))
                    .with("bit_identical", Json::Bool(true)),
            );
            last_fit_s = Some(fit_s);
        }
        if let Some(fit_s) = last_fit_s {
            fit_walls.push((s, fit_s));
        }
    }

    // Fit scaling vs the unsharded baseline (same thread count): sharding
    // replaces one n-point factorization with k (n/k)-point ones.
    if let Some(&(_, base)) =
        fit_walls.iter().find(|(s, _)| *s == 1).or_else(|| fit_walls.first())
    {
        for &(s, w) in &fit_walls {
            println!("fit scaling: shards={s} {} ({:.2}x vs baseline)", fmt_secs(w), base / w.max(1e-12));
            if s > 1 && w >= base {
                println!("  WARN: shards={s} fit did not beat the unsharded fit");
            }
        }
    }

    // Streaming economics: appending one held-out batch through the
    // incremental observe path vs absorbing the same batch through a
    // drift-forced full refit — the wall-time gap the observe plane
    // exists for, recorded into the trajectory alongside the shard sweep.
    mka_gp::par::set_threads(threads_list.last().copied().unwrap_or(1));
    let base = MkaGp::fit(&tr, &kern, 0.1, &cfg).expect("observe base fit");
    base.log_marginal().expect("warm factor"); // build the factor outside both timers
    let b = 16.min(te.n());
    let xb = te.x.block(0, b, 0, te.x.cols);
    let yb = te.y[..b].to_vec();
    let t_obs = Timer::start();
    let (_inc, rep_inc) = base.observed(&xb, &yb, &ObservePolicy::default()).expect("observe");
    let observe_s = t_obs.elapsed_secs();
    assert_eq!(rep_inc.path, ObservePath::Incremental, "default policy must extend in place");
    let forced = ObservePolicy { drift_threshold: 1e-12, ..ObservePolicy::default() };
    let t_ref = Timer::start();
    let (_refit, rep_ref) = base.observed(&xb, &yb, &forced).expect("forced refit");
    let refit_s = t_ref.elapsed_secs();
    assert_eq!(rep_ref.path, ObservePath::Refit, "zero drift threshold must force a refit");
    let stats = rep_inc.stats.as_ref().expect("incremental path carries extend stats");
    println!(
        "observe batch={b} (n={}): incremental {} ({}/{} stages rebuilt) vs refit {} ({:.1}x)",
        tr.n(),
        fmt_secs(observe_s),
        stats.stages_rebuilt,
        stats.stages_total,
        fmt_secs(refit_s),
        refit_s / observe_s.max(1e-12)
    );
    let observe = Json::obj()
        .with("batch", Json::Num(b as f64))
        .with("n_base", Json::Num(tr.n() as f64))
        .with("observe_s", Json::Num(observe_s))
        .with("refit_s", Json::Num(refit_s))
        .with("refit_over_observe", Json::Num(refit_s / observe_s.max(1e-12)))
        .with("stages_rebuilt", Json::Num(stats.stages_rebuilt as f64))
        .with("stages_total", Json::Num(stats.stages_total as f64))
        .with("blocks_reused", Json::Num(stats.blocks_reused as f64));

    // Predict-path latency plane: a repeat-test-set serving burst.
    // Request 1 is cold (one joint factorization + full gram assembly);
    // every later identical request must hit the joint-factor cache —
    // zero factorizations, bitwise-identical output — and the hot p50
    // must beat the cold wall strictly.
    let model = MkaGp::fit(&tr, &kern, 0.1, &cfg).expect("cache bench fit");
    let rounds = 32usize;
    let f0 = mka_gp::mka::factorize_count();
    let t_cold = Timer::start();
    let cold = model.predict(&te.x);
    let cold_s = t_cold.elapsed_secs();
    let cold_factorizes = mka_gp::mka::factorize_count() - f0;
    let f0 = mka_gp::mka::factorize_count();
    let mut hot = Vec::with_capacity(rounds);
    for _ in 0..rounds {
        let t_hot = Timer::start();
        let again = model.predict(&te.x);
        hot.push(t_hot.elapsed_secs());
        let same = cold.mean.iter().zip(&again.mean).all(|(a, b)| a.to_bits() == b.to_bits())
            && cold.var.iter().zip(&again.var).all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(same, "cache hit must be bitwise identical to the cold predict");
    }
    let hot_factorizes = mka_gp::mka::factorize_count() - f0;
    assert_eq!(hot_factorizes, 0, "warm repeat predicts must not factorize");
    hot.sort_by(|a, b| a.total_cmp(b));
    let hot_p50 = mka_gp::la::stats::quantile_sorted(&hot, 0.5);
    let hot_p99 = mka_gp::la::stats::quantile_sorted(&hot, 0.99);
    assert!(hot_p50 < cold_s, "hot p50 ({hot_p50}s) must beat the cold predict ({cold_s}s)");
    let cache = model.predict_cache();
    let hit_rate = cache.hits() as f64 / (cache.hits() + cache.misses()).max(1) as f64;
    // Assembly savings: a model whose train factor already exists keeps
    // the memoized train×train gram, so its first (cold) predict only
    // assembles the cross and test tiles instead of the full (n+p)²
    // joint gram. Same single factorization either way — the wall-time
    // delta is the tile reuse.
    let memo = MkaGp::fit(&tr, &kern, 0.1, &cfg).expect("memo bench fit");
    memo.log_marginal().expect("train factor"); // memoizes the train gram
    let t_tiled = Timer::start();
    let tiled_pred = memo.predict(&te.x);
    let tiled_s = t_tiled.elapsed_secs();
    let same_cold = cold.mean.iter().zip(&tiled_pred.mean).all(|(a, b)| a.to_bits() == b.to_bits());
    assert!(same_cold, "tiled joint assembly must match the full rebuild bitwise");
    println!(
        "predict cache burst p={} rounds={rounds}: cold {} ({cold_factorizes} factorize) | hot p50 {} p99 {} (0 factorize, hit rate {:.2}) | speedup {:.1}x | tiled cold assembly {} ({:.2}x vs full)",
        te.n(),
        fmt_secs(cold_s),
        fmt_secs(hot_p50),
        fmt_secs(hot_p99),
        hit_rate,
        cold_s / hot_p50.max(1e-12),
        fmt_secs(tiled_s),
        cold_s / tiled_s.max(1e-12)
    );
    let predict_cache = Json::obj()
        .with("p", Json::Num(te.n() as f64))
        .with("rounds", Json::Num(rounds as f64))
        .with("cold_s", Json::Num(cold_s))
        .with("hot_p50_s", Json::Num(hot_p50))
        .with("hot_p99_s", Json::Num(hot_p99))
        .with("cold_over_hot_p50", Json::Num(cold_s / hot_p50.max(1e-12)))
        .with("cold_factorizes", Json::Num(cold_factorizes as f64))
        .with("hot_factorizes", Json::Num(hot_factorizes as f64))
        .with("hit_rate", Json::Num(hit_rate))
        .with("cold_tiled_assembly_s", Json::Num(tiled_s))
        .with("assembly_saving", Json::Num(cold_s / tiled_s.max(1e-12)))
        .with("bitwise_identical", Json::Bool(true));

    let doc = Json::obj()
        .with("bench", Json::Str("shard_plane".into()))
        .with(
            "generated_by",
            Json::Str("cargo bench --bench coordinator_perf -- --json".into()),
        )
        .with("n", Json::Num(n as f64))
        .with("k", Json::Num(k as f64))
        .with("observe", observe)
        .with("predict_cache", predict_cache)
        .with("results", Json::Arr(results));
    std::fs::write(&out_path, doc.dump_pretty()).expect("write bench json");
    println!("wrote {out_path}");
}
