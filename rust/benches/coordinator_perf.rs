//! Bench: coordinator overhead and batching behaviour (E8) — router
//! dispatch latency, TCP round-trip latency, and the effect of the dynamic
//! prediction batcher under concurrent clients.
//!
//!     cargo bench --bench coordinator_perf [-- --clients 8]

use std::sync::Arc;

use mka_gp::bench::{bench, fmt_secs, Table};
use mka_gp::coordinator::{Client, Router, Server, ServiceConfig};
use mka_gp::data::synth::{gp_dataset, SynthSpec};
use mka_gp::prelude::*;
use mka_gp::util::Timer;

fn main() {
    let args = Args::from_env(false);
    let n_clients = args.get_usize("clients", 8);

    // Service with a published MKA model.
    let cfg = ServiceConfig { port: 0, n_workers: 2, batch_window_ms: 3, ..Default::default() };
    let router = Arc::new(Router::new(cfg));
    let data = gp_dataset(&SynthSpec::named("perf", 600, 4), 3);
    let (tr, te) = data.split(0.9, 1);
    let kern = RbfKernel::new(0.8);
    let model =
        MkaGp::fit(&tr, &kern, 0.1, &MkaConfig { d_core: 32, block_size: 128, ..Default::default() })
            .unwrap();
    router.registry.publish("m", Arc::new(model));
    let server = Server::start(Arc::clone(&router), "127.0.0.1", 0).unwrap();
    let addr = format!("{}", server.addr());

    println!("=== Coordinator performance ===\n");
    let mut table = Table::new(&["op", "p50", "p95", "mean"]);

    // 1. Router dispatch (in-process, no TCP).
    let ping = Json::parse(r#"{"op":"ping"}"#).unwrap();
    let st = bench("router-ping", 50, 2000, || {
        std::hint::black_box(router.handle(&ping));
    });
    table.row(&["router ping".into(), fmt_secs(st.p50_s), fmt_secs(st.p95_s), fmt_secs(st.mean_s)]);

    // 2. TCP round trip.
    let mut client = Client::connect(&addr).unwrap();
    let st = bench("tcp-ping", 20, 500, || {
        std::hint::black_box(client.call(&ping).unwrap());
    });
    table.row(&["tcp ping".into(), fmt_secs(st.p50_s), fmt_secs(st.p95_s), fmt_secs(st.mean_s)]);

    // 3. Single predict (1 point) over TCP.
    let one = Json::obj()
        .with("op", Json::Str("predict".into()))
        .with("model", Json::Str("m".into()))
        .with("x", Json::Arr(vec![Json::from_f64_slice(te.x.row(0))]));
    let st = bench("tcp-predict-1", 3, 20, || {
        std::hint::black_box(client.call(&one).unwrap());
    });
    table.row(&["predict x1".into(), fmt_secs(st.p50_s), fmt_secs(st.p95_s), fmt_secs(st.mean_s)]);

    // 4. Batched predict (32 points) over TCP.
    let x32: Vec<Json> = (0..32.min(te.n())).map(|i| Json::from_f64_slice(te.x.row(i))).collect();
    let batch = Json::obj()
        .with("op", Json::Str("predict".into()))
        .with("model", Json::Str("m".into()))
        .with("x", Json::Arr(x32));
    let st = bench("tcp-predict-32", 3, 15, || {
        std::hint::black_box(client.call(&batch).unwrap());
    });
    table.row(&["predict x32".into(), fmt_secs(st.p50_s), fmt_secs(st.p95_s), fmt_secs(st.mean_s)]);
    table.print();

    // 5. Concurrent clients: batching amortizes the factorization.
    println!("\nconcurrent predict ({n_clients} clients × 1 point each):");
    let t = Timer::start();
    let handles: Vec<_> = (0..n_clients)
        .map(|i| {
            let addr = addr.clone();
            let row = te.x.row(i % te.n()).to_vec();
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                let req = Json::obj()
                    .with("op", Json::Str("predict".into()))
                    .with("model", Json::Str("m".into()))
                    .with("x", Json::Arr(vec![Json::from_f64_slice(&row)]));
                c.call(&req).unwrap()
            })
        })
        .collect();
    for h in handles {
        let resp = h.join().unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
    }
    let wall = t.elapsed_secs();
    let snap = router.metrics.snapshot();
    let batches = snap.get("counters").and_then(|c| c.num_field("batches")).unwrap_or(0.0);
    let preds = snap.get("counters").and_then(|c| c.num_field("predictions")).unwrap_or(0.0);
    println!(
        "  wall {:.2}s | {} predictions served in {} model calls (batching gain {:.1}x)",
        wall,
        preds,
        batches,
        preds / batches.max(1.0)
    );

    // 6. Blocked multi-RHS execution: one b-point predict (one joint
    // factorization + ONE blocked cascade for all b+1 right-hand sides)
    // vs b independent per-vector predicts. This is the acceptance
    // comparison for the blocked path: the batched predict must beat b
    // independent predicts at b >= 32.
    let b = args.get_usize("batch", 32).min(te.n());
    let model = router.registry.get("m").expect("model published");
    println!("\nblocked multi-RHS predict (b = {b}):");
    let xb = te.x.block(0, b, 0, te.x.cols);
    let c0 = mka_gp::mka::cascade_count();
    let t = Timer::start();
    let batched = model.predict(&xb);
    let batched_s = t.elapsed_secs();
    let batched_cascades = mka_gp::mka::cascade_count() - c0;
    let c0 = mka_gp::mka::cascade_count();
    let t = Timer::start();
    let mut singles = Vec::with_capacity(b);
    for i in 0..b {
        let xi = te.x.block(i, i + 1, 0, te.x.cols);
        singles.push(model.predict(&xi).mean[0]);
    }
    let serial_s = t.elapsed_secs();
    let serial_cascades = mka_gp::mka::cascade_count() - c0;
    assert_eq!(batched.mean.len(), b);
    println!(
        "  batched x{b}: {} ({batched_cascades} cascades) | {b} × x1: {} ({serial_cascades} cascades) | speedup {:.1}x",
        fmt_secs(batched_s),
        fmt_secs(serial_s),
        serial_s / batched_s.max(1e-12)
    );
    if batched_s < serial_s {
        println!("  OK: batched predict beats {b} independent per-vector predicts");
    } else {
        println!("  WARN: batched predict did NOT beat independent predicts");
    }
}
