//! Bench: regenerate **Table 1** — SMSE(MNLP) for six methods × six
//! datasets under the paper's protocol (normalize, 90/10 split, CV'd
//! hyperparameters, repeats averaged).
//!
//! Default run caps dataset sizes so the table completes in minutes on one
//! core; `--full` lifts the caps to the paper's exact sizes.
//!
//!     cargo bench --bench table1 [-- --full --max-n 2048 --datasets housing,wine
//!                                   --selection cv|mll|mll-grad]

use mka_gp::experiments::table1::{format_rows, run_table, Table1Config};
use mka_gp::util::{Args, Timer};

fn main() {
    let args = Args::from_env(false);
    let mut cfg = Table1Config::default();
    if args.has_flag("full") {
        cfg.max_n = usize::MAX;
        cfg.repeats = 5;
        cfg.folds = 5;
        cfg.cv_max_n = 2048;
    }
    cfg.max_n = args.get_usize("max-n", cfg.max_n);
    cfg.repeats = args.get_usize("repeats", cfg.repeats);
    cfg.seed = args.get_u64("seed", cfg.seed);
    cfg.selection = args.get_or("selection", "cv").to_string();
    let only_arg = args.get("datasets").map(|s| s.split(',').collect::<Vec<_>>());

    println!("=== Table 1: Regression results, SMSE(MNLP) ===");
    println!(
        "(max_n={}, repeats={}, folds={}; synthetic broad-spectrum stand-ins at the paper's (n, d) — see DESIGN.md §5)\n",
        if cfg.max_n == usize::MAX { "paper".to_string() } else { cfg.max_n.to_string() },
        cfg.repeats,
        cfg.folds
    );
    let t = Timer::start();
    let rows = run_table(&cfg, only_arg.as_deref());
    println!("{}", format_rows(&rows));
    println!("\npaper's Table 1 for shape comparison (SMSE only):");
    println!("  housing    k=16: Full 0.36 | SOR 0.93 | FITC 0.91 | PITC 0.96 | MEKA 0.85 | MKA 0.52");
    println!("  rupture    k=16: Full 0.17 | SOR 0.94 | FITC 0.96 | PITC 0.93 | MEKA 0.46 | MKA 0.32");
    println!("  wine       k=32: Full 0.59 | SOR 0.86 | FITC 0.84 | PITC 0.87 | MEKA 0.97 | MKA 0.70");
    println!("  pageblocks k=32: Full 0.44 | SOR 0.86 | FITC 0.81 | PITC 0.86 | MEKA 0.96 | MKA 0.63");
    println!("  compAct    k=32: Full 0.58 | SOR 0.88 | FITC 0.91 | PITC 0.88 | MEKA 0.75 | MKA 0.60");
    println!("  pendigit   k=64: Full 0.15 | SOR 0.65 | FITC 0.70 | PITC 0.71 | MEKA 0.53 | MKA 0.30");
    println!("\nexpected shape: Full best; MKA closest to Full; SOR/FITC/PITC/MEKA trail at small k.");
    println!("total bench time: {:.1}s", t.elapsed_secs());
}
