//! Bench: the marginal-likelihood training plane.
//!
//! Measures the two quantities the `train` workload lives on:
//!
//! * **MLL evals/sec** — one evidence evaluation = one `factorize` +
//!   `solve` + `logdet` for MKA (Proposition 7's "direct method" pitch);
//! * **train-op wall time** — a full multi-start Nelder–Mead run through
//!   `train_model`, i.e. what one `{"op":"train"}` job costs;
//! * **NM vs L-BFGS** — evals-to-convergence of the derivative-free and
//!   the analytic-gradient optimizer on the same evidence surface (the
//!   gradient win the trajectory tracks).
//!
//!     cargo bench --bench train_bench [-- --sizes 512,1024 --k 32]
//!
//! `--json` mode writes the machine-readable `BENCH_train.json`
//! trajectory (MLL evals/sec, train wall time, shift-reuse economics —
//! `train_refactorize_per_eval` and `retune_ms` vs `fit_ms` — vs
//! n × threads, plus the served `train.*` histograms with their
//! p50/p95/p99 from a burst of coordinator `train` ops), asserting along
//! the way that the evidence value is bit-identical at every thread
//! count:
//!
//!     cargo bench --bench train_bench -- --json \
//!         [--sizes 512,1024,2048] [--threads 1,2,4] [--k 32] \
//!         [--max-evals 12] [--out ../BENCH_train.json]

use mka_gp::bench::{bench_budget, fmt_secs, Table};
use mka_gp::coordinator::{Router, ServiceConfig};
use mka_gp::data::synth::{gp_dataset, SynthSpec};
use mka_gp::experiments::methods::{mka_config_for, Method};
use mka_gp::gp::cv::HyperParams;
use mka_gp::gp::mka_gp::MkaGp;
use mka_gp::kernels::RbfKernel;
use mka_gp::train::{log_marginal_likelihood, train_model, ModelSelection, OptimBudget};
use mka_gp::util::{Args, Json, Timer};

fn main() {
    let args = Args::from_env(false);
    if args.has_flag("json") {
        run_json_bench(&args);
        return;
    }
    let sizes = args.get_usize_list("sizes", &[512, 1024]);
    let k = args.get_usize("k", 32);
    let hp = HyperParams { lengthscale: 1.0, sigma2: 0.1 };

    println!("=== Training plane: evidence evaluation cost ===\n");
    let mut table = Table::new(&["n", "method", "mll", "one eval", "evals/s"]);
    for &n in &sizes {
        let data = gp_dataset(&SynthSpec::named("tb", n, 4), 5);
        for m in [Method::Mka, Method::Full, Method::Sor, Method::Fitc, Method::Pitc] {
            let st = bench_budget("mll", 0.4, 20, || {
                std::hint::black_box(log_marginal_likelihood(m, &data, hp, k, 7).expect("mll"));
            });
            let val = log_marginal_likelihood(m, &data, hp, k, 7).expect("mll");
            table.row(&[
                n.to_string(),
                m.label().to_string(),
                format!("{val:.1}"),
                fmt_secs(st.mean_s),
                format!("{:.1}", 1.0 / st.mean_s.max(1e-12)),
            ]);
        }
    }
    table.print();

    let n = sizes[0];
    let data = gp_dataset(&SynthSpec::named("tb", n, 4), 5);
    let sel = ModelSelection::Mll {
        budget: OptimBudget { max_evals: 24, n_starts: 3, tol: 1e-4 },
    };
    let timer = Timer::start();
    let (_model, report) = train_model(Method::Mka, &data, &sel, k, 7).expect("train");
    println!(
        "\ntrain op (MKA, n={n}): {} evals in {}, best MLL {:.2}, converged={}",
        report.evals,
        fmt_secs(timer.elapsed_secs()),
        report.best_mll.unwrap_or(f64::NAN),
        report.converged
    );

    println!("\n=== NM vs L-BFGS: evals to convergence (same evidence surface) ===\n");
    let mut table = Table::new(&["n", "method", "optimizer", "evals", "best mll", "conv", "time"]);
    for m in [Method::Mka, Method::Full] {
        for (sel, name) in [
            (ModelSelection::Mll { budget: OptimBudget::default() }, "nelder-mead"),
            (
                ModelSelection::MllGrad { budget: OptimBudget::default(), ard: false },
                "l-bfgs",
            ),
        ] {
            let timer = Timer::start();
            let (_model, rep) = train_model(m, &data, &sel, k, 7).expect("train");
            table.row(&[
                n.to_string(),
                m.label().to_string(),
                name.to_string(),
                rep.evals.to_string(),
                format!("{:.2}", rep.best_mll.unwrap_or(f64::NAN)),
                rep.converged.to_string(),
                fmt_secs(timer.elapsed_secs()),
            ]);
        }
    }
    table.print();
}

/// `--json` mode: machine-readable training-plane perf trajectory.
fn run_json_bench(args: &Args) {
    let sizes = args.get_usize_list("sizes", &[512, 1024, 2048]);
    let threads_list = args.get_usize_list("threads", &[1, 2, 4]);
    let k = args.get_usize("k", 32);
    let max_evals = args.get_usize("max-evals", 12);
    let out_path = args.get_or("out", "../BENCH_train.json").to_string();
    let hp = HyperParams { lengthscale: 1.0, sigma2: 0.1 };

    let mut results: Vec<Json> = Vec::new();
    for &n in &sizes {
        let data = gp_dataset(&SynthSpec::named("tb", n, 4), 5);
        let mut base: Option<(f64, f64)> = None;
        let mut ref_mll: Option<f64> = None;
        for &t in &threads_list {
            mka_gp::par::set_threads(t);
            let st = bench_budget("mll", 0.5, 8, || {
                std::hint::black_box(
                    log_marginal_likelihood(Method::Mka, &data, hp, k, 7).expect("mll"),
                );
            });
            let val = log_marginal_likelihood(Method::Mka, &data, hp, k, 7).expect("mll");
            match ref_mll {
                None => ref_mll = Some(val),
                Some(r) => assert_eq!(
                    r.to_bits(),
                    val.to_bits(),
                    "MLL at {t} threads must be bit-identical to serial (n={n})"
                ),
            }
            let budget = OptimBudget { max_evals, n_starts: 2, tol: 1e-4 };
            let sel = ModelSelection::Mll { budget };
            let timer = Timer::start();
            let (_model, report) = train_model(Method::Mka, &data, &sel, k, 7).expect("train");
            let train_s = timer.elapsed_secs();

            // Same surface, analytic gradients: the evals-to-convergence
            // comparison the trajectory tracks (NM vs L-BFGS).
            let sel_g = ModelSelection::MllGrad { budget, ard: false };
            let timer_g = Timer::start();
            let (_model_g, report_g) =
                train_model(Method::Mka, &data, &sel_g, k, 7).expect("train lbfgs");
            let lbfgs_s = timer_g.elapsed_secs();

            // Shift-reuse economics: σ²-independent factor builds per
            // evidence evaluation (cache misses / evals — below 1.0
            // whenever the optimizer revisits a length scale)…
            let refac_per_eval = report.factorizations.unwrap_or(report.evals) as f64
                / report.evals.max(1) as f64;
            // …and the serving-plane version: a full MKA fit with its
            // (noise-free) train factorization vs a σ² retune on the
            // same model — the retune is pure spectrum arithmetic.
            let cfg_mka = mka_config_for(k, n, 7);
            let kern = RbfKernel::new(hp.lengthscale);
            let t_fit = Timer::start();
            let mut gp = MkaGp::fit(&data, &kern, hp.sigma2, &cfg_mka).expect("mka fit");
            let ml_fit = gp.log_marginal().expect("log marginal"); // builds the factor
            let fit_s = t_fit.elapsed_secs();
            let t_retune = Timer::start();
            gp.set_noise(hp.sigma2 * 0.5).expect("set_noise");
            let ml_retune = gp.log_marginal().expect("retuned log marginal");
            let retune_s = t_retune.elapsed_secs();
            assert!(
                ml_retune.is_finite() && ml_retune != ml_fit,
                "retune must move the evidence (fit {ml_fit}, retune {ml_retune})"
            );

            let (m0, t0) = *base.get_or_insert((st.mean_s, train_s));
            println!(
                "n={n} t={t}: mll eval {} ({:.2}x, {:.1}/s) train {} ({:.2}x, {} evals, {:.2} refac/eval) lbfgs {} ({} evals) fit {} retune {} ({:.0}x)",
                fmt_secs(st.mean_s),
                m0 / st.mean_s.max(1e-12),
                1.0 / st.mean_s.max(1e-12),
                fmt_secs(train_s),
                t0 / train_s.max(1e-12),
                report.evals,
                refac_per_eval,
                fmt_secs(lbfgs_s),
                report_g.evals,
                fmt_secs(fit_s),
                fmt_secs(retune_s),
                fit_s / retune_s.max(1e-12)
            );
            results.push(
                Json::obj()
                    .with("n", Json::Num(n as f64))
                    .with("threads", Json::Num(t as f64))
                    .with("mll_eval_s", Json::Num(st.mean_s))
                    .with("mll_evals_per_s", Json::Num(1.0 / st.mean_s.max(1e-12)))
                    .with("mll_value", Json::Num(val))
                    .with("train_s", Json::Num(train_s))
                    .with("train_evals", Json::Num(report.evals as f64))
                    .with("best_mll", Json::Num(report.best_mll.unwrap_or(f64::NAN)))
                    .with("converged", Json::Bool(report.converged))
                    .with("lbfgs_train_s", Json::Num(lbfgs_s))
                    .with("lbfgs_evals", Json::Num(report_g.evals as f64))
                    .with("lbfgs_best_mll", Json::Num(report_g.best_mll.unwrap_or(f64::NAN)))
                    .with("lbfgs_converged", Json::Bool(report_g.converged))
                    .with("train_factorizations", Json::Num(
                        report.factorizations.unwrap_or(report.evals) as f64,
                    ))
                    .with("train_refactorize_per_eval", Json::Num(refac_per_eval))
                    .with("fit_ms", Json::Num(fit_s * 1e3))
                    .with("retune_ms", Json::Num(retune_s * 1e3))
                    .with("retune_speedup", Json::Num(fit_s / retune_s.max(1e-12)))
                    .with("mll_speedup", Json::Num(m0 / st.mean_s.max(1e-12)))
                    .with("train_speedup", Json::Num(t0 / train_s.max(1e-12)))
                    .with("bit_identical", Json::Bool(true)),
            );
        }
    }

    // Served-plane percentiles: the trajectory's per-run wall times above
    // are single samples — the p50/p95/p99 view comes from the
    // coordinator's own `train.*` histograms after a burst of `train` ops.
    let smallest = sizes.iter().copied().min().unwrap_or(256);
    let hists = served_train_histograms(smallest, k, max_evals);

    let doc = Json::obj()
        .with("bench", Json::Str("train_plane".into()))
        .with(
            "generated_by",
            Json::Str("cargo bench --bench train_bench -- --json".into()),
        )
        .with("k", Json::Num(k as f64))
        .with("max_evals", Json::Num(max_evals as f64))
        .with("train_histograms", hists)
        .with("results", Json::Arr(results));
    std::fs::write(&out_path, doc.dump_pretty()).expect("write bench json");
    println!("wrote {out_path}");
}

/// Drive a burst of synchronous `{"op":"train"}` requests through a live
/// router and return its `train.{secs,evals,factorizations,best_mll}` and
/// `op.train_secs` histograms (count/mean/p50/p95/p99/max), so the
/// trajectory carries the train plane's percentile view — the same shape
/// the `metrics` op serves in production — next to the per-run wall times.
fn served_train_histograms(n: usize, k: usize, max_evals: usize) -> Json {
    let cfg = ServiceConfig { port: 0, n_workers: 2, batch_window_ms: 0, ..Default::default() };
    let router = Router::new(cfg);
    let data = gp_dataset(&SynthSpec::named("tb-hist", n, 2), 9);
    let x = Json::Arr((0..data.n()).map(|i| Json::from_f64_slice(data.x.row(i))).collect());
    let y = Json::from_f64_slice(&data.y);
    let reps = 6usize;
    for rep in 0..reps {
        let req = Json::obj()
            .with("op", Json::Str("train".into()))
            .with("model", Json::Str(format!("tb-hist-{rep}")))
            .with("method", Json::Str("mka".into()))
            .with("x", x.clone())
            .with("y", y.clone())
            .with("selection", Json::Str("mll".into()))
            .with(
                "budget",
                Json::obj()
                    .with("max_evals", Json::Num(max_evals.min(6) as f64))
                    .with("n_starts", Json::Num(1.0))
                    .with("tol", Json::Num(1e-3)),
            )
            .with("params", Json::obj().with("k", Json::Num(k.min(12) as f64)))
            .with("async", Json::Bool(false));
        let resp = router.handle(&req);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "served train failed: {resp:?}");
    }
    let mut out = std::collections::BTreeMap::new();
    let snap = router.metrics.snapshot();
    if let Some(Json::Obj(hists)) = snap.get("histograms") {
        for (name, h) in hists {
            if name.starts_with("train.") || name == "op.train_secs" {
                out.insert(name.clone(), h.clone());
            }
        }
    }
    assert!(
        out.contains_key("train.secs") && out.contains_key("op.train_secs"),
        "served train burst must populate train.secs and op.train_secs histograms"
    );
    println!("served train histograms (n={n}, {reps} train ops): {} series", out.len());
    Json::Obj(out)
}
