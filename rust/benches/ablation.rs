//! Bench: ablations over MKA's design choices (DESIGN.md E7):
//!
//! * compressor: MMF (greedy-Jacobi) vs SPCA vs exact-EVD oracle;
//! * MMF pivot rule: min-residual vs classic max-correlation;
//! * MMF pre-sweep budget (extra rotations per wavelet);
//! * per-stage compression ratio γ;
//! * stage-1 clustering method;
//! * estimator: §4.1 joint/consistent vs naive exact-k* mixing (ridge).
//!
//!     cargo bench --bench ablation [-- --n 768]

use mka_gp::bench::{fmt_secs, Table};
use mka_gp::cluster::ClusterMethod;
use mka_gp::compress::mmf::{MmfCompressor, PivotRule};
use mka_gp::compress::{compression_error, Compressor, CompressorKind};
use mka_gp::data::synth::{gp_dataset, SynthSpec};
use mka_gp::gp::metrics::smse;
use mka_gp::gp::mka_gp::MkaGp;
use mka_gp::gp::ridge::MkaRidge;
use mka_gp::gp::GpModel;
use mka_gp::kernels::{Kernel, RbfKernel};
use mka_gp::mka::{factorize, MkaConfig};
use mka_gp::util::{Args, Rng, Timer};

fn main() {
    let args = Args::from_env(false);
    let n = args.get_usize("n", 768);
    let d_core = args.get_usize("d-core", 32);
    let spec = SynthSpec { ell_local: 0.4, local_weight: 0.5, ..SynthSpec::named("abl", n, 3) };
    let data = gp_dataset(&spec, 17);
    let (tr, te) = data.split(0.9, 1);
    let kern = RbfKernel::new(0.5);
    let s2 = 0.1;
    let mut kmat = kern.gram_sym(&tr.x);
    kmat.add_diag(s2);
    let base = MkaConfig { d_core, block_size: 128, ..MkaConfig::default() };

    let eval = |cfg: &MkaConfig| -> (f64, f64, f64) {
        let t = Timer::start();
        let f = factorize(&kmat, Some(&tr.x), cfg).expect("factorize");
        let fact_s = t.elapsed_secs();
        let rel = f.to_dense().sub(&kmat).frob_norm() / kmat.frob_norm();
        let model = MkaGp::fit(&tr, &kern, s2, cfg).unwrap();
        let e = smse(&te.y, &model.predict(&te.x).mean);
        (rel, e, fact_s)
    };

    println!("=== Ablation 1: compressor kind (n={n}, d_core={d_core}) ===");
    let mut t1 = Table::new(&["compressor", "rel-frob", "SMSE", "factorize"]);
    for kind in [CompressorKind::Mmf, CompressorKind::Spca, CompressorKind::Evd] {
        let cfg = base.clone().with_compressor(kind);
        let (rel, e, s) = eval(&cfg);
        t1.row(&[format!("{kind:?}"), format!("{rel:.4}"), format!("{e:.4}"), fmt_secs(s)]);
    }
    t1.print();

    println!("\n=== Ablation 2: MMF pivot rule + pre-sweeps (per-block error) ===");
    let mut rng = Rng::new(5);
    let xb = mka_gp::la::Mat::from_fn(64, 3, |_, _| rng.normal());
    let mut block = kern.gram_sym(&xb);
    block.add_diag(s2);
    let mut t2 = Table::new(&["rule", "extra-rot", "block rel-err", "time"]);
    for rule in [PivotRule::MinResidual, PivotRule::MaxCorrelation] {
        for extra in [0usize, 2, 4] {
            let mmf = MmfCompressor { rule, extra_rotations: extra };
            let t = Timer::start();
            let comp = mmf.compress(&block, 32, &mut Rng::new(0));
            let el = t.elapsed_secs();
            t2.row(&[
                format!("{rule:?}"),
                extra.to_string(),
                format!("{:.4}", compression_error(&block, &comp)),
                fmt_secs(el),
            ]);
        }
    }
    t2.print();

    println!("\n=== Ablation 3: compression ratio γ ===");
    let mut t3 = Table::new(&["gamma", "stages", "rel-frob", "SMSE"]);
    for gamma in [0.3, 0.5, 0.7] {
        let cfg = base.clone().with_gamma(gamma);
        let f = factorize(&kmat, Some(&tr.x), &cfg).unwrap();
        let (rel, e, _) = eval(&cfg);
        t3.row(&[
            format!("{gamma}"),
            f.n_stages().to_string(),
            format!("{rel:.4}"),
            format!("{e:.4}"),
        ]);
    }
    t3.print();

    println!("\n=== Ablation 4: stage-1 clustering ===");
    let mut t4 = Table::new(&["clustering", "rel-frob", "SMSE", "factorize"]);
    for method in [ClusterMethod::Bisect, ClusterMethod::KMeans, ClusterMethod::Affinity] {
        let cfg = MkaConfig { cluster_method: method, ..base.clone() };
        let (rel, e, s) = eval(&cfg);
        t4.row(&[format!("{method:?}"), format!("{rel:.4}"), format!("{e:.4}"), fmt_secs(s)]);
    }
    t4.print();

    println!("\n=== Ablation 5: §4.1 consistent estimator vs naive mixing ===");
    let mka = MkaGp::fit(&tr, &kern, s2, &base).unwrap();
    let e_joint = smse(&te.y, &mka.predict(&te.x).mean);
    let ridge = MkaRidge::fit(&tr, &kern, s2, &base).unwrap();
    let e_naive = smse(&te.y, &ridge.predict(&te.x).mean);
    println!("  joint/consistent (MkaGp)   SMSE = {e_joint:.4}");
    println!("  naive exact-k* (MkaRidge)  SMSE = {e_naive:.4}");
    println!("  (the paper's §4.1 motivation: the naive mix amplifies truncation error)");
}
