//! Bench: regenerate **Figure 1** — qualitative fits on the Snelson-style
//! 1D toy (ground truth sampled from a GP with ℓ = 0.5, 10 pseudo-inputs /
//! d_core = 10). Emits the per-method curve CSVs and prints the
//! deviation-from-Full series that quantifies the figure.
//!
//!     cargo bench --bench fig1_snelson [-- --n 200 --k 10 --reps 3]

use mka_gp::bench::Table;
use mka_gp::data::loader::write_table;
use mka_gp::experiments::methods::Method;
use mka_gp::experiments::snelson;
use mka_gp::gp::cv::HyperParams;
use mka_gp::la::stats::mean_std_sample;
use mka_gp::util::{Args, Timer};

fn main() {
    let args = Args::from_env(false);
    let n = args.get_usize("n", 200);
    let k = args.get_usize("k", 10);
    let reps = args.get_usize("reps", 3);
    let hp = HyperParams { lengthscale: 0.5, sigma2: 0.01 };

    println!("=== Figure 1: Snelson 1D, {n} points, k = d_core = {k}, {reps} seeds ===\n");
    let t = Timer::start();
    let mut devs: std::collections::BTreeMap<&'static str, Vec<f64>> = Default::default();
    for rep in 0..reps {
        let (_data, curves) = snelson::run(n, k, 220, hp, &Method::ALL, 7 + rep as u64);
        for (m, d) in snelson::deviation_from_full(&curves) {
            devs.entry(m.label()).or_default().push(d);
        }
        if rep == 0 {
            // Emit the figure data once.
            let dir = std::path::Path::new("results/fig1");
            for c in &curves {
                let rows: Vec<Vec<f64>> = c
                    .grid
                    .iter()
                    .zip(&c.mean)
                    .zip(&c.std)
                    .map(|((x, m), s)| vec![*x, *m, m - s, m + s])
                    .collect();
                let _ = write_table(
                    &dir.join(format!("{}.csv", c.method.label().to_lowercase())),
                    &["x", "mean", "lo", "hi"],
                    &rows,
                );
            }
        }
    }

    let mut table = Table::new(&["method", "mean |dev from Full|", "std"]);
    let mut ranked: Vec<(&str, f64, f64)> = devs
        .iter()
        .map(|(m, v)| {
            let (mu, sd) = mean_std_sample(v);
            (*m, mu, sd)
        })
        .collect();
    ranked.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    for (m, mu, sd) in &ranked {
        table.row(&[m.to_string(), format!("{mu:.4}"), format!("{sd:.4}")]);
    }
    table.print();
    println!("\npaper's Figure 1: MKA's curve tracks the Full GP almost exactly while");
    println!("SOR/FITC/PITC over-smooth; expected: MKA at the top of this ranking.");
    println!("curve CSVs: results/fig1/*.csv  |  total {:.1}s", t.elapsed_secs());
}
