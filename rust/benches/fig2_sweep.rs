//! Bench: regenerate **Figure 2** (main paper + supplement) — SMSE and
//! MNLP as a function of the number of pseudo-inputs / d_core, per method.
//! The paper's claim: MKA's error stays nearly flat as the budget shrinks
//! while the low-rank family degrades quickly.
//!
//!     cargo bench --bench fig2_sweep [-- --max-n 1024 --ks 8,16,32,64]

use mka_gp::bench::Table;
use mka_gp::data::loader::write_table;
use mka_gp::data::synth::{gp_dataset, SynthSpec};
use mka_gp::experiments::methods::Method;
use mka_gp::experiments::sweep::{sweep, to_csv_rows};
use mka_gp::gp::cv::HyperParams;
use mka_gp::util::{Args, Timer};

fn main() {
    let args = Args::from_env(false);
    let max_n = args.get_usize("max-n", 1024);
    let ks = args.get_usize_list("ks", &[8, 16, 32, 64, 128]);
    let seed = args.get_u64("seed", 21);
    let t = Timer::start();

    // Two datasets, mirroring the paper's "selected datasets": a smoother
    // one and a strongly local one.
    let specs = [
        SynthSpec { ell_local: 0.7, local_weight: 0.35, ..SynthSpec::named("smooth", max_n, 8) },
        SynthSpec { ell_local: 0.35, local_weight: 0.6, ..SynthSpec::named("local", max_n, 4) },
    ];

    println!("=== Figure 2: SMSE / MNLP vs #pseudo-inputs (k), n={max_n} ===\n");
    for spec in &specs {
        let data = gp_dataset(spec, seed);
        let hp = HyperParams { lengthscale: 0.6, sigma2: 0.1 };
        let pts = sweep(&data, &ks, hp, &Method::ALL, seed);

        println!("dataset '{}' (d={}, local_weight={}):", spec.name, spec.d, spec.local_weight);
        let mut table = Table::new(&["k", "Full", "SOR", "FITC", "PITC", "MEKA", "MKA"]);
        for &k in &ks {
            let mut cells = vec![k.to_string()];
            for m in Method::ALL {
                let p = pts.iter().find(|p| p.method == m && p.k == k).unwrap();
                cells.push(match p.mnlp {
                    Some(nl) if p.smse.is_finite() => format!("{:.2}({:.2})", p.smse, nl),
                    _ if p.smse.is_finite() => format!("{:.2}(-)", p.smse),
                    _ => "-".into(),
                });
            }
            table.row(&cells);
        }
        table.print();

        // Flatness metric: SMSE(min k) − SMSE(max k) per method.
        println!("degradation from k={} to k={} (lower = flatter, paper: MKA flattest):",
            ks.last().unwrap(), ks[0]);
        for m in Method::ALL {
            if m == Method::Full {
                continue; // k-independent
            }
            let at = |k: usize| pts.iter().find(|p| p.method == m && p.k == k).unwrap().smse;
            println!("  {:<5} {:+.3}", m.label(), at(ks[0]) - at(*ks.last().unwrap()));
        }
        let (hdr, rows) = to_csv_rows(&pts);
        let path = format!("results/fig2/{}.csv", spec.name);
        let _ = write_table(std::path::Path::new(&path), &hdr, &rows);
        println!("series -> {path}\n");
    }
    println!("total {:.1}s", t.elapsed_secs());
}
