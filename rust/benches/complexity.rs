//! Bench: verify the complexity claims, Propositions 2–7.
//!
//! * Props 2/4 — factorization time: ~O(n²) per stage (vs dense O(n³));
//! * Props 3/5 — storage: ≤ (2s+1)n + d² reals for MMF-based MKA;
//! * Prop 6    — matvec: O(sn + d²), compared against dense GEMV;
//! * Prop 7    — solve / logdet / exp after factorization: O(n + d³).
//!
//!     cargo bench --bench complexity [-- --sizes 512,1024,2048,4096]

use mka_gp::bench::{bench_budget, fmt_secs, Table};
use mka_gp::data::synth::{clustered_features, gp_dataset, SynthSpec};
use mka_gp::gp::mka_gp::MkaGp;
use mka_gp::gp::GpModel;
use mka_gp::kernels::{Kernel, RbfKernel};
use mka_gp::la::{gemv, Chol, Mat};
use mka_gp::mka::parallel::default_threads;
use mka_gp::mka::{factorize, MkaConfig};
use mka_gp::util::{Args, Json, Rng, Timer};

fn main() {
    let args = Args::from_env(false);
    if args.has_flag("json") {
        run_json_bench(&args);
        return;
    }
    let sizes = args.get_usize_list("sizes", &[512, 1024, 2048, 4096]);
    let d_core = args.get_usize("d-core", 64);

    println!("=== Propositions 2–7: time & storage scaling ===\n");
    let mut table = Table::new(&[
        "n", "factorize", "stages", "stored", "bound(2s+1)n+d²", "matvec", "dense-gemv",
        "solve", "logdet", "chol(n³)",
    ]);
    let mut rng = Rng::new(3);
    for &n in &sizes {
        let data = gp_dataset(&SynthSpec::named("cx", n, 4), 5);
        let kern = RbfKernel::new(0.8);
        let mut k = kern.gram_sym(&data.x);
        k.add_diag(0.1);
        let cfg = MkaConfig { d_core, block_size: 256, ..MkaConfig::default() };

        let t = Timer::start();
        let f = factorize(&k, Some(&data.x), &cfg).expect("factorize");
        let fact_s = t.elapsed_secs();
        let s = f.n_stages();
        let bound = (2 * s + 1) * n + f.d_core() * f.d_core();

        let z = rng.normal_vec(n);
        let mv = bench_budget("matvec", 0.3, 200, || {
            std::hint::black_box(f.matvec(&z));
        });
        let dmv = bench_budget("gemv", 0.3, 200, || {
            std::hint::black_box(gemv(&k, &z));
        });
        let sv = bench_budget("solve", 0.3, 100, || {
            std::hint::black_box(f.solve(&z).unwrap());
        });
        let t = Timer::start();
        let _ld = f.logdet().unwrap();
        let ld_s = t.elapsed_secs();
        // dense Cholesky reference (the O(n³) the paper beats)
        let chol_s = if n <= 2048 {
            let t = Timer::start();
            let _ = Chol::new(&k).unwrap();
            fmt_secs(t.elapsed_secs())
        } else {
            "-".to_string() // too slow to repeat at every size
        };

        table.row(&[
            n.to_string(),
            fmt_secs(fact_s),
            s.to_string(),
            f.stored_reals().to_string(),
            bound.to_string(),
            fmt_secs(mv.mean_s),
            fmt_secs(dmv.mean_s),
            fmt_secs(sv.mean_s),
            fmt_secs(ld_s),
            chol_s,
        ]);
        assert!(f.stored_reals() <= bound, "Prop 5 violated at n={n}");
    }
    table.print();

    // Prop 7: exp/power application cost is solve-like, not cubic.
    println!("\nProp 7 — matrix functions after factorization (n = {}):", sizes[0]);
    let n = sizes[0];
    let x = clustered_features(n, 3, 6, &mut rng);
    let mut k = RbfKernel::new(1.0).gram_sym(&x);
    k.add_diag(0.2);
    let cfg = MkaConfig { d_core, ..MkaConfig::default() };
    let f = factorize(&k, Some(&x), &cfg).unwrap();
    let z = rng.normal_vec(n);
    for (name, func) in [
        ("exp(0.5·K̃)z", 0),
        ("K̃^(1/2) z", 1),
        ("K̃⁻¹ z", 2),
    ] {
        let st = bench_budget(name, 0.3, 100, || match func {
            0 => {
                std::hint::black_box(f.exp_apply(0.5, &z));
            }
            1 => {
                std::hint::black_box(f.pow_apply(0.5, &z));
            }
            _ => {
                std::hint::black_box(f.solve(&z).unwrap());
            }
        });
        println!("  {:<12} {}", name, fmt_secs(st.mean_s));
    }
    println!("\nexpected shape: factorize ≈ O(n²·const); matvec/solve grow ~linearly in n");
    println!("(vs dense gemv's n² and Cholesky's n³); storage stays under the Prop-5 bound.");

    // Blocked multi-RHS path: one cascade carrying B columns vs B serial
    // cascades. The per-rotation work turns into contiguous row axpys and
    // the core spectral op into GEMMs, so the blocked path should win well
    // beyond the bookkeeping savings.
    let bcols = args.get_usize("rhs", 32);
    println!("\nBlocked multi-RHS (n = {n}, B = {bcols}):");
    let z = Mat::from_fn(n, bcols, |_, _| rng.normal());
    let mm = bench_budget("matmat", 0.3, 100, || {
        std::hint::black_box(f.matmat(&z));
    });
    let mv = bench_budget("B-matvecs", 0.3, 100, || {
        for j in 0..bcols {
            std::hint::black_box(f.matvec(&z.col(j)));
        }
    });
    let threads = default_threads();
    let mp = bench_budget("matmat-par", 0.3, 100, || {
        std::hint::black_box(f.matmat_par(&z, threads));
    });
    let sm = bench_budget("solve_mat", 0.3, 100, || {
        std::hint::black_box(f.solve_mat(&z).unwrap());
    });
    let sv = bench_budget("B-solves", 0.3, 100, || {
        for j in 0..bcols {
            std::hint::black_box(f.solve(&z.col(j)).unwrap());
        }
    });
    println!(
        "  matmat      {} vs {bcols}×matvec {}  ({:.1}x)",
        fmt_secs(mm.mean_s),
        fmt_secs(mv.mean_s),
        mv.mean_s / mm.mean_s.max(1e-12)
    );
    println!(
        "  matmat-par  {} ({threads} threads, {:.1}x vs serial matvecs)",
        fmt_secs(mp.mean_s),
        mv.mean_s / mp.mean_s.max(1e-12)
    );
    println!(
        "  solve_mat   {} vs {bcols}×solve  {}  ({:.1}x)",
        fmt_secs(sm.mean_s),
        fmt_secs(sv.mean_s),
        sv.mean_s / sm.mean_s.max(1e-12)
    );
}

/// `--json` mode: machine-readable perf trajectory across PRs.
///
///     cargo bench --bench complexity -- --json \
///         [--sizes 1024,2048,4096] [--threads 1,2,4] [--rhs 32] \
///         [--test-points 64] [--gemm-n 512] [--out ../BENCH_perf.json]
///
/// For every (n, threads) cell it times factorize, a blocked solve
/// (`solve_mat`, `rhs` columns) and an end-to-end `MkaGp::predict`
/// (joint gram + factorize + blocked solve), asserts that every thread
/// count reproduces the single-thread solve bit-for-bit, and writes
/// speedups vs the serial column to `--out`. Predict latency is reported
/// as p50/p99 over repeated warm-arena runs; a `kernel` section records
/// single-thread gemm GFLOP/s vs the retained pre-rewrite kernel, and an
/// `arena` section snapshots the scratch-pool counters. CI runs a
/// small-n smoke invocation of exactly this path.
fn run_json_bench(args: &Args) {
    let sizes = args.get_usize_list("sizes", &[1024, 2048, 4096]);
    let threads_list = args.get_usize_list("threads", &[1, 2, 4]);
    let rhs = args.get_usize("rhs", 32);
    let test_points = args.get_usize("test-points", 64);
    let d_core = args.get_usize("d-core", 64);
    let out_path = args.get_or("out", "../BENCH_perf.json").to_string();

    let kernel_section = bench_dense_kernel(args);
    let mut results: Vec<Json> = Vec::new();
    let mut accept = Json::obj();
    for &n in &sizes {
        let data = gp_dataset(&SynthSpec::named("perf", n, 4), 5);
        let (tr, te) = data.split(0.95, 7);
        let p = test_points.min(te.n()).max(1);
        let te_x = te.x.block(0, p, 0, te.x.cols);
        let kern = RbfKernel::new(0.8);
        let mut k = kern.gram_sym(&tr.x);
        k.add_diag(0.1);
        let mut rng = Rng::new(11);
        let z = Mat::from_fn(k.rows, rhs, |_, _| rng.normal());

        let mut base: Option<(f64, f64, f64)> = None;
        let mut reference_solve: Option<Mat> = None;
        for &t in &threads_list {
            mka_gp::par::set_threads(t);
            let cfg = MkaConfig {
                d_core,
                block_size: 256,
                n_threads: t,
                ..MkaConfig::default()
            };
            let timer = Timer::start();
            let f = factorize(&k, Some(&tr.x), &cfg).expect("factorize");
            let fact_s = timer.elapsed_secs();

            let timer = Timer::start();
            let sol = f.solve_mat_par(&z, t).expect("solve");
            let solve_s = timer.elapsed_secs();
            match &reference_solve {
                None => reference_solve = Some(sol),
                Some(r) => assert_eq!(
                    r.data, sol.data,
                    "solve at {t} threads must be bit-identical to serial (n={n})"
                ),
            }

            let model = MkaGp::fit(&tr, &kern, 0.1, &cfg).expect("fit");
            // Serving-latency distribution, not just one shot: repeated
            // predicts give p50/p99 over warm arenas (the steady state a
            // serving plane actually runs in).
            let reps = if n <= 512 { 12 } else { 5 };
            let mut lat: Vec<f64> = Vec::with_capacity(reps);
            let mut predict_s = f64::INFINITY;
            for _ in 0..reps {
                let timer = Timer::start();
                let pred = model.predict(&te_x);
                let dt = timer.elapsed_secs();
                assert_eq!(pred.mean.len(), p);
                lat.push(dt);
                predict_s = predict_s.min(dt);
            }
            lat.sort_by(|a, b| a.total_cmp(b));
            let predict_p50 = mka_gp::la::stats::quantile_sorted(&lat, 0.5);
            let predict_p95 = mka_gp::la::stats::quantile_sorted(&lat, 0.95);
            let predict_p99 = mka_gp::la::stats::quantile_sorted(&lat, 0.99);

            let (f0, s0, p0) = *base.get_or_insert((fact_s, solve_s, predict_p50));
            let row = Json::obj()
                .with("n", Json::Num(n as f64))
                .with("threads", Json::Num(t as f64))
                .with("stages", Json::Num(f.n_stages() as f64))
                .with("factorize_s", Json::Num(fact_s))
                .with("solve_mat_s", Json::Num(solve_s))
                .with("predict_s", Json::Num(predict_s))
                .with("predict_p50_s", Json::Num(predict_p50))
                .with("predict_p95_s", Json::Num(predict_p95))
                .with("predict_p99_s", Json::Num(predict_p99))
                .with("factorize_speedup", Json::Num(f0 / fact_s.max(1e-12)))
                .with("solve_speedup", Json::Num(s0 / solve_s.max(1e-12)))
                .with("predict_speedup", Json::Num(p0 / predict_p50.max(1e-12)))
                .with("bit_identical", Json::Bool(true));
            println!(
                "n={n} t={t}: factorize {} ({:.2}x) solve {} ({:.2}x) predict p50 {} p99 {} ({:.2}x)",
                fmt_secs(fact_s),
                f0 / fact_s.max(1e-12),
                fmt_secs(solve_s),
                s0 / solve_s.max(1e-12),
                fmt_secs(predict_p50),
                fmt_secs(predict_p99),
                p0 / predict_p50.max(1e-12)
            );
            if n == *sizes.last().unwrap() && t == *threads_list.last().unwrap() {
                accept = Json::obj()
                    .with("n", Json::Num(n as f64))
                    .with("threads", Json::Num(t as f64))
                    .with("factorize_speedup", Json::Num(f0 / fact_s.max(1e-12)))
                    .with("predict_speedup", Json::Num(p0 / predict_p50.max(1e-12)))
                    .with(
                        "ge_2x",
                        Json::Bool(
                            f0 / fact_s.max(1e-12) >= 2.0 || p0 / predict_p50.max(1e-12) >= 2.0,
                        ),
                    );
            }
            results.push(row);
        }
    }

    let doc = Json::obj()
        .with("bench", Json::Str("mka_perf".into()))
        .with(
            "generated_by",
            Json::Str("cargo bench --bench complexity -- --json".into()),
        )
        .with("rhs_cols", Json::Num(rhs as f64))
        .with("test_points", Json::Num(test_points as f64))
        .with("pool_jobs", Json::Num(mka_gp::par::jobs_executed() as f64))
        .with("simd_level", Json::Str(format!("{:?}", mka_gp::la::simd_level())))
        .with(
            "arena",
            Json::obj()
                .with("checkouts", Json::Num(mka_gp::par::arena::checkouts() as f64))
                .with("grows", Json::Num(mka_gp::par::arena::grows() as f64))
                .with("grow_bytes", Json::Num(mka_gp::par::arena::grow_bytes() as f64)),
        )
        .with("kernel", kernel_section)
        .with("results", Json::Arr(results))
        .with("acceptance", accept);
    std::fs::write(&out_path, doc.dump_pretty()).expect("write bench json");
    println!("wrote {out_path}");
}

/// Single-thread GFLOP/s of the packed/register-blocked gemm against the
/// retained pre-rewrite blocked-axpy kernel (`gemm_baseline`) on an
/// n³ problem (default 512³, `--gemm-n` to override). The ratio is the
/// PR's headline number; `ge_2x` records whether the ≥2× target held on
/// this machine (reported, not asserted — CI runners vary).
fn bench_dense_kernel(args: &Args) -> Json {
    use mka_gp::la::blas::{gemm_baseline, gemm_mt};
    let n = args.get_usize("gemm-n", 512);
    let mut rng = Rng::new(23);
    let a = Mat::from_fn(n, n, |_, _| rng.normal());
    let b = Mat::from_fn(n, n, |_, _| rng.normal());
    let flops = 2.0 * (n as f64).powi(3);

    let new = bench_budget("gemm-new", 1.0, 50, || {
        std::hint::black_box(gemm_mt(&a, &b, 1));
    });
    let old = bench_budget("gemm-baseline", 1.0, 50, || {
        std::hint::black_box(gemm_baseline(&a, &b));
    });
    let gf_new = flops / new.min_s.max(1e-12) / 1e9;
    let gf_old = flops / old.min_s.max(1e-12) / 1e9;
    let speedup = gf_new / gf_old.max(1e-12);
    println!(
        "dense kernel {n}³ ({:?}): {gf_new:.2} GFLOP/s vs baseline {gf_old:.2} ({speedup:.2}x)",
        mka_gp::la::simd_level()
    );
    Json::obj()
        .with("gemm_n", Json::Num(n as f64))
        .with("simd_level", Json::Str(format!("{:?}", mka_gp::la::simd_level())))
        .with("gemm_gflops", Json::Num(gf_new))
        .with("baseline_gflops", Json::Num(gf_old))
        .with("speedup_vs_prepr_scalar", Json::Num(speedup))
        .with("ge_2x", Json::Bool(speedup >= 2.0))
}
