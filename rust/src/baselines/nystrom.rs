//! Shared Nyström machinery: landmark ("pseudo-input") selection and the
//! common K_zz / K_zf blocks used by SoR, FITC and PITC.

use crate::data::dataset::Dataset;
use crate::kernels::Kernel;
use crate::la::chol::Chol;
use crate::la::dense::Mat;
use crate::util::Rng;

/// Landmark selection strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LandmarkMethod {
    /// Uniform random subset of the training points (the classic choice).
    Uniform,
    /// k-means cluster centres (often tighter bounds; Zhang & Kwok style).
    KMeansCenters,
}

/// Select `m` landmark points from the training inputs.
pub fn select_landmarks(x: &Mat, m: usize, method: LandmarkMethod, seed: u64) -> Mat {
    let m = m.clamp(1, x.rows);
    let mut rng = Rng::new(seed ^ 0x4c4d4b);
    match method {
        LandmarkMethod::Uniform => {
            let idx = rng.sample_indices(x.rows, m);
            x.gather_rows(&idx)
        }
        LandmarkMethod::KMeansCenters => {
            let clustering = crate::cluster::kmeans::kmeans(x, m, 25, &mut rng);
            // centroid of each cluster
            let mut z = Mat::zeros(clustering.n_clusters(), x.cols);
            for (c, members) in clustering.clusters.iter().enumerate() {
                let inv = 1.0 / members.len() as f64;
                for &i in members {
                    let row = x.row(i);
                    let zrow = z.row_mut(c);
                    for j in 0..x.cols {
                        zrow[j] += row[j] * inv;
                    }
                }
            }
            z
        }
    }
}

/// The shared Nyström blocks for a training set and landmark set.
pub struct NystromBlocks {
    /// Landmark points (m×d).
    pub z: Mat,
    /// W = K(Z, Z) with a hair of jitter for stability.
    pub w: Mat,
    /// Cholesky of W.
    pub w_chol: Chol,
    /// K(Z, X) (m×n).
    pub kzf: Mat,
}

impl NystromBlocks {
    pub fn new(train: &Dataset, kernel: &dyn Kernel, z: Mat) -> crate::error::Result<NystromBlocks> {
        let mut w = kernel.gram_sym(&z);
        let (w_chol, _j) = Chol::new_jittered(&w, 12)?;
        // keep the jitter that made it factorizable
        if _j > 0.0 {
            w.add_diag(_j);
        }
        let kzf = kernel.gram(&z, &train.x);
        Ok(NystromBlocks { z, w, w_chol, kzf })
    }

    pub fn m(&self) -> usize {
        self.z.rows
    }

    /// q_ii = k_z(x_i)ᵀ W⁻¹ k_z(x_i) — diagonal of the Nyström approximant
    /// (needed by FITC's diagonal correction). One blocked forward
    /// substitution V = L⁻¹ K_zf carrying all n right-hand sides, then
    /// column sums of squares — replaces n per-column `solve_lower` calls.
    pub fn q_diag(&self) -> Vec<f64> {
        let v = crate::la::chol::solve_lower_mat(&self.w_chol.l, &self.kzf); // m×n
        column_sq_norms(&v)
    }

    /// Q(X, X) block between index sets a, b: K_za' W⁻¹ K_zb (for PITC).
    pub fn q_block(&self, a: &[usize], b: &[usize]) -> Mat {
        let all_rows: Vec<usize> = (0..self.m()).collect();
        let kza = self.kzf.gather(&all_rows, a); // m×|a|
        let kzb = self.kzf.gather(&all_rows, b); // m×|b|
        let winv_kzb = self.w_chol.solve_mat(&kzb);
        crate::la::blas::gemm_tn(&kza, &winv_kzb)
    }
}

/// Per-column squared norms of a row-major matrix in one row-major pass:
/// out[j] = Σ_r V[r, j]².
pub fn column_sq_norms(v: &Mat) -> Vec<f64> {
    let mut out = vec![0.0; v.cols];
    for r in 0..v.rows {
        for (o, &x) in out.iter_mut().zip(v.row(r)) {
            *o += x * x;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gp_dataset, SynthSpec};
    use crate::kernels::RbfKernel;

    fn setup() -> (Dataset, RbfKernel) {
        (gp_dataset(&SynthSpec::named("t", 80, 2), 1), RbfKernel::new(1.0))
    }

    #[test]
    fn uniform_landmarks_are_training_rows() {
        let (d, _) = setup();
        let z = select_landmarks(&d.x, 10, LandmarkMethod::Uniform, 1);
        assert_eq!(z.rows, 10);
        assert_eq!(z.cols, d.dim());
    }

    #[test]
    fn kmeans_landmarks_shape() {
        let (d, _) = setup();
        let z = select_landmarks(&d.x, 8, LandmarkMethod::KMeansCenters, 2);
        assert!(z.rows <= 8 && z.rows >= 1);
        assert_eq!(z.cols, d.dim());
    }

    #[test]
    fn blocks_shapes_and_qdiag_bounds() {
        let (d, k) = setup();
        let z = select_landmarks(&d.x, 12, LandmarkMethod::Uniform, 3);
        let nb = NystromBlocks::new(&d, &k, z).unwrap();
        assert_eq!(nb.kzf.rows, 12);
        assert_eq!(nb.kzf.cols, 80);
        // Nyström is an underestimate of the diagonal: 0 ≤ q_ii ≤ k_ii.
        for q in nb.q_diag() {
            assert!(q >= -1e-9 && q <= 1.0 + 1e-6, "q={q}");
        }
    }

    #[test]
    fn q_block_consistent_with_qdiag() {
        let (d, k) = setup();
        let z = select_landmarks(&d.x, 12, LandmarkMethod::Uniform, 4);
        let nb = NystromBlocks::new(&d, &k, z).unwrap();
        let idx: Vec<usize> = (0..5).collect();
        let qb = nb.q_block(&idx, &idx);
        let qd = nb.q_diag();
        for i in 0..5 {
            assert!((qb.at(i, i) - qd[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn landmarks_all_points_makes_q_exact() {
        let (d, k) = setup();
        let nb = NystromBlocks::new(&d, &k, d.x.clone()).unwrap();
        let qd = nb.q_diag();
        for (i, q) in qd.iter().enumerate() {
            let kii = k.diag(d.x.row(i));
            assert!((q - kii).abs() < 1e-4, "i={i} q={q} k={kii}");
        }
    }
}
