//! FITC — Fully Independent Training Conditional (Snelson & Ghahramani
//! 2005; paper baseline 3, "Sparse GPs using Pseudo-inputs").
//!
//! Like SoR but with an exact diagonal correction on the training
//! conditional: Λ = diag(K_ff − Q_ff) + σ²I. Heals SoR's degenerate
//! diagonal but still cannot represent off-diagonal short-range structure.
//!
//!   A        = W + K_zf Λ⁻¹ K_fz
//!   mean(x*) = k_zᵀ A⁻¹ K_zf Λ⁻¹ y
//!   var(x*)  = k** − k_zᵀ W⁻¹ k_z + k_zᵀ A⁻¹ k_z + σ²

use super::nystrom::{column_sq_norms, select_landmarks, LandmarkMethod, NystromBlocks};
use crate::data::dataset::Dataset;
use crate::error::Result;
use crate::gp::{GpModel, ModelInfo, Prediction};
use crate::kernels::Kernel;
use crate::la::blas::{gemm_nt, gemv, gemv_t};
use crate::la::chol::{solve_lower_mat, Chol};
use crate::la::dense::Mat;

/// Fitted FITC model.
pub struct Fitc {
    z: Mat,
    kernel: Box<dyn Kernel>,
    sigma2: f64,
    n_train: usize,
    w_chol: Chol,
    a_chol: Chol,
    /// β = A⁻¹ K_zf Λ⁻¹ y.
    beta: Vec<f64>,
}

impl Fitc {
    pub fn fit(train: &Dataset, kernel: &dyn Kernel, sigma2: f64, m: usize, seed: u64) -> Result<Fitc> {
        let z = select_landmarks(&train.x, m, LandmarkMethod::Uniform, seed);
        Self::fit_with_landmarks(train, kernel, sigma2, z)
    }

    pub fn fit_with_landmarks(
        train: &Dataset,
        kernel: &dyn Kernel,
        sigma2: f64,
        z: Mat,
    ) -> Result<Fitc> {
        let nb = NystromBlocks::new(train, kernel, z)?;
        let n = train.n();
        // Λ_ii = k_ii − q_ii + σ²  (clamped: Nyström roundoff can overshoot)
        let qd = nb.q_diag();
        let lam: Vec<f64> = (0..n)
            .map(|i| (kernel.diag(train.x.row(i)) - qd[i]).max(0.0) + sigma2)
            .collect();
        // A = W + K_zf Λ⁻¹ K_fz — one rank-n GEMM over the column-scaled
        // cross block instead of n rank-1 updates.
        let mut a = nb.w.clone();
        let mut scaled = nb.kzf.clone();
        let lam_inv: Vec<f64> = lam.iter().map(|l| 1.0 / l).collect();
        for r in 0..scaled.rows {
            for (v, &li) in scaled.row_mut(r).iter_mut().zip(&lam_inv) {
                *v *= li;
            }
        }
        a.add_assign(&gemm_nt(&scaled, &nb.kzf));
        let (a_chol, _) = Chol::new_jittered(&a, 12)?;
        // rhs = K_zf Λ⁻¹ y
        let ly: Vec<f64> = train.y.iter().zip(&lam).map(|(y, l)| y / l).collect();
        let rhs = gemv(&nb.kzf, &ly);
        let beta = a_chol.solve(&rhs);
        Ok(Fitc {
            z: nb.z,
            kernel: kernel.boxed_clone(),
            sigma2,
            n_train: train.n(),
            w_chol: nb.w_chol,
            a_chol,
            beta,
        })
    }

    pub fn n_landmarks(&self) -> usize {
        self.z.rows
    }
}

impl GpModel for Fitc {
    fn predict(&self, x_test: &Mat) -> Prediction {
        // Blocked: all p test columns go through two multi-RHS triangular
        // solves instead of 2p per-point `solve_lower` loops.
        let p = x_test.rows;
        let kzt = self.kernel.gram(&self.z, x_test); // m×p
        let mean = gemv_t(&kzt, &self.beta);
        let sw = column_sq_norms(&solve_lower_mat(&self.w_chol.l, &kzt));
        let sa = column_sq_norms(&solve_lower_mat(&self.a_chol.l, &kzt));
        let var = (0..p)
            .map(|t| {
                let kss = self.kernel.diag(x_test.row(t));
                (kss - sw[t] + sa[t] + self.sigma2).max(self.sigma2 * 1e-3)
            })
            .collect();
        Prediction { mean, var }
    }

    fn name(&self) -> String {
        format!("FITC(m={})", self.z.rows)
    }

    fn info(&self) -> ModelInfo {
        ModelInfo {
            method: self.name(),
            n: self.n_train,
            dim: self.z.cols,
            sigma2: Some(self.sigma2),
            shards: 1,
            shard_sizes: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gp_dataset, SynthSpec};
    use crate::gp::full::FullGp;
    use crate::gp::metrics::smse;
    use crate::kernels::RbfKernel;

    #[test]
    fn all_landmarks_recovers_full_gp() {
        // With Z = X, Q = K and Λ = σ²I, FITC reduces exactly to the GP.
        let data = gp_dataset(&SynthSpec::named("t", 80, 2), 1);
        let (tr, te) = data.split(0.9, 1);
        let kern = RbfKernel::new(1.0);
        let fitc = Fitc::fit_with_landmarks(&tr, &kern, 0.1, tr.x.clone()).unwrap();
        let full = FullGp::fit(&tr, &kern, 0.1).unwrap();
        let pf = fitc.predict(&te.x);
        let pg = full.predict(&te.x);
        for i in 0..te.n() {
            assert!((pf.mean[i] - pg.mean[i]).abs() < 1e-3, "mean[{i}]");
            assert!((pf.var[i] - pg.var[i]).abs() < 1e-2, "var[{i}]"); // W-jitter slack
        }
    }

    #[test]
    fn healthy_variance_far_from_data() {
        // Unlike SoR, FITC keeps the k** term: far away var → k** + σ².
        let data = gp_dataset(&SynthSpec::named("t", 60, 1), 2);
        let fitc = Fitc::fit(&data, &RbfKernel::new(0.5), 0.05, 10, 3).unwrap();
        let far = fitc.predict(&Mat::from_vec(1, 1, vec![1e3]));
        assert!((far.var[0] - 1.05).abs() < 1e-4, "var={}", far.var[0]);
    }

    #[test]
    fn learns_with_few_landmarks() {
        let data = gp_dataset(&SynthSpec::named("t", 200, 2), 3);
        let (tr, te) = data.split(0.9, 4);
        let fitc = Fitc::fit(&tr, &RbfKernel::new(1.5), 0.1, 20, 5).unwrap();
        let e = smse(&te.y, &fitc.predict(&te.x).mean);
        assert!(e < 1.05, "SMSE {e}");
    }

    #[test]
    fn variances_positive() {
        let data = gp_dataset(&SynthSpec::named("t", 100, 3), 4);
        let fitc = Fitc::fit(&data, &RbfKernel::new(1.0), 0.1, 16, 6).unwrap();
        for v in fitc.predict(&data.x).var {
            assert!(v > 0.0);
        }
    }
}
