//! PITC — Partially Independent Training Conditional (Quiñonero-Candela &
//! Rasmussen 2005; paper baseline 4, equals PTC in the mean).
//!
//! FITC's diagonal correction upgraded to a **block-diagonal** one: the
//! training points are clustered; within a block the conditional keeps the
//! exact covariance, across blocks it is Nyström. Same algebra as FITC
//! with Λ = blockdiag(K_bb − Q_bb) + σ²I.

use super::nystrom::{column_sq_norms, select_landmarks, LandmarkMethod, NystromBlocks};
use crate::cluster::{cluster_rows, ClusterMethod};
use crate::data::dataset::Dataset;
use crate::error::Result;
use crate::gp::{GpModel, ModelInfo, Prediction};
use crate::kernels::Kernel;
use crate::la::blas::{dot, gemm, gemv_t};
use crate::la::chol::{solve_lower_mat, Chol};
use crate::la::dense::Mat;
use crate::util::Rng;

/// Fitted PITC model.
pub struct Pitc {
    z: Mat,
    kernel: Box<dyn Kernel>,
    sigma2: f64,
    n_train: usize,
    w_chol: Chol,
    a_chol: Chol,
    beta: Vec<f64>,
}

impl Pitc {
    pub fn fit(
        train: &Dataset,
        kernel: &dyn Kernel,
        sigma2: f64,
        m: usize,
        block_size: usize,
        seed: u64,
    ) -> Result<Pitc> {
        let z = select_landmarks(&train.x, m, LandmarkMethod::Uniform, seed);
        Self::fit_with_landmarks(train, kernel, sigma2, z, block_size, seed)
    }

    pub fn fit_with_landmarks(
        train: &Dataset,
        kernel: &dyn Kernel,
        sigma2: f64,
        z: Mat,
        block_size: usize,
        seed: u64,
    ) -> Result<Pitc> {
        let nb = NystromBlocks::new(train, kernel, z)?;
        let n = train.n();
        let m_ = nb.m();
        let mut rng = Rng::new(seed ^ 0x5049);
        let clustering = cluster_rows(
            ClusterMethod::Bisect,
            Some(&train.x),
            None,
            n,
            block_size.max(1),
            &mut rng,
        );

        // Per block: Λ_b = K_bb − Q_bb + σ²I; accumulate
        //   A = W + Σ_b K_zb Λ_b⁻¹ K_bz   and   r = Σ_b K_zb Λ_b⁻¹ y_b.
        let mut a = nb.w.clone();
        let mut rhs = vec![0.0; m_];
        let all_rows: Vec<usize> = (0..m_).collect();
        let mut lam_chols: Vec<(Vec<usize>, Chol)> = Vec::with_capacity(clustering.n_clusters());
        for members in &clustering.clusters {
            let kbb = kernel.gram_sym(&train.x.gather_rows(members));
            let qbb = nb.q_block(members, members);
            let mut lam = kbb.sub(&qbb);
            lam.symmetrize();
            lam.add_diag(sigma2);
            let (lchol, _) = Chol::new_jittered(&lam, 12)?;
            let kzb = nb.kzf.gather(&all_rows, members); // m×|b|
            // Λ_b⁻¹ K_bz  (|b|×m)
            let linv_kbz = lchol.solve_mat(&kzb.transpose());
            // A += K_zb (Λ_b⁻¹ K_bz)
            let contrib = gemm(&kzb, &linv_kbz);
            a.add_assign(&contrib);
            // rhs += K_zb Λ_b⁻¹ y_b
            let yb: Vec<f64> = members.iter().map(|&i| train.y[i]).collect();
            let linv_y = lchol.solve(&yb);
            for r in 0..m_ {
                rhs[r] += dot(kzb.row(r), &linv_y);
            }
            lam_chols.push((members.clone(), lchol));
        }
        a.symmetrize();
        let (a_chol, _) = Chol::new_jittered(&a, 12)?;
        let beta = a_chol.solve(&rhs);
        Ok(Pitc {
            z: nb.z,
            kernel: kernel.boxed_clone(),
            sigma2,
            n_train: train.n(),
            w_chol: nb.w_chol,
            a_chol,
            beta,
        })
    }

    pub fn n_landmarks(&self) -> usize {
        self.z.rows
    }
}

impl GpModel for Pitc {
    fn predict(&self, x_test: &Mat) -> Prediction {
        // Test points are (as standard) treated as their own block, so the
        // predictive equations coincide with FITC's. All p cross-covariance
        // columns ride TWO blocked triangular solves (W and A) instead of
        // 2p per-point `solve_lower` loops.
        let p = x_test.rows;
        let kzt = self.kernel.gram(&self.z, x_test); // m×p
        let mean = gemv_t(&kzt, &self.beta);
        let sw = column_sq_norms(&solve_lower_mat(&self.w_chol.l, &kzt));
        let sa = column_sq_norms(&solve_lower_mat(&self.a_chol.l, &kzt));
        let var = (0..p)
            .map(|t| {
                let kss = self.kernel.diag(x_test.row(t));
                (kss - sw[t] + sa[t] + self.sigma2).max(self.sigma2 * 1e-3)
            })
            .collect();
        Prediction { mean, var }
    }

    fn name(&self) -> String {
        format!("PITC(m={})", self.z.rows)
    }

    fn info(&self) -> ModelInfo {
        ModelInfo {
            method: self.name(),
            n: self.n_train,
            dim: self.z.cols,
            sigma2: Some(self.sigma2),
            shards: 1,
            shard_sizes: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gp_dataset, SynthSpec};
    use crate::gp::metrics::smse;
    use crate::kernels::RbfKernel;

    #[test]
    fn singleton_blocks_reduce_to_fitc() {
        let data = gp_dataset(&SynthSpec::named("t", 70, 2), 1);
        let (tr, te) = data.split(0.9, 1);
        let kern = RbfKernel::new(1.0);
        let z = select_landmarks(&tr.x, 12, LandmarkMethod::Uniform, 9);
        let pitc =
            Pitc::fit_with_landmarks(&tr, &kern, 0.1, z.clone(), 1, 9).unwrap();
        let fitc =
            crate::baselines::fitc::Fitc::fit_with_landmarks(&tr, &kern, 0.1, z).unwrap();
        let pp = pitc.predict(&te.x);
        let pf = fitc.predict(&te.x);
        for i in 0..te.n() {
            assert!(
                (pp.mean[i] - pf.mean[i]).abs() < 1e-6,
                "mean[{i}] {} vs {}",
                pp.mean[i],
                pf.mean[i]
            );
            assert!((pp.var[i] - pf.var[i]).abs() < 1e-6, "var[{i}]");
        }
    }

    #[test]
    fn one_block_with_all_landmarks_is_exact() {
        // a single block makes the training conditional exact;
        // with Z = X the prior is exact too ⇒ matches the full GP.
        let data = gp_dataset(&SynthSpec::named("t", 60, 2), 2);
        let (tr, te) = data.split(0.85, 2);
        let kern = RbfKernel::new(1.0);
        let pitc =
            Pitc::fit_with_landmarks(&tr, &kern, 0.1, tr.x.clone(), tr.n(), 3).unwrap();
        let full = crate::gp::full::FullGp::fit(&tr, &kern, 0.1).unwrap();
        let pp = pitc.predict(&te.x);
        let pf = full.predict(&te.x);
        for i in 0..te.n() {
            assert!((pp.mean[i] - pf.mean[i]).abs() < 1e-3, "mean[{i}]");
        }
    }

    #[test]
    fn learns_with_blocks() {
        let data = gp_dataset(&SynthSpec::named("t", 200, 2), 3);
        let (tr, te) = data.split(0.9, 4);
        let pitc = Pitc::fit(&tr, &RbfKernel::new(1.5), 0.1, 20, 25, 5).unwrap();
        let e = smse(&te.y, &pitc.predict(&te.x).mean);
        assert!(e < 1.05, "SMSE {e}");
        assert_eq!(pitc.n_landmarks(), 20);
    }
}
