//! Subset of Regressors (SoR) — the classic Nyström GP approximation
//! (paper baseline 2; equals DTC in the mean). Prior: f ≈ K_fz W⁻¹ u.
//!
//! mean(x*) = k_z(x*)ᵀ (K_zf K_fz + σ²W)⁻¹ K_zf y
//! var(x*)  = σ² k_z(x*)ᵀ (K_zf K_fz + σ²W)⁻¹ k_z(x*) + σ²
//!
//! Degenerate (strictly low-rank) prior ⇒ variance collapses far from the
//! landmarks — exactly the failure mode Figures 1–2 exhibit.

use super::nystrom::{column_sq_norms, select_landmarks, LandmarkMethod, NystromBlocks};
use crate::data::dataset::Dataset;
use crate::error::Result;
use crate::gp::{GpModel, ModelInfo, Prediction};
use crate::kernels::Kernel;
use crate::la::blas::{gemm_nt, gemv, gemv_t};
use crate::la::chol::{solve_lower_mat, Chol};
use crate::la::dense::Mat;

/// Fitted SoR model.
pub struct Sor {
    z: Mat,
    kernel: Box<dyn Kernel>,
    sigma2: f64,
    n_train: usize,
    /// Cholesky of A = K_zf K_fz + σ² W.
    a_chol: Chol,
    /// β = A⁻¹ K_zf y.
    beta: Vec<f64>,
}

impl Sor {
    pub fn fit(train: &Dataset, kernel: &dyn Kernel, sigma2: f64, m: usize, seed: u64) -> Result<Sor> {
        let z = select_landmarks(&train.x, m, LandmarkMethod::Uniform, seed);
        Self::fit_with_landmarks(train, kernel, sigma2, z)
    }

    pub fn fit_with_landmarks(
        train: &Dataset,
        kernel: &dyn Kernel,
        sigma2: f64,
        z: Mat,
    ) -> Result<Sor> {
        let nb = NystromBlocks::new(train, kernel, z)?;
        // A = K_zf K_fz + σ² W
        let mut a = gemm_nt(&nb.kzf, &nb.kzf);
        let mut sw = nb.w.clone();
        sw.scale(sigma2);
        a.add_assign(&sw);
        let (a_chol, _) = Chol::new_jittered(&a, 12)?;
        let kzf_y = gemv(&nb.kzf, &train.y);
        let beta = a_chol.solve(&kzf_y);
        Ok(Sor {
            z: nb.z,
            kernel: kernel.boxed_clone(),
            sigma2,
            n_train: train.n(),
            a_chol,
            beta,
        })
    }

    pub fn n_landmarks(&self) -> usize {
        self.z.rows
    }
}

impl GpModel for Sor {
    fn predict(&self, x_test: &Mat) -> Prediction {
        // Blocked: one m×p cross block, one multi-RHS triangular solve.
        let kzt = self.kernel.gram(&self.z, x_test); // m×p
        let mean = gemv_t(&kzt, &self.beta);
        // σ² k_zᵀ A⁻¹ k_z + σ²
        let sa = column_sq_norms(&solve_lower_mat(&self.a_chol.l, &kzt));
        let var = sa.iter().map(|s| self.sigma2 * s + self.sigma2).collect();
        Prediction { mean, var }
    }

    fn name(&self) -> String {
        format!("SOR(m={})", self.z.rows)
    }

    fn info(&self) -> ModelInfo {
        ModelInfo {
            method: self.name(),
            n: self.n_train,
            dim: self.z.cols,
            sigma2: Some(self.sigma2),
            shards: 1,
            shard_sizes: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gp_dataset, SynthSpec};
    use crate::gp::full::FullGp;
    use crate::gp::metrics::smse;
    use crate::kernels::RbfKernel;

    #[test]
    fn approaches_full_gp_with_all_landmarks() {
        let data = gp_dataset(&SynthSpec::named("t", 100, 2), 1);
        let (tr, te) = data.split(0.9, 1);
        let kern = RbfKernel::new(1.0);
        // landmarks = all training points ⇒ SoR mean = full GP mean
        let sor = Sor::fit_with_landmarks(&tr, &kern, 0.1, tr.x.clone()).unwrap();
        let full = FullGp::fit(&tr, &kern, 0.1).unwrap();
        let ps = sor.predict(&te.x);
        let pf = full.predict(&te.x);
        for i in 0..te.n() {
            assert!(
                (ps.mean[i] - pf.mean[i]).abs() < 1e-4,
                "i={i}: {} vs {}",
                ps.mean[i],
                pf.mean[i]
            );
        }
    }

    #[test]
    fn few_landmarks_still_learns_something() {
        let data = gp_dataset(&SynthSpec::named("t", 200, 2), 2);
        let (tr, te) = data.split(0.9, 2);
        let sor = Sor::fit(&tr, &RbfKernel::new(1.5), 0.1, 20, 3).unwrap();
        let pred = sor.predict(&te.x);
        let e = smse(&te.y, &pred.mean);
        assert!(e < 1.05, "SMSE {e}");
        assert_eq!(sor.n_landmarks(), 20);
    }

    #[test]
    fn variance_collapses_far_from_landmarks() {
        // The degenerate-prior pathology: far away, SoR variance → σ²
        // (no k** term), unlike the full GP's k** + σ².
        let data = gp_dataset(&SynthSpec::named("t", 60, 1), 3);
        let sor = Sor::fit(&data, &RbfKernel::new(0.5), 0.05, 10, 4).unwrap();
        let far = sor.predict(&Mat::from_vec(1, 1, vec![1e3]));
        assert!((far.var[0] - 0.05).abs() < 1e-6, "var={}", far.var[0]);
    }

    #[test]
    fn name_contains_m() {
        let data = gp_dataset(&SynthSpec::named("t", 50, 2), 4);
        let sor = Sor::fit(&data, &RbfKernel::new(1.0), 0.1, 8, 5).unwrap();
        assert_eq!(sor.name(), "SOR(m=8)");
    }
}
