//! MEKA — Memory Efficient Kernel Approximation (Si, Hsieh & Dhillon,
//! ICML 2014; paper baseline 5).
//!
//! Cluster the points, take a rank-r_i eigenbasis U_i of each diagonal
//! block, and approximate every off-diagonal block as U_i L_ij U_jᵀ where
//! the link matrix L_ij is estimated from a *subsample* of the block's
//! rows/columns (that subsampling is MEKA's memory win — and the reason
//! K̃ can lose positive semi-definiteness, which the paper's supplement
//! reports as MEKA failing on some datasets; we reproduce exactly that
//! failure mode and surface it via [`Meka::is_spsd`]).
//!
//! GP algebra: with U orthonormal (block-diagonal eigenvector matrix),
//! (K̃ + σ²I)⁻¹ = U (σ²I + L)⁻¹ Uᵀ + σ⁻² (I − U Uᵀ) exactly.

use crate::cluster::{cluster_rows, ClusterMethod};
use crate::data::dataset::Dataset;
use crate::error::{Error, Result};
use crate::gp::{GpModel, ModelInfo, Prediction};
use crate::kernels::Kernel;
use crate::la::blas::{dot, gemm, gemm_tn, gemv, gemv_t};
use crate::la::dense::Mat;
use crate::la::evd::SymEig;
use crate::la::lu::Lu;
use crate::util::Rng;

/// MEKA configuration.
#[derive(Clone, Debug)]
pub struct MekaConfig {
    /// Total rank budget (the paper compares at rank = #pseudo-inputs).
    pub rank: usize,
    /// Number of clusters.
    pub n_clusters: usize,
    /// Fraction of each block's rows sampled when estimating link matrices
    /// (ν in Si et al.) — smaller is cheaper but risks losing spsd-ness.
    pub sample_frac: f64,
    pub seed: u64,
}

impl MekaConfig {
    pub fn new(rank: usize) -> MekaConfig {
        MekaConfig { rank, n_clusters: 4, sample_frac: 0.5, seed: 42 }
    }
}

/// Fitted MEKA GP model.
pub struct Meka {
    train_x: Mat,
    kernel: Box<dyn Kernel>,
    sigma2: f64,
    /// Cluster membership (global row indices per cluster).
    clusters: Vec<Vec<usize>>,
    /// Per-cluster orthonormal bases U_i (m_i × r_i).
    bases: Vec<Mat>,
    /// Dense link matrix L (q×q, q = Σ r_i) in block layout.
    link: Mat,
    /// LU of (σ²I + L).
    inner_lu: Option<Lu>,
    /// α = (K̃ + σ²I)⁻¹ y (Woodbury form; kept for diagnostics/fallback).
    alpha: Vec<f64>,
    /// (σ²I + L)⁻¹ Uᵀ y — the consistent-predictor weights.
    uty_inner: Vec<f64>,
    /// Whether K̃ + σ²I is positive definite (MEKA can lose this).
    spsd_ok: bool,
}

impl Meka {
    pub fn fit(train: &Dataset, kernel: &dyn Kernel, sigma2: f64, cfg: &MekaConfig) -> Result<Meka> {
        let n = train.n();
        let mut rng = Rng::new(cfg.seed ^ 0x4d45_4b41);
        let c = cfg.n_clusters.clamp(1, cfg.rank.max(1));
        let clustering = cluster_rows(
            ClusterMethod::KMeans,
            Some(&train.x),
            None,
            n,
            n.div_ceil(c).max(1),
            &mut rng,
        );
        let clusters = clustering.clusters.clone();
        let nc = clusters.len();

        // ---- rank split proportional to cluster size ----------------------
        let ranks: Vec<usize> = clusters
            .iter()
            .map(|cl| {
                (((cfg.rank as f64) * (cl.len() as f64) / (n as f64)).round() as usize)
                    .clamp(1, cl.len())
            })
            .collect();
        let q: usize = ranks.iter().sum();

        // ---- per-cluster eigenbases ---------------------------------------
        let mut bases = Vec::with_capacity(nc);
        for (cl, &r) in clusters.iter().zip(&ranks) {
            let kb = kernel.gram_sym(&train.x.gather_rows(cl));
            let eig = SymEig::new(&kb);
            let m = cl.len();
            // top-r eigenvectors (largest eigenvalues are at the end)
            let mut u = Mat::zeros(m, r);
            for k in 0..r {
                let col = m - 1 - k;
                for i in 0..m {
                    u.set(i, k, eig.vectors.at(i, col));
                }
            }
            bases.push(u);
        }

        // ---- link matrices --------------------------------------------------
        // offsets of each cluster's columns inside L
        let mut offs = vec![0usize; nc + 1];
        for i in 0..nc {
            offs[i + 1] = offs[i] + ranks[i];
        }
        let mut link = Mat::zeros(q, q);
        for i in 0..nc {
            for j in i..nc {
                let lij = if i == j {
                    // Λ_i = U_iᵀ K_ii U_i (diagonal of top eigenvalues)
                    let kb = kernel.gram_sym(&train.x.gather_rows(&clusters[i]));
                    gemm_tn(&bases[i], &gemm(&kb, &bases[i]))
                } else {
                    // Subsampled estimation:
                    //   L_ij = pinv(U_i[S_i]) K[S_i, S_j] pinv(U_j[S_j])ᵀ
                    let si = sample_rows(&clusters[i], ranks[i], cfg.sample_frac, &mut rng);
                    let sj = sample_rows(&clusters[j], ranks[j], cfg.sample_frac, &mut rng);
                    let ui_s = gather_local(&bases[i], &clusters[i], &si);
                    let uj_s = gather_local(&bases[j], &clusters[j], &sj);
                    let kss = kernel.gram(&train.x.gather_rows(&si), &train.x.gather_rows(&sj));
                    // pinv via regularized normal equations
                    let pi = pinv_apply(&ui_s, &kss); // r_i × |sj|
                    pinv_apply(&uj_s, &pi.transpose()).transpose()
                };
                // write block (and mirror)
                for a in 0..ranks[i] {
                    for b in 0..ranks[j] {
                        link.set(offs[i] + a, offs[j] + b, lij.at(a, b));
                        link.set(offs[j] + b, offs[i] + a, lij.at(a, b));
                    }
                }
            }
        }
        link.symmetrize();

        // ---- inner system (σ²I + L) ---------------------------------------
        let mut inner = link.clone();
        inner.add_diag(sigma2);
        let spsd_ok = SymEig::new(&inner).values[0] > 0.0;
        let inner_lu = Lu::new(&inner).ok();

        // ---- α = (K̃+σ²I)⁻¹ y = U(σ²I+L)⁻¹Uᵀy + σ⁻²(y − UUᵀy) -------------
        let uty = apply_ut(&bases, &clusters, offs[nc], &train.y);
        let (alpha, uty_inner) = match &inner_lu {
            Some(lu) => {
                let inner_sol = lu.solve(&uty);
                let u_inner = apply_u(&bases, &clusters, n, &inner_sol);
                let u_uty = apply_u(&bases, &clusters, n, &uty);
                let alpha = (0..n)
                    .map(|i| u_inner[i] + (train.y[i] - u_uty[i]) / sigma2)
                    .collect();
                (alpha, inner_sol)
            }
            None => {
                return Err(Error::Linalg(
                    "MEKA inner system singular — approximation unusable".into(),
                ))
            }
        };

        Ok(Meka {
            train_x: train.x.clone(),
            kernel: kernel.boxed_clone(),
            sigma2,
            clusters,
            bases,
            link,
            inner_lu,
            alpha,
            uty_inner,
            spsd_ok,
        })
    }

    /// Did the approximation stay positive definite? (The paper's
    /// supplement drops MEKA results exactly when this fails.)
    pub fn is_spsd(&self) -> bool {
        self.spsd_ok
    }

    /// Dense K̃ reconstruction (tests / small n).
    pub fn dense_approx(&self) -> Mat {
        let n = self.train_x.rows;
        let q = self.link.rows;
        let mut out = Mat::zeros(n, n);
        // K̃ = U L Uᵀ
        for j in 0..n {
            let mut e = vec![0.0; n];
            e[j] = 1.0;
            let ut_e = apply_ut(&self.bases, &self.clusters, q, &e);
            let l_ut = gemv(&self.link, &ut_e);
            let col = apply_u(&self.bases, &self.clusters, n, &l_ut);
            for i in 0..n {
                out.set(i, j, col[i]);
            }
        }
        out.symmetrize();
        out
    }
}

/// Uᵀ v with block-diagonal U.
fn apply_ut(bases: &[Mat], clusters: &[Vec<usize>], q: usize, v: &[f64]) -> Vec<f64> {
    let mut out = Vec::with_capacity(q);
    for (u, cl) in bases.iter().zip(clusters) {
        let sub: Vec<f64> = cl.iter().map(|&i| v[i]).collect();
        out.extend(gemv_t(u, &sub));
    }
    out
}

/// U w with block-diagonal U.
fn apply_u(bases: &[Mat], clusters: &[Vec<usize>], n: usize, w: &[f64]) -> Vec<f64> {
    let mut out = vec![0.0; n];
    let mut off = 0;
    for (u, cl) in bases.iter().zip(clusters) {
        let r = u.cols;
        let sub = gemv(u, &w[off..off + r]);
        for (&i, &s) in cl.iter().zip(sub.iter()) {
            out[i] = s;
        }
        off += r;
    }
    out
}

/// Sample ≥ rank+2 (or frac·m) member rows of a cluster.
fn sample_rows(cluster: &[usize], rank: usize, frac: f64, rng: &mut Rng) -> Vec<usize> {
    let m = cluster.len();
    let want = (((m as f64) * frac).ceil() as usize).clamp((rank + 2).min(m), m);
    let picks = rng.sample_indices(m, want);
    picks.into_iter().map(|p| cluster[p]).collect()
}

/// Rows of a cluster basis corresponding to globally sampled indices.
fn gather_local(u: &Mat, cluster: &[usize], sampled: &[usize]) -> Mat {
    let pos: std::collections::HashMap<usize, usize> =
        cluster.iter().enumerate().map(|(a, &g)| (g, a)).collect();
    let local: Vec<usize> = sampled.iter().map(|g| pos[g]).collect();
    u.gather_rows(&local)
}

/// pinv(A)·B with ridge-regularized normal equations:
/// (AᵀA + εI)⁻¹ Aᵀ B, A is s×r with s ≥ r.
fn pinv_apply(a: &Mat, b: &Mat) -> Mat {
    let mut ata = gemm_tn(a, a);
    let eps = 1e-8 * ata.diagonal().iter().fold(1e-12f64, |m, &v| m.max(v));
    ata.add_diag(eps);
    let atb = gemm_tn(a, b);
    match crate::la::chol::Chol::new(&ata) {
        Ok(ch) => ch.solve_mat(&atb),
        Err(_) => atb, // degenerate; fall back to projection
    }
}

impl GpModel for Meka {
    fn predict(&self, x_test: &Mat) -> Prediction {
        let p = x_test.rows;
        let n = self.train_x.rows;
        let q = self.link.rows;
        let mut mean = Vec::with_capacity(p);
        let mut var = Vec::with_capacity(p);
        for t in 0..p {
            let xt = x_test.row(t);
            let kx = self.kernel.cross(xt, &self.train_x);
            // Consistent (projected) estimator: the cross-covariance is
            // approximated with the same projection as K̃ = UUᵀK UUᵀ, so
            //   mean = k̃*ᵀ(K̃+σ²I)⁻¹y = (Uᵀk*)ᵀ(σ²I+L)⁻¹ Uᵀy.
            // Using exact k* against the approximate inverse amplifies the
            // projection residual by 1/σ² — same inconsistency the paper
            // fixes for MKA in §4.1, applied here in its Nyström-style form.
            let ut_k = apply_ut(&self.bases, &self.clusters, q, &kx);
            let v = match &self.inner_lu {
                Some(lu) => {
                    let inner = lu.solve(&ut_k);
                    mean.push(dot(&self.uty_inner, &ut_k));
                    // var = k** − k̃*ᵀ(K̃+σ²I)⁻¹k̃* + σ²
                    let term_u = dot(&ut_k, &inner);
                    self.kernel.diag(xt) - term_u + self.sigma2
                }
                None => {
                    mean.push(dot(&kx, &self.alpha));
                    f64::NAN
                }
            };
            // When spsd is lost the quadratic form can exceed k**: the
            // "negative variance" signature. Keep it visible (NaN) rather
            // than silently clamping — the Table-1 harness reports a dash,
            // mirroring the paper's supplement.
            var.push(if self.spsd_ok { v.max(self.sigma2 * 1e-3) } else { f64::NAN });
            let _ = n;
        }
        Prediction { mean, var }
    }

    fn name(&self) -> String {
        format!("MEKA(r={})", self.link.rows)
    }

    fn info(&self) -> ModelInfo {
        ModelInfo {
            method: self.name(),
            n: self.train_x.rows,
            dim: self.train_x.cols,
            sigma2: Some(self.sigma2),
            shards: 1,
            shard_sizes: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gp_dataset, SynthSpec};
    use crate::gp::metrics::smse;
    use crate::kernels::RbfKernel;

    fn cfg(rank: usize, clusters: usize, frac: f64) -> MekaConfig {
        MekaConfig { rank, n_clusters: clusters, sample_frac: frac, seed: 11 }
    }

    #[test]
    fn approximates_kernel_matrix() {
        let data = gp_dataset(&SynthSpec::named("t", 80, 2), 1);
        let kern = RbfKernel::new(2.0);
        let meka = Meka::fit(&data, &kern, 0.1, &cfg(24, 3, 1.0)).unwrap();
        let k = kern.gram_sym(&data.x);
        let ka = meka.dense_approx();
        let rel = ka.sub(&k).frob_norm() / k.frob_norm();
        assert!(rel < 0.5, "rel={rel}");
    }

    #[test]
    fn full_rank_single_cluster_is_near_exact() {
        let data = gp_dataset(&SynthSpec::named("t", 40, 2), 2);
        let kern = RbfKernel::new(1.0);
        let meka = Meka::fit(&data, &kern, 0.1, &cfg(40, 1, 1.0)).unwrap();
        let k = kern.gram_sym(&data.x);
        let rel = meka.dense_approx().sub(&k).frob_norm() / k.frob_norm();
        assert!(rel < 1e-6, "rel={rel}");
        assert!(meka.is_spsd());
    }

    #[test]
    fn learns_regression() {
        let data = gp_dataset(&SynthSpec::named("t", 200, 2), 3);
        let (tr, te) = data.split(0.9, 4);
        let meka = Meka::fit(&tr, &RbfKernel::new(1.5), 0.1, &cfg(24, 3, 1.0)).unwrap();
        let e = smse(&te.y, &meka.predict(&te.x).mean);
        assert!(e < 1.05, "SMSE {e}");
    }

    #[test]
    fn aggressive_subsampling_can_lose_spsd_but_flags_it() {
        // With harsh subsampling the link estimation noise can push
        // σ²I + L indefinite; whether it does is data dependent — what we
        // require is that the flag and the NaN-variance contract hold.
        let data = gp_dataset(&SynthSpec::named("t", 150, 4), 5);
        let meka = Meka::fit(&data, &RbfKernel::new(0.4), 0.01, &cfg(40, 5, 0.15));
        if let Ok(m) = meka {
            let pred = m.predict(&data.x.block(0, 5, 0, 4));
            if m.is_spsd() {
                assert!(pred.var.iter().all(|v| v.is_finite()));
            } else {
                assert!(pred.var.iter().all(|v| v.is_nan()));
            }
        } // an Err is also an acceptable signature of the failure mode
    }

    #[test]
    fn woodbury_identity_against_dense() {
        // α from the orthonormal-U Woodbury form must equal the dense solve.
        let data = gp_dataset(&SynthSpec::named("t", 50, 2), 6);
        let kern = RbfKernel::new(1.0);
        let meka = Meka::fit(&data, &kern, 0.2, &cfg(20, 2, 1.0)).unwrap();
        let mut kt = meka.dense_approx();
        kt.add_diag(0.2);
        let chol = crate::la::chol::Chol::new_jittered(&kt, 10).unwrap().0;
        let alpha_dense = chol.solve(&data.y);
        for i in 0..50 {
            assert!(
                (alpha_dense[i] - meka.alpha[i]).abs() < 1e-5,
                "i={i}: {} vs {}",
                alpha_dense[i],
                meka.alpha[i]
            );
        }
    }
}
