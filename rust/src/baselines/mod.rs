//! The paper's five comparison methods, reimplemented from their defining
//! equations (§5): SoR/DTC, FITC, PITC (the Nyström family, sharing
//! [`nystrom`]) and MEKA (block low rank). "Full" lives in
//! [`crate::gp::full`].

pub mod fitc;
pub mod meka;
pub mod nystrom;
pub mod pitc;
pub mod sor;

pub use fitc::Fitc;
pub use meka::{Meka, MekaConfig};
pub use pitc::Pitc;
pub use sor::Sor;
