//! Custom micro/meso-benchmark harness (no criterion in the offline build).
//!
//! Provides warmup + repeated timing with mean/std/percentiles, and a
//! tabular reporter used by every `rust/benches/*.rs` target.

use crate::la::stats::{mean_std_sample, quantile_sorted};
use crate::util::timer::Timer;

/// Timing statistics for one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl BenchStats {
    pub fn throughput(&self, items: f64) -> f64 {
        items / self.mean_s.max(1e-12)
    }
}

/// Run `f` repeatedly: `warmup` discarded runs then `iters` timed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters.max(1));
    for _ in 0..iters.max(1) {
        let t = Timer::start();
        f();
        samples.push(t.elapsed_secs());
    }
    stats_from(name, &samples)
}

/// Adaptive: time-boxed benchmarking — run until `budget_s` seconds of
/// measurement or `max_iters`, whichever first (min 3 iters).
pub fn bench_budget<F: FnMut()>(name: &str, budget_s: f64, max_iters: usize, mut f: F) -> BenchStats {
    // one warmup
    f();
    let mut samples = Vec::new();
    let wall = Timer::start();
    while samples.len() < 3 || (wall.elapsed_secs() < budget_s && samples.len() < max_iters) {
        let t = Timer::start();
        f();
        samples.push(t.elapsed_secs());
        if samples.len() >= max_iters {
            break;
        }
    }
    stats_from(name, &samples)
}

fn stats_from(name: &str, samples: &[f64]) -> BenchStats {
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let (mean_s, std_s) = mean_std_sample(samples);
    BenchStats {
        name: name.to_string(),
        iters: samples.len(),
        mean_s,
        std_s,
        p50_s: quantile_sorted(&sorted, 0.5),
        p95_s: quantile_sorted(&sorted, 0.95),
        min_s: sorted[0],
    }
}

/// Simple fixed-width table printer for bench reports.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn to_string(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (c, cell) in cells.iter().enumerate() {
                line.push_str(&format!("{:>width$}  ", cell, width = widths[c]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * ncol));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.to_string());
    }
}

/// Format seconds for bench tables.
pub fn fmt_secs(s: f64) -> String {
    crate::util::timer::fmt_duration(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_stats() {
        let mut count = 0;
        let st = bench("noop", 2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(st.iters, 5);
        assert!(st.mean_s >= 0.0);
        assert!(st.p95_s >= st.p50_s);
        assert!(st.min_s <= st.mean_s + st.std_s + 1e-12);
    }

    #[test]
    fn bench_budget_respects_min_iters() {
        let st = bench_budget("fast", 0.0, 100, || {});
        assert!(st.iters >= 3);
        assert!(st.iters <= 100);
    }

    #[test]
    fn throughput_positive() {
        let st = bench("t", 0, 3, || std::thread::sleep(std::time::Duration::from_micros(100)));
        assert!(st.throughput(1000.0) > 0.0);
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new(&["a", "method"]);
        t.row(&["1".into(), "mka".into()]);
        let s = t.to_string();
        assert!(s.contains("method"));
        assert!(s.contains("mka"));
        assert!(s.lines().count() == 3);
    }
}
