//! Graph kernels (paper §4, last paragraph): diffusion kernels
//! K = exp(−βL) are matrix functions of a *sparse* Laplacian, which is the
//! one case where MKA can avoid even writing down the dense kernel matrix.
//!
//! This module provides graph generators, the exact dense diffusion kernel
//! (EVD-based oracle for tests/benches), and helpers to feed a Laplacian
//! into the MKA pipeline; the fast path itself is
//! `mka::MkaFactor::matrix_exp` (Proposition 7).

use crate::la::dense::Mat;
use crate::la::evd::SymEig;
use crate::la::sparse::Graph;
use crate::util::Rng;

/// Exact diffusion kernel exp(−βL) via dense EVD — O(n³) oracle.
pub fn diffusion_dense(graph: &Graph, beta: f64) -> Mat {
    let l = graph.laplacian().to_dense();
    let e = SymEig::new(&l);
    e.apply_fn(|lam| (-beta * lam).exp())
}

/// Exact p-step random-walk kernel (aI − L)^p (Smola & Kondor 2003).
pub fn random_walk_dense(graph: &Graph, a: f64, p: u32) -> Mat {
    let l = graph.laplacian().to_dense();
    let e = SymEig::new(&l);
    e.apply_fn(|lam| (a - lam).powi(p as i32))
}

/// Erdős–Rényi-ish sparse random graph with expected degree `deg`.
pub fn random_graph(n: usize, deg: f64, rng: &mut Rng) -> Graph {
    let p = (deg / (n as f64 - 1.0)).min(1.0);
    let mut edges = Vec::new();
    for i in 0..n {
        for j in (i + 1)..n {
            if rng.uniform() < p {
                edges.push((i, j, 1.0));
            }
        }
    }
    // Guarantee no isolated vertices (connect stragglers to a random node).
    let mut deg_count = vec![0usize; n];
    for &(i, j, _) in &edges {
        deg_count[i] += 1;
        deg_count[j] += 1;
    }
    for i in 0..n {
        if deg_count[i] == 0 {
            let mut j = rng.below(n);
            while j == i {
                j = rng.below(n);
            }
            edges.push((i.min(j), i.max(j), 1.0));
        }
    }
    Graph::from_edges(n, &edges)
}

/// k-nearest-neighbour graph over data points (gaussian edge weights) —
/// the standard way to get a sparse Laplacian from a point cloud.
pub fn knn_graph(x: &Mat, k: usize, lengthscale: f64) -> Graph {
    let n = x.rows;
    let mut edges: Vec<(usize, usize, f64)> = Vec::new();
    let mut seen = std::collections::HashSet::new();
    for i in 0..n {
        // distances to all others (O(n²) — fine for bench sizes)
        let mut d: Vec<(f64, usize)> = (0..n)
            .filter(|&j| j != i)
            .map(|j| {
                let mut s = 0.0;
                for (a, b) in x.row(i).iter().zip(x.row(j)) {
                    s += (a - b) * (a - b);
                }
                (s, j)
            })
            .collect();
        d.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        for &(d2, j) in d.iter().take(k) {
            let key = (i.min(j), i.max(j));
            if seen.insert(key) {
                let w = (-d2 / (2.0 * lengthscale * lengthscale)).exp();
                edges.push((key.0, key.1, w));
            }
        }
    }
    Graph::from_edges(n, &edges)
}

/// Ring lattice — deterministic structured graph for tests.
pub fn ring_graph(n: usize) -> Graph {
    let edges: Vec<(usize, usize, f64)> = (0..n).map(|i| (i, (i + 1) % n, 1.0)).collect();
    let edges: Vec<(usize, usize, f64)> =
        edges.into_iter().map(|(i, j, w)| (i.min(j), i.max(j), w)).collect();
    Graph::from_edges(n, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diffusion_at_beta_zero_is_identity() {
        let g = ring_graph(8);
        let k = diffusion_dense(&g, 0.0);
        assert!(k.sub(&Mat::eye(8)).max_abs() < 1e-10);
    }

    #[test]
    fn diffusion_is_psd_and_symmetric() {
        let mut rng = Rng::new(1);
        let g = random_graph(20, 4.0, &mut rng);
        let k = diffusion_dense(&g, 0.7);
        assert!(k.asymmetry() < 1e-9);
        let e = SymEig::new(&k);
        assert!(e.values[0] > -1e-10);
    }

    #[test]
    fn diffusion_rows_sum_to_one() {
        // exp(−βL)·1 = 1 since L·1 = 0.
        let g = ring_graph(10);
        let k = diffusion_dense(&g, 1.3);
        for i in 0..10 {
            let s: f64 = k.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-9, "row {i} sums to {s}");
        }
    }

    #[test]
    fn random_graph_has_no_isolated_vertices() {
        let mut rng = Rng::new(2);
        let g = random_graph(50, 3.0, &mut rng);
        for (i, d) in g.degrees().iter().enumerate() {
            assert!(*d > 0.0, "vertex {i} isolated");
        }
    }

    #[test]
    fn knn_graph_connects_each_vertex() {
        let mut rng = Rng::new(3);
        let x = Mat::from_fn(30, 2, |_, _| rng.normal());
        let g = knn_graph(&x, 3, 1.0);
        for d in g.degrees() {
            assert!(d > 0.0);
        }
    }

    #[test]
    fn random_walk_kernel_psd_when_a_large() {
        let g = ring_graph(12);
        // max eigenvalue of ring Laplacian is ≤ 4; a = 5 keeps it psd.
        let k = random_walk_dense(&g, 5.0, 2);
        let e = SymEig::new(&k);
        assert!(e.values[0] > -1e-9);
    }
}
