//! Gram-matrix construction — the O(n²) part of the pipeline.
//!
//! `GramBuilder` assembles K(X, Y) tile by tile. Each tile either goes
//! through the native Rust evaluator or through a [`TileEngine`] — the
//! PJRT-loaded, Pallas-authored XLA artifact (see `runtime::engine`), which
//! is the paper's "forming K" hot spot moved onto the AOT compute path.

use std::sync::Arc;

use super::Kernel;
use crate::la::dense::Mat;

/// Something that can produce an RBF gram tile K(Xb, Yb) for row-blocks of
/// points. Implemented by `runtime::engine::XlaEngine` over the AOT
/// artifact; tests provide mock implementations.
pub trait TileEngine: Send + Sync {
    /// Tile size T the engine was compiled for (tiles are padded to T×T).
    fn tile(&self) -> usize;

    /// Max feature dimension D the engine was compiled for.
    fn max_dim(&self) -> usize;

    /// Compute the RBF gram tile for (possibly short) blocks `xb` (r×d) and
    /// `yb` (c×d): out[i][j] = sf² exp(−‖x_i − y_j‖²/(2ℓ²)).
    fn rbf_tile(&self, xb: &Mat, yb: &Mat, lengthscale: f64, signal_var: f64) -> Mat;
}

/// Gram tiles engage the pool above this many output entries.
const TILE_PAR_MIN_ENTRIES: usize = 1 << 14;

/// Builds gram matrices, optionally offloading tiles to a [`TileEngine`].
/// Tiles are independent, so both the engine path and the native fallback
/// are tile/band-parallel over the shared pool — each tile is produced by
/// exactly one task with the same per-tile computation as the serial
/// sweep, keeping results bit-identical at any thread count.
pub struct GramBuilder {
    kernel: Box<dyn Kernel>,
    engine: Option<Arc<dyn TileEngine>>,
    /// RBF parameters if (and only if) the kernel is RBF — the AOT tile
    /// kernel implements the RBF formula specifically.
    rbf_params: Option<(f64, f64)>,
    /// Thread-count cap (None = process-wide default).
    threads: Option<usize>,
}

impl Clone for GramBuilder {
    fn clone(&self) -> GramBuilder {
        GramBuilder {
            kernel: self.kernel.boxed_clone(),
            engine: self.engine.clone(),
            rbf_params: self.rbf_params,
            threads: self.threads,
        }
    }
}

impl GramBuilder {
    pub fn new(kernel: Box<dyn Kernel>) -> GramBuilder {
        GramBuilder { kernel, engine: None, rbf_params: None, threads: None }
    }

    /// Create a builder for an RBF kernel that may offload to `engine`.
    pub fn rbf(lengthscale: f64, signal_var: f64, engine: Option<Arc<dyn TileEngine>>) -> GramBuilder {
        GramBuilder {
            kernel: Box::new(super::RbfKernel::with_signal(lengthscale, signal_var)),
            engine,
            rbf_params: Some((lengthscale, signal_var)),
            threads: None,
        }
    }

    /// Cap the worker threads used for tile assembly (testing/benching).
    pub fn with_threads(mut self, threads: usize) -> GramBuilder {
        self.threads = Some(threads);
        self
    }

    fn effective_threads(&self) -> usize {
        self.threads.unwrap_or_else(crate::par::threads)
    }

    pub fn kernel(&self) -> &dyn Kernel {
        self.kernel.as_ref()
    }

    pub fn has_engine(&self) -> bool {
        self.engine.is_some()
    }

    /// Dense K(X, Y).
    pub fn build(&self, x: &Mat, y: &Mat) -> Mat {
        match (&self.engine, self.rbf_params) {
            (Some(eng), Some((l, sf))) if x.cols <= eng.max_dim() => {
                self.build_tiled(eng.as_ref(), x, y, l, sf)
            }
            _ => super::gram_with(self.kernel.as_ref(), x, y, self.effective_threads()),
        }
    }

    /// Dense symmetric K(X, X).
    pub fn build_sym(&self, x: &Mat) -> Mat {
        match (&self.engine, self.rbf_params) {
            (Some(eng), Some((l, sf))) if x.cols <= eng.max_dim() => {
                self.build_sym_tiled(eng.as_ref(), x, l, sf)
            }
            _ => super::gram_sym_with(self.kernel.as_ref(), x, self.effective_threads()),
        }
    }

    /// Engine path for K(X, X): upper-triangle tiles, each written to its
    /// own block and its mirror (disjoint regions per tile ⇒ tile-parallel
    /// is race-free; a diagonal tile only overwrites itself).
    fn build_sym_tiled(&self, eng: &dyn TileEngine, x: &Mat, l: f64, sf: f64) -> Mat {
        let t = eng.tile();
        let n = x.rows;
        // Arena-backed output: upper tiles plus their mirrors cover every
        // entry, and the diagonal is rewritten exactly below.
        let mut k = crate::par::arena::take_mat(n, n);
        // Enumerate upper-triangle tile origins.
        let mut tiles: Vec<(usize, usize)> = Vec::new();
        let mut r0 = 0;
        while r0 < n {
            let mut c0 = r0;
            while c0 < n {
                tiles.push((r0, c0));
                c0 = (c0 + t).min(n);
            }
            r0 = (r0 + t).min(n);
        }
        let write_tile = |kptr: crate::par::SendPtr<f64>, r0: usize, c0: usize| {
            let r1 = (r0 + t).min(n);
            let c1 = (c0 + t).min(n);
            let xb = x.block(r0, r1, 0, x.cols);
            let yb = x.block(c0, c1, 0, x.cols);
            let tile = eng.rbf_tile(&xb, &yb, l, sf);
            for i in 0..(r1 - r0) {
                for j in 0..(c1 - c0) {
                    let v = tile.at(i, j);
                    // SAFETY: tile (r0,c0) owns block [r0,r1)×[c0,c1) and
                    // its mirror [c0,c1)×[r0,r1); distinct upper tiles own
                    // distinct block pairs.
                    unsafe {
                        *kptr.ptr().add((r0 + i) * n + (c0 + j)) = v;
                        *kptr.ptr().add((c0 + j) * n + (r0 + i)) = v;
                    }
                }
            }
            crate::par::arena::give_mat(tile);
        };
        let kptr = crate::par::SendPtr::new(k.data.as_mut_ptr());
        let threads = if n * n < TILE_PAR_MIN_ENTRIES { 1 } else { self.effective_threads() };
        let tiles_ref = &tiles;
        crate::par::run_tasks(tiles.len(), threads, move |ti| {
            let (r0, c0) = tiles_ref[ti];
            write_tile(kptr, r0, c0);
        });
        // Exact diagonal.
        for i in 0..n {
            k.set(i, i, sf);
        }
        k
    }

    fn build_tiled(&self, eng: &dyn TileEngine, x: &Mat, y: &Mat, l: f64, sf: f64) -> Mat {
        let t = eng.tile();
        // Arena-backed output: the strips below overwrite every row band.
        let mut k = crate::par::arena::take_mat(x.rows, y.rows);
        let n = y.rows;
        // Row strips of tiles write disjoint row bands of K.
        let strips: Vec<usize> = (0..x.rows).step_by(t).collect();
        let fill_strip = |kptr: crate::par::SendPtr<f64>, r0: usize| {
            let r1 = (r0 + t).min(x.rows);
            let xb = x.block(r0, r1, 0, x.cols);
            let mut c0 = 0;
            while c0 < n {
                let c1 = (c0 + t).min(n);
                let yb = y.block(c0, c1, 0, y.cols);
                let tile = eng.rbf_tile(&xb, &yb, l, sf);
                for i in 0..(r1 - r0) {
                    // SAFETY: strip owns rows [r0, r1) of K.
                    unsafe {
                        std::ptr::copy_nonoverlapping(
                            tile.row(i).as_ptr(),
                            kptr.ptr().add((r0 + i) * n + c0),
                            c1 - c0,
                        );
                    }
                }
                crate::par::arena::give_mat(tile);
                c0 = c1;
            }
        };
        let kptr = crate::par::SendPtr::new(k.data.as_mut_ptr());
        let threads =
            if x.rows * n < TILE_PAR_MIN_ENTRIES { 1 } else { self.effective_threads() };
        let strips_ref = &strips;
        crate::par::run_tasks(strips.len(), threads, move |si| {
            fill_strip(kptr, strips_ref[si]);
        });
        k
    }
}

/// Pure-Rust reference tile (used by the native fallback engine and tests):
/// same math as the Pallas kernel in `python/compile/kernels/gram.py`.
pub fn rbf_tile_native(xb: &Mat, yb: &Mat, lengthscale: f64, signal_var: f64) -> Mat {
    let inv = 1.0 / (2.0 * lengthscale * lengthscale);
    // ‖x‖² + ‖y‖² − 2 x·y, then exp — mirrors the kernel's MXU+VPU split.
    // All temporaries (and the output) cycle through the worker arena.
    use crate::par::arena;
    let mut xs = arena::take_vec(xb.rows);
    for (i, s) in xs.iter_mut().enumerate() {
        *s = crate::la::blas::dot(xb.row(i), xb.row(i));
    }
    let mut ys = arena::take_vec(yb.rows);
    for (j, s) in ys.iter_mut().enumerate() {
        *s = crate::la::blas::dot(yb.row(j), yb.row(j));
    }
    let xy = crate::la::blas::gemm_nt(xb, yb);
    let mut out = arena::take_mat(xb.rows, yb.rows);
    for i in 0..xb.rows {
        let (xyr, or) = (xy.row(i), out.row_mut(i));
        for j in 0..yb.rows {
            let d2 = (xs[i] + ys[j] - 2.0 * xyr[j]).max(0.0);
            or[j] = signal_var * (-d2 * inv).exp();
        }
    }
    arena::give_mat(xy);
    arena::give_vec(xs);
    arena::give_vec(ys);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::RbfKernel;
    use crate::util::Rng;

    struct NativeEngine {
        tile: usize,
    }

    impl TileEngine for NativeEngine {
        fn tile(&self) -> usize {
            self.tile
        }
        fn max_dim(&self) -> usize {
            64
        }
        fn rbf_tile(&self, xb: &Mat, yb: &Mat, l: f64, sf: f64) -> Mat {
            rbf_tile_native(xb, yb, l, sf)
        }
    }

    fn randx(n: usize, d: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(n, d, |_, _| rng.normal())
    }

    #[test]
    fn native_tile_matches_pointwise() {
        let x = randx(7, 3, 1);
        let y = randx(5, 3, 2);
        let k = RbfKernel::with_signal(0.8, 1.5);
        let tile = rbf_tile_native(&x, &y, 0.8, 1.5);
        let direct = k.gram(&x, &y);
        assert!(tile.sub(&direct).max_abs() < 1e-12);
    }

    #[test]
    fn tiled_build_matches_direct_nonsquare_and_remainders() {
        // n deliberately not a multiple of the tile size
        let x = randx(23, 4, 3);
        let y = randx(17, 4, 4);
        let eng: Arc<dyn TileEngine> = Arc::new(NativeEngine { tile: 8 });
        let b = GramBuilder::rbf(1.2, 1.0, Some(eng));
        let k = b.build(&x, &y);
        let direct = RbfKernel::new(1.2).gram(&x, &y);
        assert!(k.sub(&direct).max_abs() < 1e-12);
    }

    #[test]
    fn tiled_sym_matches_direct() {
        let x = randx(21, 3, 5);
        let eng: Arc<dyn TileEngine> = Arc::new(NativeEngine { tile: 8 });
        let b = GramBuilder::rbf(0.6, 2.0, Some(eng));
        let k = b.build_sym(&x);
        let direct = RbfKernel::with_signal(0.6, 2.0).gram_sym(&x);
        assert!(k.sub(&direct).max_abs() < 1e-12);
        assert_eq!(k.asymmetry(), 0.0);
    }

    #[test]
    fn no_engine_falls_back() {
        let x = randx(10, 3, 6);
        let b = GramBuilder::new(Box::new(RbfKernel::new(1.0)));
        assert!(!b.has_engine());
        let k = b.build_sym(&x);
        assert!(k.sub(&RbfKernel::new(1.0).gram_sym(&x)).max_abs() < 1e-15);
    }

    #[test]
    fn high_dim_bypasses_engine() {
        // dim > engine max_dim → native path, still correct
        let x = randx(9, 70, 7);
        let eng: Arc<dyn TileEngine> = Arc::new(NativeEngine { tile: 8 });
        let b = GramBuilder::rbf(1.0, 1.0, Some(eng));
        let k = b.build_sym(&x);
        assert!(k.sub(&RbfKernel::new(1.0).gram_sym(&x)).max_abs() < 1e-12);
    }
}
