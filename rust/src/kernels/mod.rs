//! Covariance (kernel) functions and gram-matrix builders.
//!
//! The paper's experiments use the Gaussian (RBF) kernel with a single
//! length scale; we additionally provide Laplace, Matérn 3/2 & 5/2, linear
//! and polynomial kernels so the library is usable beyond the reproduction,
//! plus graph diffusion kernels (§4) in [`graph`].
//!
//! Gram construction is the O(n²) hot spot. [`gram::GramBuilder`] dispatches
//! between the native Rust path and the AOT-compiled XLA/Pallas tile kernel
//! loaded through [`crate::runtime`].

pub mod gram;
pub mod graph;

use crate::la::dense::Mat;

/// A positive-definite covariance function on feature vectors.
pub trait Kernel: Send + Sync {
    /// k(x, x').
    fn eval(&self, x: &[f64], y: &[f64]) -> f64;

    /// k(x, x) — usually the signal variance; defaults to `eval(x, x)`.
    fn diag(&self, x: &[f64]) -> f64 {
        self.eval(x, x)
    }

    /// Human-readable name for logs and manifests.
    fn name(&self) -> String;

    /// Cache-key words identifying this kernel *and* its hyperparameter
    /// bits — one component of the predict-cache model fingerprint
    /// (`gp::predict_cache`). Two kernels whose grams can differ on any
    /// input must fingerprint differently. The default hashes the
    /// display name (which embeds the parameters for every kernel
    /// here); the hot serving kernels override with their exact
    /// parameter bits so the fingerprint is collision-free, not just
    /// collision-resistant.
    fn fingerprint(&self) -> Vec<u64> {
        vec![fnv1a_bytes(self.name().as_bytes())]
    }

    /// Clone into a box (object-safe clone).
    fn boxed_clone(&self) -> Box<dyn Kernel>;

    /// Dense gram matrix K(X, Y); rows of `x`/`y` are points. Row-band
    /// parallel over the shared pool (deterministic — every entry is an
    /// independent `eval`).
    fn gram(&self, x: &Mat, y: &Mat) -> Mat {
        gram_with(self, x, y, crate::par::threads())
    }

    /// Symmetric gram matrix K(X, X) — computes the upper triangle once
    /// (band-parallel), then mirrors.
    fn gram_sym(&self, x: &Mat) -> Mat {
        gram_sym_with(self, x, crate::par::threads())
    }

    /// Cross-covariance vector k(x, X) against all rows of X.
    fn cross(&self, x: &[f64], xs: &Mat) -> Vec<f64> {
        (0..xs.rows).map(|i| self.eval(x, xs.row(i))).collect()
    }
}

impl Clone for Box<dyn Kernel> {
    fn clone(&self) -> Self {
        self.boxed_clone()
    }
}

/// FNV-1a over raw bytes — the default [`Kernel::fingerprint`] hash
/// (deterministic, std-only, stable across platforms).
pub(crate) fn fnv1a_bytes(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Gram assembly engages the pool above this many output entries (kernel
/// evals carry an `exp`, so the per-element cost is far above a gemm FMA).
const GRAM_PAR_MIN_ENTRIES: usize = 1 << 14;

/// K(X, Y) with an explicit thread-count cap. Bands of output rows are
/// filled independently; entry (i, j) is the same single `eval` at any
/// thread count, so results are bit-identical to the serial path.
pub fn gram_with<K: Kernel + ?Sized>(kernel: &K, x: &Mat, y: &Mat, threads: usize) -> Mat {
    assert_eq!(x.cols, y.cols, "dimension mismatch");
    let (n, m) = (x.rows, y.rows);
    // Arena-backed output: every entry is overwritten by the fill below.
    let mut k = crate::par::arena::take_mat(n, m);
    let fill = |kband: &mut [f64], i0: usize, i1: usize| {
        for i in i0..i1 {
            let xr = x.row(i);
            let krow = &mut kband[(i - i0) * m..(i - i0) * m + m];
            for (j, kv) in krow.iter_mut().enumerate() {
                *kv = kernel.eval(xr, y.row(j));
            }
        }
    };
    if threads <= 1 || n < 2 || n * m < GRAM_PAR_MIN_ENTRIES {
        fill(&mut k.data, 0, n);
        return k;
    }
    let kptr = crate::par::SendPtr::new(k.data.as_mut_ptr());
    crate::par::for_ranges(n, threads, move |_, lo, hi| {
        // SAFETY: bands are disjoint row ranges of K.
        let band = unsafe {
            std::slice::from_raw_parts_mut(kptr.ptr().add(lo * m), (hi - lo) * m)
        };
        fill(band, lo, hi);
    });
    k
}

/// Symmetric K(X, X) with an explicit thread-count cap: the upper triangle
/// is filled in row bands (each entry one `eval`, exactly as serial), then
/// mirrored — so `asymmetry()` is exactly 0 and any thread count gives the
/// same bits.
pub fn gram_sym_with<K: Kernel + ?Sized>(kernel: &K, x: &Mat, threads: usize) -> Mat {
    let n = x.rows;
    // Arena-backed output: the upper fill plus the mirror below together
    // overwrite every entry.
    let mut k = crate::par::arena::take_mat(n, n);
    let fill_upper = |kband: &mut [f64], i0: usize, i1: usize| {
        for i in i0..i1 {
            let xr = x.row(i);
            let krow = &mut kband[(i - i0) * n..(i - i0) * n + n];
            krow[i] = kernel.diag(xr);
            for j in (i + 1)..n {
                krow[j] = kernel.eval(xr, x.row(j));
            }
        }
    };
    if threads <= 1 || n < 2 || n * n < GRAM_PAR_MIN_ENTRIES {
        fill_upper(&mut k.data, 0, n);
        for i in 0..n {
            for j in (i + 1)..n {
                let v = k.at(i, j);
                k.set(j, i, v);
            }
        }
        return k;
    }
    let kptr = crate::par::SendPtr::new(k.data.as_mut_ptr());
    crate::par::for_ranges(n, threads, move |_, lo, hi| {
        // SAFETY: bands are disjoint row ranges of K.
        let band = unsafe {
            std::slice::from_raw_parts_mut(kptr.ptr().add(lo * n), (hi - lo) * n)
        };
        fill_upper(band, lo, hi);
    });
    // Mirror: row j of the lower triangle reads only finished upper rows.
    crate::par::for_ranges(n, threads, move |_, lo, hi| {
        for j in lo..hi {
            for i in 0..j {
                // SAFETY: writes stay inside rows [lo, hi).
                unsafe {
                    let v = *kptr.ptr().add(i * n + j);
                    *kptr.ptr().add(j * n + i) = v;
                }
            }
        }
    });
    k
}

#[inline]
fn sqdist(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let mut s = 0.0;
    for (a, b) in x.iter().zip(y) {
        let d = a - b;
        s += d * d;
    }
    s
}

/// Gaussian / RBF kernel: k(x, x') = σ_f² exp(−‖x−x'‖² / (2ℓ²)).
///
/// The paper uses a single length scale for all dimensions; so do we.
#[derive(Clone, Debug)]
pub struct RbfKernel {
    pub lengthscale: f64,
    pub signal_var: f64,
}

impl RbfKernel {
    pub fn new(lengthscale: f64) -> RbfKernel {
        RbfKernel { lengthscale, signal_var: 1.0 }
    }

    pub fn with_signal(lengthscale: f64, signal_var: f64) -> RbfKernel {
        RbfKernel { lengthscale, signal_var }
    }
}

impl Kernel for RbfKernel {
    #[inline]
    fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        self.signal_var * (-sqdist(x, y) / (2.0 * self.lengthscale * self.lengthscale)).exp()
    }

    fn diag(&self, _x: &[f64]) -> f64 {
        self.signal_var
    }

    fn name(&self) -> String {
        format!("rbf(l={}, sf2={})", self.lengthscale, self.signal_var)
    }

    fn fingerprint(&self) -> Vec<u64> {
        // Family tag + exact parameter bits: collision-free by
        // construction (the tag keeps an RBF from ever sharing a scope
        // with a one-dimensional ARD at the same ℓ).
        vec![1, self.lengthscale.to_bits(), self.signal_var.to_bits()]
    }

    fn boxed_clone(&self) -> Box<dyn Kernel> {
        Box::new(self.clone())
    }
}

/// ARD (automatic relevance determination) Gaussian kernel:
///
///   k(x, x') = σ_f² exp(−½ Σ_d (x_d − x'_d)² / ℓ_d²)
///
/// with one length scale per input dimension. With all ℓ_d equal it is
/// exactly [`RbfKernel`]; the per-dimension parametrization is what
/// gradient-based evidence maximization unlocks (`train::grad` supplies
/// ∂(log marginal likelihood)/∂log ℓ_d for every evidence evaluator, and
/// `train::optimizer`'s L-BFGS walks all d+1 log-parameters at once).
#[derive(Clone, Debug)]
pub struct ArdRbfKernel {
    /// One length scale per input dimension.
    pub lengthscales: Vec<f64>,
    pub signal_var: f64,
}

impl ArdRbfKernel {
    /// Per-dimension length scales (all must be positive and finite).
    pub fn new(lengthscales: Vec<f64>) -> ArdRbfKernel {
        assert!(
            !lengthscales.is_empty() && lengthscales.iter().all(|l| l.is_finite() && *l > 0.0),
            "ARD lengthscales must be positive and finite: {lengthscales:?}"
        );
        ArdRbfKernel { lengthscales, signal_var: 1.0 }
    }

    /// The isotropic kernel ℓ_d = ℓ for all `dim` dimensions (identical to
    /// [`RbfKernel::new`] values, useful for tied-lengthscale gradients).
    pub fn isotropic(lengthscale: f64, dim: usize) -> ArdRbfKernel {
        ArdRbfKernel::new(vec![lengthscale; dim.max(1)])
    }

    /// Number of input dimensions this kernel is parametrized for.
    pub fn dim(&self) -> usize {
        self.lengthscales.len()
    }

    /// ∂K/∂log ℓ_d as a dense matrix, reusing the already-assembled gram
    /// `k` = K(X, Y) of **this** kernel (noiseless — no σ² on the
    /// diagonal):
    ///
    ///   ∂k(x, y)/∂log ℓ_d = k(x, y) · (x_d − y_d)² / ℓ_d².
    ///
    /// One elementwise pass over K; entry (i, j) of the result depends
    /// only on entry (i, j) of `k`, so the determinism of the gram
    /// carries over.
    pub fn grad_gram_dim(&self, k: &Mat, x: &Mat, y: &Mat, d: usize) -> Mat {
        assert_eq!(k.rows, x.rows, "gram/x shape mismatch");
        assert_eq!(k.cols, y.rows, "gram/y shape mismatch");
        assert!(d < self.lengthscales.len(), "ARD dimension out of range");
        let inv_l2 = 1.0 / (self.lengthscales[d] * self.lengthscales[d]);
        Mat::from_fn(k.rows, k.cols, |i, j| {
            let diff = x.at(i, d) - y.at(j, d);
            k.at(i, j) * diff * diff * inv_l2
        })
    }

    /// ∂K/∂log ℓ for a single **tied** length scale driving every
    /// dimension (the chain-rule sum of [`ArdRbfKernel::grad_gram_dim`]
    /// over d): ∂k/∂log ℓ = k(x, y) · Σ_d (x_d − y_d)²/ℓ_d².
    pub fn grad_gram_tied(&self, k: &Mat, x: &Mat, y: &Mat) -> Mat {
        assert_eq!(k.rows, x.rows, "gram/x shape mismatch");
        assert_eq!(k.cols, y.rows, "gram/y shape mismatch");
        Mat::from_fn(k.rows, k.cols, |i, j| {
            let (xr, yr) = (x.row(i), y.row(j));
            let mut s = 0.0;
            for (d, &l) in self.lengthscales.iter().enumerate() {
                let diff = xr[d] - yr[d];
                s += diff * diff / (l * l);
            }
            k.at(i, j) * s
        })
    }
}

impl Kernel for ArdRbfKernel {
    #[inline]
    fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), self.lengthscales.len(), "ARD dim mismatch");
        let mut s = 0.0;
        for ((a, b), l) in x.iter().zip(y).zip(&self.lengthscales) {
            let d = (a - b) / l;
            s += d * d;
        }
        self.signal_var * (-0.5 * s).exp()
    }

    fn diag(&self, _x: &[f64]) -> f64 {
        self.signal_var
    }

    fn name(&self) -> String {
        format!("ard-rbf(l={:?}, sf2={})", self.lengthscales, self.signal_var)
    }

    fn fingerprint(&self) -> Vec<u64> {
        let mut fp = Vec::with_capacity(2 + self.lengthscales.len());
        fp.push(2);
        fp.push(self.signal_var.to_bits());
        fp.extend(self.lengthscales.iter().map(|l| l.to_bits()));
        fp
    }

    fn boxed_clone(&self) -> Box<dyn Kernel> {
        Box::new(self.clone())
    }
}

/// Laplace (exponential) kernel: exp(−‖x−x'‖ / ℓ). Heavier spectral tail
/// than RBF — a stress test for low-rank methods.
#[derive(Clone, Debug)]
pub struct LaplaceKernel {
    pub lengthscale: f64,
    pub signal_var: f64,
}

impl LaplaceKernel {
    pub fn new(lengthscale: f64) -> LaplaceKernel {
        LaplaceKernel { lengthscale, signal_var: 1.0 }
    }
}

impl Kernel for LaplaceKernel {
    #[inline]
    fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        self.signal_var * (-sqdist(x, y).sqrt() / self.lengthscale).exp()
    }

    fn diag(&self, _x: &[f64]) -> f64 {
        self.signal_var
    }

    fn name(&self) -> String {
        format!("laplace(l={})", self.lengthscale)
    }

    fn boxed_clone(&self) -> Box<dyn Kernel> {
        Box::new(self.clone())
    }
}

/// Matérn 3/2 kernel.
#[derive(Clone, Debug)]
pub struct Matern32Kernel {
    pub lengthscale: f64,
    pub signal_var: f64,
}

impl Matern32Kernel {
    pub fn new(lengthscale: f64) -> Matern32Kernel {
        Matern32Kernel { lengthscale, signal_var: 1.0 }
    }
}

impl Kernel for Matern32Kernel {
    #[inline]
    fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        let r = sqdist(x, y).sqrt() / self.lengthscale;
        let a = 3.0f64.sqrt() * r;
        self.signal_var * (1.0 + a) * (-a).exp()
    }

    fn diag(&self, _x: &[f64]) -> f64 {
        self.signal_var
    }

    fn name(&self) -> String {
        format!("matern32(l={})", self.lengthscale)
    }

    fn boxed_clone(&self) -> Box<dyn Kernel> {
        Box::new(self.clone())
    }
}

/// Matérn 5/2 kernel.
#[derive(Clone, Debug)]
pub struct Matern52Kernel {
    pub lengthscale: f64,
    pub signal_var: f64,
}

impl Matern52Kernel {
    pub fn new(lengthscale: f64) -> Matern52Kernel {
        Matern52Kernel { lengthscale, signal_var: 1.0 }
    }
}

impl Kernel for Matern52Kernel {
    #[inline]
    fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        let r = sqdist(x, y).sqrt() / self.lengthscale;
        let a = 5.0f64.sqrt() * r;
        self.signal_var * (1.0 + a + a * a / 3.0) * (-a).exp()
    }

    fn diag(&self, _x: &[f64]) -> f64 {
        self.signal_var
    }

    fn name(&self) -> String {
        format!("matern52(l={})", self.lengthscale)
    }

    fn boxed_clone(&self) -> Box<dyn Kernel> {
        Box::new(self.clone())
    }
}

/// Linear kernel ⟨x, y⟩ + c.
#[derive(Clone, Debug)]
pub struct LinearKernel {
    pub bias: f64,
}

impl Kernel for LinearKernel {
    #[inline]
    fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        crate::la::blas::dot(x, y) + self.bias
    }

    fn name(&self) -> String {
        format!("linear(c={})", self.bias)
    }

    fn boxed_clone(&self) -> Box<dyn Kernel> {
        Box::new(self.clone())
    }
}

/// Polynomial kernel (⟨x, y⟩ + c)^d.
#[derive(Clone, Debug)]
pub struct PolyKernel {
    pub bias: f64,
    pub degree: u32,
}

impl Kernel for PolyKernel {
    #[inline]
    fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        (crate::la::blas::dot(x, y) + self.bias).powi(self.degree as i32)
    }

    fn name(&self) -> String {
        format!("poly(c={}, d={})", self.bias, self.degree)
    }

    fn boxed_clone(&self) -> Box<dyn Kernel> {
        Box::new(self.clone())
    }
}

/// Construct a kernel by name (config system).
pub fn kernel_by_name(name: &str, lengthscale: f64) -> Box<dyn Kernel> {
    match name {
        "rbf" | "gaussian" => Box::new(RbfKernel::new(lengthscale)),
        "laplace" => Box::new(LaplaceKernel::new(lengthscale)),
        "matern32" => Box::new(Matern32Kernel::new(lengthscale)),
        "matern52" => Box::new(Matern52Kernel::new(lengthscale)),
        "linear" => Box::new(LinearKernel { bias: 1.0 }),
        _ => Box::new(RbfKernel::new(lengthscale)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::la::evd::SymEig;
    use crate::util::Rng;

    fn randx(n: usize, d: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(n, d, |_, _| rng.normal())
    }

    #[test]
    fn rbf_basic_properties() {
        let k = RbfKernel::new(1.0);
        let x = [0.0, 0.0];
        let y = [1.0, 0.0];
        assert_eq!(k.eval(&x, &x), 1.0);
        assert!((k.eval(&x, &y) - (-0.5f64).exp()).abs() < 1e-15);
        // symmetry
        assert_eq!(k.eval(&x, &y), k.eval(&y, &x));
    }

    #[test]
    fn rbf_lengthscale_monotone() {
        let x = [0.0];
        let y = [2.0];
        let k_short = RbfKernel::new(0.2).eval(&x, &y);
        let k_long = RbfKernel::new(5.0).eval(&x, &y);
        assert!(k_short < k_long);
    }

    #[test]
    fn gram_sym_matches_gram() {
        let k = RbfKernel::new(0.7);
        let x = randx(15, 3, 1);
        let a = k.gram_sym(&x);
        let b = k.gram(&x, &x);
        assert!(a.sub(&b).max_abs() < 1e-15);
        assert_eq!(a.asymmetry(), 0.0);
    }

    #[test]
    fn gram_is_psd_for_all_kernels() {
        let x = randx(20, 4, 2);
        let kernels: Vec<Box<dyn Kernel>> = vec![
            Box::new(RbfKernel::new(1.0)),
            Box::new(LaplaceKernel::new(1.0)),
            Box::new(Matern32Kernel::new(1.0)),
            Box::new(Matern52Kernel::new(1.0)),
            Box::new(LinearKernel { bias: 1.0 }),
        ];
        for k in &kernels {
            let g = k.gram_sym(&x);
            let e = SymEig::new(&g);
            assert!(e.values[0] > -1e-8, "{} min eig {}", k.name(), e.values[0]);
        }
    }

    #[test]
    fn matern_at_zero_distance() {
        let x = [1.0, 2.0];
        assert!((Matern32Kernel::new(0.5).eval(&x, &x) - 1.0).abs() < 1e-15);
        assert!((Matern52Kernel::new(0.5).eval(&x, &x) - 1.0).abs() < 1e-15);
    }

    #[test]
    fn short_lengthscale_has_heavier_spectrum() {
        // The paper's central observation: as ℓ shrinks, the number of
        // significant eigenvalues grows.
        let x = randx(40, 2, 3);
        let count_signif = |l: f64| {
            let g = RbfKernel::new(l).gram_sym(&x);
            let e = SymEig::new(&g);
            let top = e.values.last().unwrap();
            e.values.iter().filter(|&&v| v > 1e-3 * top).count()
        };
        assert!(count_signif(0.1) > count_signif(10.0));
    }

    #[test]
    fn cross_matches_gram_row() {
        let k = RbfKernel::new(1.3);
        let x = randx(6, 3, 4);
        let q = [0.1, -0.2, 0.3];
        let c = k.cross(&q, &x);
        for i in 0..6 {
            assert_eq!(c[i], k.eval(&q, x.row(i)));
        }
    }

    #[test]
    fn ard_matches_isotropic_rbf_when_tied() {
        let x = randx(12, 3, 7);
        let iso = RbfKernel::new(1.3);
        let ard = ArdRbfKernel::isotropic(1.3, 3);
        let a = iso.gram_sym(&x);
        let b = ard.gram_sym(&x);
        assert!(a.sub(&b).max_abs() < 1e-15);
        assert_eq!(ard.dim(), 3);
    }

    #[test]
    fn ard_anisotropy_stretches_one_axis() {
        // A huge ℓ_1 makes dimension 1 irrelevant: k must ignore it.
        let k = ArdRbfKernel::new(vec![1.0, 1e6]);
        let a = [0.0, 0.0];
        let b = [0.0, 5.0];
        let c = [5.0, 0.0];
        assert!((k.eval(&a, &b) - 1.0).abs() < 1e-9, "irrelevant dim moved k");
        assert!(k.eval(&a, &c) < 1e-5, "relevant dim ignored");
    }

    #[test]
    fn ard_grad_gram_matches_finite_differences() {
        let x = randx(9, 2, 11);
        let y = randx(7, 2, 12);
        let ells = vec![0.8, 1.7];
        let kern = ArdRbfKernel::new(ells.clone());
        let k = kern.gram(&x, &y);
        let h = 1e-5;
        for d in 0..2 {
            let g = kern.grad_gram_dim(&k, &x, &y, d);
            let mut up = ells.clone();
            let mut dn = ells.clone();
            up[d] *= h.exp();
            dn[d] *= (-h).exp();
            let kp = ArdRbfKernel::new(up).gram(&x, &y);
            let km = ArdRbfKernel::new(dn).gram(&x, &y);
            for i in 0..9 {
                for j in 0..7 {
                    let fd = (kp.at(i, j) - km.at(i, j)) / (2.0 * h);
                    assert!((g.at(i, j) - fd).abs() < 1e-8, "d={d} ({i},{j})");
                }
            }
        }
        // Tied gradient is the sum of the per-dimension gradients.
        let tied = kern.grad_gram_tied(&k, &x, &y);
        let sum = kern.grad_gram_dim(&k, &x, &y, 0).add(&kern.grad_gram_dim(&k, &x, &y, 1));
        assert!(tied.sub(&sum).max_abs() < 1e-12);
    }

    #[test]
    fn by_name_lookup() {
        assert!(kernel_by_name("laplace", 1.0).name().starts_with("laplace"));
        assert!(kernel_by_name("rbf", 2.0).name().starts_with("rbf"));
    }

    /// Fingerprints separate kernels whose grams can differ — across
    /// hyperparameters, across families, and (for ARD) across per-dim
    /// length-scale vectors — and are stable for equal kernels.
    #[test]
    fn fingerprints_separate_kernels() {
        let a = RbfKernel::new(1.0);
        let b = RbfKernel::new(1.0);
        assert_eq!(a.fingerprint(), b.fingerprint());
        assert_ne!(a.fingerprint(), RbfKernel::new(1.5).fingerprint());
        assert_ne!(
            a.fingerprint(),
            RbfKernel::with_signal(1.0, 2.0).fingerprint()
        );
        // family tags keep an RBF and a 1-D ARD at the same ℓ apart
        assert_ne!(a.fingerprint(), ArdRbfKernel::isotropic(1.0, 1).fingerprint());
        assert_ne!(
            ArdRbfKernel::new(vec![1.0, 2.0]).fingerprint(),
            ArdRbfKernel::new(vec![2.0, 1.0]).fingerprint()
        );
        // default (name-hash) path: distinct kernels, distinct words
        assert_ne!(
            LaplaceKernel::new(1.0).fingerprint(),
            Matern32Kernel::new(1.0).fingerprint()
        );
        assert_ne!(
            LaplaceKernel::new(1.0).fingerprint(),
            LaplaceKernel::new(2.0).fingerprint()
        );
    }
}
