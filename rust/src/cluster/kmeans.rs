//! Lloyd's k-means with k-means++ seeding.

use super::Clustering;
use crate::la::dense::Mat;
use crate::util::Rng;

/// Cluster the rows of `x` into `k` clusters. Empty clusters are re-seeded
/// from the farthest point, so the result always has exactly
/// min(k, n distinct rows) non-empty clusters.
pub fn kmeans(x: &Mat, k: usize, max_iters: usize, rng: &mut Rng) -> Clustering {
    let n = x.rows;
    let d = x.cols;
    let k = k.clamp(1, n);

    // --- k-means++ seeding ---------------------------------------------
    let mut centers = Mat::zeros(k, d);
    let first = rng.below(n);
    centers.row_mut(0).copy_from_slice(x.row(first));
    let mut dist2 = vec![f64::INFINITY; n];
    for c in 1..k {
        // update distances to nearest chosen center
        for i in 0..n {
            let d2 = sqdist(x.row(i), centers.row(c - 1));
            if d2 < dist2[i] {
                dist2[i] = d2;
            }
        }
        let total: f64 = dist2.iter().sum();
        let pick = if total <= 0.0 {
            rng.below(n)
        } else {
            let mut r = rng.uniform() * total;
            let mut idx = n - 1;
            for (i, &w) in dist2.iter().enumerate() {
                r -= w;
                if r <= 0.0 {
                    idx = i;
                    break;
                }
            }
            idx
        };
        centers.row_mut(c).copy_from_slice(x.row(pick));
    }

    // --- Lloyd iterations ------------------------------------------------
    let mut assign = vec![0usize; n];
    for _ in 0..max_iters {
        let mut changed = false;
        for i in 0..n {
            let mut best = 0;
            let mut bestd = f64::INFINITY;
            for c in 0..k {
                let d2 = sqdist(x.row(i), centers.row(c));
                if d2 < bestd {
                    bestd = d2;
                    best = c;
                }
            }
            if assign[i] != best {
                assign[i] = best;
                changed = true;
            }
        }
        // recompute centers
        let mut counts = vec![0usize; k];
        let mut sums = Mat::zeros(k, d);
        for i in 0..n {
            counts[assign[i]] += 1;
            let row = x.row(i);
            let srow = sums.row_mut(assign[i]);
            for j in 0..d {
                srow[j] += row[j];
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                // Re-seed empty cluster at the point farthest from its center.
                let far = (0..n)
                    .max_by(|&a, &b| {
                        sqdist(x.row(a), centers.row(assign[a]))
                            .partial_cmp(&sqdist(x.row(b), centers.row(assign[b])))
                            .unwrap()
                    })
                    .unwrap();
                centers.row_mut(c).copy_from_slice(x.row(far));
                assign[far] = c;
                changed = true;
            } else {
                let inv = 1.0 / counts[c] as f64;
                let srow = sums.row(c).to_vec();
                let crow = centers.row_mut(c);
                for j in 0..d {
                    crow[j] = srow[j] * inv;
                }
            }
        }
        if !changed {
            break;
        }
    }

    let mut clusters = vec![Vec::new(); k];
    for (i, &c) in assign.iter().enumerate() {
        clusters[c].push(i);
    }
    Clustering { clusters }.normalize()
}

#[inline]
fn sqdist(a: &[f64], b: &[f64]) -> f64 {
    let mut s = 0.0;
    for (x, y) in a.iter().zip(b) {
        let d = x - y;
        s += d * d;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn separates_obvious_blobs() {
        let mut rng = Rng::new(1);
        // two blobs at (0,0) and (10,10)
        let x = Mat::from_fn(40, 2, |i, _j| {
            let base = if i < 20 { 0.0 } else { 10.0 };
            base + 0.1 * rng.normal()
        });
        let c = kmeans(&x, 2, 50, &mut Rng::new(7));
        assert!(c.is_partition_of(40));
        assert_eq!(c.n_clusters(), 2);
        // each cluster should be pure
        for cl in &c.clusters {
            let lows = cl.iter().filter(|&&i| i < 20).count();
            assert!(lows == 0 || lows == cl.len(), "mixed cluster {cl:?}");
        }
    }

    #[test]
    fn k_clamped_to_n() {
        let x = Mat::from_fn(3, 1, |i, _| i as f64);
        let c = kmeans(&x, 10, 10, &mut Rng::new(2));
        assert!(c.is_partition_of(3));
        assert!(c.n_clusters() <= 3);
    }

    #[test]
    fn single_cluster() {
        let x = Mat::from_fn(10, 2, |i, j| (i + j) as f64);
        let c = kmeans(&x, 1, 10, &mut Rng::new(3));
        assert_eq!(c.n_clusters(), 1);
        assert_eq!(c.clusters[0].len(), 10);
    }

    #[test]
    fn deterministic_with_seed() {
        let mut r1 = Rng::new(11);
        let mut r2 = Rng::new(11);
        let x = Mat::from_fn(30, 3, |i, j| ((i * 7 + j * 13) % 10) as f64);
        let a = kmeans(&x, 4, 25, &mut r1);
        let b = kmeans(&x, 4, 25, &mut r2);
        assert_eq!(a.clusters, b.clusters);
    }
}
