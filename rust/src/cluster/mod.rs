//! Row/column clustering for MKA's stage blocking (Algorithm 1, step 1).
//!
//! The paper calls for "some appropriate fast clustering method, e.g.,
//! METIS or GRACLUS" and notes MKA re-clusters before every stage — after
//! stage 1 the objects being clustered are no longer data points but the
//! core rows of the compressed matrix K_ℓ, so stage ≥ 2 clustering works on
//! the rows of K_ℓ itself (affinity clustering).
//!
//! Three methods, all from scratch:
//! * [`kmeans`] — k-means++ on feature vectors (stage 1, when X is known);
//! * [`bisect`] — balanced random-projection bisection (stage 1 fallback,
//!   high-dim robust, always yields near-equal blocks);
//! * [`affinity`] — greedy seeded clustering on |K| row similarity
//!   (stages ≥ 2 and the "K only" path).

pub mod affinity;
pub mod bisect;
pub mod kmeans;

use crate::la::dense::Mat;
use crate::util::Rng;

/// Which clustering algorithm a stage uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ClusterMethod {
    KMeans,
    Bisect,
    Affinity,
}

impl ClusterMethod {
    pub fn parse(s: &str) -> ClusterMethod {
        match s {
            "kmeans" => ClusterMethod::KMeans,
            "bisect" => ClusterMethod::Bisect,
            _ => ClusterMethod::Affinity,
        }
    }
}

/// A clustering: `clusters[c]` is the sorted list of member indices.
#[derive(Clone, Debug)]
pub struct Clustering {
    pub clusters: Vec<Vec<usize>>,
}

impl Clustering {
    /// Validate and normalize: drop empties, sort members.
    pub fn normalize(mut self) -> Clustering {
        self.clusters.retain(|c| !c.is_empty());
        for c in &mut self.clusters {
            c.sort_unstable();
        }
        self
    }

    pub fn n_items(&self) -> usize {
        self.clusters.iter().map(|c| c.len()).sum()
    }

    pub fn n_clusters(&self) -> usize {
        self.clusters.len()
    }

    pub fn max_cluster(&self) -> usize {
        self.clusters.iter().map(|c| c.len()).max().unwrap_or(0)
    }

    /// The permutation that maps "blocked order" position → original index
    /// (cluster 1's members first, then cluster 2's, …) — the C_ℓ matrix of
    /// the paper, stored implicitly.
    pub fn permutation(&self) -> Vec<usize> {
        let mut p = Vec::with_capacity(self.n_items());
        for c in &self.clusters {
            p.extend_from_slice(c);
        }
        p
    }

    /// Check the clustering partitions 0..n exactly.
    pub fn is_partition_of(&self, n: usize) -> bool {
        let mut seen = vec![false; n];
        let mut count = 0;
        for c in &self.clusters {
            for &i in c {
                if i >= n || seen[i] {
                    return false;
                }
                seen[i] = true;
                count += 1;
            }
        }
        count == n
    }
}

/// Cluster `n` items into blocks of roughly `target_block` elements using
/// the chosen method. `x` (points) is used by KMeans/Bisect; `k_abs`
/// (|K| row affinity) by Affinity. Falls back to Bisect when the preferred
/// input is unavailable.
pub fn cluster_rows(
    method: ClusterMethod,
    x: Option<&Mat>,
    k: Option<&Mat>,
    n: usize,
    target_block: usize,
    rng: &mut Rng,
) -> Clustering {
    let n_clusters = n.div_ceil(target_block).max(1);
    match method {
        ClusterMethod::KMeans if x.is_some() => {
            kmeans::kmeans(x.unwrap(), n_clusters, 20, rng)
        }
        ClusterMethod::Bisect if x.is_some() => {
            bisect::bisect(x.unwrap(), target_block, rng)
        }
        ClusterMethod::Affinity if k.is_some() => {
            affinity::affinity_cluster(k.unwrap(), n_clusters, rng)
        }
        // Fallbacks: affinity on K if available, else contiguous chunks.
        _ => {
            if let Some(km) = k {
                affinity::affinity_cluster(km, n_clusters, rng)
            } else if let Some(xm) = x {
                bisect::bisect(xm, target_block, rng)
            } else {
                contiguous(n, target_block)
            }
        }
    }
}

/// Trivial contiguous chunking (used when neither X nor K is available and
/// in tests as a worst-case clustering).
pub fn contiguous(n: usize, block: usize) -> Clustering {
    let mut clusters = Vec::new();
    let mut i = 0;
    while i < n {
        clusters.push((i..(i + block).min(n)).collect());
        i += block;
    }
    Clustering { clusters }.normalize()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contiguous_partitions() {
        let c = contiguous(10, 3);
        assert!(c.is_partition_of(10));
        assert_eq!(c.n_clusters(), 4);
        assert_eq!(c.max_cluster(), 3);
        assert_eq!(c.permutation(), (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn normalize_drops_empty_and_sorts() {
        let c = Clustering { clusters: vec![vec![3, 1], vec![], vec![2, 0]] }.normalize();
        assert_eq!(c.n_clusters(), 2);
        assert_eq!(c.clusters[0], vec![1, 3]);
        assert!(c.is_partition_of(4));
    }

    #[test]
    fn partition_check_catches_duplicates() {
        let c = Clustering { clusters: vec![vec![0, 1], vec![1, 2]] };
        assert!(!c.is_partition_of(3));
        let c2 = Clustering { clusters: vec![vec![0], vec![2]] };
        assert!(!c2.is_partition_of(3)); // missing 1
    }

    #[test]
    fn cluster_rows_dispatch_and_fallback() {
        let mut rng = Rng::new(1);
        let x = Mat::from_fn(20, 2, |i, _| i as f64);
        let c = cluster_rows(ClusterMethod::KMeans, Some(&x), None, 20, 5, &mut rng);
        assert!(c.is_partition_of(20));
        // Affinity requested but no K: falls back to bisect on x.
        let c2 = cluster_rows(ClusterMethod::Affinity, Some(&x), None, 20, 5, &mut rng);
        assert!(c2.is_partition_of(20));
        // Nothing available: contiguous.
        let c3 = cluster_rows(ClusterMethod::Affinity, None, None, 12, 4, &mut rng);
        assert!(c3.is_partition_of(12));
    }
}
