//! Balanced recursive bisection by random projections.
//!
//! kd-trees are "known to be problematic in high dimensions" (paper §2.2);
//! random-projection splits are the standard robust alternative: project
//! onto a random direction, split at the median. Guarantees near-equal
//! block sizes, which keeps m_max (and hence Proposition 2/4 costs) tight.

use super::Clustering;
use crate::la::dense::Mat;
use crate::util::Rng;

/// Recursively bisect the rows of `x` until blocks are ≤ `max_block`.
pub fn bisect(x: &Mat, max_block: usize, rng: &mut Rng) -> Clustering {
    let idx: Vec<usize> = (0..x.rows).collect();
    let mut clusters = Vec::new();
    split(x, idx, max_block.max(1), rng, &mut clusters);
    Clustering { clusters }.normalize()
}

fn split(x: &Mat, idx: Vec<usize>, max_block: usize, rng: &mut Rng, out: &mut Vec<Vec<usize>>) {
    if idx.len() <= max_block {
        out.push(idx);
        return;
    }
    let d = x.cols;
    // Random unit direction.
    let mut dir: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    let norm = dir.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-12);
    for v in &mut dir {
        *v /= norm;
    }
    // Project and split at the median (ties broken by index, keeps balance).
    let mut proj: Vec<(f64, usize)> = idx
        .iter()
        .map(|&i| {
            let mut s = 0.0;
            for (a, b) in x.row(i).iter().zip(&dir) {
                s += a * b;
            }
            (s, i)
        })
        .collect();
    proj.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mid = proj.len() / 2;
    let left: Vec<usize> = proj[..mid].iter().map(|&(_, i)| i).collect();
    let right: Vec<usize> = proj[mid..].iter().map(|&(_, i)| i).collect();
    split(x, left, max_block, rng, out);
    split(x, right, max_block, rng, out);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blocks_bounded_and_balanced() {
        let mut rng = Rng::new(1);
        let x = Mat::from_fn(100, 5, |_, _| rng.normal());
        let c = bisect(&x, 16, &mut Rng::new(2));
        assert!(c.is_partition_of(100));
        assert!(c.max_cluster() <= 16);
        // Median splits keep blocks within 2x of each other.
        let min = c.clusters.iter().map(|cl| cl.len()).min().unwrap();
        assert!(c.max_cluster() <= 2 * min + 1, "max={} min={min}", c.max_cluster());
    }

    #[test]
    fn small_input_single_block() {
        let x = Mat::from_fn(5, 2, |i, j| (i * 2 + j) as f64);
        let c = bisect(&x, 8, &mut Rng::new(3));
        assert_eq!(c.n_clusters(), 1);
    }

    #[test]
    fn splits_separated_data_cleanly() {
        // 1D data: two well-separated groups; the first split should be pure.
        let x = Mat::from_fn(20, 1, |i, _| if i < 10 { 0.0 + i as f64 * 0.01 } else { 100.0 + i as f64 * 0.01 });
        let c = bisect(&x, 10, &mut Rng::new(4));
        assert_eq!(c.n_clusters(), 2);
        for cl in &c.clusters {
            let lows = cl.iter().filter(|&&i| i < 10).count();
            assert!(lows == 0 || lows == cl.len());
        }
    }

    #[test]
    fn handles_duplicate_points() {
        let x = Mat::filled(32, 3, 1.0);
        let c = bisect(&x, 8, &mut Rng::new(5));
        assert!(c.is_partition_of(32));
        assert!(c.max_cluster() <= 8);
    }
}
