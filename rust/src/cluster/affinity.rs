//! Affinity clustering on the rows of the (absolute) kernel matrix itself.
//!
//! Beyond stage 1, MKA no longer has data points — it clusters the rows of
//! the compressed matrix K_ℓ ("it is not even individual datapoints that
//! MKA clusters, but subspaces", paper §3 remark 2). We treat |K_ℓ| as an
//! affinity and run a seeded balanced assignment: pick k seeds far apart in
//! affinity space (k-means++-style on affinity), then greedily assign each
//! row to its highest-affinity seed subject to a balance cap.

use super::Clustering;
use crate::la::dense::Mat;
use crate::util::Rng;

/// Cluster the rows of symmetric `k_mat` into `n_clusters` groups by row
/// affinity with balance cap ceil(1.5 · n / n_clusters).
pub fn affinity_cluster(k_mat: &Mat, n_clusters: usize, rng: &mut Rng) -> Clustering {
    let n = k_mat.rows;
    let k = n_clusters.clamp(1, n);
    if k == 1 {
        return Clustering { clusters: vec![(0..n).collect()] };
    }
    let cap = (3 * n).div_ceil(2 * k).max(1);

    // --- seed selection: first uniformly, then min-affinity-to-seeds ----
    let mut seeds = Vec::with_capacity(k);
    seeds.push(rng.below(n));
    while seeds.len() < k {
        // Pick the row with minimal max-affinity to current seeds
        // (i.e. the least connected — analogue of farthest-point).
        let mut best_row = None;
        let mut best_val = f64::INFINITY;
        for i in 0..n {
            if seeds.contains(&i) {
                continue;
            }
            let max_aff = seeds
                .iter()
                .map(|&s| k_mat.at(i, s).abs())
                .fold(f64::NEG_INFINITY, f64::max);
            if max_aff < best_val {
                best_val = max_aff;
                best_row = Some(i);
            }
        }
        match best_row {
            Some(r) => seeds.push(r),
            None => break,
        }
    }

    // --- greedy balanced assignment --------------------------------------
    // Order rows by their best affinity (strongest first) so that strongly
    // attached rows get their preferred cluster before caps bind.
    let mut order: Vec<(f64, usize)> = (0..n)
        .map(|i| {
            let best = seeds.iter().map(|&s| k_mat.at(i, s).abs()).fold(0.0, f64::max);
            (best, i)
        })
        .collect();
    order.sort_by(|a, b| b.partial_cmp(a).unwrap());

    let mut clusters: Vec<Vec<usize>> = vec![Vec::new(); seeds.len()];
    for (ci, &s) in seeds.iter().enumerate() {
        clusters[ci].push(s);
    }
    let assigned: std::collections::HashSet<usize> = seeds.iter().copied().collect();
    for &(_, i) in &order {
        if assigned.contains(&i) {
            continue;
        }
        // rank clusters by affinity to seed, assign to best with room
        let mut ranked: Vec<(f64, usize)> = seeds
            .iter()
            .enumerate()
            .map(|(ci, &s)| (k_mat.at(i, s).abs(), ci))
            .collect();
        ranked.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let mut placed = false;
        for &(_, ci) in &ranked {
            if clusters[ci].len() < cap {
                clusters[ci].push(i);
                placed = true;
                break;
            }
        }
        if !placed {
            // all full (can happen with rounding): put in smallest
            let ci = (0..clusters.len()).min_by_key(|&c| clusters[c].len()).unwrap();
            clusters[ci].push(i);
        }
    }
    Clustering { clusters }.normalize()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{Kernel, RbfKernel};

    #[test]
    fn recovers_block_structure() {
        // Two groups of points far apart → K is block diagonal → affinity
        // clustering should recover the blocks.
        let mut rng = Rng::new(1);
        let x = Mat::from_fn(30, 2, |i, _| if i < 15 { rng.normal() } else { 50.0 + rng.normal() });
        let k = RbfKernel::new(1.0).gram_sym(&x);
        let c = affinity_cluster(&k, 2, &mut Rng::new(2));
        assert!(c.is_partition_of(30));
        assert_eq!(c.n_clusters(), 2);
        for cl in &c.clusters {
            let lows = cl.iter().filter(|&&i| i < 15).count();
            assert!(lows == 0 || lows == cl.len(), "mixed: {cl:?}");
        }
    }

    #[test]
    fn balance_cap_respected() {
        let k = Mat::filled(40, 40, 1.0); // featureless affinity
        let c = affinity_cluster(&k, 4, &mut Rng::new(3));
        assert!(c.is_partition_of(40));
        assert!(c.max_cluster() <= 15, "max={}", c.max_cluster()); // cap = ceil(1.5*40/4) = 15
    }

    #[test]
    fn one_cluster_case() {
        let k = Mat::eye(5);
        let c = affinity_cluster(&k, 1, &mut Rng::new(4));
        assert_eq!(c.n_clusters(), 1);
        assert!(c.is_partition_of(5));
    }

    #[test]
    fn k_larger_than_n() {
        let k = Mat::eye(3);
        let c = affinity_cluster(&k, 10, &mut Rng::new(5));
        assert!(c.is_partition_of(3));
    }
}
