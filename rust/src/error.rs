//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls — the offline build carries no
//! external crates (`thiserror` / `anyhow` are unavailable), and the
//! variants are few enough that a derive buys nothing.

use std::fmt;

/// All fallible operations in the crate return this error.
#[derive(Debug)]
pub enum Error {
    Linalg(String),
    Config(String),
    Data(String),
    Runtime(String),
    Coordinator(String),
    Protocol(String),
    /// Transient overload (e.g. the predict queue is at capacity): the
    /// request was rejected, not failed — clients should back off and
    /// retry. The router marks these responses with `"busy": true`.
    Busy(String),
    Io(std::io::Error),
    Json(crate::util::json::JsonError),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Linalg(m) => write!(f, "linear algebra error: {m}"),
            Error::Config(m) => write!(f, "configuration error: {m}"),
            Error::Data(m) => write!(f, "data error: {m}"),
            Error::Runtime(m) => write!(f, "runtime (PJRT/XLA) error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
            Error::Protocol(m) => write!(f, "protocol error: {m}"),
            Error::Busy(m) => write!(f, "service busy: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Json(e) => write!(f, "json error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            Error::Json(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<crate::util::json::JsonError> for Error {
    fn from(e: crate::util::json::JsonError) -> Self {
        Error::Json(e)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_variants() {
        let e = Error::Linalg("bad pivot".into());
        assert!(format!("{e}").contains("bad pivot"));
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "nope").into();
        assert!(matches!(e, Error::Io(_)));
    }

    #[test]
    fn source_chains_io() {
        use std::error::Error as _;
        let e: Error = std::io::Error::new(std::io::ErrorKind::Other, "disk").into();
        assert!(e.source().is_some());
        assert!(Error::Config("x".into()).source().is_none());
    }
}
