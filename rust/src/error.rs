//! Crate-wide error type.

use thiserror::Error;

/// All fallible operations in the crate return this error.
#[derive(Error, Debug)]
pub enum Error {
    #[error("linear algebra error: {0}")]
    Linalg(String),

    #[error("configuration error: {0}")]
    Config(String),

    #[error("data error: {0}")]
    Data(String),

    #[error("runtime (PJRT/XLA) error: {0}")]
    Runtime(String),

    #[error("coordinator error: {0}")]
    Coordinator(String),

    #[error("protocol error: {0}")]
    Protocol(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("json error: {0}")]
    Json(#[from] crate::util::json::JsonError),
}

pub type Result<T> = std::result::Result<T, Error>;

impl From<anyhow::Error> for Error {
    fn from(e: anyhow::Error) -> Self {
        Error::Runtime(format!("{e:#}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_variants() {
        let e = Error::Linalg("bad pivot".into());
        assert!(format!("{e}").contains("bad pivot"));
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "nope").into();
        assert!(matches!(e, Error::Io(_)));
    }
}
