//! General-purpose substrates built from scratch for the offline environment:
//! RNG (no `rand`), JSON (no `serde`), CLI parsing (no `clap`), timing.

pub mod args;
pub mod json;
pub mod rng;
pub mod timer;

pub use args::Args;
pub use json::Json;
pub use rng::Rng;
pub use timer::{fmt_duration, timed, Timer};
