//! A small command-line argument parser (no `clap` in the offline build).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments and
//! subcommands. Used by the `mka-gp` binary, the examples and the benches.

use std::collections::BTreeMap;

/// Parsed command line: subcommand (optional), options, flags, positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env(with_subcommand: bool) -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Self::parse(&argv, with_subcommand)
    }

    /// Parse from an explicit list.
    ///
    /// If `with_subcommand` is true, the first non-option token is treated as
    /// the subcommand name.
    pub fn parse<S: AsRef<str>>(argv: &[S], with_subcommand: bool) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = argv[i].as_ref();
            if let Some(body) = a.strip_prefix("--") {
                if let Some(eq) = body.find('=') {
                    out.opts.insert(body[..eq].to_string(), body[eq + 1..].to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].as_ref().starts_with("--") {
                    out.opts.insert(body.to_string(), argv[i + 1].as_ref().to_string());
                    i += 1;
                } else {
                    out.flags.push(body.to_string());
                }
            } else if with_subcommand && out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a.to_string());
            } else {
                out.positional.push(a.to_string());
            }
            i += 1;
        }
        out
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Comma-separated list of usizes, e.g. `--sizes 512,1024,2048`.
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            Some(s) => s
                .split(',')
                .filter(|t| !t.is_empty())
                .filter_map(|t| t.trim().parse().ok())
                .collect(),
            None => default.to_vec(),
        }
    }

    /// All `--key value` options (for config layering).
    pub fn options(&self) -> &BTreeMap<String, String> {
        &self.opts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_mixed() {
        let a = Args::parse(
            &["serve", "--port", "7070", "--verbose", "--name=gp", "file.csv"],
            true,
        );
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.get("port"), Some("7070"));
        assert_eq!(a.get("name"), Some("gp"));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["file.csv"]);
    }

    #[test]
    fn typed_getters() {
        let a = Args::parse(&["--n", "100", "--lr", "0.5", "--sizes", "1,2,3"], false);
        assert_eq!(a.get_usize("n", 0), 100);
        assert_eq!(a.get_f64("lr", 0.0), 0.5);
        assert_eq!(a.get_usize("missing", 7), 7);
        assert_eq!(a.get_usize_list("sizes", &[]), vec![1, 2, 3]);
        assert_eq!(a.get_usize_list("nope", &[4]), vec![4]);
    }

    #[test]
    fn flag_at_end() {
        let a = Args::parse(&["--x", "1", "--dry-run"], false);
        assert!(a.has_flag("dry-run"));
        assert_eq!(a.get("x"), Some("1"));
    }

    #[test]
    fn no_subcommand_mode() {
        let a = Args::parse(&["pos1", "--k", "v"], false);
        assert_eq!(a.subcommand, None);
        assert_eq!(a.positional, vec!["pos1"]);
    }
}
