//! Lightweight timing helpers shared by the bench harness and the
//! coordinator's metrics.

use std::time::{Duration, Instant};

/// A simple stopwatch.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed_secs())
}

/// Human-friendly duration formatting for logs: "1.23s", "45.6ms", "789us".
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3}s")
    } else if secs >= 1e-3 {
        format!("{:.3}ms", secs * 1e3)
    } else {
        format!("{:.1}us", secs * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(t.elapsed_secs() >= 0.002);
    }

    #[test]
    fn timed_returns_value() {
        let (v, s) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }

    #[test]
    fn formats() {
        assert_eq!(fmt_duration(2.5), "2.500s");
        assert_eq!(fmt_duration(0.0123), "12.300ms");
        assert_eq!(fmt_duration(12.3e-6), "12.3us");
    }
}
