//! Minimal JSON parser/serializer.
//!
//! No `serde` is available in the offline build, so the coordinator wire
//! protocol, the config system and the artifact manifest use this small,
//! well-tested implementation. Supports the full JSON grammar (RFC 8259)
//! minus `\u` surrogate-pair edge cases beyond the BMP combination rules,
//! which we do handle.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept sorted (BTreeMap) so serialization is
/// deterministic — useful for golden tests and reproducible manifests.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ------------------------------------------------------------------
    // Constructors / accessors
    // ------------------------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    /// Builder-style insert.
    pub fn with(mut self, key: &str, val: Json) -> Json {
        self.set(key, val);
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(x) if x.fract() == 0.0 => Some(*x as i64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Convenience: `get` + `as_str`.
    pub fn str_field(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(|v| v.as_str())
    }

    pub fn num_field(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(|v| v.as_f64())
    }

    pub fn usize_field(&self, key: &str) -> Option<usize> {
        self.get(key).and_then(|v| v.as_usize())
    }

    /// Array of f64 (fails if any element is not a number).
    pub fn f64_array(&self) -> Option<Vec<f64>> {
        match self {
            Json::Arr(v) => v.iter().map(|x| x.as_f64()).collect(),
            _ => None,
        }
    }

    pub fn from_f64_slice(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn from_str_slice(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    // ------------------------------------------------------------------
    // Serialization
    // ------------------------------------------------------------------

    /// Compact serialization.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn dump_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => write_num(*x, out),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    x.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    write_escaped(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push('}');
            }
            _ => self.write(out),
        }
    }

    // ------------------------------------------------------------------
    // Parsing
    // ------------------------------------------------------------------

    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

fn write_num(x: f64, out: &mut String) {
    if x.is_finite() {
        if x.fract() == 0.0 && x.abs() < 9e15 {
            out.push_str(&format!("{}", x as i64));
        } else {
            // `{:?}` gives a shortest round-trip representation for f64.
            out.push_str(&format!("{x:?}"));
        }
    } else {
        // JSON has no Inf/NaN; emit null like most tolerant writers.
        out.push_str("null");
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let hi = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                if self.b[self.i..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    let cp = 0x10000
                                        + (((hi - 0xD800) as u32) << 10)
                                        + (lo - 0xDC00) as u32;
                                    char::from_u32(cp).ok_or_else(|| self.err("bad surrogate"))?
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                char::from_u32(hi as u32).ok_or_else(|| self.err("bad codepoint"))?
                            };
                            out.push(c);
                            continue; // hex4 advanced i already
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 character.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u16, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("short \\u escape"));
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let v = u16::from_str_radix(s, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.i += 4;
        Ok(v)
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1", "3.5", "1e-3", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            let v2 = Json::parse(&v.dump()).unwrap();
            assert_eq!(v, v2, "input {s}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[0].as_f64().unwrap(), 1.0);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn escapes_roundtrip() {
        let orig = Json::Str("quote\" backslash\\ newline\n tab\t unicode☃".to_string());
        let parsed = Json::parse(&orig.dump()).unwrap();
        assert_eq!(orig, parsed);
    }

    #[test]
    fn unicode_escape_and_surrogates() {
        assert_eq!(Json::parse(r#""☃""#).unwrap(), Json::Str("☃".into()));
        assert_eq!(Json::parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
    }

    #[test]
    fn rejects_garbage() {
        for s in ["", "{", "[1,", "{\"a\":}", "tru", "1.2.3", "[1] x", "\"\\u12\""] {
            assert!(Json::parse(s).is_err(), "should reject {s:?}");
        }
    }

    #[test]
    fn object_builder_and_accessors() {
        let v = Json::obj()
            .with("n", Json::Num(5.0))
            .with("s", Json::Str("x".into()))
            .with("xs", Json::from_f64_slice(&[1.0, 2.5]));
        assert_eq!(v.usize_field("n"), Some(5));
        assert_eq!(v.str_field("s"), Some("x"));
        assert_eq!(v.get("xs").unwrap().f64_array().unwrap(), vec![1.0, 2.5]);
    }

    #[test]
    fn deterministic_key_order() {
        let v = Json::parse(r#"{"b":1,"a":2}"#).unwrap();
        assert_eq!(v.dump(), r#"{"a":2,"b":1}"#);
    }

    #[test]
    fn numbers_roundtrip_precisely() {
        let xs = [1.0, -0.5, 1e300, 1e-300, 123456789.123456789, f64::MIN_POSITIVE];
        for x in xs {
            let v = Json::Num(x);
            let p = Json::parse(&v.dump()).unwrap();
            assert_eq!(p.as_f64().unwrap(), x);
        }
    }

    #[test]
    fn nonfinite_serializes_as_null() {
        assert_eq!(Json::Num(f64::NAN).dump(), "null");
        assert_eq!(Json::Num(f64::INFINITY).dump(), "null");
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::parse(r#"{"a":[1,2],"b":{"c":true}}"#).unwrap();
        let p = Json::parse(&v.dump_pretty()).unwrap();
        assert_eq!(v, p);
    }
}
