//! Deterministic pseudo-random number generation.
//!
//! The offline build has no `rand` crate, so we implement xoshiro256**
//! (Blackman & Vigna) seeded through SplitMix64 — the standard, well-tested
//! construction. Every experiment in the repository takes an explicit seed so
//! paper-figure regeneration is bit-reproducible.

/// SplitMix64 — used only to expand a user seed into xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG. Fast, high-quality, 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from the polar Box–Muller transform.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal deviate via the polar Box–Muller transform.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.spare_normal = Some(v * f);
                return u * f;
            }
        }
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Vector of uniforms in [lo, hi).
    pub fn uniform_vec(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.uniform_in(lo, hi)).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample `k` distinct indices from 0..n (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        // Partial Fisher–Yates.
        let mut p: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            p.swap(i, j);
        }
        p.truncate(k);
        p
    }

    /// Split off an independent generator (for per-worker streams).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_and_in_range() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            let k = r.below(7);
            assert!(k < 7);
            counts[k] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs = r.normal_vec(n);
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut r = Rng::new(3);
        let p = r.permutation(100);
        let mut seen = vec![false; 100];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
    }

    #[test]
    fn split_streams_are_independent() {
        let mut a = Rng::new(1234);
        let mut b = a.split();
        // Streams should not be identical.
        let xa: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let xb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xa, xb);
    }
}
