//! Experiment runners shared between the bench binaries, the examples and
//! the CLI: per-paper-artifact modules (Table 1, Figure 1, Figure 2) plus
//! the uniform method dispatcher.

pub mod methods;
pub mod snelson;
pub mod sweep;
pub mod table1;

pub use methods::{Method, MethodResult};
