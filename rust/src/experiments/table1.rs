//! The Table 1 experiment: SMSE(MNLP) for six methods × six datasets with
//! the paper's protocol — normalize, 90/10 split, hyperparameter
//! selection on the train side, repeat over seeds and average.
//!
//! Selection is pluggable ([`Table1Config::selection`]): the paper's
//! 5-fold grid CV (`"cv"`, default), or evidence training through the
//! same `select_hyperparams` API the `train` op uses — derivative-free
//! (`"mll"`) or analytic-gradient L-BFGS (`"mll-grad"`) — so the table
//! can be reproduced with evidence-trained hyperparameters, riding the
//! per-lengthscale factor cache.

use crate::data::dataset::Dataset;
use crate::data::synth::{gp_dataset, table1_k, table1_specs};
use crate::experiments::methods::{cv_predict, run_method_with_shards, Method};
use crate::gp::cv::{grid_search, HyperParams};
use crate::train::{select_hyperparams, ModelSelection, OptimBudget};

/// One table cell aggregated over repeats.
#[derive(Clone, Debug)]
pub struct Cell {
    pub method: Method,
    pub smse_mean: f64,
    pub smse_std: f64,
    /// None when every repeat lost spsd (MEKA pathology).
    pub mnlp_mean: Option<f64>,
    pub fit_s_mean: f64,
}

/// One dataset row of the table.
#[derive(Clone, Debug)]
pub struct Row {
    pub dataset: String,
    pub n_used: usize,
    pub dim: usize,
    pub k: usize,
    pub chosen: HyperParams,
    pub cells: Vec<Cell>,
}

/// Experiment controls (scaled-down defaults keep the bench affordable on
/// one core; `--full` in the bench binary lifts the caps).
#[derive(Clone, Debug)]
pub struct Table1Config {
    /// Cap on dataset size (subsample above this). `usize::MAX` = paper size.
    pub max_n: usize,
    /// Number of repeat splits averaged per cell (paper: 5).
    pub repeats: usize,
    /// CV folds (paper: 5).
    pub folds: usize,
    /// Subsample used inside CV for speed.
    pub cv_max_n: usize,
    pub seed: u64,
    /// Restrict to these methods (None = all six).
    pub methods: Option<Vec<Method>>,
    /// Hyperparameter selection strategy: `"cv"` (paper protocol,
    /// default), `"mll"` (evidence / Nelder–Mead) or `"mll-grad"`
    /// (evidence / L-BFGS on analytic gradients). Unknown names fall
    /// back to CV with a warning.
    pub selection: String,
    /// Shard count for the MKA column (1 = monolithic cascade, the paper
    /// protocol). `> 1` runs MKA through the sharded serving plane —
    /// shard-per-cluster experts with rBCM recombination — so the table
    /// reports serving-plane quality next to the baselines. Only MKA
    /// shards; the other columns always run unsharded.
    pub shards: usize,
}

impl Default for Table1Config {
    fn default() -> Self {
        Table1Config {
            max_n: 1024,
            repeats: 2,
            folds: 3,
            cv_max_n: 512,
            seed: 42,
            methods: None,
            selection: "cv".into(),
            shards: 1,
        }
    }
}

/// Run the experiment for one dataset.
pub fn run_dataset(data: &Dataset, k: usize, cfg: &Table1Config) -> Row {
    let data = data.subsample(cfg.max_n, cfg.seed);
    let methods: Vec<Method> =
        cfg.methods.clone().unwrap_or_else(|| Method::ALL.to_vec());

    // ---- hyperparameter selection (on the train side of the first
    // split, with the Full model as the selection oracle when affordable,
    // otherwise SoR — both pick kernel-level parameters shared by every
    // method, matching the paper's shared-CV protocol) --------------------
    let (tr0, _te0) = data.split(0.9, cfg.seed);
    let cv_data = tr0.subsample(cfg.cv_max_n, cfg.seed ^ 1);
    let cv_method = if cv_data.n() <= 600 { Method::Full } else { Method::Sor };
    let heuristic = HyperParams {
        lengthscale: (data.dim() as f64).sqrt().max(1.0),
        sigma2: 0.1,
    };
    let sel = ModelSelection::parse(&cfg.selection, cfg.folds, OptimBudget::default(), false)
        .unwrap_or_else(|| {
            eprintln!(
                "table1 {}: unknown selection {:?}; using grid CV",
                data.name, cfg.selection
            );
            ModelSelection::GridCv { folds: cfg.folds }
        });
    let hp = if matches!(sel, ModelSelection::GridCv { .. }) {
        let grid = crate::gp::cv::default_grid(data.dim());
        match grid_search(&cv_data, cfg.folds, &grid, cfg.seed, |tr, vx, hp| {
            cv_predict(cv_method, tr, vx, hp, k, cfg.seed)
        }) {
            Ok(outcome) => outcome.best,
            // Every grid point failed (now an explicit error, not a
            // silent infinite-score winner): fall back to the √d
            // heuristic so the table row still renders, and say so.
            Err(e) => {
                eprintln!(
                    "table1 {}: CV failed ({e}); using heuristic hyperparameters",
                    data.name
                );
                heuristic
            }
        }
    } else {
        // Evidence training through the exact API the `train` op uses —
        // the optimizer's σ²-axis moves ride the per-lengthscale factor
        // cache, so this costs far fewer factorizations than evals.
        match select_hyperparams(cv_method, &cv_data, &sel, k, cfg.seed) {
            Ok(report) => report.best,
            Err(e) => {
                eprintln!(
                    "table1 {}: evidence selection failed ({e}); using heuristic hyperparameters",
                    data.name
                );
                heuristic
            }
        }
    };

    // ---- repeats ---------------------------------------------------------
    let mut acc: Vec<(Vec<f64>, Vec<f64>, Vec<f64>)> =
        vec![(Vec::new(), Vec::new(), Vec::new()); methods.len()];
    for rep in 0..cfg.repeats {
        let (tr, te) = data.split(0.9, cfg.seed + 1000 * (rep as u64 + 1));
        for (mi, &m) in methods.iter().enumerate() {
            if let Ok(r) =
                run_method_with_shards(m, &tr, &te, hp, k, cfg.seed + rep as u64, cfg.shards)
            {
                acc[mi].0.push(r.smse);
                if let Some(nl) = r.mnlp {
                    acc[mi].1.push(nl);
                }
                acc[mi].2.push(r.fit_s);
            }
        }
    }

    let cells = methods
        .iter()
        .zip(acc)
        .map(|(&m, (smses, mnlps, fits))| {
            let (sm, ss) = crate::la::stats::mean_std_sample(&smses);
            let mn = if mnlps.is_empty() {
                None
            } else {
                Some(crate::la::stats::mean(&mnlps))
            };
            Cell {
                method: m,
                smse_mean: if smses.is_empty() { f64::NAN } else { sm },
                smse_std: ss,
                mnlp_mean: mn,
                fit_s_mean: crate::la::stats::mean(&fits),
            }
        })
        .collect();

    Row {
        dataset: data.name.clone(),
        n_used: data.n(),
        dim: data.dim(),
        k,
        chosen: hp,
        cells,
    }
}

/// Run the whole table over the six catalog datasets.
pub fn run_table(cfg: &Table1Config, only: Option<&[&str]>) -> Vec<Row> {
    let mut rows = Vec::new();
    for spec in table1_specs() {
        if let Some(filter) = only {
            if !filter.contains(&spec.name.as_str()) {
                continue;
            }
        }
        let data = gp_dataset(&spec, cfg.seed);
        let k = table1_k(&spec.name);
        rows.push(run_dataset(&data, k, cfg));
    }
    rows
}

/// Render rows in the paper's `SMSE(MNLP)` cell format.
pub fn format_rows(rows: &[Row]) -> String {
    let mut t = crate::bench::Table::new(&[
        "dataset", "n", "k", "Full", "SOR", "FITC", "PITC", "MEKA", "MKA",
    ]);
    for row in rows {
        let mut cells: Vec<String> =
            vec![row.dataset.clone(), row.n_used.to_string(), row.k.to_string()];
        for m in Method::ALL {
            let cell = row.cells.iter().find(|c| c.method == m);
            cells.push(match cell {
                Some(c) if c.smse_mean.is_finite() => match c.mnlp_mean {
                    Some(nl) => format!("{:.2}({:.2})", c.smse_mean, nl),
                    None => format!("{:.2}(-)", c.smse_mean),
                },
                _ => "-".to_string(),
            });
        }
        t.row(&cells);
    }
    t.to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    #[test]
    fn run_dataset_produces_full_row() {
        let data = gp_dataset(&SynthSpec::named("mini", 160, 3), 5);
        let cfg = Table1Config {
            max_n: 160,
            repeats: 1,
            folds: 2,
            cv_max_n: 100,
            seed: 5,
            methods: Some(vec![Method::Full, Method::Sor, Method::Mka]),
            ..Table1Config::default()
        };
        let row = run_dataset(&data, 8, &cfg);
        assert_eq!(row.cells.len(), 3);
        for c in &row.cells {
            assert!(c.smse_mean.is_finite(), "{:?}", c.method);
        }
        // MKA should be competitive with (or beat) SoR at tiny k — the
        // paper's central claim. Allow generous slack; this is a smoke test.
        let get = |m: Method| row.cells.iter().find(|c| c.method == m).unwrap().smse_mean;
        assert!(get(Method::Mka) < get(Method::Sor) * 2.0 + 0.5);
    }

    /// Evidence-trained hyperparameters (ROADMAP lever): the table runs
    /// with `selection: "mll"` / `"mll-grad"` through the same
    /// `select_hyperparams` API as the `train` op, and still renders a
    /// full, finite row.
    #[test]
    fn run_dataset_with_evidence_selection() {
        let data = gp_dataset(&SynthSpec::named("mini-mll", 140, 2), 6);
        for selection in ["mll", "mll-grad"] {
            let cfg = Table1Config {
                max_n: 140,
                repeats: 1,
                folds: 2,
                cv_max_n: 90,
                seed: 6,
                methods: Some(vec![Method::Full, Method::Mka]),
                selection: selection.into(),
                shards: 1,
            };
            let row = run_dataset(&data, 8, &cfg);
            assert_eq!(row.cells.len(), 2, "{selection}");
            assert!(row.chosen.lengthscale > 0.0 && row.chosen.sigma2 > 0.0, "{selection}");
            for c in &row.cells {
                assert!(c.smse_mean.is_finite(), "{selection} {:?}", c.method);
            }
        }
    }

    /// `--shards k` table runs: the MKA column goes through the sharded
    /// serving plane and still renders a finite, competitive cell.
    #[test]
    fn run_dataset_with_sharded_mka_column() {
        let data = gp_dataset(&SynthSpec::named("mini-sh", 160, 3), 5);
        let cfg = Table1Config {
            max_n: 160,
            repeats: 1,
            folds: 2,
            cv_max_n: 100,
            seed: 5,
            methods: Some(vec![Method::Full, Method::Mka]),
            shards: 3,
            ..Table1Config::default()
        };
        let row = run_dataset(&data, 8, &cfg);
        assert_eq!(row.cells.len(), 2);
        for c in &row.cells {
            assert!(c.smse_mean.is_finite(), "{:?}", c.method);
        }
    }

    #[test]
    fn formatting_matches_paper_style() {
        let rows = vec![Row {
            dataset: "housing".into(),
            n_used: 506,
            dim: 13,
            k: 16,
            chosen: HyperParams { lengthscale: 1.0, sigma2: 0.1 },
            cells: vec![Cell {
                method: Method::Full,
                smse_mean: 0.36,
                smse_std: 0.01,
                mnlp_mean: Some(-0.32),
                fit_s_mean: 0.1,
            }],
        }];
        let s = format_rows(&rows);
        assert!(s.contains("0.36(-0.32)"));
        assert!(s.contains("housing"));
    }
}
