//! Figure 2 experiment: SMSE and MNLP as a function of the number of
//! pseudo-inputs / d_core. The paper's claim: MKA stays flat as the budget
//! shrinks while the low-rank family degrades quickly.

use crate::data::dataset::Dataset;
use crate::experiments::methods::{run_method, Method};
use crate::gp::cv::HyperParams;

/// One (method, k) point of the sweep.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub method: Method,
    pub k: usize,
    pub smse: f64,
    pub mnlp: Option<f64>,
}

/// Sweep all methods over a list of budgets on one dataset split.
pub fn sweep(
    data: &Dataset,
    ks: &[usize],
    hp: HyperParams,
    methods: &[Method],
    seed: u64,
) -> Vec<SweepPoint> {
    let (tr, te) = data.split(0.9, seed);
    let mut out = Vec::new();
    for &k in ks {
        for &m in methods {
            // Full is k-independent; evaluate it once (at the first k) and
            // reuse by emitting the same value for every k in the caller.
            match run_method(m, &tr, &te, hp, k, seed) {
                Ok(r) => out.push(SweepPoint { method: m, k, smse: r.smse, mnlp: r.mnlp }),
                Err(_) => out.push(SweepPoint { method: m, k, smse: f64::NAN, mnlp: None }),
            }
        }
    }
    out
}

/// CSV rows for plotting: method,k,smse,mnlp.
pub fn to_csv_rows(points: &[SweepPoint]) -> (Vec<&'static str>, Vec<Vec<f64>>) {
    let header = vec!["method_idx", "k", "smse", "mnlp"];
    let rows = points
        .iter()
        .map(|p| {
            vec![
                Method::ALL.iter().position(|&m| m == p.method).unwrap() as f64,
                p.k as f64,
                p.smse,
                p.mnlp.unwrap_or(f64::NAN),
            ]
        })
        .collect();
    (header, rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gp_dataset, SynthSpec};

    #[test]
    fn sweep_covers_grid() {
        let data = gp_dataset(&SynthSpec::named("t", 140, 2), 1);
        let hp = HyperParams { lengthscale: 1.4, sigma2: 0.1 };
        let pts = sweep(&data, &[4, 8], hp, &[Method::Sor, Method::Mka], 3);
        assert_eq!(pts.len(), 4);
        for p in &pts {
            assert!(p.smse.is_finite(), "{:?} k={}", p.method, p.k);
        }
    }

    #[test]
    fn mka_flatter_than_sor_in_k() {
        // The qualitative Figure-2 shape: MKA's degradation from large k to
        // small k should be no worse than SoR's (broad-spectrum data).
        let data = gp_dataset(&SynthSpec::named("t", 240, 3), 2);
        let hp = HyperParams { lengthscale: 1.7, sigma2: 0.1 };
        let pts = sweep(&data, &[8, 48], hp, &[Method::Sor, Method::Mka], 4);
        let get = |m: Method, k: usize| {
            pts.iter().find(|p| p.method == m && p.k == k).unwrap().smse
        };
        let sor_gap = get(Method::Sor, 8) - get(Method::Sor, 48);
        let mka_gap = get(Method::Mka, 8) - get(Method::Mka, 48);
        assert!(
            mka_gap <= sor_gap + 0.3,
            "MKA gap {mka_gap} vs SoR gap {sor_gap}"
        );
    }

    #[test]
    fn csv_rows_shape() {
        let pts = vec![SweepPoint { method: Method::Mka, k: 8, smse: 0.5, mnlp: Some(1.0) }];
        let (h, rows) = to_csv_rows(&pts);
        assert_eq!(h.len(), 4);
        assert_eq!(rows[0][1], 8.0);
    }
}
