//! Uniform dispatch over the six methods of the paper's evaluation
//! (§5: Full, SOR, FITC, PITC, MEKA, MKA) so every bench/table drives them
//! identically.

use crate::baselines::{Fitc, Meka, MekaConfig, Pitc, Sor};
use crate::data::dataset::Dataset;
use crate::error::Result;
use crate::gp::cv::HyperParams;
use crate::gp::full::FullGp;
use crate::gp::metrics::{mnlp, smse};
use crate::gp::mka_gp::MkaGp;
use crate::gp::GpModel;
use crate::kernels::RbfKernel;
use crate::la::dense::Mat;
use crate::mka::MkaConfig;
use crate::util::timer::Timer;

/// The six methods of Table 1 / Figures 1–2.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Full,
    Sor,
    Fitc,
    Pitc,
    Meka,
    Mka,
}

impl Method {
    pub const ALL: [Method; 6] =
        [Method::Full, Method::Sor, Method::Fitc, Method::Pitc, Method::Meka, Method::Mka];

    pub fn label(&self) -> &'static str {
        match self {
            Method::Full => "Full",
            Method::Sor => "SOR",
            Method::Fitc => "FITC",
            Method::Pitc => "PITC",
            Method::Meka => "MEKA",
            Method::Mka => "MKA",
        }
    }

    pub fn parse(s: &str) -> Option<Method> {
        match s.to_ascii_lowercase().as_str() {
            "full" => Some(Method::Full),
            "sor" | "dtc" => Some(Method::Sor),
            "fitc" => Some(Method::Fitc),
            "pitc" => Some(Method::Pitc),
            "meka" => Some(Method::Meka),
            "mka" => Some(Method::Mka),
            _ => None,
        }
    }
}

/// One method's evaluation on a train/test split.
#[derive(Clone, Debug)]
pub struct MethodResult {
    pub method: Method,
    /// Standardized mean squared error of the predictive mean.
    pub smse: f64,
    /// MNLP, `None` when the method's variances are unusable (MEKA's lost
    /// spsd-ness — the paper's supplement reports the same blanks).
    pub mnlp: Option<f64>,
    pub fit_s: f64,
    pub predict_s: f64,
}

/// PITC conditioning-block size for a landmark budget `k`: about n/10,
/// at least k (floored at 8) and at most 200. The lower bound is capped
/// at 200 too — `clamp` panics on min > max, and `k` arrives from the
/// protocol/CLI, so k > 200 must degrade instead of aborting.
pub fn pitc_block_size(n: usize, k: usize) -> usize {
    (n / 10).clamp(k.max(8).min(200), 200)
}

/// MKA configuration matched to a pseudo-input budget `k`: d_core = k,
/// block size scaled so a few stages exist (paper: c ≈ m/2 per stage).
pub fn mka_config_for(k: usize, n: usize, seed: u64) -> MkaConfig {
    MkaConfig {
        d_core: k,
        block_size: (4 * k).clamp(32, 256).min(n.max(8)),
        gamma: 0.5,
        seed,
        ..MkaConfig::default()
    }
}

/// Fit + evaluate one method. `k` is the pseudo-input / d_core / rank
/// budget; `hp` carries the kernel hyperparameters.
pub fn run_method(
    method: Method,
    train: &Dataset,
    test: &Dataset,
    hp: HyperParams,
    k: usize,
    seed: u64,
) -> Result<MethodResult> {
    run_method_with_shards(method, train, test, hp, k, seed, 1)
}

/// `run_method` with a shard count: `shards > 1` fits the MKA row through
/// the sharded serving plane (shard-per-cluster experts, routed predicts,
/// rBCM recombination) instead of one monolithic cascade. Only MKA
/// shards; every other method ignores the count and runs unsharded, so
/// the table's comparison columns stay the paper's.
pub fn run_method_with_shards(
    method: Method,
    train: &Dataset,
    test: &Dataset,
    hp: HyperParams,
    k: usize,
    seed: u64,
    shards: usize,
) -> Result<MethodResult> {
    let kernel = RbfKernel::new(hp.lengthscale);
    let s2 = hp.sigma2;
    let t_fit = Timer::start();
    let model: Box<dyn GpModel> = match method {
        Method::Full => Box::new(FullGp::fit(train, &kernel, s2)?),
        Method::Sor => Box::new(Sor::fit(train, &kernel, s2, k, seed)?),
        Method::Fitc => Box::new(Fitc::fit(train, &kernel, s2, k, seed)?),
        Method::Pitc => {
            let block = pitc_block_size(train.n(), k);
            Box::new(Pitc::fit(train, &kernel, s2, k, block, seed)?)
        }
        Method::Meka => {
            let cfg = MekaConfig {
                rank: k,
                n_clusters: (k / 8).clamp(2, 8),
                sample_frac: 0.7,
                seed,
            };
            Box::new(Meka::fit(train, &kernel, s2, &cfg)?)
        }
        Method::Mka if shards > 1 => {
            let cfg = mka_config_for(k, train.n(), seed);
            Box::new(crate::gp::sharded::ShardedGp::fit(
                train,
                &kernel,
                s2,
                &cfg,
                shards,
                crate::cluster::ClusterMethod::KMeans,
            )?)
        }
        Method::Mka => {
            let cfg = mka_config_for(k, train.n(), seed);
            Box::new(MkaGp::fit(train, &kernel, s2, &cfg)?)
        }
    };
    let fit_s = t_fit.elapsed_secs();

    let t_pred = Timer::start();
    let pred = model.predict(&test.x);
    let predict_s = t_pred.elapsed_secs();

    let e = smse(&test.y, &pred.mean);
    let nl = if pred.var.iter().all(|v| v.is_finite()) {
        Some(mnlp(&test.y, &pred.mean, &pred.var))
    } else {
        None
    };
    Ok(MethodResult { method, smse: e, mnlp: nl, fit_s, predict_s })
}

/// Quick single-method prediction used inside CV loops (mean only).
pub fn cv_predict(
    method: Method,
    train: &Dataset,
    x_val: &Mat,
    hp: HyperParams,
    k: usize,
    seed: u64,
) -> Option<Vec<f64>> {
    let kernel = RbfKernel::new(hp.lengthscale);
    let s2 = hp.sigma2;
    let mean = match method {
        Method::Full => FullGp::fit(train, &kernel, s2).ok()?.predict(x_val).mean,
        Method::Sor => Sor::fit(train, &kernel, s2, k, seed).ok()?.predict(x_val).mean,
        Method::Fitc => Fitc::fit(train, &kernel, s2, k, seed).ok()?.predict(x_val).mean,
        Method::Pitc => {
            let block = pitc_block_size(train.n(), k);
            Pitc::fit(train, &kernel, s2, k, block, seed).ok()?.predict(x_val).mean
        }
        Method::Meka => {
            let cfg = MekaConfig {
                rank: k,
                n_clusters: (k / 8).clamp(2, 8),
                sample_frac: 0.7,
                seed,
            };
            Meka::fit(train, &kernel, s2, &cfg).ok()?.predict(x_val).mean
        }
        Method::Mka => {
            let cfg = mka_config_for(k, train.n(), seed);
            MkaGp::fit(train, &kernel, s2, &cfg).ok()?.predict(x_val).mean
        }
    };
    Some(mean)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gp_dataset, SynthSpec};

    #[test]
    fn all_methods_run_on_small_data() {
        let data = gp_dataset(&SynthSpec::named("t", 120, 2), 1);
        let (tr, te) = data.split(0.9, 1);
        let hp = HyperParams { lengthscale: 1.4, sigma2: 0.1 };
        for m in Method::ALL {
            let r = run_method(m, &tr, &te, hp, 12, 7).unwrap();
            assert!(r.smse.is_finite(), "{m:?}");
            assert!(r.smse < 2.0, "{m:?} smse={}", r.smse);
            assert!(r.fit_s >= 0.0 && r.predict_s >= 0.0);
        }
    }

    #[test]
    fn parse_and_label_roundtrip() {
        for m in Method::ALL {
            assert_eq!(Method::parse(m.label()), Some(m));
        }
        assert_eq!(Method::parse("dtc"), Some(Method::Sor));
        assert_eq!(Method::parse("nope"), None);
    }

    #[test]
    fn pitc_block_size_never_panics_on_huge_k() {
        // Regression: `clamp` with min > max aborts; k comes from the
        // protocol, so k > 200 must degrade gracefully.
        assert_eq!(pitc_block_size(1000, 300), 200);
        assert_eq!(pitc_block_size(1000, 32), 100);
        assert_eq!(pitc_block_size(50, 2), 8);
        assert_eq!(pitc_block_size(10_000, 2), 200);
    }

    #[test]
    fn mka_config_scales_with_k() {
        let c = mka_config_for(16, 1000, 3);
        assert_eq!(c.d_core, 16);
        assert_eq!(c.block_size, 64);
        let c2 = mka_config_for(128, 1000, 3);
        assert_eq!(c2.block_size, 256);
    }

    #[test]
    fn sharded_mka_run_matches_quality_envelope() {
        let data = gp_dataset(&SynthSpec::named("t-sh", 150, 2), 4);
        let (tr, te) = data.split(0.9, 4);
        let hp = HyperParams { lengthscale: 1.4, sigma2: 0.1 };
        let plain = run_method_with_shards(Method::Mka, &tr, &te, hp, 12, 7, 1).unwrap();
        let sharded = run_method_with_shards(Method::Mka, &tr, &te, hp, 12, 7, 3).unwrap();
        assert!(plain.smse.is_finite() && sharded.smse.is_finite());
        // rBCM over three 45-point experts loses some accuracy vs the
        // monolithic cascade, but must stay in the same envelope.
        assert!(sharded.smse < plain.smse * 3.0 + 0.5, "sharded={}", sharded.smse);
        // Non-MKA methods ignore the shard count entirely.
        let a = run_method_with_shards(Method::Sor, &tr, &te, hp, 12, 7, 3).unwrap();
        let b = run_method(Method::Sor, &tr, &te, hp, 12, 7).unwrap();
        assert_eq!(a.smse.to_bits(), b.smse.to_bits());
    }

    #[test]
    fn cv_predict_returns_means() {
        let data = gp_dataset(&SynthSpec::named("t", 80, 2), 2);
        let (tr, va) = data.split(0.8, 2);
        let hp = HyperParams { lengthscale: 1.4, sigma2: 0.1 };
        let m = cv_predict(Method::Sor, &tr, &va.x, hp, 8, 3).unwrap();
        assert_eq!(m.len(), va.n());
    }
}
