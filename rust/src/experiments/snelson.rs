//! Figure 1 experiment: qualitative fits on the Snelson-style 1D toy —
//! predictive mean ± 1σ for all six methods on a dense input grid.

use crate::data::dataset::Dataset;
use crate::data::synth::snelson1d;
use crate::experiments::methods::{Method};
use crate::gp::cv::HyperParams;
use crate::gp::GpModel;
use crate::la::dense::Mat;

/// Curves for one method on the evaluation grid.
#[derive(Clone, Debug)]
pub struct Curves {
    pub method: Method,
    pub grid: Vec<f64>,
    pub mean: Vec<f64>,
    pub std: Vec<f64>,
}

/// Fit every requested method on the toy data and evaluate on a uniform
/// grid over the input range. Returns (data, per-method curves).
pub fn run(
    n: usize,
    k: usize,
    grid_points: usize,
    hp: HyperParams,
    methods: &[Method],
    seed: u64,
) -> (Dataset, Vec<Curves>) {
    let data = snelson1d(n, seed);
    let lo = data.x.at(0, 0) - 0.3;
    let hi = data.x.at(n - 1, 0) + 0.3;
    let grid: Vec<f64> = (0..grid_points)
        .map(|i| lo + (hi - lo) * (i as f64) / (grid_points - 1) as f64)
        .collect();
    let gx = Mat::from_vec(grid_points, 1, grid.clone());

    let mut curves = Vec::new();
    for &m in methods {
        let model: Option<Box<dyn GpModel>> = build(m, &data, hp, k, seed);
        if let Some(model) = model {
            let pred = model.predict(&gx);
            curves.push(Curves {
                method: m,
                grid: grid.clone(),
                mean: pred.mean,
                std: pred.var.iter().map(|v| v.max(0.0).sqrt()).collect(),
            });
        }
    }
    (data, curves)
}

fn build(
    m: Method,
    data: &Dataset,
    hp: HyperParams,
    k: usize,
    seed: u64,
) -> Option<Box<dyn GpModel>> {
    use crate::baselines::{Fitc, Meka, MekaConfig, Pitc, Sor};
    use crate::gp::full::FullGp;
    use crate::gp::mka_gp::MkaGp;
    use crate::kernels::RbfKernel;
    let kern = RbfKernel::new(hp.lengthscale);
    let s2 = hp.sigma2;
    Some(match m {
        Method::Full => Box::new(FullGp::fit(data, &kern, s2).ok()?),
        Method::Sor => Box::new(Sor::fit(data, &kern, s2, k, seed).ok()?),
        Method::Fitc => Box::new(Fitc::fit(data, &kern, s2, k, seed).ok()?),
        Method::Pitc => Box::new(Pitc::fit(data, &kern, s2, k, 25, seed).ok()?),
        Method::Meka => {
            let cfg = MekaConfig { rank: k, n_clusters: 3, sample_frac: 0.7, seed };
            Box::new(Meka::fit(data, &kern, s2, &cfg).ok()?)
        }
        Method::Mka => {
            let cfg = crate::experiments::methods::mka_config_for(k, data.n(), seed);
            Box::new(MkaGp::fit(data, &kern, s2, &cfg).ok()?)
        }
    })
}

/// Mean absolute deviation between a method's curve and the Full GP's —
/// the quantitative readout of "MKA fits almost as well as Full" (Fig. 1).
pub fn deviation_from_full(curves: &[Curves]) -> Vec<(Method, f64)> {
    let full = curves.iter().find(|c| c.method == Method::Full);
    let Some(full) = full else {
        return Vec::new();
    };
    curves
        .iter()
        .filter(|c| c.method != Method::Full)
        .map(|c| {
            let d = c
                .mean
                .iter()
                .zip(&full.mean)
                .map(|(a, b)| (a - b).abs())
                .sum::<f64>()
                / c.mean.len() as f64;
            (c.method, d)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn produces_curves_for_all_methods() {
        let hp = HyperParams { lengthscale: 0.5, sigma2: 0.01 };
        let (data, curves) = run(120, 10, 50, hp, &Method::ALL, 1);
        assert_eq!(data.n(), 120);
        assert!(curves.len() >= 5, "got {} curves", curves.len());
        for c in &curves {
            assert_eq!(c.mean.len(), 50);
            assert!(c.std.iter().all(|s| s.is_finite() || c.method == Method::Meka));
        }
    }

    #[test]
    fn mka_closer_to_full_than_sor() {
        // The headline qualitative claim of Figure 1.
        let hp = HyperParams { lengthscale: 0.5, sigma2: 0.01 };
        let (_, curves) = run(150, 10, 80, hp, &[Method::Full, Method::Sor, Method::Mka], 2);
        let dev = deviation_from_full(&curves);
        let get = |m: Method| dev.iter().find(|(mm, _)| *mm == m).unwrap().1;
        assert!(
            get(Method::Mka) < get(Method::Sor) * 1.5 + 0.05,
            "mka={} sor={}",
            get(Method::Mka),
            get(Method::Sor)
        );
    }
}
