//! Request router: dispatches protocol ops (JSON objects) to the fitting
//! pool, the model registry and the prediction batcher.
//!
//! Protocol (one JSON object per request):
//!   {"op": "ping"}
//!   {"op": "fit", "model": "m1", "method": "mka", "x": [[...]...],
//!    "y": [...], "params": {"lengthscale": 1.0, "sigma2": 0.1, "k": 32},
//!    "async": true}
//!   {"op": "job", "job_id": 1}
//!   {"op": "predict", "model": "m1", "x": [[...]...]}
//!   {"op": "models"} | {"op": "drop_model", "model": "m1"}
//!   {"op": "metrics"} | {"op": "config"}

use std::sync::Arc;
use std::time::Duration;

use super::batcher::PredictBatcher;
use super::config::ServiceConfig;
use super::jobs::{JobState, JobStore, ModelRegistry};
use super::metrics::Metrics;
use super::pool::WorkerPool;
use crate::data::dataset::Dataset;
use crate::error::{Error, Result};
use crate::experiments::methods::Method;
use crate::gp::cv::HyperParams;
use crate::gp::GpModel;
use crate::la::dense::Mat;
use crate::util::json::Json;
use crate::util::timer::Timer;

/// Shared coordinator state + dispatch.
pub struct Router {
    pub config: ServiceConfig,
    pub metrics: Arc<Metrics>,
    pub registry: ModelRegistry,
    pub jobs: Arc<JobStore>,
    pool: WorkerPool,
    batcher: PredictBatcher,
}

impl Router {
    pub fn new(config: ServiceConfig) -> Router {
        // Size the shared compute pool from the service config so fits
        // and batched predicts saturate the configured parallelism.
        crate::par::set_threads(config.resolved_threads());
        let metrics = Arc::new(Metrics::new());
        let registry = ModelRegistry::new();
        let batcher = PredictBatcher::start(
            registry.clone(),
            Arc::clone(&metrics),
            Duration::from_millis(config.batch_window_ms),
            config.max_batch,
        );
        let pool = WorkerPool::new(config.n_workers);
        Router { config, metrics, registry, jobs: Arc::new(JobStore::new()), pool, batcher }
    }

    /// Handle one request; never panics — protocol errors become
    /// `{"ok": false, "error": ...}`.
    pub fn handle(&self, req: &Json) -> Json {
        self.metrics.incr("requests", 1);
        let op = req.str_field("op").unwrap_or("");
        let out = match op {
            "ping" => Ok(Json::obj().with("pong", Json::Bool(true))),
            "fit" => self.handle_fit(req),
            "job" => self.handle_job(req),
            "predict" => self.handle_predict(req),
            "models" => Ok(Json::obj().with(
                "models",
                Json::Arr(self.registry.names().into_iter().map(Json::Str).collect()),
            )),
            "drop_model" => {
                let name = req.str_field("model").unwrap_or("");
                Ok(Json::obj().with("dropped", Json::Bool(self.registry.remove(name))))
            }
            "metrics" => {
                // Registry counters/histograms plus the compute-plane
                // observables: logical cascade count and pool utilization.
                let mut snap = self.metrics.snapshot();
                snap.set(
                    "compute",
                    Json::obj()
                        .with("cascades", Json::Num(crate::mka::cascade_count() as f64))
                        .with("pool_threads", Json::Num(crate::par::threads() as f64))
                        .with("pool_workers", Json::Num(crate::par::pool_workers() as f64))
                        .with("pool_jobs", Json::Num(crate::par::jobs_executed() as f64)),
                );
                Ok(snap)
            }
            "config" => Ok(self.config.to_json()),
            other => Err(Error::Protocol(format!("unknown op {other:?}"))),
        };
        match out {
            Ok(mut j) => {
                j.set("ok", Json::Bool(true));
                j
            }
            Err(e) => {
                self.metrics.incr("errors", 1);
                Json::obj()
                    .with("ok", Json::Bool(false))
                    .with("error", Json::Str(format!("{e}")))
            }
        }
    }

    fn handle_fit(&self, req: &Json) -> Result<Json> {
        let name = req
            .str_field("model")
            .ok_or_else(|| Error::Protocol("fit: missing model".into()))?
            .to_string();
        let method = Method::parse(req.str_field("method").unwrap_or("mka"))
            .ok_or_else(|| Error::Protocol("fit: unknown method".into()))?;
        let x = parse_matrix(req.get("x").ok_or_else(|| Error::Protocol("fit: missing x".into()))?)?;
        let y = req
            .get("y")
            .and_then(|v| v.f64_array())
            .ok_or_else(|| Error::Protocol("fit: missing y".into()))?;
        if x.rows != y.len() || x.rows == 0 {
            return Err(Error::Protocol("fit: x/y shape mismatch".into()));
        }
        let data = Dataset::new(name.clone(), x, y);
        let params = req.get("params");
        let hp = HyperParams {
            lengthscale: params.and_then(|p| p.num_field("lengthscale")).unwrap_or(1.0),
            sigma2: params.and_then(|p| p.num_field("sigma2")).unwrap_or(0.1),
        };
        let k = params.and_then(|p| p.usize_field("k")).unwrap_or(self.config.d_core);
        let seed = self.config.seed;
        let is_async = req.get("async").and_then(|v| v.as_bool()).unwrap_or(false);

        if is_async {
            let job_id = self.jobs.create(&name);
            let jobs = Arc::clone(&self.jobs);
            let registry = self.registry.clone();
            let metrics = Arc::clone(&self.metrics);
            let submitted = self.pool.submit(move || {
                jobs.set_state(job_id, JobState::Running);
                let t = Timer::start();
                match fit_model(method, &data, hp, k, seed) {
                    Ok(model) => {
                        registry.publish(&name, model.into());
                        metrics.incr("fits", 1);
                        jobs.set_state(job_id, JobState::Done { fit_secs: t.elapsed_secs() });
                    }
                    Err(e) => {
                        metrics.incr("fit_errors", 1);
                        jobs.set_state(job_id, JobState::Failed { error: format!("{e}") });
                    }
                }
            });
            if !submitted {
                return Err(Error::Coordinator("worker pool unavailable".into()));
            }
            Ok(Json::obj().with("job_id", Json::Num(job_id as f64)))
        } else {
            let t = Timer::start();
            let model = fit_model(method, &data, hp, k, seed)?;
            self.registry.publish(&name, model.into());
            self.metrics.incr("fits", 1);
            Ok(Json::obj()
                .with("model", Json::Str(name))
                .with("fit_secs", Json::Num(t.elapsed_secs())))
        }
    }

    fn handle_job(&self, req: &Json) -> Result<Json> {
        let id = req
            .get("job_id")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| Error::Protocol("job: missing job_id".into()))? as u64;
        Ok(self.jobs.to_json(id))
    }

    fn handle_predict(&self, req: &Json) -> Result<Json> {
        let name = req
            .str_field("model")
            .ok_or_else(|| Error::Protocol("predict: missing model".into()))?;
        let x =
            parse_matrix(req.get("x").ok_or_else(|| Error::Protocol("predict: missing x".into()))?)?;
        let pred = self.batcher.predict(name, x)?;
        Ok(Json::obj()
            .with("mean", Json::from_f64_slice(&pred.mean))
            .with("var", Json::from_f64_slice(&pred.var)))
    }
}

/// Fit a model of the requested kind (shared with the CLI).
pub fn fit_model(
    method: Method,
    data: &Dataset,
    hp: HyperParams,
    k: usize,
    seed: u64,
) -> Result<Box<dyn GpModel>> {
    use crate::baselines::{Fitc, Meka, MekaConfig, Pitc, Sor};
    use crate::gp::full::FullGp;
    use crate::gp::mka_gp::MkaGp;
    use crate::kernels::RbfKernel;
    let kern = RbfKernel::new(hp.lengthscale);
    let s2 = hp.sigma2;
    Ok(match method {
        Method::Full => Box::new(FullGp::fit(data, &kern, s2)?),
        Method::Sor => Box::new(Sor::fit(data, &kern, s2, k, seed)?),
        Method::Fitc => Box::new(Fitc::fit(data, &kern, s2, k, seed)?),
        Method::Pitc => {
            let block = (data.n() / 10).clamp(k.max(8), 200);
            Box::new(Pitc::fit(data, &kern, s2, k, block, seed)?)
        }
        Method::Meka => {
            let cfg = MekaConfig { rank: k, n_clusters: (k / 8).clamp(2, 8), sample_frac: 0.7, seed };
            Box::new(Meka::fit(data, &kern, s2, &cfg)?)
        }
        Method::Mka => {
            let cfg = crate::experiments::methods::mka_config_for(k, data.n(), seed);
            Box::new(MkaGp::fit(data, &kern, s2, &cfg)?)
        }
    })
}

/// Parse [[f64...]...] into a Mat.
pub fn parse_matrix(v: &Json) -> Result<Mat> {
    let rows = v.as_arr().ok_or_else(|| Error::Protocol("matrix must be an array".into()))?;
    if rows.is_empty() {
        return Err(Error::Protocol("matrix is empty".into()));
    }
    let parsed: Option<Vec<Vec<f64>>> = rows.iter().map(|r| r.f64_array()).collect();
    let parsed = parsed.ok_or_else(|| Error::Protocol("matrix rows must be numeric".into()))?;
    let cols = parsed[0].len();
    if cols == 0 || parsed.iter().any(|r| r.len() != cols) {
        return Err(Error::Protocol("ragged matrix".into()));
    }
    let mut m = Mat::zeros(parsed.len(), cols);
    for (i, row) in parsed.iter().enumerate() {
        m.row_mut(i).copy_from_slice(row);
    }
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gp_dataset, SynthSpec};

    fn router() -> Router {
        let cfg = ServiceConfig { batch_window_ms: 0, n_workers: 2, ..Default::default() };
        Router::new(cfg)
    }

    fn fit_req(model: &str, method: &str, n: usize, is_async: bool) -> Json {
        let data = gp_dataset(&SynthSpec::named("t", n, 2), 1);
        let x: Vec<Json> =
            (0..n).map(|i| Json::from_f64_slice(data.x.row(i))).collect();
        Json::obj()
            .with("op", Json::Str("fit".into()))
            .with("model", Json::Str(model.into()))
            .with("method", Json::Str(method.into()))
            .with("x", Json::Arr(x))
            .with("y", Json::from_f64_slice(&data.y))
            .with(
                "params",
                Json::obj()
                    .with("lengthscale", Json::Num(1.0))
                    .with("sigma2", Json::Num(0.1))
                    .with("k", Json::Num(8.0)),
            )
            .with("async", Json::Bool(is_async))
    }

    #[test]
    fn ping() {
        let r = router();
        let out = r.handle(&Json::parse(r#"{"op":"ping"}"#).unwrap());
        assert_eq!(out.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(out.get("pong"), Some(&Json::Bool(true)));
    }

    #[test]
    fn unknown_op_is_error() {
        let r = router();
        let out = r.handle(&Json::parse(r#"{"op":"nope"}"#).unwrap());
        assert_eq!(out.get("ok"), Some(&Json::Bool(false)));
        assert!(r.metrics.counter("errors") >= 1);
    }

    #[test]
    fn sync_fit_then_predict() {
        let r = router();
        let out = r.handle(&fit_req("m1", "sor", 60, false));
        assert_eq!(out.get("ok"), Some(&Json::Bool(true)), "{out:?}");
        assert_eq!(r.registry.names(), vec!["m1".to_string()]);

        let pred_req = Json::obj()
            .with("op", Json::Str("predict".into()))
            .with("model", Json::Str("m1".into()))
            .with(
                "x",
                Json::Arr(vec![Json::from_f64_slice(&[0.1, -0.2]), Json::from_f64_slice(&[1.0, 1.0])]),
            );
        let out = r.handle(&pred_req);
        assert_eq!(out.get("ok"), Some(&Json::Bool(true)), "{out:?}");
        assert_eq!(out.get("mean").unwrap().f64_array().unwrap().len(), 2);
        assert_eq!(out.get("var").unwrap().f64_array().unwrap().len(), 2);
    }

    #[test]
    fn async_fit_completes() {
        let r = router();
        let out = r.handle(&fit_req("m2", "mka", 80, true));
        assert_eq!(out.get("ok"), Some(&Json::Bool(true)), "{out:?}");
        let job_id = out.usize_field("job_id").unwrap() as u64;
        // Poll until done (bounded).
        for _ in 0..200 {
            if let Some((_, state)) = r.jobs.get(job_id) {
                match state {
                    JobState::Done { .. } => break,
                    JobState::Failed { error } => panic!("fit failed: {error}"),
                    _ => {}
                }
            }
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
        assert!(matches!(r.jobs.get(job_id).unwrap().1, JobState::Done { .. }));
        assert!(r.registry.get("m2").is_some());
    }

    #[test]
    fn fit_validation_errors() {
        let r = router();
        let bad = Json::parse(r#"{"op":"fit","model":"m","method":"mka","x":[[1,2]],"y":[1,2]}"#)
            .unwrap();
        let out = r.handle(&bad);
        assert_eq!(out.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn parse_matrix_validation() {
        assert!(parse_matrix(&Json::parse("[[1,2],[3,4]]").unwrap()).is_ok());
        assert!(parse_matrix(&Json::parse("[]").unwrap()).is_err());
        assert!(parse_matrix(&Json::parse("[[1,2],[3]]").unwrap()).is_err());
        assert!(parse_matrix(&Json::parse(r#"[["a"]]"#).unwrap()).is_err());
    }

    #[test]
    fn predict_unknown_model() {
        let r = router();
        let req = Json::parse(r#"{"op":"predict","model":"ghost","x":[[1.0]]}"#).unwrap();
        let out = r.handle(&req);
        assert_eq!(out.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn metrics_and_config_ops() {
        let r = router();
        let m = r.handle(&Json::parse(r#"{"op":"metrics"}"#).unwrap());
        assert!(m.get("counters").is_some());
        let c = r.handle(&Json::parse(r#"{"op":"config"}"#).unwrap());
        assert_eq!(c.usize_field("port"), Some(7470));
    }

    #[test]
    fn metrics_surface_compute_plane() {
        let r = router();
        // Serve one prediction so at least one cascade has run.
        let out = r.handle(&fit_req("mc", "mka", 60, false));
        assert_eq!(out.get("ok"), Some(&Json::Bool(true)), "{out:?}");
        let pred = Json::obj()
            .with("op", Json::Str("predict".into()))
            .with("model", Json::Str("mc".into()))
            .with("x", Json::Arr(vec![Json::from_f64_slice(&[0.0, 0.0])]));
        assert_eq!(r.handle(&pred).get("ok"), Some(&Json::Bool(true)));
        let m = r.handle(&Json::parse(r#"{"op":"metrics"}"#).unwrap());
        let compute = m.get("compute").expect("compute section present");
        assert!(compute.num_field("cascades").unwrap_or(0.0) >= 1.0);
        assert!(compute.num_field("pool_threads").unwrap_or(0.0) >= 1.0);
        assert!(compute.num_field("pool_jobs").is_some());
        assert!(compute.num_field("pool_workers").is_some());
    }
}
