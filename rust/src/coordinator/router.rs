//! Request router: dispatches protocol ops (JSON objects) to the fitting
//! pool, the model registry and the prediction batcher.
//!
//! Protocol (one JSON object per request):
//!   {"op": "ping"}
//!   {"op": "fit", "model": "m1", "method": "mka", "x": [[...]...],
//!    "y": [...], "params": {"lengthscale": 1.0, "sigma2": 0.1, "k": 32},
//!    "shards": 4, "batch_window_ms": 0, "async": true}
//!                                    — "shards" > 1 (MKA only; default
//!                                      from `ServiceConfig.default_shards`)
//!                                      partitions the training rows and
//!                                      serves a routed ShardedGp fleet;
//!                                      "batch_window_ms" installs a
//!                                      per-model batching window
//!                                      (omitting it reverts to the
//!                                      service default)
//!   {"op": "train", "model": "m1", "method": "mka", "x": [[...]...],
//!    "y": [...], "selection": "mll"|"mll-grad"|"cv", "ard": false,
//!    "budget": {"max_evals": 60, "n_starts": 3, "tol": 1e-5, "folds": 5},
//!    "params": {"k": 32}}            — async by default: returns a job id,
//!                                      learns (lengthscale, σ²) — or one
//!                                      length scale per dimension with
//!                                      "selection": "mll-grad", "ard": true
//!                                      (L-BFGS on analytic gradients) —
//!                                      and publishes the fitted model on
//!                                      completion
//!   {"op": "job", "job_id": 1}       — train jobs carry the eval trace
//!   {"op": "predict", "model": "m1", "x": [[...]...]}
//!   {"op": "retune", "model": "m1", "sigma2": 0.05}
//!                                    — republish at a new noise level via
//!                                      the σ² spectrum shift (no refit
//!                                      job, no refactorization)
//!   {"op": "models"} | {"op": "drop_model", "model": "m1"}
//!   {"op": "metrics"} | {"op": "config"}
//!   {"op": "trace", "tail": 8}       — last-N finished request traces
//!                                      from the bounded ring; any request
//!                                      with `"trace": true` echoes its
//!                                      own span tree inline
//!   {"op": "logs", "level": "warn", "tail": 50}
//!                                    — structured event log (bounded ring)
//!   {"op": "diagnose", "model": "m1"}
//!                                    — numerical health from held factor
//!                                      state (per-stage compression,
//!                                      shifted-spectrum condition, route
//!                                      shares); never refactorizes
//!   {"op": "observe", "model": "m1", "x": [[...]...], "y": [...],
//!    "drift_threshold": 16.0, "max_core_growth": 4.0, "window": 0}
//!                                    — streaming append: extend the
//!                                      stored factorization incrementally
//!                                      (untouched stages shared, not
//!                                      rebuilt) unless a drift or
//!                                      core-growth gate forces a windowed
//!                                      full re-fit; gate knobs default
//!                                      from the service config
//!   {"op": "refresh", "model": "m1", "every_ms": 60000}
//!                                    — recurring background re-fit jobs
//!                                      through the job store; "every_ms"
//!                                      0 cancels, omitting "model" lists
//!                                      the registered policies

use std::sync::Arc;
use std::time::Duration;

use super::batcher::PredictBatcher;
use super::config::ServiceConfig;
use super::jobs::{JobState, JobStore, ModelRegistry};
use super::metrics::Metrics;
use super::pool::WorkerPool;
use super::refresh::RefreshScheduler;
use crate::cluster::ClusterMethod;
use crate::data::dataset::Dataset;
use crate::error::{Error, Result};
use crate::experiments::methods::Method;
use crate::gp::cv::HyperParams;
use crate::la::dense::Mat;
use crate::train::{ModelSelection, OptimBudget, TrainReport};
use crate::util::json::Json;
use crate::util::timer::Timer;

/// Shared model constructor (moved to the training plane; re-exported
/// here for the CLI and existing callers).
pub use crate::train::trainer::fit_model;

/// Every op [`Router::handle`] dispatches, in protocol-reference order.
/// Kept adjacent to the dispatch match — extend BOTH when adding an op.
/// The docs round-trip test (`rust/tests/protocol_docs.rs`) requires
/// every entry here to be documented in `docs/PROTOCOL.md`, and the
/// unknown-op error below advertises this list, so a new match arm
/// without an `OPS` entry is visible immediately.
pub const OPS: &[&str] = &[
    "ping",
    "fit",
    "train",
    "job",
    "predict",
    "retune",
    "models",
    "drop_model",
    "metrics",
    "config",
    "trace",
    "logs",
    "diagnose",
    "observe",
    "refresh",
];

/// Shared coordinator state + dispatch.
pub struct Router {
    pub config: ServiceConfig,
    pub metrics: Arc<Metrics>,
    pub registry: ModelRegistry,
    pub jobs: Arc<JobStore>,
    pub refresh: RefreshScheduler,
    pool: Arc<WorkerPool>,
    batcher: PredictBatcher,
}

impl Router {
    pub fn new(config: ServiceConfig) -> Router {
        // Size the shared compute pool from the service config so fits
        // and batched predicts saturate the configured parallelism.
        crate::par::set_threads(config.resolved_threads());
        // Size the per-training-run factor cache (σ²-independent factor
        // builds memoized per length scale).
        crate::train::cache::set_default_capacity(config.train_cache_factors);
        // Size the per-model joint-factor cache on the predict path
        // (noise-free joint factors keyed by model + test-set identity).
        crate::gp::predict_cache::set_default_capacity(config.predict_cache_entries);
        // Observability plane: ring capacities, and the Chrome trace-event
        // sink (which implies trace-all — a sink with nothing flowing into
        // it would be a confusing no-op).
        crate::obs::set_trace_capacity(config.trace_ring);
        crate::obs::set_log_capacity(config.log_ring);
        if let Some(path) = &config.trace_out {
            match crate::obs::set_trace_out(path) {
                Ok(()) => crate::obs::set_trace_all(true),
                Err(e) => crate::obs::log!(
                    Warn,
                    "coordinator.router",
                    { "path" => path.display() },
                    "cannot open trace-out sink: {e}"
                ),
            }
        }
        let metrics = Arc::new(Metrics::new());
        let registry = ModelRegistry::new();
        let batcher = PredictBatcher::start(
            registry.clone(),
            Arc::clone(&metrics),
            Duration::from_millis(config.batch_window_ms),
            config.max_batch,
            config.batch_queue_max,
        );
        let pool = Arc::new(WorkerPool::new(config.n_workers));
        let jobs = Arc::new(JobStore::new());
        // Recurring re-fit jobs ride the same job store + worker pool as
        // async fits, so `job` polling and panic containment are shared.
        let refresh = RefreshScheduler::new(
            registry.clone(),
            Arc::clone(&jobs),
            Arc::clone(&pool),
            Arc::clone(&metrics),
            config.refresh_min_interval_ms,
        );
        Router { config, metrics, registry, jobs, refresh, pool, batcher }
    }

    /// Handle one request; never panics — protocol errors become
    /// `{"ok": false, "error": ...}`.
    pub fn handle(&self, req: &Json) -> Json {
        self.metrics.incr("requests", 1);
        let op = req.str_field("op").unwrap_or("");
        // Request-scoped tracing: `"trace": true` on any request (or the
        // global trace-all switch from `--trace-out`, opt-out with
        // `"trace": false`). The ring-reading introspection ops never
        // trace themselves — inspecting the ring must not grow it.
        let introspective = matches!(op, "trace" | "logs");
        let want_trace = !introspective
            && req.get("trace").and_then(|v| v.as_bool()).unwrap_or_else(crate::obs::trace_all);
        let trace_guard = want_trace.then(|| crate::obs::start_request(&format!("op.{op}")));
        // Per-op latency histograms for the serving verbs (successful
        // requests only — validation failures would drag p50 toward 0).
        let timed = matches!(op, "fit" | "train" | "predict" | "retune" | "observe");
        let op_timer = Timer::start();
        let out = match op {
            "ping" => Ok(Json::obj().with("pong", Json::Bool(true))),
            "fit" => self.handle_fit(req),
            "train" => self.handle_train(req),
            "job" => self.handle_job(req),
            "predict" => self.handle_predict(req),
            "retune" => self.handle_retune(req),
            "models" => {
                // Per-model metadata, not bare names: method, training
                // size, noise level and shard topology per entry.
                let models: Vec<Json> = self
                    .registry
                    .entries()
                    .into_iter()
                    .map(|(name, m)| {
                        let info = m.info();
                        let mut j = Json::obj()
                            .with("name", Json::Str(name))
                            .with("method", Json::Str(info.method))
                            .with("n", Json::Num(info.n as f64))
                            .with("dim", Json::Num(info.dim as f64))
                            .with("shards", Json::Num(info.shards as f64));
                        if let Some(s2) = info.sigma2 {
                            j.set("sigma2", Json::Num(s2));
                        }
                        if !info.shard_sizes.is_empty() {
                            j.set(
                                "shard_sizes",
                                Json::Arr(
                                    info.shard_sizes
                                        .iter()
                                        .map(|&s| Json::Num(s as f64))
                                        .collect(),
                                ),
                            );
                        }
                        j
                    })
                    .collect();
                Ok(Json::obj().with("models", Json::Arr(models)))
            }
            "drop_model" => {
                let name = req.str_field("model").unwrap_or("");
                // A dropped model's batching-window override must not
                // leak onto a future model fit under the same name.
                self.batcher.clear_model_window(name);
                Ok(Json::obj().with("dropped", Json::Bool(self.registry.remove(name))))
            }
            "metrics" => {
                // Registry counters/histograms plus the compute-plane
                // observables: logical cascade count, full factorization
                // count, factor-cache traffic and pool utilization.
                let mut snap = self.metrics.snapshot();
                snap.set(
                    "compute",
                    Json::obj()
                        .with("cascades", Json::Num(crate::mka::cascade_count() as f64))
                        .with("factorizes", Json::Num(crate::mka::factorize_count() as f64))
                        .with(
                            "factor_cache_hits",
                            Json::Num(crate::train::cache::factor_cache_hits() as f64),
                        )
                        .with(
                            "factor_cache_misses",
                            Json::Num(crate::train::cache::factor_cache_misses() as f64),
                        )
                        .with(
                            "predict_cache_hits",
                            Json::Num(crate::gp::predict_cache::predict_cache_hits() as f64),
                        )
                        .with(
                            "predict_cache_misses",
                            Json::Num(crate::gp::predict_cache::predict_cache_misses() as f64),
                        )
                        .with(
                            "predict_cache_evictions",
                            Json::Num(crate::gp::predict_cache::predict_cache_evictions() as f64),
                        )
                        .with("pool_threads", Json::Num(crate::par::threads() as f64))
                        .with("pool_workers", Json::Num(crate::par::pool_workers() as f64))
                        .with("pool_jobs", Json::Num(crate::par::jobs_executed() as f64))
                        .with(
                            "arena_checkouts",
                            Json::Num(crate::par::arena::checkouts() as f64),
                        )
                        .with("arena_grows", Json::Num(crate::par::arena::grows() as f64))
                        .with(
                            "arena_grow_bytes",
                            Json::Num(crate::par::arena::grow_bytes() as f64),
                        )
                        .with(
                            "simd_level",
                            Json::Str(format!("{:?}", crate::la::simd_level())),
                        )
                        .with(
                            "stage_rebuilds",
                            Json::Num(crate::mka::stage_rebuild_count() as f64),
                        )
                        .with(
                            "stage_reuses",
                            Json::Num(crate::mka::stage_reuse_count() as f64),
                        ),
                );
                // Shard topology across the registry: fleet count, total
                // shard count, per-shard sizes, and the process-wide
                // expert-consult counter from the routing layer.
                let mut fleet_models = 0u64;
                let mut shard_count = 0u64;
                let mut sizes: Vec<Json> = Vec::new();
                for (_, m) in self.registry.entries() {
                    let info = m.info();
                    if info.shards > 1 {
                        fleet_models += 1;
                        shard_count += info.shards as u64;
                        sizes.extend(info.shard_sizes.iter().map(|&s| Json::Num(s as f64)));
                    }
                }
                snap.set(
                    "shard",
                    Json::obj()
                        .with("models", Json::Num(fleet_models as f64))
                        .with("count", Json::Num(shard_count as f64))
                        .with("sizes", Json::Arr(sizes))
                        .with(
                            "route_hits",
                            Json::Num(crate::gp::sharded::route_hits() as f64),
                        ),
                );
                Ok(snap)
            }
            "config" => Ok(self.config.to_json()),
            "trace" => self.handle_trace(req),
            "logs" => self.handle_logs(req),
            "diagnose" => self.handle_diagnose(req),
            "observe" => self.handle_observe(req),
            "refresh" => self.handle_refresh(req),
            other => Err(Error::Protocol(format!("unknown op {other:?} (supported: {OPS:?})"))),
        };
        match out {
            Ok(mut j) => {
                if timed {
                    self.metrics.observe(&format!("op.{op}_secs"), op_timer.elapsed_secs());
                }
                // Echo the finished span tree on a traced request. (On the
                // error path below the guard just drops: the trace still
                // lands on the ring and the Chrome sink for the `trace`
                // op, it is not echoed.)
                if let Some(g) = trace_guard {
                    j.set("trace", crate::obs::trace_tree_json(&g.finish()));
                }
                j.set("ok", Json::Bool(true));
                j
            }
            Err(e) => {
                // Typed backpressure: a Busy rejection is shed load, not
                // a failure — it carries "busy": true for clients to back
                // off on, counts into `predict_rejected` (batcher side)
                // and stays OUT of the `errors` counter operators alert
                // on.
                let busy = matches!(e, Error::Busy(_));
                if !busy {
                    self.metrics.incr("errors", 1);
                }
                let mut j = Json::obj()
                    .with("ok", Json::Bool(false))
                    .with("error", Json::Str(format!("{e}")));
                if busy {
                    j.set("busy", Json::Bool(true));
                    // Depth-aware backoff hint: clearing the backlog takes
                    // ceil(depth / max_batch) flush rounds of roughly the
                    // observed batch-predict p50 each, floored by one
                    // batching window. Before any predict has completed
                    // (no p50 yet) the window alone is the hint.
                    let depth = self.batcher.queue_depth();
                    let max_batch = self.config.max_batch.max(1);
                    let rounds = ((depth + max_batch - 1) / max_batch) as f64;
                    let p50 = self.metrics.quantile("predict_secs", 0.5).unwrap_or(0.0);
                    let retry = (rounds * p50 * 1000.0)
                        .ceil()
                        .max(self.config.batch_window_ms as f64)
                        .max(1.0);
                    j.set("retry_after_ms", Json::Num(retry));
                    j.set("depth", Json::Num(depth as f64));
                }
                j
            }
        }
    }

    /// Parse the top-level `"shards"` field (default from the service
    /// config) and enforce the sharded plane's method constraint.
    fn parse_shards(&self, req: &Json, op: &str, method: Method) -> Result<usize> {
        let shards = match req.get("shards") {
            Some(v) => v.as_usize().ok_or_else(|| {
                Error::Protocol(format!("{op}: shards must be a non-negative integer"))
            })?,
            None => self.config.default_shards,
        };
        if shards == 0 {
            return Err(Error::Protocol(format!("{op}: shards must be >= 1")));
        }
        if shards > 1 && method != Method::Mka {
            return Err(Error::Protocol(format!(
                "{op}: shards > 1 requires method \"mka\" (got {})",
                method.label()
            )));
        }
        Ok(shards)
    }

    fn handle_fit(&self, req: &Json) -> Result<Json> {
        let name = req
            .str_field("model")
            .ok_or_else(|| Error::Protocol("fit: missing model".into()))?
            .to_string();
        let method = Method::parse(req.str_field("method").unwrap_or("mka"))
            .ok_or_else(|| Error::Protocol("fit: unknown method".into()))?;
        let x = parse_matrix(req.get("x").ok_or_else(|| Error::Protocol("fit: missing x".into()))?)?;
        let y = req
            .get("y")
            .and_then(|v| v.f64_array())
            .ok_or_else(|| Error::Protocol("fit: missing y".into()))?;
        if x.rows != y.len() || x.rows == 0 {
            return Err(Error::Protocol("fit: x/y shape mismatch".into()));
        }
        let data = Dataset::new(name.clone(), x, y);
        let params = req.get("params");
        let hp = HyperParams {
            lengthscale: params.and_then(|p| p.num_field("lengthscale")).unwrap_or(1.0),
            sigma2: params.and_then(|p| p.num_field("sigma2")).unwrap_or(0.1),
        };
        let k = params.and_then(|p| p.usize_field("k")).unwrap_or(self.config.d_core);
        let seed = self.config.seed;
        let shards = self.parse_shards(req, "fit", method)?;
        let assign = self.config.shard_assign_method();
        let is_async = req.get("async").and_then(|v| v.as_bool()).unwrap_or(false);

        // Per-model batching window: registered against the name as soon
        // as the fit is accepted (an async fit's predicts queue behind
        // the publish anyway), omitted field reverts a re-fit to the
        // service default.
        match req.get("batch_window_ms") {
            Some(v) => {
                let ms = v.as_usize().ok_or_else(|| {
                    Error::Protocol("fit: batch_window_ms must be a non-negative integer".into())
                })? as u64;
                self.batcher.set_model_window(&name, Duration::from_millis(ms));
            }
            None => self.batcher.clear_model_window(&name),
        }

        if is_async {
            let job_id = self.jobs.create(&name);
            let jobs = Arc::clone(&self.jobs);
            let registry = self.registry.clone();
            let metrics = Arc::clone(&self.metrics);
            let submitted = self.pool.submit(move || {
                jobs.set_state(job_id, JobState::Running);
                let t = Timer::start();
                // A panicking fit must not kill the worker thread (the
                // pool would shrink forever) or strand the job in
                // Running: contain it and fail the job instead.
                let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    fit_op_model(method, &data, hp, k, seed, shards, assign, &metrics)
                }));
                match outcome {
                    Ok(Ok(model)) => {
                        registry.publish(&name, model.into());
                        metrics.incr("fits", 1);
                        jobs.set_state(job_id, JobState::Done { fit_secs: t.elapsed_secs() });
                    }
                    Ok(Err(e)) => {
                        metrics.incr("fit_errors", 1);
                        jobs.set_state(job_id, JobState::Failed { error: format!("{e}") });
                    }
                    Err(p) => {
                        metrics.incr("fit_errors", 1);
                        jobs.set_state(job_id, JobState::Failed { error: panic_label(p) });
                    }
                }
            });
            if !submitted {
                return Err(Error::Coordinator("worker pool unavailable".into()));
            }
            Ok(Json::obj().with("job_id", Json::Num(job_id as f64)))
        } else {
            let t = Timer::start();
            let model = fit_op_model(method, &data, hp, k, seed, shards, assign, &self.metrics)?;
            let info = model.info();
            self.registry.publish(&name, model.into());
            self.metrics.incr("fits", 1);
            let mut out = Json::obj()
                .with("model", Json::Str(name))
                .with("fit_secs", Json::Num(t.elapsed_secs()));
            if info.shards > 1 {
                out.set("shards", Json::Num(info.shards as f64));
            }
            Ok(out)
        }
    }

    /// Hyperparameter learning as a served workload: parse the dataset,
    /// run `train_model` (MLL maximization or grid CV) on the worker
    /// pool, publish the optimized model under `model` on completion.
    /// Async by default — the response carries a job id immediately and
    /// the `job` op reports Queued → Running → Done with the eval trace.
    fn handle_train(&self, req: &Json) -> Result<Json> {
        let name = req
            .str_field("model")
            .ok_or_else(|| Error::Protocol("train: missing model".into()))?
            .to_string();
        let method = Method::parse(req.str_field("method").unwrap_or("mka"))
            .ok_or_else(|| Error::Protocol("train: unknown method".into()))?;
        let x =
            parse_matrix(req.get("x").ok_or_else(|| Error::Protocol("train: missing x".into()))?)?;
        let y = req
            .get("y")
            .and_then(|v| v.f64_array())
            .ok_or_else(|| Error::Protocol("train: missing y".into()))?;
        if x.rows != y.len() || x.rows == 0 {
            return Err(Error::Protocol("train: x/y shape mismatch".into()));
        }
        let data = Dataset::new(name.clone(), x, y);
        let k = req.get("params").and_then(|p| p.usize_field("k")).unwrap_or(self.config.d_core);
        let seed = self.config.seed;
        let budget_j = req.get("budget");
        let budget = OptimBudget {
            max_evals: budget_j
                .and_then(|b| b.usize_field("max_evals"))
                .unwrap_or(self.config.train_max_evals),
            n_starts: budget_j
                .and_then(|b| b.usize_field("n_starts"))
                .unwrap_or(self.config.train_starts),
            tol: budget_j.and_then(|b| b.num_field("tol")).unwrap_or(1e-5),
        };
        let folds = budget_j.and_then(|b| b.usize_field("folds")).unwrap_or(5);
        let sel_name = req.str_field("selection").unwrap_or("mll");
        let ard = req.get("ard").and_then(|v| v.as_bool()).unwrap_or(false);
        let selection = ModelSelection::parse(sel_name, folds, budget, ard).ok_or_else(|| {
            // Distinguish the two parse failures: a name that is simply
            // unknown vs a known non-gradient name combined with ard.
            if ard && ModelSelection::parse(sel_name, folds, budget, false).is_some() {
                Error::Protocol(
                    "train: \"ard\": true requires the gradient path (\"selection\": \"mll-grad\")"
                        .into(),
                )
            } else {
                Error::Protocol(format!("train: unknown selection {sel_name:?}"))
            }
        })?;
        let shards = self.parse_shards(req, "train", method)?;
        let assign = self.config.shard_assign_method();
        let is_async = req.get("async").and_then(|v| v.as_bool()).unwrap_or(true);

        if is_async {
            let job_id = self.jobs.create(&name);
            let jobs = Arc::clone(&self.jobs);
            let registry = self.registry.clone();
            let metrics = Arc::clone(&self.metrics);
            let submitted = self.pool.submit(move || {
                jobs.set_state(job_id, JobState::Running);
                // Same panic containment as the fit path: the par pool
                // re-throws task panics on the submitter by design, and
                // a dead worker + Running-forever job would wedge every
                // poller of this job id.
                let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    crate::train::train_model_sharded(
                        method, &data, &selection, k, seed, shards, assign,
                    )
                }));
                match outcome {
                    Ok(Ok((model, report))) => {
                        registry.publish(&name, model.into());
                        record_train_metrics(&metrics, &report);
                        let secs = report.train_secs;
                        // Detail before the terminal state: a poller that
                        // sees `done` must also see the trace.
                        jobs.set_detail(job_id, Json::obj().with("train", report.to_json()));
                        jobs.set_state(job_id, JobState::Done { fit_secs: secs });
                    }
                    Ok(Err(e)) => {
                        metrics.incr("train_errors", 1);
                        jobs.set_state(job_id, JobState::Failed { error: format!("{e}") });
                    }
                    Err(p) => {
                        metrics.incr("train_errors", 1);
                        jobs.set_state(job_id, JobState::Failed { error: panic_label(p) });
                    }
                }
            });
            if !submitted {
                return Err(Error::Coordinator("worker pool unavailable".into()));
            }
            Ok(Json::obj().with("job_id", Json::Num(job_id as f64)))
        } else {
            let (model, report) = crate::train::train_model_sharded(
                method, &data, &selection, k, seed, shards, assign,
            )?;
            self.registry.publish(&name, model.into());
            record_train_metrics(&self.metrics, &report);
            Ok(Json::obj().with("model", Json::Str(name)).with("train", report.to_json()))
        }
    }

    fn handle_job(&self, req: &Json) -> Result<Json> {
        let id = req
            .get("job_id")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| Error::Protocol("job: missing job_id".into()))? as u64;
        Ok(self.jobs.to_json(id))
    }

    fn handle_predict(&self, req: &Json) -> Result<Json> {
        let name = req
            .str_field("model")
            .ok_or_else(|| Error::Protocol("predict: missing model".into()))?;
        let x =
            parse_matrix(req.get("x").ok_or_else(|| Error::Protocol("predict: missing x".into()))?)?;
        let pred = self.batcher.predict(name, x)?;
        Ok(Json::obj()
            .with("mean", Json::from_f64_slice(&pred.mean))
            .with("var", Json::from_f64_slice(&pred.var)))
    }

    /// Republish a registry model at a new noise level σ² — a spectrum
    /// re-tune through [`crate::gp::GpModel::with_noise`], not a refit
    /// job: for MKA the stored factorization's rotations are shared and
    /// only the shift changes, so this is O(1) work and synchronous.
    /// Models whose method cannot re-tune noise cheaply answer with a
    /// protocol error directing the caller to `fit`/`train`.
    fn handle_retune(&self, req: &Json) -> Result<Json> {
        let name = req
            .str_field("model")
            .ok_or_else(|| Error::Protocol("retune: missing model".into()))?;
        let sigma2 = req
            .num_field("sigma2")
            .ok_or_else(|| Error::Protocol("retune: missing sigma2".into()))?;
        if !(sigma2.is_finite() && sigma2 > 0.0) {
            return Err(Error::Protocol(format!(
                "retune: sigma2 must be finite and > 0, got {sigma2}"
            )));
        }
        let model = self
            .registry
            .get(name)
            .ok_or_else(|| Error::Coordinator(format!("no model {name}")))?;
        let t = Timer::start();
        let retuned = model.with_noise(sigma2).ok_or_else(|| {
            Error::Protocol(format!(
                "retune: model {name:?} ({}) does not support noise re-tuning; \
                 use fit/train to rebuild it at the new sigma2",
                model.name()
            ))
        })?;
        // A sharded model re-tunes every shard's spectrum in one pass —
        // O(shards) total; record the fleet shift in its own histogram.
        if retuned.info().shards > 1 {
            self.metrics.observe("shard.retune_secs", t.elapsed_secs());
        }
        self.registry.publish(name, retuned.into());
        self.metrics.incr("retunes", 1);
        self.metrics.observe("retune_secs", t.elapsed_secs());
        Ok(Json::obj()
            .with("model", Json::Str(name.to_string()))
            .with("sigma2", Json::Num(sigma2)))
    }

    /// Last-N finished request traces (newest last) from the bounded ring.
    fn handle_trace(&self, req: &Json) -> Result<Json> {
        let tail = match req.get("tail") {
            Some(v) => v.as_usize().ok_or_else(|| {
                Error::Protocol("trace: tail must be a non-negative integer".into())
            })?,
            None => 8,
        };
        let traces: Vec<Json> =
            crate::obs::recent_traces(tail).iter().map(|t| crate::obs::trace_tree_json(t)).collect();
        Ok(Json::obj()
            .with("traces", Json::Arr(traces))
            .with("ring_capacity", Json::Num(crate::obs::trace_capacity() as f64)))
    }

    /// Tail of the structured event log at (or above) a severity level.
    fn handle_logs(&self, req: &Json) -> Result<Json> {
        let min = match req.str_field("level") {
            Some(s) => crate::obs::Level::parse(s).ok_or_else(|| {
                Error::Protocol(format!("logs: unknown level {s:?} (debug | info | warn | error)"))
            })?,
            None => crate::obs::Level::Debug,
        };
        let tail = match req.get("tail") {
            Some(v) => v.as_usize().ok_or_else(|| {
                Error::Protocol("logs: tail must be a non-negative integer".into())
            })?,
            None => 50,
        };
        let events: Vec<Json> =
            crate::obs::recent_events(min, tail).iter().map(crate::obs::event_json).collect();
        Ok(Json::obj()
            .with("events", Json::Arr(events))
            .with("level", Json::Str(min.as_str().into()))
            .with("ring_capacity", Json::Num(crate::obs::log_capacity() as f64)))
    }

    /// Numerical-health report for a registry model, strictly from state
    /// the model already holds ([`crate::gp::GpModel::diagnose`] —
    /// guaranteed to never fit or refactorize anything).
    fn handle_diagnose(&self, req: &Json) -> Result<Json> {
        let name = req
            .str_field("model")
            .ok_or_else(|| Error::Protocol("diagnose: missing model".into()))?;
        let model = self
            .registry
            .get(name)
            .ok_or_else(|| Error::Coordinator(format!("no model {name}")))?;
        let diag = model.diagnose().ok_or_else(|| {
            Error::Protocol(format!(
                "diagnose: model {name:?} ({}) reports no diagnostics; \
                 MKA and sharded-MKA models do",
                model.name()
            ))
        })?;
        Ok(Json::obj().with("model", Json::Str(name.to_string())).with("diagnose", diag))
    }

    /// Streaming append through [`crate::gp::GpModel::observe`]: the
    /// model extends its stored factorization with the batch (untouched
    /// stages Arc-shared, not rebuilt) unless a drift or core-growth
    /// gate forces a windowed full re-fit; either way the updated model
    /// is republished atomically and the response reports which path was
    /// taken with stage-reuse accounting. Gate knobs default from the
    /// service config and can be overridden per request.
    fn handle_observe(&self, req: &Json) -> Result<Json> {
        let name = req
            .str_field("model")
            .ok_or_else(|| Error::Protocol("observe: missing model".into()))?;
        let x = parse_matrix(
            req.get("x").ok_or_else(|| Error::Protocol("observe: missing x".into()))?,
        )?;
        let y = req
            .get("y")
            .and_then(|v| v.f64_array())
            .ok_or_else(|| Error::Protocol("observe: missing y".into()))?;
        if x.rows != y.len() || x.rows == 0 {
            return Err(Error::Protocol("observe: x/y shape mismatch".into()));
        }
        let mut policy = self.config.observe_policy();
        if let Some(v) = req.get("drift_threshold") {
            policy.drift_threshold = v.as_f64().ok_or_else(|| {
                Error::Protocol("observe: drift_threshold must be a number".into())
            })?;
        }
        if let Some(v) = req.get("max_core_growth") {
            policy.max_core_growth = v.as_f64().ok_or_else(|| {
                Error::Protocol("observe: max_core_growth must be a number".into())
            })?;
        }
        if let Some(v) = req.get("window") {
            policy.window = v.as_usize().ok_or_else(|| {
                Error::Protocol("observe: window must be a non-negative integer".into())
            })?;
        }
        policy.validate().map_err(|e| Error::Protocol(format!("{e}")))?;
        let model = self
            .registry
            .get(name)
            .ok_or_else(|| Error::Coordinator(format!("no model {name}")))?;
        let t = Timer::start();
        let update = model.observe(&x, &y, &policy).ok_or_else(|| {
            Error::Protocol(format!(
                "observe: model {name:?} ({}) does not support streaming observation; \
                 use fit/train to rebuild it with the new points",
                model.name()
            ))
        })??;
        self.registry.publish(name, update.model.into());
        self.metrics.incr("observes", 1);
        self.metrics.observe("observe.appended", x.rows as f64);
        if update.report.str_field("path") == Some("refit") {
            self.metrics.incr("observe_refits", 1);
        }
        Ok(Json::obj()
            .with("model", Json::Str(name.to_string()))
            .with("observe", update.report)
            .with("observe_secs", Json::Num(t.elapsed_secs())))
    }

    /// Refresh-policy management for the background scheduler: with
    /// `"model"` and a positive `"every_ms"` registers (or replaces) a
    /// recurring re-fit, `"every_ms": 0` cancels, and a bare request
    /// lists the registered policies.
    fn handle_refresh(&self, req: &Json) -> Result<Json> {
        let Some(name) = req.str_field("model") else {
            if req.get("every_ms").is_some() {
                return Err(Error::Protocol("refresh: missing model".into()));
            }
            return Ok(Json::obj().with("policies", self.refresh.policies_json()));
        };
        let every_ms = req
            .get("every_ms")
            .ok_or_else(|| Error::Protocol("refresh: missing every_ms (0 cancels)".into()))?
            .as_usize()
            .ok_or_else(|| {
                Error::Protocol("refresh: every_ms must be a non-negative integer".into())
            })? as u64;
        if every_ms == 0 {
            let cancelled = self.refresh.cancel(name);
            return Ok(Json::obj()
                .with("model", Json::Str(name.to_string()))
                .with("cancelled", Json::Bool(cancelled)));
        }
        let model = self
            .registry
            .get(name)
            .ok_or_else(|| Error::Coordinator(format!("no model {name}")))?;
        if !model.can_refresh() {
            return Err(Error::Protocol(format!(
                "refresh: model {name:?} ({}) does not support background refresh",
                model.name()
            )));
        }
        let effective = self.refresh.schedule(name, every_ms);
        Ok(Json::obj()
            .with("model", Json::Str(name.to_string()))
            .with("every_ms", Json::Num(effective as f64)))
    }
}

/// The fit op's model constructor: unsharded requests go through the
/// shared [`fit_model`]; `shards > 1` (already validated MKA-only)
/// partitions the rows and fits a routed [`crate::gp::sharded::ShardedGp`]
/// fleet, recording each shard's factorization wall time into the
/// `shard.fit_secs` histogram.
#[allow(clippy::too_many_arguments)]
fn fit_op_model(
    method: Method,
    data: &Dataset,
    hp: HyperParams,
    k: usize,
    seed: u64,
    shards: usize,
    assign: ClusterMethod,
    metrics: &Metrics,
) -> Result<Box<dyn crate::gp::GpModel>> {
    if shards <= 1 {
        return fit_model(method, data, hp, k, seed);
    }
    let kern = crate::kernels::RbfKernel::new(hp.lengthscale);
    let cfg = crate::experiments::methods::mka_config_for(k, data.n(), seed);
    let model =
        crate::gp::sharded::ShardedGp::fit(data, &kern, hp.sigma2, &cfg, shards, assign)?;
    for &s in model.fit_secs() {
        metrics.observe("shard.fit_secs", s);
    }
    metrics.incr("shard_fits", 1);
    Ok(Box::new(model))
}

/// Human-readable label for a contained job panic.
pub(crate) fn panic_label(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        format!("job panicked: {s}")
    } else if let Some(s) = p.downcast_ref::<String>() {
        format!("job panicked: {s}")
    } else {
        "job panicked".to_string()
    }
}

/// Surface `train.{evals,factorizations,best_mll,secs}` observables
/// (plus the `trains` counter) in the `metrics` op's snapshot.
/// `train.factorizations` is the run's σ²-independent factor-build count
/// — with the per-lengthscale cache it sits strictly below
/// `train.evals` whenever the optimizer revisits a length scale.
fn record_train_metrics(metrics: &Metrics, report: &TrainReport) {
    metrics.incr("trains", 1);
    metrics.observe("train.secs", report.train_secs);
    metrics.observe("train.evals", report.evals as f64);
    if let Some(fx) = report.factorizations {
        metrics.observe("train.factorizations", fx as f64);
    }
    if let Some(m) = report.best_mll {
        metrics.observe("train.best_mll", m);
    }
    if let Some(sf) = &report.shard_factorizations {
        metrics.incr("shard_trains", 1);
        for &c in sf {
            metrics.observe("train.shard_factorizations", c as f64);
        }
    }
}

/// Parse [[f64...]...] into a Mat.
pub fn parse_matrix(v: &Json) -> Result<Mat> {
    let rows = v.as_arr().ok_or_else(|| Error::Protocol("matrix must be an array".into()))?;
    if rows.is_empty() {
        return Err(Error::Protocol("matrix is empty".into()));
    }
    let parsed: Option<Vec<Vec<f64>>> = rows.iter().map(|r| r.f64_array()).collect();
    let parsed = parsed.ok_or_else(|| Error::Protocol("matrix rows must be numeric".into()))?;
    let cols = parsed[0].len();
    if cols == 0 || parsed.iter().any(|r| r.len() != cols) {
        return Err(Error::Protocol("ragged matrix".into()));
    }
    let mut m = Mat::zeros(parsed.len(), cols);
    for (i, row) in parsed.iter().enumerate() {
        m.row_mut(i).copy_from_slice(row);
    }
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gp_dataset, SynthSpec};

    fn router() -> Router {
        let cfg = ServiceConfig { batch_window_ms: 0, n_workers: 2, ..Default::default() };
        Router::new(cfg)
    }

    fn fit_req(model: &str, method: &str, n: usize, is_async: bool) -> Json {
        let data = gp_dataset(&SynthSpec::named("t", n, 2), 1);
        let x: Vec<Json> =
            (0..n).map(|i| Json::from_f64_slice(data.x.row(i))).collect();
        Json::obj()
            .with("op", Json::Str("fit".into()))
            .with("model", Json::Str(model.into()))
            .with("method", Json::Str(method.into()))
            .with("x", Json::Arr(x))
            .with("y", Json::from_f64_slice(&data.y))
            .with(
                "params",
                Json::obj()
                    .with("lengthscale", Json::Num(1.0))
                    .with("sigma2", Json::Num(0.1))
                    .with("k", Json::Num(8.0)),
            )
            .with("async", Json::Bool(is_async))
    }

    #[test]
    fn ping() {
        let r = router();
        let out = r.handle(&Json::parse(r#"{"op":"ping"}"#).unwrap());
        assert_eq!(out.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(out.get("pong"), Some(&Json::Bool(true)));
    }

    #[test]
    fn unknown_op_is_error() {
        let r = router();
        let out = r.handle(&Json::parse(r#"{"op":"nope"}"#).unwrap());
        assert_eq!(out.get("ok"), Some(&Json::Bool(false)));
        assert!(r.metrics.counter("errors") >= 1);
    }

    #[test]
    fn sync_fit_then_predict() {
        let r = router();
        let out = r.handle(&fit_req("m1", "sor", 60, false));
        assert_eq!(out.get("ok"), Some(&Json::Bool(true)), "{out:?}");
        assert_eq!(r.registry.names(), vec!["m1".to_string()]);

        let pred_req = Json::obj()
            .with("op", Json::Str("predict".into()))
            .with("model", Json::Str("m1".into()))
            .with(
                "x",
                Json::Arr(vec![Json::from_f64_slice(&[0.1, -0.2]), Json::from_f64_slice(&[1.0, 1.0])]),
            );
        let out = r.handle(&pred_req);
        assert_eq!(out.get("ok"), Some(&Json::Bool(true)), "{out:?}");
        assert_eq!(out.get("mean").unwrap().f64_array().unwrap().len(), 2);
        assert_eq!(out.get("var").unwrap().f64_array().unwrap().len(), 2);
    }

    #[test]
    fn async_fit_completes() {
        let r = router();
        let out = r.handle(&fit_req("m2", "mka", 80, true));
        assert_eq!(out.get("ok"), Some(&Json::Bool(true)), "{out:?}");
        let job_id = out.usize_field("job_id").unwrap() as u64;
        // Poll until done (bounded).
        for _ in 0..200 {
            if let Some((_, state)) = r.jobs.get(job_id) {
                match state {
                    JobState::Done { .. } => break,
                    JobState::Failed { error } => panic!("fit failed: {error}"),
                    _ => {}
                }
            }
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
        assert!(matches!(r.jobs.get(job_id).unwrap().1, JobState::Done { .. }));
        assert!(r.registry.get("m2").is_some());
    }

    #[test]
    fn fit_validation_errors() {
        let r = router();
        let bad = Json::parse(r#"{"op":"fit","model":"m","method":"mka","x":[[1,2]],"y":[1,2]}"#)
            .unwrap();
        let out = r.handle(&bad);
        assert_eq!(out.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn parse_matrix_validation() {
        assert!(parse_matrix(&Json::parse("[[1,2],[3,4]]").unwrap()).is_ok());
        assert!(parse_matrix(&Json::parse("[]").unwrap()).is_err());
        assert!(parse_matrix(&Json::parse("[[1,2],[3]]").unwrap()).is_err());
        assert!(parse_matrix(&Json::parse(r#"[["a"]]"#).unwrap()).is_err());
    }

    #[test]
    fn predict_unknown_model() {
        let r = router();
        let req = Json::parse(r#"{"op":"predict","model":"ghost","x":[[1.0]]}"#).unwrap();
        let out = r.handle(&req);
        assert_eq!(out.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn metrics_and_config_ops() {
        let r = router();
        let m = r.handle(&Json::parse(r#"{"op":"metrics"}"#).unwrap());
        assert!(m.get("counters").is_some());
        let c = r.handle(&Json::parse(r#"{"op":"config"}"#).unwrap());
        assert_eq!(c.usize_field("port"), Some(7470));
    }

    fn train_req(model: &str, method: &str, n: usize, selection: &str, is_async: bool) -> Json {
        let data = gp_dataset(&SynthSpec::named("t", n, 2), 2);
        let x: Vec<Json> =
            (0..n).map(|i| Json::from_f64_slice(data.x.row(i))).collect();
        Json::obj()
            .with("op", Json::Str("train".into()))
            .with("model", Json::Str(model.into()))
            .with("method", Json::Str(method.into()))
            .with("x", Json::Arr(x))
            .with("y", Json::from_f64_slice(&data.y))
            .with("selection", Json::Str(selection.into()))
            .with(
                "budget",
                Json::obj()
                    .with("max_evals", Json::Num(14.0))
                    .with("n_starts", Json::Num(2.0))
                    .with("folds", Json::Num(2.0)),
            )
            .with("params", Json::obj().with("k", Json::Num(8.0)))
            .with("async", Json::Bool(is_async))
    }

    #[test]
    fn sync_train_selects_and_publishes() {
        let r = router();
        let out = r.handle(&train_req("mt", "sor", 70, "mll", false));
        assert_eq!(out.get("ok"), Some(&Json::Bool(true)), "{out:?}");
        let train = out.get("train").expect("train report");
        assert!(train.num_field("best_mll").unwrap().is_finite());
        assert!(train.num_field("evals").unwrap() >= 2.0);
        assert!(train.get("best").unwrap().num_field("sigma2").unwrap() > 0.0);
        assert!(r.registry.get("mt").is_some());
        assert!(r.metrics.counter("trains") >= 1);
    }

    #[test]
    fn sync_train_cv_path() {
        let r = router();
        let out = r.handle(&train_req("mtcv", "sor", 60, "cv", false));
        assert_eq!(out.get("ok"), Some(&Json::Bool(true)), "{out:?}");
        let train = out.get("train").unwrap();
        assert_eq!(train.str_field("selection"), Some("cv"));
        assert!(train.num_field("cv_smse").unwrap().is_finite());
        assert!(r.registry.get("mtcv").is_some());
    }

    #[test]
    fn sync_train_ard_lbfgs_path() {
        let r = router();
        let mut req = train_req("mard", "sor", 70, "mll-grad", false);
        req.set("ard", Json::Bool(true));
        let out = r.handle(&req);
        assert_eq!(out.get("ok"), Some(&Json::Bool(true)), "{out:?}");
        let train = out.get("train").unwrap();
        assert_eq!(train.str_field("selection"), Some("mll-grad"));
        // per-dimension length scales surface in the report (d = 2)
        let ells = train.get("lengthscales").expect("lengthscales").f64_array().unwrap();
        assert_eq!(ells.len(), 2);
        assert!(train.num_field("best_mll").unwrap().is_finite());
        assert!(r.registry.get("mard").is_some());
        // ard without the gradient path is a protocol error, not silence
        let mut bad = train_req("mbad", "sor", 60, "mll", false);
        bad.set("ard", Json::Bool(true));
        assert_eq!(r.handle(&bad).get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn train_validation_errors() {
        let r = router();
        let bad = Json::parse(r#"{"op":"train","model":"m","method":"mka","x":[[1,2]],"y":[1,2]}"#)
            .unwrap();
        assert_eq!(r.handle(&bad).get("ok"), Some(&Json::Bool(false)));
        let bad_sel = Json::parse(
            r#"{"op":"train","model":"m","method":"mka","x":[[1.0],[2.0]],"y":[1,2],"selection":"nope"}"#,
        )
        .unwrap();
        assert_eq!(r.handle(&bad_sel).get("ok"), Some(&Json::Bool(false)));
        // MEKA + MLL is a modelling error surfaced through the protocol.
        let meka = train_req("mk", "meka", 60, "mll", false);
        assert_eq!(r.handle(&meka).get("ok"), Some(&Json::Bool(false)));
    }

    /// The retune op republishes an MKA model at a new σ² without any
    /// refit job; other methods get a typed protocol error, and bad
    /// inputs are rejected.
    #[test]
    fn retune_op_republishes_mka_model() {
        let r = router();
        let out = r.handle(&fit_req("mr", "mka", 70, false));
        assert_eq!(out.get("ok"), Some(&Json::Bool(true)), "{out:?}");
        let retune = Json::parse(r#"{"op":"retune","model":"mr","sigma2":0.4}"#).unwrap();
        let out = r.handle(&retune);
        assert_eq!(out.get("ok"), Some(&Json::Bool(true)), "{out:?}");
        assert_eq!(out.num_field("sigma2"), Some(0.4));
        assert!(r.metrics.counter("retunes") >= 1);
        // the republished model still serves predictions
        let pred = Json::obj()
            .with("op", Json::Str("predict".into()))
            .with("model", Json::Str("mr".into()))
            .with("x", Json::Arr(vec![Json::from_f64_slice(&[0.1, 0.1])]));
        let out = r.handle(&pred);
        assert_eq!(out.get("ok"), Some(&Json::Bool(true)), "{out:?}");
        // higher noise ⇒ the predictive variance floor rises with it
        assert!(out.get("var").unwrap().f64_array().unwrap()[0] >= 0.4);

        // non-MKA models cannot retune
        let out = r.handle(&fit_req("mfull", "full", 60, false));
        assert_eq!(out.get("ok"), Some(&Json::Bool(true)), "{out:?}");
        let bad = Json::parse(r#"{"op":"retune","model":"mfull","sigma2":0.2}"#).unwrap();
        let out = r.handle(&bad);
        assert_eq!(out.get("ok"), Some(&Json::Bool(false)));
        // unknown model / missing or invalid sigma2
        let ghost = Json::parse(r#"{"op":"retune","model":"ghost","sigma2":0.2}"#).unwrap();
        assert_eq!(r.handle(&ghost).get("ok"), Some(&Json::Bool(false)));
        let missing = Json::parse(r#"{"op":"retune","model":"mr"}"#).unwrap();
        assert_eq!(r.handle(&missing).get("ok"), Some(&Json::Bool(false)));
        let neg = Json::parse(r#"{"op":"retune","model":"mr","sigma2":-0.1}"#).unwrap();
        assert_eq!(r.handle(&neg).get("ok"), Some(&Json::Bool(false)));
    }

    /// Full sharded lifecycle through the protocol: fit with `"shards"`,
    /// inspect per-model metadata, predict, retune, and read the shard
    /// metrics section.
    #[test]
    fn sharded_fit_lifecycle() {
        let r = router();
        let mut req = fit_req("ms", "mka", 90, false);
        req.set("shards", Json::Num(3.0));
        let out = r.handle(&req);
        assert_eq!(out.get("ok"), Some(&Json::Bool(true)), "{out:?}");
        assert!(out.num_field("shards").unwrap_or(0.0) >= 2.0);

        // models op reports metadata objects including shard topology
        let m = r.handle(&Json::parse(r#"{"op":"models"}"#).unwrap());
        let models = m.get("models").unwrap().as_arr().unwrap();
        let entry = models
            .iter()
            .find(|e| e.str_field("name") == Some("ms"))
            .expect("ms listed");
        assert!(entry.num_field("shards").unwrap() >= 2.0);
        assert_eq!(entry.num_field("n"), Some(90.0));
        assert_eq!(entry.num_field("dim"), Some(2.0));
        assert_eq!(entry.num_field("sigma2"), Some(0.1));
        let sizes = entry.get("shard_sizes").unwrap().f64_array().unwrap();
        assert_eq!(sizes.iter().sum::<f64>(), 90.0);

        // routed predict + O(shards) retune
        let pred = Json::obj()
            .with("op", Json::Str("predict".into()))
            .with("model", Json::Str("ms".into()))
            .with("x", Json::Arr(vec![Json::from_f64_slice(&[0.2, -0.1])]));
        assert_eq!(r.handle(&pred).get("ok"), Some(&Json::Bool(true)));
        let retune = Json::parse(r#"{"op":"retune","model":"ms","sigma2":0.3}"#).unwrap();
        assert_eq!(r.handle(&retune).get("ok"), Some(&Json::Bool(true)));

        // metrics: shard section + per-op latency histograms
        let snap = r.handle(&Json::parse(r#"{"op":"metrics"}"#).unwrap());
        let shard = snap.get("shard").expect("shard section");
        assert!(shard.num_field("models").unwrap() >= 1.0);
        assert!(shard.num_field("count").unwrap() >= 2.0);
        assert!(shard.num_field("route_hits").unwrap() >= 1.0);
        assert!(!shard.get("sizes").unwrap().as_arr().unwrap().is_empty());
        let hists = snap.get("histograms").unwrap();
        for h in ["op.fit_secs", "op.predict_secs", "op.retune_secs"] {
            let j = hists.get(h).unwrap_or_else(|| panic!("{h} histogram"));
            assert!(j.num_field("p50").is_some() && j.num_field("p99").is_some(), "{h}");
        }
        assert!(hists.get("shard.fit_secs").is_some());
        assert!(hists.get("shard.retune_secs").is_some());
    }

    #[test]
    fn shard_validation_errors() {
        let r = router();
        // shards must be >= 1
        let mut zero = fit_req("z", "mka", 60, false);
        zero.set("shards", Json::Num(0.0));
        let out = r.handle(&zero);
        assert_eq!(out.get("ok"), Some(&Json::Bool(false)));
        assert!(out.str_field("error").unwrap().contains("shards"));
        // shards > 1 is MKA-only
        let mut sor = fit_req("s", "sor", 60, false);
        sor.set("shards", Json::Num(2.0));
        let out = r.handle(&sor);
        assert_eq!(out.get("ok"), Some(&Json::Bool(false)));
        assert!(out.str_field("error").unwrap().contains("mka"));
        // shards exceeding the training size is a typed error too
        let mut many = fit_req("m", "mka", 60, false);
        many.set("shards", Json::Num(61.0));
        assert_eq!(r.handle(&many).get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn sync_sharded_train_publishes_fleet() {
        let r = router();
        let mut req = train_req("mst", "mka", 90, "mll", false);
        req.set("shards", Json::Num(2.0));
        let out = r.handle(&req);
        assert_eq!(out.get("ok"), Some(&Json::Bool(true)), "{out:?}");
        let train = out.get("train").unwrap();
        assert!(train.num_field("best_mll").unwrap().is_finite());
        let sf = train.get("shard_factorizations").expect("per-shard counts");
        assert!(!sf.as_arr().unwrap().is_empty());
        let model = r.registry.get("mst").expect("fleet published");
        assert!(model.info().shards >= 2);
    }

    /// Tracing is strictly observational: a traced predict answers with
    /// bit-identical values plus a span tree whose root is the op and
    /// whose descendants reach the routed shard predicts; the `trace` op
    /// replays the same tree from the ring afterwards.
    #[test]
    fn traced_predict_echoes_span_tree_without_changing_bits() {
        let r = router();
        let mut req = fit_req("mtr", "mka", 90, false);
        req.set("shards", Json::Num(3.0));
        assert_eq!(r.handle(&req).get("ok"), Some(&Json::Bool(true)));
        let pred = |traced: bool| {
            let mut p = Json::obj()
                .with("op", Json::Str("predict".into()))
                .with("model", Json::Str("mtr".into()))
                .with(
                    "x",
                    Json::Arr(vec![
                        Json::from_f64_slice(&[0.2, -0.1]),
                        Json::from_f64_slice(&[-0.4, 0.3]),
                    ]),
                );
            if traced {
                p.set("trace", Json::Bool(true));
            }
            r.handle(&p)
        };
        let plain = pred(false);
        let traced = pred(true);
        assert_eq!(plain.get("ok"), Some(&Json::Bool(true)), "{plain:?}");
        assert!(plain.get("trace").is_none(), "untraced predicts carry no tree");
        // Identical values traced vs untraced.
        assert_eq!(plain.get("mean"), traced.get("mean"));
        assert_eq!(plain.get("var"), traced.get("var"));
        // Span tree: root op.predict with descendants down to the shards.
        let tree = traced.get("trace").expect("span tree echoed");
        assert!(tree.num_field("total_us").is_some());
        let root = tree.get("root").unwrap();
        assert_eq!(root.str_field("name"), Some("op.predict"));
        fn names(n: &Json, out: &mut Vec<String>) {
            out.push(n.str_field("name").unwrap_or("").to_string());
            if let Some(Json::Arr(ch)) = n.get("children") {
                for c in ch {
                    names(c, out);
                }
            }
        }
        let mut all = Vec::new();
        names(root, &mut all);
        assert!(all.iter().any(|n| n.starts_with("sharded.predict")), "{all:?}");
        assert!(all.iter().any(|n| n.starts_with("shard ")), "{all:?}");
        // The trace op replays it from the ring.
        let out = r.handle(&Json::parse(r#"{"op":"trace","tail":4}"#).unwrap());
        assert_eq!(out.get("ok"), Some(&Json::Bool(true)));
        let traces = out.get("traces").unwrap().as_arr().unwrap();
        assert!(traces
            .iter()
            .any(|t| t.get("root").and_then(|n| n.str_field("name")) == Some("op.predict")));
    }

    /// The three introspection ops: `diagnose` reports held factor state
    /// (never refactorizing), `logs` filters by level with typed errors
    /// for unknown levels, and malformed `trace` tails are rejected.
    #[test]
    fn trace_logs_and_diagnose_ops() {
        let r = router();
        assert_eq!(r.handle(&fit_req("md", "mka", 70, false)).get("ok"), Some(&Json::Bool(true)));
        let d = r.handle(&Json::parse(r#"{"op":"diagnose","model":"md"}"#).unwrap());
        assert_eq!(d.get("ok"), Some(&Json::Bool(true)), "{d:?}");
        assert_eq!(d.get("diagnose").unwrap().str_field("kind"), Some("mka"));
        // Sharded fit forces every shard factor: full spectrum health,
        // and diagnosing must not add a single factorization.
        let mut req = fit_req("mds", "mka", 90, false);
        req.set("shards", Json::Num(3.0));
        assert_eq!(r.handle(&req).get("ok"), Some(&Json::Bool(true)));
        let before = crate::mka::factorize_count();
        let d = r.handle(&Json::parse(r#"{"op":"diagnose","model":"mds"}"#).unwrap());
        assert_eq!(crate::mka::factorize_count(), before, "diagnose must not refactorize");
        let diag = d.get("diagnose").unwrap();
        assert_eq!(diag.str_field("kind"), Some("sharded"));
        let shards = diag.get("shards").unwrap().as_arr().unwrap();
        assert!(shards.len() >= 2);
        let factor = shards[0].get("model").unwrap().get("factor").unwrap();
        assert!(factor.num_field("condition").unwrap() >= 1.0);
        assert!(factor.num_field("overall_compression").unwrap() > 0.0);
        // Models without diagnostics, unknown models, missing fields.
        assert_eq!(r.handle(&fit_req("mf", "full", 60, false)).get("ok"), Some(&Json::Bool(true)));
        for bad in [
            r#"{"op":"diagnose","model":"mf"}"#,
            r#"{"op":"diagnose","model":"ghost"}"#,
            r#"{"op":"diagnose"}"#,
            r#"{"op":"logs","level":"loud"}"#,
            r#"{"op":"trace","tail":"many"}"#,
        ] {
            assert_eq!(
                r.handle(&Json::parse(bad).unwrap()).get("ok"),
                Some(&Json::Bool(false)),
                "{bad}"
            );
        }
        let logs = r.handle(&Json::parse(r#"{"op":"logs","level":"warn","tail":10}"#).unwrap());
        assert_eq!(logs.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(logs.str_field("level"), Some("warn"));
        assert!(logs.get("events").unwrap().as_arr().is_some());
        assert!(logs.num_field("ring_capacity").unwrap() >= 1.0);
    }

    /// The observe op appends through the protocol: the model grows by
    /// the batch, stays servable, and the response reports the path
    /// taken with stage-reuse accounting; malformed batches and
    /// incapable models get typed errors.
    #[test]
    fn observe_op_appends_and_republishes() {
        let r = router();
        assert_eq!(r.handle(&fit_req("mo", "mka", 80, false)).get("ok"), Some(&Json::Bool(true)));
        let obs = Json::obj()
            .with("op", Json::Str("observe".into()))
            .with("model", Json::Str("mo".into()))
            .with(
                "x",
                Json::Arr(vec![
                    Json::from_f64_slice(&[0.3, 0.1]),
                    Json::from_f64_slice(&[-0.2, 0.4]),
                ]),
            )
            .with("y", Json::from_f64_slice(&[0.1, -0.3]));
        let out = r.handle(&obs);
        assert_eq!(out.get("ok"), Some(&Json::Bool(true)), "{out:?}");
        let rep = out.get("observe").expect("observe report");
        assert!(matches!(rep.str_field("path"), Some("incremental") | Some("refit")));
        assert_eq!(rep.usize_field("appended"), Some(2));
        assert_eq!(rep.usize_field("n_total"), Some(82));
        assert_eq!(r.registry.get("mo").unwrap().info().n, 82);
        assert!(r.metrics.counter("observes") >= 1);
        // the grown model still serves predictions
        let pred = Json::obj()
            .with("op", Json::Str("predict".into()))
            .with("model", Json::Str("mo".into()))
            .with("x", Json::Arr(vec![Json::from_f64_slice(&[0.0, 0.0])]));
        assert_eq!(r.handle(&pred).get("ok"), Some(&Json::Bool(true)));
        // an absurd drift override forces the refit path + counter
        let mut forced = obs.clone();
        forced.set("drift_threshold", Json::Num(1e-12));
        let out = r.handle(&forced);
        assert_eq!(out.get("ok"), Some(&Json::Bool(true)), "{out:?}");
        assert_eq!(out.get("observe").unwrap().str_field("path"), Some("refit"));
        assert!(r.metrics.counter("observe_refits") >= 1);
        // typed failures: bad shapes, bad knobs, wrong model kinds
        let full = r.handle(&fit_req("mfull2", "full", 60, false));
        assert_eq!(full.get("ok"), Some(&Json::Bool(true)));
        let mut wrong = obs.clone();
        wrong.set("model", Json::Str("mfull2".into()));
        let out = r.handle(&wrong);
        assert_eq!(out.get("ok"), Some(&Json::Bool(false)));
        assert!(out.str_field("error").unwrap().contains("streaming"));
        for bad in [
            r#"{"op":"observe","model":"mo"}"#,
            r#"{"op":"observe","model":"mo","x":[[1,2]],"y":[1,2]}"#,
            r#"{"op":"observe","model":"ghost","x":[[1,2]],"y":[1]}"#,
            r#"{"op":"observe","model":"mo","x":[[1,2]],"y":[1],"drift_threshold":"big"}"#,
            r#"{"op":"observe","model":"mo","x":[[1,2]],"y":[1],"window":-3}"#,
        ] {
            assert_eq!(
                r.handle(&Json::parse(bad).unwrap()).get("ok"),
                Some(&Json::Bool(false)),
                "{bad}"
            );
        }
        // the op is timed: a latency histogram appears on success
        let snap = r.handle(&Json::parse(r#"{"op":"metrics"}"#).unwrap());
        assert!(snap.get("histograms").unwrap().get("op.observe_secs").is_some());
        let compute = snap.get("compute").unwrap();
        assert!(compute.num_field("stage_rebuilds").is_some());
        assert!(compute.num_field("stage_reuses").is_some());
    }

    /// Refresh-policy lifecycle through the protocol: schedule (with the
    /// floor clamp), list, fire at least once through the job store, and
    /// cancel; scheduling for absent or refresh-incapable models fails
    /// with typed errors.
    #[test]
    fn refresh_op_schedules_fires_and_cancels() {
        let cfg = ServiceConfig {
            batch_window_ms: 0,
            n_workers: 2,
            refresh_min_interval_ms: 30,
            ..Default::default()
        };
        let r = Router::new(cfg);
        assert_eq!(r.handle(&fit_req("mrf", "mka", 60, false)).get("ok"), Some(&Json::Bool(true)));
        // sub-floor period clamps up to the configured minimum
        let out = r.handle(&Json::parse(r#"{"op":"refresh","model":"mrf","every_ms":1}"#).unwrap());
        assert_eq!(out.get("ok"), Some(&Json::Bool(true)), "{out:?}");
        assert_eq!(out.usize_field("every_ms"), Some(30));
        // listed
        let out = r.handle(&Json::parse(r#"{"op":"refresh"}"#).unwrap());
        let pols = out.get("policies").unwrap().as_arr().unwrap();
        assert_eq!(pols.len(), 1);
        assert_eq!(pols[0].str_field("model"), Some("mrf"));
        // fires through the shared job store + pool
        let mut fired = false;
        for _ in 0..200 {
            if r.metrics.counter("refreshes") >= 1 {
                fired = true;
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        assert!(fired, "refresh never fired: errors={}", r.metrics.counter("refresh_errors"));
        assert!(r.registry.get("mrf").is_some(), "model stays published across refreshes");
        // cancel is idempotent and reported
        let out = r.handle(&Json::parse(r#"{"op":"refresh","model":"mrf","every_ms":0}"#).unwrap());
        assert_eq!(out.get("cancelled"), Some(&Json::Bool(true)));
        let out = r.handle(&Json::parse(r#"{"op":"refresh","model":"mrf","every_ms":0}"#).unwrap());
        assert_eq!(out.get("cancelled"), Some(&Json::Bool(false)));
        // typed failures
        let full = r.handle(&fit_req("mfull3", "full", 60, false));
        assert_eq!(full.get("ok"), Some(&Json::Bool(true)));
        for bad in [
            r#"{"op":"refresh","model":"ghost","every_ms":100}"#,
            r#"{"op":"refresh","model":"mfull3","every_ms":100}"#,
            r#"{"op":"refresh","model":"mrf"}"#,
            r#"{"op":"refresh","model":"mrf","every_ms":"fast"}"#,
            r#"{"op":"refresh","every_ms":100}"#,
        ] {
            assert_eq!(
                r.handle(&Json::parse(bad).unwrap()).get("ok"),
                Some(&Json::Bool(false)),
                "{bad}"
            );
        }
    }

    /// A fit-time `"batch_window_ms"` override governs that model's
    /// predicts (here: an immediate flush despite a minute-long service
    /// default), malformed values are typed errors, and dropping the
    /// model clears the override.
    #[test]
    fn fit_time_batch_window_overrides_service_default() {
        let cfg = ServiceConfig { batch_window_ms: 60_000, n_workers: 2, ..Default::default() };
        let r = Router::new(cfg);
        let mut req = fit_req("mw", "sor", 60, false);
        req.set("batch_window_ms", Json::Num(0.0));
        assert_eq!(r.handle(&req).get("ok"), Some(&Json::Bool(true)));
        assert_eq!(r.batcher.window_for("mw"), Duration::ZERO);
        let pred = Json::obj()
            .with("op", Json::Str("predict".into()))
            .with("model", Json::Str("mw".into()))
            .with("x", Json::Arr(vec![Json::from_f64_slice(&[0.1, 0.2])]));
        let out = r.handle(&pred);
        assert_eq!(out.get("ok"), Some(&Json::Bool(true)), "{out:?}");
        let mut bad = fit_req("mw2", "sor", 60, false);
        bad.set("batch_window_ms", Json::Str("fast".into()));
        assert_eq!(r.handle(&bad).get("ok"), Some(&Json::Bool(false)));
        let drop_req = Json::parse(r#"{"op":"drop_model","model":"mw"}"#).unwrap();
        assert_eq!(r.handle(&drop_req).get("ok"), Some(&Json::Bool(true)));
        assert_eq!(r.batcher.window_for("mw"), Duration::from_millis(60_000));
    }

    /// A busy rejection reports the queue depth it was rejected at and a
    /// depth-aware `retry_after_ms` floored by the batching window; shed
    /// load stays out of the `errors` counter.
    #[test]
    fn busy_response_carries_depth_and_scaled_retry() {
        let cfg = ServiceConfig {
            batch_window_ms: 60_000,
            batch_queue_max: 1,
            n_workers: 2,
            ..Default::default()
        };
        let r = Router::new(cfg);
        assert_eq!(r.handle(&fit_req("mb", "sor", 60, false)).get("ok"), Some(&Json::Bool(true)));
        // Park one request inside its (long) batching window via the raw
        // batcher handle so the queue sits exactly at the bound.
        let rx = r.batcher.submit("mb", Mat::from_rows(&[&[0.1, 0.2]]));
        let pred = Json::obj()
            .with("op", Json::Str("predict".into()))
            .with("model", Json::Str("mb".into()))
            .with("x", Json::Arr(vec![Json::from_f64_slice(&[0.3, 0.4])]));
        let out = r.handle(&pred);
        assert_eq!(out.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(out.get("busy"), Some(&Json::Bool(true)));
        assert_eq!(out.num_field("depth"), Some(1.0));
        assert!(out.num_field("retry_after_ms").unwrap() >= 60_000.0, "{out:?}");
        assert_eq!(r.metrics.counter("errors"), 0, "busy is shed load, not an error");
        drop(r); // shutdown flushes the parked request
        assert!(rx.recv().unwrap().is_ok());
    }

    #[test]
    fn metrics_surface_compute_plane() {
        let r = router();
        // Serve one prediction so at least one cascade has run.
        let out = r.handle(&fit_req("mc", "mka", 60, false));
        assert_eq!(out.get("ok"), Some(&Json::Bool(true)), "{out:?}");
        let pred = Json::obj()
            .with("op", Json::Str("predict".into()))
            .with("model", Json::Str("mc".into()))
            .with("x", Json::Arr(vec![Json::from_f64_slice(&[0.0, 0.0])]));
        assert_eq!(r.handle(&pred).get("ok"), Some(&Json::Bool(true)));
        let m = r.handle(&Json::parse(r#"{"op":"metrics"}"#).unwrap());
        let compute = m.get("compute").expect("compute section present");
        assert!(compute.num_field("cascades").unwrap_or(0.0) >= 1.0);
        assert!(compute.num_field("factorizes").unwrap_or(0.0) >= 1.0);
        assert!(compute.num_field("factor_cache_hits").is_some());
        assert!(compute.num_field("factor_cache_misses").is_some());
        // The predict above went through the joint-factor cache: at
        // least one (process-global) miss, and all three counters are
        // surfaced for hit-rate dashboards.
        assert!(compute.num_field("predict_cache_hits").is_some());
        assert!(compute.num_field("predict_cache_misses").unwrap_or(0.0) >= 1.0);
        assert!(compute.num_field("predict_cache_evictions").is_some());
        assert!(compute.num_field("pool_threads").unwrap_or(0.0) >= 1.0);
        assert!(compute.num_field("pool_jobs").is_some());
        assert!(compute.num_field("pool_workers").is_some());
        // Arena protocol observables: the fit+predict above must have
        // checked scratch out of the per-worker pools at least once, and
        // the dispatch level is surfaced for perf triage.
        assert!(compute.num_field("arena_checkouts").unwrap_or(0.0) >= 1.0);
        assert!(compute.num_field("arena_grows").is_some());
        assert!(compute.num_field("arena_grow_bytes").is_some());
        match compute.get("simd_level") {
            Some(Json::Str(s)) => {
                assert!(["Scalar", "Avx2", "Avx512"].contains(&s.as_str()), "{s}")
            }
            other => panic!("simd_level missing or not a string: {other:?}"),
        }
    }
}
