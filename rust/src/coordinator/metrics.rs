//! Lightweight metrics registry: named counters and latency histograms,
//! exported as JSON through the `metrics` protocol op.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::la::stats::quantile_sorted;
use crate::util::json::Json;

/// Registry of counters and histograms. Cheap to share behind an `Arc`.
#[derive(Default)]
pub struct Metrics {
    // Plain u64 under the map's own Mutex: every access already takes the
    // lock, so per-entry atomics bought nothing but indirection.
    counters: Mutex<BTreeMap<String, u64>>,
    histograms: Mutex<BTreeMap<String, Vec<f64>>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Increment a counter by `delta`.
    pub fn incr(&self, name: &str, delta: u64) {
        let mut c = self.counters.lock().unwrap();
        *c.entry(name.to_string()).or_insert(0) += delta;
    }

    /// Record one observation (e.g. latency seconds). Non-finite values
    /// are never admitted to a histogram — they would poison every
    /// quantile downstream — and are flagged on the
    /// `observations_non_finite` counter instead.
    pub fn observe(&self, name: &str, value: f64) {
        if !value.is_finite() {
            self.incr("observations_non_finite", 1);
            return;
        }
        let mut h = self.histograms.lock().unwrap();
        let v = h.entry(name.to_string()).or_default();
        // Bound memory: keep a sliding window of the most recent 10k.
        if v.len() >= 10_000 {
            v.drain(..5_000);
        }
        v.push(value);
    }

    /// Convenience: time a closure into a histogram.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t = crate::util::timer::Timer::start();
        let out = f();
        self.observe(name, t.elapsed_secs());
        out
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    /// The `q`-quantile of a histogram's current window, or None when
    /// nothing has been observed — what depth-aware admission control
    /// reads (`predict_secs` p50) to scale `retry_after_ms`.
    pub fn quantile(&self, name: &str, q: f64) -> Option<f64> {
        let h = self.histograms.lock().unwrap();
        let v = h.get(name)?;
        if v.is_empty() {
            return None;
        }
        let mut sorted = v.clone();
        sorted.sort_by(f64::total_cmp);
        Some(quantile_sorted(&sorted, q))
    }

    /// Snapshot everything as JSON: counters verbatim, histograms as
    /// {count, mean, p50, p95, p99, max}.
    pub fn snapshot(&self) -> Json {
        let mut counters = Json::obj();
        for (k, &v) in self.counters.lock().unwrap().iter() {
            counters.set(k, Json::Num(v as f64));
        }
        let mut hists = Json::obj();
        for (k, v) in self.histograms.lock().unwrap().iter() {
            if v.is_empty() {
                continue;
            }
            let mut sorted = v.clone();
            // total_cmp: snapshot must never panic, whatever was observed
            // (observe() filters non-finite, but stay panic-free anyway).
            sorted.sort_by(f64::total_cmp);
            let mean = sorted.iter().sum::<f64>() / sorted.len() as f64;
            hists.set(
                k,
                Json::obj()
                    .with("count", Json::Num(sorted.len() as f64))
                    .with("mean", Json::Num(mean))
                    .with("p50", Json::Num(quantile_sorted(&sorted, 0.5)))
                    .with("p95", Json::Num(quantile_sorted(&sorted, 0.95)))
                    .with("p99", Json::Num(quantile_sorted(&sorted, 0.99)))
                    .with("max", Json::Num(*sorted.last().unwrap())),
            );
        }
        Json::obj().with("counters", counters).with("histograms", hists)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("req", 1);
        m.incr("req", 2);
        assert_eq!(m.counter("req"), 3);
        assert_eq!(m.counter("nope"), 0);
    }

    #[test]
    fn histograms_summarize() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.observe("lat", i as f64);
        }
        let snap = m.snapshot();
        let lat = snap.get("histograms").unwrap().get("lat").unwrap();
        assert_eq!(lat.num_field("count"), Some(100.0));
        assert!((lat.num_field("p50").unwrap() - 50.5).abs() < 1.0);
        assert!((lat.num_field("p99").unwrap() - 99.0).abs() < 1.5);
        assert_eq!(lat.num_field("max"), Some(100.0));
    }

    #[test]
    fn quantile_reads_the_window() {
        let m = Metrics::new();
        assert_eq!(m.quantile("lat", 0.5), None);
        for i in 1..=100 {
            m.observe("lat", i as f64);
        }
        assert!((m.quantile("lat", 0.5).unwrap() - 50.5).abs() < 1.0);
        assert!(m.quantile("lat", 0.99).unwrap() > 95.0);
    }

    #[test]
    fn time_records() {
        let m = Metrics::new();
        let out = m.time("op", || 7);
        assert_eq!(out, 7);
        let snap = m.snapshot();
        assert!(snap.get("histograms").unwrap().get("op").is_some());
    }

    /// NaN/±∞ observations must neither crash `snapshot` (the old
    /// `partial_cmp().unwrap()` sort panicked on NaN) nor skew quantiles:
    /// they are dropped at `observe` and tallied on a counter.
    #[test]
    fn non_finite_observations_are_flagged_not_recorded() {
        let m = Metrics::new();
        m.observe("lat", 1.0);
        m.observe("lat", f64::NAN);
        m.observe("lat", f64::INFINITY);
        m.observe("lat", f64::NEG_INFINITY);
        m.observe("lat", 3.0);
        let snap = m.snapshot(); // must not panic
        let lat = snap.get("histograms").unwrap().get("lat").unwrap();
        assert_eq!(lat.num_field("count"), Some(2.0));
        assert_eq!(lat.num_field("max"), Some(3.0));
        assert!(lat.num_field("p99").unwrap().is_finite());
        assert_eq!(m.counter("observations_non_finite"), 3);
    }

    #[test]
    fn window_bounds_memory() {
        let m = Metrics::new();
        for i in 0..25_000 {
            m.observe("big", i as f64);
        }
        let snap = m.snapshot();
        let count = snap
            .get("histograms")
            .unwrap()
            .get("big")
            .unwrap()
            .num_field("count")
            .unwrap();
        assert!(count <= 10_000.0);
    }
}
