//! Lightweight metrics registry: named counters and latency histograms,
//! exported as JSON through the `metrics` protocol op.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::la::stats::quantile_sorted;
use crate::util::json::Json;

/// Registry of counters and histograms. Cheap to share behind an `Arc`.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, AtomicU64>>,
    histograms: Mutex<BTreeMap<String, Vec<f64>>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Increment a counter by `delta`.
    pub fn incr(&self, name: &str, delta: u64) {
        let mut c = self.counters.lock().unwrap();
        c.entry(name.to_string())
            .or_insert_with(|| AtomicU64::new(0))
            .fetch_add(delta, Ordering::Relaxed);
    }

    /// Record one observation (e.g. latency seconds).
    pub fn observe(&self, name: &str, value: f64) {
        let mut h = self.histograms.lock().unwrap();
        let v = h.entry(name.to_string()).or_default();
        // Bound memory: keep a sliding window of the most recent 10k.
        if v.len() >= 10_000 {
            v.drain(..5_000);
        }
        v.push(value);
    }

    /// Convenience: time a closure into a histogram.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t = crate::util::timer::Timer::start();
        let out = f();
        self.observe(name, t.elapsed_secs());
        out
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Snapshot everything as JSON: counters verbatim, histograms as
    /// {count, mean, p50, p95, p99, max}.
    pub fn snapshot(&self) -> Json {
        let mut counters = Json::obj();
        for (k, v) in self.counters.lock().unwrap().iter() {
            counters.set(k, Json::Num(v.load(Ordering::Relaxed) as f64));
        }
        let mut hists = Json::obj();
        for (k, v) in self.histograms.lock().unwrap().iter() {
            if v.is_empty() {
                continue;
            }
            let mut sorted = v.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let mean = sorted.iter().sum::<f64>() / sorted.len() as f64;
            hists.set(
                k,
                Json::obj()
                    .with("count", Json::Num(sorted.len() as f64))
                    .with("mean", Json::Num(mean))
                    .with("p50", Json::Num(quantile_sorted(&sorted, 0.5)))
                    .with("p95", Json::Num(quantile_sorted(&sorted, 0.95)))
                    .with("p99", Json::Num(quantile_sorted(&sorted, 0.99)))
                    .with("max", Json::Num(*sorted.last().unwrap())),
            );
        }
        Json::obj().with("counters", counters).with("histograms", hists)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("req", 1);
        m.incr("req", 2);
        assert_eq!(m.counter("req"), 3);
        assert_eq!(m.counter("nope"), 0);
    }

    #[test]
    fn histograms_summarize() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.observe("lat", i as f64);
        }
        let snap = m.snapshot();
        let lat = snap.get("histograms").unwrap().get("lat").unwrap();
        assert_eq!(lat.num_field("count"), Some(100.0));
        assert!((lat.num_field("p50").unwrap() - 50.5).abs() < 1.0);
        assert!((lat.num_field("p99").unwrap() - 99.0).abs() < 1.5);
        assert_eq!(lat.num_field("max"), Some(100.0));
    }

    #[test]
    fn time_records() {
        let m = Metrics::new();
        let out = m.time("op", || 7);
        assert_eq!(out, 7);
        let snap = m.snapshot();
        assert!(snap.get("histograms").unwrap().get("op").is_some());
    }

    #[test]
    fn window_bounds_memory() {
        let m = Metrics::new();
        for i in 0..25_000 {
            m.observe("big", i as f64);
        }
        let snap = m.snapshot();
        let count = snap
            .get("histograms")
            .unwrap()
            .get("big")
            .unwrap()
            .num_field("count")
            .unwrap();
        assert!(count <= 10_000.0);
    }
}
