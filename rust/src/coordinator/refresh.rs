//! Recurring background refresh: re-fit registered models on a schedule.
//!
//! The streaming observe path keeps a model current cheaply, but its
//! incremental extensions are approximations — after enough of them the
//! factorization drifts from what a fresh fit would build. The
//! [`RefreshScheduler`] closes that gap: a `refresh` request registers a
//! per-model period, and a tick thread fires a re-fit job through the
//! existing [`JobStore`]/[`WorkerPool`] machinery whenever one is due.
//! Refits call the model's own [`GpModel::refreshed`] hook (a
//! from-scratch fit of its currently-held training set) and republish
//! atomically, so serving never pauses: readers keep the old `Arc` until
//! the swap.
//!
//! Scheduling guarantees:
//!
//! * at most one refresh per model is in flight at a time (a slow refit
//!   never stacks up behind itself);
//! * periods are clamped up to the configured
//!   `refresh_min_interval_ms` floor;
//! * a policy whose model has vanished from the registry is dropped
//!   with a warn event rather than firing forever.
//!
//! [`GpModel::refreshed`]: crate::gp::GpModel::refreshed

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::jobs::{JobState, JobStore, ModelRegistry};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::pool::WorkerPool;
use crate::obs;
use crate::util::json::Json;

/// One model's refresh policy.
struct Policy {
    every_ms: u64,
    next_due: Instant,
    /// Set while a refresh job for this model is queued or running.
    inflight: Arc<AtomicBool>,
    /// Completed + in-flight fires since the policy was registered.
    fires: u64,
}

struct Inner {
    policies: Mutex<BTreeMap<String, Policy>>,
    stop: AtomicBool,
    registry: ModelRegistry,
    jobs: Arc<JobStore>,
    pool: Arc<WorkerPool>,
    metrics: Arc<Metrics>,
    min_interval_ms: u64,
}

/// Background scheduler for recurring model re-fit jobs.
pub struct RefreshScheduler {
    inner: Arc<Inner>,
    handle: Option<JoinHandle<()>>,
}

impl RefreshScheduler {
    /// Start the tick thread. `min_interval_ms` is the floor every
    /// scheduled period is clamped up to (and also bounds how stale a
    /// due policy can go unnoticed: ticks run every ~10 ms).
    pub fn new(
        registry: ModelRegistry,
        jobs: Arc<JobStore>,
        pool: Arc<WorkerPool>,
        metrics: Arc<Metrics>,
        min_interval_ms: u64,
    ) -> RefreshScheduler {
        let inner = Arc::new(Inner {
            policies: Mutex::new(BTreeMap::new()),
            stop: AtomicBool::new(false),
            registry,
            jobs,
            pool,
            metrics,
            min_interval_ms: min_interval_ms.max(1),
        });
        let tick = Arc::clone(&inner);
        let handle = std::thread::Builder::new()
            .name("mka-refresh".into())
            .spawn(move || run_ticks(&tick))
            .ok();
        RefreshScheduler { inner, handle }
    }

    /// Register (or replace) a recurring refresh for `model`, returning
    /// the effective period after clamping to the configured floor. The
    /// first fire happens one period from now.
    pub fn schedule(&self, model: &str, every_ms: u64) -> u64 {
        let every = every_ms.max(self.inner.min_interval_ms);
        let mut p = self.inner.policies.lock().unwrap();
        let existing_inflight = p.get(model).map(|old| Arc::clone(&old.inflight));
        p.insert(
            model.to_string(),
            Policy {
                every_ms: every,
                next_due: Instant::now() + Duration::from_millis(every),
                inflight: existing_inflight.unwrap_or_else(|| Arc::new(AtomicBool::new(false))),
                fires: 0,
            },
        );
        every
    }

    /// Drop `model`'s refresh policy. Returns whether one existed. An
    /// already-running refresh job finishes normally; it just never
    /// fires again.
    pub fn cancel(&self, model: &str) -> bool {
        self.inner.policies.lock().unwrap().remove(model).is_some()
    }

    /// The registered policies, for the `refresh` op's list form.
    pub fn policies_json(&self) -> Json {
        let p = self.inner.policies.lock().unwrap();
        let mut arr = Vec::with_capacity(p.len());
        for (name, pol) in p.iter() {
            arr.push(
                Json::obj()
                    .with("model", Json::Str(name.clone()))
                    .with("every_ms", Json::Num(pol.every_ms as f64))
                    .with("fires", Json::Num(pol.fires as f64))
                    .with("inflight", Json::Bool(pol.inflight.load(Ordering::SeqCst))),
            );
        }
        Json::Arr(arr)
    }

    /// Number of registered policies.
    pub fn len(&self) -> usize {
        self.inner.policies.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Drop for RefreshScheduler {
    fn drop(&mut self) {
        self.inner.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// The tick loop: scan for due policies, fire refresh jobs, sleep.
fn run_ticks(inner: &Arc<Inner>) {
    while !inner.stop.load(Ordering::SeqCst) {
        let due: Vec<(String, Arc<AtomicBool>)> = {
            let mut p = inner.policies.lock().unwrap();
            let now = Instant::now();
            let mut fired = Vec::new();
            for (name, pol) in p.iter_mut() {
                if now >= pol.next_due && !pol.inflight.load(Ordering::SeqCst) {
                    pol.inflight.store(true, Ordering::SeqCst);
                    pol.next_due = now + Duration::from_millis(pol.every_ms);
                    pol.fires += 1;
                    fired.push((name.clone(), Arc::clone(&pol.inflight)));
                }
            }
            fired
        };
        for (name, inflight) in due {
            fire(inner, name, inflight);
        }
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Submit one refresh job for `name` through the job store + pool.
fn fire(inner: &Arc<Inner>, name: String, inflight: Arc<AtomicBool>) {
    let job_id = inner.jobs.create(&name);
    inner.jobs.set_detail(
        job_id,
        Json::obj()
            .with("kind", Json::Str("refresh".into()))
            .with("model", Json::Str(name.clone())),
    );
    let scoped = Arc::clone(inner);
    let submitted = inner.pool.submit(move || {
        let _g = obs::span!("refresh.job model={name}");
        scoped.jobs.set_state(job_id, JobState::Running);
        let started = Instant::now();
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            refresh_once(&scoped, &name)
        }));
        let secs = started.elapsed().as_secs_f64();
        match outcome {
            Ok(Ok(())) => {
                scoped.metrics.incr("refreshes", 1);
                scoped.metrics.observe("refresh.secs", secs);
                scoped.jobs.set_state(job_id, JobState::Done { fit_secs: secs });
            }
            Ok(Err(msg)) => {
                scoped.metrics.incr("refresh_errors", 1);
                scoped.jobs.set_state(job_id, JobState::Failed { error: msg });
            }
            Err(panic) => {
                let label = crate::coordinator::router::panic_label(panic);
                scoped.metrics.incr("refresh_errors", 1);
                scoped.jobs.set_state(job_id, JobState::Failed { error: label });
            }
        }
        inflight.store(false, Ordering::SeqCst);
    });
    if !submitted {
        inner.metrics.incr("refresh_errors", 1);
        inner.jobs.set_state(job_id, JobState::Failed { error: "worker pool closed".into() });
        inflight.store(false, Ordering::SeqCst);
    }
}

/// One refresh: look the model up, re-fit via its `refreshed` hook,
/// republish. A missing or refresh-incapable model drops its policy.
fn refresh_once(inner: &Arc<Inner>, name: &str) -> std::result::Result<(), String> {
    let Some(model) = inner.registry.get(name) else {
        inner.policies.lock().unwrap().remove(name);
        obs::log!(
            Warn,
            "coordinator.refresh",
            { "model" => name },
            "refresh policy dropped: model no longer registered"
        );
        return Err(format!("model {name:?} no longer registered; policy dropped"));
    };
    match model.refreshed() {
        Some(Ok(fresh)) => {
            inner.registry.publish(name, Arc::from(fresh));
            Ok(())
        }
        Some(Err(e)) => Err(format!("refresh failed: {e}")),
        None => {
            inner.policies.lock().unwrap().remove(name);
            obs::log!(
                Warn,
                "coordinator.refresh",
                { "model" => name },
                "refresh policy dropped: model does not support refresh"
            );
            Err(format!("model {name:?} does not support refresh; policy dropped"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::gp::mka_gp::MkaGp;
    use crate::kernels::RbfKernel;
    use crate::la::dense::Mat;
    use crate::mka::MkaConfig;

    fn toy_model() -> Arc<dyn crate::gp::GpModel> {
        let n = 48;
        let x = Mat::from_fn(n, 2, |i, j| ((i * 7 + j * 3) % 13) as f64 / 13.0);
        let y: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
        let data = Dataset::new("toy", x, y);
        let cfg = MkaConfig { d_core: 8, block_size: 16, n_threads: 1, ..MkaConfig::default() };
        let gp = MkaGp::fit(&data, &RbfKernel::new(0.8), 1e-2, &cfg).unwrap();
        Arc::new(gp)
    }

    fn rig(min_ms: u64) -> (RefreshScheduler, ModelRegistry, Arc<Metrics>) {
        let registry = ModelRegistry::new();
        let jobs = Arc::new(JobStore::new());
        let pool = Arc::new(WorkerPool::new(1));
        let metrics = Arc::new(Metrics::new());
        let s = RefreshScheduler::new(registry.clone(), jobs, pool, Arc::clone(&metrics), min_ms);
        (s, registry, metrics)
    }

    fn wait_for(mut cond: impl FnMut() -> bool) -> bool {
        for _ in 0..400 {
            if cond() {
                return true;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        false
    }

    #[test]
    fn recurring_refresh_republishes() {
        let (s, registry, metrics) = rig(20);
        registry.publish("m", toy_model());
        // sub-floor request is clamped up
        assert_eq!(s.schedule("m", 1), 20);
        assert!(
            wait_for(|| metrics.counter("refreshes") >= 2),
            "refresh never fired twice: refreshes={} errors={}",
            metrics.counter("refreshes"),
            metrics.counter("refresh_errors")
        );
        assert!(registry.get("m").is_some(), "model must stay published");
        let listed = s.policies_json();
        let arr = listed.as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].str_field("model"), Some("m"));
        assert!(arr[0].usize_field("fires").unwrap() >= 2);
        assert!(s.cancel("m"));
        assert!(!s.cancel("m"));
        assert!(s.is_empty());
    }

    #[test]
    fn missing_model_drops_its_policy() {
        let (s, _registry, metrics) = rig(20);
        s.schedule("ghost", 1);
        assert!(
            wait_for(|| metrics.counter("refresh_errors") >= 1 && s.is_empty()),
            "vanished model must drop its policy"
        );
    }
}
