//! Layered service configuration: built-in defaults ← JSON config file ←
//! `MKA_GP_*` environment variables ← CLI `--key value` overrides.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::cluster::ClusterMethod;
use crate::compress::CompressorKind;
use crate::error::{Error, Result};
use crate::mka::MkaConfig;
use crate::util::json::Json;

/// Coordinator service configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct ServiceConfig {
    /// TCP bind address.
    pub host: String,
    pub port: u16,
    /// Worker threads for fitting jobs.
    pub n_workers: usize,
    /// Compute-pool threads for the dense plane (GEMM, gram tiles, stage
    /// rotations, cascades). 0 = auto-detect hardware parallelism.
    pub n_threads: usize,
    /// Artifacts directory for the XLA engine (None = native kernels only).
    pub artifacts_dir: Option<PathBuf>,
    /// Prediction batcher window (milliseconds) and max batch size.
    pub batch_window_ms: u64,
    pub max_batch: usize,
    /// Backpressure bound: at most this many predict requests may sit in
    /// the batcher queue; submissions beyond it are rejected immediately
    /// with a typed busy error instead of growing the queue without limit.
    pub batch_queue_max: usize,
    /// Default MKA parameters for fit requests that don't override them.
    pub d_core: usize,
    pub block_size: usize,
    pub gamma: f64,
    pub compressor: String,
    pub cluster: String,
    pub seed: u64,
    /// Default budget for `train` requests that don't override it:
    /// total MLL evaluations and Nelder–Mead restarts.
    pub train_max_evals: usize,
    pub train_starts: usize,
    /// Per-training-run factor-cache capacity (LRU entries per family):
    /// the σ²-independent halves of evidence evaluations — noise-free
    /// MKA factorizations, Nyström blocks — kept per length scale so
    /// σ²-only optimizer moves cost zero factorizations. 0 disables.
    pub train_cache_factors: usize,
    /// Per-model predict-cache capacity: how many (test set → noise-free
    /// joint factor) entries each served MKA model keeps, so repeat
    /// test sets cost zero factorizations and σ²-only retunes stay hot.
    /// 0 disables caching.
    pub predict_cache_entries: usize,
    /// Default shard count for `fit`/`train` requests that don't carry a
    /// top-level `"shards"` field. 1 = unsharded serving (the default).
    pub default_shards: usize,
    /// Clustering method for the shard partition
    /// (`kmeans` | `bisect` | `affinity`).
    pub shard_assign: String,
    /// Stream every finished trace to this file in Chrome trace-event
    /// JSON (load in `chrome://tracing` / `ui.perfetto.dev`). Setting it
    /// also turns on tracing for every request, opt-out per request with
    /// `"trace": false`. None = per-request opt-in only.
    pub trace_out: Option<PathBuf>,
    /// How many finished traces the in-memory ring keeps for the `trace`
    /// op.
    pub trace_ring: usize,
    /// How many structured events the log ring keeps for the `logs` op.
    pub log_ring: usize,
    /// Streaming observe drift gate: force a windowed refit when the
    /// pre-update model's mean standardized squared residual on the
    /// incoming batch exceeds this (≈1 when calibrated).
    pub observe_drift_threshold: f64,
    /// Streaming observe compression gate: refit when the extended
    /// factor's core has grown past this multiple of the configured
    /// `d_core`.
    pub observe_max_core_growth: f64,
    /// Refit window for the streaming observe fallback: keep only the
    /// most recent this-many training points (0 = keep everything).
    pub observe_window: usize,
    /// Floor for recurring refresh periods: `refresh` requests asking for
    /// a shorter `every_ms` are clamped up to this.
    pub refresh_min_interval_ms: u64,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            host: "127.0.0.1".into(),
            port: 7470,
            n_workers: 2,
            n_threads: 0,
            artifacts_dir: None,
            batch_window_ms: 5,
            max_batch: 64,
            batch_queue_max: 1024,
            d_core: 64,
            block_size: 256,
            gamma: 0.5,
            compressor: "mmf".into(),
            cluster: "bisect".into(),
            seed: 42,
            train_max_evals: 60,
            train_starts: 3,
            train_cache_factors: 4,
            predict_cache_entries: 8,
            default_shards: 1,
            shard_assign: "kmeans".into(),
            trace_out: None,
            trace_ring: 32,
            log_ring: 256,
            observe_drift_threshold: 16.0,
            observe_max_core_growth: 4.0,
            observe_window: 0,
            refresh_min_interval_ms: 1000,
        }
    }
}

impl ServiceConfig {
    /// Apply a flat key→value map (file/env/CLI all reduce to this).
    pub fn apply(&mut self, kv: &BTreeMap<String, String>) -> Result<()> {
        for (k, v) in kv {
            match k.as_str() {
                "host" => self.host = v.clone(),
                "port" => self.port = parse(k, v)?,
                "n_workers" | "workers" => self.n_workers = parse(k, v)?,
                "n_threads" | "threads" => self.n_threads = parse(k, v)?,
                "artifacts_dir" | "artifacts" => {
                    self.artifacts_dir =
                        if v.is_empty() || v == "none" { None } else { Some(PathBuf::from(v)) }
                }
                "batch_window_ms" => self.batch_window_ms = parse(k, v)?,
                "max_batch" => self.max_batch = parse(k, v)?,
                "batch_queue_max" => self.batch_queue_max = parse(k, v)?,
                "d_core" => self.d_core = parse(k, v)?,
                "block_size" => self.block_size = parse(k, v)?,
                "gamma" => self.gamma = parse(k, v)?,
                "compressor" => self.compressor = v.clone(),
                "cluster" => self.cluster = v.clone(),
                "seed" => self.seed = parse(k, v)?,
                "train_max_evals" => self.train_max_evals = parse(k, v)?,
                "train_starts" => self.train_starts = parse(k, v)?,
                "train_cache_factors" => self.train_cache_factors = parse(k, v)?,
                "predict_cache_entries" => self.predict_cache_entries = parse(k, v)?,
                "default_shards" | "shards" => self.default_shards = parse(k, v)?,
                "shard_assign" => self.shard_assign = v.clone(),
                "trace_out" | "trace-out" => {
                    self.trace_out =
                        if v.is_empty() || v == "none" { None } else { Some(PathBuf::from(v)) }
                }
                "trace_ring" => self.trace_ring = parse(k, v)?,
                "log_ring" => self.log_ring = parse(k, v)?,
                "observe_drift_threshold" => self.observe_drift_threshold = parse(k, v)?,
                "observe_max_core_growth" => self.observe_max_core_growth = parse(k, v)?,
                "observe_window" => self.observe_window = parse(k, v)?,
                "refresh_min_interval_ms" => self.refresh_min_interval_ms = parse(k, v)?,
                _ => {} // unknown keys ignored (forward compatible)
            }
        }
        self.validate()
    }

    /// Load overrides from a JSON file (flat string/number object).
    pub fn apply_file(&mut self, path: &Path) -> Result<()> {
        let text = std::fs::read_to_string(path)?;
        let v = Json::parse(&text)?;
        let obj = v
            .as_obj()
            .ok_or_else(|| Error::Config("config file must be a JSON object".into()))?;
        let mut kv = BTreeMap::new();
        for (k, val) in obj {
            let s = match val {
                Json::Str(s) => s.clone(),
                Json::Num(x) => format!("{x}"),
                Json::Bool(b) => format!("{b}"),
                _ => continue,
            };
            kv.insert(k.clone(), s);
        }
        self.apply(&kv)
    }

    /// Pull `MKA_GP_<KEY>` environment variables.
    pub fn apply_env(&mut self) -> Result<()> {
        let mut kv = BTreeMap::new();
        for (k, v) in std::env::vars() {
            if let Some(rest) = k.strip_prefix("MKA_GP_") {
                kv.insert(rest.to_ascii_lowercase(), v);
            }
        }
        self.apply(&kv)
    }

    pub fn validate(&self) -> Result<()> {
        if !(0.0 < self.gamma && self.gamma < 1.0) {
            return Err(Error::Config(format!("gamma out of range: {}", self.gamma)));
        }
        if self.n_workers == 0 || self.max_batch == 0 {
            return Err(Error::Config("n_workers and max_batch must be >= 1".into()));
        }
        if self.batch_queue_max == 0 {
            return Err(Error::Config("batch_queue_max must be >= 1".into()));
        }
        if self.train_max_evals == 0 || self.train_starts == 0 {
            return Err(Error::Config("train_max_evals and train_starts must be >= 1".into()));
        }
        if self.default_shards == 0 {
            return Err(Error::Config("default_shards must be >= 1".into()));
        }
        if !matches!(self.shard_assign.as_str(), "kmeans" | "bisect" | "affinity") {
            return Err(Error::Config(format!(
                "unknown shard_assign {:?} (kmeans | bisect | affinity)",
                self.shard_assign
            )));
        }
        if self.trace_ring == 0 || self.log_ring == 0 {
            return Err(Error::Config("trace_ring and log_ring must be >= 1".into()));
        }
        self.observe_policy().validate()?;
        Ok(())
    }

    /// The streaming-observe gates implied by the service defaults;
    /// per-request fields on the `observe` op override them.
    pub fn observe_policy(&self) -> crate::gp::ObservePolicy {
        crate::gp::ObservePolicy {
            drift_threshold: self.observe_drift_threshold,
            max_core_growth: self.observe_max_core_growth,
            window: self.observe_window,
        }
    }

    /// The shard-partition clustering method implied by `shard_assign`.
    pub fn shard_assign_method(&self) -> ClusterMethod {
        ClusterMethod::parse(&self.shard_assign)
    }

    /// Compute-pool parallelism with the auto default resolved.
    pub fn resolved_threads(&self) -> usize {
        if self.n_threads == 0 {
            crate::par::default_threads()
        } else {
            self.n_threads
        }
    }

    /// The MkaConfig implied by the service defaults.
    pub fn mka_config(&self) -> MkaConfig {
        MkaConfig {
            d_core: self.d_core,
            block_size: self.block_size,
            gamma: self.gamma,
            compressor: CompressorKind::parse(&self.compressor),
            cluster_method: ClusterMethod::parse(&self.cluster),
            seed: self.seed,
            n_threads: self.resolved_threads(),
            ..MkaConfig::default()
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .with("host", Json::Str(self.host.clone()))
            .with("port", Json::Num(self.port as f64))
            .with("n_workers", Json::Num(self.n_workers as f64))
            .with("n_threads", Json::Num(self.n_threads as f64))
            .with("d_core", Json::Num(self.d_core as f64))
            .with("block_size", Json::Num(self.block_size as f64))
            .with("gamma", Json::Num(self.gamma))
            .with("compressor", Json::Str(self.compressor.clone()))
            .with("cluster", Json::Str(self.cluster.clone()))
            .with("train_max_evals", Json::Num(self.train_max_evals as f64))
            .with("train_starts", Json::Num(self.train_starts as f64))
            .with("train_cache_factors", Json::Num(self.train_cache_factors as f64))
            .with("predict_cache_entries", Json::Num(self.predict_cache_entries as f64))
            .with("batch_queue_max", Json::Num(self.batch_queue_max as f64))
            .with("default_shards", Json::Num(self.default_shards as f64))
            .with("shard_assign", Json::Str(self.shard_assign.clone()))
            .with(
                "trace_out",
                match &self.trace_out {
                    Some(p) => Json::Str(p.display().to_string()),
                    None => Json::Null,
                },
            )
            .with("trace_ring", Json::Num(self.trace_ring as f64))
            .with("log_ring", Json::Num(self.log_ring as f64))
            .with("observe_drift_threshold", Json::Num(self.observe_drift_threshold))
            .with("observe_max_core_growth", Json::Num(self.observe_max_core_growth))
            .with("observe_window", Json::Num(self.observe_window as f64))
            .with("refresh_min_interval_ms", Json::Num(self.refresh_min_interval_ms as f64))
    }
}

fn parse<T: std::str::FromStr>(k: &str, v: &str) -> Result<T> {
    v.parse().map_err(|_| Error::Config(format!("bad value for {k}: {v:?}")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        assert!(ServiceConfig::default().validate().is_ok());
    }

    #[test]
    fn apply_overrides() {
        let mut c = ServiceConfig::default();
        let mut kv = BTreeMap::new();
        kv.insert("port".to_string(), "9999".to_string());
        kv.insert("gamma".to_string(), "0.7".to_string());
        kv.insert("compressor".to_string(), "spca".to_string());
        kv.insert("train_max_evals".to_string(), "25".to_string());
        kv.insert("train_starts".to_string(), "2".to_string());
        kv.insert("train_cache_factors".to_string(), "8".to_string());
        kv.insert("predict_cache_entries".to_string(), "12".to_string());
        kv.insert("batch_queue_max".to_string(), "16".to_string());
        kv.insert("trace-out".to_string(), "/tmp/trace.json".to_string());
        kv.insert("trace_ring".to_string(), "8".to_string());
        kv.insert("unknown_key".to_string(), "ignored".to_string());
        c.apply(&kv).unwrap();
        assert_eq!(c.trace_out, Some(PathBuf::from("/tmp/trace.json")));
        assert_eq!(c.trace_ring, 8);
        let mut kvt = BTreeMap::new();
        kvt.insert("trace_out".to_string(), "none".to_string());
        c.apply(&kvt).unwrap();
        assert_eq!(c.trace_out, None);
        assert_eq!(c.port, 9999);
        assert_eq!(c.gamma, 0.7);
        assert_eq!(c.train_max_evals, 25);
        assert_eq!(c.train_starts, 2);
        assert_eq!(c.train_cache_factors, 8);
        assert_eq!(c.predict_cache_entries, 12);
        assert_eq!(c.batch_queue_max, 16);
        assert_eq!(c.mka_config().compressor, CompressorKind::Spca);
        // a queue bound of zero would deadlock every predict — rejected
        let mut kv3 = BTreeMap::new();
        kv3.insert("batch_queue_max".to_string(), "0".to_string());
        assert!(c.apply(&kv3).is_err());
    }

    #[test]
    fn observe_knobs_layer_and_validate() {
        let mut c = ServiceConfig::default();
        assert_eq!(c.observe_policy().drift_threshold, 16.0);
        let mut kv = BTreeMap::new();
        kv.insert("observe_drift_threshold".to_string(), "2.5".to_string());
        kv.insert("observe_max_core_growth".to_string(), "8".to_string());
        kv.insert("observe_window".to_string(), "512".to_string());
        kv.insert("refresh_min_interval_ms".to_string(), "50".to_string());
        c.apply(&kv).unwrap();
        let p = c.observe_policy();
        assert_eq!(p.drift_threshold, 2.5);
        assert_eq!(p.max_core_growth, 8.0);
        assert_eq!(p.window, 512);
        assert_eq!(c.refresh_min_interval_ms, 50);
        let j = c.to_json();
        assert_eq!(j.num_field("observe_drift_threshold"), Some(2.5));
        assert_eq!(j.usize_field("observe_window"), Some(512));
        // gate thresholds must stay meaningful
        let mut bad = BTreeMap::new();
        bad.insert("observe_drift_threshold".to_string(), "0".to_string());
        assert!(c.clone().apply(&bad).is_err());
        let mut bad2 = BTreeMap::new();
        bad2.insert("observe_max_core_growth".to_string(), "0.5".to_string());
        assert!(c.apply(&bad2).is_err());
    }

    #[test]
    fn bad_values_rejected() {
        let mut c = ServiceConfig::default();
        let mut kv = BTreeMap::new();
        kv.insert("port".to_string(), "not-a-number".to_string());
        assert!(c.apply(&kv).is_err());
        let mut kv2 = BTreeMap::new();
        kv2.insert("gamma".to_string(), "1.5".to_string());
        assert!(c.apply(&kv2).is_err());
    }

    #[test]
    fn file_layering() {
        let dir = std::env::temp_dir().join("mka_gp_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        std::fs::write(&p, r#"{"port": 8123, "compressor": "evd", "gamma": 0.6}"#).unwrap();
        let mut c = ServiceConfig::default();
        c.apply_file(&p).unwrap();
        assert_eq!(c.port, 8123);
        assert_eq!(c.compressor, "evd");
        assert_eq!(c.gamma, 0.6);
    }

    #[test]
    fn json_roundtrip_summary() {
        let c = ServiceConfig::default();
        let j = c.to_json();
        assert_eq!(j.usize_field("port"), Some(7470));
        assert_eq!(j.str_field("compressor"), Some("mmf"));
    }
}
