//! Dynamic prediction batcher: concurrent predict requests against the
//! same model are coalesced into a single batched `predict` call.
//!
//! For MKA-GP this is not just a throughput trick — the §4.1 predictor
//! factorizes the joint train/test kernel once per *batch*, so b requests
//! of p points each cost one factorization instead of b.
//!
//! Batching windows are **per model**: every submission is stamped with a
//! deadline (`enqueue time + its model's window`, the service default
//! unless a `"batch_window_ms"` override was registered at fit time) and
//! the flusher parks until the earliest deadline, draining exactly the
//! ripe items. A latency-sensitive model can run a zero window while a
//! throughput-oriented one on the same service accumulates larger
//! batches.
//!
//! The queue is **bounded** (`ServiceConfig.batch_queue_max`): a
//! submission that would grow the pending set past the bound is rejected
//! immediately with [`Error::Busy`] — the router surfaces it as a typed
//! `"busy": true` response with the current queue depth and a
//! depth-scaled `retry_after_ms` — instead of queueing unbounded work
//! behind a slow model and amplifying the overload.

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::jobs::ModelRegistry;
use super::metrics::Metrics;
use crate::error::{Error, Result};
use crate::gp::Prediction;
use crate::la::dense::Mat;
use crate::obs;

struct Pending {
    model: String,
    x: Mat,
    resp: mpsc::Sender<Result<Prediction>>,
    /// Span context of the submitting request (inactive when untraced):
    /// the flusher thread re-enters it so the batched predict's spans
    /// parent back to the request that crossed the batching boundary.
    ctx: obs::SpanCtx,
    /// When the request entered the queue. Always recorded — the
    /// `op.predict_queue_secs` histogram needs it whether or not the
    /// request is traced.
    enqueued: Instant,
    /// When this item must flush: `enqueued + window_for(model)`.
    deadline: Instant,
}

#[derive(Default)]
struct Queue {
    items: Vec<Pending>,
    shutdown: bool,
}

/// The batcher: owns a flusher thread and a bounded pending queue.
pub struct PredictBatcher {
    queue: Arc<(Mutex<Queue>, Condvar)>,
    metrics: Arc<Metrics>,
    queue_max: usize,
    /// Service-wide batching window, used for models without an override.
    default_window: Duration,
    /// Per-model window overrides (`"batch_window_ms"` at fit time).
    /// Consulted once per submission to stamp the item's deadline, so a
    /// change applies to future submissions, never to parked items.
    windows: Mutex<BTreeMap<String, Duration>>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl PredictBatcher {
    pub fn start(
        registry: ModelRegistry,
        metrics: Arc<Metrics>,
        window: Duration,
        max_batch: usize,
        queue_max: usize,
    ) -> PredictBatcher {
        let queue: Arc<(Mutex<Queue>, Condvar)> = Arc::new(Default::default());
        let q2 = Arc::clone(&queue);
        let m2 = Arc::clone(&metrics);
        let worker = std::thread::Builder::new()
            .name("predict-batcher".into())
            .spawn(move || flusher(q2, registry, m2, max_batch))
            .expect("spawn batcher");
        PredictBatcher {
            queue,
            metrics,
            queue_max: queue_max.max(1),
            default_window: window,
            windows: Mutex::new(BTreeMap::new()),
            worker: Some(worker),
        }
    }

    /// Install a per-model batching window, overriding the service
    /// default for that model's future submissions.
    pub fn set_model_window(&self, model: &str, window: Duration) {
        self.windows.lock().unwrap().insert(model.to_string(), window);
    }

    /// Drop a model's window override (back to the service default).
    /// Idempotent; called when the model is dropped or re-fit without one.
    pub fn clear_model_window(&self, model: &str) {
        self.windows.lock().unwrap().remove(model);
    }

    /// The batching window in effect for `model`.
    pub fn window_for(&self, model: &str) -> Duration {
        self.windows.lock().unwrap().get(model).copied().unwrap_or(self.default_window)
    }

    /// Requests currently parked in the queue. Admission control reads
    /// this to scale `retry_after_ms` on busy responses.
    pub fn queue_depth(&self) -> usize {
        let (lock, _) = &*self.queue;
        lock.lock().unwrap().items.len()
    }

    /// Enqueue a prediction; the result arrives on the returned receiver.
    /// When the pending queue is at `queue_max`, the request is rejected
    /// immediately with [`Error::Busy`] (backpressure) rather than queued.
    pub fn submit(&self, model: &str, x: Mat) -> mpsc::Receiver<Result<Prediction>> {
        let (tx, rx) = mpsc::channel();
        let window = self.window_for(model);
        let (lock, cv) = &*self.queue;
        let mut q = lock.lock().unwrap();
        if q.shutdown {
            let _ = tx.send(Err(Error::Coordinator("batcher shut down".into())));
        } else if q.items.len() >= self.queue_max {
            self.metrics.incr("predict_rejected", 1);
            obs::log!(
                Warn,
                "coordinator.batcher",
                { "pending" => q.items.len(), "bound" => self.queue_max, "model" => model },
                "predict queue full; rejecting with busy"
            );
            let _ = tx.send(Err(Error::Busy(format!(
                "predict queue full ({} pending, bound {}); retry later",
                q.items.len(),
                self.queue_max
            ))));
        } else {
            let ctx = obs::current_ctx();
            let enqueued = Instant::now();
            q.items.push(Pending {
                model: model.to_string(),
                x,
                resp: tx,
                ctx,
                enqueued,
                deadline: enqueued + window,
            });
            cv.notify_one();
        }
        rx
    }

    /// Synchronous convenience wrapper.
    pub fn predict(&self, model: &str, x: Mat) -> Result<Prediction> {
        self.submit(model, x)
            .recv()
            .map_err(|_| Error::Coordinator("batcher dropped request".into()))?
    }
}

impl Drop for PredictBatcher {
    fn drop(&mut self) {
        {
            let (lock, cv) = &*self.queue;
            lock.lock().unwrap().shutdown = true;
            cv.notify_all();
        }
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn flusher(
    queue: Arc<(Mutex<Queue>, Condvar)>,
    registry: ModelRegistry,
    metrics: Arc<Metrics>,
    max_batch: usize,
) {
    let (lock, cv) = &*queue;
    loop {
        // Park until something is ripe: the earliest deadline among the
        // queued items governs the wait, and a new submission (whose
        // window may be shorter) re-notifies so the wait is recomputed.
        let drained: Vec<Pending> = {
            let mut q = lock.lock().unwrap();
            loop {
                if q.shutdown {
                    if q.items.is_empty() {
                        return;
                    }
                    // Shutdown flushes everything still parked, windows
                    // ignored — Drop must not stall out a batching window.
                    let take = q.items.len().min(max_batch);
                    break q.items.drain(..take).collect();
                }
                if q.items.is_empty() {
                    q = cv.wait(q).unwrap();
                    continue;
                }
                let now = Instant::now();
                let next = q.items.iter().map(|p| p.deadline).min().unwrap();
                if next > now {
                    q = cv.wait_timeout(q, next - now).unwrap().0;
                    continue;
                }
                // Drain the ripe items in arrival order, up to max_batch;
                // items still inside their window stay parked. Leftover
                // ripe items (a burst past max_batch) flush on the next
                // iteration without waiting a new window.
                let mut ripe = Vec::new();
                let mut i = 0;
                while i < q.items.len() && ripe.len() < max_batch {
                    if q.items[i].deadline <= now {
                        ripe.push(q.items.remove(i));
                    } else {
                        i += 1;
                    }
                }
                break ripe;
            }
        };
        // Non-empty by construction (both break arms drain >= 1 item).
        metrics.incr("batches", 1);
        metrics.observe("batch_size", drained.len() as f64);
        for p in &drained {
            metrics.observe("op.predict_queue_secs", p.enqueued.elapsed().as_secs_f64());
        }

        // Group by model.
        let mut groups: std::collections::BTreeMap<String, Vec<Pending>> = Default::default();
        for p in drained {
            groups.entry(p.model.clone()).or_default().push(p);
        }
        for (model_name, group) in groups {
            let model = match registry.get(&model_name) {
                Some(m) => m,
                None => {
                    for p in group {
                        let _ = p
                            .resp
                            .send(Err(Error::Coordinator(format!("no model {model_name}"))));
                    }
                    continue;
                }
            };
            // Dimension consistency check.
            let dim = group[0].x.cols;
            let (ok, bad): (Vec<Pending>, Vec<Pending>) =
                group.into_iter().partition(|p| p.x.cols == dim && p.x.rows > 0);
            for p in bad {
                let _ = p.resp.send(Err(Error::Coordinator("bad input shape".into())));
            }
            if ok.is_empty() {
                continue;
            }
            // Concatenate, predict once, split.
            let total: usize = ok.iter().map(|p| p.x.rows).sum();
            let mut xall = Mat::zeros(total, dim);
            let mut off = 0;
            for p in &ok {
                xall.set_block(off, 0, &p.x);
                off += p.x.rows;
            }
            // Parent the batched predict back to the first traced
            // submitter in the group (a batch may carry several traces;
            // the earliest wins). The guard must drop before the
            // responses go out: a reply releases the submitter, which
            // may finish its trace while a late span push would be lost.
            let hits0 = crate::gp::predict_cache::predict_cache_hits();
            let misses0 = crate::gp::predict_cache::predict_cache_misses();
            let t = crate::util::timer::Timer::start();
            let pred = {
                let _obs = ok
                    .iter()
                    .find(|p| p.ctx.is_active())
                    .map(|p| obs::enter_job(&p.ctx, "batch.predict", Some(p.enqueued)));
                model.predict(&xall)
            };
            let secs = t.elapsed_secs();
            metrics.observe("predict_secs", secs);
            // Split served latency by joint-factor cache outcome so the
            // hot path is visible as its own histogram. The counters are
            // process-global (concurrent fits elsewhere can blur a
            // delta), so a batch that looks neither purely cached nor
            // cold lands only in the combined histogram.
            let dh = crate::gp::predict_cache::predict_cache_hits().wrapping_sub(hits0);
            let dm = crate::gp::predict_cache::predict_cache_misses().wrapping_sub(misses0);
            if dm == 0 && dh > 0 {
                metrics.observe("op.predict_cached_secs", secs);
            } else if dm > 0 {
                metrics.observe("op.predict_cold_secs", secs);
            }
            metrics.incr("predictions", total as u64);
            let mut off = 0;
            for p in ok {
                let r = p.x.rows;
                let slice = Prediction {
                    mean: pred.mean[off..off + r].to_vec(),
                    var: pred.var[off..off + r].to_vec(),
                };
                off += r;
                let _ = p.resp.send(Ok(slice));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::GpModel;

    /// Model that records batch sizes and returns the row sums.
    struct RecordingModel {
        calls: Arc<Mutex<Vec<usize>>>,
    }
    impl GpModel for RecordingModel {
        fn predict(&self, x: &Mat) -> Prediction {
            self.calls.lock().unwrap().push(x.rows);
            Prediction {
                mean: (0..x.rows).map(|i| x.row(i).iter().sum()).collect(),
                var: vec![1.0; x.rows],
            }
        }
        fn name(&self) -> String {
            "rec".into()
        }
    }

    fn setup(window_ms: u64) -> (PredictBatcher, Arc<Mutex<Vec<usize>>>) {
        let (b, calls, _) = setup_metrics(window_ms, 1024);
        (b, calls)
    }

    fn setup_bounded(
        window_ms: u64,
        queue_max: usize,
    ) -> (PredictBatcher, Arc<Mutex<Vec<usize>>>) {
        let (b, calls, _) = setup_metrics(window_ms, queue_max);
        (b, calls)
    }

    fn setup_metrics(
        window_ms: u64,
        queue_max: usize,
    ) -> (PredictBatcher, Arc<Mutex<Vec<usize>>>, Arc<Metrics>) {
        let reg = ModelRegistry::new();
        let calls = Arc::new(Mutex::new(Vec::new()));
        reg.publish("m", Arc::new(RecordingModel { calls: Arc::clone(&calls) }));
        let metrics = Arc::new(Metrics::new());
        let b = PredictBatcher::start(
            reg,
            Arc::clone(&metrics),
            Duration::from_millis(window_ms),
            64,
            queue_max,
        );
        (b, calls, metrics)
    }

    #[test]
    fn single_request_roundtrip() {
        let (b, _) = setup(0);
        let x = Mat::from_rows(&[&[1.0, 2.0]]);
        let pred = b.predict("m", x).unwrap();
        assert_eq!(pred.mean, vec![3.0]);
    }

    #[test]
    fn concurrent_requests_are_coalesced() {
        let (b, calls) = setup(20);
        let rxs: Vec<_> = (0..8)
            .map(|i| b.submit("m", Mat::from_rows(&[&[i as f64, 1.0]])))
            .collect();
        let mut outs = Vec::new();
        for rx in rxs {
            outs.push(rx.recv().unwrap().unwrap());
        }
        for (i, o) in outs.iter().enumerate() {
            assert_eq!(o.mean, vec![i as f64 + 1.0]);
        }
        // All 8 should have landed in few (ideally 1) batched calls.
        let c = calls.lock().unwrap();
        assert!(c.len() < 8, "batches: {c:?}");
        assert_eq!(c.iter().sum::<usize>(), 8);
    }

    #[test]
    fn unknown_model_errors() {
        let (b, _) = setup(0);
        let err = b.predict("ghost", Mat::from_rows(&[&[0.0]]));
        assert!(err.is_err());
    }

    #[test]
    fn mismatched_dims_rejected_individually() {
        let (b, _) = setup(10);
        let rx_ok = b.submit("m", Mat::from_rows(&[&[1.0, 1.0]]));
        let rx_bad = b.submit("m", Mat::from_rows(&[&[1.0, 2.0, 3.0]]));
        let ok = rx_ok.recv().unwrap();
        let bad = rx_bad.recv().unwrap();
        // one of the two dims wins the batch; the other errors out —
        // exactly one Ok and one Err regardless of arrival order.
        assert!(ok.is_ok() != bad.is_ok() || (ok.is_ok() && bad.is_err()));
    }

    /// Regression (backpressure): submissions beyond `queue_max` must be
    /// rejected immediately with the typed busy error, while everything
    /// already queued is still answered. A long window keeps the flusher
    /// parked so the pending set is deterministic.
    #[test]
    fn backpressure_rejects_when_queue_full() {
        let (b, calls) = setup_bounded(10_000, 2);
        let rx1 = b.submit("m", Mat::from_rows(&[&[1.0, 1.0]]));
        let rx2 = b.submit("m", Mat::from_rows(&[&[2.0, 2.0]]));
        assert_eq!(b.queue_depth(), 2);
        // Third submission exceeds the bound: rejected without waiting.
        let rx3 = b.submit("m", Mat::from_rows(&[&[3.0, 3.0]]));
        match rx3.recv().expect("rejection must be delivered") {
            Err(Error::Busy(msg)) => assert!(msg.contains("queue full"), "{msg}"),
            other => panic!("expected Busy rejection, got {other:?}"),
        }
        // Shutdown flushes the two accepted requests (window cut short).
        drop(b);
        assert_eq!(rx1.recv().unwrap().unwrap().mean, vec![2.0]);
        assert_eq!(rx2.recv().unwrap().unwrap().mean, vec![4.0]);
        assert_eq!(calls.lock().unwrap().iter().sum::<usize>(), 2);
    }

    #[test]
    fn shutdown_rejects_new_work() {
        let (b, _) = setup(0);
        drop(b);
        // Batcher dropped: nothing to assert beyond not hanging.
    }

    /// Regression: dropping the batcher while the flusher slept out a
    /// non-zero batching window used to block shutdown for the whole
    /// window. The condvar wait must cut it short, and the pending
    /// request must still get an answer.
    #[test]
    fn shutdown_mid_window_is_prompt() {
        let window_ms = 5_000;
        let (b, calls) = setup(window_ms);
        let rx = b.submit("m", Mat::from_rows(&[&[2.0, 3.0]]));
        // Give the flusher a moment to park on the item's deadline.
        std::thread::sleep(Duration::from_millis(50));
        let t0 = Instant::now();
        drop(b); // join()s the flusher
        let waited = t0.elapsed();
        assert!(
            waited < Duration::from_millis(window_ms / 2),
            "shutdown stalled {waited:?} (window {window_ms}ms)"
        );
        let pred = rx.recv().expect("response channel closed").expect("predict failed");
        assert_eq!(pred.mean, vec![5.0]);
        assert_eq!(calls.lock().unwrap().len(), 1);
    }

    /// Regression: queue wait used to be recorded only for traced
    /// requests. A plain untraced predict must land in the
    /// `op.predict_queue_secs` histogram.
    #[test]
    fn queue_wait_recorded_without_tracing() {
        let (b, _, m) = setup_metrics(5, 1024);
        b.predict("m", Mat::from_rows(&[&[1.0, 1.0]])).unwrap();
        b.predict("m", Mat::from_rows(&[&[2.0, 2.0]])).unwrap();
        let p50 = m.quantile("op.predict_queue_secs", 0.5).expect("queue-wait histogram");
        // The 5ms batching window bounds the wait from below (modulo
        // scheduler slop it cannot be hugely above it either, but only
        // the lower bound is deterministic enough to assert).
        assert!(p50 >= 0.0);
        assert!(m.quantile("op.predict_queue_secs", 0.99).is_some());
    }

    /// A per-model window override beats the service default for that
    /// model's future submissions, and clearing it restores the default.
    #[test]
    fn per_model_window_overrides_default() {
        // Service default parks items effectively forever; the override
        // drops this model to an immediate flush.
        let (b, calls, _) = setup_metrics(60_000, 1024);
        b.set_model_window("m", Duration::ZERO);
        let pred = b.predict("m", Mat::from_rows(&[&[1.0, 2.0]])).unwrap();
        assert_eq!(pred.mean, vec![3.0]);
        assert_eq!(calls.lock().unwrap().len(), 1);
        // Clearing restores the default: the item stays parked.
        b.clear_model_window("m");
        let rx = b.submit("m", Mat::from_rows(&[&[1.0, 1.0]]));
        std::thread::sleep(Duration::from_millis(80));
        assert_eq!(b.queue_depth(), 1, "default window must hold the item");
        drop(b); // shutdown flushes it
        assert_eq!(rx.recv().unwrap().unwrap().mean, vec![2.0]);
    }

    /// Deadlines are per item: a ripe short-window item flushes past an
    /// unripe long-window one queued ahead of it, which stays parked.
    #[test]
    fn ripe_items_flush_past_unripe_ones() {
        let reg = ModelRegistry::new();
        let calls = Arc::new(Mutex::new(Vec::new()));
        reg.publish("fast", Arc::new(RecordingModel { calls: Arc::clone(&calls) }));
        reg.publish("slow", Arc::new(RecordingModel { calls: Arc::clone(&calls) }));
        let b = PredictBatcher::start(
            reg,
            Arc::new(Metrics::new()),
            Duration::from_millis(60_000),
            64,
            1024,
        );
        b.set_model_window("fast", Duration::ZERO);
        let rx_slow = b.submit("slow", Mat::from_rows(&[&[5.0, 5.0]]));
        let pred = b.predict("fast", Mat::from_rows(&[&[1.0, 2.0]])).unwrap();
        assert_eq!(pred.mean, vec![3.0]);
        assert_eq!(b.queue_depth(), 1, "slow item must still be parked in its window");
        drop(b); // shutdown flushes the parked item
        assert_eq!(rx_slow.recv().unwrap().unwrap().mean, vec![10.0]);
    }
}
