//! The serving coordinator — the L3 "production" layer around the MKA-GP
//! library: a JSON-over-TCP request [`server`], a request [`router`], an
//! async fit [`jobs`] store over a [`pool`] of workers, a dynamic
//! prediction [`batcher`] (concurrent predicts against one model share a
//! single joint-kernel factorization), a recurring [`refresh`] scheduler
//! for streaming models, a [`metrics`] registry and a layered [`config`]
//! system.

pub mod batcher;
pub mod config;
pub mod jobs;
pub mod metrics;
pub mod pool;
pub mod refresh;
pub mod router;
pub mod server;

pub use batcher::PredictBatcher;
pub use config::ServiceConfig;
pub use jobs::{JobState, JobStore, ModelRegistry};
pub use metrics::Metrics;
pub use pool::WorkerPool;
pub use refresh::RefreshScheduler;
pub use router::Router;
pub use server::{Client, Server};
