//! Fixed-size worker thread pool for background jobs (model fitting).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A simple shared-queue thread pool.
pub struct WorkerPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    pub fn new(n: usize) -> WorkerPool {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("mka-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        WorkerPool { tx: Some(tx), workers }
    }

    /// Submit a job; returns false if the pool is shutting down.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) -> bool {
        match &self.tx {
            Some(tx) => tx.send(Box::new(job)).is_ok(),
            None => false,
        }
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.tx.take(); // close the channel
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = WorkerPool::new(4);
        let count = Arc::new(AtomicUsize::new(0));
        let (done_tx, done_rx) = mpsc::channel();
        for _ in 0..50 {
            let c = Arc::clone(&count);
            let d = done_tx.clone();
            assert!(pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = d.send(());
            }));
        }
        for _ in 0..50 {
            done_rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(count.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = WorkerPool::new(2);
        assert_eq!(pool.n_workers(), 2);
        let flag = Arc::new(AtomicUsize::new(0));
        let f = Arc::clone(&flag);
        pool.submit(move || {
            f.store(1, Ordering::SeqCst);
        });
        drop(pool); // must join, not hang
        assert_eq!(flag.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn zero_requested_becomes_one() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.n_workers(), 1);
    }
}
