//! TCP server + client: newline-delimited JSON over a socket, one thread
//! per connection (request volume here is model-ops, not packet-ops).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use super::router::Router;
use crate::error::{Error, Result};
use crate::util::json::Json;

/// Running server handle.
pub struct Server {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and start serving in background threads. Port 0 picks a free
    /// port (the bound address is available via [`Server::addr`]).
    pub fn start(router: Arc<Router>, host: &str, port: u16) -> Result<Server> {
        let listener = TcpListener::bind((host, port))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let accept_thread = std::thread::Builder::new()
            .name("mka-accept".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            let router = Arc::clone(&router);
                            let _ = std::thread::Builder::new()
                                .name("mka-conn".into())
                                .spawn(move || serve_conn(stream, router));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })
            .map_err(|e| Error::Coordinator(format!("spawn acceptor: {e}")))?;
        Ok(Server { addr, stop, accept_thread: Some(accept_thread) })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn serve_conn(stream: TcpStream, router: Arc<Router>) {
    let peer = stream.peer_addr().ok();
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => break, // connection closed
            Ok(_) => {}
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let response = match Json::parse(trimmed) {
            Ok(req) => router.handle(&req),
            Err(e) => Json::obj()
                .with("ok", Json::Bool(false))
                .with("error", Json::Str(format!("bad json: {e}"))),
        };
        let mut out = response.dump();
        out.push('\n');
        if writer.write_all(out.as_bytes()).is_err() {
            break;
        }
    }
    let _ = peer;
}

/// Minimal blocking client for tests, examples and the CLI.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { reader, writer: stream })
    }

    /// Send one request, wait for one response.
    pub fn call(&mut self, req: &Json) -> Result<Json> {
        let mut line = req.dump();
        line.push('\n');
        self.writer.write_all(line.as_bytes())?;
        let mut resp = String::new();
        self.reader.read_line(&mut resp)?;
        if resp.is_empty() {
            return Err(Error::Protocol("server closed connection".into()));
        }
        Ok(Json::parse(resp.trim())?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::ServiceConfig;

    fn start_server() -> (Server, String) {
        let cfg = ServiceConfig { batch_window_ms: 0, n_workers: 1, ..Default::default() };
        let router = Arc::new(Router::new(cfg));
        let server = Server::start(router, "127.0.0.1", 0).unwrap();
        let addr = format!("{}", server.addr());
        (server, addr)
    }

    #[test]
    fn ping_over_tcp() {
        let (_server, addr) = start_server();
        let mut client = Client::connect(&addr).unwrap();
        let resp = client.call(&Json::parse(r#"{"op":"ping"}"#).unwrap()).unwrap();
        assert_eq!(resp.get("pong"), Some(&Json::Bool(true)));
    }

    #[test]
    fn bad_json_reported() {
        let (_server, addr) = start_server();
        let stream = TcpStream::connect(&addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        writer.write_all(b"this is not json\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(line.trim()).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn multiple_requests_one_connection() {
        let (_server, addr) = start_server();
        let mut client = Client::connect(&addr).unwrap();
        for _ in 0..5 {
            let resp = client.call(&Json::parse(r#"{"op":"models"}"#).unwrap()).unwrap();
            assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        }
    }

    #[test]
    fn concurrent_clients() {
        let (_server, addr) = start_server();
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let mut c = Client::connect(&addr).unwrap();
                    let r = c.call(&Json::parse(r#"{"op":"ping"}"#).unwrap()).unwrap();
                    assert_eq!(r.get("pong"), Some(&Json::Bool(true)));
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn shutdown_stops_accepting() {
        let (mut server, addr) = start_server();
        server.shutdown();
        std::thread::sleep(std::time::Duration::from_millis(30));
        // New connections may connect (OS backlog) but must not be served.
        if let Ok(mut c) = Client::connect(&addr) {
            let r = c.call(&Json::parse(r#"{"op":"ping"}"#).unwrap());
            assert!(r.is_err() || r.is_ok()); // just must not hang
        }
    }
}
