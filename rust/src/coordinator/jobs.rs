//! Job store and model registry: fit requests run asynchronously on the
//! worker pool; finished models are published under a name and served by
//! the prediction path.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::gp::GpModel;
use crate::util::json::Json;

/// Lifecycle of a fit job.
#[derive(Clone, Debug, PartialEq)]
pub enum JobState {
    Queued,
    Running,
    Done { fit_secs: f64 },
    Failed { error: String },
}

impl JobState {
    pub fn label(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done { .. } => "done",
            JobState::Failed { .. } => "failed",
        }
    }
}

/// One tracked job: owning model name, lifecycle state, and an optional
/// result payload (e.g. the per-candidate trace of a `train` job) merged
/// into the `job` op's response.
#[derive(Clone, Debug)]
struct JobEntry {
    model: String,
    state: JobState,
    detail: Option<Json>,
}

/// Tracks job states by id.
#[derive(Default)]
pub struct JobStore {
    next_id: Mutex<u64>,
    jobs: Mutex<BTreeMap<u64, JobEntry>>,
}

impl JobStore {
    pub fn new() -> JobStore {
        JobStore::default()
    }

    /// Create a new job (Queued) for the given model name; returns its id.
    pub fn create(&self, model: &str) -> u64 {
        let mut id = self.next_id.lock().unwrap();
        *id += 1;
        let jid = *id;
        self.jobs.lock().unwrap().insert(
            jid,
            JobEntry { model: model.to_string(), state: JobState::Queued, detail: None },
        );
        jid
    }

    pub fn set_state(&self, id: u64, state: JobState) {
        if let Some(entry) = self.jobs.lock().unwrap().get_mut(&id) {
            entry.state = state;
        }
    }

    /// Attach a result payload; its top-level fields are merged into the
    /// `job` op's JSON (set before the terminal state so pollers never
    /// observe `done` without the detail).
    pub fn set_detail(&self, id: u64, detail: Json) {
        if let Some(entry) = self.jobs.lock().unwrap().get_mut(&id) {
            entry.detail = Some(detail);
        }
    }

    pub fn get(&self, id: u64) -> Option<(String, JobState)> {
        self.jobs.lock().unwrap().get(&id).map(|e| (e.model.clone(), e.state.clone()))
    }

    pub fn to_json(&self, id: u64) -> Json {
        let entry = self.jobs.lock().unwrap().get(&id).cloned();
        match entry {
            None => Json::obj().with("error", Json::Str(format!("no job {id}"))),
            Some(JobEntry { model, state, detail }) => {
                let mut j = Json::obj()
                    .with("job_id", Json::Num(id as f64))
                    .with("model", Json::Str(model))
                    .with("state", Json::Str(state.label().to_string()));
                match state {
                    JobState::Done { fit_secs } => {
                        j.set("fit_secs", Json::Num(fit_secs));
                    }
                    JobState::Failed { error } => {
                        j.set("error", Json::Str(error));
                    }
                    _ => {}
                }
                if let Some(d) = detail {
                    if let Some(fields) = d.as_obj().cloned() {
                        for (k, v) in fields {
                            j.set(&k, v);
                        }
                    } else {
                        j.set("detail", d);
                    }
                }
                j
            }
        }
    }
}

/// Published, fitted models by name.
#[derive(Default, Clone)]
pub struct ModelRegistry {
    inner: Arc<Mutex<BTreeMap<String, Arc<dyn GpModel>>>>,
}

impl ModelRegistry {
    pub fn new() -> ModelRegistry {
        ModelRegistry::default()
    }

    pub fn publish(&self, name: &str, model: Arc<dyn GpModel>) {
        self.inner.lock().unwrap().insert(name.to_string(), model);
    }

    pub fn get(&self, name: &str) -> Option<Arc<dyn GpModel>> {
        self.inner.lock().unwrap().get(name).cloned()
    }

    pub fn remove(&self, name: &str) -> bool {
        self.inner.lock().unwrap().remove(name).is_some()
    }

    pub fn names(&self) -> Vec<String> {
        self.inner.lock().unwrap().keys().cloned().collect()
    }

    /// Snapshot of every published `(name, model)` pair in name order —
    /// the `models` op reads per-model metadata through this.
    pub fn entries(&self) -> Vec<(String, Arc<dyn GpModel>)> {
        self.inner.lock().unwrap().iter().map(|(k, v)| (k.clone(), Arc::clone(v))).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::Prediction;
    use crate::la::dense::Mat;

    struct DummyModel;
    impl GpModel for DummyModel {
        fn predict(&self, x: &Mat) -> Prediction {
            Prediction { mean: vec![0.0; x.rows], var: vec![1.0; x.rows] }
        }
        fn name(&self) -> String {
            "dummy".into()
        }
    }

    #[test]
    fn job_lifecycle() {
        let store = JobStore::new();
        let id = store.create("m1");
        assert_eq!(store.get(id).unwrap().1, JobState::Queued);
        store.set_state(id, JobState::Running);
        assert_eq!(store.get(id).unwrap().1.label(), "running");
        store.set_state(id, JobState::Done { fit_secs: 0.5 });
        let j = store.to_json(id);
        assert_eq!(j.str_field("state"), Some("done"));
        assert_eq!(j.num_field("fit_secs"), Some(0.5));
    }

    #[test]
    fn unique_ids() {
        let store = JobStore::new();
        let a = store.create("a");
        let b = store.create("b");
        assert_ne!(a, b);
    }

    #[test]
    fn failed_state_carries_error() {
        let store = JobStore::new();
        let id = store.create("m");
        store.set_state(id, JobState::Failed { error: "boom".into() });
        let j = store.to_json(id);
        assert_eq!(j.str_field("state"), Some("failed"));
        assert_eq!(j.str_field("error"), Some("boom"));
    }

    #[test]
    fn unknown_job_json() {
        let store = JobStore::new();
        assert!(store.to_json(99).str_field("error").is_some());
    }

    #[test]
    fn detail_fields_merge_into_job_json() {
        let store = JobStore::new();
        let id = store.create("m");
        store.set_detail(
            id,
            Json::obj().with(
                "train",
                Json::obj().with("evals", Json::Num(7.0)).with("best_mll", Json::Num(-12.5)),
            ),
        );
        store.set_state(id, JobState::Done { fit_secs: 0.2 });
        let j = store.to_json(id);
        assert_eq!(j.str_field("state"), Some("done"));
        let train = j.get("train").expect("train detail merged");
        assert_eq!(train.num_field("evals"), Some(7.0));
        assert_eq!(train.num_field("best_mll"), Some(-12.5));
        // jobs without detail are unaffected
        let id2 = store.create("m2");
        assert!(store.to_json(id2).get("train").is_none());
    }

    #[test]
    fn registry_publish_get_remove() {
        let reg = ModelRegistry::new();
        assert!(reg.get("m").is_none());
        reg.publish("m", Arc::new(DummyModel));
        assert!(reg.get("m").is_some());
        assert_eq!(reg.names(), vec!["m".to_string()]);
        assert!(reg.remove("m"));
        assert!(!reg.remove("m"));
    }
}
