//! `mka-gp` — command line interface.
//!
//! Subcommands:
//!   serve       start the coordinator (JSON-over-TCP GP service)
//!   fit         fit a model on a CSV (last column = target) and report CV metrics
//!   train       learn (lengthscale, σ²) by MLL maximization or grid CV, then fit
//!   experiment  run a paper experiment: table1 | fig1 | fig2
//!   selftest    verify the AOT artifacts against native kernels
//!   info        print config / artifact status

// Match the library's lint posture (CI runs `cargo clippy -- -D warnings`).
#![allow(clippy::style, clippy::complexity, clippy::perf)]

use std::path::Path;
use std::sync::Arc;

use mka_gp::coordinator::{Router, Server, ServiceConfig};
use mka_gp::data::loader;
use mka_gp::error::Result;
use mka_gp::experiments::methods::Method;
use mka_gp::gp::cv::HyperParams;
use mka_gp::gp::metrics::{mnlp, smse};
use mka_gp::kernels::gram::rbf_tile_native;
use mka_gp::la::dense::Mat;
use mka_gp::runtime::engine::XlaEngine;
use mka_gp::util::{Args, Rng};

fn main() {
    let args = Args::from_env(true);
    let code = match args.subcommand.as_deref() {
        Some("serve") => cmd_serve(&args),
        Some("fit") => cmd_fit(&args),
        Some("train") => cmd_train(&args),
        Some("experiment") => cmd_experiment(&args),
        Some("selftest") => cmd_selftest(&args),
        Some("info") => cmd_info(&args),
        _ => {
            print_usage();
            Ok(())
        }
    }
    .map(|_| 0)
    .unwrap_or_else(|e| {
        eprintln!("error: {e}");
        1
    });
    std::process::exit(code);
}

fn print_usage() {
    println!(
        "mka-gp — Multiresolution Kernel Approximation for GP regression\n\
         \n\
         USAGE: mka-gp <subcommand> [--options]\n\
         \n\
         serve       --port 7470 --workers 2 --config cfg.json --artifacts artifacts\n\
                     --trace-out trace.json (Chrome trace-event stream; implies trace-all)\n\
         fit         --data file.csv --method mka|full|sor|fitc|pitc|meka --k 32\n\
         train       --data file.csv | --synth N [--dim D] --method mka --k 32\n\
                     --selection mll|mll-grad|cv [--ard] --max-evals 60\n\
                     --starts 3 --folds 5 [--assert-converged] [--assert-cache-hit]\n\
         experiment  --name table1|fig1|fig2 [--full] [--max-n N] [--datasets a,b]\n\
                     [--selection cv|mll|mll-grad] [--shards K]\n\
         selftest    --artifacts artifacts\n\
         info        [--artifacts artifacts]"
    );
}

fn service_config(args: &Args) -> Result<ServiceConfig> {
    let mut cfg = ServiceConfig::default();
    if let Some(path) = args.get("config") {
        cfg.apply_file(Path::new(path))?;
    }
    cfg.apply_env()?;
    cfg.apply(args.options())?;
    Ok(cfg)
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = service_config(args)?;
    let host = cfg.host.clone();
    let port = cfg.port;
    println!("mka-gp coordinator on {host}:{port} ({} workers)", cfg.n_workers);
    // Keep the engine alive for the life of the server when available.
    let _engine = cfg.artifacts_dir.as_ref().and_then(|dir| match XlaEngine::start(dir) {
        Ok(engine) => {
            println!("XLA engine ready ({} artifacts)", dir.display());
            Some(engine)
        }
        Err(e) => {
            println!("XLA engine unavailable ({e}); using native kernels");
            None
        }
    });
    let router = Arc::new(Router::new(cfg));
    let server = Server::start(router, &host, port)?;
    println!("listening on {}", server.addr());
    // Block forever (Ctrl-C exits the process).
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_fit(args: &Args) -> Result<()> {
    let path = args
        .get("data")
        .ok_or_else(|| mka_gp::error::Error::Config("fit: --data <csv> required".into()))?;
    let method = Method::parse(args.get_or("method", "mka"))
        .ok_or_else(|| mka_gp::error::Error::Config("unknown --method".into()))?;
    let k = args.get_usize("k", 32);
    let seed = args.get_u64("seed", 42);
    let mut data = loader::load_csv(Path::new(path), "cli")?;
    data.normalize();
    let (train, test) = data.split(0.9, seed);
    let hp = HyperParams {
        lengthscale: args.get_f64("lengthscale", (data.dim() as f64).sqrt()),
        sigma2: args.get_f64("sigma2", 0.1),
    };
    println!(
        "fitting {} on {} (n={}, d={}, k={k})",
        method.label(),
        data.name,
        train.n(),
        data.dim()
    );
    let model = mka_gp::coordinator::router::fit_model(method, &train, hp, k, seed)?;
    let pred = model.predict(&test.x);
    println!("test SMSE = {:.4}", smse(&test.y, &pred.mean));
    if pred.var.iter().all(|v| v.is_finite()) {
        println!("test MNLP = {:.4}", mnlp(&test.y, &pred.mean, &pred.var));
    }
    Ok(())
}

/// Hyperparameter learning from the command line: select (lengthscale,
/// σ²) by evidence maximization (default: derivative-free `mll`;
/// `mll-grad` runs L-BFGS on the analytic gradients, `--ard` learns one
/// length scale per input dimension) or grid CV, fit the final model,
/// and report held-out metrics. `--synth N` generates a seeded synthetic
/// dataset when no CSV is at hand (CI smoke uses this).
fn cmd_train(args: &Args) -> Result<()> {
    use mka_gp::train::{train_model, ModelSelection, OptimBudget};
    let method = Method::parse(args.get_or("method", "mka"))
        .ok_or_else(|| mka_gp::error::Error::Config("unknown --method".into()))?;
    let k = args.get_usize("k", 32);
    let seed = args.get_u64("seed", 42);
    let mut data = match args.get("data") {
        Some(path) => loader::load_csv(Path::new(path), "cli")?,
        None => {
            let n = args.get_usize("synth", 0);
            if n == 0 {
                return Err(mka_gp::error::Error::Config(
                    "train: --data <csv> or --synth <n> required".into(),
                ));
            }
            let dim = args.get_usize("dim", 2);
            mka_gp::data::synth::gp_dataset(
                &mka_gp::data::synth::SynthSpec::named("synthetic", n, dim),
                seed,
            )
        }
    };
    data.normalize();
    let (train, test) = data.split(0.9, seed);
    let budget = OptimBudget {
        max_evals: args.get_usize("max-evals", 60),
        n_starts: args.get_usize("starts", 3),
        tol: args.get_f64("tol", 1e-5),
    };
    let ard = args.has_flag("ard");
    let sel_name = args.get_or("selection", "mll");
    let folds = args.get_usize("folds", 5);
    let selection = ModelSelection::parse(sel_name, folds, budget, ard).ok_or_else(|| {
        // A known non-gradient name + --ard is a flag conflict; anything
        // else is an unknown selection name.
        mka_gp::error::Error::Config(
            if ard && ModelSelection::parse(sel_name, folds, budget, false).is_some() {
                "--ard requires the gradient path (--selection mll-grad)".into()
            } else {
                "unknown --selection (mll|mll-grad|cv)".into()
            },
        )
    })?;
    println!(
        "training {} on {} (n={}, d={}, k={k}, selection={})",
        method.label(),
        data.name,
        train.n(),
        data.dim(),
        selection.label()
    );
    // Factor-cache delta around this run (single-process CLI, so the
    // global counters are exact for it): σ²-only optimizer moves at a
    // cached length scale must not refactorize.
    let cache_hits_before = mka_gp::train::factor_cache_hits();
    let (model, report) = train_model(method, &train, &selection, k, seed)?;
    let cache_hits = mka_gp::train::factor_cache_hits() - cache_hits_before;
    println!(
        "chosen lengthscale = {:.4}, sigma2 = {:.5} ({} evals in {:.2}s, converged={})",
        report.best.lengthscale,
        report.best.sigma2,
        report.evals,
        report.train_secs,
        report.converged
    );
    if let Some(fx) = report.factorizations {
        println!(
            "factor cache: {fx} σ²-independent builds over {} evals ({cache_hits} hits)",
            report.evals
        );
    }
    if let Some(ells) = &report.lengthscales {
        let pretty: Vec<String> = ells.iter().map(|l| format!("{l:.4}")).collect();
        println!("ARD lengthscales = [{}]", pretty.join(", "));
    }
    if let Some(mll) = report.best_mll {
        if !mll.is_finite() {
            return Err(mka_gp::error::Error::Config(format!(
                "train: non-finite best log marginal likelihood {mll}"
            )));
        }
        println!("best log marginal likelihood = {mll:.4}");
    }
    if let Some(cv) = report.cv_score {
        println!("best CV validation SMSE = {cv:.4}");
    }
    let pred = model.predict(&test.x);
    println!("test SMSE = {:.4}", smse(&test.y, &pred.mean));
    if pred.var.iter().all(|v| v.is_finite()) {
        println!("test MNLP = {:.4}", mnlp(&test.y, &pred.mean, &pred.var));
    }
    if args.has_flag("assert-converged") && !report.converged {
        return Err(mka_gp::error::Error::Config(
            "train: optimizer did not converge within --max-evals".into(),
        ));
    }
    if args.has_flag("assert-cache-hit") && cache_hits == 0 {
        return Err(mka_gp::error::Error::Config(
            "train: expected at least one factor-cache hit (σ²-only moves \
             must reuse the per-lengthscale factorization)"
                .into(),
        ));
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let name = args.get_or("name", "table1");
    match name {
        "table1" => {
            let mut cfg = mka_gp::experiments::table1::Table1Config::default();
            if args.has_flag("full") {
                cfg.max_n = usize::MAX;
                cfg.repeats = 5;
                cfg.folds = 5;
            }
            cfg.max_n = args.get_usize("max-n", cfg.max_n);
            cfg.selection = args.get_or("selection", "cv").to_string();
            // --shards K runs the MKA column through the sharded serving
            // plane (shard-per-cluster experts, rBCM recombination).
            cfg.shards = args.get_usize("shards", 1).max(1);
            let only = args.get("datasets").map(|s| s.split(',').collect::<Vec<_>>());
            let rows = mka_gp::experiments::table1::run_table(&cfg, only.as_deref());
            println!("{}", mka_gp::experiments::table1::format_rows(&rows));
        }
        "fig1" => {
            let hp = HyperParams { lengthscale: 0.5, sigma2: 0.01 };
            let (_data, curves) =
                mka_gp::experiments::snelson::run(200, 10, 200, hp, &Method::ALL, 7);
            for c in &curves {
                println!(
                    "{:?}: mean range [{:.2}, {:.2}]",
                    c.method,
                    c.mean.iter().cloned().fold(f64::INFINITY, f64::min),
                    c.mean.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
                );
            }
            for (m, d) in mka_gp::experiments::snelson::deviation_from_full(&curves) {
                println!("deviation from Full: {m:?} = {d:.4}");
            }
        }
        "fig2" => {
            let data = mka_gp::data::synth::gp_dataset(
                &mka_gp::data::synth::SynthSpec::named("sweep", 800, 8),
                13,
            );
            let hp = HyperParams { lengthscale: 1.0, sigma2: 0.1 };
            let pts = mka_gp::experiments::sweep::sweep(
                &data,
                &[8, 16, 32, 64, 128],
                hp,
                &Method::ALL,
                13,
            );
            for p in pts {
                println!("{:?} k={}: smse={:.3} mnlp={:?}", p.method, p.k, p.smse, p.mnlp);
            }
        }
        other => println!("unknown experiment {other}; use table1|fig1|fig2"),
    }
    Ok(())
}

fn cmd_selftest(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    println!("loading artifacts from {dir} ...");
    let engine = XlaEngine::start(Path::new(dir))?;
    let handle = engine.handle();
    let mut rng = Rng::new(7);
    // gram tile vs native
    let t = handle.gram_tile_size().min(64);
    let d = handle.gram_max_dim().min(8);
    let x = Mat::from_fn(t, d, |_, _| rng.normal());
    let y = Mat::from_fn(t, d, |_, _| rng.normal());
    let via_xla = handle.rbf_tile(&x, &y, 0.9, 1.3)?;
    let via_native = rbf_tile_native(&x, &y, 0.9, 1.3);
    let err = via_xla.sub(&via_native).max_abs();
    println!("gram_tile   max|xla - native| = {err:.3e}");
    assert!(err < 1e-10, "gram tile mismatch");
    // ata vs native
    let a = Mat::from_fn(96, 96, |_, _| rng.normal());
    let g_xla = handle.ata(&a)?;
    let g_nat = mka_gp::la::syrk_ata(&a);
    let err = g_xla.sub(&g_nat).max_abs();
    println!("ata         max|xla - native| = {err:.3e}");
    assert!(err < 1e-9, "ata mismatch");
    // chol_solve vs native
    let b = Mat::from_fn(80, 85, |_, _| rng.normal());
    let mut k = mka_gp::la::gemm_nt(&b, &b);
    k.scale(1.0 / 85.0);
    let yv = rng.normal_vec(80);
    let alpha_xla = handle.chol_solve(&k, &yv, 0.1)?;
    let mut kp = k.clone();
    kp.add_diag(0.1);
    let alpha_nat = mka_gp::la::Chol::new(&kp)?.solve(&yv);
    let err = alpha_xla
        .iter()
        .zip(&alpha_nat)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("chol_solve  max|xla - native| = {err:.3e}");
    assert!(err < 1e-7, "chol_solve mismatch");
    println!("selftest OK — all AOT artifacts agree with native kernels");
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let cfg = service_config(args)?;
    println!("config: {}", cfg.to_json().dump_pretty());
    let dir = args.get_or("artifacts", "artifacts");
    match mka_gp::runtime::Manifest::load(Path::new(dir)) {
        Ok(m) => {
            println!("artifacts in {dir}:");
            for a in &m.artifacts {
                println!("  {} ({} params, sha {})", a.name, a.n_params, a.sha256);
            }
            println!(
                "shapes: gram tile {}x{} | ata {} | chol {}",
                m.gram_tile, m.gram_dim, m.ata_m, m.chol_n
            );
        }
        Err(e) => println!("no artifacts: {e}"),
    }
    Ok(())
}
