//! Shared parallel compute plane.
//!
//! One process-wide persistent [`ThreadPool`] sits under every dense hot
//! loop: row-band GEMM/SYRK (`la::blas`), tile-parallel gram assembly
//! (`kernels`), the per-stage rotation application of the MKA factorize
//! loop, and the block-parallel cascade (`mka::stage`). The old
//! spawn-per-call `mka::parallel::par_map` is now a thin shim over it.
//!
//! **Determinism contract**: every parallel path in this crate uses fixed
//! sharding over *output* regions (row bands, column panels, tiles, or
//! disjoint rotation blocks) and runs, per output element, exactly the
//! same accumulation sequence as the serial code. Results are therefore
//! bit-for-bit identical at any thread count — `rust/tests/
//! par_determinism.rs` enforces this across thread counts 1/2/4.
//!
//! The memory half of the plane is [`arena`]: per-thread grow-only
//! scratch pools with a checkout/return protocol, so panel packing, gram
//! tiles, and cascade buffers stop allocating in steady state.

pub mod arena;
pub mod pool;

pub use pool::ThreadPool;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Requested parallelism (0 = auto-detect at first use).
static TARGET: AtomicUsize = AtomicUsize::new(0);
static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();

/// Hardware parallelism (fallback 1).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
}

/// Set the target parallelism for the shared pool (0 = auto). Growing an
/// already-started pool spawns additional workers; results never depend
/// on this value (see the determinism contract), only wall-clock does.
pub fn set_threads(n: usize) {
    TARGET.store(n, Ordering::Relaxed);
    if n > 1 {
        if let Some(p) = GLOBAL.get() {
            p.ensure_workers(n);
        }
    }
}

/// Current target parallelism (≥ 1).
pub fn threads() -> usize {
    let t = TARGET.load(Ordering::Relaxed);
    if t == 0 {
        default_threads()
    } else {
        t.max(1)
    }
}

/// The process-wide pool, started on first use.
pub fn global() -> &'static ThreadPool {
    GLOBAL.get_or_init(|| ThreadPool::new(threads()))
}

/// Jobs executed so far on the shared pool (0 if it never started).
pub fn jobs_executed() -> u64 {
    GLOBAL.get().map(|p| p.jobs_executed()).unwrap_or(0)
}

/// Worker threads currently alive in the shared pool (0 if not started).
pub fn pool_workers() -> usize {
    GLOBAL.get().map(|p| p.n_workers()).unwrap_or(0)
}

/// Split `0..n` into at most `k` contiguous, near-equal, non-empty ranges.
pub fn chunk_ranges(n: usize, k: usize) -> Vec<(usize, usize)> {
    if n == 0 {
        return Vec::new();
    }
    let k = k.clamp(1, n);
    let base = n / k;
    let extra = n % k;
    let mut out = Vec::with_capacity(k);
    let mut start = 0;
    for i in 0..k {
        let len = base + usize::from(i < extra);
        out.push((start, start + len));
        start += len;
    }
    debug_assert_eq!(start, n);
    out
}

/// Run `f(shard_index, lo, hi)` over contiguous shards of `0..n` on the
/// shared pool. With one shard (or `n == 0`) the call is inlined — the
/// serial path and the parallel path execute the same code on the same
/// ranges, which is what makes callers bit-deterministic.
pub fn for_ranges<F>(n: usize, max_shards: usize, f: F)
where
    F: Fn(usize, usize, usize) + Send + Sync,
{
    if n == 0 {
        return;
    }
    let ranges = chunk_ranges(n, max_shards.max(1));
    if ranges.len() <= 1 {
        f(0, 0, n);
        return;
    }
    let fref = &f;
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = ranges
        .iter()
        .enumerate()
        .map(|(i, &(lo, hi))| {
            let b: Box<dyn FnOnce() + Send + '_> = Box::new(move || fref(i, lo, hi));
            b
        })
        .collect();
    global().run_all(tasks);
}

/// Run `f(task_index)` for every index in `0..n_tasks` with at most
/// `max_parallel` pool tasks in flight: indices are grouped into
/// contiguous chunks, one pool task per chunk, serial inside a chunk —
/// so `max_parallel` is a real concurrency cap for this call, not just a
/// hint. Per-index execution is identical to the serial loop, keeping
/// callers bit-deterministic. `f` must tolerate concurrent calls for
/// different indices.
pub fn run_tasks<F>(n_tasks: usize, max_parallel: usize, f: F)
where
    F: Fn(usize) + Send + Sync,
{
    if n_tasks == 0 {
        return;
    }
    let groups = chunk_ranges(n_tasks, max_parallel.max(1));
    if max_parallel <= 1 || groups.len() <= 1 {
        for i in 0..n_tasks {
            f(i);
        }
        return;
    }
    let fref = &f;
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = groups
        .iter()
        .map(|&(lo, hi)| {
            let b: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                for i in lo..hi {
                    fref(i);
                }
            });
            b
        })
        .collect();
    global().run_all(tasks);
}

/// Raw mutable pointer that may cross thread boundaries.
///
/// # Safety contract
/// The *user* guarantees that concurrent tasks touch disjoint regions
/// behind the pointer (disjoint row bands, tiles, or rotation blocks) and
/// that the allocation outlives the parallel region — which `run_all`'s
/// blocking semantics provide.
#[derive(Clone, Copy)]
pub struct SendPtr<T>(*mut T);

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    pub fn new(p: *mut T) -> SendPtr<T> {
        SendPtr(p)
    }

    pub fn ptr(&self) -> *mut T {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threads_is_positive() {
        assert!(threads() >= 1);
        assert!(default_threads() >= 1);
    }

    #[test]
    // The global pool's workers outlive the test process's miri view.
    #[cfg_attr(miri, ignore)]
    fn for_ranges_covers_everything_once() {
        let n = 1000;
        let mut hits = vec![0u8; n];
        let ptr = SendPtr::new(hits.as_mut_ptr());
        for_ranges(n, 7, move |_, lo, hi| {
            for i in lo..hi {
                // SAFETY: shards are disjoint.
                unsafe { *ptr.ptr().add(i) += 1 };
            }
        });
        assert!(hits.iter().all(|&h| h == 1));
    }

    #[test]
    fn for_ranges_serial_inline() {
        // One shard: f runs inline exactly once over the full range.
        let calls = std::sync::atomic::AtomicUsize::new(0);
        let covered = std::sync::atomic::AtomicUsize::new(0);
        for_ranges(10, 1, |_, lo, hi| {
            calls.fetch_add(1, Ordering::SeqCst);
            covered.fetch_add(hi - lo, Ordering::SeqCst);
        });
        assert_eq!(calls.load(Ordering::SeqCst), 1);
        assert_eq!(covered.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn chunk_ranges_cover_exactly() {
        for (n, k) in [(10, 3), (1, 4), (7, 7), (16, 2), (5, 1), (100, 8)] {
            let ranges = chunk_ranges(n, k);
            assert!(ranges.len() <= k);
            assert_eq!(ranges[0].0, 0);
            assert_eq!(ranges.last().unwrap().1, n);
            for w in ranges.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous");
            }
            for &(a, b) in &ranges {
                assert!(b > a, "non-empty");
            }
            let sizes: Vec<usize> = ranges.iter().map(|&(a, b)| b - a).collect();
            let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(mx - mn <= 1, "near-equal: {sizes:?}");
        }
        assert!(chunk_ranges(0, 4).is_empty());
    }
}
