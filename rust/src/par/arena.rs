//! Per-worker scratch arena: grow-only, thread-local buffer reuse for the
//! dense compute plane.
//!
//! Every hot path in the crate — panel packing in `la::blas`, the
//! `Stage::rotate_vec` scratch, gram-assembly tiles, the cascade's
//! per-stage `wavs` buffers — needs short-lived `Vec<f64>` scratch of
//! roughly the same size on every call. Allocating it per call puts the
//! allocator on the serving hot path; this module replaces that with a
//! checkout/return protocol:
//!
//! * [`take_vec`] / [`take_zeroed`] check a buffer out of the calling
//!   thread's pool (best-fit on capacity; contents of `take_vec` are
//!   **unspecified** — stale data from a previous user, or zeros — so
//!   callers must fully overwrite before reading).
//! * [`give_vec`] returns a buffer to the pool of whichever thread calls
//!   it (buffers migrate freely between threads; each pool is bounded).
//! * [`take_mat`] / [`take_mat_zeroed`] / [`give_mat`] are the same
//!   protocol for `Mat`-shaped scratch, and [`take_aligned`] hands out a
//!   64-byte-aligned RAII slice for packed microkernel panels.
//!
//! The pools are **grow-only**: a checkout that no held buffer can
//! satisfy grows (or allocates) one buffer and records the event in a
//! global counter. In steady state — repeated predicts against a fitted
//! model, repeated gemms of the same shape — every checkout is a hit and
//! the dense plane performs **zero heap allocations**. The counters
//! ([`checkouts`], [`grows`], [`grow_bytes`]) are monotonic and exposed
//! through `metrics.compute` so that claim is observable in production
//! and pinned by `rust/tests/arena_steady.rs`.
//!
//! Determinism: the arena only recycles storage; it never changes what
//! values are computed, so the bit-determinism contract is untouched.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::la::dense::Mat;

/// Max buffers held per thread; beyond this, returned buffers displace
/// the smallest held one (or are dropped) so a burst of odd sizes can't
/// pin unbounded memory. Sized above the cascade's end-of-solve donation
/// burst (a few buffers per stage) so steady-state serving never cycles
/// through drop-then-regrow.
const MAX_HELD: usize = 32;

/// 64-byte line / vector-register alignment, in f64 elements.
const ALIGN_ELEMS: usize = 8;

static CHECKOUTS: AtomicU64 = AtomicU64::new(0);
static GROWS: AtomicU64 = AtomicU64::new(0);
static GROW_BYTES: AtomicU64 = AtomicU64::new(0);

thread_local! {
    static POOL: RefCell<Vec<Vec<f64>>> = const { RefCell::new(Vec::new()) };
}

/// Total checkouts ([`take_vec`]/[`take_zeroed`]/[`take_aligned`] and the
/// `Mat` variants) since process start. Monotonic.
pub fn checkouts() -> u64 {
    CHECKOUTS.load(Ordering::Relaxed)
}

/// Checkouts that no held buffer could satisfy (each one is a real heap
/// allocation or reallocation). Flat across repeated same-shape work ⇒
/// the arena path is allocation-free in steady state. Monotonic.
pub fn grows() -> u64 {
    GROWS.load(Ordering::Relaxed)
}

/// Bytes of new capacity acquired by grow events. Monotonic.
pub fn grow_bytes() -> u64 {
    GROW_BYTES.load(Ordering::Relaxed)
}

/// Check out a buffer with `len` elements. Contents are **unspecified**
/// (stale or zero) — the caller must overwrite every element it reads.
pub fn take_vec(len: usize) -> Vec<f64> {
    CHECKOUTS.fetch_add(1, Ordering::Relaxed);
    let mut v = POOL.with(|p| {
        let mut pool = p.borrow_mut();
        // Best fit: the smallest held buffer that satisfies the request,
        // so small checkouts never strand a large buffer that a later
        // large checkout would otherwise have to re-grow.
        let fit = (0..pool.len())
            .filter(|&i| pool[i].capacity() >= len)
            .min_by_key(|&i| pool[i].capacity());
        match fit {
            Some(i) => pool.swap_remove(i),
            None => {
                // Nothing fits: grow the largest held buffer rather than
                // accumulating ever more small ones.
                match (0..pool.len()).max_by_key(|&i| pool[i].capacity()) {
                    Some(i) => pool.swap_remove(i),
                    None => Vec::new(),
                }
            }
        }
    });
    if v.capacity() < len {
        GROWS.fetch_add(1, Ordering::Relaxed);
        GROW_BYTES.fetch_add(((len - v.capacity()) * 8) as u64, Ordering::Relaxed);
    }
    if v.len() < len {
        v.resize(len, 0.0);
    } else {
        v.truncate(len);
    }
    v
}

/// Check out a buffer of `len` zeros.
pub fn take_zeroed(len: usize) -> Vec<f64> {
    let mut v = take_vec(len);
    v.fill(0.0);
    v
}

/// Return a buffer to the calling thread's pool for reuse.
pub fn give_vec(v: Vec<f64>) {
    if v.capacity() == 0 {
        return;
    }
    POOL.with(|p| {
        let mut pool = p.borrow_mut();
        if pool.len() >= MAX_HELD {
            // Displace the smallest held buffer if the newcomer is
            // bigger; otherwise drop the newcomer.
            if let Some(i) = (0..pool.len()).min_by_key(|&i| pool[i].capacity()) {
                if pool[i].capacity() < v.capacity() {
                    pool[i] = v;
                }
            }
        } else {
            pool.push(v);
        }
    });
}

/// Check out a `rows × cols` matrix with **unspecified contents** — the
/// caller must write every element it reads.
pub fn take_mat(rows: usize, cols: usize) -> Mat {
    Mat::from_vec(rows, cols, take_vec(rows * cols))
}

/// Check out a `rows × cols` matrix of zeros.
pub fn take_mat_zeroed(rows: usize, cols: usize) -> Mat {
    Mat::from_vec(rows, cols, take_zeroed(rows * cols))
}

/// Return a matrix's storage to the pool.
pub fn give_mat(m: Mat) {
    give_vec(m.data);
}

/// A checked-out, 64-byte-aligned scratch slice; its storage returns to
/// the pool on drop. Contents are unspecified at checkout.
pub struct Scratch {
    buf: Vec<f64>,
    off: usize,
    len: usize,
}

impl Scratch {
    pub fn slice(&self) -> &[f64] {
        &self.buf[self.off..self.off + self.len]
    }

    pub fn slice_mut(&mut self) -> &mut [f64] {
        &mut self.buf[self.off..self.off + self.len]
    }
}

impl Drop for Scratch {
    fn drop(&mut self) {
        give_vec(std::mem::take(&mut self.buf));
    }
}

/// Check out `len` elements starting on a 64-byte boundary (cache-line /
/// widest-vector alignment; the microkernels still use unaligned loads,
/// so alignment is a cache courtesy, not a correctness requirement).
pub fn take_aligned(len: usize) -> Scratch {
    let buf = take_vec(len + ALIGN_ELEMS - 1);
    let off = buf.as_ptr().align_offset(64 / std::mem::size_of::<f64>());
    // align_offset may decline (returns usize::MAX under some const-eval
    // contexts); fall back to an unaligned slice — always correct.
    let off = if off < ALIGN_ELEMS { off } else { 0 };
    Scratch { buf, off, len }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_give_roundtrip_reuses_capacity() {
        // Drain influence from other tests in this binary: observe only
        // deltas produced by this thread's own traffic.
        let v = take_vec(4096);
        let cap = v.capacity();
        let ptr = v.as_ptr() as usize;
        give_vec(v);
        let g0 = grows();
        let v2 = take_vec(4096);
        assert_eq!(v2.capacity(), cap);
        assert_eq!(v2.as_ptr() as usize, ptr, "same buffer must come back");
        assert_eq!(grows(), g0, "a satisfiable checkout must not grow");
        give_vec(v2);
    }

    #[test]
    fn smaller_checkout_truncates_and_larger_zero_fills() {
        let mut v = take_vec(64);
        for x in v.iter_mut() {
            *x = 7.0;
        }
        give_vec(v);
        let small = take_vec(16);
        assert_eq!(small.len(), 16);
        give_vec(small);
        let z = take_zeroed(32);
        assert!(z.iter().all(|&x| x == 0.0));
        give_vec(z);
    }

    #[test]
    fn counters_are_monotonic() {
        let c0 = checkouts();
        let g0 = grow_bytes();
        let v = take_vec(1 << 12);
        give_vec(v);
        assert!(checkouts() > c0);
        assert!(grow_bytes() >= g0);
    }

    #[test]
    fn aligned_scratch_is_aligned_and_sized() {
        let mut s = take_aligned(37);
        assert_eq!(s.slice().len(), 37);
        assert_eq!(s.slice_mut().as_ptr() as usize % 64, 0);
        s.slice_mut()[36] = 1.5;
        assert_eq!(s.slice()[36], 1.5);
    }

    #[test]
    fn mat_checkout_shapes() {
        let m = take_mat_zeroed(5, 7);
        assert_eq!((m.rows, m.cols), (5, 7));
        assert!(m.data.iter().all(|&x| x == 0.0));
        give_mat(m);
        let m2 = take_mat(3, 4);
        assert_eq!(m2.data.len(), 12);
        give_mat(m2);
    }

    #[test]
    fn pool_is_bounded() {
        let held: Vec<Vec<f64>> = (0..2 * MAX_HELD).map(|i| take_vec(8 + i)).collect();
        for v in held {
            give_vec(v);
        }
        POOL.with(|p| assert!(p.borrow().len() <= MAX_HELD));
    }
}
