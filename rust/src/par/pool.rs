//! Persistent work-sharing thread pool.
//!
//! One shared FIFO of jobs, N long-lived worker threads, and a *helping*
//! submitter: `run_all` pushes its tasks and then executes jobs from the
//! shared queue itself until its own batch completes. Helping makes nested
//! submission deadlock-free (a task that submits a sub-batch drains the
//! queue while it waits) and means a pool of N workers delivers N+1-way
//! execution under a blocked caller.
//!
//! Panic safety: a panicking task never kills a worker; the first payload
//! is captured and re-thrown on the thread that called `run_all`, after
//! every task of the batch has finished (so borrowed data stays valid for
//! exactly the call duration — the invariant behind the lifetime erasure).

use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A queued job plus the batch it belongs to (None for detached `spawn`s).
/// The batch handle lets a helping submitter pick *its own* jobs out of
/// the shared FIFO, so a small batch's latency never includes a foreign
/// long-running job.
struct QueuedJob {
    run: Job,
    batch: Option<Arc<Batch>>,
}

struct Shared {
    queue: Mutex<VecDeque<QueuedJob>>,
    work_cv: Condvar,
    shutdown: AtomicBool,
    jobs_executed: AtomicU64,
}

/// Completion latch for one `run_all` batch.
struct Batch {
    state: Mutex<BatchState>,
    done_cv: Condvar,
}

struct BatchState {
    remaining: usize,
    panic: Option<Box<dyn Any + Send>>,
}

impl Batch {
    fn new(n: usize) -> Batch {
        Batch {
            state: Mutex::new(BatchState { remaining: n, panic: None }),
            done_cv: Condvar::new(),
        }
    }

    /// Mark one task finished, recording the first panic payload.
    fn complete(&self, panic: Option<Box<dyn Any + Send>>) {
        let mut st = self.state.lock().unwrap();
        if st.panic.is_none() {
            st.panic = panic;
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            self.done_cv.notify_all();
        }
    }
}

/// A fixed set of persistent worker threads sharing one job queue.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl ThreadPool {
    /// Spawn a pool with `n` workers (at least 1).
    pub fn new(n: usize) -> ThreadPool {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            work_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            jobs_executed: AtomicU64::new(0),
        });
        let pool = ThreadPool { shared, workers: Mutex::new(Vec::new()) };
        pool.ensure_workers(n.max(1));
        pool
    }

    /// Grow the pool to at least `n` workers (never shrinks).
    pub fn ensure_workers(&self, n: usize) {
        let mut workers = self.workers.lock().unwrap();
        while workers.len() < n {
            let shared = Arc::clone(&self.shared);
            let idx = workers.len();
            let handle = std::thread::Builder::new()
                .name(format!("mka-par-{idx}"))
                .spawn(move || worker_loop(&shared))
                .expect("spawn pool worker");
            workers.push(handle);
        }
    }

    pub fn n_workers(&self) -> usize {
        self.workers.lock().unwrap().len()
    }

    /// Total jobs executed on this pool (workers + helping submitters).
    pub fn jobs_executed(&self) -> u64 {
        self.shared.jobs_executed.load(Ordering::Relaxed)
    }

    /// Fire-and-forget job. Panics in `f` are swallowed (they must not
    /// kill a worker); use `run_all` when failure matters.
    pub fn spawn(&self, f: impl FnOnce() + Send + 'static) {
        // Carry the submitter's span context so a traced caller sees its
        // detached work too; inactive (the common case) this is one
        // atomic load and an Option::None clone.
        let ctx = crate::obs::current_ctx();
        let enq = ctx.is_active().then(std::time::Instant::now);
        let job: Job = Box::new(move || {
            let _obs = crate::obs::enter_job(&ctx, "pool.job", enq);
            let _ = catch_unwind(AssertUnwindSafe(f));
        });
        let mut q = self.shared.queue.lock().unwrap();
        q.push_back(QueuedJob { run: job, batch: None });
        self.shared.work_cv.notify_one();
    }

    /// Execute every task, blocking until all have finished. The calling
    /// thread helps by executing *its own batch's* queued jobs while it
    /// waits — nested `run_all` from inside a task therefore cannot
    /// deadlock, and a small batch never waits on an unrelated long job.
    /// If any task panicked, the first payload is re-thrown here — after
    /// the whole batch is done.
    pub fn run_all<'env>(&self, tasks: Vec<Box<dyn FnOnce() + Send + 'env>>) {
        let n = tasks.len();
        if n == 0 {
            return;
        }
        if n == 1 {
            let task = tasks.into_iter().next().unwrap();
            task();
            return;
        }
        let batch = Arc::new(Batch::new(n));
        // Span propagation: capture the submitter's context once; each
        // job re-installs it on its executing thread (worker or helping
        // submitter) under a "pool.job" span carrying the queue wait.
        // When no trace is live this is one atomic load per batch.
        let ctx = crate::obs::current_ctx();
        {
            let mut q = self.shared.queue.lock().unwrap();
            for task in tasks {
                let b = Arc::clone(&batch);
                let ctx = ctx.clone();
                let enq = ctx.is_active().then(std::time::Instant::now);
                let job: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
                    let result = {
                        // Close the job span before `complete`: the batch
                        // latch can release the submitter (and the trace
                        // root) the moment the last task completes.
                        let _obs = crate::obs::enter_job(&ctx, "pool.job", enq);
                        catch_unwind(AssertUnwindSafe(task))
                    };
                    b.complete(result.err());
                });
                // SAFETY: `run_all` does not return until `remaining == 0`,
                // i.e. every job (and everything it borrows from 'env) has
                // finished executing, so erasing 'env to 'static never lets
                // a job outlive its borrows.
                let job: Job = unsafe {
                    std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Job>(job)
                };
                q.push_back(QueuedJob { run: job, batch: Some(Arc::clone(&batch)) });
            }
            self.shared.work_cv.notify_all();
        }
        self.help_until(&batch);
        let panic = batch.state.lock().unwrap().panic.take();
        if let Some(p) = panic {
            std::panic::resume_unwind(p);
        }
    }

    /// Pop the first queued job belonging to `batch`, if any.
    fn pop_own(&self, batch: &Arc<Batch>) -> Option<QueuedJob> {
        let mut q = self.shared.queue.lock().unwrap();
        let pos = q
            .iter()
            .position(|j| j.batch.as_ref().is_some_and(|b| Arc::ptr_eq(b, batch)));
        pos.and_then(|p| q.remove(p))
    }

    /// Execute this batch's queued jobs until none are left, then block on
    /// the batch latch until jobs picked up by workers have finished too.
    /// Own jobs cannot reappear once the queue holds none (a batch's jobs
    /// are all pushed up front), so a single drain-then-wait suffices.
    fn help_until(&self, batch: &Arc<Batch>) {
        while let Some(job) = self.pop_own(batch) {
            self.shared.jobs_executed.fetch_add(1, Ordering::Relaxed);
            (job.run)();
        }
        let mut st = batch.state.lock().unwrap();
        while st.remaining > 0 {
            st = batch.done_cv.wait(st).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            // Store + notify under the queue lock: a worker checks the
            // shutdown flag while holding this lock and releases it
            // atomically when it parks on work_cv, so the store can never
            // land inside a worker's check-then-wait window (which would
            // lose the wakeup and hang the join below).
            let _q = self.shared.queue.lock().unwrap();
            self.shared.shutdown.store(true, Ordering::Relaxed);
            self.shared.work_cv.notify_all();
        }
        let mut workers = self.workers.lock().unwrap();
        for w in workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break Some(j);
                }
                // Drain-then-exit: pending jobs are always completed, so a
                // pool dropped while busy still runs everything submitted.
                if shared.shutdown.load(Ordering::Relaxed) {
                    break None;
                }
                q = shared.work_cv.wait(q).unwrap();
            }
        };
        match job {
            Some(j) => {
                shared.jobs_executed.fetch_add(1, Ordering::Relaxed);
                (j.run)();
            }
            None => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::time::Duration;

    #[test]
    fn runs_all_tasks() {
        let pool = ThreadPool::new(4);
        let count = AtomicUsize::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..64)
            .map(|_| {
                let c = &count;
                let b: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
                b
            })
            .collect();
        pool.run_all(tasks);
        assert_eq!(count.load(Ordering::SeqCst), 64);
        assert!(pool.jobs_executed() >= 1);
    }

    #[test]
    fn borrowed_results_are_visible() {
        let pool = ThreadPool::new(2);
        let mut out = vec![0usize; 32];
        {
            let ptr = crate::par::SendPtr::new(out.as_mut_ptr());
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..32)
                .map(|i| {
                    let b: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                        // SAFETY: one task per slot.
                        unsafe { *ptr.ptr().add(i) = i * i };
                    });
                    b
                })
                .collect();
            pool.run_all(tasks);
        }
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn nested_run_all_does_not_deadlock() {
        let pool = ThreadPool::new(2);
        let count = AtomicUsize::new(0);
        let outer: Vec<Box<dyn FnOnce() + Send + '_>> = (0..8)
            .map(|_| {
                let pool_ref = &pool;
                let c = &count;
                let b: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    let inner: Vec<Box<dyn FnOnce() + Send + '_>> = (0..8)
                        .map(|_| {
                            let b2: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                                c.fetch_add(1, Ordering::SeqCst);
                            });
                            b2
                        })
                        .collect();
                    pool_ref.run_all(inner);
                });
                b
            })
            .collect();
        pool.run_all(outer);
        assert_eq!(count.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn panic_propagates_and_pool_survives() {
        let pool = ThreadPool::new(2);
        let result = catch_unwind(AssertUnwindSafe(|| {
            let tasks: Vec<Box<dyn FnOnce() + Send + 'static>> = (0..4)
                .map(|i| {
                    let b: Box<dyn FnOnce() + Send + 'static> = Box::new(move || {
                        if i == 2 {
                            panic!("task boom");
                        }
                    });
                    b
                })
                .collect();
            pool.run_all(tasks);
        }));
        assert!(result.is_err(), "panic must propagate to the submitter");
        // Pool is still usable after a panicked batch.
        let count = AtomicUsize::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..8)
            .map(|_| {
                let c = &count;
                let b: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
                b
            })
            .collect();
        pool.run_all(tasks);
        assert_eq!(count.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn drop_while_busy_completes_spawned_jobs() {
        let pool = ThreadPool::new(2);
        let count = Arc::new(AtomicUsize::new(0));
        for _ in 0..16 {
            let c = Arc::clone(&count);
            pool.spawn(move || {
                std::thread::sleep(Duration::from_millis(2));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // workers drain the queue before exiting
        assert_eq!(count.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn ensure_workers_grows() {
        let pool = ThreadPool::new(1);
        assert_eq!(pool.n_workers(), 1);
        pool.ensure_workers(3);
        assert_eq!(pool.n_workers(), 3);
        pool.ensure_workers(2); // never shrinks
        assert_eq!(pool.n_workers(), 3);
    }

    #[test]
    fn jobs_parent_to_submitting_span() {
        let pool = ThreadPool::new(2);
        let req = crate::obs::start_request("pool-trace");
        {
            let _submit = crate::obs::span!("submit-batch");
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                .map(|_| {
                    let b: Box<dyn FnOnce() + Send + '_> = Box::new(|| {});
                    b
                })
                .collect();
            pool.run_all(tasks);
        }
        let trace = req.finish();
        let submit = trace.spans.iter().find(|s| s.name == "submit-batch").unwrap();
        let jobs: Vec<_> = trace.spans.iter().filter(|s| s.name == "pool.job").collect();
        assert_eq!(jobs.len(), 4, "every pool job records a span");
        assert!(
            jobs.iter().all(|j| j.parent == submit.id),
            "worker-executed jobs parent to the submitting span"
        );
    }

    #[test]
    fn empty_and_single_batches() {
        let pool = ThreadPool::new(2);
        pool.run_all(Vec::new());
        let ran = AtomicUsize::new(0);
        let r = &ran;
        pool.run_all(vec![Box::new(move || {
            r.fetch_add(1, Ordering::SeqCst);
        }) as Box<dyn FnOnce() + Send + '_>]);
        assert_eq!(ran.load(Ordering::SeqCst), 1);
    }
}
