//! # mka-gp — Multiresolution Kernel Approximation for Gaussian Process Regression
//!
//! A production-grade reimplementation of Ding, Kondor & Eskreis-Winkler,
//! *Multiresolution Kernel Approximation for Gaussian Process Regression*
//! (NIPS 2017), as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the MKA meta-algorithm (clustering,
//!   core-diagonal compression, telescoping factorization, matrix-free
//!   operator algebra), the full GP regression stack, all five comparison
//!   baselines, and a serving coordinator.
//! * **Layer 2** — JAX compute graphs for the dense hot spots (kernel gram
//!   tiles, AᵀA), AOT-lowered to HLO text in `artifacts/`.
//! * **Layer 1** — Pallas kernels called by the L2 graphs (see
//!   `python/compile/kernels/`).
//!
//! Python never runs at inference time: the rust binary loads the AOT
//! artifacts through PJRT ([`runtime`]) or falls back to native kernels.
//!
//! ## Orientation
//!
//! The layer order is `la → par → kernels → cluster/compress → mka →
//! gp/baselines → train → coordinator` — `docs/ARCHITECTURE.md` maps it
//! in full (including where each paper equation lives) and
//! `docs/PROTOCOL.md` is the executable coordinator op reference. The
//! [`obs`] plane (request-scoped spans, structured event log,
//! numerical-health diagnostics) threads through every layer but is
//! strictly observational — tracing on or off never changes a bit of
//! any result.
//!
//! Paper-notation anchors: the telescoping factor K̃ of eq. 6 is
//! [`mka::MkaFactor`] (stages: [`mka::Stage`], core size:
//! `MkaConfig::d_core`); the Proposition 7 operator algebra (solve,
//! powers, exp, `logdet`, explicit spectrum) hangs off the factor in
//! `mka::ops`; the §4.1 joint train/test predictor is
//! [`gp::mka_gp::MkaGp`]; the evidence `log p(y)` and its per-method
//! evaluators live in [`train::mll`], their analytic gradients in
//! [`train::grad`], and the Nelder–Mead / L-BFGS maximizers in
//! [`train::optimizer`]. The (per-dimension, ARD-capable) hyperparameter
//! types are [`gp::cv::HyperParams`] / [`gp::cv::ArdHyperParams`] with
//! kernels [`kernels::RbfKernel`] / [`kernels::ArdRbfKernel`].
//!
//! **Determinism:** every parallel path shards fixed output regions and
//! replays the serial accumulation order per element, so all results are
//! bit-identical at any thread count ([`par`] documents the contract).

// CI runs `cargo clippy -- -D warnings`; style/complexity/perf lints are
// advisory for this from-scratch numeric code (index-heavy kernels trip
// `needless_range_loop` et al. by design) — correctness and suspicious
// lints stay denied.
#![allow(clippy::style, clippy::complexity, clippy::perf)]

pub mod error;
pub mod util;
pub mod obs;
pub mod par;
pub mod la;
pub mod kernels;
pub mod cluster;
pub mod compress;
pub mod mka;
pub mod gp;
pub mod train;
pub mod baselines;
pub mod data;
pub mod runtime;
pub mod coordinator;
pub mod experiments;
pub mod bench;

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::baselines::{fitc::Fitc, meka::Meka, pitc::Pitc, sor::Sor};
    pub use crate::cluster::ClusterMethod;
    pub use crate::compress::CompressorKind;
    pub use crate::data::dataset::Dataset;
    pub use crate::data::synth::{self, SynthSpec};
    pub use crate::error::{Error, Result};
    pub use crate::gp::metrics::{mnlp, smse};
    pub use crate::gp::{full::FullGp, mka_gp::MkaGp, GpModel, Prediction};
    pub use crate::gp::cv::{ArdHyperParams, HyperParams};
    pub use crate::kernels::{ArdRbfKernel, Kernel, RbfKernel};
    pub use crate::la::Mat;
    pub use crate::mka::{MkaConfig, MkaFactor};
    pub use crate::train::{mll_grad, train_model, MllGrad, ModelSelection, OptimBudget};
    pub use crate::util::{Args, Json, Rng};
}
