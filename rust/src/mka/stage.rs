//! One MKA stage: the blocked rotation Q̄_ℓ = ⊕_i Q_i, the core/wavelet
//! split, and the diagonal D_ℓ (Algorithm 1 steps 1–5).
//!
//! Permutations C_ℓ and P_ℓ are never materialized ("they really just
//! correspond to different ways of blocking K_s", §3 remark 3): blocks
//! store their member indices and the core/wavelet split stores global
//! positions, so gather/scatter does the permuting implicitly.

use crate::compress::QFactor;
use crate::la::blas::{gemm_mt, gemm_tn_mt};
use crate::la::dense::Mat;
use crate::par::{arena, SendPtr};

/// Block-parallel rotation of a multi-RHS block engages above this many
/// matrix elements (n_in × b).
const STAGE_MAT_PAR_MIN: usize = 1 << 16;

/// Block-parallel rotation of a single vector engages above this length —
/// per-block work is only O(m) flops, so it takes a big stage to win.
const STAGE_VEC_PAR_MIN: usize = 1 << 13;

/// The local rotation of one diagonal block, in stage-input coordinates.
#[derive(Clone, Debug)]
pub struct BlockFactor {
    /// Stage-input coordinates belonging to this block (sorted).
    pub idx: Vec<usize>,
    /// Local orthogonal factor on `idx.len()` coordinates.
    pub q: QFactor,
}

/// One stage of the telescoping factorization.
#[derive(Clone, Debug)]
pub struct Stage {
    /// Dimension entering this stage (n_{ℓ-1} in paper notation).
    pub n_in: usize,
    /// Per-cluster local rotations (disjoint index sets covering 0..n_in).
    pub blocks: Vec<BlockFactor>,
    /// Stage-input coordinates that continue as the next stage's core,
    /// in the order they map to coordinates 0.. of the next stage.
    pub core_global: Vec<usize>,
    /// Stage-input coordinates retired as wavelets.
    pub wavelet_global: Vec<usize>,
    /// D_ℓ: **noise-free** diagonal values for the wavelet coordinates
    /// (same order). The owning [`crate::mka::MkaFactor`] adds its
    /// diagonal `shift` (σ²) at the point of use, so stages are shared
    /// unchanged between noise levels.
    pub dvals: Vec<f64>,
}

impl Stage {
    /// Number of core coordinates c_ℓ.
    pub fn c(&self) -> usize {
        self.core_global.len()
    }

    /// Realized per-stage compression ratio γ_ℓ = c_ℓ / n_{ℓ-1} (the
    /// `diagnose` op reports one per stage).
    pub fn compression(&self) -> f64 {
        self.c() as f64 / self.n_in.max(1) as f64
    }

    /// Apply Q̄_ℓ to a stage-input vector in place (v ← Q̄ v), then split
    /// into (core, wavelet-coefficients).
    pub fn forward(&self, v: &mut [f64], scratch: &mut Vec<f64>) -> (Vec<f64>, Vec<f64>) {
        self.forward_mt(v, scratch, 1)
    }

    /// [`Stage::forward`] with block-parallel rotations: blocks act on
    /// disjoint coordinate sets, so each can rotate its slice of `v`
    /// concurrently — this is what parallelizes 1-RHS solves, where column
    /// sharding has nothing to split.
    pub fn forward_mt(
        &self,
        v: &mut [f64],
        scratch: &mut Vec<f64>,
        threads: usize,
    ) -> (Vec<f64>, Vec<f64>) {
        debug_assert_eq!(v.len(), self.n_in);
        self.rotate_vec(v, scratch, false, threads);
        // Arena-backed splits (every entry written by the gathers).
        let mut core = arena::take_vec(self.core_global.len());
        for (c, &i) in core.iter_mut().zip(&self.core_global) {
            *c = v[i];
        }
        let mut wav = arena::take_vec(self.wavelet_global.len());
        for (w, &i) in wav.iter_mut().zip(&self.wavelet_global) {
            *w = v[i];
        }
        (core, wav)
    }

    /// Inverse of [`Stage::forward`]: scatter (core, wavelet) back into a
    /// stage-input vector and apply Q̄ᵀ.
    pub fn backward(&self, core: &[f64], wav: &[f64], scratch: &mut Vec<f64>) -> Vec<f64> {
        self.backward_mt(core, wav, scratch, 1)
    }

    /// [`Stage::backward`] with block-parallel rotations.
    pub fn backward_mt(
        &self,
        core: &[f64],
        wav: &[f64],
        scratch: &mut Vec<f64>,
        threads: usize,
    ) -> Vec<f64> {
        debug_assert_eq!(core.len(), self.core_global.len());
        debug_assert_eq!(wav.len(), self.wavelet_global.len());
        // Arena scratch: core ∪ wavelet partition 0..n_in (check_valid),
        // so the two scatters overwrite every entry.
        let mut v = arena::take_vec(self.n_in);
        for (&g, &c) in self.core_global.iter().zip(core) {
            v[g] = c;
        }
        for (&g, &w) in self.wavelet_global.iter().zip(wav) {
            v[g] = w;
        }
        self.rotate_vec(&mut v, scratch, true, threads);
        v
    }

    /// Apply every block's rotation (or transpose) to a vector, block-
    /// parallel when the stage is large enough. Each block gathers its own
    /// coordinates, applies Q locally and scatters back — identical
    /// arithmetic serial or parallel, so bits never depend on `threads`.
    fn rotate_vec(&self, v: &mut [f64], scratch: &mut Vec<f64>, transpose: bool, threads: usize) {
        if threads <= 1 || self.blocks.len() < 2 || self.n_in < STAGE_VEC_PAR_MIN {
            for b in &self.blocks {
                apply_block(&b.q, &b.idx, v, scratch, transpose);
            }
            return;
        }
        let vptr = SendPtr::new(v.as_mut_ptr());
        let blocks = &self.blocks;
        crate::par::run_tasks(blocks.len(), threads, move |bi| {
            let b = &blocks[bi];
            // Per-worker arena scratch instead of a fresh Vec per block.
            let mut local = arena::take_vec(0);
            // SAFETY: blocks partition the coordinates (check_valid), so
            // tasks touch disjoint entries.
            unsafe { apply_block_vec_ptr(&b.q, &b.idx, vptr.ptr(), &mut local, transpose) };
            arena::give_vec(local);
        });
    }

    /// Blocked (multi-RHS) [`Stage::forward`]: apply Q̄_ℓ to every column
    /// of an `n_in × b` block at once, then split the rows into
    /// (core, wavelet) blocks. One pass over the stage's rotations serves
    /// all b right-hand sides — the per-rotation work is two contiguous
    /// row axpys instead of b strided scalar pairs.
    pub fn forward_mat(&self, v: &mut Mat) -> (Mat, Mat) {
        self.forward_mat_mt(v, 1)
    }

    /// [`Stage::forward_mat`] with block-parallel rotations (row ranges of
    /// the RHS block are owned by disjoint rotation blocks).
    pub fn forward_mat_mt(&self, v: &mut Mat, threads: usize) -> (Mat, Mat) {
        debug_assert_eq!(v.rows, self.n_in);
        self.rotate_mat(v, false, threads);
        (gather_rows_arena(v, &self.core_global), gather_rows_arena(v, &self.wavelet_global))
    }

    /// Inverse of [`Stage::forward_mat`]: scatter the (core, wavelet) row
    /// blocks back into stage-input coordinates and apply Q̄ᵀ to all
    /// columns.
    pub fn backward_mat(&self, core: &Mat, wav: &Mat) -> Mat {
        self.backward_mat_mt(core, wav, 1)
    }

    /// [`Stage::backward_mat`] with block-parallel rotations.
    pub fn backward_mat_mt(&self, core: &Mat, wav: &Mat, threads: usize) -> Mat {
        debug_assert_eq!(core.rows, self.core_global.len());
        debug_assert_eq!(wav.rows, self.wavelet_global.len());
        debug_assert_eq!(core.cols, wav.cols);
        // Arena scratch: the core/wavelet scatters below cover every row
        // (the splits partition 0..n_in), so stale contents never leak.
        let mut v = arena::take_mat(self.n_in, core.cols);
        for (a, &g) in self.core_global.iter().enumerate() {
            v.row_mut(g).copy_from_slice(core.row(a));
        }
        for (a, &g) in self.wavelet_global.iter().enumerate() {
            v.row_mut(g).copy_from_slice(wav.row(a));
        }
        self.rotate_mat(&mut v, true, threads);
        v
    }

    /// Apply every block's rotation (or transpose) to all columns of `v`,
    /// block-parallel when there is enough work. Serial and parallel run
    /// the same per-block kernel on the same rows — bit-identical output
    /// at any thread count.
    fn rotate_mat(&self, v: &mut Mat, transpose: bool, threads: usize) {
        if threads <= 1 || self.blocks.len() < 2 || self.n_in * v.cols < STAGE_MAT_PAR_MIN {
            for b in &self.blocks {
                apply_block_mat(&b.q, &b.idx, v, transpose);
            }
            return;
        }
        let cols = v.cols;
        let vptr = SendPtr::new(v.data.as_mut_ptr());
        let blocks = &self.blocks;
        crate::par::run_tasks(blocks.len(), threads, move |bi| {
            let b = &blocks[bi];
            // SAFETY: blocks own disjoint row sets (check_valid).
            unsafe { apply_block_mat_ptr(&b.q, &b.idx, vptr.ptr(), cols, transpose) };
        });
    }

    /// Stored reals in this stage (Proposition 3/5 audits): rotations + D.
    pub fn stored_reals(&self) -> usize {
        self.blocks.iter().map(|b| b.q.stored_reals()).sum::<usize>() + self.dvals.len()
    }

    /// Structural invariant: blocks partition 0..n_in; core ∪ wavelet too.
    pub fn check_valid(&self) -> bool {
        let mut seen = vec![false; self.n_in];
        for b in &self.blocks {
            for &i in &b.idx {
                if i >= self.n_in || seen[i] {
                    return false;
                }
                seen[i] = true;
            }
        }
        if !seen.iter().all(|&s| s) {
            return false;
        }
        let mut seen2 = vec![false; self.n_in];
        for &i in self.core_global.iter().chain(&self.wavelet_global) {
            if i >= self.n_in || seen2[i] {
                return false;
            }
            seen2[i] = true;
        }
        seen2.iter().all(|&s| s) && self.dvals.len() == self.wavelet_global.len()
    }
}

/// `Mat::gather_rows` into arena-recycled storage: every row of the
/// output is written, so unspecified checkout contents never leak.
fn gather_rows_arena(v: &Mat, idx: &[usize]) -> Mat {
    let mut out = arena::take_mat(idx.len(), v.cols);
    for (a, &g) in idx.iter().enumerate() {
        out.row_mut(a).copy_from_slice(v.row(g));
    }
    out
}

/// Gather a block's subvector, apply the local rotation (or its transpose),
/// scatter back. `scratch` avoids reallocation in the matvec hot loop.
#[inline]
fn apply_block(q: &QFactor, idx: &[usize], v: &mut [f64], scratch: &mut Vec<f64>, transpose: bool) {
    for &i in idx {
        debug_assert!(i < v.len());
    }
    // SAFETY: exclusive &mut access to the whole vector.
    unsafe { apply_block_vec_ptr(q, idx, v.as_mut_ptr(), scratch, transpose) }
}

/// Shared implementation behind the serial and block-parallel vector
/// rotation paths: gather through the raw pointer, rotate locally, scatter
/// back — same arithmetic regardless of how blocks are scheduled.
///
/// # Safety
/// `data` must cover every index in `idx`, and no other access to those
/// entries may be live.
unsafe fn apply_block_vec_ptr(
    q: &QFactor,
    idx: &[usize],
    data: *mut f64,
    scratch: &mut Vec<f64>,
    transpose: bool,
) {
    match q {
        QFactor::Identity => {}
        _ => {
            scratch.clear();
            scratch.reserve(idx.len());
            for &i in idx {
                scratch.push(*data.add(i));
            }
            if transpose {
                q.apply_vec_t(scratch);
            } else {
                q.apply_vec(scratch);
            }
            for (&i, &s) in idx.iter().zip(scratch.iter()) {
                *data.add(i) = s;
            }
        }
    }
}

/// Blocked analogue of [`apply_block`]: apply one block's local rotation
/// (or its transpose) to every column of an `n_in × b` matrix.
fn apply_block_mat(q: &QFactor, idx: &[usize], v: &mut Mat, transpose: bool) {
    // SAFETY: exclusive &mut access to the whole matrix.
    unsafe { apply_block_mat_ptr(q, idx, v.data.as_mut_ptr(), v.cols, transpose) }
}

/// The one shared implementation behind the serial and block-parallel
/// multi-RHS rotation paths — operating through a raw pointer so disjoint
/// blocks can run concurrently.
///
/// * Givens factors act directly on the full block — a rotation in the
///   (local i, j) plane mixes global rows `idx[i]` and `idx[j]`, two
///   contiguous slices in the row-major layout.
/// * Dense factors gather the block's rows once and hit them with a single
///   `gemm` instead of b `gemv`s (serial inner gemm: the block task *is*
///   the parallel grain).
///
/// # Safety
/// `data` must point to a row-major buffer with `cols` columns covering
/// every row in `idx`, and no concurrent access to those rows may exist.
unsafe fn apply_block_mat_ptr(
    q: &QFactor,
    idx: &[usize],
    data: *mut f64,
    cols: usize,
    transpose: bool,
) {
    match q {
        QFactor::Identity => {}
        QFactor::Givens(seq) => {
            if transpose {
                for g in seq.rots.iter().rev() {
                    rotate_rows_ptr(data, cols, idx[g.i], idx[g.j], g.c, -g.s);
                }
            } else {
                for g in &seq.rots {
                    rotate_rows_ptr(data, cols, idx[g.i], idx[g.j], g.c, g.s);
                }
            }
        }
        QFactor::Dense(qm) => {
            let m = idx.len();
            // Arena scratch, fully overwritten by the gather below.
            let mut sub = arena::take_mat(m, cols);
            for (a, &i) in idx.iter().enumerate() {
                let dst = sub.row_mut(a).as_mut_ptr();
                std::ptr::copy_nonoverlapping(data.add(i * cols), dst, cols);
            }
            let new = if transpose { gemm_tn_mt(qm, &sub, 1) } else { gemm_mt(qm, &sub, 1) };
            for (a, &i) in idx.iter().enumerate() {
                std::ptr::copy_nonoverlapping(new.row(a).as_ptr(), data.add(i * cols), cols);
            }
            arena::give_mat(sub);
            arena::give_mat(new);
        }
    }
}

/// Row-pair Givens application: (rowᵢ, rowⱼ) ← (c·rowᵢ + s·rowⱼ,
/// −s·rowᵢ + c·rowⱼ). The transpose is the same map with s ↦ −s.
///
/// # Safety
/// Rows `i` and `j` (distinct) must be exclusively owned by the caller.
#[inline]
unsafe fn rotate_rows_ptr(data: *mut f64, cols: usize, i: usize, j: usize, c: f64, s: f64) {
    debug_assert_ne!(i, j);
    let ri = std::slice::from_raw_parts_mut(data.add(i * cols), cols);
    let rj = std::slice::from_raw_parts_mut(data.add(j * cols), cols);
    for (a, b) in ri.iter_mut().zip(rj.iter_mut()) {
        let (x, y) = (*a, *b);
        *a = c * x + s * y;
        *b = -s * x + c * y;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::la::givens::{Givens, GivensSeq};
    use crate::util::Rng;

    fn demo_stage() -> Stage {
        // n_in = 4, two blocks {0,2} and {1,3}, each with one rotation.
        let mut s1 = GivensSeq::new();
        s1.push(Givens::jacobi(0, 1, 2.0, 1.0, 1.0));
        let mut s2 = GivensSeq::new();
        s2.push(Givens::jacobi(0, 1, 1.0, -0.5, 3.0));
        Stage {
            n_in: 4,
            blocks: vec![
                BlockFactor { idx: vec![0, 2], q: QFactor::Givens(s1) },
                BlockFactor { idx: vec![1, 3], q: QFactor::Givens(s2) },
            ],
            core_global: vec![0, 1],
            wavelet_global: vec![2, 3],
            dvals: vec![0.5, 0.25],
        }
    }

    #[test]
    fn forward_backward_roundtrip() {
        let st = demo_stage();
        assert!(st.check_valid());
        let mut rng = Rng::new(1);
        let x = rng.normal_vec(4);
        let mut v = x.clone();
        let mut scratch = Vec::new();
        let (core, wav) = st.forward(&mut v, &mut scratch);
        assert_eq!(core.len(), 2);
        assert_eq!(wav.len(), 2);
        let back = st.backward(&core, &wav, &mut scratch);
        for i in 0..4 {
            assert!((back[i] - x[i]).abs() < 1e-12, "i={i}");
        }
    }

    #[test]
    fn forward_preserves_norm() {
        // Q̄ is orthogonal, so ‖(core, wav)‖ = ‖x‖.
        let st = demo_stage();
        let mut rng = Rng::new(2);
        let x = rng.normal_vec(4);
        let n0: f64 = x.iter().map(|v| v * v).sum();
        let mut v = x;
        let mut scratch = Vec::new();
        let (core, wav) = st.forward(&mut v, &mut scratch);
        let n1: f64 =
            core.iter().map(|v| v * v).sum::<f64>() + wav.iter().map(|v| v * v).sum::<f64>();
        assert!((n0 - n1).abs() < 1e-12);
    }

    #[test]
    fn invalid_structures_detected() {
        let mut st = demo_stage();
        st.core_global = vec![0, 0]; // duplicate
        assert!(!st.check_valid());
        let mut st2 = demo_stage();
        st2.blocks[0].idx = vec![0, 1]; // overlaps block 2
        assert!(!st2.check_valid());
        let mut st3 = demo_stage();
        st3.dvals = vec![1.0]; // wrong length
        assert!(!st3.check_valid());
    }

    #[test]
    fn forward_mat_matches_columnwise_forward() {
        let st = demo_stage();
        let mut rng = Rng::new(7);
        let b = 5;
        let z = Mat::from_fn(4, b, |_, _| rng.normal());
        let mut vm = z.clone();
        let (core_m, wav_m) = st.forward_mat(&mut vm);
        let mut scratch = Vec::new();
        for j in 0..b {
            let mut col = z.col(j);
            let (core, wav) = st.forward(&mut col, &mut scratch);
            for (i, &c) in core.iter().enumerate() {
                assert!((core_m.at(i, j) - c).abs() < 1e-12, "core[{i},{j}]");
            }
            for (i, &w) in wav.iter().enumerate() {
                assert!((wav_m.at(i, j) - w).abs() < 1e-12, "wav[{i},{j}]");
            }
        }
    }

    #[test]
    fn forward_backward_mat_roundtrip() {
        let st = demo_stage();
        let mut rng = Rng::new(8);
        let z = Mat::from_fn(4, 3, |_, _| rng.normal());
        let mut vm = z.clone();
        let (core, wav) = st.forward_mat(&mut vm);
        let back = st.backward_mat(&core, &wav);
        assert!(back.sub(&z).max_abs() < 1e-12);
    }

    #[test]
    fn dense_block_forward_mat_matches_vector_path() {
        // A stage with a Dense Q exercises the gemm branch of
        // apply_block_mat.
        let mut rng = Rng::new(9);
        let q = {
            // Orthogonalize a random 3x3 via Givens products.
            let mut seq = GivensSeq::new();
            seq.push(Givens::jacobi(0, 1, rng.normal(), rng.normal(), rng.normal()));
            seq.push(Givens::jacobi(1, 2, rng.normal(), rng.normal(), rng.normal()));
            seq.to_dense(3)
        };
        let st = Stage {
            n_in: 4,
            blocks: vec![
                BlockFactor { idx: vec![0, 2, 3], q: QFactor::Dense(q) },
                BlockFactor { idx: vec![1], q: QFactor::Identity },
            ],
            core_global: vec![0, 1],
            wavelet_global: vec![2, 3],
            dvals: vec![0.4, 0.6],
        };
        assert!(st.check_valid());
        let z = Mat::from_fn(4, 6, |_, _| rng.normal());
        let mut vm = z.clone();
        let (core_m, wav_m) = st.forward_mat(&mut vm);
        let mut scratch = Vec::new();
        for j in 0..6 {
            let mut col = z.col(j);
            let (core, wav) = st.forward(&mut col, &mut scratch);
            for (i, &c) in core.iter().enumerate() {
                assert!((core_m.at(i, j) - c).abs() < 1e-12);
            }
            for (i, &w) in wav.iter().enumerate() {
                assert!((wav_m.at(i, j) - w).abs() < 1e-12);
            }
        }
        let back = st.backward_mat(&core_m, &wav_m);
        assert!(back.sub(&z).max_abs() < 1e-12);
    }

    #[test]
    fn stored_reals_counts() {
        let st = demo_stage();
        // two Givens rotations (2 reals each) + 2 dvals
        assert_eq!(st.stored_reals(), 6);
    }
}
