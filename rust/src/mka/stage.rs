//! One MKA stage: the blocked rotation Q̄_ℓ = ⊕_i Q_i, the core/wavelet
//! split, and the diagonal D_ℓ (Algorithm 1 steps 1–5).
//!
//! Permutations C_ℓ and P_ℓ are never materialized ("they really just
//! correspond to different ways of blocking K_s", §3 remark 3): blocks
//! store their member indices and the core/wavelet split stores global
//! positions, so gather/scatter does the permuting implicitly.

use crate::compress::QFactor;
use crate::la::blas::{gemm, gemm_tn};
use crate::la::dense::Mat;

/// The local rotation of one diagonal block, in stage-input coordinates.
#[derive(Clone, Debug)]
pub struct BlockFactor {
    /// Stage-input coordinates belonging to this block (sorted).
    pub idx: Vec<usize>,
    /// Local orthogonal factor on `idx.len()` coordinates.
    pub q: QFactor,
}

/// One stage of the telescoping factorization.
#[derive(Clone, Debug)]
pub struct Stage {
    /// Dimension entering this stage (n_{ℓ-1} in paper notation).
    pub n_in: usize,
    /// Per-cluster local rotations (disjoint index sets covering 0..n_in).
    pub blocks: Vec<BlockFactor>,
    /// Stage-input coordinates that continue as the next stage's core,
    /// in the order they map to coordinates 0.. of the next stage.
    pub core_global: Vec<usize>,
    /// Stage-input coordinates retired as wavelets.
    pub wavelet_global: Vec<usize>,
    /// D_ℓ: diagonal values for the wavelet coordinates (same order).
    pub dvals: Vec<f64>,
}

impl Stage {
    /// Number of core coordinates c_ℓ.
    pub fn c(&self) -> usize {
        self.core_global.len()
    }

    /// Apply Q̄_ℓ to a stage-input vector in place (v ← Q̄ v), then split
    /// into (core, wavelet-coefficients).
    pub fn forward(&self, v: &mut [f64], scratch: &mut Vec<f64>) -> (Vec<f64>, Vec<f64>) {
        debug_assert_eq!(v.len(), self.n_in);
        for b in &self.blocks {
            apply_block(&b.q, &b.idx, v, scratch, false);
        }
        let core = self.core_global.iter().map(|&i| v[i]).collect();
        let wav = self.wavelet_global.iter().map(|&i| v[i]).collect();
        (core, wav)
    }

    /// Inverse of [`Stage::forward`]: scatter (core, wavelet) back into a
    /// stage-input vector and apply Q̄ᵀ.
    pub fn backward(&self, core: &[f64], wav: &[f64], scratch: &mut Vec<f64>) -> Vec<f64> {
        debug_assert_eq!(core.len(), self.core_global.len());
        debug_assert_eq!(wav.len(), self.wavelet_global.len());
        let mut v = vec![0.0; self.n_in];
        for (&g, &c) in self.core_global.iter().zip(core) {
            v[g] = c;
        }
        for (&g, &w) in self.wavelet_global.iter().zip(wav) {
            v[g] = w;
        }
        for b in &self.blocks {
            apply_block(&b.q, &b.idx, &mut v, scratch, true);
        }
        v
    }

    /// Blocked (multi-RHS) [`Stage::forward`]: apply Q̄_ℓ to every column
    /// of an `n_in × b` block at once, then split the rows into
    /// (core, wavelet) blocks. One pass over the stage's rotations serves
    /// all b right-hand sides — the per-rotation work is two contiguous
    /// row axpys instead of b strided scalar pairs.
    pub fn forward_mat(&self, v: &mut Mat) -> (Mat, Mat) {
        debug_assert_eq!(v.rows, self.n_in);
        for b in &self.blocks {
            apply_block_mat(&b.q, &b.idx, v, false);
        }
        (v.gather_rows(&self.core_global), v.gather_rows(&self.wavelet_global))
    }

    /// Inverse of [`Stage::forward_mat`]: scatter the (core, wavelet) row
    /// blocks back into stage-input coordinates and apply Q̄ᵀ to all
    /// columns.
    pub fn backward_mat(&self, core: &Mat, wav: &Mat) -> Mat {
        debug_assert_eq!(core.rows, self.core_global.len());
        debug_assert_eq!(wav.rows, self.wavelet_global.len());
        debug_assert_eq!(core.cols, wav.cols);
        let mut v = Mat::zeros(self.n_in, core.cols);
        for (a, &g) in self.core_global.iter().enumerate() {
            v.row_mut(g).copy_from_slice(core.row(a));
        }
        for (a, &g) in self.wavelet_global.iter().enumerate() {
            v.row_mut(g).copy_from_slice(wav.row(a));
        }
        for b in &self.blocks {
            apply_block_mat(&b.q, &b.idx, &mut v, true);
        }
        v
    }

    /// Stored reals in this stage (Proposition 3/5 audits): rotations + D.
    pub fn stored_reals(&self) -> usize {
        self.blocks.iter().map(|b| b.q.stored_reals()).sum::<usize>() + self.dvals.len()
    }

    /// Structural invariant: blocks partition 0..n_in; core ∪ wavelet too.
    pub fn check_valid(&self) -> bool {
        let mut seen = vec![false; self.n_in];
        for b in &self.blocks {
            for &i in &b.idx {
                if i >= self.n_in || seen[i] {
                    return false;
                }
                seen[i] = true;
            }
        }
        if !seen.iter().all(|&s| s) {
            return false;
        }
        let mut seen2 = vec![false; self.n_in];
        for &i in self.core_global.iter().chain(&self.wavelet_global) {
            if i >= self.n_in || seen2[i] {
                return false;
            }
            seen2[i] = true;
        }
        seen2.iter().all(|&s| s) && self.dvals.len() == self.wavelet_global.len()
    }
}

/// Gather a block's subvector, apply the local rotation (or its transpose),
/// scatter back. `scratch` avoids reallocation in the matvec hot loop.
#[inline]
fn apply_block(q: &QFactor, idx: &[usize], v: &mut [f64], scratch: &mut Vec<f64>, transpose: bool) {
    match q {
        QFactor::Identity => {}
        _ => {
            scratch.clear();
            scratch.extend(idx.iter().map(|&i| v[i]));
            if transpose {
                q.apply_vec_t(scratch);
            } else {
                q.apply_vec(scratch);
            }
            for (&i, &s) in idx.iter().zip(scratch.iter()) {
                v[i] = s;
            }
        }
    }
}

/// Blocked analogue of [`apply_block`]: apply one block's local rotation
/// (or its transpose) to every column of an `n_in × b` matrix.
///
/// * Givens factors act directly on the full block — a rotation in the
///   (local i, j) plane mixes global rows `idx[i]` and `idx[j]`, two
///   contiguous slices in the row-major layout.
/// * Dense factors gather the block's rows once and hit them with a single
///   `gemm` instead of b `gemv`s.
fn apply_block_mat(q: &QFactor, idx: &[usize], v: &mut Mat, transpose: bool) {
    match q {
        QFactor::Identity => {}
        QFactor::Givens(seq) => {
            if transpose {
                for g in seq.rots.iter().rev() {
                    rotate_rows(v, idx[g.i], idx[g.j], g.c, -g.s);
                }
            } else {
                for g in &seq.rots {
                    rotate_rows(v, idx[g.i], idx[g.j], g.c, g.s);
                }
            }
        }
        QFactor::Dense(qm) => {
            let sub = v.gather_rows(idx); // m × b
            let new = if transpose { gemm_tn(qm, &sub) } else { gemm(qm, &sub) };
            for (a, &i) in idx.iter().enumerate() {
                v.row_mut(i).copy_from_slice(new.row(a));
            }
        }
    }
}

/// Row-pair Givens application: (rowᵢ, rowⱼ) ← (c·rowᵢ + s·rowⱼ,
/// −s·rowᵢ + c·rowⱼ). The transpose is the same map with s ↦ −s.
#[inline]
fn rotate_rows(v: &mut Mat, i: usize, j: usize, c: f64, s: f64) {
    let (ri, rj) = v.rows_pair_mut(i, j);
    for (a, b) in ri.iter_mut().zip(rj.iter_mut()) {
        let (x, y) = (*a, *b);
        *a = c * x + s * y;
        *b = -s * x + c * y;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::la::givens::{Givens, GivensSeq};
    use crate::util::Rng;

    fn demo_stage() -> Stage {
        // n_in = 4, two blocks {0,2} and {1,3}, each with one rotation.
        let mut s1 = GivensSeq::new();
        s1.push(Givens::jacobi(0, 1, 2.0, 1.0, 1.0));
        let mut s2 = GivensSeq::new();
        s2.push(Givens::jacobi(0, 1, 1.0, -0.5, 3.0));
        Stage {
            n_in: 4,
            blocks: vec![
                BlockFactor { idx: vec![0, 2], q: QFactor::Givens(s1) },
                BlockFactor { idx: vec![1, 3], q: QFactor::Givens(s2) },
            ],
            core_global: vec![0, 1],
            wavelet_global: vec![2, 3],
            dvals: vec![0.5, 0.25],
        }
    }

    #[test]
    fn forward_backward_roundtrip() {
        let st = demo_stage();
        assert!(st.check_valid());
        let mut rng = Rng::new(1);
        let x = rng.normal_vec(4);
        let mut v = x.clone();
        let mut scratch = Vec::new();
        let (core, wav) = st.forward(&mut v, &mut scratch);
        assert_eq!(core.len(), 2);
        assert_eq!(wav.len(), 2);
        let back = st.backward(&core, &wav, &mut scratch);
        for i in 0..4 {
            assert!((back[i] - x[i]).abs() < 1e-12, "i={i}");
        }
    }

    #[test]
    fn forward_preserves_norm() {
        // Q̄ is orthogonal, so ‖(core, wav)‖ = ‖x‖.
        let st = demo_stage();
        let mut rng = Rng::new(2);
        let x = rng.normal_vec(4);
        let n0: f64 = x.iter().map(|v| v * v).sum();
        let mut v = x;
        let mut scratch = Vec::new();
        let (core, wav) = st.forward(&mut v, &mut scratch);
        let n1: f64 =
            core.iter().map(|v| v * v).sum::<f64>() + wav.iter().map(|v| v * v).sum::<f64>();
        assert!((n0 - n1).abs() < 1e-12);
    }

    #[test]
    fn invalid_structures_detected() {
        let mut st = demo_stage();
        st.core_global = vec![0, 0]; // duplicate
        assert!(!st.check_valid());
        let mut st2 = demo_stage();
        st2.blocks[0].idx = vec![0, 1]; // overlaps block 2
        assert!(!st2.check_valid());
        let mut st3 = demo_stage();
        st3.dvals = vec![1.0]; // wrong length
        assert!(!st3.check_valid());
    }

    #[test]
    fn forward_mat_matches_columnwise_forward() {
        let st = demo_stage();
        let mut rng = Rng::new(7);
        let b = 5;
        let z = Mat::from_fn(4, b, |_, _| rng.normal());
        let mut vm = z.clone();
        let (core_m, wav_m) = st.forward_mat(&mut vm);
        let mut scratch = Vec::new();
        for j in 0..b {
            let mut col = z.col(j);
            let (core, wav) = st.forward(&mut col, &mut scratch);
            for (i, &c) in core.iter().enumerate() {
                assert!((core_m.at(i, j) - c).abs() < 1e-12, "core[{i},{j}]");
            }
            for (i, &w) in wav.iter().enumerate() {
                assert!((wav_m.at(i, j) - w).abs() < 1e-12, "wav[{i},{j}]");
            }
        }
    }

    #[test]
    fn forward_backward_mat_roundtrip() {
        let st = demo_stage();
        let mut rng = Rng::new(8);
        let z = Mat::from_fn(4, 3, |_, _| rng.normal());
        let mut vm = z.clone();
        let (core, wav) = st.forward_mat(&mut vm);
        let back = st.backward_mat(&core, &wav);
        assert!(back.sub(&z).max_abs() < 1e-12);
    }

    #[test]
    fn dense_block_forward_mat_matches_vector_path() {
        // A stage with a Dense Q exercises the gemm branch of
        // apply_block_mat.
        let mut rng = Rng::new(9);
        let q = {
            // Orthogonalize a random 3x3 via Givens products.
            let mut seq = GivensSeq::new();
            seq.push(Givens::jacobi(0, 1, rng.normal(), rng.normal(), rng.normal()));
            seq.push(Givens::jacobi(1, 2, rng.normal(), rng.normal(), rng.normal()));
            seq.to_dense(3)
        };
        let st = Stage {
            n_in: 4,
            blocks: vec![
                BlockFactor { idx: vec![0, 2, 3], q: QFactor::Dense(q) },
                BlockFactor { idx: vec![1], q: QFactor::Identity },
            ],
            core_global: vec![0, 1],
            wavelet_global: vec![2, 3],
            dvals: vec![0.4, 0.6],
        };
        assert!(st.check_valid());
        let z = Mat::from_fn(4, 6, |_, _| rng.normal());
        let mut vm = z.clone();
        let (core_m, wav_m) = st.forward_mat(&mut vm);
        let mut scratch = Vec::new();
        for j in 0..6 {
            let mut col = z.col(j);
            let (core, wav) = st.forward(&mut col, &mut scratch);
            for (i, &c) in core.iter().enumerate() {
                assert!((core_m.at(i, j) - c).abs() < 1e-12);
            }
            for (i, &w) in wav.iter().enumerate() {
                assert!((wav_m.at(i, j) - w).abs() < 1e-12);
            }
        }
        let back = st.backward_mat(&core_m, &wav_m);
        assert!(back.sub(&z).max_abs() < 1e-12);
    }

    #[test]
    fn stored_reals_counts() {
        let st = demo_stage();
        // two Givens rotations (2 reals each) + 2 dvals
        assert_eq!(st.stored_reals(), 6);
    }
}
