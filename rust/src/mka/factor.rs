//! The telescoping MKA factor
//! K̃ = Q₁ᵀ(Q₂ᵀ(… Q_sᵀ(K_s ⊕ D_s)Q_s …) ⊕ D₂)Q₂ ⊕ D₁)Q₁   (paper eq. 6)
//! and its matrix-free application (Proposition 6).

use std::sync::OnceLock;

use super::stage::Stage;
use crate::la::blas::gemv;
use crate::la::dense::Mat;
use crate::la::evd::SymEig;

/// A factorized kernel approximation. Obtained from [`super::factorize`].
#[derive(Debug)]
pub struct MkaFactor {
    /// Ambient dimension n.
    pub n: usize,
    /// Stages, outermost (stage 1) first.
    pub stages: Vec<Stage>,
    /// Final dense core K_s (d_core × d_core).
    pub core: Mat,
    /// Lazily computed EVD of the core (Proposition 7's d³ step).
    pub(crate) core_eig: OnceLock<SymEig>,
}

impl Clone for MkaFactor {
    fn clone(&self) -> Self {
        MkaFactor {
            n: self.n,
            stages: self.stages.clone(),
            core: self.core.clone(),
            core_eig: OnceLock::new(),
        }
    }
}

impl MkaFactor {
    pub fn new(n: usize, stages: Vec<Stage>, core: Mat) -> MkaFactor {
        MkaFactor { n, stages, core, core_eig: OnceLock::new() }
    }

    /// Size of the final core d_core.
    pub fn d_core(&self) -> usize {
        self.core.rows
    }

    /// Number of stages s.
    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }

    /// EVD of the core, computed once on first use.
    pub(crate) fn eig(&self) -> &SymEig {
        self.core_eig.get_or_init(|| SymEig::new(&self.core))
    }

    /// K̃ z — the Proposition 6 cascade: forward through every stage,
    /// multiply the core / scale the wavelets, cascade back.
    pub fn matvec(&self, z: &[f64]) -> Vec<f64> {
        self.apply_with(z, |core_vec| gemv(&self.core, core_vec), |d| d)
    }

    /// Generic spectral application: given how to act on the final core
    /// vector and how to map each wavelet diagonal value, apply the
    /// corresponding matrix function of K̃ (Proposition 7 pattern).
    pub(crate) fn apply_with(
        &self,
        z: &[f64],
        core_op: impl Fn(&[f64]) -> Vec<f64>,
        dmap: impl Fn(f64) -> f64,
    ) -> Vec<f64> {
        assert_eq!(z.len(), self.n, "matvec dimension mismatch");
        let mut scratch: Vec<f64> = Vec::new();
        let mut v = z.to_vec();
        let mut wavs: Vec<Vec<f64>> = Vec::with_capacity(self.stages.len());
        for st in &self.stages {
            let (core, wav) = st.forward(&mut v, &mut scratch);
            wavs.push(wav);
            v = core;
        }
        // Core action.
        let mut u = core_op(&v);
        // Backward cascade, scaling wavelet coefficients by f(D).
        for (st, wav) in self.stages.iter().zip(wavs.iter()).rev() {
            let scaled: Vec<f64> =
                wav.iter().zip(&st.dvals).map(|(w, &d)| w * dmap(d)).collect();
            u = st.backward(&u, &scaled, &mut scratch);
        }
        u
    }

    /// Dense reconstruction of K̃ (tests / small n only): n matvecs.
    pub fn to_dense(&self) -> Mat {
        let n = self.n;
        let mut out = Mat::zeros(n, n);
        let mut e = vec![0.0; n];
        for j in 0..n {
            e[j] = 1.0;
            let col = self.matvec(&e);
            for i in 0..n {
                out.set(i, j, col[i]);
            }
            e[j] = 0.0;
        }
        out
    }

    /// Stored reals (Proposition 3/5): rotations + diagonals + core.
    pub fn stored_reals(&self) -> usize {
        self.stages.iter().map(|s| s.stored_reals()).sum::<usize>()
            + self.core.rows * self.core.cols
    }

    /// All wavelet diagonal values across stages (the spectrum outside the
    /// core, up to rotation).
    pub fn all_dvals(&self) -> Vec<f64> {
        self.stages.iter().flat_map(|s| s.dvals.iter().copied()).collect()
    }

    /// Structural validation of the whole factor.
    pub fn check_valid(&self) -> bool {
        let mut dim = self.n;
        for st in &self.stages {
            if st.n_in != dim || !st.check_valid() {
                return false;
            }
            dim = st.c();
        }
        dim == self.core.rows && self.core.is_square()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::QFactor;
    use crate::la::givens::{Givens, GivensSeq};
    use crate::mka::stage::BlockFactor;
    use crate::util::Rng;

    /// A hand-built 4→2 single-stage factor for exact checks.
    fn tiny_factor() -> MkaFactor {
        let mut seq = GivensSeq::new();
        seq.push(Givens::jacobi(0, 1, 3.0, 1.0, 2.0));
        let stage = Stage {
            n_in: 4,
            blocks: vec![
                BlockFactor { idx: vec![0, 1], q: QFactor::Givens(seq) },
                BlockFactor { idx: vec![2, 3], q: QFactor::Identity },
            ],
            core_global: vec![0, 2],
            wavelet_global: vec![1, 3],
            dvals: vec![0.7, 0.9],
        };
        let core = Mat::from_rows(&[&[2.0, 0.3], &[0.3, 1.5]]);
        MkaFactor::new(4, vec![stage], core)
    }

    #[test]
    fn structure_valid() {
        let f = tiny_factor();
        assert!(f.check_valid());
        assert_eq!(f.d_core(), 2);
        assert_eq!(f.n_stages(), 1);
    }

    #[test]
    fn matvec_matches_dense_reconstruction() {
        let f = tiny_factor();
        let dense = f.to_dense();
        assert!(dense.asymmetry() < 1e-12, "K̃ must be symmetric");
        let mut rng = Rng::new(1);
        let z = rng.normal_vec(4);
        let y = f.matvec(&z);
        let y2 = gemv(&dense, &z);
        for i in 0..4 {
            assert!((y[i] - y2[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn matvec_linear() {
        let f = tiny_factor();
        let mut rng = Rng::new(2);
        let a = rng.normal_vec(4);
        let b = rng.normal_vec(4);
        let ab: Vec<f64> = a.iter().zip(&b).map(|(x, y)| 2.0 * x - 3.0 * y).collect();
        let fa = f.matvec(&a);
        let fb = f.matvec(&b);
        let fab = f.matvec(&ab);
        for i in 0..4 {
            assert!((fab[i] - (2.0 * fa[i] - 3.0 * fb[i])).abs() < 1e-12);
        }
    }

    #[test]
    fn dense_is_psd_when_parts_are() {
        // Core is pd, dvals positive ⇒ K̃ psd (Proposition 1).
        let f = tiny_factor();
        let e = crate::la::evd::SymEig::new(&f.to_dense());
        assert!(e.values[0] > 0.0);
    }

    #[test]
    fn stored_reals_accounting() {
        let f = tiny_factor();
        // 1 rotation (2) + 2 dvals + 2x2 core = 8
        assert_eq!(f.stored_reals(), 8);
    }
}
