//! The telescoping MKA factor
//! K̃ = Q₁ᵀ(Q₂ᵀ(… Q_sᵀ(K_s ⊕ D_s)Q_s …) ⊕ D₂)Q₂ ⊕ D₁)Q₁   (paper eq. 6)
//! and its matrix-free application (Proposition 6).
//!
//! ## The noise-shift view
//!
//! Diagonal shifts commute with the whole cascade: every Q̄_ℓ is
//! orthogonal, so Q̄(K + σ²I)Q̄ᵀ = Q̄KQ̄ᵀ + σ²I, and the core/wavelet split
//! keeps diagonal entries — the running matrix of `factorize(K + σ²I)`
//! differs from that of `factorize(K)` by exactly σ²I at every stage.
//! Because the default pivot rules score candidates on shift-invariant
//! quantities (off-diagonal energies, diagonal *differences*, outside
//! Grams — see `compress::mmf`; the EVD oracle's eigenvectors are
//! shift-invariant too), both runs choose the same rotations, and the
//! two factors share Q̄s while every spectral value (core eigenvalue or
//! wavelet diagonal) moves by σ². The factor therefore stores the
//! **noise-free** cascade plus a single [`MkaFactor::shift`], applied to
//! the spectrum at the point of use; [`MkaFactor::shifted`] is an O(1)
//! view sharing the rotations, so re-tuning σ² never refactorizes.
//!
//! Caveat: the non-default SPCA compressor and MMF's MaxCorrelation
//! ablation rule score shift-*dependent* quantities (Gram diagonals),
//! so for those configurations `factorize(K).shifted(σ²)` is a
//! different — still valid, still spsd — member of the approximation
//! family than `factorize(K + σ²I)`, not the identical factor.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

use super::parallel::{chunk_ranges, par_map};
use super::stage::Stage;
use crate::la::blas::{axpy, gemm, gemv, scale_rows};
use crate::la::dense::Mat;
use crate::la::evd::SymEig;
use crate::par::arena;
use crate::util::json::Json;

/// Process-wide count of *logical* orthogonal cascades (one full
/// forward+backward sweep through every stage). A blocked apply carrying
/// b right-hand sides counts **once**, and a column-sharded parallel
/// apply also counts once even though its chunks sweep concurrently —
/// this is the observable contract behind "a coalesced batch is one
/// cascade", used by the coordinator integration tests and cheap enough
/// to keep on in production for serving metrics.
static CASCADES: AtomicU64 = AtomicU64::new(0);

/// Process-wide count of full O(n²)–O(n³) factorizations ([`super::factorize`]
/// runs). The shift view exists precisely to keep this from growing with
/// σ² re-tunes: a σ²-only hyperparameter move through the training
/// plane's factor cache, or a serving-plane `retune`, must not bump it.
/// Sits next to [`cascade_count`] as the training plane's cost gauge.
static FACTORIZES: AtomicU64 = AtomicU64::new(0);

/// Process-wide count of stages the incremental extend path
/// ([`super::update::extend_factorize`]) rebuilt — i.e. stages where
/// fresh compression work ran for appended points. Together with
/// [`STAGE_REUSES`] this is the observable contract behind the streaming
/// observe plane: an incremental update must reuse strictly more stages
/// than it rebuilds, and must never bump [`FACTORIZES`].
static STAGE_REBUILDS: AtomicU64 = AtomicU64::new(0);

/// Process-wide count of stages the incremental extend path carried over
/// by replaying their stored rotations verbatim (no compressor ran).
static STAGE_REUSES: AtomicU64 = AtomicU64::new(0);

/// Total orthogonal cascades executed by this process so far.
pub fn cascade_count() -> u64 {
    CASCADES.load(Ordering::Relaxed)
}

/// Total kernel factorizations executed by this process so far.
pub fn factorize_count() -> u64 {
    FACTORIZES.load(Ordering::Relaxed)
}

/// Total stages rebuilt (fresh compression) by incremental extends.
pub fn stage_rebuild_count() -> u64 {
    STAGE_REBUILDS.load(Ordering::Relaxed)
}

/// Total stages reused (rotations replayed) by incremental extends.
pub fn stage_reuse_count() -> u64 {
    STAGE_REUSES.load(Ordering::Relaxed)
}

/// Bumped by [`super::factorize`] once per factorization run.
pub(crate) fn record_factorize() {
    FACTORIZES.fetch_add(1, Ordering::Relaxed);
}

/// Bumped by [`super::update::extend_factorize`] per stage it rebuilt.
pub(crate) fn record_stage_rebuilds(n: u64) {
    STAGE_REBUILDS.fetch_add(n, Ordering::Relaxed);
}

/// Bumped by [`super::update::extend_factorize`] per stage it reused.
pub(crate) fn record_stage_reuses(n: u64) {
    STAGE_REUSES.fetch_add(n, Ordering::Relaxed);
}

/// Below this many columns a parallel split would be all overhead.
const MIN_PAR_COLS: usize = 16;

/// A factorized kernel approximation representing K̃ + `shift`·I.
/// Obtained from [`super::factorize`] (at `shift = 0`) or as a cheap
/// [`MkaFactor::shifted`] view of an existing factor.
#[derive(Clone, Debug)]
pub struct MkaFactor {
    /// Ambient dimension n.
    pub n: usize,
    /// Stages, outermost (stage 1) first — shared between shifted views.
    pub stages: Arc<Vec<Stage>>,
    /// Final dense core K_s (d_core × d_core) of the **noise-free**
    /// cascade; the shift is added to its spectrum at the point of use.
    pub core: Arc<Mat>,
    /// Diagonal noise shift σ² ≥ 0: every consumer (solve, logdet,
    /// pow/exp, spectrum, validity gates) reads the spectrum as λ + shift
    /// and each wavelet diagonal as d + shift.
    pub shift: f64,
    /// Worker threads for block-parallel stage rotations inside the
    /// cascade (set from `MkaConfig::n_threads` at factorize time; purely
    /// a wall-clock knob — results are bit-identical at any value).
    pub n_threads: usize,
    /// Lazily computed EVD of the noise-free core (Proposition 7's d³
    /// step). Shared between shifted views — the eigenvectors are
    /// shift-independent, so one EVD serves every σ².
    pub(crate) core_eig: Arc<OnceLock<SymEig>>,
}

impl MkaFactor {
    pub fn new(n: usize, stages: Vec<Stage>, core: Mat) -> MkaFactor {
        MkaFactor {
            n,
            stages: Arc::new(stages),
            core: Arc::new(core),
            shift: 0.0,
            n_threads: 1,
            core_eig: Arc::new(OnceLock::new()),
        }
    }

    /// Set the cascade's block-parallel thread cap (builder style).
    pub fn with_threads(mut self, threads: usize) -> MkaFactor {
        self.n_threads = threads.max(1);
        self
    }

    /// An O(1) view of this factor at **absolute** diagonal shift
    /// `sigma2`: the result represents K̃ + σ²I where K̃ is the factorized
    /// (noise-free) approximation — re-shifting a view replaces the
    /// shift, it does not accumulate. Rotations, core and the core EVD
    /// are shared, so this is the paper-exact equivalent of
    /// `factorize(K + σ²I)` at zero factorization cost (see the module
    /// docs for why — and for which pivot rules — the equivalence is
    /// exact).
    pub fn shifted(&self, sigma2: f64) -> MkaFactor {
        MkaFactor { shift: sigma2, ..self.clone() }
    }

    /// Size of the final core d_core.
    pub fn d_core(&self) -> usize {
        self.core.rows
    }

    /// Number of stages s.
    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }

    /// EVD of the noise-free core, computed once on first use and shared
    /// by every shifted view.
    pub(crate) fn eig(&self) -> &SymEig {
        self.core_eig.get_or_init(|| SymEig::new(&self.core))
    }

    /// (K̃ + shift·I) z — the Proposition 6 cascade: forward through every
    /// stage, multiply the core / scale the wavelets, cascade back.
    pub fn matvec(&self, z: &[f64]) -> Vec<f64> {
        let s = self.shift;
        self.apply_with(
            z,
            |core_vec| {
                let mut u = gemv(&self.core, core_vec);
                if s != 0.0 {
                    axpy(s, core_vec, &mut u);
                }
                u
            },
            |d| d + s,
        )
    }

    /// (K̃ + shift·I) Z for a block of right-hand sides (columns of `z`):
    /// ONE cascade through the stages carrying all columns, with the core
    /// hit by a single `gemm` instead of per-column `gemv` pairs.
    pub fn matmat(&self, z: &Mat) -> Mat {
        let s = self.shift;
        self.apply_with_mat(
            z,
            |core_block| {
                let mut u = gemm(&self.core, core_block);
                shift_acc(&mut u, core_block, s);
                u
            },
            |d| d + s,
        )
    }

    /// Column-parallel [`MkaFactor::matmat`]: wide blocks are split into
    /// near-equal column chunks, one blocked cascade per worker thread.
    /// Narrow blocks (or `n_threads <= 1`) run one blocked cascade whose
    /// stage rotations are block-parallel instead — so a single wide batch
    /// and a 1-RHS solve both saturate the pool.
    pub fn matmat_par(&self, z: &Mat, n_threads: usize) -> Mat {
        let s = self.shift;
        self.par_over_cols(z, n_threads, |chunk, stage_threads| {
            self.apply_with_mat_stage(
                chunk,
                |c| {
                    let mut u = gemm(&self.core, c);
                    shift_acc(&mut u, c, s);
                    u
                },
                |d| d + s,
                stage_threads,
            )
        })
    }

    /// Shared column-chunking driver for the `_par` entry points. Counts
    /// ONE logical cascade itself; `apply` must be an *uncounted* blocked
    /// apply so chunked execution doesn't inflate the counter. The second
    /// argument handed to `apply` is the stage-level thread cap: when the
    /// columns are sharded the chunks are the parallel grain (stage
    /// rotations run serial inside each), when they are not the cascade
    /// parallelizes over rotation blocks instead. Either schedule yields
    /// bit-identical results.
    pub(crate) fn par_over_cols<F>(&self, z: &Mat, n_threads: usize, apply: F) -> Mat
    where
        F: Fn(&Mat, usize) -> Mat + Send + Sync,
    {
        CASCADES.fetch_add(1, Ordering::Relaxed);
        if n_threads <= 1 || z.cols < MIN_PAR_COLS.max(2 * n_threads) {
            return apply(z, self.n_threads.max(n_threads));
        }
        let chunks = chunk_ranges(z.cols, n_threads);
        let parts = par_map(chunks, n_threads, |_, (c0, c1)| {
            // Column chunk via per-worker arena scratch (every row is
            // overwritten by the copy).
            let mut sub = arena::take_mat(z.rows, c1 - c0);
            for r in 0..z.rows {
                sub.row_mut(r).copy_from_slice(&z.row(r)[c0..c1]);
            }
            let out = apply(&sub, 1);
            arena::give_mat(sub);
            out
        });
        let out = Mat::hstack(&parts);
        for p in parts {
            arena::give_mat(p);
        }
        out
    }

    /// Generic spectral application: given how to act on the final core
    /// vector and how to map each wavelet diagonal value, apply the
    /// corresponding matrix function of K̃ + shift·I (Proposition 7
    /// pattern; `dmap` receives the noise-free diagonal values, so shift
    /// handling belongs to the caller's closures). Stage rotations run
    /// block-parallel under `self.n_threads` (bit-identical to serial at
    /// any thread count).
    pub(crate) fn apply_with(
        &self,
        z: &[f64],
        core_op: impl Fn(&[f64]) -> Vec<f64>,
        dmap: impl Fn(f64) -> f64,
    ) -> Vec<f64> {
        assert_eq!(z.len(), self.n, "matvec dimension mismatch");
        CASCADES.fetch_add(1, Ordering::Relaxed);
        let threads = self.n_threads;
        let mut scratch: Vec<f64> = arena::take_vec(0);
        let mut v = arena::take_vec(self.n);
        v.copy_from_slice(z);
        let mut wavs: Vec<Vec<f64>> = Vec::with_capacity(self.stages.len());
        for st in self.stages.iter() {
            let (core, wav) = st.forward_mt(&mut v, &mut scratch, threads);
            wavs.push(wav);
            arena::give_vec(std::mem::replace(&mut v, core));
        }
        // Core action.
        let mut u = core_op(&v);
        arena::give_vec(v);
        // Backward cascade, scaling wavelet coefficients by f(D); dead
        // intermediates are donated back to the arena as they retire.
        for (st, wav) in self.stages.iter().zip(wavs.iter()).rev() {
            let mut scaled = arena::take_vec(wav.len());
            for ((s, w), &d) in scaled.iter_mut().zip(wav).zip(&st.dvals) {
                *s = w * dmap(d);
            }
            let next = st.backward_mt(&u, &scaled, &mut scratch, threads);
            arena::give_vec(scaled);
            arena::give_vec(std::mem::replace(&mut u, next));
        }
        for w in wavs {
            arena::give_vec(w);
        }
        arena::give_vec(scratch);
        u
    }

    /// Blocked analogue of [`MkaFactor::apply_with`]: one forward sweep
    /// carries every column of `z`, the core action is a single matrix op,
    /// and f(D_ℓ) scales whole wavelet rows (contiguous in the row-major
    /// layout). This is the Proposition 6/7 cascade at block granularity —
    /// the batched-serving hot path.
    pub(crate) fn apply_with_mat(
        &self,
        z: &Mat,
        core_op: impl Fn(&Mat) -> Mat,
        dmap: impl Fn(f64) -> f64,
    ) -> Mat {
        CASCADES.fetch_add(1, Ordering::Relaxed);
        self.apply_with_mat_stage(z, core_op, dmap, self.n_threads)
    }

    /// The cascade body without the counter bump — chunk workers of the
    /// `_par` entry points use this so a sharded apply still counts as
    /// one logical cascade. `stage_threads` caps the block-parallel
    /// rotation work inside each stage.
    pub(crate) fn apply_with_mat_stage(
        &self,
        z: &Mat,
        core_op: impl Fn(&Mat) -> Mat,
        dmap: impl Fn(f64) -> f64,
        stage_threads: usize,
    ) -> Mat {
        assert_eq!(z.rows, self.n, "matmat dimension mismatch");
        let mut v = arena::take_mat(z.rows, z.cols);
        v.data.copy_from_slice(&z.data);
        let mut wavs: Vec<Mat> = Vec::with_capacity(self.stages.len());
        for (si, st) in self.stages.iter().enumerate() {
            let _sp = crate::obs::span!("stage {si} fwd b={}", z.cols);
            let (core, wav) = st.forward_mat_mt(&mut v, stage_threads);
            wavs.push(wav);
            arena::give_mat(std::mem::replace(&mut v, core));
        }
        // Core action on the whole block.
        let mut u = {
            let _sp = crate::obs::span!("core {0}x{0} b={1}", self.core.rows, z.cols);
            core_op(&v)
        };
        arena::give_mat(v);
        // Backward cascade, scaling each wavelet row by f(d); the wavelet
        // buffers are dead after this, so scale them in place and donate
        // them (and each retired `u`) back to the per-worker arenas.
        let n_stages = self.stages.len();
        for (ri, (st, mut wav)) in self.stages.iter().zip(wavs).rev().enumerate() {
            let _sp = crate::obs::span!("stage {} bwd b={}", n_stages - 1 - ri, z.cols);
            let mut fd = arena::take_vec(st.dvals.len());
            for (f, &d) in fd.iter_mut().zip(&st.dvals) {
                *f = dmap(d);
            }
            scale_rows(&mut wav, &fd);
            arena::give_vec(fd);
            let next = st.backward_mat_mt(&u, &wav, stage_threads);
            arena::give_mat(std::mem::replace(&mut u, next));
            arena::give_mat(wav);
        }
        u
    }

    /// Dense reconstruction of K̃ + shift·I (tests / small n only): one
    /// blocked cascade over the identity instead of n serial matvecs.
    pub fn to_dense(&self) -> Mat {
        self.matmat(&Mat::eye(self.n))
    }

    /// Stored reals (Proposition 3/5): rotations + diagonals + core.
    pub fn stored_reals(&self) -> usize {
        self.stages.iter().map(|s| s.stored_reals()).sum::<usize>()
            + self.core.rows * self.core.cols
    }

    /// All wavelet diagonal values across stages, **with the shift
    /// applied** — i.e. the part of the spectrum of K̃ + shift·I that
    /// lives outside the core (up to rotation).
    pub fn all_dvals(&self) -> Vec<f64> {
        self.stages
            .iter()
            .flat_map(|s| s.dvals.iter().map(|&d| d + self.shift))
            .collect()
    }

    /// Structural validation of the whole factor (including the shift:
    /// a noise variance must be finite and nonnegative).
    pub fn check_valid(&self) -> bool {
        if !self.shift.is_finite() || self.shift < 0.0 {
            return false;
        }
        let mut dim = self.n;
        for st in self.stages.iter() {
            if st.n_in != dim || !st.check_valid() {
                return false;
            }
            dim = st.c();
        }
        dim == self.core.rows && self.core.is_square()
    }

    /// Numerical-health report of this (shifted) factor, computed from
    /// **held state only**: per-stage dimensions/compression plus the
    /// explicit shifted spectrum extremes (Proposition 7: core
    /// eigenvalues ∪ wavelet diagonal, every value + shift). May lazily
    /// trigger the core EVD (the d³ step shared by every shifted view) —
    /// never a refactorization; [`factorize_count`] is unchanged.
    pub fn health(&self) -> FactorHealth {
        let stages: Vec<StageHealth> = self
            .stages
            .iter()
            .enumerate()
            .map(|(i, st)| StageHealth {
                stage: i,
                n_in: st.n_in,
                n_out: st.c(),
                wavelets: st.dvals.len(),
                compression: st.compression(),
            })
            .collect();
        let mut lambda_min = f64::INFINITY;
        let mut lambda_max = f64::NEG_INFINITY;
        let spectrum = self
            .eig()
            .values
            .iter()
            .map(|&v| v + self.shift)
            .chain(self.all_dvals());
        for v in spectrum {
            lambda_min = lambda_min.min(v);
            lambda_max = lambda_max.max(v);
        }
        let condition = if lambda_min > 0.0 { lambda_max / lambda_min } else { f64::INFINITY };
        FactorHealth {
            n: self.n,
            d_core: self.d_core(),
            n_stages: self.n_stages(),
            shift: self.shift,
            stored_reals: self.stored_reals(),
            lambda_min,
            lambda_max,
            condition,
            stages,
        }
    }
}

/// Dimensions and compression of one cascade stage, for diagnostics.
#[derive(Clone, Debug)]
pub struct StageHealth {
    /// Stage index (0 = outermost).
    pub stage: usize,
    /// Rows entering the stage.
    pub n_in: usize,
    /// Core rows leaving the stage.
    pub n_out: usize,
    /// Wavelet (diagonal) rows split off.
    pub wavelets: usize,
    /// `n_out / n_in` — the realized per-stage γ.
    pub compression: f64,
}

/// Snapshot of an [`MkaFactor`]'s numerical health (the coordinator's
/// `diagnose` payload). See [`MkaFactor::health`].
#[derive(Clone, Debug)]
pub struct FactorHealth {
    /// Ambient dimension n.
    pub n: usize,
    /// Final core size.
    pub d_core: usize,
    /// Number of cascade stages.
    pub n_stages: usize,
    /// Diagonal noise shift σ² of the reporting view.
    pub shift: f64,
    /// Stored reals (Proposition 3/5 accounting).
    pub stored_reals: usize,
    /// Smallest shifted spectral value (core eigenvalues ∪ wavelet
    /// diagonal, + shift).
    pub lambda_min: f64,
    /// Largest shifted spectral value.
    pub lambda_max: f64,
    /// `lambda_max / lambda_min`, or +∞ when λ_min ≤ 0 (singular /
    /// indefinite under roundoff).
    pub condition: f64,
    /// Per-stage dimensions, outermost first.
    pub stages: Vec<StageHealth>,
}

impl FactorHealth {
    /// Serialize for the `diagnose` op. Non-finite numbers (a +∞
    /// condition) serialize as JSON `null` per the crate's JSON rules.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .with("n", Json::Num(self.n as f64))
            .with("d_core", Json::Num(self.d_core as f64))
            .with("n_stages", Json::Num(self.n_stages as f64))
            .with("shift", Json::Num(self.shift))
            .with("stored_reals", Json::Num(self.stored_reals as f64))
            .with(
                "overall_compression",
                Json::Num(self.stored_reals as f64 / ((self.n * self.n).max(1) as f64)),
            )
            .with("lambda_min", Json::Num(self.lambda_min))
            .with("lambda_max", Json::Num(self.lambda_max))
            .with("condition", Json::Num(self.condition))
            .with(
                "stages",
                Json::Arr(
                    self.stages
                        .iter()
                        .map(|s| {
                            Json::obj()
                                .with("stage", Json::Num(s.stage as f64))
                                .with("n_in", Json::Num(s.n_in as f64))
                                .with("n_out", Json::Num(s.n_out as f64))
                                .with("wavelets", Json::Num(s.wavelets as f64))
                                .with("compression", Json::Num(s.compression))
                        })
                        .collect(),
                ),
            )
    }
}

/// u += s · z elementwise — the core block's share of the diagonal shift
/// (the forward cascade is orthogonal, so shifting the core coordinates
/// by s·I and every wavelet value by s reproduces K + sI exactly).
fn shift_acc(u: &mut Mat, z: &Mat, s: f64) {
    if s != 0.0 {
        axpy(s, &z.data, &mut u.data);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::QFactor;
    use crate::la::givens::{Givens, GivensSeq};
    use crate::mka::stage::BlockFactor;
    use crate::util::Rng;

    /// A hand-built 4→2 single-stage factor for exact checks.
    fn tiny_factor() -> MkaFactor {
        let mut seq = GivensSeq::new();
        seq.push(Givens::jacobi(0, 1, 3.0, 1.0, 2.0));
        let stage = Stage {
            n_in: 4,
            blocks: vec![
                BlockFactor { idx: vec![0, 1], q: QFactor::Givens(seq) },
                BlockFactor { idx: vec![2, 3], q: QFactor::Identity },
            ],
            core_global: vec![0, 2],
            wavelet_global: vec![1, 3],
            dvals: vec![0.7, 0.9],
        };
        let core = Mat::from_rows(&[&[2.0, 0.3], &[0.3, 1.5]]);
        MkaFactor::new(4, vec![stage], core)
    }

    #[test]
    fn structure_valid() {
        let f = tiny_factor();
        assert!(f.check_valid());
        assert_eq!(f.d_core(), 2);
        assert_eq!(f.n_stages(), 1);
    }

    #[test]
    fn matvec_matches_dense_reconstruction() {
        let f = tiny_factor();
        let dense = f.to_dense();
        assert!(dense.asymmetry() < 1e-12, "K̃ must be symmetric");
        let mut rng = Rng::new(1);
        let z = rng.normal_vec(4);
        let y = f.matvec(&z);
        let y2 = gemv(&dense, &z);
        for i in 0..4 {
            assert!((y[i] - y2[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn matvec_linear() {
        let f = tiny_factor();
        let mut rng = Rng::new(2);
        let a = rng.normal_vec(4);
        let b = rng.normal_vec(4);
        let ab: Vec<f64> = a.iter().zip(&b).map(|(x, y)| 2.0 * x - 3.0 * y).collect();
        let fa = f.matvec(&a);
        let fb = f.matvec(&b);
        let fab = f.matvec(&ab);
        for i in 0..4 {
            assert!((fab[i] - (2.0 * fa[i] - 3.0 * fb[i])).abs() < 1e-12);
        }
    }

    #[test]
    fn dense_is_psd_when_parts_are() {
        // Core is pd, dvals positive ⇒ K̃ psd (Proposition 1).
        let f = tiny_factor();
        let e = crate::la::evd::SymEig::new(&f.to_dense());
        assert!(e.values[0] > 0.0);
    }

    #[test]
    fn stored_reals_accounting() {
        let f = tiny_factor();
        // 1 rotation (2) + 2 dvals + 2x2 core = 8
        assert_eq!(f.stored_reals(), 8);
    }

    #[test]
    fn matmat_matches_per_column_matvec() {
        let f = tiny_factor();
        let mut rng = Rng::new(5);
        let z = Mat::from_fn(4, 7, |_, _| rng.normal());
        let blocked = f.matmat(&z);
        for j in 0..7 {
            let col = f.matvec(&z.col(j));
            for i in 0..4 {
                assert!((blocked.at(i, j) - col[i]).abs() < 1e-12, "({i},{j})");
            }
        }
    }

    #[test]
    fn matmat_par_matches_serial() {
        let f = tiny_factor();
        let mut rng = Rng::new(6);
        let z = Mat::from_fn(4, 40, |_, _| rng.normal());
        let serial = f.matmat(&z);
        let parallel = f.matmat_par(&z, 4);
        assert!(parallel.sub(&serial).max_abs() < 1e-12);
        // Narrow blocks take the serial path and still agree.
        let narrow = Mat::from_fn(4, 3, |_, _| rng.normal());
        assert!(f.matmat_par(&narrow, 4).sub(&f.matmat(&narrow)).max_abs() < 1e-12);
    }

    #[test]
    fn blocked_apply_counts_one_cascade() {
        let f = tiny_factor();
        let mut rng = Rng::new(7);
        let z = Mat::from_fn(4, 9, |_, _| rng.normal());
        let before = cascade_count();
        let _ = f.matmat(&z);
        // Other tests run concurrently in this binary, so only a lower
        // bound is exact — but a single blocked apply adds exactly one.
        assert!(cascade_count() >= before + 1);
    }

    #[test]
    fn shifted_is_a_cheap_view() {
        let f = tiny_factor();
        let fs = f.shifted(0.5);
        // Rotations, core and the (lazy) core EVD are shared, not copied.
        assert!(Arc::ptr_eq(&f.stages, &fs.stages));
        assert!(Arc::ptr_eq(&f.core, &fs.core));
        assert!(Arc::ptr_eq(&f.core_eig, &fs.core_eig));
        assert_eq!(fs.shift, 0.5);
        // The shift is absolute, not cumulative.
        assert_eq!(fs.shifted(0.2).shift, 0.2);
        assert!(f.check_valid() && fs.check_valid());
        // A noise variance must be finite and nonnegative.
        assert!(!f.shifted(-1.0).check_valid());
        assert!(!f.shifted(f64::NAN).check_valid());
    }

    #[test]
    fn shifted_matvec_and_dense_add_sigma2_identity() {
        let f = tiny_factor();
        let s2 = 0.37;
        let fs = f.shifted(s2);
        // to_dense of the view is exactly K̃ + σ²I.
        let mut expect = f.to_dense();
        expect.add_diag(s2);
        assert!(fs.to_dense().sub(&expect).max_abs() < 1e-12);
        // matvec and blocked/parallel matmat agree with the dense shift.
        let mut rng = Rng::new(8);
        let z = rng.normal_vec(4);
        let y = fs.matvec(&z);
        let y2 = gemv(&expect, &z);
        for i in 0..4 {
            assert!((y[i] - y2[i]).abs() < 1e-12);
        }
        let zb = Mat::from_fn(4, 20, |_, _| rng.normal());
        let blocked = fs.matmat(&zb);
        let par = fs.matmat_par(&zb, 3);
        assert!(par.sub(&blocked).max_abs() < 1e-12);
        for j in 0..20 {
            let col = fs.matvec(&zb.col(j));
            for i in 0..4 {
                assert!((blocked.at(i, j) - col[i]).abs() < 1e-12);
            }
        }
        // all_dvals reads through the shift.
        assert_eq!(fs.all_dvals(), vec![0.7 + s2, 0.9 + s2]);
        assert_eq!(f.all_dvals(), vec![0.7, 0.9]);
    }

    #[test]
    fn health_reports_shifted_spectrum_without_refactorize() {
        let f = tiny_factor();
        let s2 = 0.5;
        let before = factorize_count();
        let h = f.shifted(s2).health();
        assert_eq!(factorize_count(), before, "health must not factorize");
        assert_eq!(h.n, 4);
        assert_eq!(h.d_core, 2);
        assert_eq!(h.n_stages, 1);
        assert_eq!(h.shift, s2);
        assert_eq!(h.stages.len(), 1);
        assert_eq!(h.stages[0].n_in, 4);
        assert_eq!(h.stages[0].n_out, 2);
        assert_eq!(h.stages[0].wavelets, 2);
        assert!((h.stages[0].compression - 0.5).abs() < 1e-15);
        // Spectrum = eig(core) ∪ dvals, all + σ². Core [[2.0,0.3],[0.3,1.5]]
        // has eigenvalues 1.75 ± sqrt(0.0625 + 0.09).
        let disc = (0.0625f64 + 0.09).sqrt();
        let expect_min = (1.75 - disc + s2).min(0.7 + s2);
        let expect_max = (1.75 + disc + s2).max(0.9 + s2);
        assert!((h.lambda_min - expect_min).abs() < 1e-12, "λ_min {}", h.lambda_min);
        assert!((h.lambda_max - expect_max).abs() < 1e-12, "λ_max {}", h.lambda_max);
        assert!((h.condition - expect_max / expect_min).abs() < 1e-9);
        let rendered = h.to_json().dump();
        assert!(rendered.contains("\"condition\""));
        assert!(rendered.contains("\"stages\""));
    }
}
