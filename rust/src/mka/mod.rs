//! Multiresolution Kernel Approximation — the paper's Algorithm 1.
//!
//! [`factorize`] drives the stage loop: cluster → compress each diagonal
//! block (in parallel) → apply Q̄_ℓ = ⊕Q_i to the whole matrix → split into
//! the next core K_ℓ and the wavelet diagonal D_ℓ → recurse on K_ℓ. The
//! result is an [`MkaFactor`] supporting matrix-free matvec / solve /
//! logdet / powers / exp (Propositions 6–7).
//!
//! Paper ↔ type map: K̃ (eq. 6) is [`MkaFactor`]; each Q̄_ℓ with its
//! wavelet diagonal D_ℓ is a [`Stage`] (`blocks` hold the per-cluster
//! rotations, `dvals` the D_ℓ entries); the final dense core K_s is
//! `MkaFactor::core` with d_core = [`MkaConfig::d_core`]; the explicit
//! spectrum of Proposition 7 (core eigenvalues ∪ wavelet diagonal) backs
//! `solve`/`logdet`/`spectrum` in [`ops`], which the training plane
//! consumes for evidence values *and* gradients
//! ([`crate::train::grad`]).
//!
//! Noise is a **view, not an input**: [`factorize`] operates on the
//! noise-free gram, and `K + σ²I` is served by the O(1)
//! [`MkaFactor::shifted`] view (same rotations, spectrum moved by σ² —
//! see the `factor` module docs for the exactness argument). Callers that
//! used to bake σ² into the gram with `add_diag` before factorizing
//! should factorize noise-free and shift instead; σ² re-tunes then cost
//! zero factorizations, observable through [`factorize_count`].

pub mod factor;
pub mod ops;
pub mod parallel;
pub mod stage;
pub mod update;

pub use factor::{
    cascade_count, factorize_count, stage_rebuild_count, stage_reuse_count, FactorHealth,
    MkaFactor, StageHealth,
};
pub use stage::{BlockFactor, Stage};
pub use update::{extend_factorize, ExtendStats};

use crate::cluster::{cluster_rows, ClusterMethod};
use crate::compress::{Compression, CompressorKind, QFactor};
use crate::error::{Error, Result};
use crate::la::blas::{gemm_mt, gemm_nt_mt};
use crate::la::dense::Mat;
use crate::par::SendPtr;
use crate::util::Rng;

/// Configuration for the MKA factorization.
#[derive(Clone, Debug)]
pub struct MkaConfig {
    /// Stop when the running core is at most this size; the final K_s is
    /// d_core×d_core (the paper's analogue of "number of pseudo-inputs").
    pub d_core: usize,
    /// Target cluster/block size m (m_max in the complexity analysis).
    pub block_size: usize,
    /// Per-stage compression ratio γ = c/m (the paper uses γ ≈ 1/2:
    /// "c is often on the order of m/2").
    pub gamma: f64,
    /// Safety cap on the number of stages.
    pub max_stages: usize,
    /// Which core-diagonal compressor to use (MKA is a meta-algorithm).
    pub compressor: CompressorKind,
    /// Clustering method for stage 1 (later stages always use affinity
    /// clustering on the compressed matrix).
    pub cluster_method: ClusterMethod,
    /// RNG seed (clustering, SPCA initialization).
    pub seed: u64,
    /// Worker threads for block compression.
    pub n_threads: usize,
    /// Relative floor for wavelet diagonal values: values below
    /// `diag_floor · max_diag` are clamped up, preserving spsd-ness
    /// (Proposition 1) under roundoff.
    pub diag_floor: f64,
}

impl Default for MkaConfig {
    fn default() -> Self {
        MkaConfig {
            d_core: 64,
            block_size: 256,
            gamma: 0.5,
            max_stages: 32,
            compressor: CompressorKind::Mmf,
            cluster_method: ClusterMethod::KMeans,
            seed: 42,
            n_threads: 1,
            diag_floor: 1e-12,
        }
    }
}

impl MkaConfig {
    pub fn with_d_core(mut self, d: usize) -> Self {
        self.d_core = d;
        self
    }

    pub fn with_compressor(mut self, c: CompressorKind) -> Self {
        self.compressor = c;
        self
    }

    pub fn with_block_size(mut self, b: usize) -> Self {
        self.block_size = b;
        self
    }

    pub fn with_gamma(mut self, g: f64) -> Self {
        self.gamma = g;
        self
    }

    pub fn with_seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    pub fn with_threads(mut self, t: usize) -> Self {
        self.n_threads = t;
        self
    }

    pub fn validate(&self) -> Result<()> {
        if !(0.0 < self.gamma && self.gamma < 1.0) {
            return Err(Error::Config(format!("gamma must be in (0,1), got {}", self.gamma)));
        }
        if self.d_core == 0 || self.block_size < 2 {
            return Err(Error::Config("d_core >= 1 and block_size >= 2 required".into()));
        }
        Ok(())
    }
}

/// Factorize a symmetric psd kernel matrix. `x` (the data points, rows
/// aligned with `k`) is optional — when present, stage-1 clustering uses
/// the point geometry; otherwise row affinity of K itself is used.
pub fn factorize(k: &Mat, x: Option<&Mat>, config: &MkaConfig) -> Result<MkaFactor> {
    config.validate()?;
    if !k.is_square() {
        return Err(Error::Linalg("MKA needs a square matrix".into()));
    }
    if k.asymmetry() > 1e-6 * k.max_abs().max(1.0) {
        return Err(Error::Linalg("MKA needs a symmetric matrix".into()));
    }
    factor::record_factorize();
    let n = k.rows;
    let _sp = crate::obs::span!("mka.factorize n={n}");
    let mut rng = Rng::new(config.seed);
    let compressor = config.compressor.build();
    let mut kc = k.clone();
    kc.symmetrize();
    let mut stages: Vec<Stage> = Vec::new();
    // Phase profiling for the perf pass: MKA_PROFILE=1 prints per-stage
    // timings of the four phases (cluster / compress / rotate / split).
    let profile = std::env::var("MKA_PROFILE").is_ok();

    while kc.rows > config.d_core && stages.len() < config.max_stages {
        let n_cur = kc.rows;
        let _stage_sp = crate::obs::span!("factorize.stage {} n={n_cur}", stages.len());
        let t_stage = crate::util::Timer::start();
        // ---- 1. cluster --------------------------------------------------
        let clustering = if stages.is_empty() {
            cluster_rows(config.cluster_method, x, Some(&kc), n_cur, config.block_size, &mut rng)
        } else {
            cluster_rows(ClusterMethod::Affinity, None, Some(&kc), n_cur, config.block_size, &mut rng)
        };
        debug_assert!(clustering.is_partition_of(n_cur));
        let t_cluster = t_stage.elapsed_secs();

        // ---- per-block core targets --------------------------------------
        let targets = block_targets(&clustering.clusters, config.gamma, config.d_core, n_cur);

        // ---- 2. compress diagonal blocks (parallel) ----------------------
        let work: Vec<(Vec<usize>, usize, u64)> = clustering
            .clusters
            .iter()
            .zip(&targets)
            .enumerate()
            .map(|(bi, (idx, &c))| (idx.clone(), c, config.seed ^ ((stages.len() as u64) << 32) ^ bi as u64))
            .collect();
        let kc_ref = &kc;
        let compressor = &compressor;
        let comps: Vec<(Vec<usize>, Compression)> =
            parallel::par_map(work, config.n_threads, move |_, (idx, c_target, seed)| {
                let a = kc_ref.gather(&idx, &idx);
                let mut brng = Rng::new(seed);
                let comp = compressor.compress(&a, c_target, &mut brng);
                debug_assert!(comp.is_valid_for(idx.len()));
                (idx, comp)
            });

        let t_compress = t_stage.elapsed_secs() - t_cluster;

        // ---- 3. rotate the FULL matrix by Q̄ = ⊕Q_i ----------------------
        apply_stage_rotations(&mut kc, &comps, config.n_threads);
        let t_rotate = t_stage.elapsed_secs() - t_cluster - t_compress;

        // ---- 4–5. split core / wavelet, read D from the rotated diagonal -
        let mut core_global: Vec<usize> = Vec::new();
        let mut wavelet_global: Vec<usize> = Vec::new();
        let mut blocks: Vec<BlockFactor> = Vec::with_capacity(comps.len());
        for (idx, comp) in comps {
            for &c in &comp.core_local {
                core_global.push(idx[c]);
            }
            for &w in &comp.wavelet_local {
                wavelet_global.push(idx[w]);
            }
            blocks.push(BlockFactor { idx, q: comp.q });
        }
        // psd clamp for the diagonal (Proposition 1 under roundoff)
        let max_diag = kc.diagonal().iter().fold(0.0f64, |m, &v| m.max(v.abs())).max(1e-300);
        let floor = config.diag_floor * max_diag;
        let dvals: Vec<f64> =
            wavelet_global.iter().map(|&i| kc.at(i, i).max(floor)).collect();

        if core_global.len() == n_cur {
            // No compression happened (e.g. d_core ≥ γ·n); avoid looping.
            break;
        }

        let next = kc.gather(&core_global, &core_global);
        if profile {
            eprintln!(
                "[mka-profile] stage {}: n={} cluster={:.3}s compress={:.3}s rotate={:.3}s split={:.3}s",
                stages.len(),
                n_cur,
                t_cluster,
                t_compress,
                t_rotate,
                t_stage.elapsed_secs() - t_cluster - t_compress - t_rotate
            );
        }
        stages.push(Stage { n_in: n_cur, blocks, core_global, wavelet_global, dvals });
        kc = next;
        kc.symmetrize();
    }

    let f = MkaFactor::new(n, stages, kc).with_threads(config.n_threads);
    debug_assert!(f.check_valid());
    Ok(f)
}

/// Per-block core sizes: start at round(γ·m_i), then adjust upward so the
/// stage's total core is at least d_core (never compress past the final
/// target) while keeping every block ≥ 1 core row.
fn block_targets(clusters: &[Vec<usize>], gamma: f64, d_core: usize, n_cur: usize) -> Vec<usize> {
    let mut targets: Vec<usize> = clusters
        .iter()
        .map(|c| (((c.len() as f64) * gamma).round() as usize).clamp(1, c.len()))
        .collect();
    let mut total: usize = targets.iter().sum();
    let want = d_core.min(n_cur);
    // Bump round-robin until the total core is ≥ d_core.
    let mut i = 0;
    while total < want {
        let m = clusters[i % clusters.len()].len();
        if targets[i % clusters.len()] < m {
            targets[i % clusters.len()] += 1;
            total += 1;
        }
        i += 1;
        if i > 4 * n_cur {
            break; // every block saturated
        }
    }
    targets
}

/// Below this matrix dimension the stage rotation stays serial.
const ROTATE_PAR_MIN_N: usize = 512;

/// Apply the whole stage rotation K ← Q̄ K Q̄ᵀ with Q̄ = ⊕Q_i, in two
/// phases:
///
/// 1. **Left (rows)**: K[idxᵢ, :] ← Qᵢ · K[idxᵢ, :]. Blocks own disjoint
///    row sets, so blocks run in parallel; when a stage has few blocks,
///    each block's work is further split into column panels (a rotation
///    acts on each column independently, so panels don't change bits).
/// 2. **Right (columns)**: K[:, idxᵢ] ← K[:, idxᵢ] · Qᵢᵀ for every block,
///    sharded over row bands — each row's entries at `idx` positions
///    rotate like a gathered vector.
///
/// Serial execution runs the exact same phase kernels over single ranges,
/// so the result is bit-identical at any thread count.
fn apply_stage_rotations(kc: &mut Mat, comps: &[(Vec<usize>, Compression)], threads: usize) {
    let n = kc.rows;
    if n == 0 {
        return;
    }
    let t = if threads <= 1 || n < ROTATE_PAR_MIN_N { 1 } else { threads };
    let kptr = SendPtr::new(kc.data.as_mut_ptr());

    // ---- Phase 1: left multiply (rows) --------------------------------
    // Work units are (block, column panel) pairs; panels only exist when
    // blocks alone can't feed the requested parallelism. Each unit owns a
    // disjoint row×col region, and `run_tasks` caps in-flight tasks at t.
    // panels is capped by n: chunk_ranges clamps its output to n ranges,
    // so an oversized configured thread count must not out-index it.
    let panels = if t <= 1 || comps.len() >= 2 * t { 1 } else { t.min(n) };
    let panel_ranges = parallel::chunk_ranges(n, panels);
    debug_assert_eq!(panel_ranges.len(), panels);
    let panel_ranges = &panel_ranges;
    crate::par::run_tasks(comps.len() * panels, t, move |u| {
        let (idx, comp) = &comps[u / panels];
        let (c0, c1) = panel_ranges[u % panels];
        // SAFETY: blocks own disjoint rows; panels own disjoint columns
        // within a block (serial execution when t <= 1).
        unsafe { rotate_block_rows_ptr(&comp.q, idx, kptr, n, c0, c1) };
    });

    // ---- Phase 2: right multiply (columns), row-banded ----------------
    crate::par::for_ranges(n, t, move |_, r0, r1| {
        for (idx, comp) in comps {
            // SAFETY: bands own disjoint rows of K.
            unsafe { rotate_block_cols_ptr(&comp.q, idx, kptr, n, r0, r1) };
        }
    });
}

/// Left-phase kernel: rows `idx` of the n×n buffer, columns [c0, c1) only,
/// get Q applied (row mixing).
///
/// # Safety
/// The caller guarantees exclusive access to the (idx × [c0, c1)) region.
unsafe fn rotate_block_rows_ptr(
    q: &QFactor,
    idx: &[usize],
    kptr: SendPtr<f64>,
    n: usize,
    c0: usize,
    c1: usize,
) {
    let data = kptr.ptr();
    match q {
        QFactor::Identity => {}
        QFactor::Givens(seq) => {
            for g in &seq.rots {
                let (gi, gj) = (idx[g.i], idx[g.j]);
                let ri = std::slice::from_raw_parts_mut(data.add(gi * n + c0), c1 - c0);
                let rj = std::slice::from_raw_parts_mut(data.add(gj * n + c0), c1 - c0);
                for (a, b) in ri.iter_mut().zip(rj.iter_mut()) {
                    let (x, y) = (*a, *b);
                    *a = g.c * x + g.s * y;
                    *b = -g.s * x + g.c * y;
                }
            }
        }
        QFactor::Dense(qm) => {
            let m = idx.len();
            let w = c1 - c0;
            let mut sub = Mat::zeros(m, w);
            for (a, &i) in idx.iter().enumerate() {
                std::ptr::copy_nonoverlapping(data.add(i * n + c0), sub.row_mut(a).as_mut_ptr(), w);
            }
            let new = gemm_mt(qm, &sub, 1);
            for (a, &i) in idx.iter().enumerate() {
                std::ptr::copy_nonoverlapping(new.row(a).as_ptr(), data.add(i * n + c0), w);
            }
        }
    }
}

/// Right-phase kernel: rows [r0, r1) get K[r, idx] ← K[r, idx] · Qᵀ — the
/// entries at `idx` positions of each row rotate exactly like a gathered
/// vector under Q (uᵀ Qᵀ = (Q u)ᵀ).
///
/// # Safety
/// The caller guarantees exclusive access to rows [r0, r1).
unsafe fn rotate_block_cols_ptr(
    q: &QFactor,
    idx: &[usize],
    kptr: SendPtr<f64>,
    n: usize,
    r0: usize,
    r1: usize,
) {
    let data = kptr.ptr();
    match q {
        QFactor::Identity => {}
        QFactor::Givens(seq) => {
            for r in r0..r1 {
                let row = std::slice::from_raw_parts_mut(data.add(r * n), n);
                for g in &seq.rots {
                    let (gi, gj) = (idx[g.i], idx[g.j]);
                    let (x, y) = (row[gi], row[gj]);
                    row[gi] = g.c * x + g.s * y;
                    row[gj] = -g.s * x + g.c * y;
                }
            }
        }
        QFactor::Dense(qm) => {
            let m = idx.len();
            let h = r1 - r0;
            // Gather K[r0..r1, idx] (h×m), right-multiply by Qᵀ, scatter.
            let mut sub = Mat::zeros(h, m);
            for r in r0..r1 {
                let srow = sub.row_mut(r - r0);
                for (b, &j) in idx.iter().enumerate() {
                    srow[b] = *data.add(r * n + j);
                }
            }
            let new = gemm_nt_mt(&sub, qm, 1); // (h×m)·(m×m)ᵀ
            for r in r0..r1 {
                let nrow = new.row(r - r0);
                for (b, &j) in idx.iter().enumerate() {
                    *data.add(r * n + j) = nrow[b];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{Kernel, RbfKernel};
    use crate::la::evd::SymEig;

    fn kernel_matrix(n: usize, d: usize, ell: f64, seed: u64) -> (Mat, Mat) {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(n, d, |_, _| rng.normal());
        let mut k = RbfKernel::new(ell).gram_sym(&x);
        k.add_diag(0.1); // σ² — keeps everything well conditioned
        (k, x)
    }

    fn small_config(d_core: usize, block: usize) -> MkaConfig {
        // Bisect keeps blocks balanced on these unclustered random inputs,
        // making the quality thresholds below stable across seeds.
        MkaConfig {
            d_core,
            block_size: block,
            n_threads: 2,
            cluster_method: ClusterMethod::Bisect,
            ..MkaConfig::default()
        }
    }

    #[test]
    fn factorize_shapes_and_validity() {
        let (k, x) = kernel_matrix(96, 3, 1.0, 1);
        let f = factorize(&k, Some(&x), &small_config(16, 32)).unwrap();
        assert_eq!(f.n, 96);
        assert!(f.d_core() <= 32, "d_core = {}", f.d_core());
        assert!(f.d_core() >= 16);
        assert!(f.n_stages() >= 2);
        assert!(f.check_valid());
    }

    #[test]
    fn approximation_quality_reasonable() {
        let (k, x) = kernel_matrix(80, 3, 2.0, 2);
        let f = factorize(&k, Some(&x), &small_config(20, 27)).unwrap();
        let dense = f.to_dense();
        let rel = dense.sub(&k).frob_norm() / k.frob_norm();
        assert!(rel < 0.35, "relative error {rel}");
        // diagonal must be well preserved (core + diagonal keeps it)
        for i in 0..80 {
            assert!((dense.at(i, i) - k.at(i, i)).abs() < 0.5);
        }
    }

    #[test]
    fn spsd_preserved() {
        // Proposition 1: K̃ spsd whenever K is.
        let (k, x) = kernel_matrix(64, 2, 0.5, 3);
        let f = factorize(&k, Some(&x), &small_config(8, 16)).unwrap();
        assert!(f.min_eig() > 0.0, "min eig {}", f.min_eig());
        let e = SymEig::new(&f.to_dense());
        assert!(e.values[0] > -1e-9);
    }

    #[test]
    fn no_compression_when_small() {
        let (k, x) = kernel_matrix(20, 2, 1.0, 4);
        let f = factorize(&k, Some(&x), &small_config(32, 16)).unwrap();
        // n ≤ d_core: no stages, core is K itself.
        assert_eq!(f.n_stages(), 0);
        assert!(f.to_dense().sub(&k).max_abs() < 1e-10);
    }

    #[test]
    fn deterministic_given_seed() {
        let (k, x) = kernel_matrix(60, 3, 1.0, 5);
        let f1 = factorize(&k, Some(&x), &small_config(12, 20)).unwrap();
        let f2 = factorize(&k, Some(&x), &small_config(12, 20)).unwrap();
        let d1 = f1.to_dense();
        let d2 = f2.to_dense();
        assert!(d1.sub(&d2).max_abs() < 1e-12);
    }

    #[test]
    fn works_without_points() {
        let (k, _) = kernel_matrix(48, 3, 1.0, 6);
        let f = factorize(&k, None, &small_config(12, 16)).unwrap();
        assert!(f.check_valid());
        let rel = f.to_dense().sub(&k).frob_norm() / k.frob_norm();
        assert!(rel < 0.5, "rel={rel}");
    }

    #[test]
    fn all_compressors_run() {
        let (k, x) = kernel_matrix(48, 2, 1.0, 7);
        for comp in [CompressorKind::Mmf, CompressorKind::Spca, CompressorKind::Evd] {
            let cfg = small_config(12, 16).with_compressor(comp);
            let f = factorize(&k, Some(&x), &cfg).unwrap();
            assert!(f.check_valid(), "{comp:?}");
            let rel = f.to_dense().sub(&k).frob_norm() / k.frob_norm();
            assert!(rel < 0.6, "{comp:?} rel={rel}");
        }
    }

    #[test]
    fn evd_compressor_beats_mmf_globally() {
        let (k, x) = kernel_matrix(64, 3, 1.0, 8);
        let err = |kind: CompressorKind| {
            let f = factorize(&k, Some(&x), &small_config(16, 32).with_compressor(kind)).unwrap();
            f.to_dense().sub(&k).frob_norm() / k.frob_norm()
        };
        let e_evd = err(CompressorKind::Evd);
        let e_mmf = err(CompressorKind::Mmf);
        // Oracle should be at least as good (allow small tolerance — the
        // clusterings differ through RNG state usage).
        assert!(e_evd <= e_mmf * 1.5 + 0.02, "evd={e_evd} mmf={e_mmf}");
    }

    #[test]
    fn solve_through_factor() {
        let (k, x) = kernel_matrix(64, 3, 1.0, 9);
        let f = factorize(&k, Some(&x), &small_config(16, 32)).unwrap();
        let mut rng = Rng::new(10);
        let z = rng.normal_vec(64);
        let b = f.matvec(&z);
        let back = f.solve(&b).unwrap();
        for i in 0..64 {
            assert!((back[i] - z[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn rejects_bad_inputs() {
        let cfg = MkaConfig::default();
        let rect = Mat::zeros(3, 4);
        assert!(factorize(&rect, None, &cfg).is_err());
        let asym = Mat::from_rows(&[&[1.0, 5.0], &[0.0, 1.0]]);
        assert!(factorize(&asym, None, &cfg).is_err());
        let bad_cfg = MkaConfig { gamma: 1.5, ..MkaConfig::default() };
        assert!(factorize(&Mat::eye(4), None, &bad_cfg).is_err());
    }

    #[test]
    fn block_targets_respect_d_core() {
        let clusters: Vec<Vec<usize>> = vec![(0..10).collect(), (10..20).collect()];
        let t = block_targets(&clusters, 0.5, 16, 20);
        assert!(t.iter().sum::<usize>() >= 16);
        assert!(t.iter().zip(&clusters).all(|(&c, cl)| c <= cl.len()));
        // plain case: γ·m each
        let t2 = block_targets(&clusters, 0.5, 4, 20);
        assert_eq!(t2, vec![5, 5]);
    }

    #[test]
    fn storage_scales_like_prop5() {
        // (2s+1)n + d² bound for MMF-based MKA.
        let (k, x) = kernel_matrix(128, 3, 1.0, 11);
        let f = factorize(&k, Some(&x), &small_config(16, 32)).unwrap();
        let s = f.n_stages();
        let bound = (2 * s + 1) * f.n + f.d_core() * f.d_core();
        assert!(
            f.stored_reals() <= bound,
            "stored {} > bound {bound}",
            f.stored_reals()
        );
    }
}
