//! Direct operator algebra on the MKA factor (Proposition 7): because every
//! Q̄_ℓ is orthogonal and the nesting is block diagonal, any matrix function
//! f(K̃) is obtained by applying f to the core spectrum (one d³ EVD) and to
//! each wavelet diagonal value — O(n + d³) total, "direct method" in the
//! paper's sense (no iterative solver anywhere).
//!
//! Every operation here acts on K̃ + `shift`·I: the core EVD is of the
//! noise-free core (shared across shifted views), and f is applied to
//! λ + shift / d + shift at the point of use. That is what makes σ²
//! re-tuning free — `solve`, `logdet`, `spectrum` at a new noise level
//! are pure arithmetic on an existing factorization.

use super::factor::MkaFactor;
use crate::error::{Error, Result};
use crate::la::blas::{gemm, gemm_tn, scale_rows};
use crate::la::dense::Mat;

impl MkaFactor {
    /// Solve (K̃ + shift·I) x = b exactly. Errors if the shifted factor is
    /// numerically singular.
    pub fn solve(&self, b: &[f64]) -> Result<Vec<f64>> {
        self.check_invertible()?;
        let eig = self.eig();
        let s = self.shift;
        Ok(self.apply_with(
            b,
            |v| spectral_apply(eig, v, |lam| 1.0 / (lam + s)),
            |d| 1.0 / (d + s),
        ))
    }

    /// Blocked solve (K̃ + shift·I) X = B for a block of right-hand sides
    /// (columns of `b`): one cascade, one core spectral op — the
    /// multi-RHS Proposition 7 path used by batched prediction.
    pub fn solve_mat(&self, b: &Mat) -> Result<Mat> {
        self.check_invertible()?;
        let eig = self.eig();
        let s = self.shift;
        Ok(self.apply_with_mat(
            b,
            |v| spectral_apply_mat(eig, v, |lam| 1.0 / (lam + s)),
            |d| 1.0 / (d + s),
        ))
    }

    /// Column-parallel [`MkaFactor::solve_mat`]: wide blocks are sharded
    /// over `n_threads` workers (one logical cascade regardless of how
    /// many chunks execute it); narrow blocks parallelize over rotation
    /// blocks inside each stage instead.
    pub fn solve_mat_par(&self, b: &Mat, n_threads: usize) -> Result<Mat> {
        self.check_invertible()?;
        let eig = self.eig();
        let s = self.shift;
        Ok(self.par_over_cols(b, n_threads, |chunk, stage_threads| {
            self.apply_with_mat_stage(
                chunk,
                |v| spectral_apply_mat(eig, v, |lam| 1.0 / (lam + s)),
                |d| 1.0 / (d + s),
                stage_threads,
            )
        }))
    }

    /// (K̃ + shift·I)^α b for any real α (Proposition 7 item 1). Requires
    /// positive shifted spectrum for non-integer α.
    pub fn pow_apply(&self, alpha: f64, b: &[f64]) -> Vec<f64> {
        let eig = self.eig();
        let s = self.shift;
        self.apply_with(
            b,
            |v| spectral_apply(eig, v, |lam| signed_pow(lam + s, alpha)),
            |d| signed_pow(d + s, alpha),
        )
    }

    /// Blocked (K̃ + shift·I)^α B (columns of `b` are independent vectors).
    pub fn pow_apply_mat(&self, alpha: f64, b: &Mat) -> Mat {
        let eig = self.eig();
        let s = self.shift;
        self.apply_with_mat(
            b,
            |v| spectral_apply_mat(eig, v, |lam| signed_pow(lam + s, alpha)),
            |d| signed_pow(d + s, alpha),
        )
    }

    /// exp(β (K̃ + shift·I)) b (Proposition 7 item 2) — e.g. diffusion
    /// kernels from a factorized graph Laplacian.
    pub fn exp_apply(&self, beta: f64, b: &[f64]) -> Vec<f64> {
        let eig = self.eig();
        let s = self.shift;
        self.apply_with(
            b,
            |v| spectral_apply(eig, v, |lam| (beta * (lam + s)).exp()),
            |d| (beta * (d + s)).exp(),
        )
    }

    /// Blocked exp(β (K̃ + shift·I)) B.
    pub fn exp_apply_mat(&self, beta: f64, b: &Mat) -> Mat {
        let eig = self.eig();
        let s = self.shift;
        self.apply_with_mat(
            b,
            |v| spectral_apply_mat(eig, v, |lam| (beta * (lam + s)).exp()),
            |d| (beta * (d + s)).exp(),
        )
    }

    /// log det (K̃ + shift·I) (Proposition 7 item 3) — the GP
    /// marginal-likelihood term.
    ///
    /// Errors on a non-positive shifted spectral value: log det of a
    /// non-psd "kernel" is a modelling bug upstream, and silently summing
    /// log|λ| (the old behaviour) produced a finite but meaningless
    /// marginal likelihood.
    pub fn logdet(&self) -> Result<f64> {
        self.check_invertible()?;
        let eig = self.eig();
        let mut ld = 0.0f64;
        for &l in &eig.values {
            let l = l + self.shift;
            if l <= 0.0 {
                return Err(Error::Linalg(format!(
                    "logdet: non-positive core eigenvalue {l}"
                )));
            }
            ld += l.ln();
        }
        // all_dvals reads through the shift already.
        for d in self.all_dvals() {
            if d <= 0.0 {
                return Err(Error::Linalg(format!(
                    "logdet: non-positive wavelet diagonal value {d}"
                )));
            }
            ld += d.ln();
        }
        Ok(ld)
    }

    /// det (K̃ + shift·I) = Π (λ_i + shift) · Π (d + shift) — rotations
    /// have det 1.
    pub fn det(&self) -> f64 {
        let eig = self.eig();
        let mut det: f64 = eig.values.iter().map(|&l| l + self.shift).product();
        for d in self.all_dvals() {
            det *= d;
        }
        det
    }

    /// The full spectrum of K̃ + shift·I: shifted core eigenvalues ∪
    /// shifted wavelet diagonal values (exact — the wavelet coordinates
    /// are eigendirections of K̃ up to the orthogonal cascade).
    pub fn spectrum(&self) -> Vec<f64> {
        let mut s: Vec<f64> = self.eig().values.iter().map(|&l| l + self.shift).collect();
        s.extend(self.all_dvals());
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        s
    }

    /// Smallest shifted spectral value (negative ⇒ K̃ + shift·I not psd).
    pub fn min_eig(&self) -> f64 {
        let core_min =
            self.eig().values.first().copied().unwrap_or(f64::INFINITY) + self.shift;
        let d_min =
            self.all_dvals().into_iter().fold(f64::INFINITY, f64::min);
        core_min.min(d_min)
    }

    pub(crate) fn check_invertible(&self) -> Result<()> {
        // Relative gate: a spectral value only counts as nonzero when it
        // clears `RTOL ×` the largest spectral magnitude. The old absolute
        // 1e-300 floor waved through any factor that was singular in
        // f64 arithmetic (e.g. eigenvalues {1, 1e-18}), and solve/logdet
        // then returned garbage amplified by ~1/λ_min. RTOL is a few
        // hundred ulps — merely ill-conditioned factors (κ up to ~1e13)
        // still solve; only spectra unresolvable in f64 are rejected.
        // The gate sees the *shifted* spectrum: a noise-free factor may be
        // singular while the σ²-shifted view it serves is λ_min ≥ σ².
        const RTOL: f64 = 64.0 * f64::EPSILON; // ≈ 1.4e-14
        let eig = self.eig();
        let mut max_mag = 0.0f64;
        for &l in &eig.values {
            max_mag = max_mag.max((l + self.shift).abs());
        }
        let dvals = self.all_dvals();
        for &d in &dvals {
            max_mag = max_mag.max(d.abs());
        }
        let tol = RTOL * max_mag.max(1e-300);
        if eig.values.iter().any(|&l| (l + self.shift).abs() < tol)
            || dvals.iter().any(|d| d.abs() < tol)
        {
            return Err(Error::Linalg(format!(
                "MKA factor is numerically singular (spectral value below {RTOL:e} of max magnitude {max_mag:e})"
            )));
        }
        Ok(())
    }
}

/// V f(Λ) Vᵀ x without forming the dense function.
fn spectral_apply(
    eig: &crate::la::evd::SymEig,
    x: &[f64],
    f: impl Fn(f64) -> f64,
) -> Vec<f64> {
    // y = Vᵀ x; y_i *= f(λ_i); out = V y
    let vt_x = crate::la::blas::gemv_t(&eig.vectors, x);
    let scaled: Vec<f64> =
        vt_x.iter().zip(&eig.values).map(|(v, &l)| v * f(l)).collect();
    crate::la::blas::gemv(&eig.vectors, &scaled)
}

/// Blocked V f(Λ) Vᵀ X: two GEMMs + one contiguous row scaling for the
/// whole block, replacing 2b GEMV sweeps.
fn spectral_apply_mat(
    eig: &crate::la::evd::SymEig,
    x: &Mat,
    f: impl Fn(f64) -> f64,
) -> Mat {
    let mut vt_x = gemm_tn(&eig.vectors, x);
    let mut fvals = crate::par::arena::take_vec(eig.values.len());
    for (fv, &l) in fvals.iter_mut().zip(&eig.values) {
        *fv = f(l);
    }
    scale_rows(&mut vt_x, &fvals);
    crate::par::arena::give_vec(fvals);
    let out = gemm(&eig.vectors, &vt_x);
    crate::par::arena::give_mat(vt_x);
    out
}

/// |λ|^α · sign(λ) for odd behaviour on any stray negatives (psd clamping
/// upstream should make these impossible, but stay well-defined).
fn signed_pow(lam: f64, alpha: f64) -> f64 {
    if lam == 0.0 {
        0.0
    } else {
        lam.signum() * lam.abs().powf(alpha)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::QFactor;
    use crate::la::blas::{gemm, gemv};
    use crate::la::dense::Mat;
    use crate::la::evd::SymEig;
    use crate::la::givens::{Givens, GivensSeq};
    use crate::mka::stage::{BlockFactor, Stage};
    use crate::util::Rng;
    use std::sync::Arc;

    fn tiny_factor() -> MkaFactor {
        let mut seq = GivensSeq::new();
        seq.push(Givens::jacobi(0, 1, 3.0, 1.0, 2.0));
        let stage = Stage {
            n_in: 4,
            blocks: vec![
                BlockFactor { idx: vec![0, 1], q: QFactor::Givens(seq) },
                BlockFactor { idx: vec![2, 3], q: QFactor::Identity },
            ],
            core_global: vec![0, 2],
            wavelet_global: vec![1, 3],
            dvals: vec![0.7, 0.9],
        };
        let core = Mat::from_rows(&[&[2.0, 0.3], &[0.3, 1.5]]);
        MkaFactor::new(4, vec![stage], core)
    }

    #[test]
    fn solve_inverts_matvec() {
        let f = tiny_factor();
        let mut rng = Rng::new(1);
        let x = rng.normal_vec(4);
        let b = f.matvec(&x);
        let xr = f.solve(&b).unwrap();
        for i in 0..4 {
            assert!((xr[i] - x[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn logdet_matches_dense() {
        let f = tiny_factor();
        let dense = f.to_dense();
        let e = SymEig::new(&dense);
        let ld_dense: f64 = e.values.iter().map(|l| l.ln()).sum();
        assert!((f.logdet().unwrap() - ld_dense).abs() < 1e-9);
        assert!((f.det() - e.values.iter().product::<f64>()).abs() < 1e-9);
    }

    #[test]
    fn pow_half_squares_to_matvec() {
        let f = tiny_factor();
        let mut rng = Rng::new(2);
        let x = rng.normal_vec(4);
        let half = f.pow_apply(0.5, &x);
        let full = f.pow_apply(0.5, &half);
        let direct = f.matvec(&x);
        for i in 0..4 {
            assert!((full[i] - direct[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn pow_minus_one_matches_solve() {
        let f = tiny_factor();
        let mut rng = Rng::new(3);
        let b = rng.normal_vec(4);
        let a = f.pow_apply(-1.0, &b);
        let s = f.solve(&b).unwrap();
        for i in 0..4 {
            assert!((a[i] - s[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn exp_matches_dense_expm() {
        let f = tiny_factor();
        let dense = f.to_dense();
        let e = SymEig::new(&dense);
        let expm = e.apply_fn(|l| (0.3 * l).exp());
        let mut rng = Rng::new(4);
        let x = rng.normal_vec(4);
        let fast = f.exp_apply(0.3, &x);
        let slow = gemv(&expm, &x);
        for i in 0..4 {
            assert!((fast[i] - slow[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn spectrum_matches_dense() {
        let f = tiny_factor();
        let dense = f.to_dense();
        let e = SymEig::new(&dense);
        let s = f.spectrum();
        for (a, b) in s.iter().zip(&e.values) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
        assert!(f.min_eig() > 0.0);
    }

    /// Every Proposition-7 operation of the shifted view must agree with
    /// the dense EVD of K̃ + σ²I — the point of the shift refactor.
    #[test]
    fn shifted_ops_match_dense_shifted_matrix() {
        let f = tiny_factor();
        let s2 = 0.25;
        let fs = f.shifted(s2);
        let mut dense = f.to_dense();
        dense.add_diag(s2);
        let e = SymEig::new(&dense);

        // solve inverts the shifted operator
        let mut rng = Rng::new(31);
        let x = rng.normal_vec(4);
        let b = fs.matvec(&x);
        let xr = fs.solve(&b).unwrap();
        for i in 0..4 {
            assert!((xr[i] - x[i]).abs() < 1e-10);
        }
        // logdet / det / spectrum / min_eig all read λ + σ²
        let ld_dense: f64 = e.values.iter().map(|l| l.ln()).sum();
        assert!((fs.logdet().unwrap() - ld_dense).abs() < 1e-9);
        assert!((fs.det() - e.values.iter().product::<f64>()).abs() < 1e-9);
        for (a, b) in fs.spectrum().iter().zip(&e.values) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
        assert!((fs.min_eig() - e.values[0]).abs() < 1e-9);
        // pow/exp act on the shifted spectrum
        let expm = e.apply_fn(|l| (0.3 * l).exp());
        let fast = fs.exp_apply(0.3, &x);
        let slow = gemv(&expm, &x);
        for i in 0..4 {
            assert!((fast[i] - slow[i]).abs() < 1e-9);
        }
        let half = fs.pow_apply(0.5, &x);
        let full = fs.pow_apply(0.5, &half);
        let direct = fs.matvec(&x);
        for i in 0..4 {
            assert!((full[i] - direct[i]).abs() < 1e-9);
        }
        // the underlying noise-free factor is untouched
        assert_eq!(f.shift, 0.0);
        assert!((f.logdet().unwrap()
            - SymEig::new(&f.to_dense()).values.iter().map(|l| l.ln()).sum::<f64>())
        .abs()
            < 1e-9);
    }

    /// A factor that is singular at shift 0 becomes well-posed under a
    /// positive noise shift — λ_min(K̃ + σ²I) ≥ σ² for psd K̃.
    #[test]
    fn shift_rescues_singular_spectrum() {
        let core = Mat::from_rows(&[&[1.0, 0.0], &[0.0, 1e-18]]);
        let f = MkaFactor::new(2, vec![], core);
        assert!(f.solve(&[1.0, 1.0]).is_err());
        assert!(f.logdet().is_err());
        let fs = f.shifted(0.1);
        let x = fs.solve(&[1.0, 1.0]).unwrap();
        assert!((x[0] - 1.0 / 1.1).abs() < 1e-12);
        assert!((x[1] - 1.0 / (0.1 + 1e-18)).abs() < 1e-6);
        assert!((fs.logdet().unwrap() - (1.1f64.ln() + 0.1f64.ln())).abs() < 1e-9);
    }

    #[test]
    fn solve_mat_matches_per_column_solve() {
        let f = tiny_factor();
        let mut rng = Rng::new(21);
        let b = Mat::from_fn(4, 6, |_, _| rng.normal());
        let blocked = f.solve_mat(&b).unwrap();
        for j in 0..6 {
            let col = f.solve(&b.col(j)).unwrap();
            for i in 0..4 {
                assert!((blocked.at(i, j) - col[i]).abs() < 1e-12, "({i},{j})");
            }
        }
        let par = f.solve_mat_par(&b, 3).unwrap();
        assert!(par.sub(&blocked).max_abs() < 1e-12);
        // shifted views run the same blocked paths
        let fs = f.shifted(0.4);
        let sb = fs.solve_mat(&b).unwrap();
        let sp = fs.solve_mat_par(&b, 3).unwrap();
        assert!(sp.sub(&sb).max_abs() < 1e-12);
        for j in 0..6 {
            let col = fs.solve(&b.col(j)).unwrap();
            for i in 0..4 {
                assert!((sb.at(i, j) - col[i]).abs() < 1e-12, "shifted ({i},{j})");
            }
        }
    }

    #[test]
    fn pow_and_exp_mat_match_vector_paths() {
        let f = tiny_factor();
        let mut rng = Rng::new(22);
        let b = Mat::from_fn(4, 5, |_, _| rng.normal());
        let powm = f.pow_apply_mat(0.5, &b);
        let expm = f.exp_apply_mat(0.3, &b);
        for j in 0..5 {
            let pv = f.pow_apply(0.5, &b.col(j));
            let ev = f.exp_apply(0.3, &b.col(j));
            for i in 0..4 {
                assert!((powm.at(i, j) - pv[i]).abs() < 1e-12);
                assert!((expm.at(i, j) - ev[i]).abs() < 1e-12);
            }
        }
    }

    /// Regression: the old absolute 1e-300 singularity floor accepted a
    /// factor with spectrum {O(1), 1e-18} and let solve/logdet emit
    /// garbage. The gate is now relative to the largest spectral value.
    #[test]
    fn relatively_singular_factor_rejected() {
        let core = Mat::from_rows(&[&[1.0, 0.0], &[0.0, 1e-18]]);
        let f = MkaFactor::new(2, vec![], core);
        assert!(f.check_valid());
        assert!(f.solve(&[1.0, 1.0]).is_err(), "solve must reject λ_min/λ_max = 1e-18");
        assert!(f.logdet().is_err());
        // A tiny wavelet diagonal value trips the same gate.
        let mut f2 = tiny_factor();
        Arc::make_mut(&mut f2.stages)[0].dvals[1] = 1e-20;
        assert!(f2.solve(&[1.0; 4]).is_err());
        // Well-conditioned factors still pass.
        assert!(tiny_factor().solve(&[1.0; 4]).is_ok());
        // Merely ill-conditioned (κ ≈ 1e12, resolvable in f64) passes —
        // the gate targets numerical singularity, not conditioning.
        let ill = MkaFactor::new(2, vec![], Mat::from_rows(&[&[1.0, 0.0], &[0.0, 1e-12]]));
        assert!(ill.solve(&[1.0, 1.0]).is_ok());
    }

    #[test]
    fn logdet_rejects_non_positive_spectrum() {
        // Negative wavelet diagonal: |λ| used to be taken silently.
        let mut f = tiny_factor();
        Arc::make_mut(&mut f.stages)[0].dvals[0] = -0.7;
        assert!(f.logdet().is_err());
        // det and pow_apply stay well-defined on the signed spectrum.
        assert!(f.det().is_finite());
        let _ = f.pow_apply(1.0, &[1.0; 4]);
        // Negative core eigenvalue trips it too.
        let core = Mat::from_rows(&[&[-2.0, 0.0], &[0.0, 1.5]]);
        let f2 = MkaFactor::new(2, vec![], core);
        assert!(f2.logdet().is_err());
    }

    #[test]
    fn inverse_dense_consistency() {
        // K̃ · K̃⁻¹ = I via dense reconstruction of both.
        let f = tiny_factor();
        let dense = f.to_dense();
        let n = 4;
        let mut inv = Mat::zeros(n, n);
        let mut e = vec![0.0; n];
        for j in 0..n {
            e[j] = 1.0;
            let col = f.solve(&e).unwrap();
            for i in 0..n {
                inv.set(i, j, col[i]);
            }
            e[j] = 0.0;
        }
        let prod = gemm(&dense, &inv);
        assert!(prod.sub(&Mat::eye(n)).max_abs() < 1e-9);
    }
}
