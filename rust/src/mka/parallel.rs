//! Minimal scoped-thread parallel map for block compression.
//!
//! MKA is "inherently bottom-up … naturally parallelizable" (§3 remark 5):
//! within a stage, every diagonal block is compressed independently. No
//! rayon offline, so this is a small work-stealing-free static partitioner
//! over `std::thread::scope` — adequate because MKA blocks are
//! near-uniform in size by construction (balanced clustering).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Map `f` over `items` using up to `n_threads` OS threads, preserving
/// order. Falls back to a plain serial map when `n_threads <= 1` or the
/// item count is small.
pub fn par_map<T, R, F>(items: Vec<T>, n_threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Send + Sync,
{
    let n = items.len();
    if n_threads <= 1 || n <= 1 {
        return items.into_iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let n_threads = n_threads.min(n);
    // Slots for results; dynamic index dispenser for load balancing.
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let items: Vec<std::sync::Mutex<Option<T>>> =
        items.into_iter().map(|t| std::sync::Mutex::new(Some(t))).collect();
    let next = AtomicUsize::new(0);
    let slot_ptr = SlotsPtr(slots.as_mut_ptr());

    std::thread::scope(|scope| {
        for _ in 0..n_threads {
            let f = &f;
            let items = &items;
            let next = &next;
            let slot_ptr = &slot_ptr;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = items[i].lock().unwrap().take().unwrap();
                let r = f(i, item);
                // SAFETY: each index i is claimed exactly once via the
                // atomic dispenser, so writes to slots are disjoint; the
                // scope guarantees the buffer outlives the threads.
                unsafe {
                    *slot_ptr.0.add(i) = Some(r);
                }
            });
        }
    });

    slots.into_iter().map(|s| s.expect("worker failed to fill slot")).collect()
}

/// Wrapper to make the raw slot pointer Sync for the scoped threads.
struct SlotsPtr<R>(*mut Option<R>);
unsafe impl<R: Send> Sync for SlotsPtr<R> {}
unsafe impl<R: Send> Send for SlotsPtr<R> {}

/// Number of worker threads to use by default.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
}

/// Split `0..n` into at most `k` contiguous, near-equal, non-empty ranges
/// (used to shard the columns of a multi-RHS block across workers).
pub fn chunk_ranges(n: usize, k: usize) -> Vec<(usize, usize)> {
    if n == 0 {
        return Vec::new();
    }
    let k = k.clamp(1, n);
    let base = n / k;
    let extra = n % k;
    let mut out = Vec::with_capacity(k);
    let mut start = 0;
    for i in 0..k {
        let len = base + usize::from(i < extra);
        out.push((start, start + len));
        start += len;
    }
    debug_assert_eq!(start, n);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_serial_map() {
        let items: Vec<usize> = (0..100).collect();
        let serial: Vec<usize> = items.iter().map(|&x| x * x).collect();
        let parallel = par_map(items, 4, |_, x| x * x);
        assert_eq!(serial, parallel);
    }

    #[test]
    fn order_preserved_with_uneven_work() {
        let items: Vec<usize> = (0..40).collect();
        let out = par_map(items, 8, |i, x| {
            if x % 7 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            (i, x * 2)
        });
        for (i, (idx, v)) in out.iter().enumerate() {
            assert_eq!(*idx, i);
            assert_eq!(*v, i * 2);
        }
    }

    #[test]
    fn single_thread_path() {
        let out = par_map(vec![1, 2, 3], 1, |_, x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<i32> = par_map(Vec::<i32>::new(), 4, |_, x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn more_threads_than_items() {
        let out = par_map(vec![5], 16, |_, x| x * 10);
        assert_eq!(out, vec![50]);
    }

    #[test]
    fn chunk_ranges_cover_exactly() {
        for (n, k) in [(10, 3), (1, 4), (7, 7), (16, 2), (5, 1), (100, 8)] {
            let ranges = chunk_ranges(n, k);
            assert!(ranges.len() <= k);
            assert_eq!(ranges[0].0, 0);
            assert_eq!(ranges.last().unwrap().1, n);
            for w in ranges.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous");
            }
            for &(a, b) in &ranges {
                assert!(b > a, "non-empty");
            }
            let sizes: Vec<usize> = ranges.iter().map(|&(a, b)| b - a).collect();
            let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(mx - mn <= 1, "near-equal: {sizes:?}");
        }
        assert!(chunk_ranges(0, 4).is_empty());
    }
}
