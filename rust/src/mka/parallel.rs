//! Thin compatibility shim over the shared compute plane (`crate::par`).
//!
//! MKA is "inherently bottom-up … naturally parallelizable" (§3 remark 5):
//! within a stage, every diagonal block is compressed independently. Block
//! compression used to spawn scoped OS threads per call; the map now rides
//! the persistent work-sharing pool, so a factorization no longer pays
//! thread startup per stage and shares workers with the GEMM/gram/cascade
//! layers.

pub use crate::par::{chunk_ranges, default_threads};

use crate::par::SendPtr;

/// Map `f` over `items`, preserving order, with at most `n_threads` pool
/// tasks in flight (contiguous item groups, serial within a group —
/// adequate because MKA blocks are near-uniform in size by construction).
/// `n_threads <= 1` (or a trivial item count) runs serially inline.
/// Output order — and every output value — is identical either way.
pub fn par_map<T, R, F>(items: Vec<T>, n_threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Send + Sync,
{
    let n = items.len();
    if n_threads <= 1 || n <= 1 {
        return items.into_iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    // Split the items into at most n_threads contiguous groups, keeping
    // each group's base index so results land in their original slots.
    let groups = chunk_ranges(n, n_threads);
    let mut grouped: Vec<(usize, Vec<T>)> = Vec::with_capacity(groups.len());
    let mut rest = items;
    for &(lo, _hi) in groups.iter().rev() {
        let tail = rest.split_off(lo);
        grouped.push((lo, tail));
    }
    grouped.reverse();

    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let slot_ptr = SendPtr::new(slots.as_mut_ptr());
    let fref = &f;
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = grouped
        .into_iter()
        .map(|(base, group)| {
            let b: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                for (off, item) in group.into_iter().enumerate() {
                    let r = fref(base + off, item);
                    // SAFETY: each group writes only its own slot range,
                    // and `run_all` keeps `slots` alive until every task
                    // is done.
                    unsafe { *slot_ptr.ptr().add(base + off) = Some(r) };
                }
            });
            b
        })
        .collect();
    crate::par::global().run_all(tasks);
    slots.into_iter().map(|s| s.expect("worker failed to fill slot")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_serial_map() {
        let items: Vec<usize> = (0..100).collect();
        let serial: Vec<usize> = items.iter().map(|&x| x * x).collect();
        let parallel = par_map(items, 4, |_, x| x * x);
        assert_eq!(serial, parallel);
    }

    #[test]
    fn order_preserved_with_uneven_work() {
        let items: Vec<usize> = (0..40).collect();
        let out = par_map(items, 8, |i, x| {
            if x % 7 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            (i, x * 2)
        });
        for (i, (idx, v)) in out.iter().enumerate() {
            assert_eq!(*idx, i);
            assert_eq!(*v, i * 2);
        }
    }

    #[test]
    fn single_thread_path() {
        let out = par_map(vec![1, 2, 3], 1, |_, x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<i32> = par_map(Vec::<i32>::new(), 4, |_, x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn more_threads_than_items() {
        let out = par_map(vec![5], 16, |_, x| x * 10);
        assert_eq!(out, vec![50]);
    }

    #[test]
    fn nested_par_map_through_the_pool() {
        // Block compression calls gemm, which may itself shard onto the
        // pool — nested submission must complete and stay ordered.
        let out = par_map((0..6).collect::<Vec<usize>>(), 3, |_, x| {
            let inner = par_map((0..4).collect::<Vec<usize>>(), 2, move |_, y| x * 10 + y);
            inner.iter().sum::<usize>()
        });
        let expect: Vec<usize> = (0..6).map(|x| 4 * x * 10 + 6).collect();
        assert_eq!(out, expect);
    }
}
