//! Incremental extension of an existing MKA factor — the streaming
//! observe plane's factorization step.
//!
//! [`extend_factorize`] appends `b` new points to a factorized kernel
//! without rerunning Algorithm 1 from scratch. The telescoping structure
//! makes this cheap and *locally exact*:
//!
//! * Every stored rotation acts on a fixed index set, and appended points
//!   occupy fresh trailing indices at every level — so existing block
//!   rotations are **replayed verbatim** (never recomputed), and the
//!   old×old entries of every level matrix come out bit-identical to the
//!   original factorization. Stored wavelet diagonals therefore stay
//!   exact and are carried over untouched.
//! * At stage 0 each appended point is assigned to its nearest existing
//!   cluster by mean kernel affinity; the new points of each touched
//!   cluster form one *new* block, compressed among themselves with the
//!   configured compressor. This is the only fresh compression work —
//!   the per-call [`ExtendStats`] and the process-wide
//!   [`super::stage_rebuild_count`] / [`super::stage_reuse_count`]
//!   counters account for it.
//! * At deeper stages the surviving new core coordinates ride through as
//!   one appended identity (all-core) block, so they reach the final
//!   core exactly. The core grows by the stage-0 core count per extend;
//!   callers bound the growth with a drift gate
//!   ([`crate::gp::ObservePolicy`]) and fall back to a full refit.
//!
//! The result is a genuine [`MkaFactor`] of the extended gram (valid
//! partitions at every stage, spsd by the same Proposition 1 clamp), and
//! [`extend_factorize`] never bumps [`super::factorize_count`] — the σ²
//! shift view keeps re-tunes free exactly as on the fresh-fit path.

use super::factor::{record_stage_rebuilds, record_stage_reuses, MkaFactor};
use super::stage::{BlockFactor, Stage};
use super::{apply_stage_rotations, parallel, MkaConfig};
use crate::compress::{Compression, QFactor};
use crate::error::{Error, Result};
use crate::la::dense::Mat;
use crate::util::Rng;

/// Seed salt for the extend path's block compressions, so an extend never
/// replays the RNG stream of the original factorization.
const EXTEND_SEED_SALT: u64 = 0x4f42_5345;

/// Per-call accounting of one [`extend_factorize`] run. Process-wide
/// counters only support lower-bound assertions in concurrent test
/// binaries; this struct is the exact record.
#[derive(Clone, Debug, Default)]
pub struct ExtendStats {
    /// Points appended by this call.
    pub appended: usize,
    /// Stages in the factor (unchanged by an extend).
    pub stages_total: usize,
    /// Stages where fresh compression work ran (new non-identity blocks).
    pub stages_rebuilt: usize,
    /// Stages carried over by replaying stored rotations verbatim.
    pub stages_reused: usize,
    /// Existing blocks whose rotations were replayed unchanged.
    pub blocks_reused: usize,
    /// Stage-0 clusters that received new points (new blocks appended).
    pub blocks_touched: usize,
    /// Core rows added relative to the source factor.
    pub core_growth: usize,
}

/// Extend `old` (a factor of the leading `old.n`×`old.n` principal block
/// of `kj`) to a factor of the full extended gram `kj`. The appended
/// points must occupy the trailing rows/columns of `kj`; `kj` is
/// noise-free, exactly like [`super::factorize`]'s input — σ² stays a
/// free [`MkaFactor::shifted`] re-tune of the result (the source shift is
/// carried over).
pub fn extend_factorize(
    old: &MkaFactor,
    kj: &Mat,
    config: &MkaConfig,
) -> Result<(MkaFactor, ExtendStats)> {
    config.validate()?;
    if !kj.is_square() {
        return Err(Error::Linalg("extend_factorize needs a square matrix".into()));
    }
    if kj.rows <= old.n {
        return Err(Error::Data(format!(
            "extend_factorize: extended gram has {} rows but the factor already covers {}",
            kj.rows, old.n
        )));
    }
    if kj.asymmetry() > 1e-6 * kj.max_abs().max(1.0) {
        return Err(Error::Linalg("extend_factorize needs a symmetric matrix".into()));
    }
    let n_ext = kj.rows;
    let b = n_ext - old.n;
    let _sp = crate::obs::span!("mka.extend n={} b={b}", old.n);
    let compressor = config.compressor.build();
    let mut kc = kj.clone();
    kc.symmetrize();
    let mut stats =
        ExtendStats { appended: b, stages_total: old.stages.len(), ..ExtendStats::default() };
    let mut stages: Vec<Stage> = Vec::with_capacity(old.stages.len());
    // New coordinates entering the current level; they always sit at the
    // trailing positions st.n_in.. of the extended level matrix.
    let mut incoming = b;

    for (li, st) in old.stages.iter().enumerate() {
        let m = st.n_in;
        let n_cur = m + incoming;
        debug_assert_eq!(kc.rows, n_cur);

        // ---- group incoming coordinates into new blocks ------------------
        let new_comps: Vec<(Vec<usize>, Compression)> = if li == 0 {
            // Nearest existing cluster by mean |K| affinity against the
            // block's members (ties → lower block id, deterministic).
            let mut groups: Vec<Vec<usize>> = vec![Vec::new(); st.blocks.len()];
            for j in m..n_cur {
                let mut best = 0usize;
                let mut best_aff = f64::NEG_INFINITY;
                for (bi, blk) in st.blocks.iter().enumerate() {
                    let s: f64 = blk.idx.iter().map(|&i| kc.at(j, i).abs()).sum();
                    let aff = s / blk.idx.len().max(1) as f64;
                    if aff > best_aff {
                        best_aff = aff;
                        best = bi;
                    }
                }
                groups[best].push(j);
            }
            stats.blocks_touched = groups.iter().filter(|g| !g.is_empty()).count();
            let work: Vec<(Vec<usize>, usize, u64)> = groups
                .into_iter()
                .enumerate()
                .filter(|(_, g)| !g.is_empty())
                .map(|(bi, g)| {
                    let c = (((g.len() as f64) * config.gamma).round() as usize).clamp(1, g.len());
                    (g, c, config.seed ^ EXTEND_SEED_SALT ^ ((li as u64) << 32) ^ bi as u64)
                })
                .collect();
            let kc_ref = &kc;
            let compressor = &compressor;
            parallel::par_map(work, config.n_threads, move |_, (idx, c_target, seed)| {
                let comp = if c_target >= idx.len() {
                    Compression::identity(idx.len())
                } else {
                    let a = kc_ref.gather(&idx, &idx);
                    let mut brng = Rng::new(seed);
                    compressor.compress(&a, c_target, &mut brng)
                };
                debug_assert!(comp.is_valid_for(idx.len()));
                (idx, comp)
            })
        } else {
            // Deeper levels: surviving new core coordinates ride through
            // as one identity all-core block.
            vec![((m..n_cur).collect(), Compression::identity(incoming))]
        };

        let rebuilt = new_comps.iter().any(|(_, c)| !matches!(c.q, QFactor::Identity));
        if rebuilt {
            stats.stages_rebuilt += 1;
        } else {
            stats.stages_reused += 1;
        }
        stats.blocks_reused += st.blocks.len();

        // ---- replay stored rotations, then apply the new ones ------------
        // apply_stage_rotations only reads the orthogonal factor of each
        // entry, so replayed blocks carry empty core/wavelet splits.
        let mut comps: Vec<(Vec<usize>, Compression)> = st
            .blocks
            .iter()
            .map(|bf| {
                (
                    bf.idx.clone(),
                    Compression {
                        q: bf.q.clone(),
                        core_local: Vec::new(),
                        wavelet_local: Vec::new(),
                    },
                )
            })
            .collect();
        comps.extend(new_comps.iter().cloned());
        apply_stage_rotations(&mut kc, &comps, config.n_threads);

        // ---- split: stored old splits + the new blocks' splits -----------
        let mut core_global = st.core_global.clone();
        let mut wavelet_global = st.wavelet_global.clone();
        // Stored dvals are exact for the extended matrix too (new blocks
        // never mix old coordinates), so they carry over untouched; only
        // newly retired wavelets read the rotated diagonal, under the same
        // Proposition 1 clamp as a fresh factorization.
        let mut dvals = st.dvals.clone();
        let max_diag = kc.diagonal().iter().fold(0.0f64, |mx, &v| mx.max(v.abs())).max(1e-300);
        let floor = config.diag_floor * max_diag;
        let mut blocks: Vec<BlockFactor> = st.blocks.clone();
        for (idx, comp) in new_comps {
            for &c in &comp.core_local {
                core_global.push(idx[c]);
            }
            for &w in &comp.wavelet_local {
                let g = idx[w];
                wavelet_global.push(g);
                dvals.push(kc.at(g, g).max(floor));
            }
            blocks.push(BlockFactor { idx, q: comp.q });
        }

        let next = kc.gather(&core_global, &core_global);
        incoming = core_global.len() - st.core_global.len();
        stages.push(Stage { n_in: n_cur, blocks, core_global, wavelet_global, dvals });
        kc = next;
        kc.symmetrize();
    }

    record_stage_rebuilds(stats.stages_rebuilt as u64);
    record_stage_reuses(stats.stages_reused as u64);
    stats.core_growth = kc.rows.saturating_sub(old.core.rows);
    let f = MkaFactor::new(n_ext, stages, kc).with_threads(config.n_threads).shifted(old.shift);
    debug_assert!(f.check_valid());
    Ok((f, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterMethod;
    use crate::kernels::{Kernel, RbfKernel};
    use crate::mka::{factorize, factorize_count};

    fn points(n: usize, d: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(n, d, |_, _| rng.normal())
    }

    fn cfg(d_core: usize, block: usize) -> MkaConfig {
        MkaConfig {
            d_core,
            block_size: block,
            n_threads: 2,
            cluster_method: ClusterMethod::Bisect,
            ..MkaConfig::default()
        }
    }

    fn split_factor(n: usize, b: usize, d_core: usize, block: usize) -> (Mat, MkaFactor, Mat) {
        let x = points(n + b, 3, 17);
        let kj = RbfKernel::new(1.0).gram_sym(&x);
        let kold = kj.gather(&(0..n).collect::<Vec<_>>(), &(0..n).collect::<Vec<_>>());
        let xold = x.gather_rows(&(0..n).collect::<Vec<_>>());
        let old = factorize(&kold, Some(&xold), &cfg(d_core, block)).unwrap();
        (kj, old, kold)
    }

    #[test]
    fn extend_produces_valid_factor_without_factorizing() {
        let (kj, old, _) = split_factor(96, 8, 16, 32);
        let before = factorize_count();
        let (f, stats) = extend_factorize(&old, &kj, &cfg(16, 32)).unwrap();
        assert_eq!(factorize_count(), before, "extend must not count as a factorization");
        assert_eq!(f.n, 104);
        assert!(f.check_valid());
        assert_eq!(stats.appended, 8);
        assert_eq!(stats.stages_total, old.n_stages());
        assert_eq!(stats.stages_rebuilt + stats.stages_reused, stats.stages_total);
        // the acceptance contract: rebuilds strictly below the stage count
        assert!(old.n_stages() >= 2, "fixture must be multi-stage");
        assert!(
            stats.stages_rebuilt < stats.stages_total,
            "rebuilt {} of {} stages",
            stats.stages_rebuilt,
            stats.stages_total
        );
        assert!(stats.blocks_reused > 0);
        assert!(stats.blocks_touched >= 1);
        assert_eq!(f.d_core(), old.d_core() + stats.core_growth);
    }

    #[test]
    fn old_block_reconstruction_is_preserved_exactly() {
        // New blocks never mix old coordinates, so the extended factor's
        // reconstruction restricted to the old points is the old one.
        let (kj, old, _) = split_factor(80, 6, 16, 27);
        let (f, _) = extend_factorize(&old, &kj, &cfg(16, 27)).unwrap();
        let dense_old = old.to_dense();
        let dense_ext = f.to_dense();
        for i in 0..80 {
            for j in 0..80 {
                assert!(
                    (dense_ext.at(i, j) - dense_old.at(i, j)).abs() < 1e-12,
                    "({i},{j}): {} vs {}",
                    dense_ext.at(i, j),
                    dense_old.at(i, j)
                );
            }
        }
    }

    #[test]
    fn extended_approximation_quality_tracks_fresh() {
        let (kj, old, _) = split_factor(90, 10, 20, 30);
        let c = cfg(20, 30);
        let (f, _) = extend_factorize(&old, &kj, &c).unwrap();
        let rel = f.to_dense().sub(&kj).frob_norm() / kj.frob_norm();
        let x = points(100, 3, 17);
        let fresh = factorize(&kj, Some(&x), &c).unwrap();
        let rel_fresh = fresh.to_dense().sub(&kj).frob_norm() / kj.frob_norm();
        // The extend keeps more core than a fresh run, so it should stay
        // within a modest factor of (often better than) the fresh error.
        assert!(rel < (2.0 * rel_fresh).max(0.35), "extend rel {rel} vs fresh {rel_fresh}");
    }

    #[test]
    fn deterministic_and_thread_invariant() {
        let (kj, old, _) = split_factor(72, 5, 12, 24);
        let (f1, s1) = extend_factorize(&old, &kj, &cfg(12, 24)).unwrap();
        let (f2, _) = extend_factorize(&old, &kj, &cfg(12, 24)).unwrap();
        let c4 = MkaConfig { n_threads: 4, ..cfg(12, 24) };
        let (f4, s4) = extend_factorize(&old, &kj, &c4).unwrap();
        let d1 = f1.to_dense();
        assert_eq!(
            d1.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            f2.to_dense().data.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(
            d1.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            f4.to_dense().data.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(s1.stages_rebuilt, s4.stages_rebuilt);
        assert_eq!(s1.core_growth, s4.core_growth);
    }

    #[test]
    fn stageless_factor_extends_to_stageless() {
        // n ≤ d_core: the factor is its own core; the extension too.
        let x = points(24, 2, 3);
        let kj = RbfKernel::new(1.0).gram_sym(&x);
        let idx: Vec<usize> = (0..20).collect();
        let kold = kj.gather(&idx, &idx);
        let old = factorize(&kold, None, &cfg(32, 16)).unwrap();
        assert_eq!(old.n_stages(), 0);
        let (f, stats) = extend_factorize(&old, &kj, &cfg(32, 16)).unwrap();
        assert_eq!(f.n_stages(), 0);
        assert_eq!(f.d_core(), 24);
        assert_eq!(stats.stages_total, 0);
        assert!(f.to_dense().sub(&kj).max_abs() < 1e-10);
    }

    #[test]
    fn shift_carries_over_and_rejects_bad_inputs() {
        let (kj, old, _) = split_factor(60, 4, 12, 20);
        let shifted = old.shifted(0.3);
        let (f, _) = extend_factorize(&shifted, &kj, &cfg(12, 20)).unwrap();
        assert_eq!(f.shift, 0.3);
        // too-small gram, rectangular and asymmetric inputs are typed errors
        assert!(extend_factorize(&old, &kj.gather(&[0, 1], &[0, 1]), &cfg(12, 20)).is_err());
        assert!(extend_factorize(&old, &Mat::zeros(70, 64), &cfg(12, 20)).is_err());
        let mut asym = kj.clone();
        asym.set(0, 1, asym.at(0, 1) + 1.0);
        assert!(extend_factorize(&old, &asym, &cfg(12, 20)).is_err());
    }

    #[test]
    fn counters_account_for_reuse() {
        use crate::mka::{stage_rebuild_count, stage_reuse_count};
        let (kj, old, _) = split_factor(96, 8, 16, 32);
        let before_rebuild = stage_rebuild_count();
        let before_reuse = stage_reuse_count();
        let (_, stats) = extend_factorize(&old, &kj, &cfg(16, 32)).unwrap();
        // Concurrent tests may also bump these: lower bounds only.
        assert!(stage_rebuild_count() >= before_rebuild + stats.stages_rebuilt as u64);
        assert!(stage_reuse_count() >= before_reuse + stats.stages_reused as u64);
        assert!(stats.stages_reused >= 1, "deeper stages must be reused");
    }
}
