//! Model *learning*: hyperparameter selection strategies unified behind
//! one train API.
//!
//! [`ModelSelection`] names the three strategies the repo supports:
//!
//! * `GridCv` — the paper's §5 protocol (k-fold CV over a grid), the old
//!   `gp::cv` path. O(folds × grid) refits; works for every method
//!   including MEKA.
//! * `Mll` — evidence maximization through [`crate::train::mll`]: one
//!   `factorize` + `solve` + `logdet` per candidate for MKA (the direct
//!   method's free lunch), closed Woodbury forms for the Nyström family,
//!   driven by the multi-start Nelder–Mead in
//!   [`crate::train::optimizer`].
//! * `MllGrad` — the same evidence surfaces climbed with their analytic
//!   gradients ([`crate::train::grad`]) by bounded L-BFGS; with
//!   `ard: true` the optimizer learns one length scale **per input
//!   dimension** and the final fit uses the matching
//!   [`crate::kernels::ArdRbfKernel`].
//!
//! [`train_model`] = select hyperparameters + one final [`fit_model`];
//! it backs both the `train` CLI subcommand and the coordinator's async
//! `{"op":"train"}` job.

use crate::cluster::ClusterMethod;
use crate::data::dataset::Dataset;
use crate::error::{Error, Result};
use crate::experiments::methods::{cv_predict, mka_config_for, Method};
use crate::gp::cv::{default_grid, grid_search, ArdHyperParams, HyperParams};
use crate::gp::sharded::{shard_partition, ShardedGp};
use crate::gp::GpModel;
use crate::kernels::Kernel;
use crate::mka::MkaConfig;
use crate::par::{self, SendPtr};
use crate::train::cache::FactorCache;
use crate::train::grad::{mll_grad_cached, shard_mll_grad_mka, MllGrad};
use crate::train::mll::{log_marginal_likelihood_cached, shard_log_marginal_likelihood};
use crate::train::optimizer::{maximize_mll, maximize_mll_lbfgs, EvalRecord, OptimBudget, SearchBox};
use crate::util::json::Json;
use crate::util::timer::Timer;

/// How to choose the kernel hyperparameters before the final fit.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ModelSelection {
    /// k-fold cross-validation over the default grid (validation SMSE).
    GridCv { folds: usize },
    /// Log-marginal-likelihood maximization (direct evidence),
    /// derivative-free Nelder–Mead over `(lengthscale, σ²)`.
    Mll { budget: OptimBudget },
    /// Evidence maximization with analytic gradients (bounded L-BFGS);
    /// `ard` learns one length scale per input dimension.
    MllGrad { budget: OptimBudget, ard: bool },
}

impl ModelSelection {
    /// Parse a protocol/CLI name; `folds`/`budget`/`ard` fill in the
    /// knobs. `ard = true` is only representable on the gradient-based
    /// selection — any other name combined with it parses to `None`
    /// rather than silently dropping the flag.
    pub fn parse(
        name: &str,
        folds: usize,
        budget: OptimBudget,
        ard: bool,
    ) -> Option<ModelSelection> {
        let sel = match name.to_ascii_lowercase().as_str() {
            "cv" | "gridcv" | "grid_cv" => ModelSelection::GridCv { folds },
            "mll" | "ml" | "evidence" => ModelSelection::Mll { budget },
            "mll-grad" | "mll_grad" | "mllgrad" | "grad" | "lbfgs" => {
                ModelSelection::MllGrad { budget, ard }
            }
            _ => return None,
        };
        if ard && !matches!(sel, ModelSelection::MllGrad { .. }) {
            return None;
        }
        Some(sel)
    }

    pub fn label(&self) -> &'static str {
        match self {
            ModelSelection::GridCv { .. } => "cv",
            ModelSelection::Mll { .. } => "mll",
            ModelSelection::MllGrad { .. } => "mll-grad",
        }
    }
}

/// What a training run found, protocol-serializable for the `job` op.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub method: Method,
    pub selection: &'static str,
    /// Chosen isotropic pair; for an ARD run this is the
    /// [`ArdHyperParams::tied`] summary (geometric-mean length scale).
    pub best: HyperParams,
    /// Per-dimension length scales when the ARD path selected them.
    pub lengthscales: Option<Vec<f64>>,
    /// Evidence at the chosen point (`Mll`/`MllGrad` paths only).
    pub best_mll: Option<f64>,
    /// Mean validation SMSE at the chosen point (`GridCv` path only).
    pub cv_score: Option<f64>,
    /// Candidate evaluations spent (including failed ones).
    pub evals: usize,
    /// σ²-independent factor builds spent by the evidence paths (MKA
    /// factorizations / Nyström block assemblies — the per-run
    /// [`FactorCache`] misses). `evals − factorizations` evaluations were
    /// pure spectrum/Woodbury arithmetic on a cached factor. `None` when
    /// the run has no cacheable factor to count: the CV path (refits
    /// models instead of scoring evidence) and `Method::Full` (every
    /// eval is one Cholesky that never routes through the cache —
    /// reporting 0 there would read as perfect reuse).
    pub factorizations: Option<usize>,
    /// Per-shard factor-build counts of a sharded evidence run, in
    /// shard-id order (each shard rides its own [`FactorCache`]; summing
    /// this vector gives `factorizations`). `None` on unsharded runs.
    pub shard_factorizations: Option<Vec<usize>>,
    pub converged: bool,
    /// Per-candidate trace (successful evaluations only).
    pub trace: Vec<EvalRecord>,
    pub train_secs: f64,
}

impl TrainReport {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .with("method", Json::Str(self.method.label().into()))
            .with("selection", Json::Str(self.selection.into()))
            .with("evals", Json::Num(self.evals as f64))
            .with("converged", Json::Bool(self.converged))
            .with("secs", Json::Num(self.train_secs))
            .with(
                "best",
                Json::obj()
                    .with("lengthscale", Json::Num(self.best.lengthscale))
                    .with("sigma2", Json::Num(self.best.sigma2)),
            );
        if let Some(ells) = &self.lengthscales {
            j.set("lengthscales", Json::from_f64_slice(ells));
        }
        if let Some(fx) = self.factorizations {
            j.set("factorizations", Json::Num(fx as f64));
        }
        if let Some(sf) = &self.shard_factorizations {
            j.set(
                "shard_factorizations",
                Json::Arr(sf.iter().map(|&c| Json::Num(c as f64)).collect()),
            );
        }
        if let Some(m) = self.best_mll {
            j.set("best_mll", Json::Num(m));
        }
        if let Some(s) = self.cv_score {
            j.set("cv_smse", Json::Num(s));
        }
        let trace: Vec<Json> = self
            .trace
            .iter()
            .map(|e| {
                Json::obj()
                    .with("lengthscale", Json::Num(e.hp.lengthscale))
                    .with("sigma2", Json::Num(e.hp.sigma2))
                    .with("value", Json::Num(e.value))
            })
            .collect();
        j.with("trace", Json::Arr(trace))
    }
}

/// Run the selection strategy and report the chosen hyperparameters
/// (no final fit).
pub fn select_hyperparams(
    method: Method,
    data: &Dataset,
    selection: &ModelSelection,
    k: usize,
    seed: u64,
) -> Result<TrainReport> {
    let _sp = crate::obs::span!("train.select {} n={}", method.label(), data.n());
    let t = Timer::start();
    match selection {
        ModelSelection::GridCv { folds } => {
            let grid = default_grid(data.dim());
            let out = grid_search(data, *folds, &grid, seed, |tr, vx, hp| {
                cv_predict(method, tr, vx, hp, k, seed)
            })?;
            let trace = out.table.iter().map(|&(hp, v)| EvalRecord { hp, value: v }).collect();
            Ok(TrainReport {
                method,
                selection: "cv",
                best: out.best,
                lengthscales: None,
                best_mll: None,
                cv_score: Some(out.best_score),
                evals: grid.len(),
                factorizations: None,
                shard_factorizations: None,
                converged: true,
                trace,
                train_secs: t.elapsed_secs(),
            })
        }
        ModelSelection::Mll { budget } => {
            if method == Method::Meka {
                return Err(Error::Config(
                    "MEKA has no marginal likelihood (spsd-ness lost); use selection=\"cv\"".into(),
                ));
            }
            let sbox = SearchBox::for_dim(data.dim());
            // One factor cache per training run: σ²-only simplex moves
            // (and revisited length scales) become pure spectrum
            // arithmetic — the cache's miss count IS the number of
            // σ²-independent factor builds this run paid for.
            let cache = FactorCache::with_default_capacity();
            let out = maximize_mll(
                |hp| log_marginal_likelihood_cached(method, data, hp, k, seed, &cache).ok(),
                data.dim(),
                budget,
                &sbox,
            )?;
            Ok(TrainReport {
                method,
                selection: "mll",
                best: out.best,
                lengthscales: None,
                best_mll: Some(out.best_mll),
                cv_score: None,
                evals: out.evals,
                factorizations: cacheable_factorizations(method, &cache),
                shard_factorizations: None,
                converged: out.converged,
                trace: out.trace,
                train_secs: t.elapsed_secs(),
            })
        }
        ModelSelection::MllGrad { budget, ard } => {
            if method == Method::Meka {
                return Err(Error::Config(
                    "MEKA has no marginal likelihood (spsd-ness lost); use selection=\"cv\"".into(),
                ));
            }
            let sbox = SearchBox::for_dim(data.dim());
            let tied = !*ard;
            let cache = FactorCache::with_default_capacity();
            let out = maximize_mll_lbfgs(
                |hp| {
                    mll_grad_cached(method, data, hp, tied, k, seed, &cache)
                        .ok()
                        .map(|g| (g.mll, g.grad_vec()))
                },
                data.dim(),
                *ard,
                budget,
                &sbox,
            )?;
            Ok(TrainReport {
                method,
                selection: "mll-grad",
                best: out.best.tied(),
                lengthscales: if *ard { Some(out.best.lengthscales.clone()) } else { None },
                best_mll: Some(out.best_mll),
                cv_score: None,
                evals: out.evals,
                factorizations: cacheable_factorizations(method, &cache),
                shard_factorizations: None,
                converged: out.converged,
                trace: out.trace,
                train_secs: t.elapsed_secs(),
            })
        }
    }
}

/// Evaluate one objective per shard on the shared pool (fixed slots, one
/// task per shard) and hand the slot vector back for a **serial in-order
/// reduction** at the call site — the two halves of the sharded
/// determinism contract: schedule-independent placement, then a
/// schedule-independent sum.
fn eval_shards<T: Clone + Send>(
    n_shards: usize,
    eval: impl Fn(usize) -> Option<T> + Send + Sync,
) -> Vec<Option<T>> {
    let mut slots: Vec<Option<T>> = vec![None; n_shards];
    {
        let ptr = SendPtr::new(slots.as_mut_ptr());
        par::run_tasks(n_shards, n_shards, |s| {
            let v = eval(s);
            // SAFETY: task s writes only slot s; run_tasks blocks until
            // every task finished.
            unsafe { *ptr.ptr().add(s) = v };
        });
    }
    slots
}

/// Sum of per-shard MKA evidences at `hp` — the objective surface of a
/// sharded [`ModelSelection::Mll`] run. Any failed shard fails the
/// candidate (the optimizer skips it), mirroring the unsharded contract.
fn sharded_mll_sum(
    shards: &[Dataset],
    hp: HyperParams,
    cfg: &MkaConfig,
    caches: &[FactorCache],
) -> Option<f64> {
    let slots = eval_shards(shards.len(), |s| {
        shard_log_marginal_likelihood(&shards[s], hp, cfg, &caches[s], s as u64).ok()
    });
    let mut sum = 0.0;
    for v in slots {
        sum += v?;
    }
    Some(sum)
}

/// Sum of per-shard MKA evidences **and gradients** at `hp` — a sum of
/// independent log-likelihoods, so the gradient of the sum is the
/// in-order sum of the per-shard gradients.
fn sharded_mll_grad_sum(
    shards: &[Dataset],
    hp: &ArdHyperParams,
    tied: bool,
    cfg: &MkaConfig,
    caches: &[FactorCache],
) -> Option<(f64, Vec<f64>)> {
    let slots: Vec<Option<MllGrad>> = eval_shards(shards.len(), |s| {
        shard_mll_grad_mka(&shards[s], hp, tied, cfg, &caches[s], s as u64).ok()
    });
    let mut mll = 0.0;
    let mut grad: Option<Vec<f64>> = None;
    for g in slots {
        let g = g?;
        mll += g.mll;
        let gv = g.grad_vec();
        match &mut grad {
            None => grad = Some(gv),
            Some(acc) => {
                for (a, b) in acc.iter_mut().zip(&gv) {
                    *a += b;
                }
            }
        }
    }
    Some((mll, grad?))
}

/// Sharded hyperparameter selection: partition `data` exactly as
/// [`ShardedGp::fit`] will (same assign method, partition seed =
/// `config.seed`), then learn ONE shared `(ℓ, σ²)` — or ARD vector —
/// from the **sum of per-shard MKA evidences**. Each shard rides its own
/// [`FactorCache`] under a shard-tagged scope, so a σ²-only move does
/// zero factorizations on every shard at once; candidates are evaluated
/// shard-parallel with a serial in-order reduction (bit-deterministic at
/// any thread count).
///
/// `n_shards <= 1` delegates to [`select_hyperparams`] — the unsharded
/// path, bit-identical surface. Sharded evidence is MKA-only (the
/// sharded plane serves MKA shards); `GridCv` has no evidence to sum and
/// is rejected here rather than silently falling back.
pub fn select_hyperparams_sharded(
    method: Method,
    data: &Dataset,
    selection: &ModelSelection,
    k: usize,
    seed: u64,
    n_shards: usize,
    assign: ClusterMethod,
) -> Result<TrainReport> {
    if n_shards <= 1 {
        return select_hyperparams(method, data, selection, k, seed);
    }
    if method != Method::Mka {
        return Err(Error::Config(format!(
            "sharded training is MKA-only (got {}): the sharded plane serves MKA shards",
            method.label()
        )));
    }
    let cfg = mka_config_for(k, data.n(), seed);
    let parts = shard_partition(&data.x, n_shards, assign, cfg.seed)?;
    let shards: Vec<Dataset> = parts.iter().map(|m| data.subset(m)).collect();
    let caches: Vec<FactorCache> =
        (0..shards.len()).map(|_| FactorCache::with_default_capacity()).collect();
    let t = Timer::start();
    let mut report = match selection {
        ModelSelection::GridCv { .. } => {
            return Err(Error::Config(
                "sharded selection needs an evidence surface; use selection=\"mll\" or \"mll-grad\""
                    .into(),
            ));
        }
        ModelSelection::Mll { budget } => {
            let sbox = SearchBox::for_dim(data.dim());
            let out = maximize_mll(
                |hp| sharded_mll_sum(&shards, hp, &cfg, &caches),
                data.dim(),
                budget,
                &sbox,
            )?;
            TrainReport {
                method,
                selection: "mll",
                best: out.best,
                lengthscales: None,
                best_mll: Some(out.best_mll),
                cv_score: None,
                evals: out.evals,
                factorizations: None,
                shard_factorizations: None,
                converged: out.converged,
                trace: out.trace,
                train_secs: 0.0,
            }
        }
        ModelSelection::MllGrad { budget, ard } => {
            let sbox = SearchBox::for_dim(data.dim());
            let tied = !*ard;
            let out = maximize_mll_lbfgs(
                |hp| sharded_mll_grad_sum(&shards, hp, tied, &cfg, &caches),
                data.dim(),
                *ard,
                budget,
                &sbox,
            )?;
            TrainReport {
                method,
                selection: "mll-grad",
                best: out.best.tied(),
                lengthscales: if *ard { Some(out.best.lengthscales.clone()) } else { None },
                best_mll: Some(out.best_mll),
                cv_score: None,
                evals: out.evals,
                factorizations: None,
                shard_factorizations: None,
                converged: out.converged,
                trace: out.trace,
                train_secs: 0.0,
            }
        }
    };
    let per_shard: Vec<usize> = caches.iter().map(|c| c.misses() as usize).collect();
    report.factorizations = Some(per_shard.iter().sum());
    report.shard_factorizations = Some(per_shard);
    report.train_secs = t.elapsed_secs();
    Ok(report)
}

/// Sharded [`train_model`]: select shared hyperparameters from the
/// summed per-shard evidence, then fit the serving [`ShardedGp`] at the
/// chosen point (same partition — assign method and seed match the
/// selection pass). `n_shards <= 1` delegates to [`train_model`].
pub fn train_model_sharded(
    method: Method,
    data: &Dataset,
    selection: &ModelSelection,
    k: usize,
    seed: u64,
    n_shards: usize,
    assign: ClusterMethod,
) -> Result<(Box<dyn GpModel>, TrainReport)> {
    if n_shards <= 1 {
        return train_model(method, data, selection, k, seed);
    }
    let _sp =
        crate::obs::span!("train.model_sharded {} n={} k={n_shards}", method.label(), data.n());
    let t = Timer::start();
    let mut report =
        select_hyperparams_sharded(method, data, selection, k, seed, n_shards, assign)?;
    let cfg = mka_config_for(k, data.n(), seed);
    let model: Box<dyn GpModel> = match &report.lengthscales {
        Some(ells) => {
            let hp = ArdHyperParams { lengthscales: ells.clone(), sigma2: report.best.sigma2 };
            Box::new(ShardedGp::fit(data, &hp.kernel(), hp.sigma2, &cfg, n_shards, assign)?)
        }
        None => {
            let kern = crate::kernels::RbfKernel::new(report.best.lengthscale);
            Box::new(ShardedGp::fit(data, &kern, report.best.sigma2, &cfg, n_shards, assign)?)
        }
    };
    report.train_secs = t.elapsed_secs();
    Ok((model, report))
}

/// The run's σ²-independent factor-build count, or `None` for methods
/// that never route through the cache (Full's Cholesky-per-eval has no
/// cacheable factor — a literal 0 would misreport it as perfect reuse).
fn cacheable_factorizations(method: Method, cache: &FactorCache) -> Option<usize> {
    match method {
        Method::Full | Method::Meka => None,
        _ => Some(cache.misses() as usize),
    }
}

/// Select hyperparameters, then fit the final model at the chosen point.
/// An ARD selection fits with the matching per-dimension kernel.
pub fn train_model(
    method: Method,
    data: &Dataset,
    selection: &ModelSelection,
    k: usize,
    seed: u64,
) -> Result<(Box<dyn GpModel>, TrainReport)> {
    let _sp = crate::obs::span!("train.model {} n={}", method.label(), data.n());
    let t = Timer::start();
    let mut report = select_hyperparams(method, data, selection, k, seed)?;
    let model = {
        let _sp = crate::obs::span!("train.final_fit");
        match &report.lengthscales {
            Some(ells) => fit_model_ard(method, data, ells, report.best.sigma2, k, seed)?,
            None => fit_model(method, data, report.best, k, seed)?,
        }
    };
    report.train_secs = t.elapsed_secs();
    Ok((model, report))
}

/// Fit a model of the requested kind at explicit isotropic
/// hyperparameters (shared by the CLI, the coordinator's `fit` op and
/// the final step of [`train_model`]).
pub fn fit_model(
    method: Method,
    data: &Dataset,
    hp: HyperParams,
    k: usize,
    seed: u64,
) -> Result<Box<dyn GpModel>> {
    let kern = crate::kernels::RbfKernel::new(hp.lengthscale);
    fit_model_with_kernel(method, data, &kern, hp.sigma2, k, seed)
}

/// Fit with per-dimension (ARD) length scales.
pub fn fit_model_ard(
    method: Method,
    data: &Dataset,
    lengthscales: &[f64],
    sigma2: f64,
    k: usize,
    seed: u64,
) -> Result<Box<dyn GpModel>> {
    let hp = ArdHyperParams { lengthscales: lengthscales.to_vec(), sigma2 };
    if !hp.is_valid() || hp.dim() != data.dim() {
        return Err(Error::Config(format!(
            "fit_model_ard: invalid lengthscales for {}-dimensional data: {hp:?}",
            data.dim()
        )));
    }
    let kern = hp.kernel();
    fit_model_with_kernel(method, data, &kern, sigma2, k, seed)
}

/// The kernel-generic fit every entry point reduces to.
pub fn fit_model_with_kernel(
    method: Method,
    data: &Dataset,
    kern: &dyn Kernel,
    s2: f64,
    k: usize,
    seed: u64,
) -> Result<Box<dyn GpModel>> {
    use crate::baselines::{Fitc, Meka, MekaConfig, Pitc, Sor};
    use crate::gp::full::FullGp;
    use crate::gp::mka_gp::MkaGp;
    Ok(match method {
        Method::Full => Box::new(FullGp::fit(data, kern, s2)?),
        Method::Sor => Box::new(Sor::fit(data, kern, s2, k, seed)?),
        Method::Fitc => Box::new(Fitc::fit(data, kern, s2, k, seed)?),
        Method::Pitc => {
            let block = crate::experiments::methods::pitc_block_size(data.n(), k);
            Box::new(Pitc::fit(data, kern, s2, k, block, seed)?)
        }
        Method::Meka => {
            let cfg = MekaConfig { rank: k, n_clusters: (k / 8).clamp(2, 8), sample_frac: 0.7, seed };
            Box::new(Meka::fit(data, kern, s2, &cfg)?)
        }
        Method::Mka => {
            let cfg = crate::experiments::methods::mka_config_for(k, data.n(), seed);
            Box::new(MkaGp::fit(data, kern, s2, &cfg)?)
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gp_dataset, SynthSpec};
    use crate::gp::metrics::smse;

    fn tiny_budget() -> OptimBudget {
        OptimBudget { max_evals: 18, n_starts: 2, tol: 1e-4 }
    }

    #[test]
    fn parse_roundtrip() {
        let b = OptimBudget::default();
        assert_eq!(
            ModelSelection::parse("cv", 3, b, false),
            Some(ModelSelection::GridCv { folds: 3 })
        );
        assert_eq!(
            ModelSelection::parse("MLL", 3, b, false),
            Some(ModelSelection::Mll { budget: b })
        );
        assert_eq!(
            ModelSelection::parse("mll-grad", 3, b, true),
            Some(ModelSelection::MllGrad { budget: b, ard: true })
        );
        assert_eq!(
            ModelSelection::parse("lbfgs", 3, b, false),
            Some(ModelSelection::MllGrad { budget: b, ard: false })
        );
        assert_eq!(ModelSelection::parse("nope", 3, b, false), None);
        // ard is only representable on the gradient path — never dropped
        assert_eq!(ModelSelection::parse("mll", 3, b, true), None);
        assert_eq!(ModelSelection::parse("cv", 3, b, true), None);
        assert_eq!(ModelSelection::GridCv { folds: 5 }.label(), "cv");
        assert_eq!(ModelSelection::Mll { budget: b }.label(), "mll");
        assert_eq!(ModelSelection::MllGrad { budget: b, ard: true }.label(), "mll-grad");
    }

    #[test]
    fn meka_mll_is_rejected() {
        let d = gp_dataset(&SynthSpec::named("t", 60, 2), 1);
        let sel = ModelSelection::Mll { budget: tiny_budget() };
        let err = select_hyperparams(Method::Meka, &d, &sel, 8, 1);
        assert!(err.is_err());
        let sel = ModelSelection::MllGrad { budget: tiny_budget(), ard: true };
        assert!(select_hyperparams(Method::Meka, &d, &sel, 8, 1).is_err());
    }

    #[test]
    fn lbfgs_training_produces_serving_model() {
        let d = gp_dataset(&SynthSpec::named("t", 110, 2), 6);
        let (tr, te) = d.split(0.85, 2);
        let sel = ModelSelection::MllGrad { budget: tiny_budget(), ard: false };
        let (model, report) = train_model(Method::Full, &tr, &sel, 8, 3).unwrap();
        assert_eq!(report.selection, "mll-grad");
        assert!(report.best_mll.unwrap().is_finite());
        assert!(report.lengthscales.is_none(), "tied run must not report ARD scales");
        assert!(report.evals >= 2 && !report.trace.is_empty());
        let pred = model.predict(&te.x);
        assert!(smse(&te.y, &pred.mean) < 1.0);
    }

    #[test]
    fn ard_training_reports_per_dimension_lengthscales() {
        let d = gp_dataset(&SynthSpec::named("t", 100, 3), 7);
        let budget = OptimBudget { max_evals: 30, n_starts: 2, tol: 1e-4 };
        let sel = ModelSelection::MllGrad { budget, ard: true };
        let (model, report) = train_model(Method::Sor, &d, &sel, 10, 4).unwrap();
        let ells = report.lengthscales.as_ref().expect("ARD lengthscales");
        assert_eq!(ells.len(), 3);
        assert!(ells.iter().all(|l| l.is_finite() && *l > 0.0));
        // the tied summary is the geometric mean of the reported scales
        let gm = (ells.iter().map(|l| l.ln()).sum::<f64>() / 3.0).exp();
        assert!((report.best.lengthscale - gm).abs() < 1e-9);
        // serialization carries the per-dimension scales
        let j = report.to_json();
        assert_eq!(j.get("lengthscales").unwrap().f64_array().unwrap().len(), 3);
        assert_eq!(model.predict(&d.x).mean.len(), d.n());
    }

    /// The factor cache makes σ²-only simplex moves free: an MKA
    /// evidence run must report strictly fewer σ²-independent factor
    /// builds than evidence evaluations (each Nelder–Mead start's σ²
    /// vertex alone revisits its start's length scale).
    #[test]
    fn evidence_selection_reports_factorization_economics() {
        let d = gp_dataset(&SynthSpec::named("t", 90, 2), 8);
        // Single start: the factorization count is deterministic (no
        // cross-start build races on shared cache keys).
        let sel =
            ModelSelection::Mll { budget: OptimBudget { max_evals: 16, n_starts: 1, tol: 1e-4 } };
        let report = select_hyperparams(Method::Mka, &d, &sel, 10, 3).unwrap();
        let fx = report.factorizations.expect("evidence path reports factorizations");
        assert!(fx >= 1, "at least one factor must be built");
        assert!(fx < report.evals, "factorizations {fx} !< evals {}", report.evals);
        let j = report.to_json();
        assert_eq!(j.num_field("factorizations"), Some(fx as f64));
        // the CV path refits models — no evidence factorizations to report
        let cv =
            select_hyperparams(Method::Sor, &d, &ModelSelection::GridCv { folds: 2 }, 8, 3)
                .unwrap();
        assert!(cv.factorizations.is_none());
        assert!(cv.to_json().get("factorizations").is_none());
        // Full never routes through the cache: None, not a false Some(0)
        let full = select_hyperparams(Method::Full, &d, &sel, 8, 3).unwrap();
        assert!(full.factorizations.is_none());
    }

    #[test]
    fn sharded_selection_sums_per_shard_evidence() {
        let d = gp_dataset(&SynthSpec::named("t", 120, 2), 9);
        let sel =
            ModelSelection::Mll { budget: OptimBudget { max_evals: 14, n_starts: 1, tol: 1e-4 } };
        let report =
            select_hyperparams_sharded(Method::Mka, &d, &sel, 8, 3, 3, ClusterMethod::KMeans)
                .unwrap();
        assert_eq!(report.selection, "mll");
        assert!(report.best_mll.unwrap().is_finite());
        let per_shard = report.shard_factorizations.as_ref().expect("per-shard counts");
        assert!(!per_shard.is_empty());
        assert_eq!(report.factorizations, Some(per_shard.iter().sum()));
        // every shard paid at least one factor build
        assert!(per_shard.iter().all(|&c| c >= 1), "{per_shard:?}");
        let j = report.to_json();
        let sf = j.get("shard_factorizations").unwrap().as_arr().unwrap();
        assert_eq!(sf.len(), per_shard.len());
        // 1-shard delegates to the unsharded path: no shard counts
        let one = select_hyperparams_sharded(Method::Mka, &d, &sel, 8, 3, 1, ClusterMethod::KMeans)
            .unwrap();
        assert!(one.shard_factorizations.is_none());
        // typed rejections: non-MKA method, CV selection
        assert!(select_hyperparams_sharded(
            Method::Sor,
            &d,
            &sel,
            8,
            3,
            2,
            ClusterMethod::KMeans
        )
        .is_err());
        assert!(select_hyperparams_sharded(
            Method::Mka,
            &d,
            &ModelSelection::GridCv { folds: 2 },
            8,
            3,
            2,
            ClusterMethod::KMeans
        )
        .is_err());
    }

    #[test]
    fn sharded_training_produces_sharded_serving_model() {
        let d = gp_dataset(&SynthSpec::named("t", 130, 2), 10);
        let (tr, te) = d.split(0.85, 3);
        let sel = ModelSelection::Mll { budget: tiny_budget() };
        let (model, report) =
            train_model_sharded(Method::Mka, &tr, &sel, 8, 4, 2, ClusterMethod::KMeans).unwrap();
        assert!(report.best_mll.unwrap().is_finite());
        let info = model.info();
        assert!(info.shards >= 2, "sharded fit must serve >1 shard, got {}", info.shards);
        assert_eq!(info.shard_sizes.iter().sum::<usize>(), tr.n());
        let pred = model.predict(&te.x);
        assert!(smse(&te.y, &pred.mean) < 1.2);
    }

    #[test]
    fn mll_training_produces_serving_model() {
        let d = gp_dataset(&SynthSpec::named("t", 110, 2), 2);
        let (tr, te) = d.split(0.85, 2);
        let sel = ModelSelection::Mll { budget: tiny_budget() };
        let (model, report) = train_model(Method::Full, &tr, &sel, 8, 3).unwrap();
        assert_eq!(report.selection, "mll");
        assert!(report.best_mll.unwrap().is_finite());
        assert!(report.evals >= 2 && !report.trace.is_empty());
        assert!(report.train_secs >= 0.0);
        let pred = model.predict(&te.x);
        assert!(smse(&te.y, &pred.mean) < 1.0);
    }

    #[test]
    fn cv_training_flows_through_same_api() {
        let d = gp_dataset(&SynthSpec::named("t", 90, 2), 3);
        let sel = ModelSelection::GridCv { folds: 2 };
        let (model, report) = train_model(Method::Sor, &d, &sel, 8, 4).unwrap();
        assert_eq!(report.selection, "cv");
        assert!(report.cv_score.unwrap().is_finite());
        assert!(report.best_mll.is_none());
        assert!(!report.trace.is_empty());
        assert_eq!(model.predict(&d.x).mean.len(), d.n());
    }

    #[test]
    fn report_serializes_trace() {
        let d = gp_dataset(&SynthSpec::named("t", 80, 2), 5);
        let sel = ModelSelection::Mll { budget: tiny_budget() };
        let report = select_hyperparams(Method::Sor, &d, &sel, 8, 5).unwrap();
        let j = report.to_json();
        assert_eq!(j.str_field("selection"), Some("mll"));
        assert!(j.num_field("best_mll").unwrap().is_finite());
        assert!(j.get("trace").unwrap().as_arr().unwrap().len() >= 1);
        let best = j.get("best").unwrap();
        assert!(best.num_field("lengthscale").unwrap() > 0.0);
        assert!(best.num_field("sigma2").unwrap() > 0.0);
    }
}
