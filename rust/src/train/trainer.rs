//! Model *learning*: hyperparameter selection strategies unified behind
//! one train API.
//!
//! [`ModelSelection`] names the two strategies the repo supports:
//!
//! * `GridCv` — the paper's §5 protocol (k-fold CV over a grid), the old
//!   `gp::cv` path. O(folds × grid) refits; works for every method
//!   including MEKA.
//! * `Mll` — evidence maximization through [`crate::train::mll`]: one
//!   `factorize` + `solve` + `logdet` per candidate for MKA (the direct
//!   method's free lunch), closed Woodbury forms for the Nyström family,
//!   driven by the multi-start Nelder–Mead in
//!   [`crate::train::optimizer`].
//!
//! [`train_model`] = select hyperparameters + one final [`fit_model`];
//! it backs both the `train` CLI subcommand and the coordinator's async
//! `{"op":"train"}` job.

use crate::data::dataset::Dataset;
use crate::error::{Error, Result};
use crate::experiments::methods::{cv_predict, Method};
use crate::gp::cv::{default_grid, grid_search, HyperParams};
use crate::gp::GpModel;
use crate::train::mll::log_marginal_likelihood;
use crate::train::optimizer::{maximize_mll, EvalRecord, OptimBudget, SearchBox};
use crate::util::json::Json;
use crate::util::timer::Timer;

/// How to choose `(lengthscale, σ²)` before the final fit.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ModelSelection {
    /// k-fold cross-validation over the default grid (validation SMSE).
    GridCv { folds: usize },
    /// Log-marginal-likelihood maximization (direct evidence).
    Mll { budget: OptimBudget },
}

impl ModelSelection {
    /// Parse a protocol/CLI name; `folds`/`budget` fill in the knobs.
    pub fn parse(name: &str, folds: usize, budget: OptimBudget) -> Option<ModelSelection> {
        match name.to_ascii_lowercase().as_str() {
            "cv" | "gridcv" | "grid_cv" => Some(ModelSelection::GridCv { folds }),
            "mll" | "ml" | "evidence" => Some(ModelSelection::Mll { budget }),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            ModelSelection::GridCv { .. } => "cv",
            ModelSelection::Mll { .. } => "mll",
        }
    }
}

/// What a training run found, protocol-serializable for the `job` op.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub method: Method,
    pub selection: &'static str,
    pub best: HyperParams,
    /// Evidence at the chosen point (`Mll` path only).
    pub best_mll: Option<f64>,
    /// Mean validation SMSE at the chosen point (`GridCv` path only).
    pub cv_score: Option<f64>,
    /// Candidate evaluations spent (including failed ones).
    pub evals: usize,
    pub converged: bool,
    /// Per-candidate trace (successful evaluations only).
    pub trace: Vec<EvalRecord>,
    pub train_secs: f64,
}

impl TrainReport {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .with("method", Json::Str(self.method.label().into()))
            .with("selection", Json::Str(self.selection.into()))
            .with("evals", Json::Num(self.evals as f64))
            .with("converged", Json::Bool(self.converged))
            .with("secs", Json::Num(self.train_secs))
            .with(
                "best",
                Json::obj()
                    .with("lengthscale", Json::Num(self.best.lengthscale))
                    .with("sigma2", Json::Num(self.best.sigma2)),
            );
        if let Some(m) = self.best_mll {
            j.set("best_mll", Json::Num(m));
        }
        if let Some(s) = self.cv_score {
            j.set("cv_smse", Json::Num(s));
        }
        let trace: Vec<Json> = self
            .trace
            .iter()
            .map(|e| {
                Json::obj()
                    .with("lengthscale", Json::Num(e.hp.lengthscale))
                    .with("sigma2", Json::Num(e.hp.sigma2))
                    .with("value", Json::Num(e.value))
            })
            .collect();
        j.with("trace", Json::Arr(trace))
    }
}

/// Run the selection strategy and report the chosen hyperparameters
/// (no final fit).
pub fn select_hyperparams(
    method: Method,
    data: &Dataset,
    selection: &ModelSelection,
    k: usize,
    seed: u64,
) -> Result<TrainReport> {
    let t = Timer::start();
    match selection {
        ModelSelection::GridCv { folds } => {
            let grid = default_grid(data.dim());
            let out = grid_search(data, *folds, &grid, seed, |tr, vx, hp| {
                cv_predict(method, tr, vx, hp, k, seed)
            })?;
            let trace = out.table.iter().map(|&(hp, v)| EvalRecord { hp, value: v }).collect();
            Ok(TrainReport {
                method,
                selection: "cv",
                best: out.best,
                best_mll: None,
                cv_score: Some(out.best_score),
                evals: grid.len(),
                converged: true,
                trace,
                train_secs: t.elapsed_secs(),
            })
        }
        ModelSelection::Mll { budget } => {
            if method == Method::Meka {
                return Err(Error::Config(
                    "MEKA has no marginal likelihood (spsd-ness lost); use selection=\"cv\"".into(),
                ));
            }
            let sbox = SearchBox::for_dim(data.dim());
            let out = maximize_mll(
                |hp| log_marginal_likelihood(method, data, hp, k, seed).ok(),
                data.dim(),
                budget,
                &sbox,
            )?;
            Ok(TrainReport {
                method,
                selection: "mll",
                best: out.best,
                best_mll: Some(out.best_mll),
                cv_score: None,
                evals: out.evals,
                converged: out.converged,
                trace: out.trace,
                train_secs: t.elapsed_secs(),
            })
        }
    }
}

/// Select hyperparameters, then fit the final model at the chosen point.
pub fn train_model(
    method: Method,
    data: &Dataset,
    selection: &ModelSelection,
    k: usize,
    seed: u64,
) -> Result<(Box<dyn GpModel>, TrainReport)> {
    let t = Timer::start();
    let mut report = select_hyperparams(method, data, selection, k, seed)?;
    let model = fit_model(method, data, report.best, k, seed)?;
    report.train_secs = t.elapsed_secs();
    Ok((model, report))
}

/// Fit a model of the requested kind at explicit hyperparameters (shared
/// by the CLI, the coordinator's `fit` op and the final step of
/// [`train_model`]).
pub fn fit_model(
    method: Method,
    data: &Dataset,
    hp: HyperParams,
    k: usize,
    seed: u64,
) -> Result<Box<dyn GpModel>> {
    use crate::baselines::{Fitc, Meka, MekaConfig, Pitc, Sor};
    use crate::gp::full::FullGp;
    use crate::gp::mka_gp::MkaGp;
    use crate::kernels::RbfKernel;
    let kern = RbfKernel::new(hp.lengthscale);
    let s2 = hp.sigma2;
    Ok(match method {
        Method::Full => Box::new(FullGp::fit(data, &kern, s2)?),
        Method::Sor => Box::new(Sor::fit(data, &kern, s2, k, seed)?),
        Method::Fitc => Box::new(Fitc::fit(data, &kern, s2, k, seed)?),
        Method::Pitc => {
            let block = crate::experiments::methods::pitc_block_size(data.n(), k);
            Box::new(Pitc::fit(data, &kern, s2, k, block, seed)?)
        }
        Method::Meka => {
            let cfg = MekaConfig { rank: k, n_clusters: (k / 8).clamp(2, 8), sample_frac: 0.7, seed };
            Box::new(Meka::fit(data, &kern, s2, &cfg)?)
        }
        Method::Mka => {
            let cfg = crate::experiments::methods::mka_config_for(k, data.n(), seed);
            Box::new(MkaGp::fit(data, &kern, s2, &cfg)?)
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gp_dataset, SynthSpec};
    use crate::gp::metrics::smse;

    fn tiny_budget() -> OptimBudget {
        OptimBudget { max_evals: 18, n_starts: 2, tol: 1e-4 }
    }

    #[test]
    fn parse_roundtrip() {
        let b = OptimBudget::default();
        assert_eq!(
            ModelSelection::parse("cv", 3, b),
            Some(ModelSelection::GridCv { folds: 3 })
        );
        assert_eq!(
            ModelSelection::parse("MLL", 3, b),
            Some(ModelSelection::Mll { budget: b })
        );
        assert_eq!(ModelSelection::parse("nope", 3, b), None);
        assert_eq!(ModelSelection::GridCv { folds: 5 }.label(), "cv");
        assert_eq!(ModelSelection::Mll { budget: b }.label(), "mll");
    }

    #[test]
    fn meka_mll_is_rejected() {
        let d = gp_dataset(&SynthSpec::named("t", 60, 2), 1);
        let sel = ModelSelection::Mll { budget: tiny_budget() };
        let err = select_hyperparams(Method::Meka, &d, &sel, 8, 1);
        assert!(err.is_err());
    }

    #[test]
    fn mll_training_produces_serving_model() {
        let d = gp_dataset(&SynthSpec::named("t", 110, 2), 2);
        let (tr, te) = d.split(0.85, 2);
        let sel = ModelSelection::Mll { budget: tiny_budget() };
        let (model, report) = train_model(Method::Full, &tr, &sel, 8, 3).unwrap();
        assert_eq!(report.selection, "mll");
        assert!(report.best_mll.unwrap().is_finite());
        assert!(report.evals >= 2 && !report.trace.is_empty());
        assert!(report.train_secs >= 0.0);
        let pred = model.predict(&te.x);
        assert!(smse(&te.y, &pred.mean) < 1.0);
    }

    #[test]
    fn cv_training_flows_through_same_api() {
        let d = gp_dataset(&SynthSpec::named("t", 90, 2), 3);
        let sel = ModelSelection::GridCv { folds: 2 };
        let (model, report) = train_model(Method::Sor, &d, &sel, 8, 4).unwrap();
        assert_eq!(report.selection, "cv");
        assert!(report.cv_score.unwrap().is_finite());
        assert!(report.best_mll.is_none());
        assert!(!report.trace.is_empty());
        assert_eq!(model.predict(&d.x).mean.len(), d.n());
    }

    #[test]
    fn report_serializes_trace() {
        let d = gp_dataset(&SynthSpec::named("t", 80, 2), 5);
        let sel = ModelSelection::Mll { budget: tiny_budget() };
        let report = select_hyperparams(Method::Sor, &d, &sel, 8, 5).unwrap();
        let j = report.to_json();
        assert_eq!(j.str_field("selection"), Some("mll"));
        assert!(j.num_field("best_mll").unwrap().is_finite());
        assert!(j.get("trace").unwrap().as_arr().unwrap().len() >= 1);
        let best = j.get("best").unwrap();
        assert!(best.num_field("lengthscale").unwrap() > 0.0);
        assert!(best.num_field("sigma2").unwrap() > 0.0);
    }
}
