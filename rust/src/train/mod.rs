//! Marginal-likelihood training plane.
//!
//! Turns MKA's free `logdet` (Proposition 7) into hyperparameter
//! *learning*: after one factorization, `K̃′⁻¹y` and `log det K̃′` are
//! both cheap, which is exactly the pair of quantities the GP log
//! marginal likelihood needs — so evidence-based selection costs one
//! factorize + solve + logdet per candidate instead of O(folds × grid)
//! CV refits.
//!
//! * [`mll`] — per-method evidence evaluators (Full/Cholesky, MKA/
//!   Proposition 7, Nyström family/Woodbury + determinant lemma);
//! * [`optimizer`] — bounded multi-start Nelder–Mead over log-space
//!   `(lengthscale, σ²)`, concurrent on the shared `par` pool,
//!   bit-deterministic at any thread count;
//! * [`trainer`] — the [`trainer::ModelSelection`] strategy enum
//!   (`GridCv` | `Mll`) behind one [`trainer::train_model`] API, used by
//!   the `train` CLI subcommand and the coordinator's async
//!   `{"op":"train"}` job.

pub mod mll;
pub mod optimizer;
pub mod trainer;

pub use mll::log_marginal_likelihood;
pub use optimizer::{maximize_mll, EvalRecord, OptimBudget, OptimOutcome, SearchBox};
pub use trainer::{fit_model, select_hyperparams, train_model, ModelSelection, TrainReport};
