//! Marginal-likelihood training plane.
//!
//! Turns MKA's free `logdet` (Proposition 7) into hyperparameter
//! *learning*: after one factorization, `K̃′⁻¹y` and `log det K̃′` are
//! both cheap, which is exactly the pair of quantities the GP log
//! marginal likelihood needs — so evidence-based selection costs one
//! factorize + solve + logdet per candidate instead of O(folds × grid)
//! CV refits.
//!
//! * [`mll`] — per-method evidence evaluators (Full/Cholesky, MKA/
//!   Proposition 7, Nyström family/Woodbury + determinant lemma);
//! * [`cache`] — the per-run [`cache::FactorCache`]: the σ²-independent
//!   half of every evaluation (noise-free MKA factorization, Nyström
//!   K_mm/K_mn blocks) memoized per length-scale vector, so σ²-only
//!   optimizer moves cost **zero factorizations** (noise is a spectrum
//!   shift — `MkaFactor::shifted`);
//! * [`grad`] — the matching analytic gradients
//!   `∂(log marginal likelihood)/∂(log ℓ_d, log σ²)`: the classic
//!   `½ tr((ααᵀ − C⁻¹)∂C/∂θ)` identity organized per family (blocked
//!   dense solves for Full, differentiated Woodbury/determinant-lemma
//!   forms for SoR/FITC/PITC, fixed-seed Hutchinson probes through one
//!   cascade for MKA);
//! * [`optimizer`] — two maximizers over log-space hyperparameters:
//!   bounded multi-start Nelder–Mead (`maximize_mll`, 2-D) and bounded
//!   L-BFGS (`maximize_mll_lbfgs`, d+1-dimensional with ARD), both
//!   concurrent on the shared `par` pool and bit-deterministic at any
//!   thread count;
//! * [`trainer`] — the [`trainer::ModelSelection`] strategy enum
//!   (`GridCv` | `Mll` | `MllGrad`) behind one [`trainer::train_model`]
//!   API, used by the `train` CLI subcommand and the coordinator's async
//!   `{"op":"train"}` job.

pub mod cache;
pub mod grad;
pub mod mll;
pub mod optimizer;
pub mod trainer;

pub use cache::{factor_cache_hits, factor_cache_misses, FactorCache};
pub use grad::{mll_grad, mll_grad_cached, shard_mll_grad_mka, MllGrad, TraceMode};
pub use mll::{
    log_marginal_likelihood, log_marginal_likelihood_cached, shard_log_marginal_likelihood,
};
pub use optimizer::{
    maximize_mll, maximize_mll_lbfgs, EvalRecord, GradOptimOutcome, OptimBudget, OptimOutcome,
    SearchBox,
};
pub use trainer::{
    fit_model, fit_model_ard, fit_model_with_kernel, select_hyperparams,
    select_hyperparams_sharded, train_model, train_model_sharded, ModelSelection, TrainReport,
};
