//! Per-lengthscale cache of the σ²-independent factor-stack quantities.
//!
//! Every evidence (and gradient) evaluation splits into an expensive part
//! that depends **only on the length scales** — the noise-free MKA
//! `factorize` (σ² is a spectrum shift, same rotations for every noise
//! level: see `mka::factor`) and the Nyström blocks (K_mm, K_mn and
//! chol(K_mm) never see σ²) — and near-free σ²-dependent arithmetic
//! (shifted-spectrum solves/logdets, Woodbury forms with a new Λ).
//! [`FactorCache`] memoizes the first part keyed on a caller-supplied
//! scope (capacity budget k / seed / config identity) plus the exact
//! bits of the (ARD) length-scale vector, so σ²-only optimizer moves —
//! Nelder–Mead's σ² simplex vertex, revisited ℓ candidates, L-BFGS
//! probes along the noise axis — cost **zero factorizations**, while a
//! caller that varies k or seed against one instance cannot be handed
//! the wrong entry. The trainer creates one cache per training run
//! ([`FactorCache::with_default_capacity`], sized by
//! `ServiceConfig.train_cache_factors`); the *dataset* stays outside the
//! key and is the one thing a cache instance must not be shared across.
//!
//! Determinism: entries are bit-deterministic functions of their key
//! (fixed seeds all the way down), so a cache hit returns exactly the
//! value a rebuild would produce — concurrent optimizer starts sharing
//! the cache cannot observe the hit/miss pattern in their results, and
//! the PR-2 bit-determinism contract survives caching untouched. Two
//! starts racing on the same key may both build (the build runs outside
//! the lock precisely so starts never serialize on each other's
//! factorizations); the first insert wins and the duplicate is dropped.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::baselines::nystrom::NystromBlocks;
use crate::error::Result;
use crate::la::dense::Mat;
use crate::mka::MkaFactor;

/// Process-wide hit/miss counters, surfaced by the coordinator's
/// `metrics` op as `compute.factor_cache_{hits,misses}`.
static HITS: AtomicU64 = AtomicU64::new(0);
static MISSES: AtomicU64 = AtomicU64::new(0);

/// Total factor-cache hits across every training run in this process.
pub fn factor_cache_hits() -> u64 {
    HITS.load(Ordering::Relaxed)
}

/// Total factor-cache misses (σ²-independent builds) in this process.
pub fn factor_cache_misses() -> u64 {
    MISSES.load(Ordering::Relaxed)
}

/// Default per-run capacity; `ServiceConfig.train_cache_factors`
/// overrides it at router construction (0 disables caching).
static DEFAULT_CAPACITY: AtomicUsize = AtomicUsize::new(4);

/// Set the process-wide default capacity new caches are created with.
///
/// Process-wide and last-writer-wins, exactly like `par::set_threads`
/// (the other knob `Router::new` sizes from its config): embedding
/// several routers with *different* `train_cache_factors` in one
/// process makes the last-constructed router's value govern — a known
/// tradeoff of the global-knob pattern, irrelevant for the served
/// deployment (one router per process) and harmless for correctness
/// (capacity only changes wall-clock, never values).
pub fn set_default_capacity(cap: usize) {
    DEFAULT_CAPACITY.store(cap, Ordering::Relaxed);
}

/// The current process-wide default capacity.
pub fn default_capacity() -> usize {
    DEFAULT_CAPACITY.load(Ordering::Relaxed)
}

/// σ²-independent MKA quantities at one length-scale vector.
pub struct MkaEntry {
    /// Noise-free factorization (shift 0); consumers take `shifted(σ²)`.
    pub factor: MkaFactor,
    /// The noise-free gram K(X, X) the factor was built from. Only the
    /// gradient path reads it (∂K/∂θ is an elementwise map over it), and
    /// an n×n dense matrix per cached length scale is real memory — so
    /// the value path drops it after factorizing and it regenerates
    /// lazily if a consumer ever asks.
    gram: OnceLock<Mat>,
}

impl MkaEntry {
    /// Entry holding the factor only (value path — no gram retained).
    pub fn new(factor: MkaFactor) -> MkaEntry {
        MkaEntry { factor, gram: OnceLock::new() }
    }

    /// Entry that keeps the gram it was factorized from (gradient path).
    pub fn with_gram(factor: MkaFactor, gram: Mat) -> MkaEntry {
        let slot = OnceLock::new();
        let _ = slot.set(gram);
        MkaEntry { factor, gram: slot }
    }

    /// The noise-free gram, rebuilt by `build` if this entry dropped it.
    pub fn gram(&self, build: impl FnOnce() -> Mat) -> &Mat {
        self.gram.get_or_init(build)
    }
}

/// σ²-independent Nyström quantities at one length-scale vector
/// (K_mm = `nb.w`, K_mn = `nb.kzf`, chol(K_mm) = `nb.w_chol`), plus
/// lazily built per-method extras so SoR/PITC entries never pay for
/// FITC's diagonals and vice versa.
pub struct NystromEntry {
    pub nb: NystromBlocks,
    /// FITC's Λ ingredients (diag Q = diag(K_nm W⁻¹ K_mn), k_ii per
    /// train point) — σ²-independent, built on first FITC use only.
    fitc_diag: OnceLock<(Vec<f64>, Vec<f64>)>,
    /// PITC's conditioning partition, tagged by the block size it was
    /// built for (block is not part of the entry key — Nyström entries
    /// are shared across SoR/FITC/PITC — so the tag guards a caller that
    /// varies block size against one entry). Built on first PITC use.
    clusters: Mutex<Option<(u64, Arc<Vec<Vec<usize>>>)>>,
    /// V = W⁻¹U (m×n) — the gradient paths' dominant σ²-independent
    /// product (O(m²n)); built on first gradient use so a σ²-only
    /// L-BFGS move pays none of it.
    winv_u: OnceLock<Mat>,
}

impl NystromEntry {
    pub fn new(nb: NystromBlocks) -> NystromEntry {
        NystromEntry {
            nb,
            fitc_diag: OnceLock::new(),
            clusters: Mutex::new(None),
            winv_u: OnceLock::new(),
        }
    }

    /// FITC's (diag Q, k_ii), built by `build` on first use. Entries are
    /// shared across threads (`Arc`); `OnceLock` keeps one winner and the
    /// build is deterministic, so racing initializers agree bit-for-bit.
    pub fn fitc_diag(
        &self,
        build: impl FnOnce() -> (Vec<f64>, Vec<f64>),
    ) -> &(Vec<f64>, Vec<f64>) {
        self.fitc_diag.get_or_init(build)
    }

    /// PITC's clusters for conditioning-block size `block`, built by
    /// `build` on first use (or when `block` differs from the cached
    /// partition's — the entry never hands back clusters for a block
    /// size it was not asked about).
    pub fn clusters(
        &self,
        block: u64,
        build: impl FnOnce() -> Vec<Vec<usize>>,
    ) -> Arc<Vec<Vec<usize>>> {
        let mut slot = self.clusters.lock().unwrap();
        if let Some((b, c)) = slot.as_ref() {
            if *b == block {
                return Arc::clone(c);
            }
        }
        let built = Arc::new(build());
        *slot = Some((block, Arc::clone(&built)));
        built
    }

    /// W⁻¹U, built by `build` on first use.
    pub fn winv_u(&self, build: impl FnOnce() -> Mat) -> &Mat {
        self.winv_u.get_or_init(build)
    }
}

struct Slot<T> {
    key: Vec<u64>,
    entry: Arc<T>,
    tick: u64,
}

struct Store<T> {
    slots: Vec<Slot<T>>,
    tick: u64,
}

impl<T> Default for Store<T> {
    fn default() -> Self {
        Store { slots: Vec::new(), tick: 0 }
    }
}

/// A small LRU over σ²-independent factor entries, keyed on (scope,
/// exact f64 bits of the length-scale vector). One instance per
/// training run over one dataset.
pub struct FactorCache {
    cap: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    mka: Mutex<Store<MkaEntry>>,
    nystrom: Mutex<Store<NystromEntry>>,
}

impl FactorCache {
    /// A cache holding at most `cap` entries per family (MKA / Nyström).
    /// `cap = 0` disables storage: every lookup builds and nothing is
    /// kept — but each build still counts as an instance-level miss, so
    /// `TrainReport.factorizations` stays truthful when caching is
    /// configured off. Only the process-wide traffic gauges skip
    /// disabled caches (the uncached compatibility wrappers create a
    /// throwaway disabled instance per call).
    pub fn new(cap: usize) -> FactorCache {
        FactorCache {
            cap,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            mka: Mutex::new(Store::default()),
            nystrom: Mutex::new(Store::default()),
        }
    }

    /// A cache sized by the service-configurable process default.
    pub fn with_default_capacity() -> FactorCache {
        FactorCache::new(default_capacity())
    }

    /// A cache that never stores anything.
    pub fn disabled() -> FactorCache {
        FactorCache::new(0)
    }

    /// Hits observed by this instance (process-local, pollution-free —
    /// unlike the global counters, unaffected by concurrent runs).
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Misses (= σ²-independent builds) performed through this instance.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// The MKA entry for the length-scale vector `ells`, building it with
    /// `build` on a miss. `scope` must encode everything *besides* the
    /// length scales that determines the entry for a fixed dataset
    /// (d_core/block/seed of the config) — two lookups with equal ℓ but
    /// different scopes must not collide.
    pub fn mka(
        &self,
        scope: &[u64],
        ells: &[f64],
        build: impl FnOnce() -> Result<MkaEntry>,
    ) -> Result<Arc<MkaEntry>> {
        get_or_build(&self.mka, self.cap, &self.hits, &self.misses, scope, ells, build)
    }

    /// The Nyström entry for the length-scale vector `ells`; `scope`
    /// carries (landmark count, seed) — see [`FactorCache::mka`].
    pub fn nystrom(
        &self,
        scope: &[u64],
        ells: &[f64],
        build: impl FnOnce() -> Result<NystromEntry>,
    ) -> Result<Arc<NystromEntry>> {
        get_or_build(&self.nystrom, self.cap, &self.hits, &self.misses, scope, ells, build)
    }

    /// Drop every entry (both families) whose key starts with `prefix`,
    /// returning how many were removed. Keys are `[scope…, ℓ bits…]`, and
    /// sharded training tags its scopes `[shard_id, scope…]`
    /// (`train::mll::shard_scope`), so `invalidate_scope(&[s])` evicts
    /// exactly shard `s`'s factors — the streaming observe plane calls
    /// this when new points land in a shard, since every cached factor
    /// for that shard was built from a dataset that no longer exists.
    /// An empty prefix clears the cache. Entries still borrowed through
    /// an `Arc` stay alive until the borrower drops them; they are only
    /// unreachable for future lookups.
    pub fn invalidate_scope(&self, prefix: &[u64]) -> usize {
        let mut removed = 0;
        {
            let mut s = self.mka.lock().unwrap();
            let before = s.slots.len();
            s.slots.retain(|sl| !sl.key.starts_with(prefix));
            removed += before - s.slots.len();
        }
        {
            let mut s = self.nystrom.lock().unwrap();
            let before = s.slots.len();
            s.slots.retain(|sl| !sl.key.starts_with(prefix));
            removed += before - s.slots.len();
        }
        removed
    }
}

fn key_bits(scope: &[u64], ells: &[f64]) -> Vec<u64> {
    scope.iter().copied().chain(ells.iter().map(|l| l.to_bits())).collect()
}

fn get_or_build<T>(
    store: &Mutex<Store<T>>,
    cap: usize,
    hits: &AtomicU64,
    misses: &AtomicU64,
    scope: &[u64],
    ells: &[f64],
    build: impl FnOnce() -> Result<T>,
) -> Result<Arc<T>> {
    if cap == 0 {
        // Storage disabled: the build is real work, so the instance
        // counts it (a train run with train_cache_factors = 0 must
        // report factorizations == evals, not 0); the global gauges
        // only track enabled caches.
        misses.fetch_add(1, Ordering::Relaxed);
        return build().map(Arc::new);
    }
    let key = key_bits(scope, ells);
    {
        let mut s = store.lock().unwrap();
        s.tick += 1;
        let tick = s.tick;
        if let Some(slot) = s.slots.iter_mut().find(|sl| sl.key == key) {
            slot.tick = tick;
            hits.fetch_add(1, Ordering::Relaxed);
            HITS.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::clone(&slot.entry));
        }
    }
    // Build OUTSIDE the lock: concurrent optimizer starts must not
    // serialize on each other's factorizations. A failed build is not
    // cached — the error propagates and a later lookup retries.
    misses.fetch_add(1, Ordering::Relaxed);
    MISSES.fetch_add(1, Ordering::Relaxed);
    let built = Arc::new(build()?);
    let mut s = store.lock().unwrap();
    s.tick += 1;
    let tick = s.tick;
    if let Some(slot) = s.slots.iter_mut().find(|sl| sl.key == key) {
        // Another thread built the same (bit-identical) entry first;
        // keep the stored one and drop the duplicate.
        slot.tick = tick;
        return Ok(Arc::clone(&slot.entry));
    }
    if s.slots.len() >= cap {
        // Evict the least-recently-used slot. A displaced factor costs a
        // full refactorization if its lengthscale comes back, so thrash
        // here is worth a warning.
        let lru = s
            .slots
            .iter()
            .enumerate()
            .min_by_key(|(_, sl)| sl.tick)
            .map(|(i, _)| i)
            .expect("non-empty at capacity");
        crate::obs::log!(
            Warn,
            "train.cache",
            { "capacity" => cap },
            "factor cache full: displacing LRU entry — refit cost returns if its ℓ is revisited"
        );
        s.slots.remove(lru);
    }
    s.slots.push(Slot { key, entry: Arc::clone(&built), tick });
    Ok(built)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::Error;

    fn entry(v: f64) -> MkaEntry {
        MkaEntry::new(MkaFactor::new(1, vec![], Mat::from_rows(&[&[v]])))
    }

    #[test]
    fn mka_entry_gram_is_lazy_and_sticky() {
        let kept = MkaEntry::with_gram(
            MkaFactor::new(1, vec![], Mat::from_rows(&[&[2.0]])),
            Mat::from_rows(&[&[2.0]]),
        );
        // with_gram: no rebuild on access
        assert_eq!(kept.gram(|| panic!("gram was retained")).at(0, 0), 2.0);
        // new: regenerates once, then sticks
        let dropped = entry(3.0);
        let mut builds = 0;
        let g = dropped
            .gram(|| {
                builds += 1;
                Mat::from_rows(&[&[3.0]])
            })
            .at(0, 0);
        assert_eq!(g, 3.0);
        assert_eq!(dropped.gram(|| panic!("second build")).at(0, 0), 3.0);
        assert_eq!(builds, 1);
    }

    #[test]
    fn hit_and_miss_accounting() {
        let c = FactorCache::new(4);
        let a = c.mka(&[], &[1.0], || Ok(entry(1.0))).unwrap();
        assert_eq!((c.hits(), c.misses()), (0, 1));
        let b = c.mka(&[], &[1.0], || panic!("must not rebuild on a hit")).unwrap();
        assert_eq!((c.hits(), c.misses()), (1, 1));
        assert!(Arc::ptr_eq(&a, &b), "hit must return the stored entry");
        // a different ARD vector is a different key
        let _ = c.mka(&[], &[1.0, 1.0], || Ok(entry(2.0))).unwrap();
        assert_eq!((c.hits(), c.misses()), (1, 2));
    }

    /// Equal length scales under different scopes (k / seed / config)
    /// are different entries — a caller varying the budget against one
    /// instance must never be handed the wrong factor.
    #[test]
    fn scope_isolates_entries() {
        let c = FactorCache::new(4);
        let _ = c.mka(&[16, 7], &[1.0], || Ok(entry(1.0))).unwrap();
        let mut rebuilt = false;
        let _ = c
            .mka(&[32, 7], &[1.0], || {
                rebuilt = true;
                Ok(entry(2.0))
            })
            .unwrap();
        assert!(rebuilt, "same ℓ, different scope must not collide");
        // and the original scope still hits
        let _ = c.mka(&[16, 7], &[1.0], || panic!("scoped hit expected")).unwrap();
        assert_eq!((c.hits(), c.misses()), (1, 2));
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let c = FactorCache::new(2);
        let _ = c.mka(&[], &[1.0], || Ok(entry(1.0))).unwrap();
        let _ = c.mka(&[], &[2.0], || Ok(entry(2.0))).unwrap();
        // touch 1.0 so 2.0 becomes LRU, then insert a third
        let _ = c.mka(&[], &[1.0], || panic!("hit")).unwrap();
        let _ = c.mka(&[], &[3.0], || Ok(entry(3.0))).unwrap();
        // 1.0 survived, 2.0 was evicted
        let _ = c.mka(&[], &[1.0], || panic!("1.0 must still be cached")).unwrap();
        let mut rebuilt = false;
        let _ = c.mka(&[], &[2.0], || {
                rebuilt = true;
                Ok(entry(2.0))
            })
            .unwrap();
        assert!(rebuilt, "2.0 must have been evicted");
    }

    #[test]
    fn disabled_cache_always_builds_and_counts_its_misses() {
        let c = FactorCache::disabled();
        let mut builds = 0;
        for _ in 0..3 {
            let _ = c.mka(&[], &[1.0], || {
                    builds += 1;
                    Ok(entry(1.0))
                })
                .unwrap();
        }
        assert_eq!(builds, 3);
        // Every build is an instance-level miss even with storage off —
        // factorization reporting must not claim perfect reuse when the
        // cache is disabled.
        assert_eq!((c.hits(), c.misses()), (0, 3));
    }

    #[test]
    fn build_errors_are_not_cached() {
        let c = FactorCache::new(2);
        let err = c.mka(&[], &[1.0], || Err(Error::Linalg("boom".into())));
        assert!(err.is_err());
        // the failed key rebuilds (and can now succeed)
        let ok = c.mka(&[], &[1.0], || Ok(entry(1.0)));
        assert!(ok.is_ok());
        assert_eq!(c.misses(), 2);
    }

    /// Scoped invalidation removes exactly the prefixed entries: shard
    /// 1's factors go, shard 2's still hit — what the observe plane needs
    /// when a streaming batch lands in one shard of a training run.
    #[test]
    fn invalidate_scope_evicts_only_the_prefix() {
        let c = FactorCache::new(8);
        // shard-tagged scopes, as sharded training builds them
        let _ = c.mka(&[1, 16, 7], &[1.0], || Ok(entry(1.0))).unwrap();
        let _ = c.mka(&[1, 16, 7], &[2.0], || Ok(entry(2.0))).unwrap();
        let _ = c.mka(&[2, 16, 7], &[1.0], || Ok(entry(3.0))).unwrap();
        assert_eq!(c.invalidate_scope(&[1]), 2);
        // shard 2 still hits...
        let _ = c.mka(&[2, 16, 7], &[1.0], || panic!("shard 2 untouched")).unwrap();
        // ...shard 1 rebuilds
        let mut rebuilt = false;
        let _ = c
            .mka(&[1, 16, 7], &[1.0], || {
                rebuilt = true;
                Ok(entry(1.0))
            })
            .unwrap();
        assert!(rebuilt, "invalidated shard must rebuild");
        // idempotent; empty prefix clears everything
        assert_eq!(c.invalidate_scope(&[99]), 0);
        assert!(c.invalidate_scope(&[]) >= 2);
        let mut again = false;
        let _ = c
            .mka(&[2, 16, 7], &[1.0], || {
                again = true;
                Ok(entry(3.0))
            })
            .unwrap();
        assert!(again, "full clear must evict shard 2 too");
    }

    #[test]
    fn capacity_is_respected() {
        assert_eq!(FactorCache::new(7).cap, 7);
        assert_eq!(FactorCache::disabled().cap, 0);
        // The process-wide default knob is last-writer-wins and shared
        // with every concurrently constructed Router (which writes it in
        // Router::new), so only exercise the API — asserting a specific
        // global value here would race other lib tests.
        set_default_capacity(default_capacity());
        let _ = FactorCache::with_default_capacity();
    }
}
