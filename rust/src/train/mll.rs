//! Exact-given-the-approximation GP log marginal likelihood.
//!
//! The evidence of a zero-mean GP with covariance C is
//!
//!   log p(y) = −½ yᵀC⁻¹y − ½ log det C − (n/2) log 2π,
//!
//! and every approximation family in this crate admits a *direct* form of
//! both terms:
//!
//! * **Full** — one Cholesky of K + σ²I (Rasmussen & Williams Alg. 2.1);
//! * **MKA** — one `factorize` then Proposition-7 `solve` + `logdet`: this
//!   is the paper's selling point ("direct method"), here finally consumed
//!   by hyperparameter learning instead of sitting unused;
//! * **SoR / FITC** — Woodbury for the quadratic form and the matrix
//!   determinant lemma for the log det of C = K_zfᵀW⁻¹K_zf + Λ with
//!   diagonal Λ, all through the m×m [`NystromBlocks`];
//! * **PITC** — the same with block-diagonal Λ = blockdiag(K_bb − Q_bb)
//!   + σ²I, one small Cholesky per block.
//!
//! MEKA is deliberately absent: its approximant loses spsd-ness, so its
//! "evidence" is undefined — callers must select MEKA hyperparameters by
//! CV instead.

use crate::baselines::nystrom::{select_landmarks, LandmarkMethod, NystromBlocks};
use crate::cluster::{cluster_rows, ClusterMethod};
use crate::data::dataset::Dataset;
use crate::error::{Error, Result};
use crate::experiments::methods::{mka_config_for, pitc_block_size, Method};
use crate::gp::cv::HyperParams;
use crate::gp::full::FullGp;
use crate::gp::mka_gp::MkaGp;
use crate::kernels::{Kernel, RbfKernel};
use crate::la::blas::{dot, gemm, gemm_nt, gemv};
use crate::la::chol::Chol;
use crate::la::dense::Mat;
use crate::mka::{factorize, MkaConfig, MkaFactor};
use crate::train::cache::{FactorCache, MkaEntry, NystromEntry};
use crate::util::Rng;

/// Assemble the Gaussian evidence from its two computed terms.
pub fn gaussian_mll(quad: f64, logdet: f64, n: usize) -> f64 {
    -0.5 * quad - 0.5 * logdet - 0.5 * (n as f64) * (2.0 * std::f64::consts::PI).ln()
}

fn check_hp(hp: HyperParams) -> Result<()> {
    let ok = hp.lengthscale.is_finite()
        && hp.lengthscale > 0.0
        && hp.sigma2.is_finite()
        && hp.sigma2 > 0.0;
    if !ok {
        return Err(Error::Config(format!(
            "invalid hyperparameters: lengthscale={}, sigma2={}",
            hp.lengthscale, hp.sigma2
        )));
    }
    Ok(())
}

/// Exact evidence via one Cholesky of K + σ²I.
pub fn mll_full(data: &Dataset, kernel: &dyn Kernel, sigma2: f64) -> Result<f64> {
    let gp = FullGp::fit(data, kernel, sigma2)?;
    Ok(gp.log_marginal(&data.y))
}

/// MKA evidence: one noise-free factorization served through the
/// σ²-shifted spectrum view, then a Proposition-7 solve for the
/// quadratic form and the free `logdet`.
pub fn mll_mka(data: &Dataset, kernel: &dyn Kernel, sigma2: f64, cfg: &MkaConfig) -> Result<f64> {
    MkaGp::fit(data, kernel, sigma2, cfg)?.log_marginal()
}

/// Evidence straight from a (shifted) MKA factor — the σ²-dependent half
/// of an MKA evidence evaluation, pure spectrum arithmetic once the
/// factor exists. This is what a [`FactorCache`] hit reduces an
/// evaluation to.
pub fn mll_from_factor(f: &MkaFactor, y: &[f64]) -> Result<f64> {
    let alpha = f.solve(y)?;
    Ok(gaussian_mll(dot(y, &alpha), f.logdet()?, y.len()))
}

/// Build the σ²-independent Nyström entry (landmarks, K_mm/K_mn blocks,
/// chol(K_mm)) that both the cached and the uncached SoR/FITC/PITC
/// paths route through — landmark selection lives in exactly one place.
/// FITC's diagonals and PITC's clusters attach lazily on first use.
pub(crate) fn nystrom_entry(
    data: &Dataset,
    kernel: &dyn Kernel,
    m: usize,
    seed: u64,
) -> Result<NystromEntry> {
    let z = select_landmarks(&data.x, m, LandmarkMethod::Uniform, seed);
    Ok(NystromEntry::new(NystromBlocks::new(data, kernel, z)?))
}

/// Build the σ²-independent MKA entry (noise-free gram → factorize) —
/// the single home of the factor build for both the value and the
/// gradient evaluators. `keep_gram` retains the gram on the entry for
/// the gradient path's ∂K/∂θ maps; the value path drops it (an n×n
/// dense matrix per cached length scale is real memory).
pub(crate) fn mka_entry(
    data: &Dataset,
    kernel: &dyn Kernel,
    cfg: &MkaConfig,
    keep_gram: bool,
) -> Result<MkaEntry> {
    let g = kernel.gram_sym(&data.x);
    let f = factorize(&g, Some(&data.x), cfg)?;
    Ok(if keep_gram { MkaEntry::with_gram(f, g) } else { MkaEntry::new(f) })
}

/// Cache-key scope for an MKA config: everything besides the length
/// scales (and the fixed dataset) that determines the factor.
/// `n_threads` is deliberately absent — it is a wall-clock knob only,
/// bit-identical results at any value (the PR-2 contract).
pub(crate) fn mka_scope(cfg: &MkaConfig) -> [u64; 8] {
    [
        cfg.d_core as u64,
        cfg.block_size as u64,
        cfg.seed,
        cfg.gamma.to_bits(),
        cfg.max_stages as u64,
        cfg.compressor as u64,
        cfg.cluster_method as u64,
        cfg.diag_floor.to_bits(),
    ]
}

/// FITC's Λ = (k_ii − q_ii)₊ + σ² — the single home of the value-path
/// clamp (the gradient path keeps its own copy because it also needs
/// the clamp *mask*).
pub(crate) fn fitc_lambda(k_diag: &[f64], q_diag: &[f64], sigma2: f64) -> Vec<f64> {
    k_diag
        .iter()
        .zip(q_diag)
        .map(|(&kd, &qd)| (kd - qd).max(0.0) + sigma2)
        .collect()
}

/// The σ²-independent FITC diagonal ingredients of an entry (built once,
/// shared by every σ² at this length scale).
fn fitc_entry_diag<'a>(
    e: &'a NystromEntry,
    data: &Dataset,
    kernel: &dyn Kernel,
) -> &'a (Vec<f64>, Vec<f64>) {
    e.fitc_diag(|| {
        let qd = e.nb.q_diag();
        let kd = (0..data.n()).map(|i| kernel.diag(data.x.row(i))).collect();
        (qd, kd)
    })
}

/// Evidence of the Nyström prior C = K_zfᵀ W⁻¹ K_zf + Λ for **diagonal**
/// Λ (SoR: Λ = σ²I; FITC: Λ = diag(K − Q) + σ²I), without ever forming
/// the n×n C:
///
///   C⁻¹y     = Λ⁻¹y − Λ⁻¹K_zfᵀ B⁻¹ K_zf Λ⁻¹y,   B = W + K_zf Λ⁻¹ K_fz
///   log det C = log det B − log det W + Σᵢ log Λᵢᵢ
///
/// (Woodbury + matrix determinant lemma), so the cost is one m×m Cholesky
/// plus O(nm²).
pub fn woodbury_mll(nb: &NystromBlocks, y: &[f64], lam: &[f64]) -> Result<f64> {
    let n = y.len();
    assert_eq!(nb.kzf.cols, n, "K_zf / y shape mismatch");
    assert_eq!(lam.len(), n, "Λ / y shape mismatch");
    if lam.iter().any(|&l| !(l > 0.0)) {
        return Err(Error::Linalg("woodbury_mll: non-positive Λ entry".into()));
    }
    // B = W + K_zf Λ⁻¹ K_fz — one rank-n GEMM over the column-scaled block.
    let mut scaled = nb.kzf.clone();
    for r in 0..scaled.rows {
        for (v, &l) in scaled.row_mut(r).iter_mut().zip(lam) {
            *v /= l;
        }
    }
    let mut b = nb.w.clone();
    b.add_assign(&gemm_nt(&scaled, &nb.kzf));
    b.symmetrize();
    let (b_chol, _) = Chol::new_jittered(&b, 12)?;
    // quad = yᵀΛ⁻¹y − rᵀB⁻¹r with r = K_zf Λ⁻¹ y.
    let ly: Vec<f64> = y.iter().zip(lam).map(|(v, &l)| v / l).collect();
    let r = gemv(&nb.kzf, &ly);
    let quad = dot(y, &ly) - dot(&r, &b_chol.solve(&r));
    let logdet =
        b_chol.logdet() - nb.w_chol.logdet() + lam.iter().map(|l| l.ln()).sum::<f64>();
    Ok(gaussian_mll(quad, logdet, n))
}

/// SoR evidence (Λ = σ²I), landmark selection identical to [`crate::baselines::Sor::fit`].
pub fn mll_sor(
    data: &Dataset,
    kernel: &dyn Kernel,
    sigma2: f64,
    m: usize,
    seed: u64,
) -> Result<f64> {
    let e = nystrom_entry(data, kernel, m, seed)?;
    woodbury_mll(&e.nb, &data.y, &vec![sigma2; data.n()])
}

/// FITC evidence (Λ = diag(K − Q) + σ²I, clamped like `Fitc::fit`).
pub fn mll_fitc(
    data: &Dataset,
    kernel: &dyn Kernel,
    sigma2: f64,
    m: usize,
    seed: u64,
) -> Result<f64> {
    let e = nystrom_entry(data, kernel, m, seed)?;
    let (qd, kd) = fitc_entry_diag(&e, data, kernel);
    woodbury_mll(&e.nb, &data.y, &fitc_lambda(kd, qd, sigma2))
}

/// The PITC block structure: same clustering method, block size and seed
/// mixing as [`crate::baselines::Pitc::fit`], exposed so tests can build
/// the dense block-diagonal reference from the identical partition.
pub fn pitc_clusters(x: &Mat, block_size: usize, seed: u64) -> Vec<Vec<usize>> {
    let mut rng = Rng::new(seed ^ 0x5049);
    cluster_rows(ClusterMethod::Bisect, Some(x), None, x.rows, block_size.max(1), &mut rng)
        .clusters
}

/// PITC evidence: block-diagonal Λ with Λ_b = K_bb − Q_bb + σ²I. One
/// |b|×|b| Cholesky per block feeds both the quadratic form and the
/// determinant lemma; B accumulates W + Σ_b K_zb Λ_b⁻¹ K_bz.
pub fn block_woodbury_mll(
    nb: &NystromBlocks,
    data: &Dataset,
    kernel: &dyn Kernel,
    sigma2: f64,
    clusters: &[Vec<usize>],
) -> Result<f64> {
    let n = data.n();
    let m = nb.m();
    let all_rows: Vec<usize> = (0..m).collect();
    let mut b = nb.w.clone();
    let mut r = vec![0.0; m];
    let mut quad_diag = 0.0;
    let mut logdet_lam = 0.0;
    for members in clusters {
        let kbb = kernel.gram_sym(&data.x.gather_rows(members));
        let qbb = nb.q_block(members, members);
        let mut lam = kbb.sub(&qbb);
        lam.symmetrize();
        lam.add_diag(sigma2);
        let (lchol, _) = Chol::new_jittered(&lam, 12)?;
        logdet_lam += lchol.logdet();
        let kzb = nb.kzf.gather(&all_rows, members); // m×|b|
        let linv_kbz = lchol.solve_mat(&kzb.transpose()); // |b|×m
        b.add_assign(&gemm(&kzb, &linv_kbz));
        let yb: Vec<f64> = members.iter().map(|&i| data.y[i]).collect();
        let linv_y = lchol.solve(&yb);
        quad_diag += dot(&yb, &linv_y);
        for (row, acc) in r.iter_mut().enumerate() {
            *acc += dot(kzb.row(row), &linv_y);
        }
    }
    b.symmetrize();
    let (b_chol, _) = Chol::new_jittered(&b, 12)?;
    let quad = quad_diag - dot(&r, &b_chol.solve(&r));
    let logdet = b_chol.logdet() - nb.w_chol.logdet() + logdet_lam;
    Ok(gaussian_mll(quad, logdet, n))
}

/// PITC evidence with the standard landmark/clustering choices.
pub fn mll_pitc(
    data: &Dataset,
    kernel: &dyn Kernel,
    sigma2: f64,
    m: usize,
    block_size: usize,
    seed: u64,
) -> Result<f64> {
    let e = nystrom_entry(data, kernel, m, seed)?;
    let clusters = e.clusters(block_size as u64, || pitc_clusters(&data.x, block_size, seed));
    block_woodbury_mll(&e.nb, data, kernel, sigma2, &clusters)
}

/// Method-dispatched log marginal likelihood, with the same per-method
/// budget interpretation (`k` → landmarks / d_core, PITC block sizing) as
/// [`crate::train::trainer::fit_model`], so the value scored during
/// selection is the evidence of the model that will actually be fitted.
pub fn log_marginal_likelihood(
    method: Method,
    data: &Dataset,
    hp: HyperParams,
    k: usize,
    seed: u64,
) -> Result<f64> {
    log_marginal_likelihood_cached(method, data, hp, k, seed, &FactorCache::disabled())
}

/// [`log_marginal_likelihood`] with a per-run [`FactorCache`]: the
/// σ²-independent half of the evaluation — MKA's noise-free `factorize`,
/// the Nyström family's (K_mm, K_mn, chol, diag Q) blocks — is looked up
/// by length scale, so candidates that revisit an ℓ (in particular,
/// σ²-only optimizer moves) are pure spectrum/Woodbury arithmetic. The
/// cached value is bit-identical to the uncached one: entries are
/// deterministic functions of the key.
pub fn log_marginal_likelihood_cached(
    method: Method,
    data: &Dataset,
    hp: HyperParams,
    k: usize,
    seed: u64,
    cache: &FactorCache,
) -> Result<f64> {
    check_hp(hp)?;
    let kern = RbfKernel::new(hp.lengthscale);
    let s2 = hp.sigma2;
    let ells = [hp.lengthscale];
    let nys_scope = [k as u64, seed];
    match method {
        Method::Full => mll_full(data, &kern, s2),
        Method::Sor => {
            let e = cache.nystrom(&nys_scope, &ells, || nystrom_entry(data, &kern, k, seed))?;
            woodbury_mll(&e.nb, &data.y, &vec![s2; data.n()])
        }
        Method::Fitc => {
            let e = cache.nystrom(&nys_scope, &ells, || nystrom_entry(data, &kern, k, seed))?;
            let (qd, kd) = fitc_entry_diag(&e, data, &kern);
            woodbury_mll(&e.nb, &data.y, &fitc_lambda(kd, qd, s2))
        }
        Method::Pitc => {
            let block = pitc_block_size(data.n(), k);
            let e = cache.nystrom(&nys_scope, &ells, || nystrom_entry(data, &kern, k, seed))?;
            // Clusters depend only on (x, block, seed) — cached on the
            // entry, so a σ²-only move re-clusters nothing either.
            let clusters = e.clusters(block as u64, || pitc_clusters(&data.x, block, seed));
            block_woodbury_mll(&e.nb, data, &kern, s2, &clusters)
        }
        Method::Meka => Err(Error::Config(
            "MEKA loses spsd-ness, so its marginal likelihood is undefined; use grid CV".into(),
        )),
        Method::Mka => {
            let cfg = mka_config_for(k, data.n(), seed);
            let e = cache.mka(&mka_scope(&cfg), &ells, || mka_entry(data, &kern, &cfg, false))?;
            mll_from_factor(&e.factor.shifted(s2), &data.y)
        }
    }
}

/// Prepend a shard tag to a cache scope: shard `tag`'s entries live
/// under `[tag, scope…]`, so sharded training can never collide entries
/// across shards — the shard id joins the cache key.
pub(crate) fn shard_scope(tag: u64, scope: &[u64]) -> Vec<u64> {
    let mut v = Vec::with_capacity(scope.len() + 1);
    v.push(tag);
    v.extend_from_slice(scope);
    v
}

/// MKA evidence of **one shard** of a sharded training run: the factor
/// rides `cache` under a shard-tagged scope ([`shard_scope`]), and `cfg`
/// is the fleet-wide config — the same config every shard of the fitted
/// [`crate::gp::sharded::ShardedGp`] will use — so the summed surface the
/// optimizer climbs is the evidence of the model that will be served.
/// MKA-only by construction: the sharded plane serves MKA shards.
pub fn shard_log_marginal_likelihood(
    data: &Dataset,
    hp: HyperParams,
    cfg: &MkaConfig,
    cache: &FactorCache,
    shard_id: u64,
) -> Result<f64> {
    check_hp(hp)?;
    let kern = RbfKernel::new(hp.lengthscale);
    let scope = shard_scope(shard_id, &mka_scope(cfg));
    let e = cache.mka(&scope, &[hp.lengthscale], || mka_entry(data, &kern, cfg, false))?;
    mll_from_factor(&e.factor.shifted(hp.sigma2), &data.y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gp_dataset, SynthSpec};

    fn small() -> Dataset {
        gp_dataset(&SynthSpec::named("mll", 80, 2), 3)
    }

    #[test]
    fn dispatcher_rejects_bad_hp_and_meka() {
        let d = small();
        let bad = HyperParams { lengthscale: -1.0, sigma2: 0.1 };
        assert!(log_marginal_likelihood(Method::Full, &d, bad, 8, 1).is_err());
        let nan = HyperParams { lengthscale: 1.0, sigma2: f64::NAN };
        assert!(log_marginal_likelihood(Method::Sor, &d, nan, 8, 1).is_err());
        let ok = HyperParams { lengthscale: 1.0, sigma2: 0.1 };
        assert!(log_marginal_likelihood(Method::Meka, &d, ok, 8, 1).is_err());
    }

    #[test]
    fn every_tractable_method_returns_finite_negative_mll() {
        let d = small();
        let hp = HyperParams { lengthscale: 1.2, sigma2: 0.1 };
        for m in [Method::Full, Method::Sor, Method::Fitc, Method::Pitc, Method::Mka] {
            let v = log_marginal_likelihood(m, &d, hp, 10, 5).unwrap();
            assert!(v.is_finite(), "{m:?}: {v}");
            // normalized targets ⇒ evidence is negative
            assert!(v < 0.0, "{m:?}: {v}");
        }
    }

    #[test]
    fn ordering_prefers_sane_lengthscale() {
        // The whole point of MLL selection: an absurd lengthscale scores
        // worse than a reasonable one, for every tractable method.
        let d = small();
        let sane = HyperParams { lengthscale: 1.2, sigma2: 0.1 };
        let absurd = HyperParams { lengthscale: 1e-3, sigma2: 0.1 };
        for m in [Method::Full, Method::Sor, Method::Fitc, Method::Pitc, Method::Mka] {
            let good = log_marginal_likelihood(m, &d, sane, 10, 5).unwrap();
            let bad = log_marginal_likelihood(m, &d, absurd, 10, 5).unwrap();
            assert!(bad < good, "{m:?}: bad {bad} !< good {good}");
        }
    }

    /// Cached evaluation must be bit-identical to uncached — the cache
    /// stores deterministic σ²-independent halves, so hit/miss patterns
    /// are invisible in the values (the determinism contract).
    #[test]
    fn cached_evidence_is_bit_identical_to_uncached() {
        let d = small();
        let cache = FactorCache::new(4);
        for m in [Method::Sor, Method::Fitc, Method::Pitc, Method::Mka] {
            for s2 in [0.05, 0.1, 0.3] {
                let hp = HyperParams { lengthscale: 1.2, sigma2: s2 };
                let plain = log_marginal_likelihood(m, &d, hp, 10, 5).unwrap();
                let cached =
                    log_marginal_likelihood_cached(m, &d, hp, 10, 5, &cache).unwrap();
                assert_eq!(plain.to_bits(), cached.to_bits(), "{m:?} σ²={s2}");
            }
        }
        // All 12 evaluations share one ℓ: one MKA build, one Nyström
        // build (SoR/FITC/PITC share identical landmarks at equal k and
        // seed), everything else hits.
        assert_eq!(cache.misses(), 2, "hits={} misses={}", cache.hits(), cache.misses());
        assert_eq!(cache.hits(), 10);
    }

    #[test]
    fn woodbury_rejects_non_positive_lambda() {
        let d = small();
        let z = select_landmarks(&d.x, 8, LandmarkMethod::Uniform, 1);
        let nb = NystromBlocks::new(&d, &RbfKernel::new(1.0), z).unwrap();
        let mut lam = vec![0.1; d.n()];
        lam[3] = 0.0;
        assert!(woodbury_mll(&nb, &d.y, &lam).is_err());
    }

    #[test]
    fn sor_is_fitc_with_flat_lambda() {
        // With Λ forced to σ²I, the FITC machinery must reproduce mll_sor.
        let d = small();
        let kern = RbfKernel::new(1.0);
        let z = select_landmarks(&d.x, 10, LandmarkMethod::Uniform, 7);
        let nb = NystromBlocks::new(&d, &kern, z).unwrap();
        let via_woodbury = woodbury_mll(&nb, &d.y, &vec![0.1; d.n()]).unwrap();
        let via_sor = mll_sor(&d, &kern, 0.1, 10, 7).unwrap();
        assert!((via_woodbury - via_sor).abs() < 1e-9);
    }

    #[test]
    fn pitc_single_block_matches_full_when_landmarks_are_all_points() {
        // One block ⇒ the training conditional is exact; Z = X makes the
        // prior exact too, so the PITC evidence is the exact evidence.
        let d = gp_dataset(&SynthSpec::named("pitc1", 50, 2), 4);
        let kern = RbfKernel::new(1.0);
        let nb = NystromBlocks::new(&d, &kern, d.x.clone()).unwrap();
        let clusters = vec![(0..d.n()).collect::<Vec<usize>>()];
        let pitc = block_woodbury_mll(&nb, &d, &kern, 0.1, &clusters).unwrap();
        let full = mll_full(&d, &kern, 0.1).unwrap();
        // W carries a hair of jitter (K(X,X) is near-singular at n=50),
        // so the identity holds to jitter precision, not machine precision.
        assert!(
            (pitc - full).abs() < 1e-3 * full.abs().max(1.0),
            "pitc {pitc} vs full {full}"
        );
    }
}
