//! Analytic gradients of the log marginal likelihood.
//!
//! Every evidence evaluator in [`crate::train::mll`] gains a matching
//! gradient: for a parameter θ ∈ {log ℓ_1, …, log ℓ_d, log σ²} of the ARD
//! covariance C(θ),
//!
//!   ∂/∂θ log p(y) = ½ αᵀ(∂C/∂θ)α − ½ tr(C⁻¹ ∂C/∂θ),   α = C⁻¹y
//!
//! (the classic `½ tr((ααᵀ − C⁻¹) ∂C/∂θ)` identity, Rasmussen & Williams
//! eq. 5.9). The work is organizing that trace per approximation family
//! without ever forming an n×n inverse where the evaluator itself doesn't:
//!
//! * **Full** — one blocked [`Chol::solve_mat`] against the identity gives
//!   C⁻¹ (the evaluator already paid the n³ Cholesky), then each ∂C/∂θ is
//!   an elementwise product with the gram (see
//!   [`crate::kernels::ArdRbfKernel::grad_gram_dim`]).
//! * **SoR / FITC** (diagonal Λ) and **PITC** (block-diagonal Λ) — the
//!   Woodbury/determinant-lemma forms differentiate through the m×m
//!   Nyström blocks: with C = UᵀW⁻¹U + Λ, U = K_zf, the key identity is
//!   W⁻¹ U C⁻¹ = B⁻¹ S where S = UΛ⁻¹ and B = W + SUᵀ — so every trace
//!   reduces to m×n products against T = B⁻¹S and V = W⁻¹U.
//! * **MKA** — the factorization is produced by a combinatorial pipeline
//!   (clustering, Jacobi rotations), so we differentiate the *model*,
//!   not the pipeline: d(logdet K̃′)/dθ ≈ tr(K̃′⁻¹ ∂K/∂θ), with the trace
//!   estimated by a fixed-seed Hutchinson probe batch pushed through ONE
//!   [`crate::mka::MkaFactor::solve_mat_par`] cascade (bit-deterministic
//!   at any thread count per the PR-2 contract), or computed exactly via
//!   a dense solve for validation ([`TraceMode::Exact`]). The σ²
//!   direction needs tr(K̃′⁻¹), which the factor's explicit spectrum
//!   (Proposition 7) gives **exactly** — no probes.

use crate::data::dataset::Dataset;
use crate::error::{Error, Result};
use crate::experiments::methods::{mka_config_for, pitc_block_size, Method};
use crate::gp::cv::ArdHyperParams;
use crate::kernels::{ArdRbfKernel, Kernel};
use crate::la::blas::{dot, gemm, gemm_nt, gemm_tn, gemv, gemv_t};
use crate::la::chol::Chol;
use crate::la::dense::Mat;
use crate::mka::MkaConfig;
use crate::train::cache::FactorCache;
use crate::train::mll::{
    gaussian_mll, mka_entry, mka_scope, nystrom_entry, pitc_clusters, shard_scope,
};
use crate::util::Rng;

/// Default Hutchinson probe count for the MKA trace estimator.
pub const MKA_TRACE_PROBES: usize = 16;

/// How the MKA gradient estimates tr(K̃′⁻¹ ∂K/∂θ).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceMode {
    /// Fixed-seed Rademacher probes, all pushed through one blocked
    /// cascade — O(P) extra solve columns.
    Probes(usize),
    /// Exact dense trace via a blocked solve against ∂K/∂θ itself —
    /// O(n) extra solve columns per parameter; the validation path.
    Exact,
}

/// The evidence and its gradient in log-parameter space.
#[derive(Clone, Debug)]
pub struct MllGrad {
    pub mll: f64,
    /// ∂mll/∂log ℓ — one entry per dimension (ARD), or a single entry for
    /// a tied length scale.
    pub d_log_ell: Vec<f64>,
    /// ∂mll/∂log σ².
    pub d_log_sigma2: f64,
}

impl MllGrad {
    /// The flat gradient vector `(∂/∂log ℓ…, ∂/∂log σ²)` the optimizer
    /// consumes.
    pub fn grad_vec(&self) -> Vec<f64> {
        let mut g = self.d_log_ell.clone();
        g.push(self.d_log_sigma2);
        g
    }
}

/// Σ_ij A∘B — equals tr(AᵀB), and tr(AB) for symmetric A (or B).
fn elem_dot(a: &Mat, b: &Mat) -> f64 {
    debug_assert_eq!((a.rows, a.cols), (b.rows, b.cols));
    dot(&a.data, &b.data)
}

/// Per-column dots: out[j] = Σ_i A[i,j]·B[i,j] = diag(AᵀB).
fn col_dots(a: &Mat, b: &Mat) -> Vec<f64> {
    debug_assert_eq!((a.rows, a.cols), (b.rows, b.cols));
    let mut out = vec![0.0; a.cols];
    for r in 0..a.rows {
        for ((o, &x), &y) in out.iter_mut().zip(a.row(r)).zip(b.row(r)) {
            *o += x * y;
        }
    }
    out
}

fn check_hp(data: &Dataset, hp: &ArdHyperParams) -> Result<()> {
    if !hp.is_valid() {
        return Err(Error::Config(format!("invalid ARD hyperparameters: {hp:?}")));
    }
    if hp.dim() != data.dim() {
        return Err(Error::Config(format!(
            "ARD dimension mismatch: {} lengthscales for {}-dimensional data",
            hp.dim(),
            data.dim()
        )));
    }
    Ok(())
}

/// Number of length-scale parameters in the tied/ARD layout.
fn n_ell_params(kern: &ArdRbfKernel, tied: bool) -> usize {
    if tied {
        1
    } else {
        kern.dim()
    }
}

/// The gradient gram for length-scale parameter `p` of the tied/ARD
/// layout — materialized one at a time, so an ARD evaluation never holds
/// more than a single dense gram regardless of the input dimension.
fn ell_grad_at(kern: &ArdRbfKernel, k: &Mat, x: &Mat, y: &Mat, tied: bool, p: usize) -> Mat {
    if tied {
        kern.grad_gram_tied(k, x, y)
    } else {
        kern.grad_gram_dim(k, x, y, p)
    }
}

// ----------------------------------------------------------------------
// Full GP
// ----------------------------------------------------------------------

/// Exact gradient of the exact evidence: one Cholesky of K + σ²I, one
/// blocked solve against the identity for C⁻¹, then elementwise traces.
pub fn mll_grad_full(data: &Dataset, hp: &ArdHyperParams, tied: bool) -> Result<MllGrad> {
    check_hp(data, hp)?;
    let n = data.n();
    let kern = hp.kernel();
    let k = kern.gram_sym(&data.x);
    let mut kp = k.clone();
    kp.add_diag(hp.sigma2);
    let (chol, _) = Chol::new_jittered(&kp, 12)?;
    let alpha = chol.solve(&data.y);
    let mll = gaussian_mll(dot(&data.y, &alpha), chol.logdet(), n);
    // C⁻¹ explicitly — the blocked multi-RHS path on the shared pool.
    let cinv = chol.solve_mat(&Mat::eye(n));
    let n_ell = n_ell_params(&kern, tied);
    let mut d_log_ell = Vec::with_capacity(n_ell);
    for p in 0..n_ell {
        let g = ell_grad_at(&kern, &k, &data.x, &data.x, tied, p);
        let ga = gemv(&g, &alpha);
        d_log_ell.push(0.5 * (dot(&alpha, &ga) - elem_dot(&cinv, &g)));
    }
    let tr_cinv: f64 = cinv.diagonal().iter().sum();
    let d_log_sigma2 = 0.5 * hp.sigma2 * (dot(&alpha, &alpha) - tr_cinv);
    Ok(MllGrad { mll, d_log_ell, d_log_sigma2 })
}

// ----------------------------------------------------------------------
// SoR / FITC (diagonal Λ)
// ----------------------------------------------------------------------

/// Shared SoR/FITC gradient: C = UᵀW⁻¹U + Λ with diagonal Λ (SoR: σ²I;
/// FITC: diag(K − Q) + σ²I). Never forms C — every term reduces to m×n
/// products against T = B⁻¹S and V = W⁻¹U.
fn nystrom_mll_grad(
    data: &Dataset,
    hp: &ArdHyperParams,
    tied: bool,
    m: usize,
    seed: u64,
    fitc: bool,
    cache: &FactorCache,
) -> Result<MllGrad> {
    check_hp(data, hp)?;
    let n = data.n();
    let s2 = hp.sigma2;
    let kern = hp.kernel();
    // The σ²-independent blocks (landmarks, K_mm, K_mn, chol) come from
    // the per-lengthscale cache — a σ²-only line-search move reuses them.
    let entry =
        cache.nystrom(&[m as u64, seed], &hp.lengthscales, || nystrom_entry(data, &kern, m, seed))?;
    let nb = &entry.nb;
    let u = &nb.kzf; // m×n
    // V = W⁻¹U is the dominant σ²-independent product (O(m²n)) — cached
    // on the entry so σ²-only moves skip it too.
    let v = entry.winv_u(|| nb.w_chol.solve_mat(u));

    // Λ and, for FITC, where the (k_ii − q_ii) ≥ 0 clamp engaged (there
    // the length-scale derivative of Λ is zero).
    let q = col_dots(u, &v); // diag(UᵀW⁻¹U)
    let mut clamped = vec![false; n];
    let lam: Vec<f64> = if fitc {
        (0..n)
            .map(|i| {
                let corr = kern.diag(data.x.row(i)) - q[i];
                clamped[i] = corr < 0.0;
                corr.max(0.0) + s2
            })
            .collect()
    } else {
        vec![s2; n]
    };
    if lam.iter().any(|&l| !(l > 0.0)) {
        return Err(Error::Linalg("nystrom_mll_grad: non-positive Λ entry".into()));
    }

    // S = UΛ⁻¹, B = W + SUᵀ, T = B⁻¹S.
    let mut s = u.clone();
    for r in 0..s.rows {
        for (x, &l) in s.row_mut(r).iter_mut().zip(&lam) {
            *x /= l;
        }
    }
    let mut b = nb.w.clone();
    b.add_assign(&gemm_nt(&s, u));
    b.symmetrize();
    let (bchol, _) = Chol::new_jittered(&b, 12)?;
    let t = bchol.solve_mat(&s);

    // α = Λ⁻¹y − Tᵀ(Sy); evidence from the determinant lemma.
    let ly: Vec<f64> = data.y.iter().zip(&lam).map(|(yi, &l)| yi / l).collect();
    let sy = gemv(&s, &data.y);
    let tt_sy = gemv_t(&t, &sy);
    let alpha: Vec<f64> = ly.iter().zip(&tt_sy).map(|(a, b)| a - b).collect();
    let logdet =
        bchol.logdet() - nb.w_chol.logdet() + lam.iter().map(|l| l.ln()).sum::<f64>();
    let mll = gaussian_mll(dot(&data.y, &alpha), logdet, n);

    // Reusable pieces: Vα, diag(C⁻¹) = Λ⁻¹ − diag(SᵀT), M = VC⁻¹Vᵀ = TVᵀ.
    let va = gemv(&v, &alpha);
    let st_diag = col_dots(&s, &t);
    let cinv_diag: Vec<f64> =
        lam.iter().zip(&st_diag).map(|(&l, &d)| 1.0 / l - d).collect();
    let m_mat = gemm_nt(&t, &v);

    let n_ell = n_ell_params(&kern, tied);
    let mut d_log_ell = Vec::with_capacity(n_ell);
    for p in 0..n_ell {
        let udot = ell_grad_at(&kern, u, &nb.z, &data.x, tied, p);
        let wdot = ell_grad_at(&kern, &nb.w, &nb.z, &nb.z, tied, p);
        let ua = gemv(&udot, &alpha);
        let wva = gemv(&wdot, &va);
        let mut quad = 2.0 * dot(&ua, &va) - dot(&va, &wva);
        let mut tr = 2.0 * elem_dot(&udot, &t) - elem_dot(&wdot, &m_mat);
        if fitc {
            // Λ̇_i = −q̇_i (zero where the clamp engaged):
            // q̇ = diag(U̇ᵀV + VᵀU̇ − VᵀẆV).
            let wv = gemm(&wdot, &v);
            let qdot_raw: Vec<f64> = col_dots(&udot, &v)
                .iter()
                .zip(col_dots(&v, &wv))
                .map(|(&uv, &vwv)| 2.0 * uv - vwv)
                .collect();
            for i in 0..n {
                if !clamped[i] {
                    let ld = -qdot_raw[i];
                    quad += ld * alpha[i] * alpha[i];
                    tr += ld * cinv_diag[i];
                }
            }
        }
        d_log_ell.push(0.5 * (quad - tr));
    }

    // log σ²: U̇ = Ẇ = 0, Λ̇ = σ²I for both SoR and FITC.
    let d_log_sigma2 =
        0.5 * s2 * (dot(&alpha, &alpha) - cinv_diag.iter().sum::<f64>());
    Ok(MllGrad { mll, d_log_ell, d_log_sigma2 })
}

/// SoR evidence gradient (Λ = σ²I), landmarks as in `mll_sor`.
pub fn mll_grad_sor(
    data: &Dataset,
    hp: &ArdHyperParams,
    tied: bool,
    m: usize,
    seed: u64,
) -> Result<MllGrad> {
    nystrom_mll_grad(data, hp, tied, m, seed, false, &FactorCache::disabled())
}

/// FITC evidence gradient (Λ = diag(K − Q) + σ²I), landmarks as in
/// `mll_fitc`.
pub fn mll_grad_fitc(
    data: &Dataset,
    hp: &ArdHyperParams,
    tied: bool,
    m: usize,
    seed: u64,
) -> Result<MllGrad> {
    nystrom_mll_grad(data, hp, tied, m, seed, true, &FactorCache::disabled())
}

// ----------------------------------------------------------------------
// PITC (block-diagonal Λ)
// ----------------------------------------------------------------------

/// Per-block state shared by every parameter's gradient pass.
struct PitcBlock {
    members: Vec<usize>,
    xb: Mat,
    /// Base gram K_bb of the block (noiseless, before the Q subtraction).
    kbb: Mat,
    /// Λ_b⁻¹ (dense |b|×|b|).
    linv: Mat,
    /// m×|b| column gathers of V, S, T at the block's indices.
    vb: Mat,
    sb: Mat,
    tb: Mat,
    alpha_b: Vec<f64>,
}

/// PITC evidence gradient: identical clustering and Λ_b assembly to
/// `mll_pitc`, with Λ̇_b = Ġ_bb − Q̇_bb per block for the length-scale
/// directions and σ²I_b for the noise direction.
pub fn mll_grad_pitc(
    data: &Dataset,
    hp: &ArdHyperParams,
    tied: bool,
    m: usize,
    block_size: usize,
    seed: u64,
) -> Result<MllGrad> {
    mll_grad_pitc_cached(data, hp, tied, m, block_size, seed, &FactorCache::disabled())
}

/// [`mll_grad_pitc`] with the per-lengthscale Nyström blocks served from
/// a [`FactorCache`].
pub fn mll_grad_pitc_cached(
    data: &Dataset,
    hp: &ArdHyperParams,
    tied: bool,
    m: usize,
    block_size: usize,
    seed: u64,
    cache: &FactorCache,
) -> Result<MllGrad> {
    check_hp(data, hp)?;
    let n = data.n();
    let s2 = hp.sigma2;
    let kern = hp.kernel();
    let entry =
        cache.nystrom(&[m as u64, seed], &hp.lengthscales, || nystrom_entry(data, &kern, m, seed))?;
    let nb = &entry.nb;
    let u = &nb.kzf;
    let mm = nb.m();
    let all_rows: Vec<usize> = (0..mm).collect();
    let v = entry.winv_u(|| nb.w_chol.solve_mat(u));
    let clusters =
        entry.clusters(block_size as u64, || pitc_clusters(&data.x, block_size, seed));

    // Per-block Λ_b = K_bb − Q_bb + σ²I; assemble S = UΛ⁻¹ and Λ⁻¹y by
    // scattering block results into the global column layout.
    let mut s = Mat::zeros(mm, n);
    let mut ly = vec![0.0; n];
    let mut logdet_lam = 0.0;
    let mut blocks: Vec<PitcBlock> = Vec::with_capacity(clusters.len());
    for members in clusters.iter() {
        let xb = data.x.gather_rows(members);
        let kbb = kern.gram_sym(&xb);
        let qbb = nb.q_block(members, members);
        let mut lam = kbb.sub(&qbb);
        lam.symmetrize();
        lam.add_diag(s2);
        let (lchol, _) = Chol::new_jittered(&lam, 12)?;
        logdet_lam += lchol.logdet();
        let linv = lchol.solve_mat(&Mat::eye(members.len()));
        let ub = u.gather(&all_rows, members);
        // S_b = U_b Λ_b⁻¹ = (Λ_b⁻¹ U_bᵀ)ᵀ.
        let sb = lchol.solve_mat(&ub.transpose()).transpose();
        for (jl, &jg) in members.iter().enumerate() {
            for a in 0..mm {
                s.set(a, jg, sb.at(a, jl));
            }
        }
        let yb: Vec<f64> = members.iter().map(|&i| data.y[i]).collect();
        let ly_b = lchol.solve(&yb);
        for (jl, &jg) in members.iter().enumerate() {
            ly[jg] = ly_b[jl];
        }
        blocks.push(PitcBlock {
            members: members.clone(),
            xb,
            kbb,
            linv,
            vb: v.gather(&all_rows, members),
            sb,
            tb: Mat::zeros(0, 0), // filled once T exists
            alpha_b: Vec::new(),  // filled once α exists
        });
    }

    // B = W + SUᵀ, T = B⁻¹S, α = Λ⁻¹y − Tᵀ(Sy).
    let mut b = nb.w.clone();
    b.add_assign(&gemm_nt(&s, u));
    b.symmetrize();
    let (bchol, _) = Chol::new_jittered(&b, 12)?;
    let t = bchol.solve_mat(&s);
    let sy = gemv(&s, &data.y);
    let tt_sy = gemv_t(&t, &sy);
    let alpha: Vec<f64> = ly.iter().zip(&tt_sy).map(|(a, b)| a - b).collect();
    let logdet = bchol.logdet() - nb.w_chol.logdet() + logdet_lam;
    let mll = gaussian_mll(dot(&data.y, &alpha), logdet, n);

    for blk in &mut blocks {
        blk.tb = t.gather(&all_rows, &blk.members);
        blk.alpha_b = blk.members.iter().map(|&i| alpha[i]).collect();
    }

    let va = gemv(&v, &alpha);
    let m_mat = gemm_nt(&t, &v);

    let n_ell = n_ell_params(&kern, tied);
    let mut d_log_ell = Vec::with_capacity(n_ell);
    for p in 0..n_ell {
        let udot = ell_grad_at(&kern, u, &nb.z, &data.x, tied, p);
        let wdot = ell_grad_at(&kern, &nb.w, &nb.z, &nb.z, tied, p);
        let ua = gemv(&udot, &alpha);
        let wva = gemv(&wdot, &va);
        let mut quad = 2.0 * dot(&ua, &va) - dot(&va, &wva);
        let mut tr = 2.0 * elem_dot(&udot, &t) - elem_dot(&wdot, &m_mat);
        for blk in &blocks {
            // Λ̇_b = Ġ_bb − (U̇_bᵀV_b + V_bᵀU̇_b − V_bᵀẆV_b).
            let gbb = ell_grad_at(&kern, &blk.kbb, &blk.xb, &blk.xb, tied, p);
            let udot_b = udot.gather(&all_rows, &blk.members);
            let a1 = gemm_tn(&udot_b, &blk.vb);
            let wv_b = gemm(&wdot, &blk.vb);
            let a2 = gemm_tn(&blk.vb, &wv_b);
            let mut lamdot = gbb.sub(&a1).sub(&a1.transpose());
            lamdot.add_assign(&a2);
            // C⁻¹_bb = Λ_b⁻¹ − S_bᵀT_b.
            let cinv_bb = blk.linv.sub(&gemm_tn(&blk.sb, &blk.tb));
            let la = gemv(&lamdot, &blk.alpha_b);
            quad += dot(&blk.alpha_b, &la);
            tr += elem_dot(&cinv_bb, &lamdot);
        }
        d_log_ell.push(0.5 * (quad - tr));
    }

    // log σ²: Λ̇ = σ²I ⇒ tr(C⁻¹Λ̇) = σ² Σ_b tr(Λ_b⁻¹ − S_bᵀT_b).
    let mut tr_cinv = 0.0;
    for blk in &blocks {
        tr_cinv += blk.linv.diagonal().iter().sum::<f64>();
        tr_cinv -= col_dots(&blk.sb, &blk.tb).iter().sum::<f64>();
    }
    let d_log_sigma2 = 0.5 * s2 * (dot(&alpha, &alpha) - tr_cinv);
    Ok(MllGrad { mll, d_log_ell, d_log_sigma2 })
}

// ----------------------------------------------------------------------
// MKA
// ----------------------------------------------------------------------

/// MKA evidence gradient through the cascade. The quadratic-form term is
/// exact given the factorization (`½ αᵀ(∂K/∂θ)α`, α = K̃′⁻¹y); the logdet
/// term uses tr(K̃′⁻¹ ∂K/∂θ) per `mode`, and the σ² direction uses the
/// factor's exact spectrum for tr(K̃′⁻¹). `probe_seed` fixes the
/// Rademacher batch, so the estimate is deterministic — and because the
/// probes ride one `solve_mat_par`, bit-identical at any thread count.
pub fn mll_grad_mka(
    data: &Dataset,
    hp: &ArdHyperParams,
    tied: bool,
    cfg: &MkaConfig,
    mode: TraceMode,
    probe_seed: u64,
) -> Result<MllGrad> {
    mll_grad_mka_cached(data, hp, tied, cfg, mode, probe_seed, &FactorCache::disabled())
}

/// [`mll_grad_mka`] with the noise-free factorization (and the gram the
/// ∂K/∂θ maps read) served from a per-lengthscale [`FactorCache`]: K̃′ =
/// K̃ + σ²I is the factor's shifted spectrum view, so every gradient
/// evaluation at a cached ℓ — in particular σ²-only L-BFGS moves — does
/// zero factorizations.
pub fn mll_grad_mka_cached(
    data: &Dataset,
    hp: &ArdHyperParams,
    tied: bool,
    cfg: &MkaConfig,
    mode: TraceMode,
    probe_seed: u64,
    cache: &FactorCache,
) -> Result<MllGrad> {
    mll_grad_mka_at_scope(data, hp, tied, cfg, mode, probe_seed, cache, &mka_scope(cfg))
}

/// One shard's MKA evidence gradient in a sharded training run: same
/// cascade gradient, but the cache entry lives under a shard-tagged
/// scope ([`shard_scope`]) so shards sharing a `FactorCache` never serve
/// each other's factors. Trace mode and probe seed match the `mll_grad`
/// dispatcher's MKA arm, so a 1-shard run climbs the identical surface.
pub fn shard_mll_grad_mka(
    data: &Dataset,
    hp: &ArdHyperParams,
    tied: bool,
    cfg: &MkaConfig,
    cache: &FactorCache,
    shard_id: u64,
) -> Result<MllGrad> {
    mll_grad_mka_at_scope(
        data,
        hp,
        tied,
        cfg,
        TraceMode::Probes(MKA_TRACE_PROBES),
        cfg.seed ^ 0x70524f42,
        cache,
        &shard_scope(shard_id, &mka_scope(cfg)),
    )
}

#[allow(clippy::too_many_arguments)]
fn mll_grad_mka_at_scope(
    data: &Dataset,
    hp: &ArdHyperParams,
    tied: bool,
    cfg: &MkaConfig,
    mode: TraceMode,
    probe_seed: u64,
    cache: &FactorCache,
    scope: &[u64],
) -> Result<MllGrad> {
    check_hp(data, hp)?;
    let n = data.n();
    let kern = hp.kernel();
    let entry = cache.mka(scope, &hp.lengthscales, || mka_entry(data, &kern, cfg, true))?;
    // The entry was built with its gram retained; the lazy accessor only
    // rebuilds if a value-path entry (factor-only) ever lands on this key.
    let k = entry.gram(|| kern.gram_sym(&data.x));
    let f = entry.factor.shifted(hp.sigma2);
    let alpha = f.solve(&data.y)?;
    let mll = gaussian_mll(dot(&data.y, &alpha), f.logdet()?, n);
    let threads = crate::par::threads();

    // One blocked cascade carries the whole probe batch (Probes mode).
    let probes = match mode {
        TraceMode::Probes(p) => {
            let p = p.max(1);
            crate::obs::log!(
                Debug,
                "train.grad",
                { "probes" => p, "n" => n },
                "trace terms via Hutchinson probes (stochastic, not exact)"
            );
            let mut rng = Rng::new(probe_seed);
            let z = Mat::from_fn(n, p, |_, _| {
                if rng.next_u64() & 1 == 0 {
                    1.0
                } else {
                    -1.0
                }
            });
            let r = f.solve_mat_par(&z, threads)?;
            Some((z, r))
        }
        TraceMode::Exact => None,
    };

    let n_ell = n_ell_params(&kern, tied);
    let mut d_log_ell = Vec::with_capacity(n_ell);
    for p in 0..n_ell {
        let g = ell_grad_at(&kern, &k, &data.x, &data.x, tied, p);
        let ga = gemv(&g, &alpha);
        let quad = dot(&alpha, &ga);
        let tr = match &probes {
            Some((z, r)) => {
                // tr(K̃′⁻¹G) ≈ mean_p (K̃′⁻¹z_p)ᵀ(G z_p).
                let gz = gemm(&g, z);
                elem_dot(r, &gz) / z.cols as f64
            }
            None => {
                let x = f.solve_mat_par(&g, threads)?;
                x.diagonal().iter().sum()
            }
        };
        d_log_ell.push(0.5 * (quad - tr));
    }

    // tr(K̃′⁻¹) exactly from the explicit spectrum (Proposition 7):
    // core eigenvalues ∪ wavelet diagonal values.
    let tr_inv: f64 = f.spectrum().iter().map(|l| 1.0 / l).sum();
    let d_log_sigma2 = 0.5 * hp.sigma2 * (dot(&alpha, &alpha) - tr_inv);
    Ok(MllGrad { mll, d_log_ell, d_log_sigma2 })
}

// ----------------------------------------------------------------------
// Dispatch
// ----------------------------------------------------------------------

/// Method-dispatched evidence gradient with the same budget
/// interpretation (`k` → landmarks / d_core, PITC block sizing) as
/// [`crate::train::mll::log_marginal_likelihood`], so the surface the
/// L-BFGS optimizer climbs is the evidence of the model that will be
/// fitted. `tied = true` collapses the length-scale gradient to a single
/// entry (the isotropic parametrization); `tied = false` is full ARD.
pub fn mll_grad(
    method: Method,
    data: &Dataset,
    hp: &ArdHyperParams,
    tied: bool,
    k: usize,
    seed: u64,
) -> Result<MllGrad> {
    mll_grad_cached(method, data, hp, tied, k, seed, &FactorCache::disabled())
}

/// [`mll_grad`] with a per-run [`FactorCache`]: every family's
/// σ²-independent half (noise-free MKA factor + gram, Nyström blocks) is
/// looked up by the length-scale vector. Bit-identical to the uncached
/// path — the L-BFGS trainer's evaluation loop rides this.
pub fn mll_grad_cached(
    method: Method,
    data: &Dataset,
    hp: &ArdHyperParams,
    tied: bool,
    k: usize,
    seed: u64,
    cache: &FactorCache,
) -> Result<MllGrad> {
    match method {
        Method::Full => mll_grad_full(data, hp, tied),
        Method::Sor => nystrom_mll_grad(data, hp, tied, k, seed, false, cache),
        Method::Fitc => nystrom_mll_grad(data, hp, tied, k, seed, true, cache),
        Method::Pitc => {
            let block = pitc_block_size(data.n(), k);
            mll_grad_pitc_cached(data, hp, tied, k, block, seed, cache)
        }
        Method::Meka => Err(Error::Config(
            "MEKA loses spsd-ness, so its marginal likelihood has no gradient; use grid CV"
                .into(),
        )),
        Method::Mka => {
            let cfg = mka_config_for(k, data.n(), seed);
            mll_grad_mka_cached(
                data,
                hp,
                tied,
                &cfg,
                TraceMode::Probes(MKA_TRACE_PROBES),
                seed ^ 0x70524f42,
                cache,
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gp_dataset, SynthSpec};
    use crate::gp::cv::HyperParams;

    fn small() -> Dataset {
        gp_dataset(&SynthSpec::named("grad", 70, 2), 3)
    }

    fn hp() -> ArdHyperParams {
        ArdHyperParams { lengthscales: vec![0.9, 1.6], sigma2: 0.08 }
    }

    // Finite-difference validation of every evaluator lives in the
    // integration suite (`rust/tests/grad_check.rs`) — one shared
    // central-difference harness instead of a per-module copy. The unit
    // tests here pin the cheap structural invariants only.

    #[test]
    fn tied_gradient_is_sum_of_ard_gradients() {
        let d = small();
        // With equal lengthscales, the tied derivative must equal the sum
        // of the per-dimension derivatives (chain rule).
        let iso = ArdHyperParams::isotropic(HyperParams { lengthscale: 1.1, sigma2: 0.1 }, 2);
        let tied = mll_grad_full(&d, &iso, true).unwrap();
        let ard = mll_grad_full(&d, &iso, false).unwrap();
        let sum: f64 = ard.d_log_ell.iter().sum();
        assert!((tied.d_log_ell[0] - sum).abs() < 1e-9);
        assert!((tied.mll - ard.mll).abs() < 1e-12);
        assert!((tied.d_log_sigma2 - ard.d_log_sigma2).abs() < 1e-12);
    }

    #[test]
    fn dispatcher_validates_and_rejects_meka() {
        let d = small();
        let bad = ArdHyperParams { lengthscales: vec![1.0], sigma2: 0.1 }; // wrong dim
        assert!(mll_grad(Method::Full, &d, &bad, false, 8, 1).is_err());
        let neg = ArdHyperParams { lengthscales: vec![1.0, -1.0], sigma2: 0.1 };
        assert!(mll_grad(Method::Sor, &d, &neg, false, 8, 1).is_err());
        assert!(mll_grad(Method::Meka, &d, &hp(), false, 8, 1).is_err());
    }

    #[test]
    fn every_method_returns_finite_gradients() {
        let d = small();
        let hp = hp();
        for m in [Method::Full, Method::Sor, Method::Fitc, Method::Pitc, Method::Mka] {
            let g = mll_grad(m, &d, &hp, false, 10, 5).unwrap();
            assert!(g.mll.is_finite(), "{m:?}");
            assert_eq!(g.d_log_ell.len(), 2, "{m:?}");
            assert!(g.grad_vec().iter().all(|v| v.is_finite()), "{m:?}: {g:?}");
        }
    }

    #[test]
    fn mll_value_agrees_with_mll_module() {
        // The gradient evaluators must score the same evidence surface as
        // the value-only evaluators (isotropic case).
        let d = small();
        let flat = HyperParams { lengthscale: 1.2, sigma2: 0.1 };
        let iso = ArdHyperParams::isotropic(flat, 2);
        for m in [Method::Full, Method::Sor, Method::Fitc, Method::Pitc] {
            let v = crate::train::mll::log_marginal_likelihood(m, &d, flat, 10, 5).unwrap();
            let g = mll_grad(m, &d, &iso, true, 10, 5).unwrap();
            assert!(
                (v - g.mll).abs() < 1e-6 * v.abs().max(1.0),
                "{m:?}: value {v} vs grad-path {}",
                g.mll
            );
        }
    }
}
