//! Maximization of the log marginal likelihood over log-space
//! hyperparameters — derivative-free ([`maximize_mll`], Nelder–Mead over
//! `(lengthscale, σ²)`) and gradient-based ([`maximize_mll_lbfgs`],
//! bounded L-BFGS over `(ℓ_1..ℓ_d, σ²)` with ARD support).
//!
//! Std-only Nelder–Mead with a bounded box and multi-start: start points
//! come from the [`default_grid`] heuristic (spread evenly through the
//! grid), each start runs an independent simplex under a shared eval
//! budget, and the starts execute **concurrently on the shared `par`
//! pool** with the crate's bit-determinism contract preserved — each
//! start owns a fixed output slot (one pool task per start, no work
//! stealing across slots) and the final reduction walks the slots in
//! start order with strict-improvement comparisons, so the outcome is
//! identical at any thread count.
//!
//! Working in log space makes the box constraints multiplicative and the
//! evidence surface far better conditioned (lengthscale and σ² are scale
//! parameters); failed evaluations (e.g. a Cholesky failure at an
//! aggressive setting) score −∞ and the simplex walks back into the
//! feasible region.
//!
//! Both optimizers compose with the trainer's per-run
//! [`crate::train::cache::FactorCache`]: every Nelder–Mead start's
//! initial simplex perturbs σ² at a fixed length scale (one of the three
//! vertices shares ℓ with the start point bit-for-bit), and any
//! revisited ℓ thereafter, so evidence evaluations along the noise axis
//! reuse the cached noise-free factorization — zero factorizations, by
//! construction rather than by luck. Cached values are bit-identical to
//! fresh ones, so the determinism contract is unaffected by hit/miss
//! timing between concurrent starts.

use crate::error::{Error, Result};
use crate::gp::cv::{default_grid, ArdHyperParams, HyperParams};
use crate::la::blas::{axpy, dot};
use crate::par::{run_tasks, SendPtr};

/// Evaluation budget for one optimizer call.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OptimBudget {
    /// Total objective evaluations across all starts (soft cap: each
    /// start gets an equal share, min 5, and may finish its current
    /// simplex step).
    pub max_evals: usize,
    /// Independent Nelder–Mead restarts.
    pub n_starts: usize,
    /// Relative convergence tolerance on the simplex value spread.
    pub tol: f64,
}

impl Default for OptimBudget {
    fn default() -> Self {
        OptimBudget { max_evals: 60, n_starts: 3, tol: 1e-5 }
    }
}

/// One successful objective evaluation (failures are counted but not
/// recorded — they carry no finite value to report).
#[derive(Clone, Copy, Debug)]
pub struct EvalRecord {
    pub hp: HyperParams,
    /// Objective value: MLL for the evidence path, validation SMSE for
    /// the CV path (see the owning report's `selection` label).
    pub value: f64,
}

/// Result of a multi-start maximization.
#[derive(Clone, Debug)]
pub struct OptimOutcome {
    pub best: HyperParams,
    pub best_mll: f64,
    /// Objective evaluations actually spent (including failed ones).
    pub evals: usize,
    /// Whether the start that produced `best` met the tolerance before
    /// exhausting its share of the budget.
    pub converged: bool,
    /// Every successful evaluation, in fixed start order.
    pub trace: Vec<EvalRecord>,
}

/// Box constraints in natural scale (applied in log space).
#[derive(Clone, Copy, Debug)]
pub struct SearchBox {
    pub lengthscale: (f64, f64),
    pub sigma2: (f64, f64),
}

impl SearchBox {
    /// Default box around the √d lengthscale heuristic; the noise floor
    /// matches the extended `default_grid` low-noise regime.
    pub fn for_dim(dim: usize) -> SearchBox {
        let base = (dim as f64).sqrt().max(1.0);
        SearchBox { lengthscale: (0.02 * base, 20.0 * base), sigma2: (1e-4, 2.0) }
    }

    fn lo(&self) -> [f64; 2] {
        [self.lengthscale.0.ln(), self.sigma2.0.ln()]
    }

    fn hi(&self) -> [f64; 2] {
        [self.lengthscale.1.ln(), self.sigma2.1.ln()]
    }
}

/// Maximize `objective` over the box. `objective` returns `None` when a
/// candidate fails to evaluate (treated as −∞). Errors only when *every*
/// evaluation across every start failed.
pub fn maximize_mll<F>(
    objective: F,
    dim: usize,
    budget: &OptimBudget,
    sbox: &SearchBox,
) -> Result<OptimOutcome>
where
    F: Fn(HyperParams) -> Option<f64> + Send + Sync,
{
    let n_starts = budget.n_starts.max(1);
    let per_start = (budget.max_evals / n_starts).max(5);
    let starts = seed_points(dim, n_starts, sbox);
    let (lo, hi) = (sbox.lo(), sbox.hi());

    let mut slots: Vec<Option<StartResult>> = vec![None; n_starts];
    let ptr = SendPtr::new(slots.as_mut_ptr());
    let obj = &objective;
    // One pool task per start: fixed slot sharding, no cross-start state.
    run_tasks(n_starts, n_starts, |i| {
        let res = nelder_mead(obj, starts[i], lo, hi, per_start, budget.tol);
        // SAFETY: task i writes only slot i; run_tasks blocks until done.
        unsafe { *ptr.ptr().add(i) = Some(res) };
    });

    // Serial-identical reduction: walk slots in start order, strict
    // improvement only — independent of execution interleaving.
    let mut trace = Vec::new();
    let mut best: Option<(HyperParams, f64, bool)> = None;
    let mut evals = 0;
    for slot in slots.into_iter().flatten() {
        evals += slot.evals;
        if let Some((hp, v)) = slot.best {
            if best.map_or(true, |(_, bv, _)| v > bv) {
                best = Some((hp, v, slot.converged));
            }
        }
        trace.extend(slot.trace);
    }
    let (best, best_mll, converged) = best.ok_or_else(|| {
        Error::Config("mll optimizer: every candidate evaluation failed".into())
    })?;
    Ok(OptimOutcome { best, best_mll, evals, converged, trace })
}

/// Multi-start seeds from the `default_grid` heuristic, spread evenly
/// through the grid and clamped into the box (log space).
fn seed_points(dim: usize, n_starts: usize, sbox: &SearchBox) -> Vec<[f64; 2]> {
    let grid = default_grid(dim);
    let (lo, hi) = (sbox.lo(), sbox.hi());
    (0..n_starts)
        .map(|i| {
            // Evenly spaced through the ell-major grid ordering, so
            // different starts land on different lengthscale decades.
            let g = grid[(i * grid.len()) / n_starts.max(1)];
            clamp([g.lengthscale.ln(), g.sigma2.ln()], lo, hi)
        })
        .collect()
}

fn clamp(x: [f64; 2], lo: [f64; 2], hi: [f64; 2]) -> [f64; 2] {
    [x[0].clamp(lo[0], hi[0]), x[1].clamp(lo[1], hi[1])]
}

#[derive(Clone, Debug)]
struct StartResult {
    best: Option<(HyperParams, f64)>,
    evals: usize,
    converged: bool,
    trace: Vec<EvalRecord>,
}

/// Tracks evaluations, the running best and the success trace for one
/// start. Cost is the *negated* objective (Nelder–Mead minimizes).
struct EvalCtx<'a, F> {
    obj: &'a F,
    evals: usize,
    trace: Vec<EvalRecord>,
    best: Option<(HyperParams, f64)>,
}

impl<F: Fn(HyperParams) -> Option<f64>> EvalCtx<'_, F> {
    fn cost(&mut self, x: [f64; 2]) -> f64 {
        let hp = HyperParams { lengthscale: x[0].exp(), sigma2: x[1].exp() };
        self.evals += 1;
        match (self.obj)(hp) {
            Some(v) if v.is_finite() => {
                self.trace.push(EvalRecord { hp, value: v });
                if self.best.map_or(true, |(_, bv)| v > bv) {
                    self.best = Some((hp, v));
                }
                -v
            }
            _ => f64::INFINITY,
        }
    }
}

/// Bounded 2-D Nelder–Mead (α=1, γ=2, ρ=½, σ=½): every candidate is
/// clamped into the box before evaluation.
fn nelder_mead<F>(
    obj: &F,
    x0: [f64; 2],
    lo: [f64; 2],
    hi: [f64; 2],
    max_evals: usize,
    tol: f64,
) -> StartResult
where
    F: Fn(HyperParams) -> Option<f64>,
{
    let mut ctx = EvalCtx { obj, evals: 0, trace: Vec::new(), best: None };
    // Initial simplex: steps of 0.45 in log space (≈ ×1.57), flipped
    // when the start sits against the upper bound.
    let mut simplex: Vec<([f64; 2], f64)> = Vec::with_capacity(3);
    let p0 = clamp(x0, lo, hi);
    simplex.push((p0, ctx.cost(p0)));
    for d in 0..2 {
        let step = if p0[d] + 0.45 <= hi[d] { 0.45 } else { -0.45 };
        let mut p = p0;
        p[d] += step;
        let p = clamp(p, lo, hi);
        simplex.push((p, ctx.cost(p)));
    }

    let mut converged = false;
    while ctx.evals < max_evals {
        simplex.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal));
        let (fb, fw) = (simplex[0].1, simplex[2].1);
        if fb.is_infinite() {
            break; // the whole simplex is infeasible — nothing to walk back to
        }
        if fw.is_finite() && (fw - fb).abs() <= tol * (1.0 + fb.abs()) {
            converged = true;
            break;
        }
        // Centroid of the two best vertices.
        let c = [
            0.5 * (simplex[0].0[0] + simplex[1].0[0]),
            0.5 * (simplex[0].0[1] + simplex[1].0[1]),
        ];
        let xw = simplex[2].0;
        let refl = clamp([2.0 * c[0] - xw[0], 2.0 * c[1] - xw[1]], lo, hi);
        let fr = ctx.cost(refl);
        if fr < simplex[0].1 {
            // Expand.
            let exp = clamp([3.0 * c[0] - 2.0 * xw[0], 3.0 * c[1] - 2.0 * xw[1]], lo, hi);
            let fe = ctx.cost(exp);
            simplex[2] = if fe < fr { (exp, fe) } else { (refl, fr) };
        } else if fr < simplex[1].1 {
            simplex[2] = (refl, fr);
        } else {
            // Contract (outside if the reflection improved on the worst).
            let toward = if fr < simplex[2].1 { refl } else { xw };
            let con = clamp([0.5 * (c[0] + toward[0]), 0.5 * (c[1] + toward[1])], lo, hi);
            let fc = ctx.cost(con);
            if fc < simplex[2].1.min(fr) {
                simplex[2] = (con, fc);
            } else {
                // Shrink toward the best vertex.
                let xb = simplex[0].0;
                for v in simplex.iter_mut().skip(1) {
                    let p = clamp([0.5 * (xb[0] + v.0[0]), 0.5 * (xb[1] + v.0[1])], lo, hi);
                    *v = (p, ctx.cost(p));
                }
            }
        }
    }

    StartResult { best: ctx.best, evals: ctx.evals, converged, trace: ctx.trace }
}

// ----------------------------------------------------------------------
// Gradient-based path: bounded L-BFGS with ARD
// ----------------------------------------------------------------------

/// Result of a gradient-based multi-start maximization. Unlike
/// [`OptimOutcome`], `best` carries per-dimension length scales; the
/// `trace` records isotropic summaries ([`ArdHyperParams::tied`]) so the
/// protocol-serialized eval trace keeps a uniform shape.
#[derive(Clone, Debug)]
pub struct GradOptimOutcome {
    pub best: ArdHyperParams,
    pub best_mll: f64,
    /// Objective+gradient evaluations spent (including failed ones).
    pub evals: usize,
    /// Whether the winning start met the projected-gradient tolerance.
    pub converged: bool,
    pub trace: Vec<EvalRecord>,
}

/// L-BFGS history depth (pairs of (s, y) kept for the two-loop recursion).
const LBFGS_HISTORY: usize = 8;

/// Armijo sufficient-decrease constant.
const ARMIJO_C1: f64 = 1e-4;

/// Maximize `objective` (which returns the MLL **and** its gradient with
/// respect to the log-parameters) over the box with bounded L-BFGS.
///
/// The parameter vector is `(log ℓ_1, …, log ℓ_p, log σ²)` with `p = dim`
/// when `ard` is true and `p = 1` (one tied length scale broadcast to all
/// dimensions) otherwise; the gradient the objective returns must have
/// the same layout (see [`crate::train::grad::MllGrad::grad_vec`]).
/// Box constraints are enforced by projection: every trial point is
/// clamped before evaluation and the Armijo test uses the projected step,
/// so iterates can slide along active bounds. Starts run concurrently on
/// the shared pool with fixed slot sharding and an in-order reduction —
/// the same bit-determinism contract as [`maximize_mll`].
pub fn maximize_mll_lbfgs<F>(
    objective: F,
    dim: usize,
    ard: bool,
    budget: &OptimBudget,
    sbox: &SearchBox,
) -> Result<GradOptimOutcome>
where
    F: Fn(&ArdHyperParams) -> Option<(f64, Vec<f64>)> + Send + Sync,
{
    let dim = dim.max(1);
    let n_ell = if ard { dim } else { 1 };
    let p = n_ell + 1;
    let n_starts = budget.n_starts.max(1);
    let per_start = (budget.max_evals / n_starts).max(5);
    let (lo2, hi2) = (sbox.lo(), sbox.hi());
    // Broadcast the 2-D box to the full parameter vector.
    let mut lo = vec![lo2[0]; p];
    let mut hi = vec![hi2[0]; p];
    lo[n_ell] = lo2[1];
    hi[n_ell] = hi2[1];
    let starts: Vec<Vec<f64>> = seed_points(dim, n_starts, sbox)
        .into_iter()
        .map(|s2| {
            let mut x = vec![s2[0]; p];
            x[n_ell] = s2[1];
            x
        })
        .collect();

    let mut slots: Vec<Option<GradStartResult>> = vec![None; n_starts];
    let ptr = SendPtr::new(slots.as_mut_ptr());
    let obj = &objective;
    run_tasks(n_starts, n_starts, |i| {
        let res = lbfgs(obj, dim, ard, &starts[i], &lo, &hi, per_start, budget.tol);
        // SAFETY: task i writes only slot i; run_tasks blocks until done.
        unsafe { *ptr.ptr().add(i) = Some(res) };
    });

    let mut trace = Vec::new();
    let mut best: Option<(Vec<f64>, f64, bool)> = None;
    let mut evals = 0;
    for slot in slots.into_iter().flatten() {
        evals += slot.evals;
        if let Some((x, v)) = slot.best {
            if best.as_ref().map_or(true, |(_, bv, _)| v > *bv) {
                best = Some((x, v, slot.converged));
            }
        }
        trace.extend(slot.trace);
    }
    let (x, best_mll, converged) = best.ok_or_else(|| {
        Error::Config("mll lbfgs: every candidate evaluation failed".into())
    })?;
    Ok(GradOptimOutcome {
        best: theta_to_hp(&x, dim, ard),
        best_mll,
        evals,
        converged,
        trace,
    })
}

/// Decode a log-parameter vector into hyperparameters (tied length scale
/// broadcast to every dimension when `ard` is false).
fn theta_to_hp(x: &[f64], dim: usize, ard: bool) -> ArdHyperParams {
    let n_ell = if ard { dim } else { 1 };
    let lengthscales = if ard {
        x[..n_ell].iter().map(|v| v.exp()).collect()
    } else {
        vec![x[0].exp(); dim]
    };
    ArdHyperParams { lengthscales, sigma2: x[n_ell].exp() }
}

fn clamp_vec(x: &[f64], lo: &[f64], hi: &[f64]) -> Vec<f64> {
    x.iter().zip(lo).zip(hi).map(|((v, &l), &h)| v.clamp(l, h)).collect()
}

#[derive(Clone, Debug)]
struct GradStartResult {
    best: Option<(Vec<f64>, f64)>,
    evals: usize,
    converged: bool,
    trace: Vec<EvalRecord>,
}

/// One bounded L-BFGS descent on the negated objective.
fn lbfgs<F>(
    obj: &F,
    dim: usize,
    ard: bool,
    x0: &[f64],
    lo: &[f64],
    hi: &[f64],
    max_evals: usize,
    tol: f64,
) -> GradStartResult
where
    F: Fn(&ArdHyperParams) -> Option<(f64, Vec<f64>)>,
{
    let p = x0.len();
    let mut evals = 0usize;
    let mut trace: Vec<EvalRecord> = Vec::new();
    let mut best: Option<(Vec<f64>, f64)> = None;
    // Evaluate cost = −mll and its gradient at a point, recording traces.
    let eval = |x: &[f64],
                    evals: &mut usize,
                    trace: &mut Vec<EvalRecord>,
                    best: &mut Option<(Vec<f64>, f64)>|
     -> Option<(f64, Vec<f64>)> {
        *evals += 1;
        let hp = theta_to_hp(x, dim, ard);
        match obj(&hp) {
            Some((v, g))
                if v.is_finite() && g.len() == p && g.iter().all(|a| a.is_finite()) =>
            {
                trace.push(EvalRecord { hp: hp.tied(), value: v });
                if best.as_ref().map_or(true, |(_, bv)| v > *bv) {
                    *best = Some((x.to_vec(), v));
                }
                Some((-v, g.iter().map(|a| -a).collect()))
            }
            _ => None,
        }
    };

    let mut x = clamp_vec(x0, lo, hi);
    let Some((mut fx, mut gx)) = eval(&x, &mut evals, &mut trace, &mut best) else {
        return GradStartResult { best: None, evals, converged: false, trace: Vec::new() };
    };
    let mut hist: Vec<(Vec<f64>, Vec<f64>, f64)> = Vec::new(); // (s, y, 1/sᵀy)
    let mut converged = false;

    while evals < max_evals {
        // Projected-gradient convergence test: the box-feasible steepest
        // step length (∞-norm) relative to the objective scale.
        let pg = x
            .iter()
            .zip(&gx)
            .zip(lo.iter().zip(hi))
            .map(|((&xi, &gi), (&l, &h))| ((xi - gi).clamp(l, h) - xi).abs())
            .fold(0.0f64, f64::max);
        if pg <= tol * (1.0 + fx.abs()) {
            converged = true;
            break;
        }

        let mut d = lbfgs_direction(&hist, &gx);
        if dot(&d, &gx) >= 0.0 {
            // Not a descent direction (stale curvature) — steepest descent.
            d = gx.iter().map(|g| -g).collect();
            hist.clear();
        }

        // Backtracking Armijo line search on the projected point.
        let mut step = 1.0f64;
        let mut accepted: Option<(Vec<f64>, f64, Vec<f64>, Vec<f64>)> = None;
        for _ in 0..16 {
            if evals >= max_evals {
                break;
            }
            let cand: Vec<f64> = x.iter().zip(&d).map(|(xi, di)| xi + step * di).collect();
            let xt = clamp_vec(&cand, lo, hi);
            let s: Vec<f64> = xt.iter().zip(&x).map(|(a, b)| a - b).collect();
            if s.iter().all(|v| v.abs() < 1e-14) {
                break; // projection collapsed the step entirely
            }
            // Projection can flip a descent direction against the box
            // (gᵀs ≥ 0): never accept such a step — backtracking shrinks
            // it until fewer components clamp and s realigns with d.
            let gs = dot(&gx, &s);
            if gs < 0.0 {
                if let Some((ft, gt)) = eval(&xt, &mut evals, &mut trace, &mut best) {
                    if ft <= fx + ARMIJO_C1 * gs {
                        accepted = Some((xt, ft, gt, s));
                        break;
                    }
                }
            }
            step *= 0.5;
        }
        let Some((xt, ft, gt, s)) = accepted else {
            break; // no acceptable step — at a (possibly bound) stationary point
        };

        let y: Vec<f64> = gt.iter().zip(&gx).map(|(a, b)| a - b).collect();
        let sy = dot(&s, &y);
        let sn = dot(&s, &s).sqrt();
        let yn = dot(&y, &y).sqrt();
        if sy > 1e-10 * sn * yn {
            if hist.len() == LBFGS_HISTORY {
                hist.remove(0);
            }
            hist.push((s, y, 1.0 / sy));
        }
        x = xt;
        fx = ft;
        gx = gt;
    }

    GradStartResult { best, evals, converged, trace }
}

/// Two-loop recursion: returns the descent direction −H∇f, with the
/// standard γ = sᵀy/yᵀy initial Hessian scaling.
fn lbfgs_direction(hist: &[(Vec<f64>, Vec<f64>, f64)], g: &[f64]) -> Vec<f64> {
    let mut q = g.to_vec();
    let mut alphas = vec![0.0; hist.len()];
    for (i, (s, y, rho)) in hist.iter().enumerate().rev() {
        let a = rho * dot(s, &q);
        alphas[i] = a;
        axpy(-a, y, &mut q);
    }
    if let Some((s, y, _)) = hist.last() {
        let yy = dot(y, y);
        if yy > 0.0 {
            let gamma = dot(s, y) / yy;
            for v in &mut q {
                *v *= gamma;
            }
        }
    }
    for (i, (s, y, rho)) in hist.iter().enumerate() {
        let b = rho * dot(y, &q);
        axpy(alphas[i] - b, s, &mut q);
    }
    for v in &mut q {
        *v = -*v;
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Smooth test objective with a known maximum at (ℓ*, σ²*).
    fn bowl(ell_star: f64, s2_star: f64) -> impl Fn(HyperParams) -> Option<f64> + Send + Sync {
        move |hp: HyperParams| {
            let a = hp.lengthscale.ln() - ell_star.ln();
            let b = hp.sigma2.ln() - s2_star.ln();
            Some(-(a * a) - 0.5 * (b * b))
        }
    }

    #[test]
    fn recovers_quadratic_maximum() {
        let budget = OptimBudget { max_evals: 180, n_starts: 3, tol: 1e-5 };
        let sbox = SearchBox::for_dim(2);
        let out = maximize_mll(bowl(1.5, 0.05), 2, &budget, &sbox).unwrap();
        assert!(out.converged, "evals={}", out.evals);
        assert!((out.best.lengthscale.ln() - 1.5f64.ln()).abs() < 0.05, "{:?}", out.best);
        assert!((out.best.sigma2.ln() - 0.05f64.ln()).abs() < 0.1, "{:?}", out.best);
        assert!(out.best_mll > -1e-3);
        assert!(!out.trace.is_empty());
        assert!(out.evals <= budget.max_evals + 15); // per-start step overshoot only
    }

    #[test]
    fn respects_box_bounds() {
        // Maximum far outside the box ⇒ the optimum lands on the boundary.
        let sbox = SearchBox { lengthscale: (0.5, 2.0), sigma2: (0.01, 0.1) };
        let budget = OptimBudget { max_evals: 90, n_starts: 2, tol: 1e-10 };
        let out = maximize_mll(bowl(100.0, 1.0), 2, &budget, &sbox).unwrap();
        assert!(out.best.lengthscale <= 2.0 + 1e-9);
        assert!(out.best.sigma2 <= 0.1 + 1e-9);
        for e in &out.trace {
            assert!(e.hp.lengthscale >= 0.5 - 1e-9 && e.hp.lengthscale <= 2.0 + 1e-9);
            assert!(e.hp.sigma2 >= 0.01 - 1e-9 && e.hp.sigma2 <= 0.1 + 1e-9);
        }
    }

    #[test]
    fn all_failures_error_and_partial_failures_recover() {
        let budget = OptimBudget { max_evals: 30, n_starts: 2, tol: 1e-6 };
        let sbox = SearchBox::for_dim(2);
        let out = maximize_mll(|_| None, 2, &budget, &sbox);
        assert!(out.is_err());
        // Feasible only above ℓ = 1: the simplex must still find the bowl.
        let partial = |hp: HyperParams| {
            if hp.lengthscale < 1.0 {
                None
            } else {
                Some(-(hp.lengthscale.ln() - 2.0f64.ln()).powi(2))
            }
        };
        let wide = OptimBudget { max_evals: 90, n_starts: 3, tol: 1e-8 };
        let out = maximize_mll(partial, 2, &wide, &sbox).unwrap();
        assert!(out.best.lengthscale >= 1.0);
    }

    #[test]
    fn deterministic_across_thread_counts() {
        // Fixed slot sharding + in-order reduction ⇒ the outcome cannot
        // depend on pool parallelism.
        let budget = OptimBudget { max_evals: 60, n_starts: 4, tol: 1e-8 };
        let sbox = SearchBox::for_dim(3);
        let run = || maximize_mll(bowl(2.0, 0.02), 3, &budget, &sbox).unwrap();
        let a = run();
        crate::par::set_threads(4);
        let b = run();
        crate::par::set_threads(1);
        let c = run();
        for other in [&b, &c] {
            assert_eq!(a.best.lengthscale.to_bits(), other.best.lengthscale.to_bits());
            assert_eq!(a.best.sigma2.to_bits(), other.best.sigma2.to_bits());
            assert_eq!(a.best_mll.to_bits(), other.best_mll.to_bits());
            assert_eq!(a.evals, other.evals);
            assert_eq!(a.trace.len(), other.trace.len());
        }
    }

    /// ARD quadratic bowl with a known maximum and exact gradients.
    fn grad_bowl(
        ells: Vec<f64>,
        s2: f64,
        ard: bool,
    ) -> impl Fn(&ArdHyperParams) -> Option<(f64, Vec<f64>)> + Send + Sync {
        move |hp: &ArdHyperParams| {
            let mut v = 0.0;
            let mut g = Vec::new();
            if ard {
                for (l, t) in hp.lengthscales.iter().zip(&ells) {
                    let a = l.ln() - t.ln();
                    v -= a * a;
                    g.push(-2.0 * a);
                }
            } else {
                let a = hp.lengthscales[0].ln() - ells[0].ln();
                v -= a * a;
                g.push(-2.0 * a);
            }
            let b = hp.sigma2.ln() - s2.ln();
            v -= 0.5 * b * b;
            g.push(-b);
            Some((v, g))
        }
    }

    #[test]
    fn lbfgs_recovers_ard_maximum() {
        let budget = OptimBudget { max_evals: 120, n_starts: 2, tol: 1e-7 };
        let sbox = SearchBox::for_dim(3);
        let targets = vec![0.5, 1.5, 4.0];
        let out =
            maximize_mll_lbfgs(grad_bowl(targets.clone(), 0.05, true), 3, true, &budget, &sbox)
                .unwrap();
        assert!(out.converged, "evals={}", out.evals);
        assert_eq!(out.best.lengthscales.len(), 3);
        for (l, t) in out.best.lengthscales.iter().zip(&targets) {
            assert!((l.ln() - t.ln()).abs() < 1e-3, "{:?}", out.best);
        }
        assert!((out.best.sigma2.ln() - 0.05f64.ln()).abs() < 1e-3);
        assert!(out.best_mll > -1e-5);
        assert!(!out.trace.is_empty());
    }

    #[test]
    fn lbfgs_tied_mode_broadcasts_single_lengthscale() {
        let budget = OptimBudget { max_evals: 80, n_starts: 2, tol: 1e-7 };
        let sbox = SearchBox::for_dim(4);
        let out = maximize_mll_lbfgs(grad_bowl(vec![2.0], 0.1, false), 4, false, &budget, &sbox)
            .unwrap();
        assert_eq!(out.best.lengthscales.len(), 4);
        let l0 = out.best.lengthscales[0];
        assert!(out.best.lengthscales.iter().all(|l| (l - l0).abs() < 1e-12));
        assert!((l0.ln() - 2.0f64.ln()).abs() < 1e-3, "{:?}", out.best);
    }

    #[test]
    fn lbfgs_respects_box_and_converges_on_boundary() {
        let sbox = SearchBox { lengthscale: (0.5, 2.0), sigma2: (0.01, 0.1) };
        let budget = OptimBudget { max_evals: 80, n_starts: 2, tol: 1e-9 };
        let out = maximize_mll_lbfgs(grad_bowl(vec![100.0], 1.0, false), 1, false, &budget, &sbox)
            .unwrap();
        assert!(out.best.lengthscales[0] <= 2.0 + 1e-9);
        assert!(out.best.sigma2 <= 0.1 + 1e-9);
        // the optimum sits against the upper bounds
        assert!((out.best.lengthscales[0] - 2.0).abs() < 1e-6, "{:?}", out.best);
        for e in &out.trace {
            assert!(e.hp.lengthscale >= 0.5 - 1e-9 && e.hp.lengthscale <= 2.0 + 1e-9);
        }
    }

    #[test]
    fn lbfgs_all_failures_error() {
        let budget = OptimBudget { max_evals: 20, n_starts: 2, tol: 1e-6 };
        let sbox = SearchBox::for_dim(2);
        assert!(maximize_mll_lbfgs(|_| None, 2, true, &budget, &sbox).is_err());
    }

    #[test]
    fn lbfgs_deterministic_across_thread_counts() {
        let budget = OptimBudget { max_evals: 60, n_starts: 3, tol: 1e-8 };
        let sbox = SearchBox::for_dim(2);
        let run = || {
            maximize_mll_lbfgs(grad_bowl(vec![0.7, 3.0], 0.02, true), 2, true, &budget, &sbox)
                .unwrap()
        };
        let a = run();
        crate::par::set_threads(4);
        let b = run();
        crate::par::set_threads(1);
        let c = run();
        for other in [&b, &c] {
            for (x, y) in a.best.lengthscales.iter().zip(&other.best.lengthscales) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            assert_eq!(a.best.sigma2.to_bits(), other.best.sigma2.to_bits());
            assert_eq!(a.best_mll.to_bits(), other.best_mll.to_bits());
            assert_eq!(a.evals, other.evals);
            assert_eq!(a.trace.len(), other.trace.len());
        }
    }

    #[test]
    fn seed_points_land_in_box_and_differ() {
        let sbox = SearchBox::for_dim(4);
        let (lo, hi) = (sbox.lo(), sbox.hi());
        let pts = seed_points(4, 3, &sbox);
        assert_eq!(pts.len(), 3);
        for p in &pts {
            assert!(p[0] >= lo[0] && p[0] <= hi[0]);
            assert!(p[1] >= lo[1] && p[1] <= hi[1]);
        }
        assert!(pts[0] != pts[1] || pts[1] != pts[2]);
    }
}
