//! Givens rotations and rotation sequences.
//!
//! MMF-based MKA stores each local orthogonal factor Q as a product of
//! ⌊(1−γ)m⌋ Givens rotations (paper §4, feature (a)), so a whole stage's
//! Q̄_ℓ is a `GivensSeq` over global coordinates — 2 reals + 2 indices per
//! rotation, giving the (2s+1)n storage bound of Proposition 5 and the
//! O(sn) matvec of Proposition 6.

use super::dense::Mat;

/// A single Givens rotation acting in the (i, j) coordinate plane.
///
/// As an operator on vectors:
///   (Gx)_i =  c·x_i + s·x_j
///   (Gx)_j = −s·x_i + c·x_j
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Givens {
    pub i: usize,
    pub j: usize,
    pub c: f64,
    pub s: f64,
}

impl Givens {
    /// The Jacobi rotation G such that conjugating A by G (A' = G A Gᵀ)
    /// zeroes the (i, j) off-diagonal entry, given the 2×2 submatrix
    /// [[a_ii, a_ij], [a_ij, a_jj]].
    pub fn jacobi(i: usize, j: usize, aii: f64, aij: f64, ajj: f64) -> Givens {
        if aij.abs() < 1e-300 {
            return Givens { i, j, c: 1.0, s: 0.0 };
        }
        let theta = (ajj - aii) / (2.0 * aij);
        // Solve t² − 2θt − 1 = 0 stably, taking the smaller-|t| root
        // (this convention matches (Gx)_i = c·x_i + s·x_j,
        // (Gx)_j = −s·x_i + c·x_j).
        let t = if theta >= 0.0 {
            -1.0 / (theta + (1.0 + theta * theta).sqrt())
        } else {
            1.0 / (-theta + (1.0 + theta * theta).sqrt())
        };
        let c = 1.0 / (1.0 + t * t).sqrt();
        let s = t * c;
        Givens { i, j, c, s }
    }

    /// Apply to a vector: x ← Gx.
    #[inline]
    pub fn apply_vec(&self, x: &mut [f64]) {
        let xi = x[self.i];
        let xj = x[self.j];
        x[self.i] = self.c * xi + self.s * xj;
        x[self.j] = -self.s * xi + self.c * xj;
    }

    /// Apply the transpose (= inverse): x ← Gᵀx.
    #[inline]
    pub fn apply_vec_t(&self, x: &mut [f64]) {
        let xi = x[self.i];
        let xj = x[self.j];
        x[self.i] = self.c * xi - self.s * xj;
        x[self.j] = self.s * xi + self.c * xj;
    }

    /// Two-sided symmetric conjugation A ← G A Gᵀ (dense A).
    ///
    /// Hot path of both MMF compression and the stage-global rotation
    /// application: the row updates run on contiguous memory (two fused
    /// axpy-like passes that auto-vectorize), and the symmetric column
    /// copies are done in two clean strided passes afterwards.
    pub fn conjugate_sym(&self, a: &mut Mat) {
        let (i, j, c, s) = (self.i, self.j, self.c, self.s);
        let n = a.rows;
        debug_assert!(a.is_square() && i < n && j < n && i != j);
        // --- rows i and j, contiguous (uses pre-rotation values of both) --
        let (lo, hi) = (i.min(j), i.max(j));
        let (first, second) = a.data.split_at_mut(hi * n);
        let row_lo = &mut first[lo * n..lo * n + n];
        let row_hi = &mut second[..n];
        if lo == i {
            for (vi, vj) in row_lo.iter_mut().zip(row_hi.iter_mut()) {
                let (x, y) = (*vi, *vj);
                *vi = c * x + s * y;
                *vj = -s * x + c * y;
            }
        } else {
            for (vj, vi) in row_lo.iter_mut().zip(row_hi.iter_mut()) {
                let (x, y) = (*vi, *vj);
                *vi = c * x + s * y;
                *vj = -s * x + c * y;
            }
        }
        // --- the 2×2 corner (from symmetric two-sided formulas) -----------
        // After the row pass, a[i][j] currently holds c·A_ij + s·A_jj etc.;
        // recompute the corner exactly from the one-sided values.
        let b_ii = a.at(i, i); // = c·A_ii + s·A_ij (wrong for two-sided)
        let b_ij = a.at(i, j);
        let b_ji = a.at(j, i);
        let b_jj = a.at(j, j);
        // Apply the right-hand rotation to the corner columns:
        // new_ii = c·b_ii + s·b_ij, new_ij = −s·b_ii + c·b_ij, etc.
        let nii = c * b_ii + s * b_ij;
        let nij = -s * b_ii + c * b_ij;
        let nji = c * b_ji + s * b_jj;
        let njj = -s * b_ji + c * b_jj;
        a.set(i, i, nii);
        a.set(i, j, 0.5 * (nij + nji)); // symmetrize roundoff
        a.set(j, i, 0.5 * (nij + nji));
        a.set(j, j, njj);
        // --- mirror the new rows into columns i and j ----------------------
        for k in 0..n {
            if k != i && k != j {
                let vi = a.at(i, k);
                let vj = a.at(j, k);
                a.set(k, i, vi);
                a.set(k, j, vj);
            }
        }
    }

    /// Left-multiply a dense matrix: A ← G A (rows i, j mix).
    pub fn apply_left(&self, a: &mut Mat) {
        let (i, j, c, s) = (self.i, self.j, self.c, self.s);
        let cols = a.cols;
        for k in 0..cols {
            let aik = a.at(i, k);
            let ajk = a.at(j, k);
            a.set(i, k, c * aik + s * ajk);
            a.set(j, k, -s * aik + c * ajk);
        }
    }

    /// Right-multiply by the transpose: A ← A Gᵀ (columns i, j mix).
    pub fn apply_right_t(&self, a: &mut Mat) {
        let (i, j, c, s) = (self.i, self.j, self.c, self.s);
        for r in 0..a.rows {
            let row = a.row_mut(r);
            let ari = row[i];
            let arj = row[j];
            row[i] = c * ari + s * arj;
            row[j] = -s * ari + c * arj;
        }
    }

    /// Dense matrix representation (tests only).
    pub fn to_dense(&self, n: usize) -> Mat {
        let mut g = Mat::eye(n);
        g.set(self.i, self.i, self.c);
        g.set(self.i, self.j, self.s);
        g.set(self.j, self.i, -self.s);
        g.set(self.j, self.j, self.c);
        g
    }
}

/// An ordered product of Givens rotations Q = g_L · … · g_2 · g_1.
///
/// `apply_vec` computes Qx (g_1 first); `apply_vec_t` computes Qᵀx.
#[derive(Clone, Debug, Default)]
pub struct GivensSeq {
    pub rots: Vec<Givens>,
}

impl GivensSeq {
    pub fn new() -> GivensSeq {
        GivensSeq { rots: Vec::new() }
    }

    pub fn push(&mut self, g: Givens) {
        self.rots.push(g);
    }

    pub fn len(&self) -> usize {
        self.rots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rots.is_empty()
    }

    /// x ← Qx.
    pub fn apply_vec(&self, x: &mut [f64]) {
        for g in &self.rots {
            g.apply_vec(x);
        }
    }

    /// x ← Qᵀx (reverse order, transposed rotations).
    pub fn apply_vec_t(&self, x: &mut [f64]) {
        for g in self.rots.iter().rev() {
            g.apply_vec_t(x);
        }
    }

    /// A ← Q A Qᵀ.
    pub fn conjugate_sym(&self, a: &mut Mat) {
        for g in &self.rots {
            g.conjugate_sym(a);
        }
    }

    /// Dense representation (tests only).
    pub fn to_dense(&self, n: usize) -> Mat {
        let mut q = Mat::eye(n);
        for g in &self.rots {
            g.apply_left(&mut q);
        }
        q
    }

    /// Number of stored reals (2 per rotation) — for Prop. 5 storage audits.
    pub fn stored_reals(&self) -> usize {
        2 * self.rots.len()
    }

    /// Shift all indices by `offset` (for assembling block-diagonal ⊕Q_i).
    pub fn offset(&self, offset: usize) -> GivensSeq {
        GivensSeq {
            rots: self
                .rots
                .iter()
                .map(|g| Givens { i: g.i + offset, j: g.j + offset, ..*g })
                .collect(),
        }
    }

    /// Remap indices through `map` (local-to-global index translation).
    pub fn remap(&self, map: &[usize]) -> GivensSeq {
        GivensSeq {
            rots: self
                .rots
                .iter()
                .map(|g| Givens { i: map[g.i], j: map[g.j], ..*g })
                .collect(),
        }
    }

    pub fn extend(&mut self, other: GivensSeq) {
        self.rots.extend(other.rots);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::la::blas::{conjugate, gemm_tn};
    use crate::util::Rng;

    #[test]
    fn jacobi_zeroes_offdiag() {
        let (aii, aij, ajj) = (2.0, 1.5, -1.0);
        let g = Givens::jacobi(0, 1, aii, aij, ajj);
        let mut a = Mat::from_rows(&[&[aii, aij], &[aij, ajj]]);
        g.conjugate_sym(&mut a);
        assert!(a[(0, 1)].abs() < 1e-14);
        assert!(a[(1, 0)].abs() < 1e-14);
        // trace preserved
        assert!((a[(0, 0)] + a[(1, 1)] - (aii + ajj)).abs() < 1e-12);
    }

    #[test]
    fn rotation_is_orthogonal() {
        let g = Givens::jacobi(1, 3, 1.0, 0.7, -0.2);
        let d = g.to_dense(5);
        let dtd = gemm_tn(&d, &d);
        assert!(dtd.sub(&Mat::eye(5)).max_abs() < 1e-14);
    }

    #[test]
    fn vec_apply_matches_dense() {
        let mut rng = Rng::new(1);
        let g = Givens::jacobi(0, 4, 1.0, -0.4, 2.0);
        let x: Vec<f64> = rng.normal_vec(6);
        let mut xv = x.clone();
        g.apply_vec(&mut xv);
        let d = g.to_dense(6);
        let expected = crate::la::blas::gemv(&d, &x);
        for i in 0..6 {
            assert!((xv[i] - expected[i]).abs() < 1e-12);
        }
        // transpose undoes
        g.apply_vec_t(&mut xv);
        for i in 0..6 {
            assert!((xv[i] - x[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn conjugate_sym_matches_dense() {
        let mut rng = Rng::new(2);
        let mut a = Mat::from_fn(6, 6, |_, _| rng.normal());
        a.symmetrize();
        let g = Givens::jacobi(2, 5, a[(2, 2)], a[(2, 5)], a[(5, 5)]);
        let mut fast = a.clone();
        g.conjugate_sym(&mut fast);
        let d = g.to_dense(6);
        // G A Gᵀ = conjugate(Gᵀ, A) since conjugate(Q,A) = QᵀAQ
        let slow = conjugate(&d.transpose(), &a);
        assert!(fast.sub(&slow).max_abs() < 1e-12);
        assert!(fast.asymmetry() < 1e-12);
    }

    #[test]
    fn seq_apply_and_inverse() {
        let mut rng = Rng::new(3);
        let mut seq = GivensSeq::new();
        for k in 0..10 {
            let i = k % 5;
            let j = (k + 2) % 5;
            if i != j {
                seq.push(Givens::jacobi(i.min(j), i.max(j), rng.normal(), rng.normal(), rng.normal()));
            }
        }
        let x = rng.normal_vec(5);
        let mut y = x.clone();
        seq.apply_vec(&mut y);
        seq.apply_vec_t(&mut y);
        for i in 0..5 {
            assert!((y[i] - x[i]).abs() < 1e-12);
        }
        // dense consistency
        let q = seq.to_dense(5);
        let qtq = gemm_tn(&q, &q);
        assert!(qtq.sub(&Mat::eye(5)).max_abs() < 1e-12);
    }

    #[test]
    fn seq_conjugation_matches_dense() {
        let mut rng = Rng::new(4);
        let mut a = Mat::from_fn(7, 7, |_, _| rng.normal());
        a.symmetrize();
        let mut seq = GivensSeq::new();
        for _ in 0..6 {
            let i = rng.below(7);
            let mut j = rng.below(7);
            while j == i {
                j = rng.below(7);
            }
            seq.push(Givens::jacobi(i, j, rng.normal(), rng.normal(), rng.normal()));
        }
        let mut fast = a.clone();
        seq.conjugate_sym(&mut fast);
        let q = seq.to_dense(7);
        let slow = conjugate(&q.transpose(), &a);
        assert!(fast.sub(&slow).max_abs() < 1e-11);
    }

    #[test]
    fn remap_and_offset() {
        let g = Givens { i: 0, j: 1, c: 0.6, s: 0.8 };
        let mut seq = GivensSeq::new();
        seq.push(g);
        let off = seq.offset(10);
        assert_eq!(off.rots[0].i, 10);
        assert_eq!(off.rots[0].j, 11);
        let re = seq.remap(&[5, 9]);
        assert_eq!(re.rots[0].i, 5);
        assert_eq!(re.rots[0].j, 9);
        assert_eq!(seq.stored_reals(), 2);
    }
}
