//! Symmetric eigendecomposition.
//!
//! Default path: Householder tridiagonalization (`tred2`) + implicit-shift
//! QL (`tql2`) — the classic EISPACK pair, O(n³) with a small constant
//! (≈20× faster than Jacobi at n = 512; see EXPERIMENTS.md §Perf). The
//! cyclic-Jacobi solver is retained as [`SymEig::jacobi`] and used by the
//! tests as an independent oracle.

use super::blas::gemm;
use super::dense::Mat;

/// Eigendecomposition A = V diag(λ) Vᵀ with eigenvalues ascending.
#[derive(Clone, Debug)]
pub struct SymEig {
    /// Eigenvalues, ascending.
    pub values: Vec<f64>,
    /// Columns are eigenvectors (same order as `values`).
    pub vectors: Mat,
}

impl SymEig {
    /// Compute the full EVD of a symmetric matrix (tred2 + tql2).
    pub fn new(a: &Mat) -> SymEig {
        assert!(a.is_square());
        let n = a.rows;
        if n <= 4 {
            // tiny cases: Jacobi is exact and allocation-light
            return SymEig::jacobi(a);
        }
        let mut z = a.clone();
        z.symmetrize();
        let (mut d, mut e) = tred2(&mut z);
        tql2(&mut d, &mut e, &mut z);
        // Sort ascending (tql2 leaves eigenvalues unordered in general).
        let mut idx: Vec<usize> = (0..n).collect();
        idx.sort_by(|&p, &q| d[p].partial_cmp(&d[q]).unwrap());
        let values: Vec<f64> = idx.iter().map(|&p| d[p]).collect();
        let mut vectors = Mat::zeros(n, n);
        for (newj, &oldj) in idx.iter().enumerate() {
            for i in 0..n {
                vectors.set(i, newj, z.at(i, oldj));
            }
        }
        SymEig { values, vectors }
    }

    /// Cyclic-Jacobi EVD (slow, very accurate) — test oracle.
    pub fn jacobi(a: &Mat) -> SymEig {
        assert!(a.is_square());
        let n = a.rows;
        let mut m = a.clone();
        m.symmetrize();
        let mut v = Mat::eye(n);

        if n <= 1 {
            return SymEig { values: m.diagonal(), vectors: v };
        }

        let max_sweeps = 64;
        for _sweep in 0..max_sweeps {
            let mut off = 0.0f64;
            for i in 0..n {
                for j in (i + 1)..n {
                    off = off.max(m.at(i, j).abs());
                }
            }
            let scale = m.max_abs().max(1e-300);
            if off <= 1e-14 * scale {
                break;
            }
            for p in 0..n {
                for q in (p + 1)..n {
                    let apq = m.at(p, q);
                    if apq.abs() <= 1e-300 {
                        continue;
                    }
                    let app = m.at(p, p);
                    let aqq = m.at(q, q);
                    // Stable rotation computation (Golub & Van Loan 8.4).
                    let theta = (aqq - app) / (2.0 * apq);
                    let t = if theta >= 0.0 {
                        1.0 / (theta + (1.0 + theta * theta).sqrt())
                    } else {
                        1.0 / (theta - (1.0 + theta * theta).sqrt())
                    };
                    let c = 1.0 / (1.0 + t * t).sqrt();
                    let s = t * c;
                    rotate_sym(&mut m, p, q, c, s);
                    rotate_cols(&mut v, p, q, c, s);
                }
            }
        }

        // Extract and sort ascending.
        let mut idx: Vec<usize> = (0..n).collect();
        let d = m.diagonal();
        idx.sort_by(|&a, &b| d[a].partial_cmp(&d[b]).unwrap());
        let values: Vec<f64> = idx.iter().map(|&i| d[i]).collect();
        let mut vectors = Mat::zeros(n, n);
        for (newj, &oldj) in idx.iter().enumerate() {
            for i in 0..n {
                vectors.set(i, newj, v.at(i, oldj));
            }
        }
        SymEig { values, vectors }
    }

    /// Apply a scalar function to the spectrum: f(A) = V f(Λ) Vᵀ.
    pub fn apply_fn(&self, f: impl Fn(f64) -> f64) -> Mat {
        let n = self.values.len();
        let mut scaled = self.vectors.clone(); // V f(Λ)
        for j in 0..n {
            let fj = f(self.values[j]);
            for i in 0..n {
                let v = scaled.at(i, j);
                scaled.set(i, j, v * fj);
            }
        }
        // (V f(Λ)) Vᵀ
        gemm(&scaled, &self.vectors.transpose())
    }

    /// Reconstruct A (for tests).
    pub fn reconstruct(&self) -> Mat {
        self.apply_fn(|x| x)
    }

    /// The largest magnitude eigenvalue.
    pub fn spectral_radius(&self) -> f64 {
        self.values.iter().fold(0.0f64, |m, &x| m.max(x.abs()))
    }
}

/// Symmetric two-sided Givens rotation on rows/cols p, q:
/// M ← JᵀMJ with J the identity plus [[c, s], [-s, c]] in the (p, q) plane.
#[inline]
fn rotate_sym(m: &mut Mat, p: usize, q: usize, c: f64, s: f64) {
    let n = m.rows;
    for k in 0..n {
        if k != p && k != q {
            let mkp = m.at(k, p);
            let mkq = m.at(k, q);
            let np = c * mkp - s * mkq;
            let nq = s * mkp + c * mkq;
            m.set(k, p, np);
            m.set(p, k, np);
            m.set(k, q, nq);
            m.set(q, k, nq);
        }
    }
    let app = m.at(p, p);
    let aqq = m.at(q, q);
    let apq = m.at(p, q);
    let new_pp = c * c * app - 2.0 * s * c * apq + s * s * aqq;
    let new_qq = s * s * app + 2.0 * s * c * apq + c * c * aqq;
    m.set(p, p, new_pp);
    m.set(q, q, new_qq);
    m.set(p, q, 0.0);
    m.set(q, p, 0.0);
}

/// Right-multiply V by the rotation (update eigenvector columns p, q).
#[inline]
fn rotate_cols(v: &mut Mat, p: usize, q: usize, c: f64, s: f64) {
    for k in 0..v.rows {
        let vkp = v.at(k, p);
        let vkq = v.at(k, q);
        v.set(k, p, c * vkp - s * vkq);
        v.set(k, q, s * vkp + c * vkq);
    }
}

/// Householder reduction of a real symmetric matrix to tridiagonal form
/// (EISPACK `tred2`). On return `z` holds the accumulated orthogonal
/// transform Q (A = Q T Qᵀ); returns (diagonal d, subdiagonal e).
fn tred2(z: &mut Mat) -> (Vec<f64>, Vec<f64>) {
    let n = z.rows;
    let mut d = vec![0.0; n];
    let mut e = vec![0.0; n];
    for i in (1..n).rev() {
        let l = i - 1;
        let mut h = 0.0;
        if l > 0 {
            let mut scale = 0.0;
            for k in 0..=l {
                scale += z.at(i, k).abs();
            }
            if scale == 0.0 {
                e[i] = z.at(i, l);
            } else {
                for k in 0..=l {
                    let v = z.at(i, k) / scale;
                    z.set(i, k, v);
                    h += v * v;
                }
                let mut f = z.at(i, l);
                let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                e[i] = scale * g;
                h -= f * g;
                z.set(i, l, f - g);
                f = 0.0;
                for j in 0..=l {
                    z.set(j, i, z.at(i, j) / h);
                    let mut g = 0.0;
                    for k in 0..=j {
                        g += z.at(j, k) * z.at(i, k);
                    }
                    for k in (j + 1)..=l {
                        g += z.at(k, j) * z.at(i, k);
                    }
                    e[j] = g / h;
                    f += e[j] * z.at(i, j);
                }
                let hh = f / (h + h);
                for j in 0..=l {
                    let fj = z.at(i, j);
                    let gj = e[j] - hh * fj;
                    e[j] = gj;
                    for k in 0..=j {
                        let v = z.at(j, k) - (fj * e[k] + gj * z.at(i, k));
                        z.set(j, k, v);
                    }
                }
            }
        } else {
            e[i] = z.at(i, l);
        }
        d[i] = h;
    }
    d[0] = 0.0;
    e[0] = 0.0;
    for i in 0..n {
        let l = i;
        if d[i] != 0.0 {
            for j in 0..l {
                let mut g = 0.0;
                for k in 0..l {
                    g += z.at(i, k) * z.at(k, j);
                }
                for k in 0..l {
                    let v = z.at(k, j) - g * z.at(k, i);
                    z.set(k, j, v);
                }
            }
        }
        d[i] = z.at(i, i);
        z.set(i, i, 1.0);
        for j in 0..l {
            z.set(j, i, 0.0);
            z.set(i, j, 0.0);
        }
    }
    (d, e)
}

/// Implicit-shift QL iteration on a symmetric tridiagonal matrix
/// (EISPACK `tql2`), accumulating eigenvectors into `z` (columns).
fn tql2(d: &mut [f64], e: &mut [f64], z: &mut Mat) {
    let n = d.len();
    if n == 0 {
        return;
    }
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;
    for l in 0..n {
        let mut iter = 0;
        loop {
            // Find a small subdiagonal element.
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            if iter > 50 {
                break; // fail soft: values are still usable to ~eps·‖A‖
            }
            // Form the implicit shift.
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = g.hypot(1.0);
            let sign_r = if g >= 0.0 { r } else { -r };
            g = d[m] - d[l] + e[l] / (g + sign_r);
            let (mut s, mut c) = (1.0f64, 1.0f64);
            let mut p = 0.0f64;
            for i in (l..m).rev() {
                let mut f = s * e[i];
                let b = c * e[i];
                r = f.hypot(g);
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                // Accumulate the rotation in the eigenvector matrix.
                for k in 0..n {
                    f = z.at(k, i + 1);
                    let v = z.at(k, i);
                    z.set(k, i + 1, s * v + c * f);
                    z.set(k, i, c * v - s * f);
                }
            }
            if r == 0.0 && m > l {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::la::blas::{gemm_nt, gemm_tn};
    use crate::util::Rng;

    fn randsym(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let mut a = Mat::from_fn(n, n, |_, _| rng.normal());
        a.symmetrize();
        a
    }

    #[test]
    fn diagonal_matrix() {
        let e = SymEig::new(&Mat::diag(&[3.0, 1.0, 2.0]));
        assert!((e.values[0] - 1.0).abs() < 1e-12);
        assert!((e.values[1] - 2.0).abs() < 1e-12);
        assert!((e.values[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 1 and 3.
        let e = SymEig::new(&Mat::from_rows(&[&[2.0, 1.0], &[1.0, 2.0]]));
        assert!((e.values[0] - 1.0).abs() < 1e-12);
        assert!((e.values[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn reconstruction_and_orthogonality() {
        for n in [1, 2, 5, 20, 40] {
            let a = randsym(n, n as u64);
            let e = SymEig::new(&a);
            let rec = e.reconstruct();
            assert!(rec.sub(&a).max_abs() < 1e-9, "n={n}");
            let vtv = gemm_tn(&e.vectors, &e.vectors);
            assert!(vtv.sub(&Mat::eye(n)).max_abs() < 1e-10, "n={n}");
        }
    }

    #[test]
    fn values_ascending() {
        let a = randsym(15, 99);
        let e = SymEig::new(&a);
        for w in e.values.windows(2) {
            assert!(w[0] <= w[1] + 1e-12);
        }
    }

    #[test]
    fn trace_and_det_invariants() {
        let a = randsym(12, 5);
        let e = SymEig::new(&a);
        let tr: f64 = a.diagonal().iter().sum();
        let tr_e: f64 = e.values.iter().sum();
        assert!((tr - tr_e).abs() < 1e-9);
    }

    #[test]
    fn apply_fn_inverse() {
        let mut rng = Rng::new(77);
        let b = Mat::from_fn(10, 12, |_, _| rng.normal());
        let mut a = gemm_nt(&b, &b);
        a.add_diag(1.0); // spd
        let e = SymEig::new(&a);
        let inv = e.apply_fn(|x| 1.0 / x);
        let prod = gemm(&a, &inv);
        assert!(prod.sub(&Mat::eye(10)).max_abs() < 1e-8);
    }

    #[test]
    fn tql2_matches_jacobi_oracle() {
        for n in [5, 8, 33, 64] {
            let a = randsym(n, 1000 + n as u64);
            let fast = SymEig::new(&a);
            let oracle = SymEig::jacobi(&a);
            for (x, y) in fast.values.iter().zip(&oracle.values) {
                assert!((x - y).abs() < 1e-8 * y.abs().max(1.0), "n={n}: {x} vs {y}");
            }
            // reconstruction through the fast path
            assert!(fast.reconstruct().sub(&a).max_abs() < 1e-9, "n={n}");
            let vtv = gemm_tn(&fast.vectors, &fast.vectors);
            assert!(vtv.sub(&Mat::eye(n)).max_abs() < 1e-9, "n={n}");
        }
    }

    #[test]
    fn large_matrix_evd_sane() {
        let a = randsym(200, 7);
        let e = SymEig::new(&a);
        assert!(e.reconstruct().sub(&a).max_abs() < 1e-8);
        let tr: f64 = a.diagonal().iter().sum();
        assert!((e.values.iter().sum::<f64>() - tr).abs() < 1e-7);
    }

    #[test]
    fn apply_fn_exp_of_zero_is_identity() {
        let z = Mat::zeros(4, 4);
        let e = SymEig::new(&z);
        let ex = e.apply_fn(f64::exp);
        assert!(ex.sub(&Mat::eye(4)).max_abs() < 1e-12);
    }
}
