//! Dense and sparse linear algebra substrate (no external BLAS/LAPACK).
//!
//! * [`dense::Mat`] — row-major matrix container
//! * [`blas`] — GEMM/SYRK/GEMV compute kernels
//! * [`chol`] — Cholesky (Full-GP baseline, Nyström inner solves)
//! * [`qr`] — Householder QR (SPCA compressor)
//! * [`evd`] — symmetric Jacobi eigensolver (Prop. 7 core EVDs)
//! * [`lu`] — partially-pivoted LU (Schur complement block)
//! * [`givens`] — Givens rotations / sequences (MMF factors)
//! * [`sparse`] — CSR + graph Laplacians (§4 diffusion kernels)
//! * [`stats`] — means/variances/standardization

pub mod blas;
pub mod chol;
pub mod dense;
pub mod evd;
pub mod givens;
pub mod lu;
pub mod qr;
pub mod sparse;
pub mod stats;

pub use blas::{
    axpy, dot, gemm, gemm_nt, gemm_tn, gemv, gemv_t, norm2, scale_rows, simd_level, syrk_aat,
    syrk_ata, SimdLevel,
};
pub use chol::{solve_lower_mat, solve_lower_t_mat, Chol};
pub use dense::Mat;
pub use evd::SymEig;
pub use givens::{Givens, GivensSeq};
pub use lu::Lu;
pub use qr::Qr;
pub use sparse::{Csr, Graph};
