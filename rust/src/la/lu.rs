//! LU decomposition with partial pivoting.
//!
//! Used for the small p×p "D block" inversion in the MKA-GP Schur-complement
//! predictor (§4.1 of the paper) where the matrix is symmetric but may be
//! only near-definite, and as a general-purpose dense solver in tests.

use super::dense::Mat;
use crate::error::{Error, Result};

/// PA = LU factorization with partial pivoting.
#[derive(Clone, Debug)]
pub struct Lu {
    /// Combined LU storage: unit-lower below diagonal, U on and above.
    lu: Mat,
    /// Row permutation: row i of the factored matrix is row piv[i] of A.
    piv: Vec<usize>,
    /// Sign of the permutation (for determinants).
    sign: f64,
}

impl Lu {
    pub fn new(a: &Mat) -> Result<Lu> {
        assert!(a.is_square());
        let n = a.rows;
        let mut lu = a.clone();
        let mut piv: Vec<usize> = (0..n).collect();
        let mut sign = 1.0;
        for k in 0..n {
            // Pivot search.
            let mut p = k;
            let mut best = lu.at(k, k).abs();
            for i in (k + 1)..n {
                let v = lu.at(i, k).abs();
                if v > best {
                    best = v;
                    p = i;
                }
            }
            if best < 1e-300 {
                return Err(Error::Linalg(format!("LU: singular at column {k}")));
            }
            if p != k {
                // swap rows p, k
                for j in 0..n {
                    let t = lu.at(k, j);
                    lu.set(k, j, lu.at(p, j));
                    lu.set(p, j, t);
                }
                piv.swap(k, p);
                sign = -sign;
            }
            let pivot = lu.at(k, k);
            for i in (k + 1)..n {
                let m = lu.at(i, k) / pivot;
                lu.set(i, k, m);
                if m != 0.0 {
                    for j in (k + 1)..n {
                        let v = lu.at(i, j) - m * lu.at(k, j);
                        lu.set(i, j, v);
                    }
                }
            }
        }
        Ok(Lu { lu, piv, sign })
    }

    /// Solve A x = b.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.lu.rows;
        assert_eq!(b.len(), n);
        // Apply permutation.
        let mut x: Vec<f64> = self.piv.iter().map(|&i| b[i]).collect();
        // Forward: L y = Pb (unit diagonal).
        for i in 1..n {
            let mut s = x[i];
            let row = self.lu.row(i);
            for j in 0..i {
                s -= row[j] * x[j];
            }
            x[i] = s;
        }
        // Backward: U x = y.
        for i in (0..n).rev() {
            let mut s = x[i];
            let row = self.lu.row(i);
            for j in (i + 1)..n {
                s -= row[j] * x[j];
            }
            x[i] = s / row[i];
        }
        x
    }

    /// Explicit inverse.
    pub fn inverse(&self) -> Mat {
        let n = self.lu.rows;
        let mut inv = Mat::zeros(n, n);
        let mut e = vec![0.0; n];
        for j in 0..n {
            e[j] = 1.0;
            let x = self.solve(&e);
            for i in 0..n {
                inv.set(i, j, x[i]);
            }
            e[j] = 0.0;
        }
        inv
    }

    /// det(A) = sign · Π U_ii.
    pub fn det(&self) -> f64 {
        self.sign * self.lu.diagonal().iter().product::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::la::blas::{gemm, gemv};
    use crate::util::Rng;

    fn randm(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(n, n, |_, _| rng.normal())
    }

    #[test]
    fn solve_recovers() {
        let a = randm(12, 1);
        let lu = Lu::new(&a).unwrap();
        let mut rng = Rng::new(2);
        let x_true = rng.normal_vec(12);
        let b = gemv(&a, &x_true);
        let x = lu.solve(&b);
        for i in 0..12 {
            assert!((x[i] - x_true[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn inverse_works() {
        let a = randm(9, 3);
        let inv = Lu::new(&a).unwrap().inverse();
        assert!(gemm(&a, &inv).sub(&Mat::eye(9)).max_abs() < 1e-8);
    }

    #[test]
    fn det_known() {
        // det([[1,2],[3,4]]) = -2
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let lu = Lu::new(&a).unwrap();
        assert!((lu.det() + 2.0).abs() < 1e-12);
    }

    #[test]
    fn det_of_permutation_needs_pivots() {
        // [[0,1],[1,0]] requires pivoting; det = -1.
        let a = Mat::from_rows(&[&[0.0, 1.0], &[1.0, 0.0]]);
        let lu = Lu::new(&a).unwrap();
        assert!((lu.det() + 1.0).abs() < 1e-12);
    }

    #[test]
    fn singular_detected() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 4.0]]);
        assert!(Lu::new(&a).is_err());
    }
}
