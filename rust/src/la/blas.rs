//! BLAS-style compute kernels (no external BLAS in the offline build).
//!
//! `gemm` is a cache-blocked, register-tiled triple loop; `syrk` exploits
//! symmetry (this is the AᵀA product that dominates MMF compression —
//! Proposition 4's `m³` term — so it is one of the L3 hot paths; the same
//! product is also available through the AOT'd XLA artifact, see
//! `runtime::engine`).

use super::dense::Mat;

/// y ← A x.
pub fn gemv(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols, x.len());
    let mut y = vec![0.0; a.rows];
    gemv_into(a, x, &mut y);
    y
}

/// y ← A x (no allocation).
pub fn gemv_into(a: &Mat, x: &[f64], y: &mut [f64]) {
    assert_eq!(a.cols, x.len());
    assert_eq!(a.rows, y.len());
    for i in 0..a.rows {
        y[i] = dot(a.row(i), x);
    }
}

/// y ← Aᵀ x.
pub fn gemv_t(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows, x.len());
    let mut y = vec![0.0; a.cols];
    for i in 0..a.rows {
        let xi = x[i];
        if xi == 0.0 {
            continue;
        }
        let row = a.row(i);
        for j in 0..a.cols {
            y[j] += xi * row[j];
        }
    }
    y
}

/// Dot product with 4-way unrolling (auto-vectorizes well).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for k in 0..chunks {
        let i = 4 * k;
        unsafe {
            s0 += a.get_unchecked(i) * b.get_unchecked(i);
            s1 += a.get_unchecked(i + 1) * b.get_unchecked(i + 1);
            s2 += a.get_unchecked(i + 2) * b.get_unchecked(i + 2);
            s3 += a.get_unchecked(i + 3) * b.get_unchecked(i + 3);
        }
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in 4 * chunks..n {
        s += a[i] * b[i];
    }
    s
}

/// y ← y + a·x.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

pub fn norm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// C ← A B, cache-blocked i-k-j loop order (B rows stream through cache).
pub fn gemm(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "gemm shape mismatch {}x{} * {}x{}", a.rows, a.cols, b.rows, b.cols);
    let mut c = Mat::zeros(a.rows, b.cols);
    gemm_acc(1.0, a, b, &mut c);
    c
}

/// C ← C + alpha·A·B. The workhorse: blocked over k and j with an i-k-j
/// inner structure; the innermost loop is an axpy over a row of B which
/// vectorizes.
pub fn gemm_acc(alpha: f64, a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols, b.rows);
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, b.cols);
    const KB: usize = 128; // k-block: keeps a strip of B in L2
    const JB: usize = 512; // j-block: row segments fit L1

    let (m, k, n) = (a.rows, a.cols, b.cols);
    for kb in (0..k).step_by(KB) {
        let kend = (kb + KB).min(k);
        for jb in (0..n).step_by(JB) {
            let jend = (jb + JB).min(n);
            for i in 0..m {
                let arow = a.row(i);
                let crow = &mut c.row_mut(i)[jb..jend];
                for kk in kb..kend {
                    let aik = alpha * arow[kk];
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = &b.row(kk)[jb..jend];
                    for (cj, bj) in crow.iter_mut().zip(brow) {
                        *cj += aik * bj;
                    }
                }
            }
        }
    }
}

/// C ← Aᵀ B  (m×k)ᵀ·(m×n): accumulate outer products of rows of A and B.
pub fn gemm_tn(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows, b.rows);
    let mut c = Mat::zeros(a.cols, b.cols);
    for i in 0..a.rows {
        let arow = a.row(i);
        let brow = b.row(i);
        for p in 0..a.cols {
            let api = arow[p];
            if api == 0.0 {
                continue;
            }
            let crow = c.row_mut(p);
            for q in 0..b.cols {
                crow[q] += api * brow[q];
            }
        }
    }
    c
}

/// C ← A Bᵀ — dot products of rows; very cache friendly.
pub fn gemm_nt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.cols);
    let mut c = Mat::zeros(a.rows, b.rows);
    for i in 0..a.rows {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for j in 0..b.rows {
            crow[j] = dot(arow, b.row(j));
        }
    }
    c
}

/// A ← diag(s) · A: scale row i by s[i]. Row-major, so each scaling is one
/// contiguous pass — this is how the blocked cascade applies f(D_ℓ) to a
/// whole wavelet block at once.
pub fn scale_rows(a: &mut Mat, s: &[f64]) {
    assert_eq!(a.rows, s.len());
    for (i, &si) in s.iter().enumerate() {
        for v in a.row_mut(i) {
            *v *= si;
        }
    }
}

/// G ← AᵀA (symmetric rank-k update). Computes only the upper triangle and
/// mirrors it. This is MMF's dominant cost; see also the XLA artifact path.
pub fn syrk_ata(a: &Mat) -> Mat {
    let n = a.cols;
    let mut g = Mat::zeros(n, n);
    // Accumulate row outer-products, upper triangle only.
    for i in 0..a.rows {
        let row = a.row(i);
        for p in 0..n {
            let v = row[p];
            if v == 0.0 {
                continue;
            }
            let grow = g.row_mut(p);
            for q in p..n {
                grow[q] += v * row[q];
            }
        }
    }
    // Mirror.
    for p in 0..n {
        for q in (p + 1)..n {
            let v = g[(p, q)];
            g[(q, p)] = v;
        }
    }
    g
}

/// G ← A Aᵀ for symmetric-needed products over rows.
pub fn syrk_aat(a: &Mat) -> Mat {
    let n = a.rows;
    let mut g = Mat::zeros(n, n);
    for i in 0..n {
        let ri = a.row(i);
        for j in i..n {
            let v = dot(ri, a.row(j));
            g[(i, j)] = v;
            g[(j, i)] = v;
        }
    }
    g
}

/// Conjugation QᵀAQ for dense Q (test helper / SPCA path).
pub fn conjugate(q: &Mat, a: &Mat) -> Mat {
    // (QᵀA)Q
    let qta = gemm_tn(q, a);
    gemm(&qta, q)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randm(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(r, c, |_, _| rng.normal())
    }

    /// Naive reference gemm.
    fn gemm_ref(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a[(i, k)] * b[(k, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    #[test]
    fn gemm_matches_reference() {
        for (m, k, n) in [(1, 1, 1), (3, 4, 5), (17, 31, 13), (64, 70, 65)] {
            let a = randm(m, k, 1);
            let b = randm(k, n, 2);
            let c = gemm(&a, &b);
            let r = gemm_ref(&a, &b);
            assert!(c.sub(&r).max_abs() < 1e-10, "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn gemm_tn_nt_match() {
        let a = randm(23, 11, 3);
        let b = randm(23, 17, 4);
        let c = gemm_tn(&a, &b);
        let r = gemm_ref(&a.transpose(), &b);
        assert!(c.sub(&r).max_abs() < 1e-10);

        let b2 = randm(19, 11, 5);
        let c2 = gemm_nt(&a, &b2);
        let r2 = gemm_ref(&a, &b2.transpose());
        assert!(c2.sub(&r2).max_abs() < 1e-10);
    }

    #[test]
    fn syrk_matches_gemm() {
        let a = randm(29, 13, 6);
        let g = syrk_ata(&a);
        let r = gemm_ref(&a.transpose(), &a);
        assert!(g.sub(&r).max_abs() < 1e-10);
        assert!(g.asymmetry() == 0.0);

        let g2 = syrk_aat(&a);
        let r2 = gemm_ref(&a, &a.transpose());
        assert!(g2.sub(&r2).max_abs() < 1e-10);
    }

    #[test]
    fn gemv_variants() {
        let a = randm(9, 7, 7);
        let x: Vec<f64> = (0..7).map(|i| i as f64).collect();
        let y = gemv(&a, &x);
        let r = gemm_ref(&a, &Mat::from_vec(7, 1, x.clone()));
        for i in 0..9 {
            assert!((y[i] - r[(i, 0)]).abs() < 1e-12);
        }
        let xt: Vec<f64> = (0..9).map(|i| (i as f64) - 4.0).collect();
        let yt = gemv_t(&a, &xt);
        let rt = gemm_ref(&a.transpose(), &Mat::from_vec(9, 1, xt));
        for j in 0..7 {
            assert!((yt[j] - rt[(j, 0)]).abs() < 1e-12);
        }
    }

    #[test]
    fn dot_axpy_norm() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [5.0, 4.0, 3.0, 2.0, 1.0];
        assert_eq!(dot(&a, &b), 35.0);
        let mut y = vec![1.0; 5];
        axpy(2.0, &a, &mut y);
        assert_eq!(y, vec![3.0, 5.0, 7.0, 9.0, 11.0]);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
    }

    #[test]
    fn conjugation_by_identity() {
        let a = {
            let mut a = randm(6, 6, 8);
            a.symmetrize();
            a
        };
        let q = Mat::eye(6);
        let c = conjugate(&q, &a);
        assert!(c.sub(&a).max_abs() < 1e-12);
    }
}
