//! BLAS-style compute kernels (no external BLAS in the offline build).
//!
//! `gemm` is a cache-blocked, register-tiled triple loop; `syrk` exploits
//! symmetry (this is the AᵀA product that dominates MMF compression —
//! Proposition 4's `m³` term — so it is one of the L3 hot paths; the same
//! product is also available through the AOT'd XLA artifact, see
//! `runtime::engine`).
//!
//! Every O(n³) kernel here is **row-band parallel** over the shared pool
//! (`crate::par`): the output rows are split into contiguous bands and
//! each band runs the *same* loop nest the serial code runs, so for every
//! output element the floating-point accumulation sequence is identical
//! at any thread count — results are bit-for-bit deterministic. Small
//! products (below [`PAR_MIN_FLOPS`]) stay serial to avoid dispatch
//! overhead. The `*_mt` variants take an explicit thread-count cap; the
//! classic names use the process-wide default (`par::threads()`).

use super::dense::Mat;
use crate::par::{self, SendPtr};

/// Below this many fused multiply-adds a parallel split is all overhead.
pub const PAR_MIN_FLOPS: usize = 1 << 21;

/// Shard count for a banded kernel: serial unless the work and the row
/// count justify splitting.
fn par_shards(rows: usize, flops: usize, threads: usize) -> usize {
    if threads <= 1 || rows < 2 || flops < PAR_MIN_FLOPS {
        1
    } else {
        threads.min(rows)
    }
}

/// Reconstruct the mutable row band [lo, hi) of a row-major buffer.
///
/// # Safety
/// Caller guarantees bands are disjoint across concurrent tasks and the
/// buffer outlives the parallel region.
unsafe fn band_mut<'a>(ptr: SendPtr<f64>, cols: usize, lo: usize, hi: usize) -> &'a mut [f64] {
    std::slice::from_raw_parts_mut(ptr.ptr().add(lo * cols), (hi - lo) * cols)
}

/// y ← A x.
pub fn gemv(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols, x.len());
    let mut y = vec![0.0; a.rows];
    gemv_into(a, x, &mut y);
    y
}

/// y ← A x (no allocation).
pub fn gemv_into(a: &Mat, x: &[f64], y: &mut [f64]) {
    assert_eq!(a.cols, x.len());
    assert_eq!(a.rows, y.len());
    for i in 0..a.rows {
        y[i] = dot(a.row(i), x);
    }
}

/// y ← Aᵀ x.
pub fn gemv_t(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows, x.len());
    let mut y = vec![0.0; a.cols];
    for i in 0..a.rows {
        let xi = x[i];
        if xi == 0.0 {
            continue;
        }
        let row = a.row(i);
        for j in 0..a.cols {
            y[j] += xi * row[j];
        }
    }
    y
}

/// Dot product with 4-way unrolling (auto-vectorizes well).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for k in 0..chunks {
        let i = 4 * k;
        unsafe {
            s0 += a.get_unchecked(i) * b.get_unchecked(i);
            s1 += a.get_unchecked(i + 1) * b.get_unchecked(i + 1);
            s2 += a.get_unchecked(i + 2) * b.get_unchecked(i + 2);
            s3 += a.get_unchecked(i + 3) * b.get_unchecked(i + 3);
        }
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in 4 * chunks..n {
        s += a[i] * b[i];
    }
    s
}

/// y ← y + a·x.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

pub fn norm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// C ← A B, cache-blocked i-k-j loop order (B rows stream through cache).
pub fn gemm(a: &Mat, b: &Mat) -> Mat {
    gemm_mt(a, b, par::threads())
}

/// [`gemm`] with an explicit thread-count cap (bit-identical at any cap).
pub fn gemm_mt(a: &Mat, b: &Mat, threads: usize) -> Mat {
    assert_eq!(a.cols, b.rows, "gemm shape mismatch {}x{} * {}x{}", a.rows, a.cols, b.rows, b.cols);
    let mut c = Mat::zeros(a.rows, b.cols);
    gemm_acc_mt(1.0, a, b, &mut c, threads);
    c
}

/// C ← C + alpha·A·B. The workhorse: blocked over k and j with an i-k-j
/// inner structure; the innermost loop is an axpy over a row of B which
/// vectorizes. Parallel over bands of C's rows — each row's accumulation
/// order is independent of the banding, so any thread count gives the
/// same bits.
pub fn gemm_acc(alpha: f64, a: &Mat, b: &Mat, c: &mut Mat) {
    gemm_acc_mt(alpha, a, b, c, par::threads());
}

/// [`gemm_acc`] with an explicit thread-count cap.
pub fn gemm_acc_mt(alpha: f64, a: &Mat, b: &Mat, c: &mut Mat, threads: usize) {
    assert_eq!(a.cols, b.rows);
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, b.cols);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let shards = par_shards(m, m * k * n, threads);
    if shards <= 1 {
        gemm_acc_rows(alpha, a, b, &mut c.data, 0, m);
        return;
    }
    let cols = c.cols;
    let cptr = SendPtr::new(c.data.as_mut_ptr());
    par::for_ranges(m, shards, move |_, lo, hi| {
        // SAFETY: bands are disjoint row ranges of C.
        let band = unsafe { band_mut(cptr, cols, lo, hi) };
        gemm_acc_rows(alpha, a, b, band, lo, hi);
    });
}

/// Band kernel for [`gemm_acc`]: rows [i0, i1) of C, `cband` holding
/// exactly those rows.
fn gemm_acc_rows(alpha: f64, a: &Mat, b: &Mat, cband: &mut [f64], i0: usize, i1: usize) {
    const KB: usize = 128; // k-block: keeps a strip of B in L2
    const JB: usize = 512; // j-block: row segments fit L1
    let (k, n) = (a.cols, b.cols);
    for kb in (0..k).step_by(KB) {
        let kend = (kb + KB).min(k);
        for jb in (0..n).step_by(JB) {
            let jend = (jb + JB).min(n);
            for i in i0..i1 {
                let arow = a.row(i);
                let crow = &mut cband[(i - i0) * n + jb..(i - i0) * n + jend];
                for kk in kb..kend {
                    let aik = alpha * arow[kk];
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = &b.row(kk)[jb..jend];
                    for (cj, bj) in crow.iter_mut().zip(brow) {
                        *cj += aik * bj;
                    }
                }
            }
        }
    }
}

/// C ← Aᵀ B  (m×k)ᵀ·(m×n): accumulate outer products of rows of A and B.
/// Parallel over bands of C's rows (columns of A).
pub fn gemm_tn(a: &Mat, b: &Mat) -> Mat {
    gemm_tn_mt(a, b, par::threads())
}

/// [`gemm_tn`] with an explicit thread-count cap.
pub fn gemm_tn_mt(a: &Mat, b: &Mat, threads: usize) -> Mat {
    assert_eq!(a.rows, b.rows);
    let mut c = Mat::zeros(a.cols, b.cols);
    let shards = par_shards(a.cols, a.rows * a.cols * b.cols, threads);
    if shards <= 1 {
        gemm_tn_rows(a, b, &mut c.data, 0, a.cols);
        return c;
    }
    let cols = c.cols;
    let cptr = SendPtr::new(c.data.as_mut_ptr());
    par::for_ranges(a.cols, shards, move |_, lo, hi| {
        // SAFETY: bands are disjoint row ranges of C.
        let band = unsafe { band_mut(cptr, cols, lo, hi) };
        gemm_tn_rows(a, b, band, lo, hi);
    });
    c
}

fn gemm_tn_rows(a: &Mat, b: &Mat, cband: &mut [f64], p0: usize, p1: usize) {
    let n = b.cols;
    for i in 0..a.rows {
        let arow = a.row(i);
        let brow = b.row(i);
        for p in p0..p1 {
            let api = arow[p];
            if api == 0.0 {
                continue;
            }
            let crow = &mut cband[(p - p0) * n..(p - p0) * n + n];
            for (cq, bq) in crow.iter_mut().zip(brow) {
                *cq += api * bq;
            }
        }
    }
}

/// C ← A Bᵀ — dot products of rows; very cache friendly. Parallel over
/// bands of C's rows.
pub fn gemm_nt(a: &Mat, b: &Mat) -> Mat {
    gemm_nt_mt(a, b, par::threads())
}

/// [`gemm_nt`] with an explicit thread-count cap.
pub fn gemm_nt_mt(a: &Mat, b: &Mat, threads: usize) -> Mat {
    assert_eq!(a.cols, b.cols);
    let mut c = Mat::zeros(a.rows, b.rows);
    let shards = par_shards(a.rows, a.rows * a.cols * b.rows, threads);
    if shards <= 1 {
        gemm_nt_rows(a, b, &mut c.data, 0, a.rows);
        return c;
    }
    let cols = c.cols;
    let cptr = SendPtr::new(c.data.as_mut_ptr());
    par::for_ranges(a.rows, shards, move |_, lo, hi| {
        // SAFETY: bands are disjoint row ranges of C.
        let band = unsafe { band_mut(cptr, cols, lo, hi) };
        gemm_nt_rows(a, b, band, lo, hi);
    });
    c
}

fn gemm_nt_rows(a: &Mat, b: &Mat, cband: &mut [f64], i0: usize, i1: usize) {
    let n = b.rows;
    for i in i0..i1 {
        let arow = a.row(i);
        let crow = &mut cband[(i - i0) * n..(i - i0) * n + n];
        for j in 0..n {
            crow[j] = dot(arow, b.row(j));
        }
    }
}

/// A ← diag(s) · A: scale row i by s[i]. Row-major, so each scaling is one
/// contiguous pass — this is how the blocked cascade applies f(D_ℓ) to a
/// whole wavelet block at once.
pub fn scale_rows(a: &mut Mat, s: &[f64]) {
    assert_eq!(a.rows, s.len());
    for (i, &si) in s.iter().enumerate() {
        for v in a.row_mut(i) {
            *v *= si;
        }
    }
}

/// G ← AᵀA (symmetric rank-k update). Computes only the upper triangle
/// (banded over G's rows — bands near p = 0 carry more of the triangle,
/// a deliberate trade for keeping the thread cap exact) and mirrors it.
/// This is MMF's dominant cost; see also the XLA artifact path.
pub fn syrk_ata(a: &Mat) -> Mat {
    syrk_ata_mt(a, par::threads())
}

/// [`syrk_ata`] with an explicit thread-count cap.
pub fn syrk_ata_mt(a: &Mat, threads: usize) -> Mat {
    let n = a.cols;
    let mut g = Mat::zeros(n, n);
    let shards = par_shards(n, a.rows * n * n / 2, threads);
    if shards <= 1 {
        syrk_ata_rows(a, &mut g.data, 0, n);
    } else {
        let gptr = SendPtr::new(g.data.as_mut_ptr());
        par::for_ranges(n, shards, move |_, lo, hi| {
            // SAFETY: bands are disjoint row ranges of G.
            let band = unsafe { band_mut(gptr, n, lo, hi) };
            syrk_ata_rows(a, band, lo, hi);
        });
    }
    mirror_upper(&mut g, shards);
    g
}

fn syrk_ata_rows(a: &Mat, gband: &mut [f64], p0: usize, p1: usize) {
    let n = a.cols;
    for i in 0..a.rows {
        let row = a.row(i);
        for p in p0..p1 {
            let v = row[p];
            if v == 0.0 {
                continue;
            }
            let grow = &mut gband[(p - p0) * n..(p - p0) * n + n];
            for q in p..n {
                grow[q] += v * row[q];
            }
        }
    }
}

/// G ← A Aᵀ for symmetric-needed products over rows. Upper triangle banded
/// over G's rows, then mirrored.
pub fn syrk_aat(a: &Mat) -> Mat {
    syrk_aat_mt(a, par::threads())
}

/// [`syrk_aat`] with an explicit thread-count cap.
pub fn syrk_aat_mt(a: &Mat, threads: usize) -> Mat {
    let n = a.rows;
    let mut g = Mat::zeros(n, n);
    let shards = par_shards(n, n * n * a.cols / 2, threads);
    if shards <= 1 {
        syrk_aat_rows(a, &mut g.data, 0, n);
    } else {
        let gptr = SendPtr::new(g.data.as_mut_ptr());
        par::for_ranges(n, shards, move |_, lo, hi| {
            // SAFETY: bands are disjoint row ranges of G.
            let band = unsafe { band_mut(gptr, n, lo, hi) };
            syrk_aat_rows(a, band, lo, hi);
        });
    }
    mirror_upper(&mut g, shards);
    g
}

fn syrk_aat_rows(a: &Mat, gband: &mut [f64], i0: usize, i1: usize) {
    let n = a.rows;
    for i in i0..i1 {
        let ri = a.row(i);
        let grow = &mut gband[(i - i0) * n..(i - i0) * n + n];
        for j in i..n {
            grow[j] = dot(ri, a.row(j));
        }
    }
}

/// Copy the finished upper triangle into the strictly-lower one. Row q of
/// the lower triangle reads only upper-triangle entries, which no task
/// writes during this phase, so banding over rows is race-free.
fn mirror_upper(g: &mut Mat, shards: usize) {
    let n = g.rows;
    if shards <= 1 {
        for p in 0..n {
            for q in (p + 1)..n {
                let v = g[(p, q)];
                g[(q, p)] = v;
            }
        }
        return;
    }
    let gptr = SendPtr::new(g.data.as_mut_ptr());
    par::for_ranges(n, shards, move |_, lo, hi| {
        for q in lo..hi {
            for p in 0..q {
                // SAFETY: writes land in rows [lo, hi) only; reads target
                // the upper triangle, untouched in this phase.
                unsafe {
                    let v = *gptr.ptr().add(p * n + q);
                    *gptr.ptr().add(q * n + p) = v;
                }
            }
        }
    });
}

/// Conjugation QᵀAQ for dense Q (test helper / SPCA path).
pub fn conjugate(q: &Mat, a: &Mat) -> Mat {
    // (QᵀA)Q
    let qta = gemm_tn(q, a);
    gemm(&qta, q)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randm(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(r, c, |_, _| rng.normal())
    }

    /// Naive reference gemm.
    fn gemm_ref(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a[(i, k)] * b[(k, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    #[test]
    fn gemm_matches_reference() {
        for (m, k, n) in [(1, 1, 1), (3, 4, 5), (17, 31, 13), (64, 70, 65)] {
            let a = randm(m, k, 1);
            let b = randm(k, n, 2);
            let c = gemm(&a, &b);
            let r = gemm_ref(&a, &b);
            assert!(c.sub(&r).max_abs() < 1e-10, "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn gemm_tn_nt_match() {
        let a = randm(23, 11, 3);
        let b = randm(23, 17, 4);
        let c = gemm_tn(&a, &b);
        let r = gemm_ref(&a.transpose(), &b);
        assert!(c.sub(&r).max_abs() < 1e-10);

        let b2 = randm(19, 11, 5);
        let c2 = gemm_nt(&a, &b2);
        let r2 = gemm_ref(&a, &b2.transpose());
        assert!(c2.sub(&r2).max_abs() < 1e-10);
    }

    #[test]
    fn syrk_matches_gemm() {
        let a = randm(29, 13, 6);
        let g = syrk_ata(&a);
        let r = gemm_ref(&a.transpose(), &a);
        assert!(g.sub(&r).max_abs() < 1e-10);
        assert!(g.asymmetry() == 0.0);

        let g2 = syrk_aat(&a);
        let r2 = gemm_ref(&a, &a.transpose());
        assert!(g2.sub(&r2).max_abs() < 1e-10);
    }

    // The bit-determinism contract (parallel == serial at any thread
    // count) lives in tests/par_determinism.rs; here we only spot-check
    // the banded gemm path engages correctly above the flop gate.
    #[test]
    fn banded_gemm_bit_matches_serial() {
        let a = randm(160, 130, 7);
        let b = randm(130, 150, 8);
        let serial = gemm_mt(&a, &b, 1);
        for t in [2, 7] {
            assert_eq!(serial.data, gemm_mt(&a, &b, t).data, "gemm t={t}");
        }
    }

    #[test]
    fn gemv_variants() {
        let a = randm(9, 7, 7);
        let x: Vec<f64> = (0..7).map(|i| i as f64).collect();
        let y = gemv(&a, &x);
        let r = gemm_ref(&a, &Mat::from_vec(7, 1, x.clone()));
        for i in 0..9 {
            assert!((y[i] - r[(i, 0)]).abs() < 1e-12);
        }
        let xt: Vec<f64> = (0..9).map(|i| (i as f64) - 4.0).collect();
        let yt = gemv_t(&a, &xt);
        let rt = gemm_ref(&a.transpose(), &Mat::from_vec(9, 1, xt));
        for j in 0..7 {
            assert!((yt[j] - rt[(j, 0)]).abs() < 1e-12);
        }
    }

    #[test]
    fn dot_axpy_norm() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [5.0, 4.0, 3.0, 2.0, 1.0];
        assert_eq!(dot(&a, &b), 35.0);
        let mut y = vec![1.0; 5];
        axpy(2.0, &a, &mut y);
        assert_eq!(y, vec![3.0, 5.0, 7.0, 9.0, 11.0]);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
    }

    #[test]
    fn conjugation_by_identity() {
        let a = {
            let mut a = randm(6, 6, 8);
            a.symmetrize();
            a
        };
        let q = Mat::eye(6);
        let c = conjugate(&q, &a);
        assert!(c.sub(&a).max_abs() < 1e-12);
    }
}
