//! BLAS-style compute kernels (no external BLAS in the offline build).
//!
//! The O(n³) kernels (`gemm*`, `syrk_*`) are register-blocked, panel-
//! packed microkernels with runtime SIMD dispatch:
//!
//! * **Packing** — the right-hand operand is packed once per call into a
//!   panel-major scratch buffer (arena-recycled, shared read-only across
//!   row bands); the left-hand operand is packed per 4-row block with
//!   alpha folded in, so the inner loop streams two contiguous buffers.
//! * **Register blocking** — each packed B panel is reused across
//!   `MR` = 4 rows of A; a full tile keeps 8 independent accumulator
//!   chains live (4×8 f64 on AVX2, 4×16 on AVX-512), enough to saturate
//!   two FMA ports at 4-cycle latency.
//! * **Dispatch** — [`simd_level`] picks Scalar / AVX2 / AVX-512 once at
//!   startup (`core::arch::x86_64` intrinsics behind
//!   `is_x86_64_feature_detected!`; `MKA_FORCE_SCALAR=1` pins the
//!   portable fallback). Non-x86 builds always take the portable path.
//!
//! **Determinism across dispatch paths**: the SIMD kernels vectorize
//! over the **j** index — each vector lane owns a distinct output
//! element — so every output element's accumulation over k is one serial
//! fused chain `s ← fma(α·a_ik, b_kj, s)`, identical in length and order
//! at every lane width, row-block height, and thread count. The portable
//! fallback runs the same chain through `f64::mul_add`, which is
//! correctly rounded with or without hardware FMA. Results are therefore
//! **bit-for-bit identical** across Scalar/AVX2/AVX-512 and across
//! thread counts (row-band sharding, as before) — pinned by
//! `tests/blas_kernels.rs` and `tests/par_determinism.rs`.
//!
//! Zero handling: the old kernels skipped individual zero scalars of the
//! left operand — a per-iteration branch that mispredicts on dense data.
//! The microkernels skip only **whole left panels** whose packed values
//! are all +0.0 (detected bitwise during packing, so −0.0 never skips);
//! dense panels run branch-free.
//!
//! Small products (below [`PAR_MIN_FLOPS`]) stay serial; the `*_mt`
//! variants take an explicit thread cap, the classic names use
//! `par::threads()`. `*_level` variants pin the dispatch level for
//! tests.

use std::sync::OnceLock;

use super::dense::Mat;
use crate::par::{self, arena, SendPtr};

/// Below this many fused multiply-adds a parallel split is all overhead.
pub const PAR_MIN_FLOPS: usize = 1 << 21;

/// Register-block height: rows of C computed per packed left panel.
const MR: usize = 4;

/// Widest panel any dispatch level uses (AVX-512: 2 × 8 lanes).
const MAX_W: usize = 16;

/// Instruction-set tier for the dense microkernels. Every tier computes
/// bit-identical results (see module docs); the tier is purely a
/// wall-clock knob, exactly like the thread count.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum SimdLevel {
    /// Portable `f64::mul_add` chains (any CPU; forced by
    /// `MKA_FORCE_SCALAR=1`).
    Scalar,
    /// 256-bit lanes via AVX2 + FMA.
    Avx2,
    /// 512-bit lanes via AVX-512F.
    Avx512,
}

/// Packed-panel width (columns per panel) for a dispatch level.
fn panel_width(level: SimdLevel) -> usize {
    match level {
        SimdLevel::Avx512 => 16,
        _ => 8,
    }
}

/// Every level this CPU can run, narrowest first ([`SimdLevel::Scalar`]
/// is always present).
pub fn available_levels() -> Vec<SimdLevel> {
    let mut v = vec![SimdLevel::Scalar];
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_64_feature_detected!("avx2")
            && std::arch::is_x86_64_feature_detected!("fma")
        {
            v.push(SimdLevel::Avx2);
        }
        if std::arch::is_x86_64_feature_detected!("avx512f") {
            v.push(SimdLevel::Avx512);
        }
    }
    v
}

/// Whether `level` is runnable on this CPU.
pub fn level_available(level: SimdLevel) -> bool {
    available_levels().contains(&level)
}

/// The process-wide dispatch level: the widest supported tier, unless
/// `MKA_FORCE_SCALAR` (any value but `0`/empty) pins the portable
/// fallback. Read once and cached.
pub fn simd_level() -> SimdLevel {
    static LEVEL: OnceLock<SimdLevel> = OnceLock::new();
    *LEVEL.get_or_init(|| {
        let forced = std::env::var("MKA_FORCE_SCALAR")
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false);
        if forced {
            SimdLevel::Scalar
        } else {
            available_levels().last().copied().unwrap_or(SimdLevel::Scalar)
        }
    })
}

fn check_level(level: SimdLevel) {
    assert!(level_available(level), "SIMD level {level:?} not available on this CPU");
}

/// Hardware FMA available? The portable tile body is additionally
/// compiled under `target_feature(fma)` when so, turning `mul_add` into
/// one instruction instead of a libm call — same bits either way.
#[cfg(target_arch = "x86_64")]
fn hw_fma() -> bool {
    static FMA: OnceLock<bool> = OnceLock::new();
    *FMA.get_or_init(|| std::arch::is_x86_64_feature_detected!("fma"))
}

/// Shard count for a banded kernel: serial unless the work and the row
/// count justify splitting.
fn par_shards(rows: usize, flops: usize, threads: usize) -> usize {
    if threads <= 1 || rows < 2 || flops < PAR_MIN_FLOPS {
        1
    } else {
        threads.min(rows)
    }
}

/// Reconstruct the mutable row band [lo, hi) of a row-major buffer.
///
/// # Safety
/// Caller guarantees bands are disjoint across concurrent tasks and the
/// buffer outlives the parallel region.
unsafe fn band_mut<'a>(ptr: SendPtr<f64>, cols: usize, lo: usize, hi: usize) -> &'a mut [f64] {
    std::slice::from_raw_parts_mut(ptr.ptr().add(lo * cols), (hi - lo) * cols)
}

/// y ← A x.
pub fn gemv(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols, x.len());
    let mut y = vec![0.0; a.rows];
    gemv_into(a, x, &mut y);
    y
}

/// y ← A x (no allocation).
pub fn gemv_into(a: &Mat, x: &[f64], y: &mut [f64]) {
    assert_eq!(a.cols, x.len());
    assert_eq!(a.rows, y.len());
    for i in 0..a.rows {
        y[i] = dot(a.row(i), x);
    }
}

/// y ← Aᵀ x.
pub fn gemv_t(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows, x.len());
    let mut y = vec![0.0; a.cols];
    for i in 0..a.rows {
        let xi = x[i];
        if xi == 0.0 {
            continue;
        }
        let row = a.row(i);
        for j in 0..a.cols {
            y[j] += xi * row[j];
        }
    }
    y
}

/// Dot product with 4-way unrolling (auto-vectorizes well).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for k in 0..chunks {
        let i = 4 * k;
        unsafe {
            s0 += a.get_unchecked(i) * b.get_unchecked(i);
            s1 += a.get_unchecked(i + 1) * b.get_unchecked(i + 1);
            s2 += a.get_unchecked(i + 2) * b.get_unchecked(i + 2);
            s3 += a.get_unchecked(i + 3) * b.get_unchecked(i + 3);
        }
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in 4 * chunks..n {
        s += a[i] * b[i];
    }
    s
}

/// y ← y + a·x.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

pub fn norm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

// ---------------------------------------------------------------------
// Packing
// ---------------------------------------------------------------------

/// How the left operand feeds the microkernel.
enum LeftOp<'a> {
    /// Output row `i` streams row `i` of `a` (gemm / gemm_nt / syrk_aat);
    /// `alpha` is folded in at pack time (one rounding, before the fused
    /// chain — the reference loops in tests mirror this exactly).
    Rows { alpha: f64, a: &'a Mat },
    /// Output row `p` streams column `p` of `a` (gemm_tn / syrk_ata).
    Cols { a: &'a Mat },
}

impl LeftOp<'_> {
    fn depth(&self) -> usize {
        match *self {
            LeftOp::Rows { a, .. } => a.cols,
            LeftOp::Cols { a } => a.rows,
        }
    }
}

/// Pack the left panel for output rows [i0, i0+h): `lp[t*h + r]` holds
/// the (alpha-folded) left value for output row `i0+r` at depth `t`.
/// Returns true when every packed value is +0.0 — the caller then skips
/// the whole panel. (This replaces the old per-scalar zero test, a
/// mispredicted branch per inner iteration on dense data; −0.0 counts
/// as nonzero so a skip can never flip an output sign bit.)
fn pack_left(left: &LeftOp<'_>, i0: usize, h: usize, lp: &mut [f64]) -> bool {
    let mut bits = 0u64;
    match *left {
        LeftOp::Rows { alpha, a } => {
            let depth = a.cols;
            for r in 0..h {
                let row = a.row(i0 + r);
                for t in 0..depth {
                    let v = alpha * row[t];
                    bits |= v.to_bits();
                    lp[t * h + r] = v;
                }
            }
        }
        LeftOp::Cols { a } => {
            for t in 0..a.rows {
                let src = &a.row(t)[i0..i0 + h];
                let dst = &mut lp[t * h..t * h + h];
                for (d, &s) in dst.iter_mut().zip(src) {
                    bits |= s.to_bits();
                    *d = s;
                }
            }
        }
    }
    bits == 0
}

/// Pack all of B panel-major: the panel starting at column `j0` (width
/// `w = min(W, n−j0)`) occupies `rp[j0*depth ..][.. depth*w]`, laid out
/// `panel[t*w + c] = b[t][j0+c]`. Packed once per call on the submitting
/// thread and shared read-only across row bands — O(K·n) against the
/// O(m·K·n) compute it feeds.
fn pack_right(b: &Mat, w_full: usize, rp: &mut [f64]) {
    let (depth, n) = (b.rows, b.cols);
    for t in 0..depth {
        let row = b.row(t);
        let mut j0 = 0;
        while j0 < n {
            let w = (n - j0).min(w_full);
            let base = j0 * depth + t * w;
            rp[base..base + w].copy_from_slice(&row[j0..j0 + w]);
            j0 += w;
        }
    }
}

/// Pack Bᵀ panel-major: `panel[t*w + c] = b[j0+c][t]` — the gemm_nt /
/// syrk_aat right-hand side, transposed once at pack time so the
/// microkernel streams it contiguously.
fn pack_right_t(b: &Mat, w_full: usize, rp: &mut [f64]) {
    let (n, depth) = (b.rows, b.cols);
    let mut j0 = 0;
    while j0 < n {
        let w = (n - j0).min(w_full);
        let base = j0 * depth;
        for c in 0..w {
            let row = b.row(j0 + c);
            for t in 0..depth {
                rp[base + t * w + c] = row[t];
            }
        }
        j0 += w;
    }
}

/// A packed right-hand side plus the dispatch parameters every band
/// shares.
struct Panels<'a> {
    level: SimdLevel,
    depth: usize,
    n: usize,
    rp: &'a [f64],
}

// ---------------------------------------------------------------------
// Microkernels
// ---------------------------------------------------------------------

/// Portable tile body: for each of the `h ≤ MR` rows and `w ≤ MAX_W`
/// columns, run one serial fused chain over the full depth, then add the
/// chain total into C. This is the *definition* of the arithmetic every
/// other path must reproduce bitwise. `clip = Some((pb, j0))` restricts
/// stores to the upper triangle q ≥ p (syrk straddle tiles) — chains are
/// unchanged, only stores are masked.
#[inline(always)]
fn mk_tile_body(
    depth: usize,
    dims: (usize, usize),
    lp: &[f64],
    rp: &[f64],
    ctile: &mut [f64],
    stride: usize,
    clip: Option<(usize, usize)>,
) {
    let (h, w) = dims;
    debug_assert!(h <= MR && w <= MAX_W);
    let mut acc = [[0.0f64; MAX_W]; MR];
    for t in 0..depth {
        let lrow = &lp[t * h..t * h + h];
        let rrow = &rp[t * w..t * w + w];
        for (accr, &l) in acc.iter_mut().zip(lrow) {
            for (av, &rv) in accr[..w].iter_mut().zip(rrow) {
                *av = l.mul_add(rv, *av);
            }
        }
    }
    for r in 0..h {
        let lo = match clip {
            Some((pb, j0)) => (pb + r).saturating_sub(j0).min(w),
            None => 0,
        };
        let crow = &mut ctile[r * stride..r * stride + w];
        for c in lo..w {
            crow[c] += acc[r][c];
        }
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::mk_tile_body;
    use std::arch::x86_64::*;

    /// The portable body compiled under `target_feature(fma)`, so
    /// `mul_add` lowers to vfmadd instead of a libm call. Bit-identical
    /// by construction: `f64::mul_add` is correctly rounded with or
    /// without hardware support.
    ///
    /// # Safety
    /// CPU must support FMA (checked by the dispatcher).
    #[target_feature(enable = "fma")]
    pub unsafe fn mk_tile_fma(
        depth: usize,
        dims: (usize, usize),
        lp: &[f64],
        rp: &[f64],
        ctile: &mut [f64],
        stride: usize,
        clip: Option<(usize, usize)>,
    ) {
        mk_tile_body(depth, dims, lp, rp, ctile, stride, clip);
    }

    #[target_feature(enable = "avx2")]
    unsafe fn store_acc256(row: *mut f64, lo: __m256d, hi: __m256d) {
        _mm256_storeu_pd(row, _mm256_add_pd(_mm256_loadu_pd(row), lo));
        _mm256_storeu_pd(row.add(4), _mm256_add_pd(_mm256_loadu_pd(row.add(4)), hi));
    }

    /// Full 4×8 AVX2 tile: 8 ymm accumulators = 4 rows × 8 j-lanes, each
    /// lane one output element's serial fma chain over the full depth.
    ///
    /// # Safety
    /// CPU must support AVX2+FMA; `lp`/`rp` hold `depth*4`/`depth*8`
    /// packed values; the 4×8 tile at `c` (row stride `stride`) is in
    /// bounds.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn mk4x8_avx2(
        depth: usize,
        lp: *const f64,
        rp: *const f64,
        c: *mut f64,
        stride: usize,
    ) {
        let mut a00 = _mm256_setzero_pd();
        let mut a01 = _mm256_setzero_pd();
        let mut a10 = _mm256_setzero_pd();
        let mut a11 = _mm256_setzero_pd();
        let mut a20 = _mm256_setzero_pd();
        let mut a21 = _mm256_setzero_pd();
        let mut a30 = _mm256_setzero_pd();
        let mut a31 = _mm256_setzero_pd();
        for t in 0..depth {
            let r0 = _mm256_loadu_pd(rp.add(t * 8));
            let r1 = _mm256_loadu_pd(rp.add(t * 8 + 4));
            let l = lp.add(t * 4);
            let l0 = _mm256_set1_pd(*l);
            a00 = _mm256_fmadd_pd(l0, r0, a00);
            a01 = _mm256_fmadd_pd(l0, r1, a01);
            let l1 = _mm256_set1_pd(*l.add(1));
            a10 = _mm256_fmadd_pd(l1, r0, a10);
            a11 = _mm256_fmadd_pd(l1, r1, a11);
            let l2 = _mm256_set1_pd(*l.add(2));
            a20 = _mm256_fmadd_pd(l2, r0, a20);
            a21 = _mm256_fmadd_pd(l2, r1, a21);
            let l3 = _mm256_set1_pd(*l.add(3));
            a30 = _mm256_fmadd_pd(l3, r0, a30);
            a31 = _mm256_fmadd_pd(l3, r1, a31);
        }
        store_acc256(c, a00, a01);
        store_acc256(c.add(stride), a10, a11);
        store_acc256(c.add(2 * stride), a20, a21);
        store_acc256(c.add(3 * stride), a30, a31);
    }

    #[target_feature(enable = "avx512f")]
    unsafe fn store_acc512(row: *mut f64, lo: __m512d, hi: __m512d) {
        _mm512_storeu_pd(row, _mm512_add_pd(_mm512_loadu_pd(row), lo));
        _mm512_storeu_pd(row.add(8), _mm512_add_pd(_mm512_loadu_pd(row.add(8)), hi));
    }

    /// Full 4×16 AVX-512 tile: 8 zmm accumulators = 4 rows × 16 j-lanes
    /// (two vectors per row keeps 8 chains live — latency-bound at 4 with
    /// one).
    ///
    /// # Safety
    /// CPU must support AVX-512F; packing/bounds as for [`mk4x8_avx2`]
    /// with panel width 16.
    #[target_feature(enable = "avx512f")]
    pub unsafe fn mk4x16_avx512(
        depth: usize,
        lp: *const f64,
        rp: *const f64,
        c: *mut f64,
        stride: usize,
    ) {
        let mut a00 = _mm512_setzero_pd();
        let mut a01 = _mm512_setzero_pd();
        let mut a10 = _mm512_setzero_pd();
        let mut a11 = _mm512_setzero_pd();
        let mut a20 = _mm512_setzero_pd();
        let mut a21 = _mm512_setzero_pd();
        let mut a30 = _mm512_setzero_pd();
        let mut a31 = _mm512_setzero_pd();
        for t in 0..depth {
            let r0 = _mm512_loadu_pd(rp.add(t * 16));
            let r1 = _mm512_loadu_pd(rp.add(t * 16 + 8));
            let l = lp.add(t * 4);
            let l0 = _mm512_set1_pd(*l);
            a00 = _mm512_fmadd_pd(l0, r0, a00);
            a01 = _mm512_fmadd_pd(l0, r1, a01);
            let l1 = _mm512_set1_pd(*l.add(1));
            a10 = _mm512_fmadd_pd(l1, r0, a10);
            a11 = _mm512_fmadd_pd(l1, r1, a11);
            let l2 = _mm512_set1_pd(*l.add(2));
            a20 = _mm512_fmadd_pd(l2, r0, a20);
            a21 = _mm512_fmadd_pd(l2, r1, a21);
            let l3 = _mm512_set1_pd(*l.add(3));
            a30 = _mm512_fmadd_pd(l3, r0, a30);
            a31 = _mm512_fmadd_pd(l3, r1, a31);
        }
        store_acc512(c, a00, a01);
        store_acc512(c.add(stride), a10, a11);
        store_acc512(c.add(2 * stride), a20, a21);
        store_acc512(c.add(3 * stride), a30, a31);
    }
}

/// Portable tile with the fastest bit-identical body this CPU has.
fn mk_tile_scalar(
    depth: usize,
    dims: (usize, usize),
    lp: &[f64],
    rp: &[f64],
    ctile: &mut [f64],
    stride: usize,
    clip: Option<(usize, usize)>,
) {
    #[cfg(target_arch = "x86_64")]
    if hw_fma() {
        // SAFETY: FMA support verified at runtime.
        unsafe { x86::mk_tile_fma(depth, dims, lp, rp, ctile, stride, clip) };
        return;
    }
    mk_tile_body(depth, dims, lp, rp, ctile, stride, clip);
}

#[cfg(target_arch = "x86_64")]
fn try_simd_tile(
    level: SimdLevel,
    depth: usize,
    dims: (usize, usize),
    lp: &[f64],
    rp: &[f64],
    ctile: &mut [f64],
    stride: usize,
) -> bool {
    let (h, w) = dims;
    match level {
        // SAFETY: the dispatch level was availability-checked at entry;
        // packed panels hold depth*h / depth*w values; the full tile is
        // in bounds of `ctile` with row stride `stride`.
        SimdLevel::Avx2 if h == MR && w == 8 => {
            unsafe { x86::mk4x8_avx2(depth, lp.as_ptr(), rp.as_ptr(), ctile.as_mut_ptr(), stride) };
            true
        }
        SimdLevel::Avx512 if h == MR && w == 16 => {
            unsafe {
                x86::mk4x16_avx512(depth, lp.as_ptr(), rp.as_ptr(), ctile.as_mut_ptr(), stride)
            };
            true
        }
        _ => false,
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn try_simd_tile(
    _level: SimdLevel,
    _depth: usize,
    _dims: (usize, usize),
    _lp: &[f64],
    _rp: &[f64],
    _ctile: &mut [f64],
    _stride: usize,
) -> bool {
    false
}

/// Drive one row band [i0, i1) of the output through the packed
/// microkernels. `upper` restricts stores to the upper triangle q ≥ p
/// (syrk): panels fully below the diagonal are skipped, full tiles
/// strictly inside the triangle take the SIMD path, straddling tiles
/// fall back to the clipped portable body (same chains, masked stores).
fn mk_band(
    p: &Panels<'_>,
    left: &LeftOp<'_>,
    cband: &mut [f64],
    i0: usize,
    i1: usize,
    upper: bool,
) {
    let (depth, n) = (p.depth, p.n);
    if depth == 0 || n == 0 {
        return;
    }
    let w_full = panel_width(p.level);
    let mut lp = arena::take_aligned(depth * MR);
    for ib in (i0..i1).step_by(MR) {
        let h = (i1 - ib).min(MR);
        if pack_left(left, ib, h, &mut lp.slice_mut()[..depth * h]) {
            continue; // whole-panel zero skip: all-(+0.0) left panel
        }
        let lph = &lp.slice()[..depth * h];
        let row0 = ib - i0;
        let mut j0 = if upper { (ib / w_full) * w_full } else { 0 };
        while j0 < n {
            let w = (n - j0).min(w_full);
            let rpp = &p.rp[j0 * depth..j0 * depth + depth * w];
            let clip = upper && j0 < ib + h - 1;
            let off = row0 * n + j0;
            if clip || !try_simd_tile(p.level, depth, (h, w), lph, rpp, &mut cband[off..], n) {
                let c = if clip { Some((ib, j0)) } else { None };
                mk_tile_scalar(depth, (h, w), lph, rpp, &mut cband[off..], n, c);
            }
            j0 += w;
        }
    }
}

// ---------------------------------------------------------------------
// GEMM / SYRK drivers
// ---------------------------------------------------------------------

/// C ← A B.
pub fn gemm(a: &Mat, b: &Mat) -> Mat {
    gemm_mt(a, b, par::threads())
}

/// [`gemm`] with an explicit thread-count cap (bit-identical at any cap).
pub fn gemm_mt(a: &Mat, b: &Mat, threads: usize) -> Mat {
    assert_eq!(a.cols, b.rows, "gemm shape mismatch {}x{} * {}x{}", a.rows, a.cols, b.rows, b.cols);
    let mut c = arena::take_mat_zeroed(a.rows, b.cols);
    gemm_acc_mt(1.0, a, b, &mut c, threads);
    c
}

/// C ← C + alpha·A·B — the workhorse. Parallel over bands of C's rows;
/// every output element's chain is independent of banding, panel width
/// and dispatch level, so any configuration gives the same bits.
pub fn gemm_acc(alpha: f64, a: &Mat, b: &Mat, c: &mut Mat) {
    gemm_acc_mt(alpha, a, b, c, par::threads());
}

/// [`gemm_acc`] with an explicit thread-count cap.
pub fn gemm_acc_mt(alpha: f64, a: &Mat, b: &Mat, c: &mut Mat, threads: usize) {
    gemm_acc_impl(simd_level(), alpha, a, b, c, threads);
}

/// [`gemm_acc`] pinned to an explicit dispatch level (serial) — the test
/// hook behind `tests/blas_kernels.rs`. Panics if the CPU lacks `level`.
pub fn gemm_acc_level(level: SimdLevel, alpha: f64, a: &Mat, b: &Mat, c: &mut Mat) {
    check_level(level);
    gemm_acc_impl(level, alpha, a, b, c, 1);
}

fn gemm_acc_impl(level: SimdLevel, alpha: f64, a: &Mat, b: &Mat, c: &mut Mat, threads: usize) {
    assert_eq!(a.cols, b.rows);
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, b.cols);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    if alpha == 0.0 || m == 0 || k == 0 || n == 0 {
        return; // α=0 / empty depth contribute nothing (old semantics)
    }
    let mut rp = arena::take_vec(k * n);
    pack_right(b, panel_width(level), &mut rp);
    let panels = Panels { level, depth: k, n, rp: &rp };
    let shards = par_shards(m, m * k * n, threads);
    if shards <= 1 {
        gemm_acc_rows(&panels, alpha, a, &mut c.data, 0, m);
    } else {
        let cols = c.cols;
        let cptr = SendPtr::new(c.data.as_mut_ptr());
        let pref = &panels;
        par::for_ranges(m, shards, move |_, lo, hi| {
            // SAFETY: bands are disjoint row ranges of C.
            let band = unsafe { band_mut(cptr, cols, lo, hi) };
            gemm_acc_rows(pref, alpha, a, band, lo, hi);
        });
    }
    arena::give_vec(rp);
}

/// Band kernel for [`gemm_acc`]: rows [i0, i1) of C against pre-packed B.
fn gemm_acc_rows(p: &Panels<'_>, alpha: f64, a: &Mat, cband: &mut [f64], i0: usize, i1: usize) {
    mk_band(p, &LeftOp::Rows { alpha, a }, cband, i0, i1, false);
}

/// C ← Aᵀ B  (m×k)ᵀ·(m×n). Parallel over bands of C's rows (columns of
/// A).
pub fn gemm_tn(a: &Mat, b: &Mat) -> Mat {
    gemm_tn_mt(a, b, par::threads())
}

/// [`gemm_tn`] with an explicit thread-count cap.
pub fn gemm_tn_mt(a: &Mat, b: &Mat, threads: usize) -> Mat {
    gemm_tn_impl(simd_level(), a, b, threads)
}

/// [`gemm_tn`] pinned to an explicit dispatch level (serial).
pub fn gemm_tn_level(level: SimdLevel, a: &Mat, b: &Mat) -> Mat {
    check_level(level);
    gemm_tn_impl(level, a, b, 1)
}

fn gemm_tn_impl(level: SimdLevel, a: &Mat, b: &Mat, threads: usize) -> Mat {
    assert_eq!(a.rows, b.rows);
    let (depth, m, n) = (a.rows, a.cols, b.cols);
    let mut c = arena::take_mat_zeroed(m, n);
    if depth == 0 || m == 0 || n == 0 {
        return c;
    }
    let mut rp = arena::take_vec(depth * n);
    pack_right(b, panel_width(level), &mut rp);
    let panels = Panels { level, depth, n, rp: &rp };
    let shards = par_shards(m, depth * m * n, threads);
    if shards <= 1 {
        gemm_tn_rows(&panels, a, &mut c.data, 0, m);
    } else {
        let cptr = SendPtr::new(c.data.as_mut_ptr());
        let pref = &panels;
        par::for_ranges(m, shards, move |_, lo, hi| {
            // SAFETY: bands are disjoint row ranges of C.
            let band = unsafe { band_mut(cptr, n, lo, hi) };
            gemm_tn_rows(pref, a, band, lo, hi);
        });
    }
    arena::give_vec(rp);
    c
}

fn gemm_tn_rows(p: &Panels<'_>, a: &Mat, cband: &mut [f64], p0: usize, p1: usize) {
    mk_band(p, &LeftOp::Cols { a }, cband, p0, p1, false);
}

/// C ← A Bᵀ. Parallel over bands of C's rows.
pub fn gemm_nt(a: &Mat, b: &Mat) -> Mat {
    gemm_nt_mt(a, b, par::threads())
}

/// [`gemm_nt`] with an explicit thread-count cap.
pub fn gemm_nt_mt(a: &Mat, b: &Mat, threads: usize) -> Mat {
    gemm_nt_impl(simd_level(), a, b, threads)
}

/// [`gemm_nt`] pinned to an explicit dispatch level (serial).
pub fn gemm_nt_level(level: SimdLevel, a: &Mat, b: &Mat) -> Mat {
    check_level(level);
    gemm_nt_impl(level, a, b, 1)
}

fn gemm_nt_impl(level: SimdLevel, a: &Mat, b: &Mat, threads: usize) -> Mat {
    assert_eq!(a.cols, b.cols);
    let (depth, m, n) = (a.cols, a.rows, b.rows);
    let mut c = arena::take_mat_zeroed(m, n);
    if depth == 0 || m == 0 || n == 0 {
        return c;
    }
    let mut rp = arena::take_vec(depth * n);
    pack_right_t(b, panel_width(level), &mut rp);
    let panels = Panels { level, depth, n, rp: &rp };
    let shards = par_shards(m, m * depth * n, threads);
    if shards <= 1 {
        gemm_nt_rows(&panels, a, &mut c.data, 0, m);
    } else {
        let cptr = SendPtr::new(c.data.as_mut_ptr());
        let pref = &panels;
        par::for_ranges(m, shards, move |_, lo, hi| {
            // SAFETY: bands are disjoint row ranges of C.
            let band = unsafe { band_mut(cptr, n, lo, hi) };
            gemm_nt_rows(pref, a, band, lo, hi);
        });
    }
    arena::give_vec(rp);
    c
}

fn gemm_nt_rows(p: &Panels<'_>, a: &Mat, cband: &mut [f64], i0: usize, i1: usize) {
    mk_band(p, &LeftOp::Rows { alpha: 1.0, a }, cband, i0, i1, false);
}

/// A ← diag(s) · A: scale row i by s[i]. Row-major, so each scaling is one
/// contiguous pass — this is how the blocked cascade applies f(D_ℓ) to a
/// whole wavelet block at once.
pub fn scale_rows(a: &mut Mat, s: &[f64]) {
    assert_eq!(a.rows, s.len());
    for (i, &si) in s.iter().enumerate() {
        for v in a.row_mut(i) {
            *v *= si;
        }
    }
}

/// G ← AᵀA (symmetric rank-k update). Computes only the upper triangle
/// (banded over G's rows — bands near p = 0 carry more of the triangle,
/// a deliberate trade for keeping the thread cap exact) and mirrors it.
/// Upper entries are bitwise identical to `gemm_tn(a, a)`'s.
pub fn syrk_ata(a: &Mat) -> Mat {
    syrk_ata_mt(a, par::threads())
}

/// [`syrk_ata`] with an explicit thread-count cap.
pub fn syrk_ata_mt(a: &Mat, threads: usize) -> Mat {
    syrk_ata_impl(simd_level(), a, threads)
}

/// [`syrk_ata`] pinned to an explicit dispatch level (serial).
pub fn syrk_ata_level(level: SimdLevel, a: &Mat) -> Mat {
    check_level(level);
    syrk_ata_impl(level, a, 1)
}

fn syrk_ata_impl(level: SimdLevel, a: &Mat, threads: usize) -> Mat {
    let (depth, n) = (a.rows, a.cols);
    let mut g = arena::take_mat_zeroed(n, n);
    if n == 0 {
        return g;
    }
    let shards = par_shards(n, depth * n * n / 2, threads);
    if depth > 0 {
        let mut rp = arena::take_vec(depth * n);
        pack_right(a, panel_width(level), &mut rp);
        let panels = Panels { level, depth, n, rp: &rp };
        if shards <= 1 {
            syrk_ata_rows(&panels, a, &mut g.data, 0, n);
        } else {
            let gptr = SendPtr::new(g.data.as_mut_ptr());
            let pref = &panels;
            par::for_ranges(n, shards, move |_, lo, hi| {
                // SAFETY: bands are disjoint row ranges of G.
                let band = unsafe { band_mut(gptr, n, lo, hi) };
                syrk_ata_rows(pref, a, band, lo, hi);
            });
        }
        arena::give_vec(rp);
    }
    mirror_upper(&mut g, shards);
    g
}

fn syrk_ata_rows(p: &Panels<'_>, a: &Mat, gband: &mut [f64], p0: usize, p1: usize) {
    mk_band(p, &LeftOp::Cols { a }, gband, p0, p1, true);
}

/// G ← A Aᵀ. Upper triangle banded over G's rows, then mirrored.
pub fn syrk_aat(a: &Mat) -> Mat {
    syrk_aat_mt(a, par::threads())
}

/// [`syrk_aat`] with an explicit thread-count cap.
pub fn syrk_aat_mt(a: &Mat, threads: usize) -> Mat {
    syrk_aat_impl(simd_level(), a, threads)
}

/// [`syrk_aat`] pinned to an explicit dispatch level (serial).
pub fn syrk_aat_level(level: SimdLevel, a: &Mat) -> Mat {
    check_level(level);
    syrk_aat_impl(level, a, 1)
}

fn syrk_aat_impl(level: SimdLevel, a: &Mat, threads: usize) -> Mat {
    let (depth, n) = (a.cols, a.rows);
    let mut g = arena::take_mat_zeroed(n, n);
    if n == 0 {
        return g;
    }
    let shards = par_shards(n, n * n * depth / 2, threads);
    if depth > 0 {
        let mut rp = arena::take_vec(depth * n);
        pack_right_t(a, panel_width(level), &mut rp);
        let panels = Panels { level, depth, n, rp: &rp };
        if shards <= 1 {
            syrk_aat_rows(&panels, a, &mut g.data, 0, n);
        } else {
            let gptr = SendPtr::new(g.data.as_mut_ptr());
            let pref = &panels;
            par::for_ranges(n, shards, move |_, lo, hi| {
                // SAFETY: bands are disjoint row ranges of G.
                let band = unsafe { band_mut(gptr, n, lo, hi) };
                syrk_aat_rows(pref, a, band, lo, hi);
            });
        }
        arena::give_vec(rp);
    }
    mirror_upper(&mut g, shards);
    g
}

fn syrk_aat_rows(p: &Panels<'_>, a: &Mat, gband: &mut [f64], i0: usize, i1: usize) {
    mk_band(p, &LeftOp::Rows { alpha: 1.0, a }, gband, i0, i1, true);
}

/// Copy the finished upper triangle into the strictly-lower one. Row q of
/// the lower triangle reads only upper-triangle entries, which no task
/// writes during this phase, so banding over rows is race-free.
fn mirror_upper(g: &mut Mat, shards: usize) {
    let n = g.rows;
    if shards <= 1 {
        for p in 0..n {
            for q in (p + 1)..n {
                let v = g[(p, q)];
                g[(q, p)] = v;
            }
        }
        return;
    }
    let gptr = SendPtr::new(g.data.as_mut_ptr());
    par::for_ranges(n, shards, move |_, lo, hi| {
        for q in lo..hi {
            for p in 0..q {
                // SAFETY: writes land in rows [lo, hi) only; reads target
                // the upper triangle, untouched in this phase.
                unsafe {
                    let v = *gptr.ptr().add(p * n + q);
                    *gptr.ptr().add(q * n + p) = v;
                }
            }
        }
    });
}

/// Conjugation QᵀAQ for dense Q (test helper / SPCA path).
pub fn conjugate(q: &Mat, a: &Mat) -> Mat {
    // (QᵀA)Q
    let qta = gemm_tn(q, a);
    gemm(&qta, q)
}

/// The pre-microkernel gemm (blocked i-k-j axpy loops with the old
/// per-scalar zero skip), retained verbatim as the baseline the
/// `complexity` bench measures the packed kernels against
/// (`kernel.speedup_vs_prepr_scalar` in `BENCH_perf.json`).
#[doc(hidden)]
pub fn gemm_baseline(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows);
    const KB: usize = 128;
    const JB: usize = 512;
    let mut c = Mat::zeros(a.rows, b.cols);
    let (k, n) = (a.cols, b.cols);
    for kb in (0..k).step_by(KB) {
        let kend = (kb + KB).min(k);
        for jb in (0..n).step_by(JB) {
            let jend = (jb + JB).min(n);
            for i in 0..a.rows {
                let arow = a.row(i);
                let crow = &mut c.data[i * n + jb..i * n + jend];
                for kk in kb..kend {
                    let aik = arow[kk];
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = &b.row(kk)[jb..jend];
                    for (cj, bj) in crow.iter_mut().zip(brow) {
                        *cj += aik * bj;
                    }
                }
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randm(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(r, c, |_, _| rng.normal())
    }

    /// Naive reference gemm.
    fn gemm_ref(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a[(i, k)] * b[(k, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    #[test]
    fn gemm_matches_reference() {
        for (m, k, n) in [(1, 1, 1), (3, 4, 5), (17, 31, 13), (64, 70, 65)] {
            let a = randm(m, k, 1);
            let b = randm(k, n, 2);
            let c = gemm(&a, &b);
            let r = gemm_ref(&a, &b);
            assert!(c.sub(&r).max_abs() < 1e-10, "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn gemm_tn_nt_match() {
        let a = randm(23, 11, 3);
        let b = randm(23, 17, 4);
        let c = gemm_tn(&a, &b);
        let r = gemm_ref(&a.transpose(), &b);
        assert!(c.sub(&r).max_abs() < 1e-10);

        let b2 = randm(19, 11, 5);
        let c2 = gemm_nt(&a, &b2);
        let r2 = gemm_ref(&a, &b2.transpose());
        assert!(c2.sub(&r2).max_abs() < 1e-10);
    }

    #[test]
    fn syrk_matches_gemm() {
        let a = randm(29, 13, 6);
        let g = syrk_ata(&a);
        let r = gemm_ref(&a.transpose(), &a);
        assert!(g.sub(&r).max_abs() < 1e-10);
        assert!(g.asymmetry() == 0.0);

        let g2 = syrk_aat(&a);
        let r2 = gemm_ref(&a, &a.transpose());
        assert!(g2.sub(&r2).max_abs() < 1e-10);
    }

    #[test]
    fn syrk_bitwise_equals_gemm_tn() {
        // Chains are identical per element, and the mirrored lower
        // triangle matches gemm_tn's independently computed one because
        // fma chains commute their multiplicands.
        let a = randm(21, 18, 16);
        assert_eq!(syrk_ata(&a).data, gemm_tn(&a, &a).data);
        assert_eq!(syrk_aat(&a).data, gemm_nt(&a, &a).data);
    }

    // The bit-determinism contract (parallel == serial at any thread
    // count) lives in tests/par_determinism.rs; here we only spot-check
    // the banded gemm path engages correctly above the flop gate.
    #[test]
    #[cfg_attr(miri, ignore)] // global pool + big shapes
    fn banded_gemm_bit_matches_serial() {
        let a = randm(160, 130, 7);
        let b = randm(130, 150, 8);
        let serial = gemm_mt(&a, &b, 1);
        for t in [2, 7] {
            assert_eq!(serial.data, gemm_mt(&a, &b, t).data, "gemm t={t}");
        }
    }

    #[test]
    fn levels_agree_bitwise_quick() {
        // Full cross-shape matrix lives in tests/blas_kernels.rs; this
        // spot-check keeps the property visible under `cargo miri test
        // --lib` (shapes straddle the 8/16 panel widths).
        for (m, k, n) in [(5, 3, 9), (4, 6, 8), (7, 2, 17)] {
            let a = randm(m, k, 20);
            let b = randm(k, n, 21);
            let base = gemm_tn_level(SimdLevel::Scalar, &a.transpose(), &b);
            for level in available_levels() {
                let c = gemm_tn_level(level, &a.transpose(), &b);
                assert_eq!(base.data, c.data, "{level:?} m={m} k={k} n={n}");
            }
        }
    }

    #[test]
    fn zero_panels_are_skipped_correctly() {
        // Rows 0..4 of A are exactly zero: the whole left panel is
        // skipped; the result must still match the reference (C rows
        // stay at their initial values).
        let mut a = randm(10, 6, 22);
        for i in 0..4 {
            for v in a.row_mut(i) {
                *v = 0.0;
            }
        }
        let b = randm(6, 11, 23);
        let mut c = randm(10, 11, 24);
        let c0 = c.clone();
        gemm_acc(2.5, &a, &b, &mut c);
        for j in 0..11 {
            for i in 0..4 {
                assert_eq!(c[(i, j)], c0[(i, j)], "skipped rows untouched");
            }
        }
        let r = gemm_ref(&a, &b);
        for i in 4..10 {
            for j in 0..11 {
                let want = c0[(i, j)] + 2.5 * r[(i, j)];
                assert!((c[(i, j)] - want).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn gemv_variants() {
        let a = randm(9, 7, 7);
        let x: Vec<f64> = (0..7).map(|i| i as f64).collect();
        let y = gemv(&a, &x);
        let r = gemm_ref(&a, &Mat::from_vec(7, 1, x.clone()));
        for i in 0..9 {
            assert!((y[i] - r[(i, 0)]).abs() < 1e-12);
        }
        let xt: Vec<f64> = (0..9).map(|i| (i as f64) - 4.0).collect();
        let yt = gemv_t(&a, &xt);
        let rt = gemm_ref(&a.transpose(), &Mat::from_vec(9, 1, xt));
        for j in 0..7 {
            assert!((yt[j] - rt[(j, 0)]).abs() < 1e-12);
        }
    }

    #[test]
    fn dot_axpy_norm() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [5.0, 4.0, 3.0, 2.0, 1.0];
        assert_eq!(dot(&a, &b), 35.0);
        let mut y = vec![1.0; 5];
        axpy(2.0, &a, &mut y);
        assert_eq!(y, vec![3.0, 5.0, 7.0, 9.0, 11.0]);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
    }

    #[test]
    fn conjugation_by_identity() {
        let a = {
            let mut a = randm(6, 6, 8);
            a.symmetrize();
            a
        };
        let q = Mat::eye(6);
        let c = conjugate(&q, &a);
        assert!(c.sub(&a).max_abs() < 1e-12);
    }

    #[test]
    fn baseline_matches_reference() {
        let a = randm(33, 19, 30);
        let b = randm(19, 27, 31);
        assert!(gemm_baseline(&a, &b).sub(&gemm_ref(&a, &b)).max_abs() < 1e-10);
    }
}
