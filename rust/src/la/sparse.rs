//! CSR sparse matrices and graph Laplacians.
//!
//! Substrate for §4's sparse-kernel extension: diffusion kernels are matrix
//! functions of a sparse graph Laplacian, and MKA of a sparse matrix runs in
//! near-linear time because the local Gram matrices AᵀA stay cheap.

use super::dense::Mat;

/// Compressed sparse row matrix (f64).
#[derive(Clone, Debug)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<usize>,
    pub indices: Vec<usize>,
    pub values: Vec<f64>,
}

impl Csr {
    /// Build from (row, col, value) triplets; duplicates are summed.
    pub fn from_triplets(rows: usize, cols: usize, triplets: &[(usize, usize, f64)]) -> Csr {
        let mut per_row: Vec<Vec<(usize, f64)>> = vec![Vec::new(); rows];
        for &(i, j, v) in triplets {
            assert!(i < rows && j < cols, "triplet ({i},{j}) out of bounds");
            per_row[i].push((j, v));
        }
        let mut indptr = Vec::with_capacity(rows + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for row in &mut per_row {
            row.sort_by_key(|&(j, _)| j);
            // merge duplicates
            let mut k = 0;
            while k < row.len() {
                let j = row[k].0;
                let mut v = row[k].1;
                let mut k2 = k + 1;
                while k2 < row.len() && row[k2].0 == j {
                    v += row[k2].1;
                    k2 += 1;
                }
                if v != 0.0 {
                    indices.push(j);
                    values.push(v);
                }
                k = k2;
            }
            indptr.push(indices.len());
        }
        Csr { rows, cols, indptr, indices, values }
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Row i as (indices, values) slices.
    pub fn row(&self, i: usize) -> (&[usize], &[f64]) {
        let (a, b) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[a..b], &self.values[a..b])
    }

    /// y ← A x.
    pub fn spmv(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        for i in 0..self.rows {
            let (idx, val) = self.row(i);
            let mut s = 0.0;
            for (j, v) in idx.iter().zip(val) {
                s += v * x[*j];
            }
            y[i] = s;
        }
        y
    }

    /// Densify (tests / small blocks only).
    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (idx, val) = self.row(i);
            for (j, v) in idx.iter().zip(val) {
                m.set(i, *j, *v);
            }
        }
        m
    }

    /// Symmetric gather of a square CSR: dense submatrix A[idx, idx].
    pub fn gather_dense(&self, idx: &[usize]) -> Mat {
        assert_eq!(self.rows, self.cols);
        let pos: std::collections::HashMap<usize, usize> =
            idx.iter().enumerate().map(|(a, &i)| (i, a)).collect();
        let mut m = Mat::zeros(idx.len(), idx.len());
        for (a, &i) in idx.iter().enumerate() {
            let (cols, vals) = self.row(i);
            for (j, v) in cols.iter().zip(vals) {
                if let Some(&b) = pos.get(j) {
                    m.set(a, b, *v);
                }
            }
        }
        m
    }
}

/// An undirected weighted graph stored as an adjacency CSR.
#[derive(Clone, Debug)]
pub struct Graph {
    pub adj: Csr,
}

impl Graph {
    /// Build from undirected edges (i, j, w); both directions are inserted.
    pub fn from_edges(n: usize, edges: &[(usize, usize, f64)]) -> Graph {
        let mut triplets = Vec::with_capacity(edges.len() * 2);
        for &(i, j, w) in edges {
            assert_ne!(i, j, "self loops not allowed");
            triplets.push((i, j, w));
            triplets.push((j, i, w));
        }
        Graph { adj: Csr::from_triplets(n, n, &triplets) }
    }

    pub fn n(&self) -> usize {
        self.adj.rows
    }

    pub fn degrees(&self) -> Vec<f64> {
        (0..self.n())
            .map(|i| {
                let (_, vals) = self.adj.row(i);
                vals.iter().sum()
            })
            .collect()
    }

    /// Unnormalized graph Laplacian L = D − A as CSR.
    pub fn laplacian(&self) -> Csr {
        let n = self.n();
        let deg = self.degrees();
        let mut triplets = Vec::with_capacity(self.adj.nnz() + n);
        for i in 0..n {
            let (idx, val) = self.adj.row(i);
            for (j, v) in idx.iter().zip(val) {
                triplets.push((i, *j, -*v));
            }
            triplets.push((i, i, deg[i]));
        }
        Csr::from_triplets(n, n, &triplets)
    }

    /// Normalized Laplacian L̂ = I − D^{-1/2} A D^{-1/2}.
    pub fn normalized_laplacian(&self) -> Csr {
        let n = self.n();
        let deg = self.degrees();
        let dinv: Vec<f64> =
            deg.iter().map(|&d| if d > 0.0 { 1.0 / d.sqrt() } else { 0.0 }).collect();
        let mut triplets = Vec::with_capacity(self.adj.nnz() + n);
        for i in 0..n {
            let (idx, val) = self.adj.row(i);
            for (j, v) in idx.iter().zip(val) {
                triplets.push((i, *j, -v * dinv[i] * dinv[*j]));
            }
            triplets.push((i, i, 1.0));
        }
        Csr::from_triplets(n, n, &triplets)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn triplets_merge_and_sort() {
        let a = Csr::from_triplets(2, 3, &[(0, 2, 1.0), (0, 0, 2.0), (0, 2, 3.0), (1, 1, 5.0)]);
        assert_eq!(a.nnz(), 3);
        let (idx, val) = a.row(0);
        assert_eq!(idx, &[0, 2]);
        assert_eq!(val, &[2.0, 4.0]);
    }

    #[test]
    fn spmv_matches_dense() {
        let a = Csr::from_triplets(3, 3, &[(0, 0, 1.0), (0, 2, 2.0), (1, 1, 3.0), (2, 0, 4.0)]);
        let x = [1.0, 2.0, 3.0];
        let y = a.spmv(&x);
        let d = a.to_dense();
        let yd = crate::la::blas::gemv(&d, &x);
        assert_eq!(y, yd);
    }

    #[test]
    fn laplacian_rows_sum_to_zero() {
        let g = Graph::from_edges(4, &[(0, 1, 1.0), (1, 2, 2.0), (2, 3, 0.5), (0, 3, 1.0)]);
        let l = g.laplacian();
        let ones = vec![1.0; 4];
        let y = l.spmv(&ones);
        for v in y {
            assert!(v.abs() < 1e-14);
        }
    }

    #[test]
    fn laplacian_is_psd() {
        let g = Graph::from_edges(5, &[(0, 1, 1.0), (1, 2, 1.0), (2, 3, 1.0), (3, 4, 1.0), (4, 0, 1.0)]);
        let l = g.laplacian().to_dense();
        let e = crate::la::evd::SymEig::new(&l);
        assert!(e.values[0] > -1e-10, "smallest eig {}", e.values[0]);
        // connected ring: exactly one ~zero eigenvalue
        assert!(e.values[0].abs() < 1e-10);
        assert!(e.values[1] > 1e-8);
    }

    #[test]
    fn normalized_laplacian_spectrum_bounded() {
        let g = Graph::from_edges(6, &[(0, 1, 1.0), (1, 2, 1.0), (2, 0, 1.0), (3, 4, 1.0), (4, 5, 1.0)]);
        let l = g.normalized_laplacian().to_dense();
        let e = crate::la::evd::SymEig::new(&l);
        assert!(e.values[0] > -1e-10);
        assert!(*e.values.last().unwrap() <= 2.0 + 1e-10);
    }

    #[test]
    fn gather_dense_submatrix() {
        let a = Csr::from_triplets(
            4,
            4,
            &[(0, 0, 1.0), (0, 3, 2.0), (3, 0, 2.0), (3, 3, 4.0), (1, 1, 9.0)],
        );
        let sub = a.gather_dense(&[0, 3]);
        assert_eq!(sub.data, vec![1.0, 2.0, 2.0, 4.0]);
    }
}
