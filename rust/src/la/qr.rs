//! Householder QR factorization.
//!
//! Used by the augmented-SPCA compressor (orthonormalizing sparse loading
//! vectors and building the complement basis) and by tests as an
//! orthogonality oracle.

use super::blas::{dot, norm2};
use super::dense::Mat;

/// Thin QR: A (m×n, m ≥ n) = Q (m×n, orthonormal cols) · R (n×n upper).
#[derive(Clone, Debug)]
pub struct Qr {
    pub q: Mat,
    pub r: Mat,
}

impl Qr {
    pub fn new(a: &Mat) -> Qr {
        let (m, n) = (a.rows, a.cols);
        assert!(m >= n, "thin QR requires m >= n (got {m}x{n})");
        let mut r = a.clone();
        // Householder vectors stored below the diagonal + separate betas.
        let mut vs: Vec<Vec<f64>> = Vec::with_capacity(n);
        for k in 0..n {
            // Build the Householder vector for column k.
            let mut v: Vec<f64> = (k..m).map(|i| r.at(i, k)).collect();
            let alpha = -v[0].signum() * norm2(&v);
            if alpha.abs() < 1e-300 {
                // Zero column below diagonal; identity reflector.
                vs.push(vec![0.0; m - k]);
                continue;
            }
            v[0] -= alpha;
            let vnorm = norm2(&v);
            if vnorm < 1e-300 {
                vs.push(vec![0.0; m - k]);
                continue;
            }
            for x in &mut v {
                *x /= vnorm;
            }
            // Apply H = I - 2vvᵀ to R[k.., k..].
            for j in k..n {
                let mut s = 0.0;
                for i in k..m {
                    s += v[i - k] * r.at(i, j);
                }
                s *= 2.0;
                for i in k..m {
                    let x = r.at(i, j) - s * v[i - k];
                    r.set(i, j, x);
                }
            }
            vs.push(v);
        }
        // Accumulate Q = H_0 H_1 ... H_{n-1} applied to the first n columns
        // of the identity.
        let mut q = Mat::zeros(m, n);
        for j in 0..n {
            q.set(j, j, 1.0);
        }
        for k in (0..n).rev() {
            let v = &vs[k];
            if v.iter().all(|&x| x == 0.0) {
                continue;
            }
            for j in 0..n {
                let mut s = 0.0;
                for i in k..m {
                    s += v[i - k] * q.at(i, j);
                }
                s *= 2.0;
                for i in k..m {
                    let x = q.at(i, j) - s * v[i - k];
                    q.set(i, j, x);
                }
            }
        }
        // Zero out strictly-lower part of R and truncate to n×n.
        let mut rn = Mat::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                rn.set(i, j, r.at(i, j));
            }
        }
        Qr { q, r: rn }
    }
}

/// Orthonormalize the columns of A in place via modified Gram–Schmidt,
/// dropping (near-)dependent columns. Returns a matrix whose columns form an
/// orthonormal basis of range(A).
pub fn orthonormalize_cols(a: &Mat, tol: f64) -> Mat {
    let m = a.rows;
    let mut cols: Vec<Vec<f64>> = Vec::new();
    for j in 0..a.cols {
        let mut v = a.col(j);
        for u in &cols {
            let c = dot(u, &v);
            for i in 0..m {
                v[i] -= c * u[i];
            }
        }
        // Re-orthogonalize once (classic twice-is-enough).
        for u in &cols {
            let c = dot(u, &v);
            for i in 0..m {
                v[i] -= c * u[i];
            }
        }
        let nv = norm2(&v);
        if nv > tol {
            for x in &mut v {
                *x /= nv;
            }
            cols.push(v);
        }
    }
    let mut q = Mat::zeros(m, cols.len());
    for (j, c) in cols.iter().enumerate() {
        for i in 0..m {
            q.set(i, j, c[i]);
        }
    }
    q
}

/// An orthonormal basis of the orthogonal complement of range(Q)
/// (Q: m×c with orthonormal columns; result: m×(m−c)).
pub fn complement_basis(q: &Mat) -> Mat {
    let m = q.rows;
    let c = q.cols;
    // Project the identity columns and orthonormalize what survives.
    let mut candidates = Mat::zeros(m, m);
    for j in 0..m {
        // e_j - Q Qᵀ e_j
        let qt_e: Vec<f64> = (0..c).map(|k| q.at(j, k)).collect();
        for i in 0..m {
            let mut v = if i == j { 1.0 } else { 0.0 };
            for k in 0..c {
                v -= q.at(i, k) * qt_e[k];
            }
            candidates.set(i, j, v);
        }
    }
    let basis = orthonormalize_cols(&candidates, 1e-8);
    // Keep exactly m - c columns (numerical rank should match).
    assert!(
        basis.cols >= m - c,
        "complement basis rank deficient: got {} need {}",
        basis.cols,
        m - c
    );
    basis.block(0, m, 0, m - c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::la::blas::{gemm, gemm_tn};
    use crate::util::Rng;

    fn randm(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(r, c, |_, _| rng.normal())
    }

    #[test]
    fn qr_reconstructs() {
        for (m, n) in [(5, 5), (10, 4), (30, 30), (50, 7)] {
            let a = randm(m, n, (m * n) as u64);
            let qr = Qr::new(&a);
            let rec = gemm(&qr.q, &qr.r);
            assert!(rec.sub(&a).max_abs() < 1e-9, "{m}x{n}");
            let qtq = gemm_tn(&qr.q, &qr.q);
            assert!(qtq.sub(&Mat::eye(n)).max_abs() < 1e-10, "{m}x{n}");
        }
    }

    #[test]
    fn r_is_upper_triangular() {
        let a = randm(12, 6, 3);
        let qr = Qr::new(&a);
        for i in 0..6 {
            for j in 0..i {
                assert_eq!(qr.r[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn orthonormalize_drops_dependent() {
        let mut a = randm(8, 3, 4);
        // append a duplicate column
        let dup = a.col(0);
        let mut data = a.data.clone();
        let mut b = Mat::zeros(8, 4);
        for i in 0..8 {
            for j in 0..3 {
                b.set(i, j, data.remove(0));
            }
            b.set(i, 3, dup[i]);
        }
        a = b;
        let q = orthonormalize_cols(&a, 1e-10);
        assert_eq!(q.cols, 3);
        let qtq = gemm_tn(&q, &q);
        assert!(qtq.sub(&Mat::eye(3)).max_abs() < 1e-10);
    }

    #[test]
    fn complement_is_orthogonal_and_complete() {
        let a = randm(9, 3, 5);
        let q = orthonormalize_cols(&a, 1e-10);
        let u = complement_basis(&q);
        assert_eq!(u.cols, 6);
        // UᵀU = I
        let utu = gemm_tn(&u, &u);
        assert!(utu.sub(&Mat::eye(6)).max_abs() < 1e-9);
        // QᵀU = 0
        let qtu = gemm_tn(&q, &u);
        assert!(qtu.max_abs() < 1e-9);
    }
}
