//! Cholesky factorization and spd solves.
//!
//! Used by the Full-GP baseline (the paper's "Full" column), the Nyström
//! family (SoR/FITC/PITC inner m×m solves), and MKA's final core inversion.

use super::blas::dot;
use super::dense::Mat;
use crate::error::{Error, Result};

/// Lower-triangular Cholesky factor: A = L Lᵀ.
#[derive(Clone, Debug)]
pub struct Chol {
    pub l: Mat,
}

impl Chol {
    /// Factorize a symmetric positive-definite matrix. Returns an error if a
    /// non-positive pivot is hit (matrix not pd to machine precision).
    pub fn new(a: &Mat) -> Result<Chol> {
        assert!(a.is_square());
        let n = a.rows;
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                // s = A[i][j] - sum_k L[i][k] L[j][k]
                let s = a.at(i, j) - dot(&l.row(i)[..j], &l.row(j)[..j]);
                if i == j {
                    if s <= 0.0 {
                        return Err(Error::Linalg(format!(
                            "cholesky: non-positive pivot {s:.3e} at index {i}"
                        )));
                    }
                    l.set(i, j, s.sqrt());
                } else {
                    l.set(i, j, s / l.at(j, j));
                }
            }
        }
        Ok(Chol { l })
    }

    /// Factorize with a jitter fallback: retries with growing diagonal shift
    /// until the factorization succeeds. Returns (chol, jitter_used).
    pub fn new_jittered(a: &Mat, max_tries: usize) -> Result<(Chol, f64)> {
        match Chol::new(a) {
            Ok(c) => Ok((c, 0.0)),
            Err(_) => {
                let scale = a.diagonal().iter().fold(0.0f64, |m, x| m.max(x.abs())).max(1e-12);
                let mut jitter = 1e-10 * scale;
                for _ in 0..max_tries {
                    let mut aj = a.clone();
                    aj.add_diag(jitter);
                    if let Ok(c) = Chol::new(&aj) {
                        return Ok((c, jitter));
                    }
                    jitter *= 10.0;
                }
                Err(Error::Linalg("cholesky: jitter exhausted".into()))
            }
        }
    }

    /// Solve A x = b.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let y = solve_lower(&self.l, b);
        solve_lower_t(&self.l, &y)
    }

    /// Solve A X = B for all columns at once: one blocked forward and one
    /// blocked backward substitution whose inner loops are contiguous row
    /// axpys serving every right-hand side (the multi-RHS path the
    /// Nyström-family baselines route through; replaces the old
    /// column-at-a-time gather/scatter loop).
    pub fn solve_mat(&self, b: &Mat) -> Mat {
        let y = solve_lower_mat(&self.l, b);
        solve_lower_t_mat(&self.l, &y)
    }

    /// log det(A) = 2 Σ log L_ii.
    pub fn logdet(&self) -> f64 {
        self.l.diagonal().iter().map(|x| x.ln()).sum::<f64>() * 2.0
    }

    /// Explicit inverse (n³/3 extra work; prefer `solve`).
    pub fn inverse(&self) -> Mat {
        let n = self.l.rows;
        let mut inv = Mat::zeros(n, n);
        let mut e = vec![0.0; n];
        for j in 0..n {
            e[j] = 1.0;
            let x = self.solve(&e);
            for i in 0..n {
                inv.set(i, j, x[i]);
            }
            e[j] = 0.0;
        }
        inv
    }

    /// L y = b (forward substitution) — exposed for whitening tests.
    pub fn solve_l(&self, b: &[f64]) -> Vec<f64> {
        solve_lower(&self.l, b)
    }
}

/// Forward substitution: L y = b for lower-triangular L.
pub fn solve_lower(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    assert_eq!(b.len(), n);
    let mut y = vec![0.0; n];
    for i in 0..n {
        let s = b[i] - dot(&l.row(i)[..i], &y[..i]);
        y[i] = s / l.at(i, i);
    }
    y
}

/// Backward substitution with the transpose: Lᵀ x = y.
pub fn solve_lower_t(l: &Mat, y: &[f64]) -> Vec<f64> {
    let n = l.rows;
    assert_eq!(y.len(), n);
    let mut x = y.to_vec();
    for i in (0..n).rev() {
        x[i] /= l.at(i, i);
        let xi = x[i];
        // subtract xi * L[i] from earlier entries (column i of Lᵀ).
        for j in 0..i {
            x[j] -= l.at(i, j) * xi;
        }
    }
    x
}

/// Blocked forward substitution: L Y = B for every column of B at once.
/// Row-major layout makes each elimination step a contiguous axpy of row k
/// into row i — b right-hand sides per memory pass instead of one.
pub fn solve_lower_mat(l: &Mat, b: &Mat) -> Mat {
    let n = l.rows;
    assert_eq!(b.rows, n);
    let mut y = b.clone();
    for i in 0..n {
        let lrow = l.row(i);
        for (k, &lik) in lrow.iter().enumerate().take(i) {
            if lik == 0.0 {
                continue;
            }
            let (yi, yk) = y.rows_pair_mut(i, k);
            for (a, b2) in yi.iter_mut().zip(yk.iter()) {
                *a -= lik * *b2;
            }
        }
        let inv = 1.0 / lrow[i];
        for v in y.row_mut(i) {
            *v *= inv;
        }
    }
    y
}

/// Blocked backward substitution with the transpose: Lᵀ X = B for every
/// column of B at once.
pub fn solve_lower_t_mat(l: &Mat, b: &Mat) -> Mat {
    let n = l.rows;
    assert_eq!(b.rows, n);
    let mut x = b.clone();
    for i in (0..n).rev() {
        let inv = 1.0 / l.at(i, i);
        for v in x.row_mut(i) {
            *v *= inv;
        }
        let lrow = l.row(i);
        for (j, &lij) in lrow.iter().enumerate().take(i) {
            if lij == 0.0 {
                continue;
            }
            let (xj, xi) = x.rows_pair_mut(j, i);
            for (a, b2) in xj.iter_mut().zip(xi.iter()) {
                *a -= lij * *b2;
            }
        }
    }
    x
}

/// Backward substitution: U x = b for upper-triangular U.
pub fn solve_upper(u: &Mat, b: &[f64]) -> Vec<f64> {
    let n = u.rows;
    let mut x = b.to_vec();
    for i in (0..n).rev() {
        let s = dot(&u.row(i)[i + 1..], &x[i + 1..]);
        x[i] = (x[i] - s) / u.at(i, i);
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::la::blas::{gemm, gemm_nt, gemv};
    use crate::util::Rng;

    fn spd(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let b = Mat::from_fn(n, n + 3, |_, _| rng.normal());
        let mut a = gemm_nt(&b, &b);
        a.add_diag(0.5);
        a
    }

    #[test]
    fn factor_reconstructs() {
        let a = spd(20, 1);
        let c = Chol::new(&a).unwrap();
        let rec = gemm_nt(&c.l, &c.l);
        assert!(rec.sub(&a).max_abs() < 1e-9);
    }

    #[test]
    fn solve_is_inverse_application() {
        let a = spd(15, 2);
        let c = Chol::new(&a).unwrap();
        let mut rng = Rng::new(3);
        let b = rng.normal_vec(15);
        let x = c.solve(&b);
        let ax = gemv(&a, &x);
        for i in 0..15 {
            assert!((ax[i] - b[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn solve_mat_matches_columns() {
        let a = spd(10, 4);
        let c = Chol::new(&a).unwrap();
        let mut rng = Rng::new(5);
        let b = Mat::from_fn(10, 3, |_, _| rng.normal());
        let x = c.solve_mat(&b);
        let ax = gemm(&a, &x);
        assert!(ax.sub(&b).max_abs() < 1e-8);
    }

    #[test]
    fn logdet_matches_known() {
        // diag(2, 3, 4): logdet = ln 24
        let a = Mat::diag(&[2.0, 3.0, 4.0]);
        let c = Chol::new(&a).unwrap();
        assert!((c.logdet() - 24.0f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn rejects_indefinite() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eigenvalues 3, -1
        assert!(Chol::new(&a).is_err());
    }

    #[test]
    fn jitter_rescues_semidefinite() {
        // rank-1 psd matrix
        let a = Mat::from_rows(&[&[1.0, 1.0], &[1.0, 1.0]]);
        let (c, j) = Chol::new_jittered(&a, 12).unwrap();
        assert!(j > 0.0);
        assert_eq!(c.l.rows, 2);
    }

    #[test]
    fn inverse_explicit() {
        let a = spd(8, 6);
        let c = Chol::new(&a).unwrap();
        let inv = c.inverse();
        let prod = gemm(&a, &inv);
        assert!(prod.sub(&Mat::eye(8)).max_abs() < 1e-8);
    }

    #[test]
    fn triangular_solvers() {
        let l = Mat::from_rows(&[&[2.0, 0.0], &[1.0, 3.0]]);
        let y = solve_lower(&l, &[4.0, 11.0]);
        assert_eq!(y, vec![2.0, 3.0]);
        let u = Mat::from_rows(&[&[2.0, 1.0], &[0.0, 3.0]]);
        let x = solve_upper(&u, &[7.0, 9.0]);
        assert_eq!(x, vec![2.0, 3.0]);
    }
}
