//! Small statistics helpers: means, variances, standardization, quantiles.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Standardize in place to mean 0 / std 1; returns (mean, std).
/// A zero std is replaced by 1 so constant columns pass through.
pub fn standardize(xs: &mut [f64]) -> (f64, f64) {
    let m = mean(xs);
    let mut s = std_dev(xs);
    if s < 1e-12 {
        s = 1.0;
    }
    for x in xs.iter_mut() {
        *x = (*x - m) / s;
    }
    (m, s)
}

/// Quantile by linear interpolation over a *sorted* slice, q in [0, 1].
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Mean and sample-std of repeated measurements (Bessel corrected).
pub fn mean_std_sample(xs: &[f64]) -> (f64, f64) {
    let m = mean(xs);
    if xs.len() < 2 {
        return (m, 0.0);
    }
    let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    (m, v.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_var() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 1.25).abs() < 1e-15);
    }

    #[test]
    fn standardize_works() {
        let mut xs = vec![10.0, 20.0, 30.0];
        let (m, s) = standardize(&mut xs);
        assert_eq!(m, 20.0);
        assert!(s > 0.0);
        assert!(mean(&xs).abs() < 1e-12);
        assert!((std_dev(&xs) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn standardize_constant_column() {
        let mut xs = vec![5.0; 4];
        standardize(&mut xs);
        assert!(xs.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn quantiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(quantile_sorted(&xs, 0.0), 1.0);
        assert_eq!(quantile_sorted(&xs, 1.0), 5.0);
        assert_eq!(quantile_sorted(&xs, 0.5), 3.0);
        assert_eq!(quantile_sorted(&xs, 0.25), 2.0);
    }

    #[test]
    fn sample_std() {
        let (m, s) = mean_std_sample(&[2.0, 4.0]);
        assert_eq!(m, 3.0);
        assert!((s - std::f64::consts::SQRT_2).abs() < 1e-12);
    }
}
