//! Dense row-major matrix type.
//!
//! This is the workhorse container for the whole stack. No external BLAS /
//! LAPACK is available in the offline build, so the compute kernels
//! (`la::blas`) and factorizations (`la::{chol,qr,evd,lu}`) are implemented
//! from scratch on top of this type.

use std::fmt;
use std::ops::{Index, IndexMut};

/// Dense row-major `f64` matrix.
#[derive(Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    // ------------------------------------------------------------------
    // Constructors
    // ------------------------------------------------------------------

    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn filled(rows: usize, cols: usize, v: f64) -> Mat {
        Mat { rows, cols, data: vec![v; rows * cols] }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a function of (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Mat {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Mat {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        Mat { rows, cols, data }
    }

    /// Build from row slices (must be equal length).
    pub fn from_rows(rows: &[&[f64]]) -> Mat {
        let r = rows.len();
        let c = if r > 0 { rows[0].len() } else { 0 };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    /// Diagonal matrix from a vector.
    pub fn diag(d: &[f64]) -> Mat {
        let n = d.len();
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = d[i];
        }
        m
    }

    // ------------------------------------------------------------------
    // Access
    // ------------------------------------------------------------------

    #[inline(always)]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        unsafe { *self.data.get_unchecked(i * self.cols + j) }
    }

    #[inline(always)]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        unsafe {
            *self.data.get_unchecked_mut(i * self.cols + j) = v;
        }
    }

    #[inline(always)]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline(always)]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        let c = self.cols;
        &mut self.data[i * c..(i + 1) * c]
    }

    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self.at(i, j)).collect()
    }

    /// Write a column in place.
    pub fn set_col(&mut self, j: usize, v: &[f64]) {
        assert_eq!(v.len(), self.rows);
        for (i, &x) in v.iter().enumerate() {
            self.set(i, j, x);
        }
    }

    /// Two distinct rows, mutably — the row-pair rotation primitive of the
    /// blocked (multi-RHS) cascade: a Givens rotation on a column block
    /// mixes two full rows at a time.
    pub fn rows_pair_mut(&mut self, i: usize, j: usize) -> (&mut [f64], &mut [f64]) {
        assert!(i != j && i < self.rows && j < self.rows);
        let c = self.cols;
        let (lo, hi) = (i.min(j), i.max(j));
        let (first, second) = self.data.split_at_mut(hi * c);
        let row_lo = &mut first[lo * c..lo * c + c];
        let row_hi = &mut second[..c];
        if lo == i {
            (row_lo, row_hi)
        } else {
            (row_hi, row_lo)
        }
    }

    /// Horizontal concatenation [A₁ | A₂ | …] of equal-height blocks.
    pub fn hstack(parts: &[Mat]) -> Mat {
        assert!(!parts.is_empty(), "hstack of nothing");
        let rows = parts[0].rows;
        let mut cols = 0;
        for p in parts {
            assert_eq!(p.rows, rows, "hstack: ragged heights");
            cols += p.cols;
        }
        let mut out = Mat::zeros(rows, cols);
        let mut off = 0;
        for p in parts {
            out.set_block(0, off, p);
            off += p.cols;
        }
        out
    }

    pub fn diagonal(&self) -> Vec<f64> {
        let n = self.rows.min(self.cols);
        (0..n).map(|i| self.at(i, i)).collect()
    }

    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }

    // ------------------------------------------------------------------
    // Structural ops
    // ------------------------------------------------------------------

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness.
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        t.set(j, i, self.at(i, j));
                    }
                }
            }
        }
        t
    }

    /// Gather the submatrix with given row and column indices.
    pub fn gather(&self, ridx: &[usize], cidx: &[usize]) -> Mat {
        let mut m = Mat::zeros(ridx.len(), cidx.len());
        for (a, &i) in ridx.iter().enumerate() {
            let src = self.row(i);
            let dst = m.row_mut(a);
            for (b, &j) in cidx.iter().enumerate() {
                dst[b] = src[j];
            }
        }
        m
    }

    /// Gather rows only.
    pub fn gather_rows(&self, ridx: &[usize]) -> Mat {
        let mut m = Mat::zeros(ridx.len(), self.cols);
        for (a, &i) in ridx.iter().enumerate() {
            m.row_mut(a).copy_from_slice(self.row(i));
        }
        m
    }

    /// Contiguous submatrix block [r0..r1) x [c0..c1).
    pub fn block(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Mat {
        assert!(r1 <= self.rows && c1 <= self.cols && r0 <= r1 && c0 <= c1);
        let mut m = Mat::zeros(r1 - r0, c1 - c0);
        for i in r0..r1 {
            m.row_mut(i - r0).copy_from_slice(&self.row(i)[c0..c1]);
        }
        m
    }

    /// Write `src` into the block starting at (r0, c0).
    pub fn set_block(&mut self, r0: usize, c0: usize, src: &Mat) {
        assert!(r0 + src.rows <= self.rows && c0 + src.cols <= self.cols);
        for i in 0..src.rows {
            self.row_mut(r0 + i)[c0..c0 + src.cols].copy_from_slice(src.row(i));
        }
    }

    /// Symmetric permutation P A Pᵀ expressed by `perm` (new index i takes
    /// old index perm[i]).
    pub fn sym_permute(&self, perm: &[usize]) -> Mat {
        assert!(self.is_square());
        assert_eq!(perm.len(), self.rows);
        let n = self.rows;
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            let pi = perm[i];
            let src = self.row(pi);
            let dst = m.row_mut(i);
            for j in 0..n {
                dst[j] = src[perm[j]];
            }
        }
        m
    }

    // ------------------------------------------------------------------
    // Elementwise / reductions
    // ------------------------------------------------------------------

    pub fn scale(&mut self, a: f64) -> &mut Self {
        for x in &mut self.data {
            *x *= a;
        }
        self
    }

    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (x, y) in self.data.iter_mut().zip(&other.data) {
            *x += *y;
        }
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Add `v` to every diagonal entry (K + σ²I).
    pub fn add_diag(&mut self, v: f64) -> &mut Self {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            let x = self.at(i, i);
            self.set(i, i, x + v);
        }
        self
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0, |m, x| m.max(x.abs()))
    }

    /// ‖A − Aᵀ‖∞ — symmetry defect.
    pub fn asymmetry(&self) -> f64 {
        assert!(self.is_square());
        let mut d: f64 = 0.0;
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                d = d.max((self.at(i, j) - self.at(j, i)).abs());
            }
        }
        d
    }

    /// Force exact symmetry: A ← (A + Aᵀ)/2.
    pub fn symmetrize(&mut self) -> &mut Self {
        assert!(self.is_square());
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let v = 0.5 * (self.at(i, j) + self.at(j, i));
                self.set(i, j, v);
                self.set(j, i, v);
            }
        }
        self
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline(always)]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline(always)]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        let show = self.rows.min(8);
        for i in 0..show {
            write!(f, "  ")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:10.4} ", self.at(i, j))?;
            }
            writeln!(f, "{}", if self.cols > 8 { "..." } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  ...")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let m = Mat::from_fn(3, 4, |i, j| (i * 10 + j) as f64);
        assert_eq!(m[(2, 3)], 23.0);
        assert_eq!(m.row(1), &[10.0, 11.0, 12.0, 13.0]);
        assert_eq!(m.col(0), vec![0.0, 10.0, 20.0]);
    }

    #[test]
    fn eye_and_diag() {
        let i3 = Mat::eye(3);
        assert_eq!(i3.diagonal(), vec![1.0, 1.0, 1.0]);
        let d = Mat::diag(&[2.0, 3.0]);
        assert_eq!(d[(0, 0)], 2.0);
        assert_eq!(d[(0, 1)], 0.0);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = Mat::from_fn(17, 41, |i, j| (i * 41 + j) as f64);
        let t = m.transpose();
        assert_eq!(t.rows, 41);
        assert_eq!(t[(40, 16)], m[(16, 40)]);
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn gather_block() {
        let m = Mat::from_fn(5, 5, |i, j| (i * 5 + j) as f64);
        let g = m.gather(&[0, 2], &[1, 4]);
        assert_eq!(g.data, vec![1.0, 4.0, 11.0, 14.0]);
        let b = m.block(1, 3, 2, 4);
        assert_eq!(b.data, vec![7.0, 8.0, 12.0, 13.0]);
    }

    #[test]
    fn set_block_roundtrip() {
        let mut m = Mat::zeros(4, 4);
        let b = Mat::from_fn(2, 2, |i, j| (i + j) as f64 + 1.0);
        m.set_block(1, 2, &b);
        assert_eq!(m.block(1, 3, 2, 4), b);
    }

    #[test]
    fn rows_pair_and_set_col() {
        let mut m = Mat::from_fn(4, 3, |i, j| (i * 3 + j) as f64);
        {
            let (r2, r0) = m.rows_pair_mut(2, 0);
            assert_eq!(r2, &[6.0, 7.0, 8.0]);
            assert_eq!(r0, &[0.0, 1.0, 2.0]);
            r0[1] = 99.0;
        }
        assert_eq!(m[(0, 1)], 99.0);
        m.set_col(2, &[9.0, 9.0, 9.0, 9.0]);
        assert_eq!(m.col(2), vec![9.0; 4]);
    }

    #[test]
    fn hstack_concatenates() {
        let a = Mat::filled(2, 1, 1.0);
        let b = Mat::from_fn(2, 2, |i, j| (i + j) as f64);
        let h = Mat::hstack(&[a, b]);
        assert_eq!(h.rows, 2);
        assert_eq!(h.cols, 3);
        assert_eq!(h.row(0), &[1.0, 0.0, 1.0]);
        assert_eq!(h.row(1), &[1.0, 1.0, 2.0]);
    }

    #[test]
    fn sym_permute_is_conjugation() {
        let a = {
            let mut a = Mat::from_fn(4, 4, |i, j| ((i * 7 + j * 3) % 5) as f64);
            a.symmetrize();
            a
        };
        let perm = vec![2, 0, 3, 1];
        let p = a.sym_permute(&perm);
        for i in 0..4 {
            for j in 0..4 {
                assert_eq!(p[(i, j)], a[(perm[i], perm[j])]);
            }
        }
        assert!(p.asymmetry() < 1e-15);
    }

    #[test]
    fn arithmetic() {
        let a = Mat::filled(2, 2, 1.0);
        let b = Mat::filled(2, 2, 2.0);
        assert_eq!(a.add(&b).data, vec![3.0; 4]);
        assert_eq!(b.sub(&a).data, vec![1.0; 4]);
        let mut c = a.clone();
        c.scale(4.0);
        assert_eq!(c.data, vec![4.0; 4]);
        c.add_diag(1.0);
        assert_eq!(c[(0, 0)], 5.0);
        assert_eq!(c[(0, 1)], 4.0);
    }

    #[test]
    fn norms() {
        let m = Mat::from_rows(&[&[3.0, 0.0], &[0.0, 4.0]]);
        assert!((m.frob_norm() - 5.0).abs() < 1e-15);
        assert_eq!(m.max_abs(), 4.0);
    }

    #[test]
    fn symmetrize_fixes_asymmetry() {
        let mut m = Mat::from_rows(&[&[1.0, 2.0], &[4.0, 1.0]]);
        assert!(m.asymmetry() > 1.0);
        m.symmetrize();
        assert_eq!(m[(0, 1)], 3.0);
        assert_eq!(m.asymmetry(), 0.0);
    }
}
