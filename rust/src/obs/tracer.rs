//! Request-scoped hierarchical spans.
//!
//! A trace is born when the router sees a request that asked for one
//! ([`start_request`]), lives as an `Arc<TraceInner>` carried in a
//! thread-local [`SpanCtx`], and dies into an immutable [`Trace`] pushed
//! onto a bounded ring ([`recent_traces`]) and, if configured, streamed
//! to a Chrome trace-event file (`chrome.rs`).
//!
//! The fast path is the whole design: `obs::span!` first does one
//! relaxed atomic load ([`tracing_possible`]) and, when no trace is
//! live anywhere in the process, neither formats its name nor touches
//! thread-local state. Span guards record *observations only* — they
//! never feed anything back into the computation, which is why the
//! bit-determinism contract (`tests/par_determinism.rs`) holds with
//! tracing on or off.

use std::cell::RefCell;
use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

/// Number of live (unfinished) traces in the process. The `span!` gate:
/// zero means every guard constructor is a no-op.
static ACTIVE_TRACES: AtomicU64 = AtomicU64::new(0);

/// When set (e.g. `--trace-out` on the CLI), the router traces every
/// request instead of only those with `"trace": true`.
static TRACE_ALL: AtomicU64 = AtomicU64::new(0);

static NEXT_TRACE_ID: AtomicU64 = AtomicU64::new(1);

/// Completed-trace ring capacity (`ServiceConfig.trace_ring`).
static TRACE_CAP: AtomicUsize = AtomicUsize::new(32);

/// Hard per-trace span bound: beyond this, spans are counted as dropped
/// rather than stored, so a pathological request cannot hold unbounded
/// memory.
const MAX_SPANS_PER_TRACE: usize = 4096;

static TRACES: OnceLock<Mutex<VecDeque<Arc<Trace>>>> = OnceLock::new();

fn trace_ring() -> &'static Mutex<VecDeque<Arc<Trace>>> {
    TRACES.get_or_init(|| Mutex::new(VecDeque::new()))
}

static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Process observability epoch: a fixed `Instant` all traces and log
/// events are timestamped against, so successive traces lay out on one
/// timeline in the Chrome export.
pub(crate) fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

pub(crate) fn epoch_us() -> u64 {
    Instant::now().saturating_duration_since(epoch()).as_micros() as u64
}

/// `true` while at least one trace is live anywhere in the process.
/// This is the single relaxed load the disabled path pays.
#[inline]
pub fn tracing_possible() -> bool {
    ACTIVE_TRACES.load(Ordering::Relaxed) != 0
}

/// Should the router trace every request (set when `trace_out` is
/// configured)?
pub fn trace_all() -> bool {
    TRACE_ALL.load(Ordering::Relaxed) != 0
}

/// Toggle tracing of every request (normally driven by
/// `ServiceConfig.trace_out`).
pub fn set_trace_all(on: bool) {
    TRACE_ALL.store(u64::from(on), Ordering::Relaxed);
}

/// Set the completed-trace ring capacity (values below 1 clamp to 1).
pub fn set_trace_capacity(n: usize) {
    TRACE_CAP.store(n.max(1), Ordering::Relaxed);
}

/// Current completed-trace ring capacity.
pub fn trace_capacity() -> usize {
    TRACE_CAP.load(Ordering::Relaxed).max(1)
}

fn thread_name() -> String {
    std::thread::current().name().unwrap_or("unnamed").to_string()
}

/// One closed span, as stored on its trace.
#[derive(Clone, Debug)]
pub struct SpanRecord {
    /// Span id, unique within the trace; the root span is id 1.
    pub id: u64,
    /// Parent span id (0 for the root).
    pub parent: u64,
    /// Human-readable name, e.g. `stage 2 fwd b=9`.
    pub name: String,
    /// Start, µs since the trace began.
    pub start_us: u64,
    /// Wall-clock duration in µs.
    pub dur_us: u64,
    /// Name of the thread the span closed on.
    pub thread: String,
    /// For pool jobs: µs spent queued before execution began (0 elsewhere).
    pub queue_us: u64,
}

struct TraceState {
    spans: Vec<SpanRecord>,
    dropped: u64,
    closed: bool,
}

/// Shared mutable core of a live trace.
struct TraceInner {
    id: u64,
    name: String,
    t0: Instant,
    start_epoch_us: u64,
    next_span: AtomicU64,
    state: Mutex<TraceState>,
}

impl TraceInner {
    fn now_us(&self) -> u64 {
        Instant::now().saturating_duration_since(self.t0).as_micros() as u64
    }

    fn push(&self, rec: SpanRecord) {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return; // a straggler job outlived the request; drop its span
        }
        if st.spans.len() >= MAX_SPANS_PER_TRACE {
            st.dropped += 1;
        } else {
            st.spans.push(rec);
        }
    }
}

/// A completed, immutable trace.
#[derive(Debug)]
pub struct Trace {
    /// Process-unique trace id.
    pub id: u64,
    /// Root name (the protocol op).
    pub name: String,
    /// Total request wall time in µs.
    pub total_us: u64,
    /// Trace start, µs since the process observability epoch.
    pub start_epoch_us: u64,
    /// All recorded spans (root included), sorted by start time.
    pub spans: Vec<SpanRecord>,
    /// Spans discarded because the per-trace bound was hit.
    pub dropped: u64,
}

/// The propagation token: which trace (if any) the current thread is
/// inside, and which span is its cursor. Cheap to clone (`Option<Arc>` +
/// `u64`); captured by the `par` pool at submit time and re-installed on
/// the worker around each job.
#[derive(Clone, Default)]
pub struct SpanCtx {
    inner: Option<Arc<TraceInner>>,
    span: u64,
}

impl SpanCtx {
    /// Is there a live trace behind this context?
    pub fn is_active(&self) -> bool {
        self.inner.is_some()
    }
}

thread_local! {
    static CURRENT: RefCell<SpanCtx> = RefCell::new(SpanCtx::default());
}

/// Snapshot the calling thread's span context (inactive when no trace is
/// live — the common case costs one atomic load).
pub fn current_ctx() -> SpanCtx {
    if !tracing_possible() {
        return SpanCtx::default();
    }
    CURRENT.with(|c| c.borrow().clone())
}

/// RAII guard for one span. Construct through [`crate::obs::span!`]; the
/// span closes (and is recorded) when the guard drops.
pub struct SpanGuard {
    trace: Option<Arc<TraceInner>>,
    id: u64,
    prev: u64,
    name: String,
    start_us: u64,
    start: Option<Instant>,
    queue_us: u64,
}

impl SpanGuard {
    /// The no-op guard: nothing recorded, nothing restored.
    pub fn disabled() -> SpanGuard {
        SpanGuard {
            trace: None,
            id: 0,
            prev: 0,
            name: String::new(),
            start_us: 0,
            start: None,
            queue_us: 0,
        }
    }

    /// Open a span under the thread's current context. `name` is only
    /// invoked when a trace is actually live on this thread, so the
    /// disabled path never formats.
    pub fn begin_with<F: FnOnce() -> String>(name: F) -> SpanGuard {
        CURRENT.with(|c| {
            let mut cur = c.borrow_mut();
            let Some(tr) = cur.inner.clone() else {
                return SpanGuard::disabled();
            };
            let id = tr.next_span.fetch_add(1, Ordering::Relaxed);
            let prev = cur.span;
            cur.span = id;
            drop(cur);
            let start_us = tr.now_us();
            SpanGuard {
                trace: Some(tr),
                id,
                prev,
                name: name(),
                start_us,
                start: Some(Instant::now()),
                queue_us: 0,
            }
        })
    }

    /// Record pool-queue wait time on this span (µs).
    pub fn set_queue_us(&mut self, us: u64) {
        self.queue_us = us;
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(tr) = self.trace.take() else { return };
        CURRENT.with(|c| {
            let mut cur = c.borrow_mut();
            if cur.span == self.id {
                cur.span = self.prev;
            }
        });
        let dur_us = self.start.map(|s| s.elapsed().as_micros() as u64).unwrap_or(0);
        tr.push(SpanRecord {
            id: self.id,
            parent: self.prev,
            name: std::mem::take(&mut self.name),
            start_us: self.start_us,
            dur_us,
            thread: thread_name(),
            queue_us: self.queue_us,
        });
    }
}

/// Guard installing a foreign [`SpanCtx`] on the current thread for the
/// duration of a pool job (or batched request), with a span named
/// `name` parented to the submitter's cursor span. Restores the
/// thread's previous context on drop.
pub struct JobGuard {
    prev: Option<SpanCtx>,
    span: Option<SpanGuard>,
}

/// Enter `ctx` on the calling thread. No-op (and allocation-free) when
/// `ctx` is inactive. `enqueued` is the submit-time instant, measured
/// into the span's `queue_us`.
pub fn enter_job(ctx: &SpanCtx, name: &'static str, enqueued: Option<Instant>) -> JobGuard {
    if !ctx.is_active() {
        return JobGuard { prev: None, span: None };
    }
    let prev = CURRENT.with(|c| std::mem::replace(&mut *c.borrow_mut(), ctx.clone()));
    let mut span = SpanGuard::begin_with(|| name.to_string());
    if let Some(enq) = enqueued {
        span.set_queue_us(enq.elapsed().as_micros() as u64);
    }
    JobGuard { prev: Some(prev), span: Some(span) }
}

impl Drop for JobGuard {
    fn drop(&mut self) {
        // Close the span while the job's ctx is still installed, then
        // restore whatever the thread had before.
        self.span.take();
        if let Some(prev) = self.prev.take() {
            CURRENT.with(|c| *c.borrow_mut() = prev);
        }
    }
}

/// Root guard for one traced request. Dropping (or [`finish`ing]) the
/// guard closes the root span, freezes the trace, pushes it on the ring
/// and streams it to the Chrome exporter.
///
/// [`finish`ing]: RequestGuard::finish
pub struct RequestGuard {
    trace: Arc<TraceInner>,
    prev: SpanCtx,
    start: Instant,
    done: bool,
}

/// Begin a traced request named `name` (the protocol op) rooted on the
/// calling thread.
pub fn start_request(name: &str) -> RequestGuard {
    ACTIVE_TRACES.fetch_add(1, Ordering::Relaxed);
    let id = NEXT_TRACE_ID.fetch_add(1, Ordering::Relaxed);
    let start_epoch_us = epoch_us();
    let t0 = Instant::now();
    let tr = Arc::new(TraceInner {
        id,
        name: name.to_string(),
        t0,
        start_epoch_us,
        next_span: AtomicU64::new(2), // root is span 1
        state: Mutex::new(TraceState { spans: Vec::new(), dropped: 0, closed: false }),
    });
    let prev = CURRENT.with(|c| {
        std::mem::replace(&mut *c.borrow_mut(), SpanCtx { inner: Some(Arc::clone(&tr)), span: 1 })
    });
    RequestGuard { trace: tr, prev, start: t0, done: false }
}

impl RequestGuard {
    /// Close the trace and return it (also lands on the ring and the
    /// Chrome exporter).
    pub fn finish(mut self) -> Arc<Trace> {
        self.do_finish()
    }

    fn do_finish(&mut self) -> Arc<Trace> {
        self.done = true;
        CURRENT.with(|c| *c.borrow_mut() = std::mem::take(&mut self.prev));
        let total_us = (self.start.elapsed().as_micros() as u64).max(1);
        let (mut spans, dropped) = {
            let mut st = self.trace.state.lock().unwrap();
            st.closed = true;
            (std::mem::take(&mut st.spans), st.dropped)
        };
        spans.push(SpanRecord {
            id: 1,
            parent: 0,
            name: self.trace.name.clone(),
            start_us: 0,
            dur_us: total_us,
            thread: thread_name(),
            queue_us: 0,
        });
        spans.sort_by_key(|s| (s.start_us, s.id));
        let trace = Arc::new(Trace {
            id: self.trace.id,
            name: self.trace.name.clone(),
            total_us,
            start_epoch_us: self.trace.start_epoch_us,
            spans,
            dropped,
        });
        {
            let mut ring = trace_ring().lock().unwrap();
            let cap = trace_capacity();
            while ring.len() >= cap {
                ring.pop_front();
            }
            ring.push_back(Arc::clone(&trace));
        }
        super::chrome::export(&trace);
        ACTIVE_TRACES.fetch_sub(1, Ordering::Relaxed);
        trace
    }
}

impl Drop for RequestGuard {
    fn drop(&mut self) {
        if !self.done {
            let _ = self.do_finish();
        }
    }
}

/// The last `tail` completed traces, oldest first.
pub fn recent_traces(tail: usize) -> Vec<Arc<Trace>> {
    let ring = trace_ring().lock().unwrap();
    let skip = ring.len().saturating_sub(tail);
    ring.iter().skip(skip).cloned().collect()
}

fn span_node(s: &SpanRecord, children: &BTreeMap<u64, Vec<&SpanRecord>>) -> Json {
    let kids = children.get(&s.id).map(Vec::as_slice).unwrap_or(&[]);
    let child_us: u64 = kids.iter().map(|k| k.dur_us).sum();
    let mut j = Json::obj()
        .with("span_id", Json::Num(s.id as f64))
        .with("name", Json::Str(s.name.clone()))
        .with("wall_us", Json::Num(s.dur_us as f64))
        .with("self_us", Json::Num(s.dur_us.saturating_sub(child_us) as f64))
        .with("child_us", Json::Num(child_us as f64))
        .with("start_us", Json::Num(s.start_us as f64))
        .with("thread", Json::Str(s.thread.clone()));
    if s.queue_us > 0 {
        j = j.with("queue_us", Json::Num(s.queue_us as f64));
    }
    j.with("children", Json::Arr(kids.iter().map(|k| span_node(k, children)).collect()))
}

/// Render a completed trace as a span *tree* (the `"trace"` echo and the
/// `trace` op payload): per span its name, wall µs, self vs child µs,
/// executing thread and pool-queue wait.
pub fn trace_tree_json(t: &Trace) -> Json {
    let ids: BTreeSet<u64> = t.spans.iter().map(|s| s.id).collect();
    let mut children: BTreeMap<u64, Vec<&SpanRecord>> = BTreeMap::new();
    let mut root: Option<&SpanRecord> = None;
    for s in &t.spans {
        if s.id == 1 {
            root = Some(s);
        } else {
            // Re-parent orphans (parent span dropped at the bound) to root.
            let parent = if ids.contains(&s.parent) && s.parent != s.id { s.parent } else { 1 };
            children.entry(parent).or_default().push(s);
        }
    }
    for v in children.values_mut() {
        v.sort_by_key(|s| (s.start_us, s.id));
    }
    let mut j = Json::obj()
        .with("trace_id", Json::Num(t.id as f64))
        .with("name", Json::Str(t.name.clone()))
        .with("total_us", Json::Num(t.total_us as f64))
        .with("n_spans", Json::Num(t.spans.len() as f64));
    if t.dropped > 0 {
        j = j.with("dropped_spans", Json::Num(t.dropped as f64));
    }
    match root {
        Some(r) => j.with("root", span_node(r, &children)),
        None => j.with("root", Json::Null),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_span_is_a_noop() {
        // No trace live on this thread: the guard must record nothing
        // and the thread ctx must stay inactive.
        assert!(!current_ctx().is_active());
        let g = crate::obs::span!("never formatted {}", 1 / 1);
        drop(g);
        assert!(!current_ctx().is_active());
    }

    #[test]
    fn span_tree_parents_and_self_time() {
        let req = start_request("unit-op");
        {
            let _a = crate::obs::span!("outer");
            let _b = crate::obs::span!("inner {}", 42);
        }
        let trace = req.finish();
        assert!(!current_ctx().is_active(), "ctx restored after finish");
        assert_eq!(trace.spans.len(), 3);
        let root = trace.spans.iter().find(|s| s.id == 1).unwrap();
        assert_eq!(root.parent, 0);
        assert_eq!(root.name, "unit-op");
        let outer = trace.spans.iter().find(|s| s.name == "outer").unwrap();
        let inner = trace.spans.iter().find(|s| s.name == "inner 42").unwrap();
        assert_eq!(outer.parent, 1);
        assert_eq!(inner.parent, outer.id);

        let tree = trace_tree_json(&trace);
        let rendered = tree.dump();
        assert!(rendered.contains("\"name\":\"unit-op\""));
        assert!(rendered.contains("\"name\":\"inner 42\""));
    }

    #[test]
    fn ctx_propagates_across_threads() {
        let req = start_request("xthread");
        let parent_span = crate::obs::span!("submit");
        let ctx = current_ctx();
        assert!(ctx.is_active());
        let enq = Instant::now();
        let h = std::thread::Builder::new()
            .name("obs-test-worker".into())
            .spawn(move || {
                let _g = enter_job(&ctx, "pool.job", Some(enq));
                let _s = crate::obs::span!("worker-work");
            })
            .unwrap();
        h.join().unwrap();
        drop(parent_span);
        let trace = req.finish();
        let submit = trace.spans.iter().find(|s| s.name == "submit").unwrap();
        let job = trace.spans.iter().find(|s| s.name == "pool.job").unwrap();
        let work = trace.spans.iter().find(|s| s.name == "worker-work").unwrap();
        assert_eq!(job.parent, submit.id, "pool job parents to submitting span");
        assert_eq!(work.parent, job.id);
        assert_eq!(job.thread, "obs-test-worker");
    }

    #[test]
    fn trace_ring_is_bounded() {
        let cap = trace_capacity();
        let mut last_id = 0;
        for i in 0..cap + 5 {
            let r = start_request(&format!("ring-{i}"));
            last_id = r.finish().id;
        }
        // Other tests may be adding traces concurrently; the bound and
        // the presence of our newest trace are the stable assertions.
        let recent = recent_traces(usize::MAX);
        assert!(recent.len() <= cap);
        assert!(recent.iter().any(|t| t.id == last_id));
    }

    #[test]
    fn nested_requests_restore_outer_ctx() {
        let outer = start_request("outer-req");
        let outer_ctx = current_ctx();
        {
            let inner = start_request("inner-req");
            assert!(current_ctx().is_active());
            inner.finish();
        }
        // Back on the outer trace, not deactivated.
        let back = current_ctx();
        assert!(back.is_active());
        assert!(Arc::ptr_eq(
            outer_ctx.inner.as_ref().unwrap(),
            back.inner.as_ref().unwrap()
        ));
        outer.finish();
    }
}
