//! Leveled structured event log on a bounded ring.
//!
//! `obs::log!` events carry a level, a `target` (the subsystem, e.g.
//! `"gp.sharded"`), a formatted message and optional key/value fields.
//! They land on one process-wide ring of bounded capacity
//! (`ServiceConfig.log_ring`) and are drained — non-destructively — by
//! the coordinator's `{"op":"logs"}`. The intended use is *rare, telling
//! events*: silent-fallback sites (rBCM→PoE degeneration, predict prior
//! fallbacks, factor-cache displacement, busy rejections), not per-item
//! chatter.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::util::json::Json;

/// Event severity. Ordered: `Debug < Info < Warn < Error`.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Level {
    /// High-volume diagnostics (probe-trace engagement, cache traffic).
    Debug = 0,
    /// Normal lifecycle events.
    Info = 1,
    /// Degraded-but-serving: silent fallbacks, displacement, rejection.
    Warn = 2,
    /// Failed requests and internal errors.
    Error = 3,
}

impl Level {
    /// Parse a protocol-level string (`"debug" | "info" | "warn" |
    /// "warning" | "error"`), case-insensitive.
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "debug" => Some(Level::Debug),
            "info" => Some(Level::Info),
            "warn" | "warning" => Some(Level::Warn),
            "error" => Some(Level::Error),
            _ => None,
        }
    }

    /// Canonical lowercase name.
    pub fn as_str(&self) -> &'static str {
        match self {
            Level::Debug => "debug",
            Level::Info => "info",
            Level::Warn => "warn",
            Level::Error => "error",
        }
    }
}

/// One logged event.
#[derive(Clone, Debug)]
pub struct Event {
    /// Process-wide monotone sequence number (1-based).
    pub seq: u64,
    /// µs since the process observability epoch.
    pub us: u64,
    /// Severity.
    pub level: Level,
    /// Emitting subsystem, e.g. `"coordinator.batcher"`.
    pub target: &'static str,
    /// Formatted message.
    pub message: String,
    /// Structured key/value fields.
    pub fields: Vec<(&'static str, String)>,
}

static NEXT_SEQ: AtomicU64 = AtomicU64::new(1);
static LOG_CAP: AtomicUsize = AtomicUsize::new(256);
/// Minimum recorded level, as a `Level` discriminant.
static MIN_LEVEL: AtomicUsize = AtomicUsize::new(Level::Debug as usize);
static EVENTS: OnceLock<Mutex<VecDeque<Event>>> = OnceLock::new();

fn ring() -> &'static Mutex<VecDeque<Event>> {
    EVENTS.get_or_init(|| Mutex::new(VecDeque::new()))
}

/// Would an event at `level` be recorded? The `log!` macro checks this
/// before formatting anything.
#[inline]
pub fn log_enabled(level: Level) -> bool {
    level as usize >= MIN_LEVEL.load(Ordering::Relaxed)
}

/// Set the minimum recorded level (events below it are not even
/// formatted).
pub fn set_log_level(level: Level) {
    MIN_LEVEL.store(level as usize, Ordering::Relaxed);
}

/// Set the event-ring capacity (values below 1 clamp to 1).
pub fn set_log_capacity(n: usize) {
    LOG_CAP.store(n.max(1), Ordering::Relaxed);
}

/// Current event-ring capacity.
pub fn log_capacity() -> usize {
    LOG_CAP.load(Ordering::Relaxed).max(1)
}

/// Total events ever recorded (for tests; survives ring displacement).
pub fn log_seq() -> u64 {
    NEXT_SEQ.load(Ordering::Relaxed) - 1
}

/// Record one event. Call through [`crate::obs::log!`], which gates on
/// [`log_enabled`] first.
pub fn push_event(
    level: Level,
    target: &'static str,
    message: String,
    fields: Vec<(&'static str, String)>,
) {
    let ev = Event {
        seq: NEXT_SEQ.fetch_add(1, Ordering::Relaxed),
        us: super::tracer::epoch_us(),
        level,
        target,
        message,
        fields,
    };
    let mut r = ring().lock().unwrap();
    let cap = log_capacity();
    while r.len() >= cap {
        r.pop_front();
    }
    r.push_back(ev);
}

/// The last `tail` events at or above `min`, oldest first. Reading does
/// not consume the ring.
pub fn recent_events(min: Level, tail: usize) -> Vec<Event> {
    let r = ring().lock().unwrap();
    let matching: Vec<Event> = r.iter().filter(|e| e.level >= min).cloned().collect();
    let skip = matching.len().saturating_sub(tail);
    matching.into_iter().skip(skip).collect()
}

/// Serialize one event for the `logs` op.
pub fn event_json(e: &Event) -> Json {
    let mut fields = Json::obj();
    for (k, v) in &e.fields {
        fields = fields.with(*k, Json::Str(v.clone()));
    }
    Json::obj()
        .with("seq", Json::Num(e.seq as f64))
        .with("us", Json::Num(e.us as f64))
        .with("level", Json::Str(e.level.as_str().to_string()))
        .with("target", Json::Str(e.target.to_string()))
        .with("message", Json::Str(e.message.clone()))
        .with("fields", fields)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order_and_parse() {
        assert!(Level::Debug < Level::Info && Level::Info < Level::Warn);
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("warning"), Some(Level::Warn));
        assert_eq!(Level::parse("loud"), None);
        assert_eq!(Level::Error.as_str(), "error");
    }

    #[test]
    fn ring_is_bounded_and_keeps_newest() {
        let cap = log_capacity();
        let mut last_seq = 0;
        for i in 0..cap + 50 {
            crate::obs::log!(Info, "obs.test", {"i" => i}, "bound probe {i}");
            last_seq = log_seq();
        }
        let all = recent_events(Level::Debug, usize::MAX);
        assert!(all.len() <= cap);
        assert!(all.iter().any(|e| e.seq == last_seq));
        // Oldest-first ordering.
        for w in all.windows(2) {
            assert!(w[0].seq < w[1].seq);
        }
    }

    #[test]
    fn level_filter_and_fields() {
        crate::obs::log!(Warn, "obs.test", {"shard" => 3, "experts" => 2}, "degenerate {}", "bcm");
        let warns = recent_events(Level::Warn, usize::MAX);
        let ev = warns.iter().rev().find(|e| e.target == "obs.test").unwrap();
        assert_eq!(ev.level, Level::Warn);
        assert_eq!(ev.message, "degenerate bcm");
        assert!(ev.fields.iter().any(|(k, v)| *k == "shard" && v == "3"));
        assert!(warns.iter().all(|e| e.level >= Level::Warn));
        let rendered = event_json(ev).dump();
        assert!(rendered.contains("\"level\":\"warn\""));
        assert!(rendered.contains("\"shard\":\"3\""));
    }

    #[test]
    fn tail_takes_newest() {
        for i in 0..10 {
            crate::obs::log!(Debug, "obs.tail", "tail probe {i}");
        }
        let tail = recent_events(Level::Debug, 3);
        assert_eq!(tail.len(), 3);
        assert!(tail[2].seq >= tail[0].seq + 2);
    }
}
