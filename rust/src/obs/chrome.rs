//! Chrome trace-event streaming exporter.
//!
//! When `ServiceConfig.trace_out` (CLI `--trace-out file.json`) is set,
//! every completed trace appends its spans as complete (`"ph":"X"`)
//! events in the Chrome trace-event JSON array format. The file is
//! opened with `[` and intentionally never closed — both
//! `chrome://tracing` and [ui.perfetto.dev](https://ui.perfetto.dev)
//! accept the unterminated array, which is what makes streaming from a
//! live server possible. Thread names map to stable small `tid`s via
//! `"ph":"M"` metadata events, so the flamegraph groups lanes by pool
//! worker.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::Mutex;

use super::tracer::Trace;
use crate::util::json::Json;

struct ChromeOut {
    w: BufWriter<File>,
    wrote_any: bool,
    tids: BTreeMap<String, u64>,
}

static OUT: Mutex<Option<ChromeOut>> = Mutex::new(None);

/// Open (truncating) `path` as the streaming trace-event sink. Replaces
/// any previously configured sink.
pub fn set_trace_out(path: &Path) -> std::io::Result<()> {
    let f = File::create(path)?;
    let mut w = BufWriter::new(f);
    w.write_all(b"[\n")?;
    w.flush()?;
    *OUT.lock().unwrap() = Some(ChromeOut { w, wrote_any: false, tids: BTreeMap::new() });
    Ok(())
}

/// Is a trace-event sink configured?
pub fn trace_out_active() -> bool {
    OUT.lock().unwrap().is_some()
}

/// Stop exporting (flushes and drops the writer; the file stays valid
/// for the viewers).
pub fn clear_trace_out() {
    *OUT.lock().unwrap() = None;
}

fn write_event(out: &mut ChromeOut, ev: &Json) -> std::io::Result<()> {
    if out.wrote_any {
        out.w.write_all(b",\n")?;
    }
    out.wrote_any = true;
    out.w.write_all(ev.dump().as_bytes())
}

/// Append one completed trace to the sink (no-op when none configured).
/// On any I/O error the sink is dropped and a warn event is logged —
/// export failure must never take serving down.
pub(crate) fn export(trace: &Trace) {
    let mut guard = OUT.lock().unwrap();
    let Some(out) = guard.as_mut() else { return };
    let mut failed = false;
    for s in &trace.spans {
        let tid = match out.tids.get(&s.thread) {
            Some(&t) => t,
            None => {
                let t = out.tids.len() as u64 + 1;
                out.tids.insert(s.thread.clone(), t);
                let meta = Json::obj()
                    .with("name", Json::Str("thread_name".into()))
                    .with("ph", Json::Str("M".into()))
                    .with("pid", Json::Num(1.0))
                    .with("tid", Json::Num(t as f64))
                    .with("args", Json::obj().with("name", Json::Str(s.thread.clone())));
                if write_event(out, &meta).is_err() {
                    failed = true;
                }
                t
            }
        };
        let mut args = Json::obj().with("trace_id", Json::Num(trace.id as f64));
        if s.queue_us > 0 {
            args = args.with("queue_us", Json::Num(s.queue_us as f64));
        }
        let ev = Json::obj()
            .with("name", Json::Str(s.name.clone()))
            .with("cat", Json::Str("obs".into()))
            .with("ph", Json::Str("X".into()))
            .with("ts", Json::Num((trace.start_epoch_us + s.start_us) as f64))
            .with("dur", Json::Num(s.dur_us.max(1) as f64))
            .with("pid", Json::Num(1.0))
            .with("tid", Json::Num(tid as f64))
            .with("args", args);
        if write_event(out, &ev).is_err() {
            failed = true;
            break;
        }
    }
    if !failed {
        failed = out.w.flush().is_err();
    }
    if failed {
        *guard = None;
        drop(guard);
        crate::obs::log!(Warn, "obs.chrome", "trace-event export failed; trace_out disabled");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::start_request;

    #[test]
    #[cfg_attr(miri, ignore)] // touches the real filesystem
    fn exports_flamegraph_loadable_events() {
        let path = std::env::temp_dir().join(format!("mka_obs_chrome_{}.json", std::process::id()));
        set_trace_out(&path).unwrap();
        let req = start_request("chrome-unit");
        {
            let _s = crate::obs::span!("exported-span");
        }
        req.finish();
        clear_trace_out();
        let body = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert!(body.starts_with("[\n"));
        assert!(body.contains("\"ph\":\"X\""));
        assert!(body.contains("\"name\":\"exported-span\""));
        assert!(body.contains("\"thread_name\""));
        // Each event line after the opening bracket must parse as JSON.
        let mut parsed = 0;
        for line in body.lines().skip(1) {
            let line = line.trim_end_matches(',');
            if line.is_empty() {
                continue;
            }
            Json::parse(line).unwrap();
            parsed += 1;
        }
        assert!(parsed >= 2);
    }
}
