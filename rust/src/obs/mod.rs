//! Observability plane: request-scoped tracing, structured event log,
//! and Chrome trace-event export.
//!
//! Three rules shape everything here:
//!
//! 1. **One load when off.** Every instrumentation point begins with a
//!    single relaxed atomic load ([`tracing_possible`] /
//!    [`log_enabled`]); when it says "off", no name is formatted, no
//!    thread-local is touched, no lock is taken.
//! 2. **Strictly off the value path.** Spans and events *observe* —
//!    they never feed anything back into a computation, so every
//!    solve/predict/train is bit-for-bit identical with tracing on or
//!    off (`tests/par_determinism.rs` pins this).
//! 3. **Bounded everywhere.** Completed traces and log events live on
//!    rings of fixed capacity ([`set_trace_capacity`] /
//!    [`set_log_capacity`]), and a single trace stores at most a fixed
//!    number of spans; a hot server cannot grow without bound.
//!
//! Span contexts propagate across the [`crate::par`] pool: the
//! submitting thread's [`SpanCtx`] is captured at enqueue and installed
//! around each job on the worker ([`enter_job`]), so worker-executed
//! work parents to its submitting span and carries its queue-wait time.

pub mod chrome;
pub mod log;
pub mod tracer;

pub use chrome::{clear_trace_out, set_trace_out, trace_out_active};
pub use log::{
    event_json, log_capacity, log_enabled, log_seq, push_event, recent_events, set_log_capacity,
    set_log_level, Event, Level,
};
pub use tracer::{
    current_ctx, enter_job, recent_traces, set_trace_all, set_trace_capacity, start_request,
    trace_all, trace_capacity, trace_tree_json, tracing_possible, JobGuard, RequestGuard, SpanCtx,
    SpanGuard, SpanRecord, Trace,
};

// The macros are exported at crate root (`#[macro_export]`) under
// collision-safe names; re-export them here so call sites read
// `obs::span!(...)` / `obs::log!(...)`.
pub use crate::{obs_log as log, obs_span as span};

/// Open a hierarchical timed span named by a format string. Returns a
/// guard; the span closes when the guard drops. When no trace is live
/// the cost is one relaxed atomic load and the format is never
/// evaluated.
///
/// ```ignore
/// let _sp = obs::span!("stage {i} fwd b={cols}");
/// ```
#[macro_export]
macro_rules! obs_span {
    ($($arg:tt)*) => {
        if $crate::obs::tracing_possible() {
            $crate::obs::SpanGuard::begin_with(|| format!($($arg)*))
        } else {
            $crate::obs::SpanGuard::disabled()
        }
    };
}

/// Record a leveled structured event: `obs::log!(Warn, "target",
/// {"key" => value, ...}, "message {fmt}")` — the field block is
/// optional. Nothing is formatted when the level is below the recording
/// threshold.
#[macro_export]
macro_rules! obs_log {
    ($lvl:ident, $target:expr, { $($k:literal => $v:expr),* $(,)? }, $($arg:tt)*) => {
        if $crate::obs::log_enabled($crate::obs::Level::$lvl) {
            $crate::obs::push_event(
                $crate::obs::Level::$lvl,
                $target,
                format!($($arg)*),
                vec![$(($k, format!("{}", $v))),*],
            );
        }
    };
    ($lvl:ident, $target:expr, $($arg:tt)*) => {
        if $crate::obs::log_enabled($crate::obs::Level::$lvl) {
            $crate::obs::push_event(
                $crate::obs::Level::$lvl,
                $target,
                format!($($arg)*),
                Vec::new(),
            );
        }
    };
}
