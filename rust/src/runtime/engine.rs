//! The XLA execution engine: an actor thread owning the PJRT CPU client
//! and the compiled executables, plus a `Send + Sync` handle.
//!
//! Interchange contract (see `python/compile/aot.py` and
//! `/opt/xla-example/README.md`):
//! * artifacts are HLO **text**; `HloModuleProto::from_text_file` reassigns
//!   instruction ids, avoiding the 64-bit-id proto incompatibility;
//! * all exported functions were lowered with `return_tuple=True`, so
//!   results are unwrapped with `to_tuple1`;
//! * all shapes are fixed — the handle pads inputs (zero rows / identity
//!   diagonal) and slices outputs back down.
//!
//! The actual PJRT bindings live behind the `xla` cargo feature (the
//! offline build has no `xla` crate). Without the feature the full
//! manifest / padding / actor protocol still compiles and is tested, but
//! [`XlaEngine::start`] fails fast with a clear error so callers fall
//! back to the native kernels.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::kernels::gram::TileEngine;
use crate::la::dense::Mat;

use super::Manifest;

/// Requests served by the engine actor.
enum Request {
    /// RBF gram tile on padded blocks.
    RbfTile { x: Mat, y: Mat, ell: f64, sf2: f64, resp: mpsc::Sender<Result<Mat>> },
    /// G = AᵀA on a padded block.
    Ata { a: Mat, resp: mpsc::Sender<Result<Mat>> },
    /// α = (K + σ²I)⁻¹ y on a padded system.
    CholSolve { k: Mat, y: Vec<f64>, sigma2: f64, resp: mpsc::Sender<Result<Vec<f64>>> },
    /// Blocked multi-RHS solve: A = (K + σ²I)⁻¹ Y for Y with b columns.
    /// One request for the whole block; the backend uses the dedicated
    /// multi-RHS artifact in `chol_b`-wide chunks (one factorization per
    /// chunk) when it is present, and otherwise loops the single-RHS
    /// artifact per column reusing one K literal.
    CholSolveMat { k: Mat, ys: Mat, sigma2: f64, resp: mpsc::Sender<Result<Mat>> },
    Shutdown,
}

/// Thread-safe handle to the engine actor. Cloning is cheap.
#[derive(Clone)]
pub struct EngineHandle {
    tx: Arc<Mutex<mpsc::Sender<Request>>>,
    manifest: Arc<Manifest>,
}

/// The engine itself — spawn with [`XlaEngine::start`].
pub struct XlaEngine {
    handle: EngineHandle,
    join: Option<std::thread::JoinHandle<()>>,
}

impl XlaEngine {
    /// Load the manifest from `dir`, compile every artifact on a dedicated
    /// PJRT thread, and return the engine. Fails fast if the client cannot
    /// be created or any artifact fails to compile (or the crate was built
    /// without the `xla` feature).
    pub fn start(dir: &std::path::Path) -> Result<XlaEngine> {
        let manifest = Arc::new(Manifest::load(dir)?);
        manifest.check_files()?;
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let m2 = Arc::clone(&manifest);
        let join = std::thread::Builder::new()
            .name("xla-engine".into())
            .spawn(move || actor_main(m2, rx, ready_tx))
            .map_err(|e| Error::Runtime(format!("spawn engine: {e}")))?;
        // Wait for compilation to finish (or fail).
        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => return Err(e),
            Err(_) => return Err(Error::Runtime("engine thread died during init".into())),
        }
        Ok(XlaEngine {
            handle: EngineHandle { tx: Arc::new(Mutex::new(tx)), manifest },
            join: Some(join),
        })
    }

    pub fn handle(&self) -> EngineHandle {
        self.handle.clone()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.handle.manifest
    }
}

impl Drop for XlaEngine {
    fn drop(&mut self) {
        if let Ok(tx) = self.handle.tx.lock() {
            let _ = tx.send(Request::Shutdown);
        }
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl EngineHandle {
    fn send(&self, req: Request) -> Result<()> {
        self.tx
            .lock()
            .map_err(|_| Error::Runtime("engine mutex poisoned".into()))?
            .send(req)
            .map_err(|_| Error::Runtime("engine thread gone".into()))
    }

    /// RBF gram tile for (short) blocks — pads to the artifact shape and
    /// slices the result.
    pub fn rbf_tile(&self, xb: &Mat, yb: &Mat, ell: f64, sf2: f64) -> Result<Mat> {
        let t = self.manifest.gram_tile;
        let d = self.manifest.gram_dim;
        if xb.rows > t || yb.rows > t || xb.cols > d {
            return Err(Error::Runtime(format!(
                "tile too large: {}x{} (max {t}x{d})",
                xb.rows, xb.cols
            )));
        }
        let xp = pad_to(xb, t, d);
        let yp = pad_to(yb, t, d);
        let (tx_resp, rx_resp) = mpsc::channel();
        self.send(Request::RbfTile { x: xp, y: yp, ell, sf2, resp: tx_resp })?;
        let full = rx_resp
            .recv()
            .map_err(|_| Error::Runtime("engine dropped response".into()))??;
        Ok(full.block(0, xb.rows, 0, yb.rows))
    }

    /// G = AᵀA via the AOT artifact (pads with zeros — exact embedding).
    pub fn ata(&self, a: &Mat) -> Result<Mat> {
        let m = self.manifest.ata_m;
        if a.rows > m || a.cols > m {
            return Err(Error::Runtime(format!("ata block {}x{} > {m}", a.rows, a.cols)));
        }
        let ap = pad_to(a, m, m);
        let (tx_resp, rx_resp) = mpsc::channel();
        self.send(Request::Ata { a: ap, resp: tx_resp })?;
        let full = rx_resp
            .recv()
            .map_err(|_| Error::Runtime("engine dropped response".into()))??;
        Ok(full.block(0, a.cols, 0, a.cols))
    }

    /// α = (K + σ²I)⁻¹ y via the AOT artifact. K is padded with an
    /// identity diagonal, which leaves the leading entries exact.
    pub fn chol_solve(&self, k: &Mat, y: &[f64], sigma2: f64) -> Result<Vec<f64>> {
        let n = self.manifest.chol_n;
        if k.rows > n {
            return Err(Error::Runtime(format!("chol_solve n={} > {n}", k.rows)));
        }
        let mut kp = Mat::eye(n);
        kp.set_block(0, 0, k);
        let mut yp = vec![0.0; n];
        yp[..y.len()].copy_from_slice(y);
        let (tx_resp, rx_resp) = mpsc::channel();
        self.send(Request::CholSolve { k: kp, y: yp, sigma2, resp: tx_resp })?;
        let full = rx_resp
            .recv()
            .map_err(|_| Error::Runtime("engine dropped response".into()))??;
        Ok(full[..y.len()].to_vec())
    }

    /// Blocked multi-RHS solve A = (K + σ²I)⁻¹ Y, where the columns of
    /// `ys` (k.rows × b) are independent right-hand sides. K is padded
    /// once for the whole block; with the `chol_solve_mat` artifact
    /// loaded the backend solves `chol_b` columns per execution (one
    /// factorization per chunk), otherwise it falls back to per-column
    /// execution sharing one K literal. Columns come back in order.
    pub fn chol_solve_mat(&self, k: &Mat, ys: &Mat, sigma2: f64) -> Result<Mat> {
        let n = self.manifest.chol_n;
        if k.rows > n {
            return Err(Error::Runtime(format!("chol_solve_mat n={} > {n}", k.rows)));
        }
        if ys.rows != k.rows {
            return Err(Error::Runtime(format!(
                "chol_solve_mat rhs rows {} != n {}",
                ys.rows, k.rows
            )));
        }
        let mut kp = Mat::eye(n);
        kp.set_block(0, 0, k);
        let ysp = pad_to(ys, n, ys.cols);
        let (tx_resp, rx_resp) = mpsc::channel();
        self.send(Request::CholSolveMat { k: kp, ys: ysp, sigma2, resp: tx_resp })?;
        let full = rx_resp
            .recv()
            .map_err(|_| Error::Runtime("engine dropped response".into()))??;
        Ok(full.block(0, ys.rows, 0, ys.cols))
    }

    pub fn gram_tile_size(&self) -> usize {
        self.manifest.gram_tile
    }

    pub fn gram_max_dim(&self) -> usize {
        self.manifest.gram_dim
    }
}

impl TileEngine for EngineHandle {
    fn tile(&self) -> usize {
        self.manifest.gram_tile
    }

    fn max_dim(&self) -> usize {
        self.manifest.gram_dim
    }

    fn rbf_tile(&self, xb: &Mat, yb: &Mat, lengthscale: f64, signal_var: f64) -> Mat {
        match EngineHandle::rbf_tile(self, xb, yb, lengthscale, signal_var) {
            Ok(m) => m,
            Err(_) => crate::kernels::gram::rbf_tile_native(xb, yb, lengthscale, signal_var),
        }
    }
}

/// Zero-pad a matrix to (rows, cols).
fn pad_to(a: &Mat, rows: usize, cols: usize) -> Mat {
    let mut p = Mat::zeros(rows, cols);
    p.set_block(0, 0, a);
    p
}

// ---------------------------------------------------------------------------
// Actor loop (backend-agnostic).
// ---------------------------------------------------------------------------

fn actor_main(manifest: Arc<Manifest>, rx: mpsc::Receiver<Request>, ready: mpsc::Sender<Result<()>>) {
    let compiled = match backend::setup(&manifest) {
        Ok(c) => {
            let _ = ready.send(Ok(()));
            c
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    while let Ok(req) = rx.recv() {
        match req {
            Request::Shutdown => break,
            Request::RbfTile { x, y, ell, sf2, resp } => {
                let out = backend::run_gram(&compiled, &x, &y, ell, sf2);
                let _ = resp.send(out);
            }
            Request::Ata { a, resp } => {
                let out = backend::run_ata(&compiled, &a);
                let _ = resp.send(out);
            }
            Request::CholSolve { k, y, sigma2, resp } => {
                let out = backend::run_chol(&compiled, &k, &y, sigma2);
                let _ = resp.send(out);
            }
            Request::CholSolveMat { k, ys, sigma2, resp } => {
                let out = backend::run_chol_mat(&compiled, &k, &ys, sigma2);
                let _ = resp.send(out);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Real backend (the only code touching the xla crate).
// ---------------------------------------------------------------------------

// The offline build has no `xla` crate, so enabling the feature without
// vendoring it would otherwise die in a wall of unresolved-import errors.
// Surface one actionable message instead. To light up the real backend:
// add `xla = { path = "<vendored xla-rs>" }` under [dependencies] in
// rust/Cargo.toml and delete this guard.
#[cfg(feature = "xla")]
compile_error!(
    "the `xla` feature requires a vendored `xla` crate: add it as a path \
     dependency in rust/Cargo.toml, then remove this compile_error guard \
     in rust/src/runtime/engine.rs"
);

#[cfg(feature = "xla")]
mod backend {
    use super::*;

    pub struct Compiled {
        gram: Option<xla::PjRtLoadedExecutable>,
        ata: Option<xla::PjRtLoadedExecutable>,
        chol: Option<xla::PjRtLoadedExecutable>,
        chol_mat: Option<xla::PjRtLoadedExecutable>,
        chol_b: usize,
        _client: xla::PjRtClient,
    }

    pub fn setup(manifest: &Manifest) -> Result<Compiled> {
        let client = xla::PjRtClient::cpu().map_err(|e| Error::Runtime(format!("pjrt: {e}")))?;
        let compile = |name: &str| -> Result<Option<xla::PjRtLoadedExecutable>> {
            match manifest.artifact(name) {
                None => Ok(None),
                Some(info) => {
                    let proto = xla::HloModuleProto::from_text_file(&info.file)
                        .map_err(|e| Error::Runtime(format!("parse {name}: {e}")))?;
                    let comp = xla::XlaComputation::from_proto(&proto);
                    let exe = client
                        .compile(&comp)
                        .map_err(|e| Error::Runtime(format!("compile {name}: {e}")))?;
                    Ok(Some(exe))
                }
            }
        };
        Ok(Compiled {
            gram: compile("gram_tile")?,
            ata: compile("ata")?,
            chol: compile("chol_solve")?,
            chol_mat: compile("chol_solve_mat")?,
            chol_b: manifest.chol_b,
            _client: client,
        })
    }

    fn mat_literal(m: &Mat) -> Result<xla::Literal> {
        xla::Literal::vec1(&m.data)
            .reshape(&[m.rows as i64, m.cols as i64])
            .map_err(|e| Error::Runtime(format!("literal: {e}")))
    }

    fn run1(exe: &xla::PjRtLoadedExecutable, args: &[xla::Literal]) -> Result<xla::Literal> {
        let result = exe
            .execute::<xla::Literal>(args)
            .map_err(|e| Error::Runtime(format!("execute: {e}")))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("to_literal: {e}")))?;
        lit.to_tuple1().map_err(|e| Error::Runtime(format!("tuple: {e}")))
    }

    pub fn run_gram(c: &Compiled, x: &Mat, y: &Mat, ell: f64, sf2: f64) -> Result<Mat> {
        let exe = c.gram.as_ref().ok_or_else(|| Error::Runtime("gram_tile not loaded".into()))?;
        let t = x.rows;
        let args = vec![
            mat_literal(x)?,
            mat_literal(y)?,
            xla::Literal::vec1(&[ell]),
            xla::Literal::vec1(&[sf2]),
        ];
        let out = run1(exe, &args)?;
        let data = out.to_vec::<f64>().map_err(|e| Error::Runtime(format!("to_vec: {e}")))?;
        Ok(Mat::from_vec(t, t, data))
    }

    pub fn run_ata(c: &Compiled, a: &Mat) -> Result<Mat> {
        let exe = c.ata.as_ref().ok_or_else(|| Error::Runtime("ata not loaded".into()))?;
        let m = a.rows;
        let out = run1(exe, &[mat_literal(a)?])?;
        let data = out.to_vec::<f64>().map_err(|e| Error::Runtime(format!("to_vec: {e}")))?;
        Ok(Mat::from_vec(m, m, data))
    }

    pub fn run_chol(c: &Compiled, k: &Mat, y: &[f64], sigma2: f64) -> Result<Vec<f64>> {
        let exe = c.chol.as_ref().ok_or_else(|| Error::Runtime("chol_solve not loaded".into()))?;
        let args = vec![mat_literal(k)?, xla::Literal::vec1(y), xla::Literal::vec1(&[sigma2])];
        let out = run1(exe, &args)?;
        out.to_vec::<f64>().map_err(|e| Error::Runtime(format!("to_vec: {e}")))
    }

    /// Multi-RHS solve. Preferred path: the `chol_solve_mat` artifact,
    /// which factors K once per `chol_b`-wide column chunk (ragged tails
    /// are padded with zero columns — the artifact maps zero RHS to zero
    /// exactly). Fallback when that artifact is absent: loop the
    /// single-RHS executable per column, still converting/uploading the
    /// n×n K literal only once.
    pub fn run_chol_mat(c: &Compiled, k: &Mat, ys: &Mat, sigma2: f64) -> Result<Mat> {
        let (n, b) = (ys.rows, ys.cols);
        let mut out = Mat::zeros(n, b);
        if let Some(exe) = c.chol_mat.as_ref() {
            let bw = c.chol_b.max(1);
            let mut chunk = Mat::zeros(n, bw);
            // args[0] (the K literal) is built once and reused; only the
            // RHS literal is rebuilt per chunk.
            let mut args = vec![
                mat_literal(k)?,
                mat_literal(&chunk)?,
                xla::Literal::vec1(&[sigma2]),
            ];
            for c0 in (0..b).step_by(bw) {
                let width = bw.min(b - c0);
                for i in 0..n {
                    let dst = chunk.row_mut(i);
                    dst[..width].copy_from_slice(&ys.row(i)[c0..c0 + width]);
                    dst[width..].fill(0.0);
                }
                args[1] = mat_literal(&chunk)?;
                let data = run1(exe, &args)?
                    .to_vec::<f64>()
                    .map_err(|e| Error::Runtime(format!("to_vec: {e}")))?;
                let alpha = Mat::from_vec(n, bw, data);
                for i in 0..n {
                    out.row_mut(i)[c0..c0 + width].copy_from_slice(&alpha.row(i)[..width]);
                }
            }
            return Ok(out);
        }
        let exe = c.chol.as_ref().ok_or_else(|| Error::Runtime("chol_solve not loaded".into()))?;
        let mut col = vec![0.0; n];
        let mut args = vec![
            mat_literal(k)?,
            xla::Literal::vec1(&col),
            xla::Literal::vec1(&[sigma2]),
        ];
        for j in 0..b {
            for i in 0..n {
                col[i] = ys.at(i, j);
            }
            args[1] = xla::Literal::vec1(&col);
            let alpha = run1(exe, &args)?
                .to_vec::<f64>()
                .map_err(|e| Error::Runtime(format!("to_vec: {e}")))?;
            out.set_col(j, &alpha[..n]);
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Stub backend: keeps the engine protocol compiling & tested offline.
// ---------------------------------------------------------------------------

#[cfg(not(feature = "xla"))]
mod backend {
    use super::*;

    pub struct Compiled;

    const MSG: &str = "mka-gp was built without the `xla` feature; \
                       AOT artifacts cannot be executed — use native kernels";

    pub fn setup(_manifest: &Manifest) -> Result<Compiled> {
        Err(Error::Runtime(MSG.into()))
    }

    pub fn run_gram(_c: &Compiled, _x: &Mat, _y: &Mat, _ell: f64, _sf2: f64) -> Result<Mat> {
        Err(Error::Runtime(MSG.into()))
    }

    pub fn run_ata(_c: &Compiled, _a: &Mat) -> Result<Mat> {
        Err(Error::Runtime(MSG.into()))
    }

    pub fn run_chol(_c: &Compiled, _k: &Mat, _y: &[f64], _sigma2: f64) -> Result<Vec<f64>> {
        Err(Error::Runtime(MSG.into()))
    }

    pub fn run_chol_mat(_c: &Compiled, _k: &Mat, _ys: &Mat, _sigma2: f64) -> Result<Mat> {
        Err(Error::Runtime(MSG.into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_embeds_exactly() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let p = pad_to(&a, 4, 3);
        assert_eq!(p.rows, 4);
        assert_eq!(p.cols, 3);
        assert_eq!(p[(1, 1)], 4.0);
        assert_eq!(p[(2, 2)], 0.0);
        assert_eq!(p.block(0, 2, 0, 2), a);
    }

    #[test]
    fn start_fails_cleanly_without_artifacts() {
        let e = XlaEngine::start(std::path::Path::new("/definitely/not/here"));
        assert!(e.is_err());
    }
}
