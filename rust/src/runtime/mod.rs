//! PJRT runtime — loads the AOT artifacts produced by `python/compile/aot.py`
//! (HLO text + `manifest.json`) and executes them from the Rust hot path.
//!
//! Python is build-time only: after `make artifacts`, the rust binary is
//! self-contained. The PJRT client object is not `Send` (it wraps an `Rc`
//! C++ handle), so [`engine::XlaEngine`] runs on a dedicated actor thread
//! and hands out a cheap, thread-safe [`engine::EngineHandle`].

pub mod engine;

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::Json;

/// One AOT-compiled artifact entry.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: PathBuf,
    pub n_params: usize,
    pub sha256: String,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactInfo>,
    /// Fixed gram-tile edge (points per block).
    pub gram_tile: usize,
    /// Fixed (padded) feature dimension of the gram tile.
    pub gram_dim: usize,
    /// Fixed AᵀA block size.
    pub ata_m: usize,
    /// Fixed Cholesky-solve size.
    pub chol_n: usize,
    /// RHS-block width of the multi-RHS Cholesky-solve artifact.
    pub chol_b: usize,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        Self::parse(dir, &text)
    }

    /// Parse manifest text (separated out for tests).
    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let v = Json::parse(text)?;
        let arts = v
            .get("artifacts")
            .and_then(|a| a.as_obj())
            .ok_or_else(|| Error::Runtime("manifest: missing artifacts".into()))?;
        let mut artifacts = Vec::new();
        for (name, meta) in arts {
            let file = meta
                .str_field("file")
                .ok_or_else(|| Error::Runtime(format!("manifest: {name} missing file")))?;
            artifacts.push(ArtifactInfo {
                name: name.clone(),
                file: dir.join(file),
                n_params: meta.usize_field("n_params").unwrap_or(0),
                sha256: meta.str_field("sha256").unwrap_or("").to_string(),
            });
        }
        let shapes = v.get("shapes");
        let shape_of = |art: &str, field: &str, default: usize| -> usize {
            shapes
                .and_then(|s| s.get(art))
                .and_then(|a| a.usize_field(field))
                .unwrap_or(default)
        };
        Ok(Manifest {
            dir: dir.to_path_buf(),
            artifacts,
            gram_tile: shape_of("gram_tile", "tile", 128),
            gram_dim: shape_of("gram_tile", "dim", 32),
            ata_m: shape_of("ata", "m", 256),
            chol_n: shape_of("chol_solve", "n", 512),
            chol_b: shape_of("chol_solve_mat", "b", 32),
        })
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactInfo> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Verify every artifact file exists on disk.
    pub fn check_files(&self) -> Result<()> {
        for a in &self.artifacts {
            if !a.file.exists() {
                return Err(Error::Runtime(format!(
                    "artifact {} missing: {}",
                    a.name,
                    a.file.display()
                )));
            }
        }
        Ok(())
    }
}

/// Default artifacts directory: `$MKA_GP_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("MKA_GP_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": {
        "gram_tile": {"file": "gram_tile.hlo.txt", "n_params": 4, "sha256": "ab", "bytes": 10},
        "ata": {"file": "ata.hlo.txt", "n_params": 1, "sha256": "cd", "bytes": 10}
      },
      "dtype": "f64",
      "shapes": {"gram_tile": {"tile": 128, "dim": 32}, "ata": {"m": 256}, "chol_solve": {"n": 512}}
    }"#;

    #[test]
    fn parse_manifest() {
        let m = Manifest::parse(Path::new("/tmp/arts"), SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        assert_eq!(m.gram_tile, 128);
        assert_eq!(m.gram_dim, 32);
        assert_eq!(m.ata_m, 256);
        let g = m.artifact("gram_tile").unwrap();
        assert_eq!(g.n_params, 4);
        assert!(g.file.ends_with("gram_tile.hlo.txt"));
        assert!(m.artifact("nope").is_none());
    }

    #[test]
    fn missing_artifacts_key_rejected() {
        assert!(Manifest::parse(Path::new("/tmp"), r#"{"dtype": "f64"}"#).is_err());
        assert!(Manifest::parse(Path::new("/tmp"), "not json").is_err());
    }

    #[test]
    fn check_files_detects_missing() {
        let m = Manifest::parse(Path::new("/nonexistent-dir-xyz"), SAMPLE).unwrap();
        assert!(m.check_files().is_err());
    }
}
