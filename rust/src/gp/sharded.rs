//! Sharded GP serving: shard-per-cluster fit, routed predicts, BCM
//! recombination.
//!
//! One `MkaGp` holds one factor on one box; [`ShardedGp`] refactors that
//! into a fleet. Training data is partitioned with the same clustering
//! machinery the PITC baseline conditions on ([`crate::cluster`]), one
//! `MkaGp` is fitted per shard **concurrently on the shared `par` pool**
//! (fixed shard→slot order, so the PR-2 bit-determinism contract holds at
//! any thread count), and a predict routes each test point to its nearest
//! shard centroids and recombines the per-shard posteriors with a (robust)
//! Bayesian-committee-machine rule (Low et al., "Parallel Gaussian Process
//! Regression for Big Data", PAPERS.md):
//!
//!   σ⁻²_bcm = Σ_s σ⁻²_s − (m − 1)·σ⁻²_prior,
//!   μ_bcm   = σ²_bcm · Σ_s μ_s/σ²_s,
//!
//! where σ²_prior = k(x, x) + σ² is the prior predictive variance and m
//! the number of consulted experts. When the BCM precision degenerates
//! (≤ 0 from approximation error), the combiner falls back to the
//! product-of-experts form with a harmonic-mean variance — conservative,
//! never negative. A single consulted expert returns that shard's
//! prediction **unchanged**, which is what makes the 1-shard model
//! bit-identical to a plain `MkaGp`.
//!
//! Noise stays a view: `with_noise` fans out the PR-5 shift machinery per
//! shard, so a serving-plane retune is O(shards) spectrum shifts, never a
//! refit.
//!
//! Determinism contract: the partition is a fixed function of (data,
//! method, seed); shards occupy fixed slots; per-shard fits and predicts
//! are independently bit-deterministic (`MkaGp` under PR-2); routing sorts
//! by distance with ties broken toward the lower shard id; and every
//! reduction (combine loop, evidence sums in the trainer) walks shards in
//! id order — never completion order.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::{
    GpModel, ModelInfo, ObservePath, ObservePolicy, ObserveReport, ObserveUpdate, Prediction,
};
use crate::cluster::{cluster_rows, ClusterMethod};
use crate::data::dataset::Dataset;
use crate::error::{Error, Result};
use crate::gp::mka_gp::MkaGp;
use crate::kernels::Kernel;
use crate::la::dense::Mat;
use crate::mka::MkaConfig;
use crate::obs;
use crate::par::{self, SendPtr};
use crate::util::json::Json;
use crate::util::Rng;

/// How many nearest shard centroids a test point consults by default.
pub const DEFAULT_ROUTE_EXPERTS: usize = 2;

/// Process-wide count of (test point, shard) routing decisions, surfaced
/// by the coordinator's `metrics` op as `shard.route_hits`.
static ROUTE_HITS: AtomicU64 = AtomicU64::new(0);

/// Total routed (point, shard) pairs served by every `ShardedGp` in this
/// process.
pub fn route_hits() -> u64 {
    ROUTE_HITS.load(Ordering::Relaxed)
}

/// Partition `x`'s rows into (at most) `n_shards` clusters for sharded
/// fitting. Deterministic in (x, method, seed); `n_shards == 1` returns
/// the identity partition in original row order (the bit-identity path).
/// Clustering may merge small clusters, so the effective shard count is
/// `result.len() ≤ n_shards`.
pub fn shard_partition(
    x: &Mat,
    n_shards: usize,
    method: ClusterMethod,
    seed: u64,
) -> Result<Vec<Vec<usize>>> {
    let n = x.rows;
    if n_shards == 0 {
        return Err(Error::Config("shards must be >= 1".into()));
    }
    if n_shards > n {
        return Err(Error::Config(format!(
            "shards ({n_shards}) must not exceed training points ({n})"
        )));
    }
    if n_shards == 1 {
        return Ok(vec![(0..n).collect()]);
    }
    let mut rng = Rng::new(seed ^ 0x5348_4152); // "SHAR"
    let target = n.div_ceil(n_shards);
    let c = cluster_rows(method, Some(x), None, n, target, &mut rng).normalize();
    Ok(c.clusters)
}

struct Shard {
    centroid: Vec<f64>,
    model: MkaGp,
    n: usize,
}

/// A fleet of per-shard MKA-GPs behind one [`GpModel`] face.
pub struct ShardedGp {
    shards: Vec<Shard>,
    kernel: Box<dyn Kernel>,
    sigma2: f64,
    config: MkaConfig,
    route_experts: usize,
    n_total: usize,
    dim: usize,
    /// Per-shard factorization wall time from `fit`, in shard-id order
    /// (the coordinator's `shard.fit_secs` histogram feed).
    fit_secs: Vec<f64>,
    /// Per-shard (point, shard) routing decisions over this model's
    /// lifetime, shard-id order; shared across [`ShardedGp::retuned`]
    /// copies so the `diagnose` op sees one tally per logical fleet.
    route_tally: Arc<Vec<AtomicU64>>,
    /// How many recombinations degenerated from rBCM to the
    /// product-of-experts fallback (also warn-logged, once per batch).
    poe_fallbacks: Arc<AtomicU64>,
}

impl ShardedGp {
    /// Partition `train` into `n_shards` clusters by `assign` (partition
    /// seed = `config.seed`) and fit one `MkaGp` per shard, forcing every
    /// shard's noise-free train factor concurrently on the shared pool.
    pub fn fit(
        train: &Dataset,
        kernel: &dyn Kernel,
        sigma2: f64,
        config: &MkaConfig,
        n_shards: usize,
        assign: ClusterMethod,
    ) -> Result<ShardedGp> {
        let parts = shard_partition(&train.x, n_shards, assign, config.seed)?;
        let k = parts.len();
        let _sp = obs::span!("sharded.fit n={} k={k}", train.n());
        let mut shards = Vec::with_capacity(k);
        for members in &parts {
            let sub = train.subset(members);
            let mut centroid = vec![0.0; train.dim()];
            for &i in members {
                for (c, v) in centroid.iter_mut().zip(train.x.row(i)) {
                    *c += v;
                }
            }
            let inv = 1.0 / members.len() as f64;
            for c in &mut centroid {
                *c *= inv;
            }
            let model = MkaGp::fit(&sub, kernel, sigma2, config)?;
            shards.push(Shard { centroid, model, n: members.len() });
        }

        // Force every shard's train factor now, one pool task per shard
        // (fixed slots): fit-time work happens at fit time, in parallel,
        // and a poisoned shard surfaces here rather than at first predict.
        let mut fit_secs = vec![0.0f64; k];
        let mut errors: Vec<Option<String>> = vec![None; k];
        {
            let secs = SendPtr::new(fit_secs.as_mut_ptr());
            let errs = SendPtr::new(errors.as_mut_ptr());
            let fleet = &shards;
            par::run_tasks(k, k, |s| {
                let _sp = obs::span!("shard {s} fit n={}", fleet[s].n);
                let t0 = std::time::Instant::now();
                let msg = fleet[s].model.train_factor().err().map(|e| e.to_string());
                // SAFETY: task s writes only slots s; run_tasks blocks
                // until every task finished.
                unsafe {
                    *secs.ptr().add(s) = t0.elapsed().as_secs_f64();
                    *errs.ptr().add(s) = msg;
                }
            });
        }
        for (s, e) in errors.iter().enumerate() {
            if let Some(msg) = e {
                return Err(Error::Linalg(format!("shard {s} fit failed: {msg}")));
            }
        }

        Ok(ShardedGp {
            shards,
            kernel: kernel.boxed_clone(),
            sigma2,
            config: config.clone(),
            route_experts: DEFAULT_ROUTE_EXPERTS,
            n_total: train.n(),
            dim: train.dim(),
            fit_secs,
            route_tally: Arc::new((0..k).map(|_| AtomicU64::new(0)).collect()),
            poe_fallbacks: Arc::new(AtomicU64::new(0)),
        })
    }

    /// Consult the `m` nearest shard centroids per test point instead of
    /// the default [`DEFAULT_ROUTE_EXPERTS`] (clamped to the shard count
    /// at predict time; `m == 0` is rounded up to 1).
    pub fn with_route_experts(mut self, m: usize) -> ShardedGp {
        self.route_experts = m.max(1);
        self
    }

    /// Number of shards actually fitted (≤ the requested count when the
    /// clustering merged small clusters).
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Per-shard training sizes in shard-id order.
    pub fn shard_sizes(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.n).collect()
    }

    /// Per-shard factorization wall time from `fit`, in shard-id order.
    pub fn fit_secs(&self) -> &[f64] {
        &self.fit_secs
    }

    /// Current observation-noise variance σ².
    pub fn sigma2(&self) -> f64 {
        self.sigma2
    }

    /// A copy of this fleet serving at noise `sigma2`: per-shard spectrum
    /// shifts (the PR-5 view), O(shards) work, zero refactorizations.
    pub fn retuned(&self, sigma2: f64) -> Result<ShardedGp> {
        let mut shards = Vec::with_capacity(self.shards.len());
        for s in &self.shards {
            shards.push(Shard {
                centroid: s.centroid.clone(),
                model: s.model.retuned(sigma2)?,
                n: s.n,
            });
        }
        Ok(ShardedGp {
            shards,
            kernel: self.kernel.boxed_clone(),
            sigma2,
            config: self.config.clone(),
            route_experts: self.route_experts,
            n_total: self.n_total,
            dim: self.dim,
            fit_secs: self.fit_secs.clone(),
            route_tally: Arc::clone(&self.route_tally),
            poe_fallbacks: Arc::clone(&self.poe_fallbacks),
        })
    }

    /// Streaming append across the fleet: every new point goes to its
    /// nearest centroid's shard (ties toward the lower shard id — the same
    /// determinism contract as predict routing), each touched shard runs
    /// [`MkaGp::observed`] on its sub-batch, and every untouched shard is
    /// carried over by Arc-sharing its factor (a same-σ² retune — zero
    /// refactorization). Touched shards' centroids take the running-mean
    /// update. Returns the new fleet plus per-shard reports in shard-id
    /// order.
    pub fn observed(
        &self,
        xb: &Mat,
        yb: &[f64],
        policy: &ObservePolicy,
    ) -> Result<(ShardedGp, Vec<(usize, ObserveReport)>)> {
        policy.validate()?;
        let b = xb.rows;
        let k = self.shards.len();
        if b == 0 {
            return Err(Error::Data("observe: empty batch".into()));
        }
        if yb.len() != b {
            return Err(Error::Data(format!(
                "observe: x has {b} rows but y has {} entries",
                yb.len()
            )));
        }
        if xb.cols != self.dim {
            return Err(Error::Data(format!(
                "observe: batch dim {} != training dim {}",
                xb.cols, self.dim
            )));
        }
        let _sp = obs::span!("sharded.observe b={b} k={k}");

        // Each new point joins its single nearest shard (serial and
        // deterministic; unlike predict routing there is no multi-expert
        // fan-out — a training point lives in exactly one shard).
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); k];
        for j in 0..b {
            let xt = xb.row(j);
            let mut best = 0usize;
            let mut best_d = f64::INFINITY;
            for (s, sh) in self.shards.iter().enumerate() {
                let d = sqdist(xt, &sh.centroid);
                if d < best_d {
                    best_d = d;
                    best = s;
                }
            }
            groups[best].push(j);
        }

        let mut shards = Vec::with_capacity(k);
        let mut reports = Vec::new();
        for (s, sh) in self.shards.iter().enumerate() {
            if groups[s].is_empty() {
                // Untouched: a same-σ² retune Arc-shares the train factor.
                shards.push(Shard {
                    centroid: sh.centroid.clone(),
                    model: sh.model.retuned(self.sigma2)?,
                    n: sh.n,
                });
                continue;
            }
            let idx = &groups[s];
            let xs = xb.gather_rows(idx);
            let ys: Vec<f64> = idx.iter().map(|&j| yb[j]).collect();
            let (model, rep) = sh
                .model
                .observed(&xs, &ys, policy)
                .map_err(|e| Error::Runtime(format!("observe: shard {s}: {e}")))?;
            // Running-mean centroid update keeps future routing honest.
            let cnt = idx.len() as f64;
            let n_old = sh.n as f64;
            let centroid: Vec<f64> = sh
                .centroid
                .iter()
                .enumerate()
                .map(|(c, v)| {
                    let sum_new: f64 = idx.iter().map(|&j| xb.at(j, c)).sum();
                    (v * n_old + sum_new) / (n_old + cnt)
                })
                .collect();
            // A windowed refit may shrink the shard below n + |batch|, so
            // take the size from the refreshed model, not arithmetic.
            let n = model.info().n;
            shards.push(Shard { centroid, model, n });
            reports.push((s, rep));
        }

        let n_total = shards.iter().map(|sh| sh.n).sum();
        Ok((
            ShardedGp {
                shards,
                kernel: self.kernel.boxed_clone(),
                sigma2: self.sigma2,
                config: self.config.clone(),
                route_experts: self.route_experts,
                n_total,
                dim: self.dim,
                fit_secs: self.fit_secs.clone(),
                route_tally: Arc::clone(&self.route_tally),
                poe_fallbacks: Arc::clone(&self.poe_fallbacks),
            },
            reports,
        ))
    }

    /// Background refresh: every shard refit from scratch on its currently
    /// held points (factors forced eagerly), topology and routing state
    /// carried over — what the recurring refresh scheduler runs.
    pub fn refreshed_fleet(&self) -> Result<ShardedGp> {
        let _sp = obs::span!("sharded.refresh k={}", self.shards.len());
        let mut shards = Vec::with_capacity(self.shards.len());
        for (s, sh) in self.shards.iter().enumerate() {
            let model = sh
                .model
                .refreshed_model()
                .map_err(|e| Error::Runtime(format!("refresh: shard {s}: {e}")))?;
            shards.push(Shard { centroid: sh.centroid.clone(), model, n: sh.n });
        }
        Ok(ShardedGp {
            shards,
            kernel: self.kernel.boxed_clone(),
            sigma2: self.sigma2,
            config: self.config.clone(),
            route_experts: self.route_experts,
            n_total: self.n_total,
            dim: self.dim,
            fit_secs: self.fit_secs.clone(),
            route_tally: Arc::clone(&self.route_tally),
            poe_fallbacks: Arc::clone(&self.poe_fallbacks),
        })
    }

    /// The experts consulted for test point `xt`: the `route_experts`
    /// nearest centroids, distance ties broken toward the lower shard id,
    /// returned **in shard-id order** so downstream reductions are
    /// interleaving-independent.
    fn route(&self, xt: &[f64]) -> Vec<usize> {
        let k = self.shards.len();
        let m = self.route_experts.min(k);
        let d: Vec<f64> = self.shards.iter().map(|s| sqdist(xt, &s.centroid)).collect();
        let mut order: Vec<usize> = (0..k).collect();
        order.sort_by(|&a, &b| {
            d[a].partial_cmp(&d[b]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
        });
        order.truncate(m);
        order.sort_unstable();
        order
    }
}

fn sqdist(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

impl GpModel for ShardedGp {
    fn predict(&self, x_test: &Mat) -> Prediction {
        let p = x_test.rows;
        let k = self.shards.len();
        if p == 0 {
            return Prediction { mean: Vec::new(), var: Vec::new() };
        }

        let _sp = obs::span!("sharded.predict p={p} k={k}");

        // Route every point, then gather each shard's sub-batch (test
        // indices in ascending order — the cursor walk below relies on it).
        let routes: Vec<Vec<usize>> = {
            let _sp = obs::span!("route p={p}");
            (0..p).map(|t| self.route(x_test.row(t))).collect()
        };
        let hits: u64 = routes.iter().map(|r| r.len() as u64).sum();
        ROUTE_HITS.fetch_add(hits, Ordering::Relaxed);
        let mut per_shard: Vec<Vec<usize>> = vec![Vec::new(); k];
        for (t, r) in routes.iter().enumerate() {
            for &s in r {
                per_shard[s].push(t);
            }
        }
        for (s, idx) in per_shard.iter().enumerate() {
            if !idx.is_empty() {
                self.route_tally[s].fetch_add(idx.len() as u64, Ordering::Relaxed);
            }
        }

        // Per-shard predicts, one pool task per shard into fixed slots;
        // each MkaGp predict is itself bit-deterministic, so concurrent
        // shards cannot perturb each other's bits.
        let mut preds: Vec<Option<Prediction>> = vec![None; k];
        {
            let slots = SendPtr::new(preds.as_mut_ptr());
            par::run_tasks(k, k, |s| {
                let idx = &per_shard[s];
                let out = if idx.is_empty() {
                    None
                } else {
                    let _sp = obs::span!("shard {s} predict b={}", idx.len());
                    Some(self.shards[s].model.predict(&x_test.gather_rows(idx)))
                };
                // SAFETY: task s writes only slot s; run_tasks blocks
                // until every task finished.
                unsafe { *slots.ptr().add(s) = out };
            });
        }

        // Recombine serially, experts in shard-id order per point.
        let _sp_rec = obs::span!("recombine p={p}");
        let mut poe = 0u64;
        let mut cursor = vec![0usize; k];
        let mut mean = Vec::with_capacity(p);
        let mut var = Vec::with_capacity(p);
        for t in 0..p {
            let experts = &routes[t];
            let mut ms = Vec::with_capacity(experts.len());
            let mut vs = Vec::with_capacity(experts.len());
            for &s in experts {
                let pos = cursor[s];
                cursor[s] += 1;
                let pr = preds[s].as_ref().expect("routed shard has predictions");
                ms.push(pr.mean[pos]);
                vs.push(pr.var[pos]);
            }
            if experts.len() == 1 {
                // Single expert: its posterior verbatim — the 1-shard
                // fleet is bit-identical to the unsharded model.
                mean.push(ms[0]);
                var.push(vs[0]);
                continue;
            }
            let mut prec = 0.0;
            let mut wmean = 0.0;
            for (m, v) in ms.iter().zip(&vs) {
                prec += 1.0 / v;
                wmean += m / v;
            }
            let v_prior = self.kernel.diag(x_test.row(t)) + self.sigma2;
            let bcm_prec = prec - (experts.len() - 1) as f64 / v_prior;
            if bcm_prec.is_finite() && bcm_prec > 0.0 {
                mean.push(wmean / bcm_prec);
                var.push((1.0 / bcm_prec).max(self.sigma2));
            } else {
                // Degenerate BCM precision: product-of-experts mean with a
                // harmonic-mean (conservative) variance.
                poe += 1;
                mean.push(wmean / prec);
                var.push((experts.len() as f64 / prec).max(self.sigma2));
            }
        }
        if poe > 0 {
            self.poe_fallbacks.fetch_add(poe, Ordering::Relaxed);
            obs::log!(
                Warn,
                "gp.sharded",
                { "points" => poe, "batch" => p },
                "rBCM precision degenerated; product-of-experts fallback"
            );
        }
        Prediction { mean, var }
    }

    fn name(&self) -> String {
        format!("Sharded-MKA(shards={}, d={})", self.shards.len(), self.config.d_core)
    }

    fn with_noise(&self, sigma2: f64) -> Option<Box<dyn GpModel>> {
        Some(Box::new(self.retuned(sigma2).ok()?))
    }

    fn info(&self) -> ModelInfo {
        ModelInfo {
            method: self.name(),
            n: self.n_total,
            dim: self.dim,
            sigma2: Some(self.sigma2),
            shards: self.shards.len(),
            shard_sizes: self.shard_sizes(),
        }
    }

    fn diagnose(&self) -> Option<Json> {
        // Aggregates held state only: per-shard health comes from each
        // MkaGp's already-computed factor (ShardedGp::fit forces them all),
        // never from a fresh factorization.
        let total: u64 = self.route_tally.iter().map(|h| h.load(Ordering::Relaxed)).sum();
        let shards: Vec<Json> = self
            .shards
            .iter()
            .enumerate()
            .map(|(s, sh)| {
                let hits = self.route_tally[s].load(Ordering::Relaxed);
                let share = if total > 0 { hits as f64 / total as f64 } else { 0.0 };
                let mut j = Json::obj()
                    .with("shard", Json::Num(s as f64))
                    .with("n", Json::Num(sh.n as f64))
                    .with("fit_secs", Json::Num(self.fit_secs[s]))
                    .with("route_hits", Json::Num(hits as f64))
                    .with("route_share", Json::Num(share));
                if let Some(d) = sh.model.diagnose() {
                    j = j.with("model", d);
                }
                j
            })
            .collect();
        // Fleet-wide predict-cache traffic: per-shard instance counters
        // summed in shard-id order (each shard's own section sits under
        // its `model` entry). Untouched-shard carry-over Arc-shares the
        // cache, so these survive observe/retune republishes.
        let (mut pc_entries, mut pc_hits, mut pc_misses, mut pc_evictions) = (0, 0, 0, 0);
        for sh in &self.shards {
            let pc = sh.model.predict_cache();
            pc_entries += pc.len() as u64;
            pc_hits += pc.hits();
            pc_misses += pc.misses();
            pc_evictions += pc.evictions();
        }
        Some(
            Json::obj()
                .with("kind", Json::Str("sharded".into()))
                .with("method", Json::Str(self.name()))
                .with("n", Json::Num(self.n_total as f64))
                .with("dim", Json::Num(self.dim as f64))
                .with("sigma2", Json::Num(self.sigma2))
                .with("route_experts", Json::Num(self.route_experts as f64))
                .with("route_hits_total", Json::Num(total as f64))
                .with(
                    "poe_fallbacks",
                    Json::Num(self.poe_fallbacks.load(Ordering::Relaxed) as f64),
                )
                .with(
                    "predict_cache",
                    Json::obj()
                        .with("entries", Json::Num(pc_entries as f64))
                        .with("hits", Json::Num(pc_hits as f64))
                        .with("misses", Json::Num(pc_misses as f64))
                        .with("evictions", Json::Num(pc_evictions as f64)),
                )
                .with("shards", Json::Arr(shards)),
        )
    }

    fn observe(
        &self,
        x: &Mat,
        y: &[f64],
        policy: &ObservePolicy,
    ) -> Option<Result<ObserveUpdate>> {
        Some(self.observed(x, y, policy).map(|(fleet, reports)| {
            let any_refit =
                reports.iter().any(|(_, r)| r.path == ObservePath::Refit);
            let entries: Vec<Json> = reports
                .iter()
                .map(|(s, r)| r.to_json().with("shard", Json::Num(*s as f64)))
                .collect();
            let report = Json::obj()
                .with("kind", Json::Str("sharded".into()))
                .with(
                    "path",
                    Json::Str(
                        if any_refit { ObservePath::Refit } else { ObservePath::Incremental }
                            .as_str()
                            .into(),
                    ),
                )
                .with("appended", Json::Num(x.rows as f64))
                .with("n_total", Json::Num(fleet.n_total as f64))
                .with("shards_touched", Json::Num(entries.len() as f64))
                .with("shards", Json::Arr(entries));
            ObserveUpdate { model: Box::new(fleet), report }
        }))
    }

    fn can_refresh(&self) -> bool {
        true
    }

    fn refreshed(&self) -> Option<Result<Box<dyn GpModel>>> {
        Some(self.refreshed_fleet().map(|f| Box::new(f) as Box<dyn GpModel>))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gp_dataset, SynthSpec};
    use crate::kernels::RbfKernel;

    fn config(d: usize) -> MkaConfig {
        MkaConfig { d_core: d, block_size: 48, ..MkaConfig::default() }
    }

    #[test]
    fn partition_validates_and_covers() {
        let x = Mat::from_fn(30, 2, |i, j| (i * 2 + j) as f64);
        assert!(shard_partition(&x, 0, ClusterMethod::KMeans, 1).is_err());
        assert!(shard_partition(&x, 31, ClusterMethod::KMeans, 1).is_err());
        let one = shard_partition(&x, 1, ClusterMethod::KMeans, 1).unwrap();
        assert_eq!(one, vec![(0..30).collect::<Vec<_>>()]);
        let four = shard_partition(&x, 4, ClusterMethod::KMeans, 1).unwrap();
        assert!(four.len() >= 2 && four.len() <= 4, "{} shards", four.len());
        let covered: usize = four.iter().map(|c| c.len()).sum();
        assert_eq!(covered, 30);
        // deterministic in the seed
        let again = shard_partition(&x, 4, ClusterMethod::KMeans, 1).unwrap();
        assert_eq!(four, again);
    }

    #[test]
    fn one_shard_is_bit_identical_to_plain_mka() {
        let data = gp_dataset(&SynthSpec::named("shard1", 150, 2), 3);
        let (tr, te) = data.split(0.85, 1);
        let kern = RbfKernel::new(1.0);
        let cfg = config(24);
        let plain = MkaGp::fit(&tr, &kern, 0.1, &cfg).unwrap();
        let fleet =
            ShardedGp::fit(&tr, &kern, 0.1, &cfg, 1, ClusterMethod::KMeans).unwrap();
        assert_eq!(fleet.n_shards(), 1);
        let pp = plain.predict(&te.x);
        let pf = fleet.predict(&te.x);
        for i in 0..te.n() {
            assert_eq!(pp.mean[i].to_bits(), pf.mean[i].to_bits(), "mean[{i}]");
            assert_eq!(pp.var[i].to_bits(), pf.var[i].to_bits(), "var[{i}]");
        }
    }

    #[test]
    fn sharded_predicts_are_sane_and_floored_at_noise() {
        let data = gp_dataset(&SynthSpec::named("shardk", 240, 2), 5);
        let (tr, te) = data.split(0.85, 2);
        let fleet =
            ShardedGp::fit(&tr, &RbfKernel::new(0.9), 0.1, &config(16), 4, ClusterMethod::KMeans)
                .unwrap();
        assert!(fleet.n_shards() >= 2);
        assert_eq!(fleet.shard_sizes().iter().sum::<usize>(), tr.n());
        assert_eq!(fleet.fit_secs().len(), fleet.n_shards());
        let pred = fleet.predict(&te.x);
        assert_eq!(pred.len(), te.n());
        for i in 0..te.n() {
            assert!(pred.mean[i].is_finite());
            assert!(pred.var[i] >= 0.1 - 1e-12, "var[{i}] = {}", pred.var[i]);
        }
        assert!(route_hits() > 0);
    }

    #[test]
    fn retune_matches_fresh_fit() {
        let data = gp_dataset(&SynthSpec::named("shardret", 180, 2), 7);
        let (tr, te) = data.split(0.85, 3);
        let kern = RbfKernel::new(1.0);
        let fleet =
            ShardedGp::fit(&tr, &kern, 0.1, &config(16), 3, ClusterMethod::KMeans).unwrap();
        let retuned = fleet.retuned(0.3).unwrap();
        assert_eq!(retuned.sigma2(), 0.3);
        let fresh =
            ShardedGp::fit(&tr, &kern, 0.3, &config(16), 3, ClusterMethod::KMeans).unwrap();
        let pr = retuned.predict(&te.x);
        let pf = fresh.predict(&te.x);
        for i in 0..te.n() {
            assert!((pr.mean[i] - pf.mean[i]).abs() < 1e-10, "mean[{i}]");
            assert!((pr.var[i] - pf.var[i]).abs() < 1e-10, "var[{i}]");
        }
        // trait hook routes the same machinery; invalid σ² refuses
        assert!(fleet.with_noise(0.05).is_some());
        assert!(fleet.with_noise(-1.0).is_none());
    }

    #[test]
    fn info_reports_shard_topology() {
        let data = gp_dataset(&SynthSpec::named("shardinfo", 120, 3), 9);
        let fleet =
            ShardedGp::fit(&data, &RbfKernel::new(1.0), 0.2, &config(12), 3, ClusterMethod::KMeans)
                .unwrap();
        let info = fleet.info();
        assert_eq!(info.n, 120);
        assert_eq!(info.dim, 3);
        assert_eq!(info.sigma2, Some(0.2));
        assert_eq!(info.shards, fleet.n_shards());
        assert_eq!(info.shard_sizes, fleet.shard_sizes());
        assert!(info.method.starts_with("Sharded-MKA"));
    }

    /// Fleet `diagnose` carries per-shard sizes, route-hit shares, and the
    /// shifted-spectrum health of every shard's factor — all from state
    /// `fit`/`predict` already hold (the factorize counter must not move).
    #[test]
    fn diagnose_reports_fleet_health_without_refactorizing() {
        use crate::mka::factorize_count;
        let data = gp_dataset(&SynthSpec::named("sharddiag", 160, 2), 13);
        let (tr, te) = data.split(0.85, 4);
        let fleet =
            ShardedGp::fit(&tr, &RbfKernel::new(1.0), 0.1, &config(12), 3, ClusterMethod::KMeans)
                .unwrap();
        fleet.predict(&te.x);
        let before = factorize_count();
        let d = fleet.diagnose().expect("sharded always reports");
        assert_eq!(factorize_count(), before, "diagnose must not refactorize");
        assert_eq!(d.str_field("kind"), Some("sharded"));
        assert_eq!(d.num_field("n"), Some(tr.n() as f64));
        assert!(d.num_field("route_hits_total").unwrap() > 0.0);
        let shards = match d.get("shards") {
            Some(Json::Arr(a)) => a,
            other => panic!("shards array missing: {other:?}"),
        };
        assert_eq!(shards.len(), fleet.n_shards());
        let mut share = 0.0;
        for sj in shards {
            share += sj.num_field("route_share").unwrap();
            assert!(sj.num_field("n").unwrap() > 0.0);
            // fit forces every shard factor, so health must be present
            let f = sj.get("model").unwrap().get("factor").unwrap();
            assert!(f.num_field("condition").unwrap() >= 1.0);
            assert!(f.num_field("lambda_min").unwrap() >= 0.1 - 1e-12);
        }
        assert!((share - 1.0).abs() < 1e-9, "route shares sum to 1, got {share}");
    }

    /// Observe routes each new point to its nearest shard, extends only
    /// those shards, and carries every other shard over untouched.
    #[test]
    fn observe_touches_only_the_routed_shards() {
        let data = gp_dataset(&SynthSpec::named("shardobs", 180, 2), 15);
        let (base, newer) = data.split(0.9, 5);
        let fleet =
            ShardedGp::fit(&base, &RbfKernel::new(1.0), 0.1, &config(12), 3, ClusterMethod::KMeans)
                .unwrap();
        let k = fleet.n_shards();
        let (next, reports) = fleet
            .observed(&newer.x, &newer.y, &ObservePolicy::default())
            .unwrap();
        assert!(!reports.is_empty() && reports.len() <= k);
        assert_eq!(next.n_shards(), k, "observe never changes the topology");
        let appended: usize = reports.iter().map(|(_, r)| r.appended).sum();
        assert_eq!(appended, newer.n(), "every new point lands in exactly one shard");
        assert_eq!(next.info().n, base.n() + newer.n());
        assert_eq!(next.shard_sizes().iter().sum::<usize>(), next.info().n);
        // untouched shards keep their exact size
        let touched: Vec<usize> = reports.iter().map(|(s, _)| *s).collect();
        for s in 0..k {
            if !touched.contains(&s) {
                assert_eq!(next.shard_sizes()[s], fleet.shard_sizes()[s]);
            }
        }
        // the grown fleet still serves sane predictions
        let te = gp_dataset(&SynthSpec::named("shardobs-te", 20, 2), 16);
        let pred = next.predict(&te.x);
        for i in 0..te.n() {
            assert!(pred.mean[i].is_finite());
            assert!(pred.var[i] >= 0.1 - 1e-12);
        }
    }

    /// The trait hook aggregates per-shard reports under one envelope.
    #[test]
    fn observe_trait_reports_per_shard() {
        let data = gp_dataset(&SynthSpec::named("shardobs2", 140, 2), 17);
        let (base, newer) = data.split(0.9, 6);
        let fleet =
            ShardedGp::fit(&base, &RbfKernel::new(1.0), 0.1, &config(12), 2, ClusterMethod::KMeans)
                .unwrap();
        let up = fleet
            .observe(&newer.x, &newer.y, &ObservePolicy::default())
            .expect("sharded supports observe")
            .unwrap();
        assert_eq!(up.report.str_field("kind"), Some("sharded"));
        assert_eq!(up.report.num_field("appended"), Some(newer.n() as f64));
        assert_eq!(up.report.num_field("n_total"), Some((base.n() + newer.n()) as f64));
        let touched = up.report.num_field("shards_touched").unwrap() as usize;
        let shards = match up.report.get("shards") {
            Some(Json::Arr(a)) => a,
            other => panic!("shards array missing: {other:?}"),
        };
        assert_eq!(shards.len(), touched);
        for sj in shards {
            assert!(sj.num_field("shard").is_some());
            assert!(sj.str_field("path").is_some());
        }
        assert_eq!(up.model.info().n, base.n() + newer.n());
        // malformed batches are typed errors, not panics
        assert!(fleet
            .observe(&Mat::zeros(2, 5), &[1.0, 2.0], &ObservePolicy::default())
            .unwrap()
            .is_err());
    }

    /// Refresh refits every shard in place: same topology, and (refit
    /// being deterministic on unchanged data) bit-identical predictions.
    #[test]
    fn refreshed_fleet_preserves_behavior() {
        let data = gp_dataset(&SynthSpec::named("shardref", 150, 2), 19);
        let (tr, te) = data.split(0.85, 7);
        let fleet =
            ShardedGp::fit(&tr, &RbfKernel::new(1.0), 0.1, &config(12), 3, ClusterMethod::KMeans)
                .unwrap();
        let re = fleet.refreshed_fleet().unwrap();
        assert_eq!(re.n_shards(), fleet.n_shards());
        assert_eq!(re.shard_sizes(), fleet.shard_sizes());
        let p0 = fleet.predict(&te.x);
        let p1 = re.predict(&te.x);
        for i in 0..te.n() {
            assert_eq!(p0.mean[i].to_bits(), p1.mean[i].to_bits(), "mean[{i}]");
            assert_eq!(p0.var[i].to_bits(), p1.var[i].to_bits(), "var[{i}]");
        }
        // trait hook
        let boxed = fleet.refreshed().expect("supported").unwrap();
        assert_eq!(boxed.info().n, tr.n());
    }

    /// Observe invalidates exactly the touched shards' predict caches:
    /// untouched shards are carried by `retuned` (Arc-shared cache, still
    /// hot), touched shards get a fresh model with an empty cache.
    #[test]
    fn observe_invalidates_only_touched_shard_caches() {
        let data = gp_dataset(&SynthSpec::named("shardpc", 180, 2), 41);
        let (base, _) = data.split(0.9, 8);
        let fleet =
            ShardedGp::fit(&base, &RbfKernel::new(1.0), 0.1, &config(12), 3, ClusterMethod::KMeans)
                .unwrap();
        let k = fleet.n_shards();
        // Warm every shard's cache: route each test point to 1 expert so
        // per-shard sub-batches are stable, then repeat the predict.
        let fleet = fleet.with_route_experts(1);
        let te = gp_dataset(&SynthSpec::named("shardpc-te", 24, 2), 42);
        fleet.predict(&te.x);
        fleet.predict(&te.x);
        let warm: Vec<usize> =
            fleet.shards.iter().map(|sh| sh.model.predict_cache().len()).collect();
        assert!(warm.iter().sum::<usize>() > 0, "warmup must cache joint factors");
        // One new point lands in exactly one shard.
        let xb = base.x.gather_rows(&[0]);
        let (next, reports) = fleet
            .observed(&xb, &[base.y[0]], &ObservePolicy::default())
            .unwrap();
        assert_eq!(reports.len(), 1, "a single point touches a single shard");
        let touched = reports[0].0;
        for s in 0..k {
            let len = next.shards[s].model.predict_cache().len();
            if s == touched {
                assert_eq!(len, 0, "touched shard {s} must start cold");
            } else {
                assert_eq!(len, warm[s], "untouched shard {s} must keep its entries");
            }
        }
        // A σ²-only retune keeps every shard hot.
        let re = next.retuned(0.25).unwrap();
        for s in 0..k {
            assert_eq!(
                re.shards[s].model.predict_cache().len(),
                next.shards[s].model.predict_cache().len(),
                "retune must not invalidate shard {s}"
            );
        }
        // fleet diagnose aggregates the same counters
        let d = fleet.diagnose().unwrap();
        let pc = d.get("predict_cache").expect("aggregate section");
        assert_eq!(
            pc.num_field("entries"),
            Some(warm.iter().sum::<usize>() as f64)
        );
        assert!(pc.num_field("hits").unwrap() >= 1.0);
    }

    #[test]
    fn routing_consults_nearest_and_breaks_ties_low() {
        let data = gp_dataset(&SynthSpec::named("shardroute", 90, 2), 11);
        let fleet = ShardedGp::fit(
            &data,
            &RbfKernel::new(1.0),
            0.1,
            &config(8),
            3,
            ClusterMethod::KMeans,
        )
        .unwrap()
        .with_route_experts(1);
        let k = fleet.n_shards();
        for t in 0..data.n().min(20) {
            let r = fleet.route(data.x.row(t));
            assert_eq!(r.len(), 1);
            assert!(r[0] < k);
        }
        // consulting more experts than shards clamps and keeps id order
        let routed_all = fleet.with_route_experts(99);
        let r = routed_all.route(data.x.row(0));
        assert_eq!(r, (0..k).collect::<Vec<_>>());
    }
}
