//! Hyperparameter selection by k-fold cross-validation (paper §5: "On the
//! other 90% we did five-fold cross validation to learn the length scale
//! and noise parameter for each method").

use crate::data::dataset::Dataset;
use crate::error::{Error, Result};
use crate::gp::metrics::smse;

/// A candidate hyperparameter pair.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HyperParams {
    pub lengthscale: f64,
    pub sigma2: f64,
}

/// Hyperparameters with per-dimension (ARD) length scales. The tied
/// special case (all length scales equal) reproduces [`HyperParams`]
/// exactly; the gradient-based optimizer (`train::optimizer`) walks the
/// full `(log ℓ_1..log ℓ_d, log σ²)` vector.
#[derive(Clone, Debug, PartialEq)]
pub struct ArdHyperParams {
    pub lengthscales: Vec<f64>,
    pub sigma2: f64,
}

impl ArdHyperParams {
    /// Broadcast an isotropic pair to `dim` tied length scales.
    pub fn isotropic(hp: HyperParams, dim: usize) -> ArdHyperParams {
        ArdHyperParams { lengthscales: vec![hp.lengthscale; dim.max(1)], sigma2: hp.sigma2 }
    }

    pub fn dim(&self) -> usize {
        self.lengthscales.len()
    }

    /// The matching ARD kernel.
    pub fn kernel(&self) -> crate::kernels::ArdRbfKernel {
        crate::kernels::ArdRbfKernel::new(self.lengthscales.clone())
    }

    /// Isotropic summary: the geometric mean of the length scales (exact
    /// when tied), for reports and trace records that carry a single ℓ.
    pub fn tied(&self) -> HyperParams {
        let d = self.lengthscales.len().max(1) as f64;
        let gm = (self.lengthscales.iter().map(|l| l.ln()).sum::<f64>() / d).exp();
        HyperParams { lengthscale: gm, sigma2: self.sigma2 }
    }

    /// All parameters finite and positive?
    pub fn is_valid(&self) -> bool {
        !self.lengthscales.is_empty()
            && self.lengthscales.iter().all(|l| l.is_finite() && *l > 0.0)
            && self.sigma2.is_finite()
            && self.sigma2 > 0.0
    }
}

/// Default search grid: length scales around the √d heuristic of
/// standardized data, noise levels spanning from the low-noise regime
/// the paper's small-lengthscale experiments care about (1e-3) up to
/// half the target variance. Also seeds the MLL optimizer's multi-start.
pub fn default_grid(dim: usize) -> Vec<HyperParams> {
    let base = (dim as f64).sqrt().max(1.0);
    let ells = [0.1 * base, 0.2 * base, 0.4 * base, 0.8 * base, 1.6 * base, 3.2 * base];
    let sig2s = [0.001, 0.01, 0.1, 0.5];
    let mut grid = Vec::with_capacity(ells.len() * sig2s.len());
    for &l in &ells {
        for &s in &sig2s {
            grid.push(HyperParams { lengthscale: l, sigma2: s });
        }
    }
    grid
}

/// Result of a CV sweep.
#[derive(Clone, Debug)]
pub struct CvOutcome {
    pub best: HyperParams,
    pub best_score: f64,
    /// (params, mean validation SMSE) for every grid point that evaluated
    /// successfully.
    pub table: Vec<(HyperParams, f64)>,
}

/// Run k-fold CV over a grid. `fit_predict` fits on a training subset with
/// the given hyperparameters and returns mean predictions on a validation
/// matrix; errors (e.g. a Cholesky failure at an aggressive setting) simply
/// disqualify that grid point. Score is validation SMSE (lower = better).
///
/// Errors when **every** grid point fails — the old behaviour silently
/// returned `grid[0]` with an infinite score as if selection had
/// succeeded, and downstream fits then ran at an arbitrary setting.
pub fn grid_search<F>(
    data: &Dataset,
    folds: usize,
    grid: &[HyperParams],
    seed: u64,
    mut fit_predict: F,
) -> Result<CvOutcome>
where
    F: FnMut(&Dataset, &crate::la::dense::Mat, HyperParams) -> Option<Vec<f64>>,
{
    assert!(!grid.is_empty());
    let splits = data.kfold(folds, seed);
    let mut table = Vec::new();
    let mut best: Option<(HyperParams, f64)> = None;
    for &hp in grid {
        let mut scores = Vec::with_capacity(splits.len());
        let mut failed = false;
        for (tr_idx, va_idx) in &splits {
            let tr = data.subset(tr_idx);
            let va = data.subset(va_idx);
            match fit_predict(&tr, &va.x, hp) {
                Some(mean) if mean.len() == va.n() => scores.push(smse(&va.y, &mean)),
                _ => {
                    failed = true;
                    break;
                }
            }
        }
        if failed || scores.is_empty() {
            continue;
        }
        let avg = scores.iter().sum::<f64>() / scores.len() as f64;
        table.push((hp, avg));
        if best.map_or(true, |(_, s)| avg < s) {
            best = Some((hp, avg));
        }
    }
    let (best, best_score) = best.ok_or_else(|| {
        Error::Data(format!("grid_search: all {} grid points failed to fit", grid.len()))
    })?;
    Ok(CvOutcome { best, best_score, table })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gp_dataset, SynthSpec};
    use crate::gp::full::FullGp;
    use crate::gp::GpModel;
    use crate::kernels::RbfKernel;

    #[test]
    fn grid_has_expected_size() {
        let g = default_grid(4);
        assert_eq!(g.len(), 24);
        assert!(g.iter().all(|h| h.lengthscale > 0.0 && h.sigma2 > 0.0));
        // the noise axis reaches the low-noise regime
        assert!(g.iter().any(|h| h.sigma2 <= 1e-3));
    }

    #[test]
    fn ard_hyperparams_roundtrip() {
        let hp = HyperParams { lengthscale: 1.5, sigma2: 0.1 };
        let ard = ArdHyperParams::isotropic(hp, 3);
        assert_eq!(ard.dim(), 3);
        assert!(ard.is_valid());
        // tied summary of a tied vector is exact
        assert!((ard.tied().lengthscale - 1.5).abs() < 1e-12);
        assert_eq!(ard.tied().sigma2, 0.1);
        // geometric mean for a genuinely anisotropic vector
        let aniso = ArdHyperParams { lengthscales: vec![0.5, 2.0], sigma2: 0.1 };
        assert!((aniso.tied().lengthscale - 1.0).abs() < 1e-12);
        let bad = ArdHyperParams { lengthscales: vec![1.0, -1.0], sigma2: 0.1 };
        assert!(!bad.is_valid());
    }

    #[test]
    fn cv_picks_sane_lengthscale() {
        let data = gp_dataset(&SynthSpec::named("t", 150, 2), 1);
        let grid = vec![
            HyperParams { lengthscale: 0.01, sigma2: 0.1 }, // absurdly short
            HyperParams { lengthscale: 1.5, sigma2: 0.1 },  // about right
        ];
        let out = grid_search(&data, 3, &grid, 7, |tr, vx, hp| {
            let gp = FullGp::fit(tr, &RbfKernel::new(hp.lengthscale), hp.sigma2).ok()?;
            Some(gp.predict(vx).mean)
        })
        .unwrap();
        assert_eq!(out.best.lengthscale, 1.5);
        assert!(out.best_score < 1.0);
        assert_eq!(out.table.len(), 2);
    }

    #[test]
    fn failing_grid_points_skipped() {
        let data = gp_dataset(&SynthSpec::named("t", 60, 2), 2);
        let grid = vec![
            HyperParams { lengthscale: 1.0, sigma2: 0.1 },
            HyperParams { lengthscale: -1.0, sigma2: 0.1 }, // "fails"
        ];
        let out = grid_search(&data, 3, &grid, 3, |tr, vx, hp| {
            if hp.lengthscale < 0.0 {
                return None;
            }
            let gp = FullGp::fit(tr, &RbfKernel::new(hp.lengthscale), hp.sigma2).ok()?;
            Some(gp.predict(vx).mean)
        })
        .unwrap();
        assert_eq!(out.table.len(), 1);
        assert_eq!(out.best.lengthscale, 1.0);
    }

    /// Regression: when every grid point fails, the old code returned
    /// `best = grid[0]` with an infinite score as if CV had succeeded.
    #[test]
    fn all_points_failing_is_an_error() {
        let data = gp_dataset(&SynthSpec::named("t", 40, 2), 4);
        let grid = vec![
            HyperParams { lengthscale: 1.0, sigma2: 0.1 },
            HyperParams { lengthscale: 2.0, sigma2: 0.1 },
        ];
        let out = grid_search(&data, 3, &grid, 5, |_, _, _| None);
        assert!(out.is_err(), "got {out:?}");
    }
}
